//! Quickstart: the analytics API in ~40 lines.
//!
//! Pick a network, choose the paper's optimal partition for every conv
//! layer under a MAC budget, and quantify what the active memory
//! controller saves — the paper's Section II + III pipeline.
//!
//! Run: `cargo run --release --example quickstart`

use psim::analytics::bandwidth::{layer_bandwidth, ControllerMode};
use psim::analytics::partition::{partition_layer, Strategy};
use psim::analytics::sweep::network_bandwidth;
use psim::models::zoo;

fn main() {
    let net = zoo::resnet18();
    let p_macs = 2048;

    println!("== {} under a {}-MAC accelerator ==\n", net.name, p_macs);

    // Per-layer: the optimal (m, n) tile and its bandwidth split.
    println!("{:<18} {:>4} {:>4} {:>10} {:>10}", "layer", "m", "n", "B_i (M)", "B_o (M)");
    for layer in net.layers.iter().take(6) {
        let part = partition_layer(layer, p_macs, Strategy::Optimal, ControllerMode::Passive);
        let bw = layer_bandwidth(layer, part.m, part.n, ControllerMode::Passive);
        println!(
            "{:<18} {:>4} {:>4} {:>10.2} {:>10.2}",
            layer.name,
            part.m,
            part.n,
            bw.input / 1e6,
            bw.output / 1e6
        );
    }
    println!("... ({} layers total)\n", net.layers.len());

    // Network totals: the four Table I strategies.
    for s in Strategy::TABLE1 {
        let r = network_bandwidth(&net, p_macs, s, ControllerMode::Passive);
        println!("{:<12} {:>8.1} M activations/image", s.label(), r.total_mact());
    }

    // What the active controller saves (Fig. 2's y-axis).
    let passive = network_bandwidth(&net, p_macs, Strategy::Optimal, ControllerMode::Passive);
    let active = network_bandwidth(&net, p_macs, Strategy::Optimal, ControllerMode::Active);
    println!(
        "\nactive SRAM controller: {:.2} M -> {:.2} M  ({:.1}% bandwidth saved)",
        passive.total_mact(),
        active.total_mact(),
        (passive.total() - active.total()) / passive.total() * 100.0
    );
    println!(
        "floor (Table III)     : {:.3} M",
        net.min_bandwidth() as f64 / 1e6
    );
}
