//! Partition explorer: how the five strategies behave across MAC budgets,
//! and how much the paper's closed form (eq. 7 + integer adaptation)
//! gives away against the exhaustive discrete optimum — the ablation
//! DESIGN.md calls out.
//!
//! Run: `cargo run --release --example partition_explorer [network]`

use psim::analytics::bandwidth::ControllerMode;
use psim::analytics::partition::Strategy;
use psim::analytics::sweep::network_bandwidth;
use psim::models::zoo;

fn main() {
    let name = std::env::args().nth(1).unwrap_or_else(|| "GoogleNet".to_string());
    let net = zoo::by_name(&name).unwrap_or_else(|| {
        eprintln!("unknown network '{name}', using GoogleNet");
        zoo::googlenet()
    });
    let budgets = [256usize, 512, 1024, 2048, 4096, 8192, 16384, 65536];
    let strategies = [
        Strategy::MaxInput,
        Strategy::MaxOutput,
        Strategy::EqualMacs,
        Strategy::Optimal,
        Strategy::OptimalSearch,
    ];

    println!("== {} : total bandwidth (M activations) by strategy ==\n", net.name);
    print!("{:>8}", "P");
    for s in strategies {
        print!(" {:>12}", s.label());
    }
    println!(" {:>10}", "eq7 gap");
    let floor = net.min_bandwidth() as f64 / 1e6;

    for p in budgets {
        print!("{p:>8}");
        let mut formula = 0.0;
        let mut search = 0.0;
        for s in strategies {
            let t = network_bandwidth(&net, p, s, ControllerMode::Passive).total_mact();
            if s == Strategy::Optimal {
                formula = t;
            }
            if s == Strategy::OptimalSearch {
                search = t;
            }
            print!(" {t:>12.2}");
        }
        // The integer-adaptation cost: closed form vs discrete optimum.
        println!(" {:>9.2}%", (formula - search) / search * 100.0);
    }
    println!("\nfloor (Table III): {floor:.3} M — the search column approaches it as P grows");

    // Where does the optimum sit between the extremes? Show the crossover
    // structure the paper's Table I demonstrates.
    println!("\nwho wins at each budget (passive controller):");
    for p in budgets {
        let mut best = (f64::INFINITY, "");
        for s in [Strategy::MaxInput, Strategy::MaxOutput, Strategy::EqualMacs] {
            let t = network_bandwidth(&net, p, s, ControllerMode::Passive).total_mact();
            if t < best.0 {
                best = (t, s.label());
            }
        }
        let opt = network_bandwidth(&net, p, Strategy::Optimal, ControllerMode::Passive)
            .total_mact();
        println!(
            "  P={p:>6}: best heuristic = {:<11} {:>9.2} M | this work {:>9.2} M ({:+.1}%)",
            best.1,
            best.0,
            opt,
            (opt - best.0) / best.0 * 100.0
        );
    }
}
