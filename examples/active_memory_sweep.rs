//! Active-memory-controller sweep on the *event-level simulator* (not the
//! closed-form model): regenerates Fig. 2's saving curves from counted
//! transactions, and adds what the paper only argues qualitatively — the
//! energy impact of keeping psum read-backs inside the SRAM controller.
//!
//! Run: `cargo run --release --example active_memory_sweep`

use psim::analytics::bandwidth::ControllerMode;
use psim::analytics::partition::Strategy;
use psim::coordinator::parallel::{default_workers, parallel_map};
use psim::models::zoo;
use psim::sim::scheduler::{simulate_network, SimConfig};

fn main() {
    let budgets = [512usize, 1024, 2048, 4096, 8192, 16384];
    let nets = zoo::paper_networks();

    println!("== Fig. 2 from the simulator: % bandwidth saved by the active controller ==\n");
    print!("{:<12}", "CNN");
    for p in budgets {
        print!(" {p:>8}");
    }
    println!("  (energy saved @2048)");

    let rows = parallel_map(&nets, default_workers(), |net| {
        let mut cells = Vec::new();
        let mut energy_note = String::new();
        for p in budgets {
            let passive = simulate_network(
                net,
                &SimConfig::new(p, ControllerMode::Passive, Strategy::Optimal),
            )
            .stats;
            let active = simulate_network(
                net,
                &SimConfig::new(p, ControllerMode::Active, Strategy::Optimal),
            )
            .stats;
            let bw_saving = (passive.activation_traffic() as f64
                - active.activation_traffic() as f64)
                / passive.activation_traffic() as f64
                * 100.0;
            cells.push(bw_saving);
            if p == 2048 {
                let e_saving =
                    (passive.energy_pj - active.energy_pj) / passive.energy_pj * 100.0;
                energy_note = format!("{e_saving:.1}%");
            }
        }
        (net.name.clone(), cells, energy_note)
    });

    for (name, cells, energy) in rows {
        print!("{name:<12}");
        for v in cells {
            print!(" {v:>7.1}%");
        }
        println!("  {energy}");
    }

    println!(
        "\npaper's claim: 19-42% at 512 MACs, 2-38% at 16K. Savings shrink as P grows\n\
         because fewer psum passes are needed (M/m falls toward 1)."
    );
    println!(
        "note: energy saving is smaller than bandwidth saving — the active controller\n\
         still performs the read inside the SRAM array; only the interconnect hop is avoided."
    );
}
