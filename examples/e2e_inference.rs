//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! Loads the AOT artifacts (JAX/Pallas lowered to HLO by `make
//! artifacts`), starts the Rust coordinator (dynamic batcher + PJRT
//! engine), serves a closed-loop load of synthetic 32x32 images through
//! PsimNet, and reports latency/throughput — proving Python is not on the
//! request path.
//!
//! Also validates correctness without a Python oracle:
//!   1. batching invariance — a request served alone (b1 artifact) gets
//!      the same logits as the same image served inside a full batch
//!      (b8 artifact);
//!   2. determinism — identical images produce identical logits;
//!   3. linearity of the conv_step artifact — conv is linear in the psum:
//!      step(p, x, w) == step(0, x, w) + p, elementwise.
//!
//! Run: `make artifacts && cargo run --release --example e2e_inference`

use std::time::Instant;

use psim::coordinator::{InferenceService, ServiceConfig};
use psim::runtime::{ArtifactDir, Runtime, Tensor};

fn main() -> anyhow::Result<()> {
    let artifacts = ArtifactDir::open_default()?;
    println!(
        "artifacts: {} entries, fingerprint {}",
        artifacts.entries.len(),
        artifacts.fingerprint
    );

    // --- correctness gate 3: conv_step linearity (direct runtime use) ---
    {
        let mut rt = Runtime::new(artifacts.clone())?;
        let psum = Tensor::random(&[16, 32, 32], 11, 1.0);
        let x = Tensor::random(&[3, 34, 34], 12, 1.0);
        let w = Tensor::random(&[16, 3, 3, 3], 13, 0.5);
        let with_p = rt.execute("conv_step_l0", &[psum.clone(), x.clone(), w.clone()])?;
        let zero_p = rt.execute("conv_step_l0", &[Tensor::zeros(&[16, 32, 32]), x, w])?;
        let max_err = with_p[0]
            .data
            .iter()
            .zip(zero_p[0].data.iter().zip(&psum.data))
            .map(|(a, (b, p))| (a - (b + p)).abs())
            .fold(0.0f32, f32::max);
        anyhow::ensure!(max_err < 1e-4, "conv_step linearity violated: {max_err}");
        println!("conv_step linearity      : OK (max err {max_err:.2e})");
    }

    // --- the serving stack ---
    let service = InferenceService::start(artifacts, ServiceConfig::default())?;
    let img = |seed: u64| Tensor::random(&[3, 32, 32], seed, 1.0);

    // warmup compiles both batch artifacts on the engine thread
    let warm = service.infer(img(0))?;
    println!("warmup                   : class={} ({}us)", warm.top_class(), warm.latency_us);

    // --- correctness gate 1+2: batching invariance & determinism ---
    let solo = service.infer(img(777))?; // likely rides alone (b1)
    let mut rxs = Vec::new();
    for i in 0..8 {
        // 8 concurrent submissions coalesce into one b8 batch
        rxs.push(service.submit(img(if i == 3 { 777 } else { 1000 + i as u64 })));
    }
    let batched: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
    let twin = &batched[3];
    let max_dev = solo
        .logits
        .iter()
        .zip(&twin.logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    anyhow::ensure!(max_dev < 1e-4, "batching invariance violated: {max_dev}");
    println!("batching invariance      : OK (max logit dev {max_dev:.2e})");
    let again = service.infer(img(777))?;
    anyhow::ensure!(again.logits == solo.logits || {
        let d = again
            .logits
            .iter()
            .zip(&solo.logits)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        d < 1e-5
    });
    println!("determinism              : OK");

    // --- the measured run: closed-loop concurrent load ---
    let total = 256usize;
    let concurrency = 16usize;
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..concurrency {
            let service = &service;
            scope.spawn(move || {
                for i in 0..total / concurrency {
                    let _ = service.infer(img((c * 10_000 + i) as u64));
                }
            });
        }
    });
    let wall = t0.elapsed();
    let m = &service.metrics;
    println!("\n== e2e serving run (PsimNet over PJRT, Python off the path) ==");
    println!("requests                 : {total} at concurrency {concurrency}");
    println!("wall time                : {:.3} s", wall.as_secs_f64());
    println!("throughput               : {:.1} img/s", total as f64 / wall.as_secs_f64());
    println!("server metrics           : {}", m.summary());
    println!(
        "batching efficiency      : mean batch {:.2} (8 = perfect coalescing)",
        m.mean_batch_size()
    );
    Ok(())
}
