"""L2 model tests: PsimNet shapes, kernel-vs-reference equivalence, and
tiled_conv semantics (padding, relu, blocking)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels.ref import conv2d_ref


def test_tiled_conv_padding_and_relu():
    x = jnp.array(np.random.RandomState(0).randn(4, 8, 8), dtype=jnp.float32)
    w = jnp.array(np.random.RandomState(1).randn(6, 4, 3, 3), dtype=jnp.float32)
    got = model.tiled_conv(x, w, m_block=2, pad=1, relu=True)
    want = jnp.maximum(conv2d_ref(x, w, pad=1), 0.0)
    assert got.shape == (6, 8, 8)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_max_pool2():
    x = jnp.arange(16.0).reshape(1, 4, 4)
    out = model.max_pool2(x)
    np.testing.assert_allclose(out[0], [[5.0, 7.0], [13.0, 15.0]])


def test_psimnet_param_shapes():
    shapes = dict(model.psimnet_param_shapes())
    assert shapes["conv1"] == (16, 3, 3, 3)
    assert shapes["conv2"] == (32, 16, 3, 3)
    assert shapes["conv3"] == (64, 32, 3, 3)
    assert shapes["head"] == (10, 64, 1, 1)


@pytest.mark.parametrize("batch", [1, 3])
def test_psimnet_infer_matches_reference(batch):
    params = model.psimnet_init(seed=42)
    x = jnp.array(
        np.random.RandomState(7).randn(batch, *model.PSIMNET_INPUT),
        dtype=jnp.float32,
    )
    got = model.psimnet_infer(x, *params)
    want = model.psimnet_reference(x, *params)
    assert got.shape == (batch, model.PSIMNET_CLASSES)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


def test_psimnet_init_deterministic():
    a = model.psimnet_init(seed=1)
    b = model.psimnet_init(seed=1)
    for pa, pb in zip(a, b, strict=True):
        np.testing.assert_array_equal(pa, pb)
    c = model.psimnet_init(seed=2)
    assert any(
        not np.array_equal(pa, pc) for pa, pc in zip(a, c, strict=True)
    )
