"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracle.

hypothesis sweeps shapes/dtypes; assert_allclose against ref.py — the
core correctness signal for everything the Rust runtime later executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.active_update import active_update
from compile.kernels.conv_psum import conv_psum, conv_psum_step
from compile.kernels.ref import (
    active_update_ref,
    conv2d_ref,
    conv_psum_ref,
    tiled_conv_ref,
)

jax.config.update("jax_enable_x64", False)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=dtype)


# ---------------------------------------------------------------------------
# conv_psum: full tiled conv vs dense reference
# ---------------------------------------------------------------------------

shape_params = st.tuples(
    st.sampled_from([1, 2, 3, 4, 8, 16]),  # M
    st.sampled_from([1, 2, 4, 8, 16]),  # N
    st.sampled_from([1, 3, 5]),  # K
    st.integers(min_value=6, max_value=14),  # H=W
)


@settings(max_examples=25, deadline=None)
@given(shape_params, st.integers(0, 3))
def test_conv_psum_matches_ref(params, seed):
    m, n, k, h = params
    if h < k:
        h = k
    x = rand(seed, (m, h, h))
    w = rand(seed + 100, (n, m, k, k))
    got = conv_psum(x, w)
    want = conv2d_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(
    st.sampled_from([(4, 1), (4, 2), (4, 4), (8, 2), (16, 4), (16, 8)]),
    st.sampled_from([1, 3]),
    st.integers(0, 2),
)
def test_conv_psum_blocking_invariant(mb, k, seed):
    """Any m_block must give the same answer (psum chain correctness)."""
    m, m_block = mb
    x = rand(seed, (m, 10, 10))
    w = rand(seed + 7, (4, m, k, k))
    full = conv_psum(x, w)  # single pass
    blocked = conv_psum(x, w, m_block=m_block)
    np.testing.assert_allclose(blocked, full, rtol=2e-5, atol=2e-5)


def test_conv_psum_rejects_non_divisor_block():
    x = rand(0, (6, 8, 8))
    w = rand(1, (2, 6, 3, 3))
    with pytest.raises(AssertionError):
        conv_psum(x, w, m_block=4)


def test_conv_psum_rejects_channel_mismatch():
    x = rand(0, (6, 8, 8))
    w = rand(1, (2, 5, 3, 3))
    with pytest.raises(AssertionError):
        conv_psum(x, w)


# ---------------------------------------------------------------------------
# conv_psum_step: the runtime-artifact entry point
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 4))
def test_conv_psum_step_matches_ref(seed):
    psum = rand(seed, (8, 6, 6))
    x = rand(seed + 1, (4, 8, 8))
    w = rand(seed + 2, (8, 4, 3, 3))
    got = conv_psum_step(psum, x, w)
    want = conv_psum_ref(psum, x, w)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_chained_steps_equal_full_conv():
    """Section II's loop: chaining step() over ci blocks == dense conv."""
    x = rand(3, (12, 9, 9))
    w = rand(4, (5, 12, 3, 3))
    psum = jnp.zeros((5, 7, 7))
    for ci in range(0, 12, 4):
        psum = conv_psum_step(psum, x[ci : ci + 4], w[:, ci : ci + 4])
    np.testing.assert_allclose(psum, conv2d_ref(x, w), rtol=2e-5, atol=2e-5)


def test_tiled_conv_ref_self_consistent():
    x = rand(5, (8, 10, 10))
    w = rand(6, (3, 8, 3, 3))
    np.testing.assert_allclose(
        tiled_conv_ref(x, w, 2, pad=1), conv2d_ref(x, w, pad=1), rtol=2e-5, atol=2e-5
    )


# ---------------------------------------------------------------------------
# active_update: the controller op
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    st.sampled_from([(4, 4), (16, 9), (64, 30)]),
    st.booleans(),
    st.integers(0, 3),
)
def test_active_update_matches_ref(shape, relu, seed):
    c, s = shape
    a = rand(seed, (c, s, s))
    b = rand(seed + 9, (c, s, s))
    got = active_update(a, b, relu=relu)
    want = active_update_ref(a, b, relu=relu)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_active_update_relu_clamps():
    a = jnp.full((2, 2, 2), -3.0)
    b = jnp.full((2, 2, 2), 1.0)
    out = active_update(a, b, relu=True)
    assert float(out.max()) == 0.0
