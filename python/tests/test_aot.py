"""AOT pipeline tests: HLO text is produced, parseable-looking, and the
manifest indexes every entry point with correct shapes."""

import json
import os

import jax.numpy as jnp
import pytest

from compile import aot, model


def test_entry_points_cover_contract():
    names = [name for name, _fn, _specs in aot.entry_points()]
    assert "psimnet_b1" in names
    assert "psimnet_b8" in names
    assert "active_update" in names
    assert sum(n.startswith("conv_step_l") for n in names) == len(
        model.PSIMNET_LAYERS
    )


def test_to_hlo_text_emits_hlo():
    text = aot.to_hlo_text(lambda a, b: (a @ b,),
                           jnp.zeros((4, 4)), jnp.zeros((4, 4)))
    assert "HloModule" in text
    assert "dot(" in text or "dot " in text


def test_fingerprint_stable_and_sensitive(tmp_path):
    fp1 = aot.input_fingerprint()
    fp2 = aot.input_fingerprint()
    assert fp1 == fp2
    assert len(fp1) == 16


@pytest.mark.slow
def test_full_aot_build(tmp_path):
    """End-to-end: build all artifacts into a temp dir, check manifest."""
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path), "--force"]
    try:
        assert aot.main() == 0
    finally:
        sys.argv = argv

    with open(tmp_path / "manifest.json") as f:
        manifest = json.load(f)
    entries = {e["name"]: e for e in manifest["entries"]}
    assert set(entries) == {n for n, _f, _s in aot.entry_points()}
    b8 = entries["psimnet_b8"]
    assert b8["inputs"][0]["shape"] == [8, 3, 32, 32]
    assert b8["outputs"][0]["shape"] == [8, 10]
    for e in manifest["entries"]:
        path = tmp_path / e["file"]
        assert path.exists()
        head = path.read_text()[:200]
        assert "HloModule" in head

    # second run without --force is a no-op (freshness check)
    sys.argv = ["aot", "--out-dir", str(tmp_path)]
    try:
        assert aot.main() == 0
    finally:
        sys.argv = argv
