"""Build-time compile package: L2 JAX model + L1 Pallas kernels + AOT.

Nothing here runs at inference time — `make artifacts` lowers the model
to HLO text once, and the Rust runtime executes the artifacts via PJRT.
"""
