"""Layer-2: the JAX compute graph the Rust coordinator executes.

Two entry-point families, both calling the L1 Pallas kernels so they
lower into the same HLO:

* `tiled_conv` / `conv_psum_step` — single-layer building blocks used by
  the runtime microbenches and the sim-vs-functional cross-checks.
* `PsimNet` — a small CNN (32x32 RGB -> 10 classes) whose every conv runs
  through the tiled partial-sum kernel. This is the end-to-end workload:
  the Rust coordinator loads its AOT artifact and serves batched inference
  requests over it.

Python never runs at inference time; everything here is lowered once by
`aot.py` to HLO text.
"""

import jax
import jax.numpy as jnp

from .kernels.active_update import active_update
from .kernels.conv_psum import conv_psum, conv_psum_step  # noqa: F401


def tiled_conv(x, w, *, m_block=None, pad: int = 0, relu: bool = False):
    """Full convolution computed as partial-sum accumulation.

    Args:
      x: [M, H, W] input maps.
      w: [N, M, K, K] weights.
      m_block: input-channel block size (Section II's `m`); None = all.
      pad: symmetric zero padding.
      relu: apply the controller-side activation on the final psum.
    """
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    out = conv_psum(x, w, m_block=m_block)
    if relu:
        # The paper's controller applies the activation on the last
        # accumulation; standalone-kernel form keeps that datapath honest.
        out = active_update(jnp.zeros_like(out), out, relu=True)
    return out


def max_pool2(x):
    """2x2 max pool, stride 2, over [C, H, W] (H, W even)."""
    c, h, w = x.shape
    return jnp.max(x.reshape(c, h // 2, 2, w // 2, 2), axis=(2, 4))


# ---------------------------------------------------------------------------
# PsimNet: the end-to-end workload.
# ---------------------------------------------------------------------------

#: (name, cin, cout, k, pad, m_block) — m_block mirrors an optimal-ish
#: partition (divisors of cin) so the AOT graph exercises real psum chains.
PSIMNET_LAYERS = (
    ("conv1", 3, 16, 3, 1, 3),
    ("conv2", 16, 32, 3, 1, 8),
    ("conv3", 32, 64, 3, 1, 8),
)
PSIMNET_CLASSES = 10
PSIMNET_INPUT = (3, 32, 32)


def psimnet_param_shapes():
    """Ordered (name, shape) of every parameter tensor."""
    shapes = []
    for name, cin, cout, k, _pad, _mb in PSIMNET_LAYERS:
        shapes.append((name, (cout, cin, k, k)))
    shapes.append(("head", (PSIMNET_CLASSES, 64, 1, 1)))
    return shapes


def psimnet_infer(x, w1, w2, w3, w_head):
    """Forward pass: [B, 3, 32, 32] -> [B, 10] logits.

    conv(3->16) relu pool -> conv(16->32) relu pool -> conv(32->64) relu
    -> global average pool -> 1x1 conv head.
    """

    def one(img):
        h = img
        for (name, _cin, _cout, k, pad, mb), w in zip(
            PSIMNET_LAYERS, (w1, w2, w3), strict=True
        ):
            h = tiled_conv(h, w, m_block=mb, pad=pad, relu=True)
            if name in ("conv1", "conv2"):
                h = max_pool2(h)
        # global average pool -> [64, 1, 1]
        h = jnp.mean(h, axis=(1, 2), keepdims=True)
        logits = conv_psum(h, w_head)  # 1x1 conv == matmul over channels
        return logits[:, 0, 0]

    return jax.vmap(one)(x)


def psimnet_init(seed: int = 0):
    """He-style init for PsimNet, deterministic in `seed`."""
    key = jax.random.PRNGKey(seed)
    params = []
    for _name, shape in psimnet_param_shapes():
        key, sub = jax.random.split(key)
        fan_in = shape[1] * shape[2] * shape[3]
        params.append(
            jax.random.normal(sub, shape, dtype=jnp.float32)
            * jnp.sqrt(2.0 / fan_in)
        )
    return params


def psimnet_reference(x, w1, w2, w3, w_head):
    """Pure-jnp PsimNet (no Pallas) — the oracle for the AOT artifact."""
    from .kernels.ref import conv2d_ref

    def one(img):
        h = img
        for (name, _cin, _cout, _k, pad, _mb), w in zip(
            PSIMNET_LAYERS, (w1, w2, w3), strict=True
        ):
            h = jnp.maximum(conv2d_ref(h, w, pad=pad), 0.0)
            if name in ("conv1", "conv2"):
                h = max_pool2(h)
        h = jnp.mean(h, axis=(1, 2), keepdims=True)
        return conv2d_ref(h, w_head)[:, 0, 0]

    return jax.vmap(one)(x)
