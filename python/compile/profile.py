"""L1/L2 profiling: XLA cost analysis of the lowered entry points and a
VMEM/MXU structure estimate for the Pallas kernel's BlockSpecs.

interpret=True gives CPU-numpy timings only (not a TPU proxy), so the
perf pass optimizes *structure*: contraction depth feeding the MXU, VMEM
residency of the psum accumulator, HLO op mix after fusion. This script
prints those numbers for EXPERIMENTS.md §Perf.

Usage: cd python && python -m compile.profile
"""

import jax

from . import aot, model


def cost_analysis(fn, *specs):
    compiled = jax.jit(fn).lower(*specs).compile()
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        return ca
    except Exception as e:  # pragma: no cover - backend-dependent
        return {"error": str(e)}


def vmem_estimate_bytes(m_block, n, h, w, k, ho, wo, dtype_bytes=4):
    """Per-grid-step VMEM residency of conv_psum's blocks."""
    x_tile = m_block * h * w * dtype_bytes
    w_tile = n * m_block * k * k * dtype_bytes
    psum = n * ho * wo * dtype_bytes
    patches = (ho * wo) * (m_block * k * k) * dtype_bytes  # im2col lhs
    return {
        "x_tile": x_tile,
        "w_tile": w_tile,
        "psum_resident": psum,
        "im2col_lhs": patches,
        "total": x_tile + w_tile + psum + patches,
    }


def main():
    print("== XLA cost analysis (CPU backend, post-fusion) ==")
    for name, fn, specs in aot.entry_points():
        ca = cost_analysis(fn, *specs)
        flops = ca.get("flops", float("nan"))
        bytes_ = ca.get("bytes accessed", float("nan"))
        ai = flops / bytes_ if bytes_ else float("nan")
        print(f"{name:>16}: {flops:>14.0f} flops  {bytes_:>12.0f} bytes  AI={ai:6.2f}")

    print("\n== Pallas conv_psum VMEM footprint per grid step ==")
    spatial = {"conv1": 32, "conv2": 16, "conv3": 8}
    for lname, cin, cout, k, pad, mb in model.PSIMNET_LAYERS:
        s = spatial[lname]
        h = s + 2 * pad
        ho = h - k + 1
        est = vmem_estimate_bytes(mb, cout, h, h, k, ho, ho)
        # MXU structure: contraction depth per matmul
        print(
            f"{lname}: m_block={mb} -> VMEM {est['total']/1024:.1f} KiB "
            f"(psum resident {est['psum_resident']/1024:.1f} KiB), "
            f"contraction depth m*K^2={mb*k*k} "
            f"(vs {mb} for per-tap) of MXU-native 128"
        )
    print(
        "\n(16 MiB VMEM budget per TensorCore: all blocks fit with >100x headroom;\n"
        " on real hardware m_block could grow to ~128 — the analytical\n"
        " optimizer in rust picks m from bandwidth, not VMEM, at these sizes.)"
    )


if __name__ == "__main__":
    main()
