"""Pure-jnp correctness oracles for the Pallas kernels.

These define the semantics the kernels must match (fp32, same contraction
up to float reassociation). pytest + hypothesis compare kernel outputs
against these on randomized shapes — the CORE correctness signal of the
build-time stack.
"""

import jax.numpy as jnp
from jax import lax


def conv2d_ref(x, w, *, pad: int = 0):
    """Dense 2-D convolution, stride 1.

    Args:
      x: [M, H, W]    input feature maps.
      w: [N, M, K, K] weights.
      pad: symmetric zero padding applied to x.

    Returns:
      [N, Ho, Wo] with Ho = H + 2*pad - K + 1.
    """
    xb = x[None]  # NCHW with batch 1
    out = lax.conv_general_dilated(
        xb,
        w,
        window_strides=(1, 1),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out[0]


def conv_psum_ref(psum, x_tile, w_tile):
    """One partial-sum update: psum += conv(x_tile, w_tile), valid padding.

    Args:
      psum:   [N, Ho, Wo]  previous partial sums.
      x_tile: [m, H, W]    the m input maps of this iteration (pre-padded).
      w_tile: [N, m, K, K] the weight slice for these maps.
    """
    return psum + conv2d_ref(x_tile, w_tile, pad=0)


def tiled_conv_ref(x, w, m_block: int, *, pad: int = 0):
    """Full conv computed the accelerator's way: iterate input-channel
    blocks of size `m_block`, accumulating partial sums (Section II's
    loop nest). Equals `conv2d_ref(x, w, pad=pad)` up to reassociation.
    """
    M = x.shape[0]
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    k = w.shape[-1]
    ho = x.shape[1] - k + 1
    wo = x.shape[2] - k + 1
    psum = jnp.zeros((w.shape[0], ho, wo), dtype=x.dtype)
    for ci in range(0, M, m_block):
        xs = x[ci : ci + m_block]
        ws = w[:, ci : ci + m_block]
        psum = conv_psum_ref(psum, xs, ws)
    return psum


def active_update_ref(stored, incoming, *, relu: bool):
    """The active controller's read-update-write: stored + incoming,
    optionally through ReLU (the final accumulation of a layer)."""
    out = stored + incoming
    return jnp.maximum(out, 0.0) if relu else out
