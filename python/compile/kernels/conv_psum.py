"""The tiled partial-sum convolution as a Pallas kernel.

This is the PE-array hot-spot of the paper's accelerator: one `(m, n)`
tile iteration computes `n` output maps' partial sums from `m` input maps
and accumulates into the stored psums. The Pallas grid iterates the
input-channel blocks (the `ci` loop of Section II); the **psum block's
index map is constant across that grid dimension, so the accumulator
stays resident in VMEM** — the on-TPU analogue of the paper's active
memory controller (the psum never round-trips to HBM between updates).

Hardware adaptation (paper -> TPU):
  * SRAM scratchpad + active controller  ->  VMEM-resident accumulator
    block (BlockSpec with constant index map over the reduction grid).
  * `K^2 * m * n <= P` MAC budget        ->  the `m`-contraction matmul
    feeding the MXU: each (k1, k2) tap is a `[Ho*Wo, m] x [m, n]` matmul.
  * AXI bursts                            ->  HBM->VMEM block transfers
    expressed by the BlockSpecs.

Lowered with ``interpret=True`` — the CPU PJRT plugin cannot execute
Mosaic custom-calls; on a real TPU the same kernel lowers natively.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _accumulate_taps(x, w, o_ref, *, k: int, ho: int, wo: int):
    """o_ref += conv(x, w) as ONE im2col matmul.

    x: [m, H, W] (H = ho+k-1), w: [n, m, k, k], o_ref block: [n, ho, wo].

    Perf (EXPERIMENTS.md §Perf L1-1): the first version issued K^2
    separate matmuls with contraction depth `m` (3..8 here — far below
    the MXU's native 128). Gathering the K^2 shifted patches into a
    single `[ho*wo, m*K^2]` im2col operand makes one matmul with
    contraction depth `m*K^2` (27..72): 9x fewer MXU dispatches and a
    9x deeper (better-utilized) systolic pass for 3x3 kernels. FLOPs are
    identical; numerics verified against ref.py by pytest.
    """
    m_blk = x.shape[0]
    n_blk = w.shape[0]
    # [k*k, m, ho, wo] shifted patches, gathered once.
    patches = jnp.stack(
        [
            x[:, k1 : k1 + ho, k2 : k2 + wo]
            for k1 in range(k)
            for k2 in range(k)
        ]
    )
    # lhs: [ho*wo, m*k*k]  (contraction axis ordered (k1,k2,m))
    lhs = patches.reshape(k * k * m_blk, ho * wo).T
    # rhs: [m*k*k, n] with the same (k1,k2,m) ordering.
    rhs = w.transpose(2, 3, 1, 0).reshape(k * k * m_blk, n_blk)
    acc = jax.lax.dot_general(
        lhs,
        rhs,
        (((1,), (0,)), ((), ())),
        preferred_element_type=o_ref.dtype,
    )
    o_ref[...] += acc.T.reshape(n_blk, ho, wo)


def _conv_psum_kernel(x_ref, w_ref, o_ref, *, k: int, ho: int, wo: int):
    """One grid step: o += conv(x_block, w_block), valid padding, stride 1.

    Block shapes:
      x_ref: [m_blk, H, W]     (H = ho + k - 1, W = wo + k - 1)
      w_ref: [n_blk, m_blk, k, k]
      o_ref: [n_blk, ho, wo]   accumulator, resident across grid steps.
    """
    # Zero the accumulator on the first input-channel block (MemOp::Init).
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    _accumulate_taps(x_ref[...], w_ref[...], o_ref, k=k, ho=ho, wo=wo)


def conv_psum(x, w, *, m_block: int | None = None, interpret: bool = True):
    """Tiled conv: full `[N, Ho, Wo]` output from `[M, H, W]` x `[N, M, K, K]`.

    The input-channel dimension is processed in blocks of `m_block`
    (default: all of M in one pass), accumulating partial sums in a
    VMEM-resident block across the Pallas grid — Section II's `ci` loop.

    Valid padding, stride 1 (pad in the caller; see model.py).
    """
    M, H, W = x.shape
    N, Mw, k, k2 = w.shape
    assert M == Mw, f"channel mismatch {M} vs {Mw}"
    assert k == k2, "square kernels only"
    if m_block is None:
        m_block = M
    assert M % m_block == 0, f"m_block {m_block} must divide M {M}"
    ho, wo = H - k + 1, W - k + 1
    grid = (M // m_block,)

    kernel = functools.partial(_conv_psum_kernel, k=k, ho=ho, wo=wo)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # input-channel block ci of x ...
            pl.BlockSpec((m_block, H, W), lambda ci: (ci, 0, 0)),
            # ... and the matching weight slice (all N output maps)
            pl.BlockSpec((N, m_block, k, k), lambda ci: (0, ci, 0, 0)),
        ],
        # constant index map: the psum block stays resident across ci.
        out_specs=pl.BlockSpec((N, ho, wo), lambda ci: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, ho, wo), x.dtype),
        interpret=interpret,
    )(x, w)


def conv_psum_step(psum, x_tile, w_tile, *, interpret: bool = True):
    """One explicit partial-sum update (the runtime-artifact entry point):
    `psum + conv(x_tile, w_tile)` with the addition fused into the kernel's
    accumulator — what the accelerator's MAC block + active controller do
    in one iteration.

    Shapes: psum [N, Ho, Wo], x_tile [m, H, W], w_tile [N, m, K, K].
    """
    N, ho, wo = psum.shape
    m, H, W = x_tile.shape
    k = w_tile.shape[-1]
    assert (ho, wo) == (H - k + 1, W - k + 1), "psum/tile shape mismatch"

    def kernel(p_ref, x_ref, w_ref, o_ref):
        o_ref[...] = p_ref[...]
        _accumulate_taps(x_ref[...], w_ref[...], o_ref, k=k, ho=ho, wo=wo)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(psum.shape, psum.dtype),
        interpret=interpret,
    )(psum, x_tile, w_tile)
