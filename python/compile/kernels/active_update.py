"""The active memory controller's read-update-write as a Pallas kernel.

Section III offloads two ops into the SRAM controller: **Addition** of an
incoming partial sum to the stored one, and optionally the **Activation**
(ReLU) on the final accumulation. This kernel is that datapath — used by
the L2 model for the final psum pass and exported as its own artifact so
the Rust runtime (and benches) can exercise the controller op in
isolation.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _update_kernel(stored_ref, incoming_ref, o_ref, *, relu: bool):
    out = stored_ref[...] + incoming_ref[...]
    if relu:
        out = jnp.maximum(out, 0.0)
    o_ref[...] = out


def active_update(stored, incoming, *, relu: bool, interpret: bool = True):
    """stored + incoming, optionally through ReLU. Any matching shapes."""
    assert stored.shape == incoming.shape, "operand shape mismatch"
    kernel = functools.partial(_update_kernel, relu=relu)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(stored.shape, stored.dtype),
        interpret=interpret,
    )(stored, incoming)
