"""Layer-1 Pallas kernels (build-time only; never imported at runtime).

`conv_psum` is the accelerator's per-iteration hot-spot: a tiled
convolution that accumulates partial sums across input-channel blocks,
with the psum block kept resident across grid steps — the in-kernel
analogue of the paper's active memory controller. `active_update` is the
controller's read-update-write (add + optional ReLU) as a standalone
kernel. `ref` holds the pure-jnp oracles used by pytest.
"""
