"""AOT pipeline: lower the L2 entry points to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which the xla_extension 0.5.1
backing the Rust `xla` crate rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Outputs (under --out-dir, default ../artifacts):
  <name>.hlo.txt     one per entry point
  manifest.json      entry-point index: inputs/outputs shapes + dtypes

Entry points:
  psimnet_b{1,8}     PsimNet batched inference (the serving workload)
  conv_step_l{0,1,2} one partial-sum update per PsimNet layer shape
  active_update      the controller op (add + ReLU) on a 64x30x30 block

Usage: cd python && python -m compile.aot [--out-dir DIR] [--force]
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.active_update import active_update
from .kernels.conv_psum import conv_psum_step


def to_hlo_text(fn, *args) -> str:
    """Lower a jittable fn at the given abstract args to HLO text."""
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def entry_points():
    """(name, fn, abstract args) for every artifact."""
    eps = []

    # --- PsimNet inference at the batch sizes the coordinator serves ---
    wspecs = [spec(s) for _n, s in model.psimnet_param_shapes()]
    for b in (1, 8):
        eps.append(
            (
                f"psimnet_b{b}",
                model.psimnet_infer,
                [spec((b, *model.PSIMNET_INPUT)), *wspecs],
            )
        )

    # --- single partial-sum steps, one per PsimNet conv shape ---
    # Spatial dims after the preceding pools: 32, 16, 8 (padded +2).
    spatial = {"conv1": 32, "conv2": 16, "conv3": 8}
    for i, (name, cin, cout, k, pad, mb) in enumerate(model.PSIMNET_LAYERS):
        s = spatial[name]
        h = s + 2 * pad
        ho = h - k + 1
        eps.append(
            (
                f"conv_step_l{i}",
                conv_psum_step,
                [
                    spec((cout, ho, ho)),  # psum
                    spec((mb, h, h)),  # x tile (m_block channels)
                    spec((cout, mb, k, k)),  # w tile
                ],
            )
        )

    # --- the controller op in isolation ---
    eps.append(
        (
            "active_update",
            lambda a, b: active_update(a, b, relu=True),
            [spec((64, 30, 30)), spec((64, 30, 30))],
        )
    )
    return eps


def input_fingerprint() -> str:
    """Hash of every compile-path source file — artifact staleness key."""
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _dirs, files in sorted(os.walk(here)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()[:16]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--force", action="store_true", help="rebuild even if fresh")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    fp = input_fingerprint()
    if not args.force and os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                if json.load(f).get("fingerprint") == fp:
                    print(f"artifacts fresh (fingerprint {fp}); skipping")
                    return 0
        except (json.JSONDecodeError, OSError):
            pass

    manifest = {"fingerprint": fp, "entries": []}
    for name, fn, specs in entry_points():
        text = to_hlo_text(fn, *specs)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_shape = jax.eval_shape(fn, *specs)
        outs = jax.tree_util.tree_leaves(out_shape)
        manifest["entries"].append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "inputs": [
                    {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
                ],
                "outputs": [
                    {"shape": list(o.shape), "dtype": str(o.dtype)} for o in outs
                ],
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {manifest_path} ({len(manifest['entries'])} entries)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
