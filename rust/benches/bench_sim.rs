//! Simulator performance: how fast the event-level machine processes
//! whole networks and single layers — the L3 hot path the perf pass
//! optimizes (see EXPERIMENTS.md §Perf).

use psim::analytics::bandwidth::ControllerMode;
use psim::analytics::partition::Strategy;
use psim::models::zoo;
use psim::sim::scheduler::{simulate_layer, simulate_network, SimConfig};
use psim::util::benchkit::Bench;

fn main() {
    let mut b = Bench::new();

    let resnet50 = zoo::resnet50().dense_equivalent();
    let cfg_a = SimConfig::new(2048, ControllerMode::Active, Strategy::Optimal);
    let cfg_p = SimConfig::new(2048, ControllerMode::Passive, Strategy::OptimalSearch);

    // Whole-network simulations (the sweep workhorse).
    let layers = resnet50.layers.len() as u64;
    b.run_throughput("sim ResNet-50 active/optimal (layers/s)", layers, || {
        simulate_network(&resnet50, &cfg_a)
    });
    b.run_throughput("sim ResNet-50 passive/search (layers/s)", layers, || {
        simulate_network(&resnet50, &cfg_p)
    });

    // The transaction-heavy case: tiny tiles -> many iterations.
    let vgg = zoo::vgg16();
    let conv2_1 = vgg.layer("conv2_1").unwrap().clone();
    let cfg_small = SimConfig::new(256, ControllerMode::Passive, Strategy::MaxOutput);
    b.run("sim vgg conv2_1 @P=256 (psum-storm case)", || {
        simulate_layer(&conv2_1, &cfg_small)
    });

    // Full eight-network Table II regeneration through the simulator.
    let nets = zoo::paper_networks();
    b.run("sim all-8-networks x P=2048 x 2 modes", || {
        for net in &nets {
            for mode in ControllerMode::ALL {
                let cfg = SimConfig::new(2048, mode, Strategy::Optimal);
                simulate_network(net, &cfg);
            }
        }
    });

    // Partitioning itself (the analytics hot loop inside every sim call).
    b.run("partition all-8-networks x 6 budgets (search)", || {
        for net in &nets {
            for p in [512usize, 1024, 2048, 4096, 8192, 16384] {
                for layer in &net.layers {
                    psim::analytics::partition::partition_layer(
                        layer,
                        p,
                        Strategy::OptimalSearch,
                        ControllerMode::Passive,
                    );
                }
            }
        }
    });
    b.finish();
}
