//! Ablation studies DESIGN.md calls out — printed as tables, then timed.
//!
//! A1. Integer adaptation: how much the paper's closed form (eq. 7 +
//!     divisor snapping) gives away vs the exhaustive discrete optimum.
//! A2. Group awareness: faithful grouped partitioning vs the paper's
//!     dense-equivalent treatment (ResNeXt-50 / MNASNet).
//! A3. Fusion extension: the paper's "no fused operations" assumption,
//!     quantified (perfect-fusion floor + required on-chip buffer).
//! A4. Bus width: beats/cycles sensitivity of the simulator's interconnect.

use psim::analytics::bandwidth::ControllerMode;
use psim::analytics::extensions::{fusion_bound, per_image_traffic, weight_traffic};
use psim::analytics::partition::Strategy;
use psim::analytics::sweep::network_bandwidth;
use psim::models::zoo;
use psim::sim::interconnect::BusConfig;
use psim::sim::scheduler::{simulate_network, SimConfig};
use psim::util::benchkit::Bench;
use psim::util::tablefmt::Table;

fn main() {
    // ---- A1: closed form vs discrete optimum -------------------------
    println!("== A1: eq.7 + integer adaptation vs exhaustive search ==");
    let mut t = Table::new(vec!["CNN", "P", "formula (M)", "search (M)", "gap"]);
    for net in zoo::paper_networks() {
        for p in [512usize, 2048, 16384] {
            let f = network_bandwidth(&net, p, Strategy::Optimal, ControllerMode::Passive)
                .total_mact();
            let s = network_bandwidth(&net, p, Strategy::OptimalSearch, ControllerMode::Passive)
                .total_mact();
            t.row(vec![
                net.name.clone(),
                p.to_string(),
                format!("{f:.2}"),
                format!("{s:.2}"),
                format!("{:+.2}%", (f - s) / s * 100.0),
            ]);
        }
    }
    print!("{}", t.to_markdown());

    // ---- A2: faithful groups vs dense-equivalent ----------------------
    println!("\n== A2: group-aware partitioning vs dense-equivalent (P=2048) ==");
    let mut t = Table::new(vec!["CNN", "dense-equiv (M)", "faithful (M)", "saving"]);
    for (f, d) in zoo::faithful_networks().iter().zip(zoo::paper_networks().iter()) {
        if f.name == "VGG-16" {
            continue; // config D vs B: not the same layer set
        }
        let dense = network_bandwidth(d, 2048, Strategy::OptimalSearch, ControllerMode::Passive)
            .total_mact();
        let faith = network_bandwidth(f, 2048, Strategy::OptimalSearch, ControllerMode::Passive)
            .total_mact();
        t.row(vec![
            f.name.clone(),
            format!("{dense:.2}"),
            format!("{faith:.2}"),
            format!("{:.1}%", (dense - faith) / dense * 100.0),
        ]);
    }
    print!("{}", t.to_markdown());
    println!("(groups shrink the psum accumulation domain: exploiting them is free bandwidth)");

    // ---- A3: fusion extension ----------------------------------------
    println!("\n== A3: perfect-fusion floor (relaxing the paper's assumption 1) ==");
    let mut t = Table::new(vec![
        "CNN",
        "unfused floor (M)",
        "fused floor (M)",
        "saving",
        "buffer (M elems)",
        "w/ batch-8 weights (M/img)",
    ]);
    for net in zoo::paper_networks() {
        let f = fusion_bound(&net);
        let w = weight_traffic(&net);
        t.row(vec![
            net.name.clone(),
            format!("{:.3}", f.unfused / 1e6),
            format!("{:.3}", f.fused / 1e6),
            format!("{:.1}%", f.saving_fraction() * 100.0),
            format!("{:.2}", f.required_buffer_elems as f64 / 1e6),
            format!("{:.3}", per_image_traffic(f.fused, w, 8) / 1e6),
        ]);
    }
    print!("{}", t.to_markdown());

    // ---- A4: bus-width sensitivity ------------------------------------
    println!("\n== A4: interconnect width vs bus cycles (ResNet-18, P=2048, active) ==");
    let net = zoo::resnet18();
    let mut t = Table::new(vec!["bus bytes", "beats", "bus cycles", "total cycles"]);
    for bus_bytes in [4usize, 8, 16, 32, 64] {
        let mut cfg = SimConfig::new(2048, ControllerMode::Active, Strategy::Optimal);
        cfg.bus = BusConfig { bus_bytes, ..BusConfig::default() };
        let s = simulate_network(&net, &cfg).stats;
        t.row(vec![
            bus_bytes.to_string(),
            s.bus_beats.to_string(),
            s.bus_cycles.to_string(),
            s.total_cycles().to_string(),
        ]);
    }
    print!("{}", t.to_markdown());
    println!("(compute-bound once the bus stops being the max() term — the overlap model)");

    // ---- A5: spatial tiling (halo) extension ---------------------------
    println!("\n== A5: row-stripe tiling — halo overhead vs on-chip budget ==");
    println!("(VGG conv2_1: 112x112, 64->128, k3/s1 — the paper's model assumes full-plane)");
    let conv2_1 = zoo::vgg16().layer("conv2_1").unwrap().clone();
    let mut t = Table::new(vec!["SRAM budget (KiB, fp16)", "stripe rows", "halo overhead"]);
    for budget_kib in [16usize, 32, 64, 128, 256, 1024] {
        let budget_elems = (budget_kib * 1024 / 2) as u64;
        match psim::analytics::spatial::max_stripe_within(&conv2_1, 16, 8, budget_elems) {
            Some((rows, ov)) => t.row(vec![
                budget_kib.to_string(),
                rows.to_string(),
                format!("{:.1}%", ov * 100.0),
            ]),
            None => t.row(vec![budget_kib.to_string(), "-".into(), "does not fit".into()]),
        };
    }
    print!("{}", t.to_markdown());
    println!("(halo input re-reads are the price of small spatial tiles — a term eq. 2 omits)");

    // ---- timings -------------------------------------------------------
    let mut b = Bench::new();
    let nets = zoo::paper_networks();
    b.run("A1 ablation (48 cells, both variants)", || {
        for net in &nets {
            for p in [512usize, 2048, 16384] {
                network_bandwidth(net, p, Strategy::Optimal, ControllerMode::Passive);
                network_bandwidth(net, p, Strategy::OptimalSearch, ControllerMode::Passive);
            }
        }
    });
    b.run("A3 fusion bounds (8 networks)", || {
        nets.iter().map(fusion_bound).count()
    });
    b.finish();
}
