//! Coordinator micro-benches: batching, metrics and fan-out overheads —
//! the L3 serving machinery measured without (and with) PJRT underneath.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use psim::coordinator::batcher::{run_batcher, BatchPolicy};
use psim::coordinator::job::InferRequest;
use psim::coordinator::metrics::Metrics;
use psim::coordinator::parallel::parallel_map;
use psim::runtime::Tensor;
use psim::util::benchkit::Bench;

fn main() {
    let mut b = Bench::new();

    // Metrics hot path (called once per request/response).
    let m = Metrics::new();
    b.run_throughput("metrics record (ops/s)", 3, || {
        m.record_request();
        m.record_batch(8);
        m.record_response(250);
    });

    // Batcher throughput: how fast requests move through the batching
    // thread (synthetic sink, no PJRT).
    b.run_throughput("batcher pipeline (reqs/s)", 256, || {
        let (tx, rx) = mpsc::channel();
        let (btx, brx) = mpsc::channel::<Vec<InferRequest>>();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(200) };
        let h = std::thread::spawn(move || run_batcher(rx, btx, policy));
        let sink = std::thread::spawn(move || {
            let mut n = 0usize;
            while let Ok(batch) = brx.recv() {
                n += batch.len();
            }
            n
        });
        let (rtx, _rrx) = mpsc::channel();
        for i in 0..256u64 {
            tx.send(InferRequest {
                id: i,
                image: Tensor::zeros(&[1]),
                reply: rtx.clone(),
                enqueued: Instant::now(),
            })
            .unwrap();
        }
        drop(tx);
        h.join().unwrap();
        assert_eq!(sink.join().unwrap(), 256);
    });

    // parallel_map scaling on a CPU-bound job.
    let items: Vec<u64> = (0..64).collect();
    let work = |x: &u64| -> u64 {
        let mut acc = *x;
        for i in 0..200_000u64 {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
        }
        acc
    };
    b.run("parallel_map 64 jobs x 1 worker", || parallel_map(&items, 1, work));
    let workers = psim::coordinator::parallel::default_workers();
    b.run(&format!("parallel_map 64 jobs x {workers} workers"), || {
        parallel_map(&items, workers, work)
    });

    b.finish();
}
