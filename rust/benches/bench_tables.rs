//! Paper-evaluation bench: regenerates every table and figure of
//! Section IV and times the full regeneration, then measures the unified
//! sweep engine (cold vs warm cache, worker scaling) on the full paper
//! grid. `cargo bench` prints the tables themselves (the reproduction
//! artifact) followed by timings.

use psim::analytics::grid::{GridEngine, SweepSpec};
use psim::report::{compare, fig2, tables};
use psim::util::benchkit::Bench;

fn main() {
    println!("================ TABLE III (minimum bandwidth) ================");
    print!("{}", tables::table3().to_markdown());
    println!("\n================ TABLE I (partitioning strategies) ============");
    print!("{}", tables::table1().to_markdown());
    println!("\n================ TABLE II (passive vs active) =================");
    print!("{}", tables::table2().to_markdown());
    println!("\n================ FIG. 2 (% saving, active controller) =========");
    print!("{}", fig2::fig2_table().to_markdown());

    println!("\n================ PAPER vs OURS ================================");
    let cells = compare::compare_all();
    let s = compare::summarize(&cells);
    println!(
        "{} cells: median |Δ| {:.1}%, {} within 5%, {} within 15%, worst {:.1}%\n",
        s.cells,
        s.median_rel_diff * 100.0,
        s.within_5pct,
        s.within_15pct,
        s.worst * 100.0
    );

    let full = SweepSpec::paper_grid();
    println!(
        "================ SWEEP ENGINE (paper grid, {} cells) ==========",
        full.cell_count()
    );
    {
        let engine = GridEngine::new();
        engine.run(&full);
        let (hits, misses) = engine.cache_stats();
        println!(
            "layer cache on one cold run: {hits} hits / {misses} misses \
             ({:.1}% of layer evaluations collapsed)\n",
            hits as f64 / (hits + misses).max(1) as f64 * 100.0
        );
    }

    let mut b = Bench::new();
    b.run("table3 (8 networks)", tables::table3);
    b.run("table1 (96 cells, 4 strategies)", tables::table1);
    b.run("table2 (96 cells, 2 modes)", tables::table2);
    b.run("fig2 (48 saving points)", fig2::fig2_table);
    b.run("validate (200-cell comparison)", compare::compare_all);
    let cells = full.cell_count() as u64;
    b.run_throughput("grid cold engine+run, 1 worker (cells/s)", cells, || {
        GridEngine::new().run_with_workers(&full, 1)
    });
    b.run_throughput("grid cold engine+run, default workers (cells/s)", cells, || {
        GridEngine::new().run(&full)
    });
    let warm = GridEngine::new();
    warm.run(&full);
    b.run_throughput("grid warm rerun, default workers (cells/s)", cells, || warm.run(&full));
    b.run("grid jsonl encode (384 cells)", {
        let grid = GridEngine::new().run(&full);
        move || grid.to_jsonl()
    });
    b.finish();
}
