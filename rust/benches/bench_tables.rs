//! Paper-evaluation bench: regenerates every table and figure of
//! Section IV and times the full regeneration. `cargo bench` prints the
//! tables themselves (the reproduction artifact) followed by timings.

use psim::report::{compare, fig2, tables};
use psim::util::benchkit::Bench;

fn main() {
    println!("================ TABLE III (minimum bandwidth) ================");
    print!("{}", tables::table3().to_markdown());
    println!("\n================ TABLE I (partitioning strategies) ============");
    print!("{}", tables::table1().to_markdown());
    println!("\n================ TABLE II (passive vs active) =================");
    print!("{}", tables::table2().to_markdown());
    println!("\n================ FIG. 2 (% saving, active controller) =========");
    print!("{}", fig2::fig2_table().to_markdown());

    println!("\n================ PAPER vs OURS ================================");
    let cells = compare::compare_all();
    let s = compare::summarize(&cells);
    println!(
        "{} cells: median |Δ| {:.1}%, {} within 5%, {} within 15%, worst {:.1}%\n",
        s.cells,
        s.median_rel_diff * 100.0,
        s.within_5pct,
        s.within_15pct,
        s.worst * 100.0
    );

    let mut b = Bench::new();
    b.run("table3 (8 networks)", tables::table3);
    b.run("table1 (96 cells, 4 strategies)", tables::table1);
    b.run("table2 (96 cells, 2 modes)", tables::table2);
    b.run("fig2 (48 saving points)", fig2::fig2_table);
    b.run("validate (200-cell comparison)", compare::compare_all);
    b.finish();
}
