//! Design-space explorer bench: pruning effectiveness on the full paper
//! space, cold vs warm layer-cache runs, and worker scaling.

use psim::analytics::grid::GridEngine;
use psim::coordinator::parallel::default_workers;
use psim::dse::explore::explore;
use psim::dse::space::ExploreSpec;
use psim::models::zoo;
use psim::util::benchkit::Bench;

fn main() {
    let paper = ExploreSpec::paper_space();
    {
        let engine = GridEngine::new();
        let r = explore(&engine, &paper, default_workers());
        let (hits, misses) = engine.cache_stats();
        println!(
            "explore paper space: {} candidates -> {} evaluated, {} pruned ({:.1}%), \
             {} infeasible, {} frontier points; layer cache {hits} hits / {misses} misses\n",
            r.candidates,
            r.evaluated,
            r.pruned.len(),
            r.pruned.len() as f64 / r.candidates as f64 * 100.0,
            r.infeasible,
            r.frontier.len()
        );
    }

    let mut b = Bench::new();
    let alex = ExploreSpec::new(vec![zoo::alexnet()]);
    b.run("explore alexnet cold (192 candidates, 1 worker)", || {
        explore(&GridEngine::new(), &alex, 1)
    });
    let warm = GridEngine::new();
    explore(&warm, &alex, 1);
    b.run("explore alexnet warm cache (1 worker)", || explore(&warm, &alex, 1));

    let cells = paper.candidate_count() as u64;
    b.run_throughput("explore paper space cold, 1 worker (candidates/s)", cells, || {
        explore(&GridEngine::new(), &paper, 1)
    });
    b.run_throughput("explore paper space cold, default workers (candidates/s)", cells, || {
        explore(&GridEngine::new(), &paper, default_workers())
    });
    b.run("frontier jsonl encode", {
        let result = explore(&GridEngine::new(), &paper, default_workers());
        move || result.to_jsonl()
    });
    b.finish();
}
