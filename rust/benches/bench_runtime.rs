//! PJRT runtime latency: compiled-artifact execution from Rust — the
//! request-path numbers for EXPERIMENTS.md (latency per conv step, per
//! controller op, per PsimNet batch). Skips when artifacts are missing.

use psim::runtime::{ArtifactDir, Runtime, Tensor};
use psim::util::benchkit::Bench;

fn main() {
    let artifacts = match ArtifactDir::open_default() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("SKIP bench_runtime: {e:#} (run `make artifacts`)");
            return;
        }
    };
    let mut rt = Runtime::new(artifacts).expect("PJRT client");
    let mut b = Bench::new();

    // Warm compiles out of band so benches time execution only.
    for name in [
        "conv_step_l0",
        "conv_step_l1",
        "conv_step_l2",
        "active_update",
        "psimnet_b1",
        "psimnet_b8",
    ] {
        rt.load(name).expect(name);
    }
    println!(
        "compile time (all 6 executables): {:.1} ms\n",
        rt.compile_nanos as f64 / 1e6
    );

    // conv_step per layer shape (the accelerator's iteration workload)
    let cases = [
        ("conv_step_l0", vec![16usize, 32, 32], vec![3usize, 34, 34], vec![16usize, 3, 3, 3]),
        ("conv_step_l1", vec![32, 16, 16], vec![8, 18, 18], vec![32, 8, 3, 3]),
        ("conv_step_l2", vec![64, 8, 8], vec![8, 10, 10], vec![64, 8, 3, 3]),
    ];
    for (name, ps, xs, ws) in &cases {
        let psum = Tensor::zeros(ps);
        let x = Tensor::random(xs, 1, 1.0);
        let w = Tensor::random(ws, 2, 0.3);
        let macs: u64 = (ps.iter().product::<usize>() * xs[0] * 9) as u64;
        b.run_throughput(&format!("{name} (MACs/s)"), macs, || {
            rt.execute(name, &[psum.clone(), x.clone(), w.clone()]).unwrap()
        });
    }

    // the controller op
    let a1 = Tensor::random(&[64, 30, 30], 3, 1.0);
    let a2 = Tensor::random(&[64, 30, 30], 4, 1.0);
    b.run_throughput("active_update (elems/s)", (64 * 30 * 30) as u64, || {
        rt.execute("active_update", &[a1.clone(), a2.clone()]).unwrap()
    });

    // PsimNet end-to-end, b1 vs b8 (batching amortization)
    let weights: Vec<Tensor> = rt
        .entry("psimnet_b1")
        .unwrap()
        .inputs[1..]
        .iter()
        .enumerate()
        .map(|(i, sig)| Tensor::random(&sig.shape, 100 + i as u64, 0.2))
        .collect();
    let img1 = Tensor::random(&[1, 3, 32, 32], 9, 1.0);
    let mut in1 = vec![img1];
    in1.extend(weights.iter().cloned());
    b.run_throughput("psimnet_b1 (img/s)", 1, || rt.execute("psimnet_b1", &in1).unwrap());

    let img8 = Tensor::random(&[8, 3, 32, 32], 10, 1.0);
    let mut in8 = vec![img8];
    in8.extend(weights.iter().cloned());
    b.run_throughput("psimnet_b8 (img/s)", 8, || rt.execute("psimnet_b8", &in8).unwrap());

    b.finish();
    println!("\nruntime totals: {} execs, mean {:.1} µs/exec", rt.execs, rt.mean_exec_micros());
}
