//! In-tree stand-in for the `xla` PJRT bindings (xla-rs / xla_extension).
//!
//! The offline build environment does not ship the real crate, so this stub
//! provides the exact API surface `psim::runtime` consumes:
//!
//! * [`Literal`] — fully functional host-side f32 literals (`vec1`,
//!   `reshape`, `array_shape`, `to_vec`, `to_tuple`), so tensor round-trip
//!   conversion and its tests work without any native library.
//! * [`PjRtClient`] / [`PjRtLoadedExecutable`] / [`HloModuleProto`] — the
//!   execution path. Constructing a client succeeds (it is just a handle);
//!   anything that would require the native PJRT runtime (parsing HLO,
//!   compiling, executing) returns [`Error`] with a clear message.
//!
//! Swap the `xla` path dependency in `rust/Cargo.toml` for the real crate
//! to enable actual execution; no `psim` source changes are needed.

use std::borrow::Borrow;
use std::fmt;

/// Stub error type (message-only, mirrors the real crate's `Error` enough
/// for `anyhow` conversion via `?`).
#[derive(Clone, Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.msg)
    }
}

impl std::error::Error for Error {}

/// Stub result type.
pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str =
    "PJRT is unavailable in this build (in-tree xla stub); link the real xla crate to execute";

/// Element types convertible out of a [`Literal`] (f32 only — the only
/// dtype `psim` uses).
pub trait NativeType: Copy {
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
}

#[derive(Clone, Debug, PartialEq)]
enum Repr {
    Array { dims: Vec<i64>, data: Vec<f32> },
    Tuple(Vec<Literal>),
}

/// A host-side XLA literal: an f32 array with a shape, or a tuple of
/// literals. Fully functional in the stub.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal(Repr);

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal(Repr::Array { dims: vec![data.len() as i64], data: data.to_vec() })
    }

    /// Tuple literal (what `return_tuple=True` entry points produce).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal(Repr::Tuple(parts))
    }

    /// Reshape to `dims`; the element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match &self.0 {
            Repr::Array { data, .. } => {
                let want: i64 = dims.iter().product();
                if want < 0 || want as usize != data.len() {
                    return Err(Error::new(format!(
                        "reshape to {:?} ({} elements) from {} elements",
                        dims,
                        want,
                        data.len()
                    )));
                }
                Ok(Literal(Repr::Array { dims: dims.to_vec(), data: data.clone() }))
            }
            Repr::Tuple(_) => Err(Error::new("cannot reshape a tuple literal")),
        }
    }

    /// Array shape of a non-tuple literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match &self.0 {
            Repr::Array { dims, .. } => Ok(ArrayShape { dims: dims.clone() }),
            Repr::Tuple(_) => Err(Error::new("tuple literal has no array shape")),
        }
    }

    /// Copy the elements out (f32 only in the stub).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match &self.0 {
            Repr::Array { data, .. } => Ok(data.iter().map(|&v| T::from_f32(v)).collect()),
            Repr::Tuple(_) => Err(Error::new("tuple literal has no flat data")),
        }
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.0 {
            Repr::Tuple(parts) => Ok(parts.clone()),
            Repr::Array { .. } => Err(Error::new("literal is not a tuple")),
        }
    }
}

/// Shape of an array literal.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (never constructible in the stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::new(UNAVAILABLE))
    }
}

/// XLA computation wrapper.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle. Creation succeeds (cheap handle); compilation and
/// execution report the stub's unavailability.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub (PJRT unavailable)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(UNAVAILABLE))
    }
}

/// Compiled executable handle (never constructible in the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(UNAVAILABLE))
    }
}

/// Device buffer handle (never constructible in the stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new(UNAVAILABLE))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[7]).is_err());
    }

    #[test]
    fn tuple_literals() {
        let t = Literal::tuple(vec![Literal::vec1(&[1.0]), Literal::vec1(&[2.0, 3.0])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(t.array_shape().is_err());
        assert!(parts[0].to_tuple().is_err());
    }

    #[test]
    fn execution_path_reports_unavailable() {
        assert!(PjRtClient::cpu().is_ok());
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("stub"));
        assert!(c.compile(&XlaComputation::from_proto(&HloModuleProto)).is_err());
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
        let e = HloModuleProto::from_text_file("x").unwrap_err();
        assert!(e.to_string().contains("PJRT is unavailable"));
    }
}
