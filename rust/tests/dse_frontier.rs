//! Design-space explorer contract tests:
//!
//! * golden AlexNet frontier JSONL — the exact bytes of a 16-candidate
//!   exploration (values cross-computed independently of the crate);
//! * the closed-form candidate metrics equal the event simulator field
//!   for field on unstriped layers, across real zoo shapes;
//! * pruning is lossless: the frontier's best-bandwidth point at the
//!   paper's 1024-MAC budget matches the grid engine's best cell exactly,
//!   for every paper network;
//! * property test over randomized sub-spaces: frontier points are
//!   undominated over *all* candidates, pruned candidates are strictly
//!   dominated by a frontier point, and output bytes are worker-count
//!   independent.

use psim::analytics::bandwidth::ControllerMode;
use psim::analytics::grid::{GridEngine, SweepSpec};
use psim::analytics::partition::{partition_layer, Strategy};
use psim::dse::budget::SramBudget;
use psim::dse::explore::{explore, FrontierPoint, ZOO_SCOPE};
use psim::dse::metrics::{layer_stats, scope_stats};
use psim::dse::pareto::{dominates, Objective, Objectives};
use psim::dse::space::ExploreSpec;
use psim::models::{zoo, Network};
use psim::prop_assert;
use psim::sim::interconnect::BusConfig;
use psim::sim::scheduler::{simulate_layer_with, SimConfig};
use psim::util::prng::Rng;
use psim::util::quickcheck::forall;

/// Golden frontier for AlexNet over 512/1024 MACs × {unlimited, 64Ki}
/// SRAM × {max-input, equal-macs} × both modes (16 candidates).
///
/// Hand-verified highlights: the equal-macs/active designs dominate
/// everything else; the 64Ki point at P=512 ties the unlimited one
/// byte-for-byte (its working sets fit, so no striping happens), while at
/// P=1024 the 64Ki design pays conv1 halo re-reads and is dominated by
/// its unlimited sibling — SRAM capacity shows up exactly where it binds.
const GOLDEN_FRONTIER: [&str; 3] = [
    r#"{"bandwidth":20101312,"energy_pj":818333094,"mac_util_ppm":772780,"mode":"active","network":"AlexNet","p_macs":512,"sram":"unlimited","sram_accesses":32519616,"strategy":"equal-macs"}"#,
    r#"{"bandwidth":20101312,"energy_pj":818333094,"mac_util_ppm":772780,"mode":"active","network":"AlexNet","p_macs":512,"sram":"65536","sram_accesses":32519616,"strategy":"equal-macs"}"#,
    r#"{"bandwidth":14662336,"energy_pj":762182118,"mac_util_ppm":699698,"mode":"active","network":"AlexNet","p_macs":1024,"sram":"unlimited","sram_accesses":24484800,"strategy":"equal-macs"}"#,
];

fn golden_spec() -> ExploreSpec {
    ExploreSpec::new(vec![zoo::alexnet()])
        .with_macs(vec![512, 1024])
        .with_sram(vec![SramBudget::Unlimited, SramBudget::Elems(65536)])
        .with_strategies(vec![Strategy::MaxInput, Strategy::EqualMacs])
        .with_modes(vec![ControllerMode::Passive, ControllerMode::Active])
}

#[test]
fn alexnet_frontier_jsonl_golden() {
    let result = explore(&GridEngine::new(), &golden_spec(), 1);
    assert_eq!(result.candidates, 16);
    assert_eq!(result.evaluated, 16); // single chunk: nothing to prune yet
    assert_eq!(result.infeasible, 0);
    let jsonl = result.to_jsonl();
    let lines: Vec<&str> = jsonl.lines().collect();
    assert_eq!(lines.len(), GOLDEN_FRONTIER.len(), "frontier:\n{jsonl}");
    for (line, golden) in lines.iter().zip(GOLDEN_FRONTIER) {
        assert_eq!(*line, golden);
    }
}

#[test]
fn frontier_jsonl_identical_across_worker_counts() {
    // Full default AlexNet space: 192 candidates, pruning active.
    let spec = ExploreSpec::new(vec![zoo::alexnet()]);
    let one = explore(&GridEngine::new(), &spec, 1);
    let eight = explore(&GridEngine::new(), &spec, 8);
    assert_eq!(one.to_jsonl(), eight.to_jsonl(), "frontier depends on worker count");
    assert_eq!(one.pruned.len(), eight.pruned.len());
    assert!(!one.pruned.is_empty(), "bound pruned nothing on the default space");
    assert_eq!(one.evaluated + one.pruned.len(), one.candidates);
}

#[test]
fn dse_metrics_match_simulator_across_zoo() {
    // The closed form's contract: unstriped counters equal the event
    // simulator's, field for field (bus_cycles/energy are per-scope
    // roll-ups outside the per-layer closed form).
    let bus = BusConfig::default();
    for net in [zoo::alexnet(), zoo::squeezenet1_0(), zoo::mobilenet_v1()] {
        for layer in &net.layers {
            for p in [512usize, 2048] {
                for mode in ControllerMode::ALL {
                    let part = partition_layer(layer, p, Strategy::Optimal, mode);
                    let cfg = SimConfig::new(p, mode, Strategy::Optimal);
                    let mut sim = simulate_layer_with(layer, &cfg, part).stats;
                    sim.bus_cycles = 0;
                    sim.energy_pj = 0.0;
                    let dse = layer_stats(layer, part.m, part.n, layer.ho(), mode, &bus);
                    assert_eq!(dse, sim, "{}/{} P={p} {mode:?}", net.name, layer.name);
                }
            }
        }
    }
}

#[test]
fn pruning_is_lossless_at_paper_budget() {
    // Acceptance: for the paper's 1024-MAC budget, the frontier's best
    // bandwidth equals the grid engine's best cell over the same
    // strategies × modes — exactly — for every paper network.
    let engine = GridEngine::new();
    let spec = ExploreSpec::paper_space()
        .with_macs(vec![1024])
        .with_sram(vec![SramBudget::Unlimited]);
    let result = explore(&engine, &spec, 4);
    let grid = engine.run(&SweepSpec::paper_grid().with_macs(vec![1024]));
    for net in zoo::paper_networks() {
        let frontier_best = result
            .frontier_for(&net.name)
            .iter()
            .map(|f| f.objectives.bandwidth)
            .fold(f64::INFINITY, f64::min);
        let grid_best = grid
            .cells
            .iter()
            .filter(|c| c.network == net.name)
            .map(|c| c.total())
            .fold(f64::INFINITY, f64::min);
        assert_eq!(frontier_best, grid_best, "{}: frontier != grid best", net.name);
    }
}

/// Pick 1..=max distinct elements of `pool` (deterministic given `r`).
fn subset<T: Copy>(r: &mut Rng, pool: &[T], max: usize) -> Vec<T> {
    let k = r.range(1, max.min(pool.len()));
    let mut idxs: Vec<usize> = (0..pool.len()).collect();
    let mut picked = Vec::with_capacity(k);
    for _ in 0..k {
        let i = r.range(0, idxs.len() - 1);
        picked.push(pool[idxs.remove(i)]);
    }
    picked
}

#[test]
fn frontier_properties_over_random_subspaces() {
    let pool_nets = ["AlexNet", "SqueezeNet", "resnet18"];
    let pool_macs = [256usize, 512, 1024, 2048, 4096, 8192, 16384];
    let pool_sram = [
        SramBudget::Unlimited,
        SramBudget::Elems(1 << 20),
        SramBudget::Elems(1 << 18),
        SramBudget::Elems(1 << 16),
        SramBudget::Elems(1 << 14),
    ];
    let pool_strats = [
        Strategy::MaxInput,
        Strategy::MaxOutput,
        Strategy::EqualMacs,
        Strategy::Optimal,
        Strategy::OptimalSearch,
    ];
    let pool_objs = Objective::ALL;

    forall(
        "dse-frontier-invariants",
        24,
        |r| {
            (
                subset(r, &pool_nets, 2),
                subset(r, &pool_macs, 2),
                subset(r, &pool_sram, 2),
                subset(r, &pool_strats, 2),
                subset(r, &ControllerMode::ALL, 2),
                subset(r, &pool_objs, 4),
            )
        },
        |(nets, macs, sram, strats, modes, objs)| {
            let networks: Vec<Network> =
                nets.iter().map(|n| zoo::by_name(n).expect("pool network")).collect();
            let spec = ExploreSpec::new(networks)
                .with_macs(macs.clone())
                .with_sram(sram.clone())
                .with_strategies(strats.clone())
                .with_modes(modes.clone())
                .with_objectives(objs.clone());
            let engine = GridEngine::new();
            let one = explore(&engine, &spec, 1);
            let three = explore(&engine, &spec, 3);
            prop_assert!(one.to_jsonl() == three.to_jsonl(), "output depends on worker count");
            prop_assert!(
                one.evaluated + one.pruned.len() == one.candidates,
                "accounting: {} evaluated + {} pruned != {} candidates",
                one.evaluated,
                one.pruned.len(),
                one.candidates
            );

            let points = spec.points();
            let mut scopes: Vec<(String, Vec<&Network>)> =
                spec.networks.iter().map(|n| (n.name.clone(), vec![n])).collect();
            if spec.networks.len() > 1 {
                scopes.push((ZOO_SCOPE.to_string(), spec.networks.iter().collect()));
            }
            let bus = BusConfig::default();
            for (scope, nets_ref) in &scopes {
                // Exhaustive re-evaluation, independent of the explorer's
                // pruning decisions.
                let exacts: Vec<Option<Objectives>> = points
                    .iter()
                    .map(|pt| {
                        scope_stats(&engine, nets_ref, pt, &bus)
                            .map(|s| Objectives::from_stats(&s, pt.p_macs))
                    })
                    .collect();
                let frontier: Vec<&FrontierPoint> = one.frontier_for(scope);
                for fp in &frontier {
                    let idx = points.iter().position(|p| *p == fp.point).expect("known point");
                    prop_assert!(
                        exacts[idx] == Some(fp.objectives),
                        "{scope}/{}: frontier objectives drifted from re-evaluation",
                        fp.point.key()
                    );
                    for (j, e) in exacts.iter().enumerate() {
                        if let Some(e) = e {
                            prop_assert!(
                                !dominates(e, &fp.objectives, &spec.objectives),
                                "{scope}: frontier point {} dominated by candidate {}",
                                fp.point.key(),
                                points[j].key()
                            );
                        }
                    }
                }
                for pr in one.pruned.iter().filter(|p| &p.scope == scope) {
                    let idx = points.iter().position(|p| *p == pr.point).expect("known point");
                    if let Some(e) = &exacts[idx] {
                        prop_assert!(
                            frontier.iter().any(|f| dominates(&f.objectives, e, &spec.objectives)),
                            "{scope}: pruned candidate {} is not dominated",
                            pr.point.key()
                        );
                    }
                }
            }
            Ok(())
        },
    );
}
