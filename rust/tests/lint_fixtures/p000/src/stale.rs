// Seeded PS000 violations: a stale allow and a malformed one.
pub fn fine() -> u8 {
    // lint:allow(PS100, nothing on the next line needs this)
    7
}
// lint:allow(NOPE)
