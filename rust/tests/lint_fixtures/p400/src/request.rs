// Seeded PS400 command table: `alpha` fully pinned, `beta` drifted.
pub struct CommandDoc {
    pub cmd: &'static str,
}

pub const COMMANDS: [CommandDoc; 2] = [
    CommandDoc { cmd: "alpha" },
    CommandDoc { cmd: "beta" },
];
