// Seeded PS500 violations: this comment line deliberately runs well past the format gate's one-hundred-column limit.
pub const WIRE: &str = "string literals are exempt because rustfmt cannot break them either: xxxxxxxxxxxx";
pub fn f() {} 
