// Seeded PS200 violation: bare arithmetic in a size-accounting fn.
pub fn cell_count(rows: usize, cols: usize) -> usize {
    rows * cols
}

// Not size accounting: bare arithmetic here is fine.
pub fn area(rows: usize, cols: usize) -> usize {
    rows * cols
}
