// A trusted static-table constructor: the allowlisted counterpart to
// the p100 fixture. Must lint clean — and the allow must count as used.
pub const TABLE: [u8; 2] = [1, 2];

pub fn lookup() -> u8 {
    // lint:allow(PS100, trusted static table with a compile-time length)
    *TABLE.first().unwrap()
}
