// Seeded PS300 catalog: one live entry, one never recorded.
pub struct MetricDesc {
    pub name: &'static str,
    pub help: &'static str,
}

const fn counter(name: &'static str, help: &'static str) -> MetricDesc {
    MetricDesc { name, help }
}

pub const METRICS: [MetricDesc; 2] = [
    counter("requests_total", "Requests handled."),
    counter("never_recorded", "Nothing records this."),
];
