// Seeded PS300 recording sites: one cataloged, one unknown.
pub fn record(reg: &Registry) {
    reg.counter("requests_total").inc();
    reg.counter("unknown_metric").inc();
}
