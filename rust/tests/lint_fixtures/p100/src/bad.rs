// Seeded PS100 violations: one per detection shape.
pub fn parse(v: &[u8]) -> u8 {
    let first = v.first().unwrap();
    let second = v.get(1).expect("second byte");
    if *first == 0 {
        panic!("zero");
    }
    *second + v[0]
}
