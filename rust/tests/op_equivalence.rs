//! GEMM ↔ 1×1-conv equivalence: the property the operator abstraction
//! rests on (`models::op` module docs).
//!
//! Over randomized GEMM shapes, [`Op::Gemm`] lowered through
//! [`Op::lower`] must match the hand-built 1×1 [`ConvLayer`]
//! (`wi=1, hi=m_rows, m=k_dim, n=n_cols, k=1`) element-for-element:
//! derived quantities, every partitioning strategy, eq. 2/3 bandwidth,
//! the eq.-7 real-valued optimum — at element weighting and at the
//! paper's wide-psum byte weighting (8:8:32:8).

use psim::analytics::bandwidth::{layer_bandwidth, layer_bandwidth_bytes, ControllerMode};
use psim::analytics::optimizer::{optimal_m_real, optimal_m_real_bytes};
use psim::analytics::partition::{partition_layer, partition_layer_bytes, Strategy};
use psim::models::{ConvLayer, DataTypes, Op};
use psim::util::prng::Rng;

const STRATEGIES: [Strategy; 5] = [
    Strategy::MaxInput,
    Strategy::MaxOutput,
    Strategy::EqualMacs,
    Strategy::Optimal,
    Strategy::OptimalSearch,
];

/// A randomized GEMM op and its hand-built conv twin.
fn random_pair(rng: &mut Rng) -> (Op, ConvLayer) {
    let m_rows = rng.range(1, 512);
    let k_dim = rng.range(1, 1024);
    let n_cols = rng.range(1, 1024);
    let op = Op::gemm("g", m_rows, k_dim, n_cols).unwrap();
    let twin = ConvLayer::new("g", 1, m_rows, k_dim, n_cols, 1, 1, 0);
    (op, twin)
}

#[test]
fn gemm_derived_quantities_match_the_conv_twin() {
    let mut rng = Rng::new(0x0e0e_0001);
    for _ in 0..200 {
        let (op, twin) = random_pair(&mut rng);
        let lowered = op.lower();
        assert_eq!(lowered.len(), 1);
        assert_eq!(lowered[0], twin, "{op}");
        assert_eq!(op.macs(), twin.macs(), "{op}");
        assert_eq!(op.weights(), twin.weights(), "{op}");
        assert_eq!(op.input_activations(), twin.input_activations(), "{op}");
        assert_eq!(op.output_activations(), twin.output_activations(), "{op}");
        assert_eq!(op.reduction_depth(), twin.m as u64, "{op}");
    }
}

#[test]
fn gemm_bandwidth_matches_the_conv_twin_under_every_strategy() {
    let wide = DataTypes::parse("8:8:32:8").unwrap();
    let mut rng = Rng::new(0x0e0e_0002);
    for _ in 0..100 {
        let (op, twin) = random_pair(&mut rng);
        let lowered_layers = op.lower();
        let lowered = &lowered_layers[0];
        let p_macs = rng.range(1, 20000);
        for strategy in STRATEGIES {
            for mode in [ControllerMode::Passive, ControllerMode::Active] {
                let a = partition_layer(lowered, p_macs, strategy, mode);
                let b = partition_layer(&twin, p_macs, strategy, mode);
                assert_eq!(a, b, "{op} P={p_macs} {strategy:?} {mode:?}");
                let ba = layer_bandwidth(lowered, a.m, a.n, mode);
                let bb = layer_bandwidth(&twin, b.m, b.n, mode);
                assert_eq!(ba.input, bb.input, "{op} P={p_macs} {strategy:?} {mode:?}");
                assert_eq!(ba.output, bb.output, "{op} P={p_macs} {strategy:?} {mode:?}");

                // Byte weighting: wide partial sums shift the optimal
                // split identically for both spellings.
                let a = partition_layer_bytes(lowered, p_macs, strategy, mode, &wide);
                let b = partition_layer_bytes(&twin, p_macs, strategy, mode, &wide);
                assert_eq!(a, b, "{op} P={p_macs} {strategy:?} {mode:?} bytes");
                let ba = layer_bandwidth_bytes(lowered, a.m, a.n, mode, &wide);
                let bb = layer_bandwidth_bytes(&twin, b.m, b.n, mode, &wide);
                assert_eq!(ba.total(), bb.total(), "{op} P={p_macs} {strategy:?} {mode:?} bytes");
            }
        }
    }
}

/// The lowered GEMM's traffic is the module docs' closed form: eq. 2 reads
/// `m_rows·k_dim·ceil(n_cols/n)`, eq. 3 reads
/// `m_rows·n_cols·(2·ceil(k_dim/m)−1)` passive / `·ceil(k_dim/m)` active.
#[test]
fn gemm_bandwidth_is_the_documented_closed_form() {
    let mut rng = Rng::new(0x0e0e_0003);
    for _ in 0..200 {
        let (op, twin) = random_pair(&mut rng);
        let Op::Gemm { m_rows, k_dim, n_cols, .. } = &op else { unreachable!() };
        let m = rng.range(1, *k_dim);
        let n = rng.range(1, *n_cols);
        let psum_iters = k_dim.div_ceil(m);
        let bw = layer_bandwidth(&twin, m, n, ControllerMode::Passive);
        assert_eq!(bw.input, (m_rows * k_dim * n_cols.div_ceil(n)) as f64, "{op}");
        assert_eq!(bw.output, (m_rows * n_cols * (2 * psum_iters - 1)) as f64, "{op}");
        let bw = layer_bandwidth(&twin, m, n, ControllerMode::Active);
        assert_eq!(bw.output, (m_rows * n_cols * psum_iters) as f64, "{op}");
    }
}

/// Eq. 7 under the GEMM mapping: `Wo·Ho = Wi·Hi = m_rows` and `K = 1`,
/// so `m* = sqrt(f·Wo·Ho·P / (Wi·Hi·K²))` collapses to `sqrt(f·P)` —
/// the optimal K-dimension split depends only on the controller and the
/// MAC budget — and must agree with the conv twin exactly, in both
/// currencies.
#[test]
fn gemm_eq7_optimum_matches_the_conv_twin() {
    let wide = DataTypes::parse("8:8:32:8").unwrap();
    let mut rng = Rng::new(0x0e0e_0004);
    for _ in 0..200 {
        let (op, twin) = random_pair(&mut rng);
        let lowered_layers = op.lower();
        let lowered = &lowered_layers[0];
        let p_macs = rng.range(1, 20000);
        for mode in [ControllerMode::Passive, ControllerMode::Active] {
            let a = optimal_m_real(lowered, p_macs, mode);
            let b = optimal_m_real(&twin, p_macs, mode);
            assert_eq!(a, b, "{op} P={p_macs} {mode:?}");
            let f = match mode {
                ControllerMode::Passive => 2.0,
                ControllerMode::Active => 1.0,
            };
            let closed = (f * p_macs as f64).sqrt();
            assert!((a - closed).abs() < 1e-9 * closed.max(1.0), "{op}: {a} vs {closed}");
            let ab = optimal_m_real_bytes(lowered, p_macs, mode, &wide);
            let bb = optimal_m_real_bytes(&twin, p_macs, mode, &wide);
            assert_eq!(ab, bb, "{op} P={p_macs} {mode:?} bytes");
            // Wide psums (4 bytes vs 1) double the optimal reduction split.
            assert_eq!(ab, a * 2.0, "{op} P={p_macs} {mode:?} byte shift");
        }
    }
}
