//! Runtime integration over the real AOT artifacts. Requires
//! `make artifacts`; tests skip (with a loud note) when artifacts are
//! absent so `cargo test` still works in a fresh checkout.

use psim::runtime::{ArtifactDir, Runtime, Tensor};

fn runtime_or_skip() -> Option<Runtime> {
    match ArtifactDir::open_default() {
        Ok(a) => Some(Runtime::new(a).expect("PJRT CPU client")),
        Err(e) => {
            eprintln!("SKIP runtime tests: {e:#} (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn manifest_lists_expected_entries() {
    let Some(rt) = runtime_or_skip() else { return };
    for name in [
        "psimnet_b1",
        "psimnet_b8",
        "conv_step_l0",
        "conv_step_l1",
        "conv_step_l2",
        "active_update",
    ] {
        assert!(rt.artifacts().entry(name).is_some(), "missing {name}");
    }
}

#[test]
fn conv_step_zero_weights_is_identity() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let psum = Tensor::random(&[16, 32, 32], 3, 1.0);
    let x = Tensor::random(&[3, 34, 34], 4, 1.0);
    let w = Tensor::zeros(&[16, 3, 3, 3]);
    let out = rt.execute("conv_step_l0", &[psum.clone(), x, w]).unwrap();
    assert_eq!(out[0], psum, "zero weights must pass the psum through");
}

#[test]
fn conv_step_is_linear_in_psum() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let psum = Tensor::random(&[32, 16, 16], 5, 1.0);
    let x = Tensor::random(&[8, 18, 18], 6, 1.0);
    let w = Tensor::random(&[32, 8, 3, 3], 7, 0.3);
    let with_p = rt.execute("conv_step_l1", &[psum.clone(), x.clone(), w.clone()]).unwrap();
    let without = rt.execute("conv_step_l1", &[Tensor::zeros(&[32, 16, 16]), x, w]).unwrap();
    let max_err = with_p[0]
        .data
        .iter()
        .zip(without[0].data.iter().zip(&psum.data))
        .map(|(a, (b, p))| (a - (b + p)).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-4, "linearity violated: {max_err}");
}

#[test]
fn conv_step_additivity_in_x() {
    // conv is linear in the input: f(0,x1,w) + f(0,x2,w) == f(0,x1+x2,w).
    let Some(mut rt) = runtime_or_skip() else { return };
    let zero = Tensor::zeros(&[64, 8, 8]);
    let x1 = Tensor::random(&[8, 10, 10], 8, 1.0);
    let x2 = Tensor::random(&[8, 10, 10], 9, 1.0);
    let sum = Tensor::new(
        vec![8, 10, 10],
        x1.data.iter().zip(&x2.data).map(|(a, b)| a + b).collect(),
    )
    .unwrap();
    let w = Tensor::random(&[64, 8, 3, 3], 10, 0.3);
    let f1 = rt.execute("conv_step_l2", &[zero.clone(), x1, w.clone()]).unwrap();
    let f2 = rt.execute("conv_step_l2", &[zero.clone(), x2, w.clone()]).unwrap();
    let fs = rt.execute("conv_step_l2", &[zero, sum, w]).unwrap();
    let max_err = fs[0]
        .data
        .iter()
        .zip(f1[0].data.iter().zip(&f2[0].data))
        .map(|(s, (a, b))| (s - (a + b)).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-3, "additivity violated: {max_err}");
}

#[test]
fn active_update_matches_rust_oracle() {
    // relu(a + b) is trivially computable here — an exact oracle.
    let Some(mut rt) = runtime_or_skip() else { return };
    let a = Tensor::random(&[64, 30, 30], 11, 2.0);
    let b = Tensor::random(&[64, 30, 30], 12, 2.0);
    let out = rt.execute("active_update", &[a.clone(), b.clone()]).unwrap();
    for (got, (x, y)) in out[0].data.iter().zip(a.data.iter().zip(&b.data)) {
        let want = (x + y).max(0.0);
        assert!((got - want).abs() < 1e-6, "{got} vs {want}");
    }
}

#[test]
fn psimnet_batching_invariance() {
    // Row i of a b8 batch equals the same image through the b1 artifact.
    let Some(mut rt) = runtime_or_skip() else { return };
    let weights: Vec<Tensor> = rt
        .entry("psimnet_b1")
        .unwrap()
        .inputs[1..]
        .iter()
        .enumerate()
        .map(|(i, sig)| Tensor::random(&sig.shape, 100 + i as u64, 0.2))
        .collect();

    let img = Tensor::random(&[3, 32, 32], 55, 1.0);
    let mut b1_in = vec![Tensor::new(vec![1, 3, 32, 32], img.data.clone()).unwrap()];
    b1_in.extend(weights.iter().cloned());
    let solo = rt.execute("psimnet_b1", &b1_in).unwrap();

    let mut batch = vec![0.0f32; 8 * 3072];
    for row in 0..8 {
        let filler = Tensor::random(&[3, 32, 32], 200 + row as u64, 1.0);
        let src = if row == 5 { &img } else { &filler };
        batch[row * 3072..(row + 1) * 3072].copy_from_slice(&src.data);
    }
    let mut b8_in = vec![Tensor::new(vec![8, 3, 32, 32], batch).unwrap()];
    b8_in.extend(weights.iter().cloned());
    let batched = rt.execute("psimnet_b8", &b8_in).unwrap();

    let solo_row = &solo[0].data[..10];
    let batch_row = &batched[0].data[5 * 10..6 * 10];
    for (a, b) in solo_row.iter().zip(batch_row) {
        assert!((a - b).abs() < 1e-4, "batching changed logits: {a} vs {b}");
    }
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let err = rt
        .execute("active_update", &[Tensor::zeros(&[2, 2]), Tensor::zeros(&[64, 30, 30])])
        .unwrap_err()
        .to_string();
    assert!(err.contains("shape"), "unhelpful error: {err}");
    let err = rt.execute("active_update", &[Tensor::zeros(&[64, 30, 30])]).unwrap_err().to_string();
    assert!(err.contains("inputs"), "unhelpful error: {err}");
}

#[test]
fn executable_cache_reuses_compilation() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let a = Tensor::zeros(&[64, 30, 30]);
    let b = Tensor::zeros(&[64, 30, 30]);
    rt.execute("active_update", &[a.clone(), b.clone()]).unwrap();
    let compile_after_first = rt.compile_nanos;
    for _ in 0..3 {
        rt.execute("active_update", &[a.clone(), b.clone()]).unwrap();
    }
    assert_eq!(rt.compile_nanos, compile_after_first, "recompiled a cached executable");
    assert_eq!(rt.execs, 4);
}
