//! The simulator's contract: transaction-counted activation traffic
//! equals the analytical model **exactly** — every network, every
//! strategy, both controller modes, all Table I MAC budgets.

use psim::analytics::bandwidth::{layer_bandwidth, ControllerMode};
use psim::analytics::partition::{partition_layer, Strategy};
use psim::models::zoo;
use psim::sim::scheduler::{simulate_layer, simulate_network, SimConfig};

#[test]
fn exhaustive_sim_equals_model() {
    let strategies = [
        Strategy::MaxInput,
        Strategy::MaxOutput,
        Strategy::EqualMacs,
        Strategy::Optimal,
        Strategy::OptimalSearch,
    ];
    for net in zoo::paper_networks() {
        for &p in &[512usize, 2048, 16384] {
            for s in strategies {
                for mode in ControllerMode::ALL {
                    let cfg = SimConfig::new(p, mode, s);
                    let sim = simulate_network(&net, &cfg).stats;
                    let mut model_total = 0.0;
                    for layer in &net.layers {
                        let part = partition_layer(layer, p, s, mode);
                        model_total += layer_bandwidth(layer, part.m, part.n, mode).total();
                    }
                    assert_eq!(
                        sim.activation_traffic() as f64,
                        model_total,
                        "{} P={p} {:?} {:?}",
                        net.name,
                        s,
                        mode
                    );
                }
            }
        }
    }
}

#[test]
fn active_controller_absorbs_exactly_the_psum_rereads() {
    // For the same partition, passive bus traffic - active bus traffic
    // must equal the internal reads the active controller performed.
    for net in [zoo::alexnet(), zoo::resnet18()] {
        for &p in &[512usize, 4096] {
            for layer in &net.layers {
                let part = partition_layer(layer, p, Strategy::Optimal, ControllerMode::Passive);
                let cfg_p = SimConfig::new(p, ControllerMode::Passive, Strategy::Optimal);
                let cfg_a = SimConfig::new(p, ControllerMode::Active, Strategy::Optimal);
                let sp = psim::sim::scheduler::simulate_layer_with(layer, &cfg_p, part).stats;
                let sa = psim::sim::scheduler::simulate_layer_with(layer, &cfg_a, part).stats;
                assert_eq!(
                    sp.activation_traffic() - sa.activation_traffic(),
                    sa.internal_psum_reads,
                    "{}/{} P={p}",
                    net.name,
                    layer.name
                );
                // and the SRAM array does the same total work either way
                assert_eq!(sp.sram_accesses, sa.sram_accesses, "{}", layer.name);
            }
        }
    }
}

#[test]
fn mac_work_is_conserved() {
    // Total MACs executed never depends on partitioning or controller.
    let net = zoo::squeezenet1_0();
    let expected = net.total_macs();
    for s in [Strategy::MaxInput, Strategy::Optimal, Strategy::OptimalSearch] {
        for mode in ControllerMode::ALL {
            let sim = simulate_network(&net, &SimConfig::new(1024, mode, s)).stats;
            assert_eq!(sim.macs, expected, "{s:?} {mode:?}");
        }
    }
}

#[test]
fn energy_tracks_traffic_direction() {
    // More MACs -> less traffic -> less energy (for the optimal strategy).
    let net = zoo::resnet18();
    let mut prev = f64::INFINITY;
    for p in [512usize, 2048, 8192] {
        let cfg = SimConfig::new(p, ControllerMode::Active, Strategy::OptimalSearch);
        let sim = simulate_network(&net, &cfg).stats;
        assert!(sim.energy_pj < prev, "energy rose at P={p}");
        prev = sim.energy_pj;
    }
}

#[test]
fn sideband_words_only_in_active_mode() {
    let net = zoo::alexnet();
    let passive =
        simulate_network(&net, &SimConfig::new(2048, ControllerMode::Passive, Strategy::Optimal))
            .stats;
    let active =
        simulate_network(&net, &SimConfig::new(2048, ControllerMode::Active, Strategy::Optimal))
            .stats;
    // Passive writes carry Init commands on the first pass only; active
    // carries Add/AddRelu on every subsequent pass as well.
    assert!(active.sideband_words > passive.sideband_words);
    assert!(active.bus_beats < passive.bus_beats);
}

#[test]
fn per_layer_equals_whole_network() {
    let net = zoo::googlenet();
    let cfg = SimConfig::new(4096, ControllerMode::Active, Strategy::Optimal);
    let whole = simulate_network(&net, &cfg).stats;
    let mut input = 0u64;
    let mut out = 0u64;
    for layer in &net.layers {
        let s = simulate_layer(layer, &cfg).stats;
        input += s.input_reads;
        out += s.output_traffic();
    }
    assert_eq!(whole.input_reads, input);
    assert_eq!(whole.output_traffic(), out);
}
