//! Fusion-model contract tests:
//!
//! * golden depth-2 AlexNet JSONL at P=512 — pinned byte-for-byte against
//!   `tests/golden/alexnet_fusion_p512.jsonl` (the same file the CI smoke
//!   step diffs against the built binary), values recomputed
//!   independently of the crate;
//! * the fused cells are strictly cheaper than the unfused golden cells;
//! * depth-1 fusion reproduces the unfused sweep byte-identically over
//!   the full paper grid;
//! * property tests over random chains: singleton fused traffic equals
//!   `layer_bandwidth`, fused never exceeds the unfused sum when the
//!   chain fits unstriped, and stripe row spans match a brute-force
//!   per-output-row receptive-field union.

use psim::analytics::bandwidth::{layer_bandwidth, ControllerMode};
use psim::analytics::fusion::{chain_bandwidth, chains, span_rows, stripe_spans};
use psim::analytics::grid::{GridEngine, SweepSpec};
use psim::analytics::partition::{Partition, Strategy};
use psim::models::{ConvLayer, Network};
use psim::prop_assert;
use psim::util::quickcheck::forall;

const GOLDEN: &str = include_str!("golden/alexnet_fusion_p512.jsonl");

fn golden_spec(depths: Vec<usize>) -> SweepSpec {
    SweepSpec::new(vec![psim::models::zoo::alexnet()])
        .with_macs(vec![512])
        .with_strategies(vec![Strategy::MaxInput, Strategy::MaxOutput])
        .with_modes(vec![ControllerMode::Passive, ControllerMode::Active])
        .with_fusion(depths)
}

#[test]
fn alexnet_depth2_jsonl_golden() {
    let jsonl = GridEngine::new().run_with_workers(&golden_spec(vec![2]), 1).to_jsonl();
    assert_eq!(jsonl, GOLDEN, "depth-2 fusion output drifted from the pinned golden file");
}

#[test]
fn fused_cells_strictly_beat_unfused_baseline() {
    // Acceptance: at P=512 every fused AlexNet cell moves strictly less
    // activation traffic than its unfused counterpart (conv3->conv4 fuse).
    let engine = GridEngine::new();
    let unfused = engine.run_with_workers(&golden_spec(vec![1]), 1);
    let fused = engine.run_with_workers(&golden_spec(vec![2]), 1);
    assert_eq!(unfused.len(), fused.len());
    for (u, f) in unfused.cells.iter().zip(&fused.cells) {
        assert!(
            f.total() < u.total(),
            "{}: fused {} !< unfused {}",
            u.key(),
            f.total(),
            u.total()
        );
    }
}

#[test]
fn depth1_is_byte_identical_to_unfused_paper_grid() {
    // The fused code path at depth 1 must reproduce the pre-fusion sweep
    // exactly — same cells, same bytes, full paper grid.
    let engine = GridEngine::new();
    let unfused = engine.run_with_workers(&SweepSpec::paper_grid(), 2).to_jsonl();
    let depth1 =
        engine.run_with_workers(&SweepSpec::paper_grid().with_fusion(vec![1]), 2).to_jsonl();
    assert_eq!(unfused, depth1);
    assert_eq!(unfused.lines().count(), 384);
}

/// Generate a random fusable chain: stride <= kernel at every layer (the
/// contiguous-rows regime the interval model is exact in), pad < kernel,
/// consecutive planes and channel counts chained by construction.
fn random_chain(r: &mut psim::util::prng::Rng) -> Vec<ConvLayer> {
    let depth = r.range(1, 4);
    let mut hi = r.range(9, 40);
    let mut m = r.range(1, 8);
    let mut chain = Vec::new();
    for i in 0..depth {
        let k = r.range(1, hi.min(5));
        let p = r.range(0, k - 1);
        let mut s = r.range(1, k);
        if (hi + 2 * p - k) / s + 1 < 2 {
            s = 1; // keep the plane >= 2 rows so striping stays possible
        }
        let ho = (hi + 2 * p - k) / s + 1;
        if ho < 2 {
            break; // plane exhausted (only possible after the first layer)
        }
        let n = r.range(1, 8);
        chain.push(ConvLayer::new(&format!("c{i}"), hi, hi, m, n, k, s, p));
        hi = ho;
        m = n;
    }
    chain
}

#[test]
fn singleton_fused_equals_layer_bandwidth() {
    forall(
        "fusion-depth1-degenerates",
        128,
        |r| {
            let l = random_chain(r).remove(0);
            let m = r.range(1, l.m);
            let n = r.range(1, l.n);
            (l, m, n)
        },
        |(l, m, n)| {
            let part = [Partition { m: *m, n: *n }];
            let r = (l.hi + 2 * l.pad - l.k) % l.stride;
            for mode in ControllerMode::ALL {
                let fused = chain_bandwidth(std::slice::from_ref(l), &part, l.ho(), mode);
                let bw = layer_bandwidth(l, *m, *n, mode);
                prop_assert!(fused.output == bw.output, "output mismatch: {l}");
                if l.pad >= r {
                    // the single stripe covers the whole used plane
                    prop_assert!(fused.input == bw.input, "input mismatch: {l}");
                } else {
                    // floor-cropped tail rows: eq. 2 charges them, the
                    // receptive-field model does not
                    prop_assert!(fused.input <= bw.input, "input exceeds eq.2: {l}");
                }
            }
            Ok(())
        },
    );
}

#[test]
fn fused_never_exceeds_unfused_when_chain_fits() {
    forall(
        "fusion-saves-when-resident",
        128,
        |r| {
            let chain = random_chain(r);
            let parts: Vec<Partition> = chain
                .iter()
                .map(|l| Partition { m: r.range(1, l.m), n: r.range(1, l.n) })
                .collect();
            (chain, parts)
        },
        |(chain, parts)| {
            // Single stripe == intermediates fully resident in SRAM.
            let ho = chain.last().unwrap().ho();
            for mode in ControllerMode::ALL {
                let fused = chain_bandwidth(chain, parts, ho, mode);
                let unfused: f64 = chain
                    .iter()
                    .zip(parts)
                    .map(|(l, p)| layer_bandwidth(l, p.m, p.n, mode).total())
                    .sum();
                let weights: u64 = chain.iter().map(|l| l.weights()).sum();
                prop_assert!(
                    fused.total() <= unfused + weights as f64,
                    "fused {} > unfused {} (+{} weights), chain {:?}",
                    fused.total(),
                    unfused,
                    weights,
                    chain.iter().map(|l| l.to_string()).collect::<Vec<_>>()
                );
            }
            Ok(())
        },
    );
}

#[test]
fn stripe_spans_match_brute_force_receptive_field() {
    forall(
        "fusion-halo-brute-force",
        96,
        |r| {
            let chain = random_chain(r);
            let ho = chain.last().unwrap().ho();
            let y0 = r.range(0, ho - 1);
            let y1 = r.range(y0, ho - 1);
            (chain, y0, y1)
        },
        |(chain, y0, y1)| {
            let spans = stripe_spans(chain, *y0, *y1);
            // Brute force: walk every output row of every layer backward,
            // marking the exact input rows its window touches. With
            // stride <= kernel the union is contiguous, so it must equal
            // the interval model's span — halo row counts included.
            let mut needed: Vec<usize> = (*y0..=*y1).collect();
            for (i, l) in chain.iter().enumerate().rev() {
                let mut marks = vec![false; l.hi];
                for &y in &needed {
                    for ky in 0..l.k {
                        let row = (y * l.stride + ky) as i64 - l.pad as i64;
                        if (0..l.hi as i64).contains(&row) {
                            marks[row as usize] = true;
                        }
                    }
                }
                needed = (0..l.hi).filter(|&row| marks[row]).collect();
                prop_assert!(!needed.is_empty(), "empty receptive field: {l}");
                let (lo, hi) = (needed[0], *needed.last().unwrap());
                prop_assert!(
                    needed.len() == hi - lo + 1,
                    "receptive field not contiguous at layer {i}: {l}"
                );
                prop_assert!(
                    spans[i] == (lo, hi),
                    "span mismatch at layer {i}: model {:?}, brute force {:?} ({l})",
                    spans[i],
                    (lo, hi)
                );
                prop_assert!(span_rows(spans[i]) == needed.len(), "row count mismatch at {i}");
            }
            Ok(())
        },
    );
}

#[test]
fn chains_cover_every_zoo_network_exactly_once() {
    for net in psim::models::zoo::paper_networks() {
        for depth in [1usize, 2, 3, 8] {
            let ranges = chains(&net, depth);
            let mut covered = 0;
            for (i, r) in ranges.iter().enumerate() {
                assert!(!r.is_empty() && r.len() <= depth.max(1), "{}: bad chain {r:?}", net.name);
                assert_eq!(r.start, covered, "{}: gap before chain {i}", net.name);
                covered = r.end;
            }
            assert_eq!(covered, net.layers.len(), "{}: layers uncovered", net.name);
        }
    }
}

#[test]
fn deeper_fusion_is_monotone_on_vgg() {
    // VGG-16's long stride-1 stacks fuse aggressively: every extra depth
    // must remove traffic (or at worst break even), never add it.
    let net: Network = psim::models::zoo::vgg16();
    let engine = GridEngine::new();
    let mut prev = f64::INFINITY;
    for depth in 1..=5 {
        let cell =
            engine.cell_fused(&net, 2048, Strategy::Optimal, ControllerMode::Passive, 1, depth);
        assert!(cell.total() <= prev, "depth {depth} added traffic");
        prev = cell.total();
    }
    // and depth >= 2 strictly beats unfused on this topology
    let unfused = engine.cell(&net, 2048, Strategy::Optimal, ControllerMode::Passive, 1);
    let fused = engine.cell_fused(&net, 2048, Strategy::Optimal, ControllerMode::Passive, 1, 2);
    assert!(fused.total() < unfused.total());
}
