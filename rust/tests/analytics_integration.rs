//! Integration: the analytical model against the paper's published
//! numbers, at the tolerances EXPERIMENTS.md documents.

use psim::analytics::bandwidth::ControllerMode;
use psim::analytics::paper;
use psim::analytics::partition::Strategy;
use psim::analytics::sweep::network_bandwidth;
use psim::models::zoo;
use psim::report::compare;

/// Table III (minimum bandwidth) reproduces essentially exactly: the two
/// calibrated identifications (VGG-13-as-VGG-16, MobileNetV1) sit within
/// 1%, everything else within 0.1%.
#[test]
fn table3_reproduces_within_1pct() {
    for net in zoo::paper_networks() {
        let ours = net.min_bandwidth() as f64 / 1e6;
        let theirs = paper::table3(&net.name).unwrap();
        let d = (ours - theirs).abs() / theirs;
        assert!(d < 0.01, "{}: ours {ours:.3} vs paper {theirs:.3} ({:.2}%)", net.name, d * 100.0);
    }
}

/// Table II — the paper's core contribution (optimal partitioning under
/// passive vs active controllers) — reproduces with median ~4%, worst
/// under 15% across all 96 cells.
#[test]
fn table2_reproduces_within_15pct() {
    let mut diffs = Vec::new();
    for net in zoo::paper_networks() {
        for &p in &paper::TABLE2_MACS {
            let (pa, ac) = paper::table2(&net.name, p).unwrap();
            for (mode, theirs) in
                [(ControllerMode::Passive, pa), (ControllerMode::Active, ac)]
            {
                let ours = network_bandwidth(&net, p, Strategy::Optimal, mode).total() / 1e6;
                let d = (ours - theirs).abs() / theirs;
                assert!(
                    d < 0.15,
                    "{} P={p} {:?}: ours {ours:.2} vs paper {theirs:.2}",
                    net.name,
                    mode
                );
                diffs.push(d);
            }
        }
    }
    diffs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = diffs[diffs.len() / 2];
    assert!(median < 0.06, "median Table II deviation {median:.3} too large");
}

/// Fig. 2's qualitative structure: savings positive everywhere, in the
/// paper's 19-42% band at 512 MACs (with small modelling margin), and the
/// saving generally shrinks as MACs grow.
#[test]
fn fig2_savings_structure() {
    for net in zoo::paper_networks() {
        let saving = |p: usize| {
            let pa = network_bandwidth(&net, p, Strategy::Optimal, ControllerMode::Passive)
                .total();
            let ac =
                network_bandwidth(&net, p, Strategy::Optimal, ControllerMode::Active).total();
            (pa - ac) / pa * 100.0
        };
        let s512 = saving(512);
        assert!((15.0..=47.0).contains(&s512), "{} @512: {s512:.1}%", net.name);
        let s16k = saving(16384);
        assert!(s16k > 0.0, "{} @16K: {s16k:.1}%", net.name);
        // fig2 trend: constrained systems benefit more (allow mild noise)
        assert!(
            s512 > s16k - 5.0,
            "{}: saving grew with MACs ({s512:.1}% -> {s16k:.1}%)",
            net.name
        );
    }
}

/// The paper's headline ordering (Table I): "This Work" beats (or ties)
/// the three heuristics — guaranteed for the discrete-search variant,
/// and the closed form stays within 5% of the search.
#[test]
fn optimal_dominates_heuristics() {
    for net in zoo::paper_networks() {
        for p in [512usize, 2048, 16384] {
            let search =
                network_bandwidth(&net, p, Strategy::OptimalSearch, ControllerMode::Passive)
                    .total();
            for s in [Strategy::MaxInput, Strategy::MaxOutput, Strategy::EqualMacs] {
                let other = network_bandwidth(&net, p, s, ControllerMode::Passive).total();
                assert!(
                    search <= other * (1.0 + 1e-9),
                    "{} P={p}: search {search} > {:?} {other}",
                    net.name,
                    s
                );
            }
            let formula =
                network_bandwidth(&net, p, Strategy::Optimal, ControllerMode::Passive).total();
            assert!(
                formula <= search * 1.05,
                "{} P={p}: closed form {formula} >5% above search {search}",
                net.name
            );
        }
    }
}

/// Section IV: "as number of MACs increases ... it approaches the minimum
/// bandwidth as given in table III".
#[test]
fn bandwidth_approaches_floor_with_macs() {
    for net in zoo::paper_networks() {
        let floor = net.min_bandwidth() as f64;
        let huge =
            network_bandwidth(&net, 1 << 28, Strategy::OptimalSearch, ControllerMode::Passive)
                .total();
        assert!(
            (huge - floor) / floor < 0.001,
            "{}: {huge} does not approach floor {floor}",
            net.name
        );
    }
}

/// The overall comparison summary stays within the documented bands — a
/// regression canary for any future model change.
#[test]
fn comparison_summary_regression() {
    let cells = compare::compare_all();
    let s = compare::summarize(&cells);
    assert_eq!(s.cells, 200);
    assert!(s.median_rel_diff < 0.08, "median {:.3}", s.median_rel_diff);
    assert!(s.within_5pct >= 85, "within 5%: {}", s.within_5pct);
    assert!(s.within_15pct >= 150, "within 15%: {}", s.within_15pct);
}

/// Faithful architectures: group-aware partitioning never exceeds the
/// dense-equivalent treatment (groups only shrink the psum problem).
#[test]
fn faithful_grouping_never_exceeds_dense() {
    for (f, p) in zoo::faithful_networks().iter().zip(zoo::paper_networks().iter()) {
        if f.name == "VGG-16" {
            continue; // different layer sets (config D vs B)
        }
        for macs in [512usize, 4096] {
            let faithful =
                network_bandwidth(f, macs, Strategy::OptimalSearch, ControllerMode::Passive)
                    .total();
            let dense =
                network_bandwidth(p, macs, Strategy::OptimalSearch, ControllerMode::Passive)
                    .total();
            assert!(
                faithful <= dense * (1.0 + 1e-9),
                "{} P={macs}: faithful {faithful} > dense {dense}",
                f.name
            );
        }
    }
}
