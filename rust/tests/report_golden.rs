//! Golden tests on report formatting: the emitted tables must keep the
//! paper's row/column structure (these strings are what EXPERIMENTS.md
//! embeds).

use psim::report::{compare, fig2, tables};

#[test]
fn table3_golden() {
    let md = tables::table3().to_markdown();
    // exact paper-profile values, formatted at 3 decimals
    for needle in [
        "| AlexNet    | 0.823",
        "| VGG-16     | 20.020",
        "| SqueezeNet | 7.304",
        "| GoogleNet  | 7.889",
        "| ResNet-18  | 4.666",
        "| ResNet-50  | 28.349",
        "| MobileNet  | 10.186",
        "| MNASNet    | 11.001",
    ] {
        assert!(md.contains(needle), "missing row {needle:?} in:\n{md}");
    }
}

#[test]
fn table1_structure() {
    let t = tables::table1();
    assert_eq!(t.n_rows(), 8);
    let md = t.to_markdown();
    for h in ["P=512 Max Input", "P=2048 Equal MACs", "P=16384 This Work"] {
        assert!(md.contains(h), "missing column {h}");
    }
    // markdown is rectangular
    let widths: Vec<usize> = md.lines().map(|l| l.chars().count()).collect();
    assert!(widths.windows(2).all(|w| w[0] == w[1]));
}

#[test]
fn table2_structure() {
    let t = tables::table2();
    let csv = t.to_csv();
    let header = csv.lines().next().unwrap();
    assert_eq!(header.split(',').count(), 13); // CNN + 6 passive + 6 active
    assert_eq!(csv.lines().count(), 9); // header + 8 networks
}

#[test]
fn fig2_csv_plottable() {
    let csv = fig2::fig2_table().to_csv();
    assert_eq!(csv.lines().count(), 9);
    let header = csv.lines().next().unwrap();
    assert!(header.contains("512 MACs") && header.contains("16384 MACs"));
    // every data cell is a percentage
    for line in csv.lines().skip(1) {
        for cell in line.split(',').skip(1) {
            assert!(cell.ends_with('%'), "cell {cell} not a percentage");
        }
    }
}

#[test]
fn compare_table_has_signed_deltas() {
    let cells = compare::compare_all();
    let md = compare::to_table(&cells[..10], false).to_markdown();
    assert!(md.contains('+') || md.contains('-'));
    assert!(md.lines().count() == 12); // header + sep + 10 rows
}

#[test]
fn stable_across_invocations() {
    // Table generation must be deterministic (parallel_map preserves order).
    let a = tables::table1().to_markdown();
    let b = tables::table1().to_markdown();
    assert_eq!(a, b);
}
