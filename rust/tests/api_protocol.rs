//! Protocol-surface contract tests for the typed `api` facade:
//!
//! * golden request/response fixtures — one pinned pair per protocol
//!   command (`rust/tests/golden/protocol/*.txt`, the same files the CI
//!   smoke step diffs against the built binary via `psim request`);
//! * encode/decode round-trip property tests over randomized specs;
//! * the request-size cap rejects oversized sweep AND explore requests
//!   with `code:"too_large"` from every frontend (library dispatch,
//!   protocol line, CLI).

use psim::analytics::bandwidth::ControllerMode;
use psim::analytics::grid::SweepSpec;
use psim::analytics::partition::Strategy;
use psim::api::{codec, ApiError, Engine, ErrorCode, Request, MAX_REQUEST_CELLS};
use psim::dse::budget::SramBudget;
use psim::dse::pareto::Objective;
use psim::dse::space::ExploreSpec;
use psim::models::zoo;
use psim::util::prng::Rng;

/// Every fixture: line 1 is the request, line 2 the expected reply —
/// byte-for-byte what a fresh engine answers (and what `psim request`
/// prints, which is what CI diffs).
///
/// `sweep`/`explore`/`fusion`/`tables` pin full numeric success replies
/// (derived from the PR 1–3 pinned goldens); `analyze` and `infer` pin
/// their deterministic error replies instead — analyze's success table
/// is too environment-heavy to hand-pin byte-exactly, and is covered
/// structurally by `report::analyze` unit tests and the CLI tests.
#[test]
fn golden_protocol_fixtures() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/protocol");
    let mut seen = 0;
    for entry in std::fs::read_dir(dir).expect("fixture dir") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("txt") {
            continue;
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        let request = lines.next().expect("fixture request line");
        let expected = lines.next().expect("fixture response line");
        assert!(lines.next().is_none(), "{}: more than two lines", path.display());
        // Fresh engine per fixture: replies (cache deltas, metrics
        // counters) must not depend on session history.
        let engine = Engine::analytics();
        let (reply, _) = engine.handle_line(request);
        assert_eq!(reply.to_string(), expected, "fixture {}", path.display());
        seen += 1;
    }
    assert_eq!(seen, 11, "one fixture per protocol command");
}

fn roundtrip(req: &Request) {
    let encoded = codec::encode_request(req);
    let decoded = codec::decode_request(&encoded)
        .unwrap_or_else(|e| panic!("decode({encoded}) failed: {e}"));
    assert_eq!(decoded.cmd(), req.cmd());
    let re_encoded = codec::encode_request(&decoded);
    assert_eq!(re_encoded.to_string(), encoded.to_string(), "round-trip changed the request");
}

#[test]
fn fixed_requests_round_trip() {
    let reqs = vec![
        Request::Sweep { spec: SweepSpec::paper_grid(), workers: None },
        Request::Explore { spec: ExploreSpec::paper_space(), workers: Some(8) },
        Request::Fusion {
            networks: vec![zoo::alexnet(), zoo::vgg16()],
            depth: 3,
            p_macs: 2048,
            strategy: Strategy::MaxOutput,
            mode: ControllerMode::Active,
            dt: psim::models::DataTypes::default(),
        },
        Request::Fusion {
            networks: vec![zoo::alexnet()],
            depth: 2,
            p_macs: 1024,
            strategy: Strategy::Optimal,
            mode: ControllerMode::Passive,
            dt: psim::models::DataTypes::parse("8:8:32:8").unwrap(),
        },
        Request::Analyze {
            network: zoo::resnet18(),
            p_macs: 512,
            strategy: Strategy::OptimalSearch,
            mode: ControllerMode::Passive,
            dt: psim::models::DataTypes::default(),
        },
        Request::Analyze {
            network: zoo::alexnet(),
            p_macs: 2048,
            strategy: Strategy::Optimal,
            mode: ControllerMode::Active,
            dt: psim::models::DataTypes::parse("8:8:24:8").unwrap(),
        },
        Request::Tables { table: psim::api::TableKind::Fig2Ascii, faithful: true },
        Request::Zoo,
        Request::Infer { image: vec![0.0, 1.5, -2.25] },
        Request::Metrics,
        Request::Stats,
        Request::Version,
        Request::Shutdown,
    ];
    for req in &reqs {
        roundtrip(req);
    }
}

const NET_NAMES: [&str; 8] = [
    "AlexNet",
    "VGG-16",
    "SqueezeNet",
    "GoogleNet",
    "ResNet-18",
    "ResNet-50",
    "MobileNet",
    "MNASNet",
];
const STRATEGIES: [Strategy; 5] = [
    Strategy::MaxInput,
    Strategy::MaxOutput,
    Strategy::EqualMacs,
    Strategy::Optimal,
    Strategy::OptimalSearch,
];
const MODE_SETS: [&[ControllerMode]; 3] = [
    &[ControllerMode::Passive],
    &[ControllerMode::Active],
    &[ControllerMode::Passive, ControllerMode::Active],
];

fn random_networks(rng: &mut Rng) -> Vec<psim::models::Network> {
    (0..rng.range(1, 3)).map(|_| zoo::by_name(rng.pick(&NET_NAMES)).unwrap()).collect()
}

fn random_subset<T: Copy>(rng: &mut Rng, pool: &[T]) -> Vec<T> {
    (0..rng.range(1, pool.len())).map(|_| *rng.pick(pool)).collect()
}

#[test]
fn random_sweep_requests_round_trip() {
    const BITS: [&str; 4] = ["8:8:8:8", "8:8:32:8", "16:16:32:16", "8:8:24:8"];
    let mut rng = Rng::new(0x5eed_0001);
    for _ in 0..50 {
        let mut spec = SweepSpec::new(random_networks(&mut rng))
            .with_macs((0..rng.range(1, 4)).map(|_| rng.range(1, 20000)).collect())
            .with_strategies(random_subset(&mut rng, &STRATEGIES))
            .with_modes(rng.pick(&MODE_SETS).to_vec())
            .with_batches((0..rng.range(1, 3)).map(|_| rng.range(1, 16)).collect())
            .with_fusion((0..rng.range(1, 3)).map(|_| rng.range(1, 4)).collect());
        if rng.chance(0.5) {
            spec = spec.with_datatypes(
                (0..rng.range(1, 3))
                    .map(|_| psim::models::DataTypes::parse(rng.pick(&BITS)).unwrap())
                    .collect(),
            );
        }
        let workers = rng.chance(0.5).then(|| rng.range(1, 64));
        roundtrip(&Request::Sweep { spec, workers });
    }
}

#[test]
fn random_explore_requests_round_trip() {
    const SRAM: [SramBudget; 4] = [
        SramBudget::Unlimited,
        SramBudget::Elems(1 << 16),
        SramBudget::Elems(1 << 20),
        SramBudget::Elems(123_456),
    ];
    let mut rng = Rng::new(0x5eed_0002);
    for _ in 0..50 {
        let mut spec = ExploreSpec::new(random_networks(&mut rng))
            .with_macs((0..rng.range(1, 4)).map(|_| rng.range(1, 20000)).collect())
            .with_sram(random_subset(&mut rng, &SRAM))
            .with_strategies(random_subset(&mut rng, &STRATEGIES))
            .with_modes(rng.pick(&MODE_SETS).to_vec())
            .with_fusion((0..rng.range(1, 3)).map(|_| rng.range(1, 4)).collect())
            .with_objectives(random_subset(&mut rng, &Objective::ALL));
        if rng.chance(0.5) {
            spec = spec
                .with_datatypes(psim::models::DataTypes::parse("8:8:32:8").unwrap())
                .with_objectives(vec![Objective::BandwidthBytes, Objective::Utilization]);
        }
        let workers = rng.chance(0.5).then(|| rng.range(1, 64));
        roundtrip(&Request::Explore { spec, workers });
    }
}

/// An oversized sweep spec: paper-default axes for one network (48 cells
/// per batch) times 2101 batch sizes > 100k cells.
fn oversized_sweep() -> SweepSpec {
    let spec = SweepSpec::new(vec![zoo::alexnet()]).with_batches((1..=2101).collect());
    assert!(spec.cell_count() > MAX_REQUEST_CELLS);
    spec
}

/// An oversized explore spec: 32 candidates per MAC budget × 3200 budgets.
fn oversized_explore() -> ExploreSpec {
    let spec = ExploreSpec::new(vec![zoo::alexnet()]).with_macs((1..=3200).collect());
    assert!(spec.candidate_count() > MAX_REQUEST_CELLS);
    spec
}

#[test]
fn oversized_requests_rejected_from_library_dispatch() {
    let engine = Engine::analytics();
    let err = engine.dispatch(&Request::Sweep { spec: oversized_sweep(), workers: None });
    assert_eq!(err.unwrap_err().code, ErrorCode::TooLarge);
    let err = engine.dispatch(&Request::Explore { spec: oversized_explore(), workers: None });
    assert_eq!(err.unwrap_err().code, ErrorCode::TooLarge);
}

#[test]
fn oversized_requests_rejected_from_protocol_lines() {
    let engine = Engine::analytics();
    for req in [
        codec::encode_request(&Request::Sweep { spec: oversized_sweep(), workers: None }),
        codec::encode_request(&Request::Explore { spec: oversized_explore(), workers: None }),
    ] {
        let (reply, stop) = engine.handle_line(&req.to_string());
        assert!(!stop);
        assert_eq!(reply.get("code").unwrap().as_str(), Some("too_large"), "{reply}");
        let msg = reply.get("error").unwrap().as_str().unwrap();
        assert!(msg.contains("limit 100000"), "{msg}");
    }
}

#[test]
fn oversized_requests_rejected_from_cli() {
    let batches = (1..=2101).map(|i| i.to_string()).collect::<Vec<_>>().join(",");
    let argv: Vec<String> = ["sweep", "--networks", "AlexNet", "--batches", batches.as_str()]
        .map(String::from)
        .to_vec();
    let err = psim::cli::run(&argv).unwrap_err();
    let api_err = err.downcast_ref::<ApiError>().expect("an ApiError from CLI sweep");
    assert_eq!(api_err.code, ErrorCode::TooLarge);

    let macs = (1..=3200).map(|i| i.to_string()).collect::<Vec<_>>().join(":");
    let constraints = format!("macs={macs}");
    let argv: Vec<String> =
        ["explore", "--networks", "AlexNet", "--constraints", constraints.as_str()]
            .map(String::from)
            .to_vec();
    let err = psim::cli::run(&argv).unwrap_err();
    let api_err = err.downcast_ref::<ApiError>().expect("an ApiError from CLI explore");
    assert_eq!(api_err.code, ErrorCode::TooLarge);
}

#[test]
fn error_replies_carry_stable_codes() {
    let engine = Engine::analytics();
    for (line, code) in [
        ("not json", "bad_request"),
        (r#"{"cmd":"bogus"}"#, "bad_request"),
        (r#"{"cmd":"sweep","macs":[0]}"#, "bad_request"),
        ("{}", "bad_request"),
        (r#"{"cmd":"version","protocol":99}"#, "bad_request"),
    ] {
        let (reply, _) = engine.handle_line(line);
        assert_eq!(reply.get("code").unwrap().as_str(), Some(code), "{line}");
        assert!(reply.get("error").is_some(), "{line}");
    }
}

/// The serve protocol accepts an explicit matching `protocol` field and
/// version requests report it back.
#[test]
fn protocol_version_negotiation() {
    let engine = Engine::analytics();
    let (reply, _) =
        engine.handle_line(r#"{"cmd":"sweep","networks":["AlexNet"],"macs":[512],"protocol":1,
                               "strategies":["optimal"],"modes":["passive"]}"#);
    assert_eq!(reply.get("count").unwrap().as_usize(), Some(1));
    let (reply, _) = engine.handle_line(r#"{"cmd":"version"}"#);
    assert_eq!(
        reply.get("protocol").unwrap().as_usize(),
        Some(psim::api::PROTOCOL_VERSION)
    );
}
