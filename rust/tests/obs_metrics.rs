//! Property-based invariants for the `obs::metrics` histogram and the
//! `obs::span` ring buffer, using the in-tree quickcheck harness
//! (deterministic, replayable).
//!
//! The three load-bearing claims behind `{"cmd":"stats"}`:
//! per-thread histograms merge losslessly, bucketed percentiles stay
//! within one bucket width of the exact [`percentile`] over the raw
//! samples, and concurrent recording never drops a count.

use psim::obs::metrics::{bucket_bound, Counter, Histogram, BUCKETS};
use psim::obs::span::SpanLog;
use psim::prop_assert;
use psim::util::benchkit::percentile;
use psim::util::prng::Rng;
use psim::util::quickcheck::forall;

/// Smallest bucket index whose upper bound holds `v` — the bucket
/// `Histogram::record` files `v` under, recomputed from the public
/// bounds so the test cannot share a bug with the implementation.
fn bucket_of(v: u64) -> usize {
    (0..BUCKETS).find(|&i| v <= bucket_bound(i)).expect("last bucket holds u64::MAX")
}

/// Random latency sample sets: mixed magnitudes so buckets across the
/// whole log-2 range (including 0 and the overflow bucket) get hit.
fn gen_samples(r: &mut Rng) -> Vec<u64> {
    let n = r.range(1, 200);
    (0..n)
        .map(|_| {
            let magnitude = r.range(0, 40) as u32;
            r.below(2u64.saturating_pow(magnitude).max(1))
        })
        .collect()
}

#[test]
fn merged_shards_equal_single_histogram() {
    forall("hist-merge-lossless", 64, gen_samples, |samples| {
        let single = Histogram::new();
        for &v in samples {
            single.record(v);
        }
        // Shard the same samples over 4 histograms, then merge.
        let shards: Vec<Histogram> = (0..4).map(|_| Histogram::new()).collect();
        for (i, &v) in samples.iter().enumerate() {
            shards[i % shards.len()].record(v);
        }
        let merged = Histogram::new();
        for shard in &shards {
            merged.merge(shard);
        }
        prop_assert!(merged.count() == single.count(), "count diverged");
        prop_assert!(merged.sum() == single.sum(), "sum diverged");
        prop_assert!(merged.max_value() == single.max_value(), "max diverged");
        prop_assert!(
            merged.bucket_counts() == single.bucket_counts(),
            "bucket counts diverged: {:?} != {:?}",
            merged.bucket_counts(),
            single.bucket_counts()
        );
        Ok(())
    });
}

#[test]
fn bucketed_percentiles_track_exact_percentiles() {
    forall("hist-percentile-vs-exact", 64, gen_samples, |samples| {
        let hist = Histogram::new();
        for &v in samples {
            hist.record(v);
        }
        let mut sorted = samples.clone();
        sorted.sort_unstable();
        for p in [0.50, 0.95, 0.99] {
            let exact = percentile(&sorted, p);
            let bucketed = hist.percentile(p);
            prop_assert!(
                exact <= bucketed,
                "p{p}: bucketed {bucketed} below exact {exact}"
            );
            let bucket = bucket_of(exact);
            prop_assert!(
                bucket_of(bucketed) == bucket,
                "p{p}: bucketed {bucketed} left exact {exact}'s bucket {bucket}"
            );
            let lower = if bucket == 0 { 0 } else { bucket_bound(bucket - 1) };
            let width = bucket_bound(bucket) - lower;
            prop_assert!(
                bucketed - exact <= width,
                "p{p}: bucketed {bucketed} more than one bucket width {width} above {exact}"
            );
        }
        Ok(())
    });
}

#[test]
fn concurrent_recording_never_loses_counts() {
    for (threads, per_thread) in [(2, 100), (4, 250), (8, 397)] {
        let hist = Histogram::new();
        let counter = Counter::new();
        std::thread::scope(|scope| {
            for t in 0..threads {
                let (hist, counter) = (&hist, &counter);
                scope.spawn(move || {
                    for i in 0..per_thread {
                        hist.record((t * per_thread + i) as u64);
                        counter.inc();
                    }
                });
            }
        });
        let expected = (threads * per_thread) as u64;
        assert_eq!(hist.count(), expected, "{threads}x{per_thread}: histogram lost counts");
        assert_eq!(counter.get(), expected, "{threads}x{per_thread}: counter lost increments");
        let bucket_total: u64 = hist.bucket_counts().iter().sum();
        assert_eq!(bucket_total, expected, "{threads}x{per_thread}: buckets lost counts");
        let max_sample = expected - 1;
        let exact_sum = max_sample * expected / 2;
        assert_eq!(hist.sum(), exact_sum, "{threads}x{per_thread}: sum lost increments");
        assert_eq!(hist.max_value(), max_sample, "{threads}x{per_thread}: max lost");
    }
}

#[test]
fn span_ring_accounts_for_every_record() {
    forall(
        "span-ring-conservation",
        64,
        |r: &mut Rng| (r.range(0, 16), r.range(0, 64)),
        |&(cap, records)| {
            let log = SpanLog::new(cap);
            for i in 0..records {
                log.record_us("stage", i as u64);
            }
            let kept = records.min(cap);
            prop_assert!(log.len() == kept, "kept {} != {kept}", log.len());
            prop_assert!(
                log.dropped() == (records - kept) as u64,
                "dropped {} != {}",
                log.dropped(),
                records - kept
            );
            // The ring keeps the NEWEST entries: the survivors are the
            // last `kept` durations in record order.
            let tail: Vec<u64> = (records - kept..records).map(|i| i as u64).collect();
            let snap: Vec<u64> = log.snapshot().iter().map(|s| s.dur_us).collect();
            prop_assert!(snap == tail, "ring kept {snap:?}, expected {tail:?}");
            Ok(())
        },
    );
}
