//! Cache-equivalence, canonicalization and corruption suite for the
//! content-addressed result store:
//!
//! * canonicalization: request spelling (JSON key order, axis order,
//!   elided-vs-explicit defaults, `workers`, `protocol`) never changes
//!   the content address, and a 1000-spec randomized corpus produces no
//!   FNV-1a digest collisions;
//! * pinned digests: the canonical digest of every decodable golden
//!   protocol request, recomputed and compared byte-for-byte — a drift
//!   here silently orphans every artifact ever written, so it must be
//!   deliberate;
//! * cache equivalence: cold replies with the store attached stay
//!   byte-identical to the pinned fixtures, and warm repeats replay the
//!   cold bytes verbatim — in-process and through the pooled server;
//! * the LRU eviction property and the conservation law
//!   `cache_hits + cache_misses == cache_lookups`;
//! * corruption: truncated, bit-flipped, mis-checksummed, wrong-version
//!   and garbage artifacts are rejected (counted as invalidations),
//!   recomputed to the exact fixture bytes and repaired on disk — never
//!   served stale, never a panic;
//! * acceptance: the warm repeat of the full AlexNet paper-grid sweep
//!   records zero new `grid_cell_eval_us` observations.

use std::collections::HashSet;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::Duration;

use psim::api::codec::decode_line;
use psim::api::Engine;
use psim::cli::commands::serve::{bind, serve_on, ServeConfig};
use psim::store::canon::{cache_key, canonical_line};
use psim::store::digest::{digest_hex, fnv1a_64};
use psim::store::{artifact, ResultStore};
use psim::util::prng::Rng;
use psim::util::sync::lock_unpoisoned;

/// `grid_cell_eval_us` lives in the process-global registry, so every
/// test in this binary that dispatches a sweep serializes here —
/// otherwise the zero-new-observations acceptance assertion would race
/// with its neighbors' grid evaluations.
static GRID_HISTOGRAM: Mutex<()> = Mutex::new(());

const SHUTDOWN_LINE: &str = r#"{"cmd":"shutdown"}"#;

/// The pinned FNV-1a content address of every decodable golden request
/// (`digest_hex(canonical_line(request))`). The two fixtures missing
/// here (`analyze`, `infer`) pin error replies: their requests fail to
/// decode and can never reach the store.
const PINNED: [(&str, &str); 9] = [
    ("explore", "128c793c9df0acfd"),
    ("fusion", "6ffd21f078298471"),
    ("metrics", "9f3db6d01f7499af"),
    ("shutdown", "e6d083f7651e09ba"),
    ("stats", "b322baa1be826859"),
    ("sweep", "8801cdb52ecd4a33"),
    ("tables", "ea80e65b9cc1145e"),
    ("version", "989ee366adf9c38c"),
    ("zoo", "973c519d6f4e70bc"),
];

/// `(request line, pinned reply line)` of one golden protocol fixture.
fn fixture(stem: &str) -> (String, String) {
    let path = format!("{}/tests/golden/protocol/{stem}.txt", env!("CARGO_MANIFEST_DIR"));
    let text = std::fs::read_to_string(&path).unwrap_or_else(|_| panic!("fixture {stem}"));
    let mut lines = text.lines();
    let request = lines.next().expect("fixture request line").to_string();
    let reply = lines.next().expect("fixture reply line").to_string();
    (request, reply)
}

fn engine_with_memory_store(capacity: usize) -> Engine {
    let engine = Engine::analytics();
    let store = ResultStore::memory(capacity, engine.registry());
    assert!(engine.attach_store(store));
    engine
}

fn engine_with_disk_store(dir: &Path) -> Engine {
    let engine = Engine::analytics();
    let store = ResultStore::open(dir, 8, engine.registry()).expect("open disk store");
    assert!(engine.attach_store(store));
    engine
}

/// A fresh per-test artifact directory (removed up front so reruns
/// start clean; each caller removes it again on success).
fn temp_store_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("psim_store_cache_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

// ---------------------------------------------------------------------
// Canonicalization
// ---------------------------------------------------------------------

/// Pinned content addresses for the golden requests. A digest change
/// means previously written artifacts stop matching this build — a
/// breaking store change that must be deliberate, exactly like a reply
/// fixture drift.
#[test]
fn golden_request_digests_are_pinned() {
    for (stem, expected) in PINNED {
        let (request, _) = fixture(stem);
        let req = decode_line(&request).unwrap_or_else(|e| panic!("decode {stem}: {e}"));
        let digest = digest_hex(canonical_line(&req).as_bytes());
        assert_eq!(digest, expected, "canonical digest for '{stem}' drifted");
    }
    for stem in ["analyze", "infer"] {
        let (request, _) = fixture(stem);
        assert!(decode_line(&request).is_err(), "'{stem}' fixture unexpectedly decodes");
    }
    // Every fixture is accounted for: a new command must pin its digest
    // here (or join the undecodable pair above).
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/protocol");
    let fixtures = std::fs::read_dir(dir)
        .expect("fixture dir")
        .filter_map(|entry| entry.ok())
        .filter(|entry| entry.path().extension().and_then(|e| e.to_str()) == Some("txt"))
        .count();
    assert_eq!(fixtures, PINNED.len() + 2, "new fixture: pin its content address");
}

/// JSON key order, axis order, elided-vs-explicit defaults, the
/// `protocol` field and `workers` are all spelling, not identity: every
/// variant lands on one canonical line and one digest.
#[test]
fn spelling_never_changes_the_content_address() {
    let sweeps = [
        concat!(
            r#"{"cmd":"sweep","networks":["AlexNet"],"macs":[512,1024],"#,
            r#""strategies":["max-input","max-output"],"modes":["passive","active"],"#,
            r#""batches":[1],"fusion_depth":[1]}"#
        ),
        // Scrambled keys and axes, defaults elided instead of explicit.
        concat!(
            r#"{"modes":["active","passive"],"strategies":["max-output","max-input"],"#,
            r#""macs":[1024,512],"networks":["AlexNet"],"cmd":"sweep"}"#
        ),
        // Explicit protocol version and a worker hint.
        concat!(
            r#"{"cmd":"sweep","protocol":1,"workers":7,"networks":["AlexNet"],"#,
            r#""macs":[512,1024],"strategies":["max-input","max-output"],"#,
            r#""modes":["passive","active"]}"#
        ),
    ];
    let explores = [
        r#"{"cmd":"explore","networks":["AlexNet"],"macs":[512]}"#.to_string(),
        // Every default axis spelled out, scrambled, plus protocol and
        // workers: identical to the elided form above.
        concat!(
            r#"{"workers":3,"sram":[65536,262144,1048576,"unlimited"],"#,
            r#""objectives":["utilization","energy","sram-accesses","bandwidth"],"#,
            r#""strategies":["optimal","equal-macs","max-output","max-input"],"#,
            r#""modes":["active","passive"],"fusion":[1],"macs":[512],"#,
            r#""networks":["AlexNet"],"cmd":"explore","protocol":1}"#
        )
        .to_string(),
    ];
    let canon = |line: &str| {
        let req = decode_line(line).unwrap_or_else(|e| panic!("decode {line}: {e}"));
        canonical_line(&req)
    };
    let sweep_canonical = canon(sweeps[0]);
    for line in &sweeps[1..] {
        assert_eq!(canon(line), sweep_canonical, "sweep spelling changed the identity: {line}");
    }
    let explore_canonical = canon(&explores[0]);
    for line in &explores[1..] {
        assert_eq!(canon(line), explore_canonical, "explore spelling changed identity: {line}");
    }
    assert_ne!(
        fnv1a_64(sweep_canonical.as_bytes()),
        fnv1a_64(explore_canonical.as_bytes()),
        "distinct requests must not share an address"
    );
}

/// 1000 randomized specs (each with a unique MAC budget, so every
/// canonical line is distinct by construction): no two may collide to
/// one FNV-1a digest — a collision would silently cross-serve replies.
#[test]
fn randomized_spec_corpus_has_no_digest_collisions() {
    const N: usize = 1_000;
    let strategies = ["max-input", "max-output", "equal-macs", "optimal"];
    let modes = ["passive", "active"];
    let srams = [r#""unlimited""#, "65536", "262144"];
    let mut rng = Rng::new(0x5eed_cafe);
    let mut canonicals: HashSet<String> = HashSet::new();
    let mut digests: HashSet<u64> = HashSet::new();
    for i in 0..N {
        let unique = 20_000 + i; // a MAC budget no other spec in the corpus has
        let extra = 512u64 << rng.below(4);
        let strategy = *rng.pick(&strategies);
        let mode = *rng.pick(&modes);
        let line = if i % 2 == 0 {
            format!(
                concat!(
                    r#"{{"cmd":"sweep","networks":["AlexNet"],"macs":[{u},{e}],"#,
                    r#""strategies":["{s}"],"modes":["{m}"]}}"#
                ),
                u = unique,
                e = extra,
                s = strategy,
                m = mode
            )
        } else {
            format!(
                concat!(
                    r#"{{"cmd":"explore","networks":["AlexNet"],"macs":[{u}],"#,
                    r#""sram":[{sr}],"strategies":["{s}"],"modes":["{m}"]}}"#
                ),
                u = unique,
                sr = rng.pick(&srams),
                s = strategy,
                m = mode
            )
        };
        let req = decode_line(&line).unwrap_or_else(|e| panic!("spec #{i}: {e}"));
        let canonical = canonical_line(&req);
        assert!(canonicals.insert(canonical.clone()), "duplicate canonical at #{i}");
        let fresh = digests.insert(fnv1a_64(canonical.as_bytes()));
        assert!(fresh, "FNV-1a collision at spec #{i}: {canonical}");
    }
    assert_eq!(digests.len(), N);
}

// ---------------------------------------------------------------------
// Cache equivalence
// ---------------------------------------------------------------------

/// Cold replies with the store attached are byte-identical to the
/// pinned fixtures (attaching a store must never change reply bytes),
/// and every cacheable command's warm repeat replays the cold bytes
/// verbatim with exact `cache_*` accounting.
#[test]
fn fixtures_replay_byte_identical_cold_and_warm_in_process() {
    let _grid = lock_unpoisoned(&GRID_HISTOGRAM);
    for (stem, _) in PINNED {
        let (request, expected) = fixture(stem);
        let engine = engine_with_memory_store(16);
        let (cold, _) = engine.handle_line(&request);
        assert_eq!(cold.to_string(), expected, "cold '{stem}' drifted with the store on");
        let req = decode_line(&request).expect("pinned fixtures decode");
        if cache_key(&req).is_none() {
            let counters = engine.store().expect("store attached").counters();
            assert_eq!(counters.lookups.get(), 0, "'{stem}' must never consult the store");
            continue;
        }
        let (warm, _) = engine.handle_line(&request);
        assert_eq!(warm.to_string(), expected, "warm '{stem}' is not the stored bytes");
        let counters = engine.store().expect("store attached").counters();
        assert_eq!(counters.hits.get(), 1, "'{stem}' warm repeat must hit");
        assert_eq!(counters.misses.get(), 1);
        assert_eq!(counters.lookups.get(), 2);
    }
}

/// One JSON-lines client connection against the pooled server.
struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        Client { writer: stream.try_clone().unwrap(), reader: BufReader::new(stream) }
    }

    fn roundtrip(&mut self, line: &str) -> String {
        writeln!(self.writer, "{line}").expect("send");
        let mut reply = String::new();
        assert!(self.reader.read_line(&mut reply).expect("reply") > 0, "server closed");
        reply.trim_end().to_string()
    }
}

/// A pooled server over a store-attached engine on an ephemeral port.
struct PooledServer {
    addr: SocketAddr,
    done: mpsc::Receiver<()>,
    handle: thread::JoinHandle<()>,
}

fn start_pooled(engine: Arc<Engine>) -> PooledServer {
    let config = ServeConfig { workers: 2, queue: 8, max_conns: 16, timeout: None };
    let (listener, _port) = bind(0).expect("ephemeral bind");
    let addr = listener.local_addr().expect("listener addr");
    let (tx, done) = mpsc::channel();
    let handle = thread::spawn(move || {
        serve_on(listener, &engine, &config).expect("server failed");
        let _ = tx.send(());
    });
    PooledServer { addr, done, handle }
}

/// The cacheable fixtures replay byte-identical through the pooled
/// server too: the cold reply matches the pinned fixture and the warm
/// repeat replays the stored bytes over the wire (the store-hit branch
/// of the shared handler, upstream of the coalescer).
#[test]
fn fixtures_replay_byte_identical_through_the_pooled_server() {
    let _grid = lock_unpoisoned(&GRID_HISTOGRAM);
    for (stem, _) in PINNED {
        let (request, expected) = fixture(stem);
        let req = decode_line(&request).expect("pinned fixtures decode");
        if cache_key(&req).is_none() {
            continue;
        }
        let engine = Arc::new(engine_with_memory_store(16));
        let server = start_pooled(engine.clone());
        let mut client = Client::connect(server.addr);
        let cold = client.roundtrip(&request);
        assert_eq!(cold, expected, "cold '{stem}' drifted through the pooled server");
        let warm = client.roundtrip(&request);
        assert_eq!(warm, expected, "warm '{stem}' is not the stored bytes over the wire");
        let counters = engine.store().expect("store attached").counters();
        assert_eq!(counters.hits.get(), 1, "'{stem}' warm repeat must hit");
        assert_eq!(counters.hits.get() + counters.misses.get(), counters.lookups.get());
        let bye = client.roundtrip(SHUTDOWN_LINE);
        assert!(bye.contains("true"), "{bye}");
        server.done.recv_timeout(Duration::from_secs(10)).expect("server shutdown deadline");
        server.handle.join().expect("server thread panicked");
    }
}

/// LRU eviction property, end to end through the engine: a capacity-1
/// store thrashes between two alternating requests (every lookup
/// misses, every insert evicts) while a capacity-2 store holds both —
/// and the conservation law holds exactly either way.
#[test]
fn lru_eviction_property_through_the_engine() {
    let table1 = r#"{"cmd":"tables","table":"table1"}"#;
    let table2 = r#"{"cmd":"tables","table":"table2"}"#;

    let thrashing = engine_with_memory_store(1);
    for line in [table1, table2, table1, table2] {
        let (_reply, _) = thrashing.handle_line(line);
    }
    let c = thrashing.store().expect("store attached").counters();
    assert_eq!(c.hits.get(), 0, "capacity 1 cannot hold two alternating entries");
    assert_eq!(c.misses.get(), 4);
    assert_eq!(c.evictions.get(), 3, "every insert after the first evicts the other entry");
    assert_eq!(c.hits.get() + c.misses.get(), c.lookups.get());

    let roomy = engine_with_memory_store(2);
    for line in [table1, table2, table1, table2] {
        let (_reply, _) = roomy.handle_line(line);
    }
    let c = roomy.store().expect("store attached").counters();
    assert_eq!(c.hits.get(), 2, "capacity 2 holds both entries");
    assert_eq!(c.misses.get(), 2);
    assert_eq!(c.evictions.get(), 0);
    assert_eq!(c.hits.get() + c.misses.get(), c.lookups.get());
}

// ---------------------------------------------------------------------
// Corruption
// ---------------------------------------------------------------------

/// Every corrupted artifact is rejected (counted as exactly one
/// invalidation), recomputed to the exact fixture bytes, and repaired
/// on disk so the next fresh store hits again. No corruption panics,
/// none serves stale bytes.
#[test]
fn corrupted_artifacts_are_rejected_recomputed_and_repaired() {
    let (request, expected) = fixture("tables");
    let cases: [(&str, fn(&str) -> String); 8] = [
        ("truncated", |text| {
            text.lines().next().map(|m| format!("{m}\n")).unwrap_or_default()
        }),
        ("bit_flipped_payload", |text| {
            let mut lines = text.lines();
            let manifest = lines.next().expect("manifest line");
            let payload = lines.next().expect("payload line");
            format!("{manifest}\n{payload}X\n")
        }),
        ("wrong_checksum", |text| {
            let mut lines = text.lines();
            let manifest = lines.next().expect("manifest line").to_string();
            let payload = lines.next().expect("payload line");
            let forged = manifest.replace(&digest_hex(payload.as_bytes()), &"0".repeat(16));
            format!("{forged}\n{payload}\n")
        }),
        ("wrong_schema", |text| text.replace(r#""schema":1"#, r#""schema":99"#)),
        ("wrong_protocol", |text| text.replace(r#""protocol":1,"#, r#""protocol":99,"#)),
        ("garbage_manifest", |text| {
            let payload = text.lines().nth(1).expect("payload line");
            format!("not json {{]\n{payload}\n")
        }),
        ("empty_file", |_| String::new()),
        ("extra_trailing_line", |text| format!("{text}stale\n")),
    ];
    for (tag, corrupt) in cases {
        let dir = temp_store_dir(&format!("corrupt_{tag}"));
        // Seed one valid artifact by computing through a disk-backed engine.
        let seeded = engine_with_disk_store(&dir);
        let (cold, _) = seeded.handle_line(&request);
        assert_eq!(cold.to_string(), expected, "'{tag}': seed reply drifted");
        let entries = artifact::scan(&dir).expect("scan seeded store");
        assert_eq!(entries.len(), 1, "'{tag}': expected exactly the seeded artifact");
        let path = entries[0].0.clone();
        let text = std::fs::read_to_string(&path).expect("artifact text");
        let forged = corrupt(&text);
        assert_ne!(forged, text, "'{tag}': corruption must change the bytes");
        std::fs::write(&path, forged).expect("write corruption");

        // A fresh store must reject the artifact, recompute, and repair.
        let engine = engine_with_disk_store(&dir);
        let (reply, _) = engine.handle_line(&request);
        assert_eq!(reply.to_string(), expected, "'{tag}': recomputed reply drifted");
        let c = engine.store().expect("store attached").counters();
        assert_eq!(c.hits.get(), 0, "'{tag}': a corrupted artifact must never hit");
        assert_eq!(c.misses.get(), 1, "'{tag}': rejection falls through to a miss");
        assert_eq!(c.invalidations.get(), 1, "'{tag}': rejection must be counted");
        assert_eq!(c.hits.get() + c.misses.get(), c.lookups.get());

        // The recompute rewrote the artifact: the next fresh store hits.
        let healed = engine_with_disk_store(&dir);
        let (warm, _) = healed.handle_line(&request);
        assert_eq!(warm.to_string(), expected, "'{tag}': repaired reply drifted");
        let c = healed.store().expect("store attached").counters();
        assert_eq!(c.hits.get(), 1, "'{tag}': the repaired artifact must hit");
        assert_eq!(c.invalidations.get(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}

// ---------------------------------------------------------------------
// Acceptance: warm paper-grid sweep
// ---------------------------------------------------------------------

/// A warm repeat of the full AlexNet paper-grid sweep is a pure store
/// replay: byte-identical to the cold reply AND zero new
/// `grid_cell_eval_us` observations (the grid engine is never
/// consulted). A respelled repeat (scrambled keys, explicit protocol)
/// hits the same entry.
#[test]
fn warm_paper_grid_sweep_records_zero_new_grid_cell_observations() {
    let _grid = lock_unpoisoned(&GRID_HISTOGRAM);
    let engine = engine_with_memory_store(8);
    let line = r#"{"cmd":"sweep","networks":["AlexNet"]}"#;
    let hist = psim::obs::registry::global().histogram("grid_cell_eval_us");

    let before_cold = hist.count();
    let (cold, _) = engine.handle_line(line);
    let after_cold = hist.count();
    assert!(after_cold > before_cold, "cold paper-grid sweep must evaluate grid cells");

    let (warm, _) = engine.handle_line(line);
    assert_eq!(hist.count(), after_cold, "warm repeat re-evaluated grid cells");
    assert_eq!(warm.to_string(), cold.to_string(), "warm bytes differ from cold");

    let respelled = r#"{"networks":["AlexNet"],"cmd":"sweep","protocol":1}"#;
    let (respelled_warm, _) = engine.handle_line(respelled);
    assert_eq!(hist.count(), after_cold, "respelled repeat re-evaluated grid cells");
    assert_eq!(respelled_warm.to_string(), cold.to_string());

    let counters = engine.store().expect("store attached").counters();
    assert_eq!(counters.hits.get(), 2, "both repeats must hit the one entry");
    assert_eq!(counters.misses.get(), 1);
    assert_eq!(counters.lookups.get(), 3);
}
