//! Property-based invariants over randomized layers and partitions,
//! using the in-tree quickcheck harness (deterministic, replayable).

use psim::analytics::bandwidth::{layer_bandwidth, ControllerMode};
use psim::analytics::optimizer;
use psim::analytics::partition::{partition_layer, Strategy};
use psim::models::ConvLayer;
use psim::prop_assert;
use psim::sim::scheduler::{simulate_layer_with, SimConfig};
use psim::util::mathx::{divisors, nearest_divisor_log};
use psim::util::prng::Rng;
use psim::util::quickcheck::forall;

/// Random-but-plausible conv layer: channels in [1, 256], spatial in
/// [k, 64], kernel in {1,3,5,7}, optional grouping.
fn gen_layer(r: &mut Rng) -> ConvLayer {
    let k = *r.pick(&[1usize, 3, 5, 7]);
    let wi = r.range(k.max(4), 64);
    let hi = r.range(k.max(4), 64);
    let mut m = r.range(1, 256);
    let mut n = r.range(1, 256);
    let pad = r.range(0, k / 2);
    // sometimes grouped (including depthwise)
    let groups = if r.chance(0.25) {
        let g = *r.pick(&[2usize, 4, 8]);
        m = (m / g).max(1) * g;
        n = (n / g).max(1) * g;
        g
    } else if r.chance(0.1) {
        m = m.max(2);
        n = m; // depthwise
        m
    } else {
        1
    };
    ConvLayer::grouped("rand", wi, hi, m, n, k, 1, pad, groups)
}

fn gen_budget(r: &mut Rng) -> usize {
    *r.pick(&[128usize, 512, 1024, 2048, 4096, 16384])
}

#[test]
fn prop_sim_matches_model_on_random_layers() {
    forall(
        "sim == model",
        192,
        |r| (gen_layer(r), gen_budget(r)),
        |(layer, p)| {
            for mode in ControllerMode::ALL {
                let part = partition_layer(layer, *p, Strategy::Optimal, mode);
                let cfg = SimConfig::new(*p, mode, Strategy::Optimal);
                let sim = simulate_layer_with(layer, &cfg, part).stats;
                let model = layer_bandwidth(layer, part.m, part.n, mode);
                prop_assert!(
                    sim.activation_traffic() as f64 == model.total(),
                    "sim {} != model {} for {layer} at P={p} {mode:?} {part:?}",
                    sim.activation_traffic(),
                    model.total()
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_active_never_worse_than_passive() {
    forall(
        "active <= passive",
        256,
        |r| {
            let layer = gen_layer(r);
            let mg = layer.m_per_group();
            let ng = layer.n_per_group();
            let m = *r.pick(&divisors(mg));
            let n = r.range(1, ng);
            (layer, m, n)
        },
        |(layer, m, n)| {
            let p = layer_bandwidth(layer, *m, *n, ControllerMode::Passive);
            let a = layer_bandwidth(layer, *m, *n, ControllerMode::Active);
            prop_assert!(a.total() <= p.total(), "active {} > passive {}", a.total(), p.total());
            prop_assert!(a.input == p.input, "input side must not change");
            Ok(())
        },
    );
}

#[test]
fn prop_search_is_discrete_optimum() {
    // The search result must beat every feasible (divisor-m, any-n) pair
    // we can sample.
    forall(
        "search optimal",
        96,
        |r| {
            let layer = gen_layer(r);
            let p = gen_budget(r);
            let mode = if r.chance(0.5) { ControllerMode::Passive } else { ControllerMode::Active };
            // a random feasible alternative
            let k2 = layer.k * layer.k;
            let mg = layer.m_per_group();
            let cap_m: Vec<usize> =
                divisors(mg).into_iter().filter(|&d| k2 * d <= p || d == 1).collect();
            let m = *r.pick(&cap_m);
            let n_cap = (p / (k2 * m)).max(1).min(layer.n_per_group());
            let n = r.range(1, n_cap);
            (layer, p, mode, m, n)
        },
        |(layer, p, mode, m, n)| {
            let best = optimizer::search_partition(layer, *p, *mode);
            let best_bw = layer_bandwidth(layer, best.m, best.n, *mode).total();
            let alt_bw = layer_bandwidth(layer, *m, *n, *mode).total();
            prop_assert!(
                best_bw <= alt_bw + 1e-9,
                "search {best:?}={best_bw} beaten by ({m},{n})={alt_bw} on {layer} P={p}"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_bandwidth_floor_and_monotonicity() {
    forall(
        "floor + monotone in m",
        192,
        |r| gen_layer(r),
        |layer| {
            let floor = (layer.input_activations() + layer.output_activations()) as f64;
            let mg = layer.m_per_group();
            let ng = layer.n_per_group();
            // full residency hits the floor
            let full = layer_bandwidth(layer, mg, ng, ControllerMode::Passive);
            prop_assert!(full.total() == floor, "full tile {} != floor {floor}", full.total());
            // growing m (n fixed = N) monotonically lowers output traffic
            let mut prev = f64::INFINITY;
            for m in divisors(mg) {
                let bw = layer_bandwidth(layer, m, ng, ControllerMode::Passive);
                prop_assert!(
                    bw.output <= prev + 1e-9,
                    "output traffic rose at m={m}: {} > {prev}",
                    bw.output
                );
                prev = bw.output;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_eq7_stationary_point() {
    // The real-valued m* from eq. (7) minimizes the continuous relaxation
    // B(m) = a*m + b/m - c: check neighbours are no better.
    forall(
        "eq7 is the continuous optimum",
        128,
        |r| (gen_layer(r), gen_budget(r)),
        |(layer, p)| {
            let wi_hi = (layer.wi * layer.hi) as f64;
            let wo_ho = (layer.wo() * layer.ho()) as f64;
            let k2 = (layer.k * layer.k) as f64;
            let mg = layer.m_per_group() as f64;
            let ng = layer.n_per_group() as f64;
            let b_cont = |m: f64| {
                // eq. (6): Bi with n = P/(K^2 m), Bo passive
                wi_hi * mg * ng * k2 * m / (*p as f64) + wo_ho * ng * (2.0 * mg / m - 1.0)
            };
            let m_star = optimizer::optimal_m_real(layer, *p, ControllerMode::Passive);
            let b0 = b_cont(m_star);
            for factor in [0.5, 0.9, 1.1, 2.0] {
                let m = m_star * factor;
                prop_assert!(
                    b_cont(m) >= b0 - 1e-6 * b0.abs(),
                    "B({m}) = {} < B(m*={m_star}) = {b0}",
                    b_cont(m)
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_divisor_helpers() {
    forall(
        "divisor helpers",
        256,
        |r| (r.range(1, 4096), r.f64() * 100.0),
        |(x, target)| {
            let ds = divisors(*x);
            prop_assert!(ds.first() == Some(&1) && ds.last() == Some(x), "ends wrong for {x}");
            for d in &ds {
                prop_assert!(x % d == 0, "{d} does not divide {x}");
            }
            let nd = nearest_divisor_log(*x, *target);
            prop_assert!(x % nd == 0, "nearest {nd} not a divisor of {x}");
            // no other divisor is strictly closer in log space
            let t = target.max(1e-12).ln();
            let best = (nd as f64).ln() - t;
            for d in &ds {
                let dist = (*d as f64).ln() - t;
                prop_assert!(
                    dist.abs() >= best.abs() - 1e-12,
                    "divisor {d} closer than {nd} to {target} for {x}"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip() {
    use psim::util::json::Json;
    fn gen_json(r: &mut Rng, depth: usize) -> Json {
        if depth == 0 || r.chance(0.4) {
            match r.range(0, 3) {
                0 => Json::Num((r.range(0, 10_000) as f64) / 8.0),
                1 => Json::Bool(r.chance(0.5)),
                2 => Json::Str(format!("s{}-\"q\"", r.range(0, 99))),
                _ => Json::Null,
            }
        } else if r.chance(0.5) {
            Json::Arr((0..r.range(0, 4)).map(|_| gen_json(r, depth - 1)).collect())
        } else {
            Json::Obj(
                (0..r.range(0, 4))
                    .map(|i| (format!("k{i}"), gen_json(r, depth - 1)))
                    .collect(),
            )
        }
    }
    forall(
        "json print->parse roundtrip",
        256,
        |r| gen_json(r, 3),
        |j| {
            let text = j.to_string();
            let back = Json::parse(&text).map_err(|e| format!("{e} in {text}"))?;
            prop_assert!(&back == j, "roundtrip changed {j:?} -> {back:?}");
            Ok(())
        },
    );
}
