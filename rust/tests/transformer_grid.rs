//! End-to-end grid goldens for the transformer workload class: ViT-Tiny
//! swept over the paper's full per-network grid (6 MAC budgets × 4
//! Table I strategies × both controller modes, batch 1) must reproduce
//! `rust/tests/golden/vit_tiny_grid.jsonl` byte-for-byte — the same file
//! the CI smoke step diffs against the built binary. Values recomputed
//! independently from the lowered 1×1-conv equations.

use psim::analytics::grid::{GridEngine, SweepSpec};
use psim::models::zoo;

#[test]
fn vit_tiny_jsonl_golden() {
    let golden = include_str!("golden/vit_tiny_grid.jsonl");
    // `SweepSpec::new` defaults are exactly the paper's per-network grid.
    let spec = SweepSpec::new(vec![zoo::vit_tiny()]);
    assert_eq!(spec.cell_count(), 48);
    let jsonl = GridEngine::new().run_with_workers(&spec, 1).to_jsonl();
    assert_eq!(jsonl, golden);
    // and the stream is worker-count independent
    let eight = GridEngine::new().run_with_workers(&spec, 8).to_jsonl();
    assert_eq!(jsonl, eight);
}

#[test]
fn vit_tiny_floor_is_respected_and_attention_dominates() {
    let spec = SweepSpec::new(vec![zoo::vit_tiny()]);
    let grid = GridEngine::new().run_with_workers(&spec, 4);
    let net = zoo::vit_tiny();
    let floor = net.min_bandwidth() as f64;
    for cell in &grid.cells {
        assert!(cell.total() >= floor, "{} below the activation floor", cell.total());
    }
    // The op view and the lowered view agree on the floor.
    let acts: u64 =
        net.ops.iter().map(|o| o.input_activations() + o.output_activations()).sum();
    assert_eq!(acts, net.min_bandwidth());
}
