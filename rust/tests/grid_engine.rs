//! Grid-engine contract tests:
//!
//! * golden JSONL for AlexNet — hand-computed cell values pinned byte-
//!   for-byte (eqs. 2–3 evaluated on paper for the MaxInput/MaxOutput
//!   partitions at P=512);
//! * memoized grid results equal direct `network_bandwidth` computation
//!   exactly for every cell of the full paper grid;
//! * the JSONL stream is byte-identical between `--workers 1` and
//!   `--workers 8`.

use psim::analytics::bandwidth::ControllerMode;
use psim::analytics::grid::{GridEngine, SweepSpec};
use psim::analytics::partition::Strategy;
use psim::analytics::sweep::network_bandwidth;
use psim::models::zoo;

/// Hand-verified AlexNet cells at P=512 (budget = P/K² per layer; see the
/// derivation in the comments of each constant).
///
/// MaxInput/passive: per layer (m, n) = conv1 (3,1), conv2 (16,1),
/// conv3 (48,1), conv4 (48,1), conv5 (32,1); inputs re-read N times,
/// psum passes 1/4/4/8/8.
const GOLDEN_512: [&str; 4] = [
    // MaxInput, passive: input 58 740 736, output 2 925 568
    r#"{"batch":1,"input":58740736,"min_bw":822784,"mode":"passive","network":"AlexNet","output":2925568,"p_macs":512,"strategy":"max-input","total":61666304,"total_mact":61.666304,"weights_per_image":2468544}"#,
    // MaxInput, active: psum read-backs absorbed -> output 1 705 280
    r#"{"batch":1,"input":58740736,"min_bw":822784,"mode":"active","network":"AlexNet","output":1705280,"p_macs":512,"strategy":"max-input","total":60446016,"total_mact":60.446016,"weights_per_image":2468544}"#,
    // MaxOutput, passive: (m, n) = (1,4)/(1,16)/(1,48)/(1,32)/(1,32);
    // input 4 093 184, output 98 890 496
    r#"{"batch":1,"input":4093184,"min_bw":822784,"mode":"passive","network":"AlexNet","output":98890496,"p_macs":512,"strategy":"max-output","total":102983680,"total_mact":102.98368,"weights_per_image":2468544}"#,
    // MaxOutput, active
    r#"{"batch":1,"input":4093184,"min_bw":822784,"mode":"active","network":"AlexNet","output":49687744,"p_macs":512,"strategy":"max-output","total":53780928,"total_mact":53.780928,"weights_per_image":2468544}"#,
];

#[test]
fn alexnet_jsonl_golden() {
    let spec = SweepSpec::new(vec![zoo::alexnet()])
        .with_macs(vec![512])
        .with_strategies(vec![Strategy::MaxInput, Strategy::MaxOutput])
        .with_modes(vec![ControllerMode::Passive, ControllerMode::Active])
        .with_batches(vec![1]);
    let jsonl = GridEngine::new().run_with_workers(&spec, 1).to_jsonl();
    let lines: Vec<&str> = jsonl.lines().collect();
    assert_eq!(lines.len(), 4);
    for (line, golden) in lines.iter().zip(GOLDEN_512) {
        assert_eq!(*line, golden);
    }
}

#[test]
fn alexnet_batch_amortization_golden() {
    // Batch changes only `batch` and `weights_per_image` (2468544 / 8).
    let spec = SweepSpec::new(vec![zoo::alexnet()])
        .with_macs(vec![512])
        .with_strategies(vec![Strategy::MaxInput])
        .with_modes(vec![ControllerMode::Passive])
        .with_batches(vec![8]);
    let jsonl = GridEngine::new().run_with_workers(&spec, 1).to_jsonl();
    assert_eq!(
        jsonl.trim_end(),
        r#"{"batch":8,"input":58740736,"min_bw":822784,"mode":"passive","network":"AlexNet","output":2925568,"p_macs":512,"strategy":"max-input","total":61666304,"total_mact":61.666304,"weights_per_image":308568}"#
    );
}

#[test]
fn alexnet_full_grid_shape() {
    // Paper-default axes for one network: 6 budgets x 4 strategies x 2
    // modes = 48 JSONL records, all parseable, totals above the floor.
    let spec = SweepSpec::new(vec![zoo::alexnet()]);
    let grid = GridEngine::new().run_with_workers(&spec, 4);
    assert_eq!(grid.len(), 48);
    let jsonl = grid.to_jsonl();
    assert_eq!(jsonl.lines().count(), 48);
    let floor = zoo::alexnet().min_bandwidth() as f64;
    for line in jsonl.lines() {
        let v = psim::util::json::Json::parse(line).expect("valid json");
        assert_eq!(v.get("network").unwrap().as_str(), Some("AlexNet"));
        assert!(v.get("total").unwrap().as_f64().unwrap() >= floor - 1e-6);
    }
}

#[test]
fn memoized_grid_equals_direct_computation_everywhere() {
    // Every cell of the full paper grid (8 networks x 6 budgets x 4
    // strategies x 2 modes): the cached/shared-shape path must reproduce
    // the direct, cache-free computation bit-for-bit (all quantities are
    // exact integer-valued f64 arithmetic).
    let spec = SweepSpec::paper_grid();
    let engine = GridEngine::new();
    let grid = engine.run(&spec);
    assert_eq!(grid.len(), spec.cell_count());
    for cell in &grid.cells {
        let net = spec.networks.iter().find(|n| n.name == cell.network).unwrap();
        let direct = network_bandwidth(net, cell.p_macs, cell.strategy, cell.mode);
        assert_eq!(
            cell.total(),
            direct.total(),
            "{}: memoized != direct",
            cell.key()
        );
        let di: f64 = direct.layers.iter().map(|l| l.bandwidth.input).sum();
        let dout: f64 = direct.layers.iter().map(|l| l.bandwidth.output).sum();
        assert_eq!(cell.input, di, "{}: input mismatch", cell.key());
        assert_eq!(cell.output, dout, "{}: output mismatch", cell.key());
    }
    // The cache must actually collapse work: far fewer layer evaluations
    // than cells x layers.
    let (hits, misses) = engine.cache_stats();
    assert!(hits > misses, "cache ineffective: {hits} hits / {misses} misses");
}

#[test]
fn jsonl_identical_across_worker_counts() {
    let spec = SweepSpec::paper_grid();
    let one = GridEngine::new().run_with_workers(&spec, 1).to_jsonl();
    let eight = GridEngine::new().run_with_workers(&spec, 8).to_jsonl();
    assert_eq!(one, eight, "sweep output depends on worker count");
    assert_eq!(one.lines().count(), 384);
}
