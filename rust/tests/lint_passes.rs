//! Integration tests for `psim lint`.
//!
//! Each pass must fire on its seeded fixture tree under
//! `tests/lint_fixtures/` (one deliberately-bad mini repo per pass),
//! the allowlist must both suppress covered findings and be audited by
//! `PS000`, and — the meta-test — the real repository must lint clean,
//! which is exactly what the CI gate asserts via `psim lint --json`.

use std::path::PathBuf;

use psim::lint::{run, LintConfig, Report};
use psim::util::json::Json;

fn fixture_cfg(case: &str) -> LintConfig {
    let root = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/lint_fixtures"));
    LintConfig {
        root: root.join(case),
        src_dirs: vec![PathBuf::from("src")],
        fmt_dirs: Vec::new(),
        hostile: vec!["bad.rs".to_string(), "ok.rs".to_string()],
        max_width: 100,
        registry: Some(PathBuf::from("src/registry.rs")),
        request: Some(PathBuf::from("src/request.rs")),
        protocol_doc: Some(PathBuf::from("docs/PROTOCOL.md")),
        fixtures_dir: Some(PathBuf::from("golden/protocol")),
        golden_dir: Some(PathBuf::from("golden")),
        ref_paths: vec![PathBuf::from("refs")],
        exclude_dirs: Vec::new(),
    }
}

fn lint_fixture(case: &str) -> Report {
    run(&fixture_cfg(case)).expect("fixture lint run")
}

fn with_code<'a>(report: &'a Report, code: &str) -> Vec<&'a psim::lint::Finding> {
    report.findings.iter().filter(|f| f.code == code).collect()
}

#[test]
fn ps100_flags_every_panic_shape() {
    let report = lint_fixture("p100");
    let hits = with_code(&report, "PS100");
    let got: Vec<(usize, &str)> =
        hits.iter().map(|f| (f.line, f.message.as_str())).collect();
    assert_eq!(
        got,
        vec![
            (3, "`.unwrap()` on the hostile-input path"),
            (4, "`.expect()` on the hostile-input path"),
            (6, "`panic!` on the hostile-input path"),
            (8, "indexing by integer literal on the hostile-input path"),
        ],
        "all findings: {:?}",
        report.findings
    );
    for f in &hits {
        assert_eq!(f.path, "src/bad.rs");
        assert!(f.col > 0, "columns are 1-based");
    }
}

#[test]
fn allowlisted_violation_is_suppressed_and_counts_as_used() {
    let report = lint_fixture("p100_allow");
    // The unwrap is covered by the standalone allow on the line above,
    // and because the allow suppressed something, PS000 stays quiet.
    assert!(report.findings.is_empty(), "{:?}", report.findings);
}

#[test]
fn ps200_flags_unchecked_arithmetic_in_count_fns_only() {
    let report = lint_fixture("p200");
    let hits = with_code(&report, "PS200");
    assert_eq!(hits.len(), 1, "{:?}", report.findings);
    assert_eq!(hits[0].message, "unchecked `*` in size-accounting fn `cell_count`");
    assert_eq!(hits[0].line, 3);
}

#[test]
fn ps300_flags_catalog_drift_in_both_directions() {
    let report = lint_fixture("p300");
    let msgs: Vec<&str> =
        with_code(&report, "PS300").iter().map(|f| f.message.as_str()).collect();
    assert_eq!(msgs.len(), 2, "{:?}", report.findings);
    assert!(msgs
        .contains(&"metric \"unknown_metric\" recorded but absent from the METRICS catalog"));
    assert!(msgs.contains(&"METRICS entry \"never_recorded\" is never recorded"));
}

#[test]
fn ps400_flags_undocumented_commands_and_orphan_fixtures() {
    let report = lint_fixture("p400");
    let msgs: Vec<&str> =
        with_code(&report, "PS400").iter().map(|f| f.message.as_str()).collect();
    assert_eq!(msgs.len(), 4, "{:?}", report.findings);
    assert!(msgs.contains(&"command \"beta\" has no PROTOCOL.md section"));
    assert!(msgs.contains(&"command \"beta\" has no PROTOCOL.md table row"));
    assert!(msgs.contains(&"command \"beta\" has no golden fixture beta.txt"));
    assert!(msgs.contains(&"orphan protocol fixture gamma.txt: no matching command"));
    // `alpha` is pinned all three ways and must not be flagged.
    assert!(msgs.iter().all(|m| !m.contains("alpha")));
}

#[test]
fn ps500_flags_width_and_trailing_ws_but_exempts_string_literals() {
    let report = lint_fixture("p500");
    let hits = with_code(&report, "PS500");
    assert_eq!(hits.len(), 2, "{:?}", report.findings);
    assert_eq!((hits[0].line, hits[0].col), (1, 101));
    assert!(hits[0].message.contains("chars (limit 100)"));
    assert_eq!(hits[1].line, 3);
    assert_eq!(hits[1].message, "trailing whitespace");
    // Line 2 overflows too, but only inside a string literal.
    assert!(hits.iter().all(|f| f.line != 2));
}

#[test]
fn ps600_flags_unreferenced_golden_files() {
    let report = lint_fixture("p600");
    let hits = with_code(&report, "PS600");
    assert_eq!(hits.len(), 1, "{:?}", report.findings);
    assert_eq!(hits[0].path, "golden/orphan.jsonl");
    assert_eq!(
        hits[0].message,
        "golden file orphan.jsonl is referenced by no test, CI step or doc"
    );
}

#[test]
fn ps000_flags_stale_and_malformed_allows() {
    let report = lint_fixture("p000");
    let hits = with_code(&report, "PS000");
    assert_eq!(hits.len(), 2, "{:?}", report.findings);
    assert_eq!(hits[0].line, 3);
    assert_eq!(hits[0].message, "stale lint:allow(PS100): it suppresses nothing");
    assert_eq!(hits[1].line, 6);
    assert_eq!(
        hits[1].message,
        "malformed lint:allow directive (need a known code and a reason)"
    );
}

#[test]
fn json_report_round_trips_through_the_parser() {
    let report = lint_fixture("p500");
    let parsed = Json::parse(&report.to_json().to_string()).expect("report JSON parses");
    assert_eq!(parsed.get("schema").and_then(Json::as_usize), Some(1));
    assert_eq!(parsed.get("count").and_then(Json::as_usize), Some(2));
    let findings = parsed.get("findings").and_then(Json::as_arr).expect("findings");
    assert_eq!(findings.len(), 2);
    for f in findings {
        assert_eq!(f.get("code").and_then(Json::as_str), Some("PS500"));
        assert!(f.get("path").and_then(Json::as_str).is_some());
        assert!(f.get("line").and_then(Json::as_usize).is_some());
        assert!(f.get("hint").and_then(Json::as_str).is_some());
    }
}

/// The meta-test behind the CI gate: the real tree lints clean with
/// the production configuration, and this covers the orphan-golden
/// sweep for every file under `rust/tests/golden/` too (PS600 runs as
/// part of the full registry).
#[test]
fn repository_lints_clean_with_the_production_config() {
    let root = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/.."));
    let report = run(&LintConfig::repo(&root)).expect("repo lint run");
    let rendered: Vec<String> = report
        .findings
        .iter()
        .map(|f| format!("{}:{}:{}: {} {}", f.path, f.line, f.col, f.code, f.message))
        .collect();
    assert!(
        report.findings.is_empty(),
        "the repository must lint clean; findings:\n{}",
        rendered.join("\n")
    );
    assert!(report.files_scanned > 50, "scanned only {} files", report.files_scanned);
}
