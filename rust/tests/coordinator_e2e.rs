//! Coordinator end-to-end: batcher + engine + metrics over real PJRT.
//! Skips when artifacts are absent (run `make artifacts`).

use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use psim::coordinator::{InferenceService, ServiceConfig};
use psim::runtime::{ArtifactDir, Tensor};

/// xla_extension 0.5.1's CPU plugin aborts (`literal.size_bytes() ==
/// b->size()` check) when several PJRT clients in one process mix
/// `buffer_from_host_literal` + `execute_b` concurrently. Each test
/// therefore takes this lock — tests stay independent but serialized.
static PJRT_LOCK: Mutex<()> = Mutex::new(());

fn pjrt_guard() -> MutexGuard<'static, ()> {
    PJRT_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn service_or_skip(cfg: ServiceConfig) -> Option<InferenceService> {
    match ArtifactDir::open_default() {
        Ok(a) => Some(InferenceService::start(a, cfg).expect("service start")),
        Err(e) => {
            eprintln!("SKIP coordinator tests: {e:#} (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn single_request_roundtrip() {
    let _g = pjrt_guard();
    let Some(svc) = service_or_skip(ServiceConfig::default()) else { return };
    let resp = svc.infer(Tensor::random(&[3, 32, 32], 1, 1.0)).unwrap();
    assert_eq!(resp.logits.len(), 10);
    assert!(resp.logits.iter().all(|v| v.is_finite()));
    assert!(resp.latency_us > 0);
}

#[test]
fn concurrent_load_all_answered_and_batched() {
    let _g = pjrt_guard();
    let Some(svc) = service_or_skip(ServiceConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(5),
        weight_seed: 7,
    }) else {
        return;
    };
    // Warm up so compilation doesn't skew the run.
    svc.infer(Tensor::random(&[3, 32, 32], 0, 1.0)).unwrap();

    let n = 48usize;
    let rxs: Vec<_> =
        (0..n).map(|i| svc.submit(Tensor::random(&[3, 32, 32], i as u64, 1.0))).collect();
    let mut got = 0;
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("response");
        assert_eq!(resp.logits.len(), 10);
        got += 1;
    }
    assert_eq!(got, n);
    // Burst submissions must have coalesced into real batches.
    let mean_batch = svc.metrics.mean_batch_size();
    assert!(mean_batch > 1.5, "no batching observed: mean {mean_batch}");
    let total = svc.metrics.responses.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(total, (n + 1) as u64);
}

#[test]
fn deterministic_across_service_restarts() {
    let _g = pjrt_guard();
    let cfg = ServiceConfig { weight_seed: 99, ..ServiceConfig::default() };
    let Some(svc1) = service_or_skip(cfg.clone()) else { return };
    let img = Tensor::random(&[3, 32, 32], 1234, 1.0);
    let a = svc1.infer(img.clone()).unwrap();
    drop(svc1);
    let svc2 = service_or_skip(cfg).unwrap();
    let b = svc2.infer(img).unwrap();
    assert_eq!(a.logits, b.logits, "same seed + image must reproduce logits");
}

#[test]
fn different_weight_seeds_change_outputs() {
    let _g = pjrt_guard();
    let Some(svc1) = service_or_skip(ServiceConfig { weight_seed: 1, ..Default::default() })
    else {
        return;
    };
    let img = Tensor::random(&[3, 32, 32], 5, 1.0);
    let a = svc1.infer(img.clone()).unwrap();
    drop(svc1);
    let svc2 = service_or_skip(ServiceConfig { weight_seed: 2, ..Default::default() }).unwrap();
    let b = svc2.infer(img).unwrap();
    assert_ne!(a.logits, b.logits);
}

#[test]
fn rejects_malformed_images() {
    let _g = pjrt_guard();
    let Some(svc) = service_or_skip(ServiceConfig::default()) else { return };
    // wrong shape: the engine drops the batch; the reply channel closes.
    let rx = svc.submit(Tensor::zeros(&[3, 8, 8]));
    assert!(rx.recv_timeout(Duration::from_secs(60)).is_err());
    // the service remains healthy afterwards
    let ok = svc.infer(Tensor::random(&[3, 32, 32], 9, 1.0)).unwrap();
    assert_eq!(ok.logits.len(), 10);
}
