//! Stress / regression suite for the pooled `psim serve` (PR 6):
//!
//! * full-load stress: 32 concurrent clients over a mixed workload plus
//!   idle keep-alives — every request gets a reply, nothing is shed
//!   below the configured bounds, and the served-request counters add up
//!   exactly;
//! * shutdown under load returns within a hard deadline and closes every
//!   peer cleanly;
//! * backpressure property: with a pool of 1 worker and a queue of 1,
//!   a burst of K connections yields exactly `accepted + shed == K`,
//!   every shed reply is the pinned `too_busy` fixture line, and the
//!   queue high-water mark never exceeds the bound;
//! * per-request timeouts reclaim workers pinned by idle peers;
//! * all eleven protocol fixtures replay through the pooled server — ten
//!   byte-identical, `stats` structurally (the pooled path legitimately
//!   counts its own accepted connection, so its counters differ from the
//!   fresh-engine fixture pinned by `psim request`);
//! * the same 32-client load against a store-attached engine keeps the
//!   result-store conservation identity `hits + misses == lookups` exact;
//! * the `psim bench` CLI produces a schema-valid summary against the
//!   pooled server and fails cleanly without one, and the live
//!   `{"cmd":"stats"}` snapshot keeps `dispatched + coalesced == replies`.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use psim::api::{Engine, Request, Response, TOO_BUSY_MESSAGE};
use psim::cli::commands::serve::{bind, serve_on, ServeConfig};
use psim::util::json::Json;

const VERSION_LINE: &str = r#"{"cmd":"version"}"#;
const METRICS_LINE: &str = r#"{"cmd":"metrics"}"#;
const SHUTDOWN_LINE: &str = r#"{"cmd":"shutdown"}"#;
const SWEEP_LINE: &str = concat!(
    r#"{"cmd":"sweep","networks":["AlexNet"],"macs":[512],"#,
    r#""strategies":["optimal"],"modes":["passive"]}"#
);
const EXPLORE_LINE: &str = concat!(
    r#"{"cmd":"explore","networks":["AlexNet"],"macs":[512],"sram":["unlimited"],"#,
    r#""strategies":["optimal"],"modes":["active"]}"#
);
/// The stress workload: two real analytics computations (coalescable)
/// and two trivial commands, rotated per client so every client touches
/// every kind.
const MIX: [&str; 4] = [SWEEP_LINE, VERSION_LINE, EXPLORE_LINE, METRICS_LINE];

/// A real pooled server on an ephemeral port, with the engine kept
/// reachable for counter assertions after shutdown.
struct Server {
    addr: SocketAddr,
    engine: Arc<Engine>,
    done: mpsc::Receiver<()>,
    handle: thread::JoinHandle<()>,
}

impl Server {
    fn start(config: ServeConfig) -> Server {
        Server::start_with(config, Arc::new(Engine::analytics()))
    }

    fn start_with(config: ServeConfig, engine: Arc<Engine>) -> Server {
        let (listener, _port) = bind(0).expect("ephemeral bind");
        let addr = listener.local_addr().unwrap();
        let (tx, done) = mpsc::channel();
        let handle = thread::spawn({
            let engine = engine.clone();
            move || {
                serve_on(listener, &engine, &config).expect("server failed");
                let _ = tx.send(());
            }
        });
        Server { addr, engine, done, handle }
    }

    /// Wait for a clean server exit; panics loudly past the deadline
    /// (the regression this suite exists to catch is exactly "shutdown
    /// hangs forever").
    fn join_within(self, deadline: Duration) -> Arc<Engine> {
        self.done.recv_timeout(deadline).expect("server did not shut down within the deadline");
        self.handle.join().expect("server thread panicked");
        self.engine
    }
}

/// One JSON-lines client connection with a liveness read timeout.
struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        Client { writer: stream.try_clone().unwrap(), reader: BufReader::new(stream) }
    }

    fn send(&mut self, line: &str) {
        writeln!(self.writer, "{line}").expect("send");
    }

    /// Read one reply line; EOF is an error (callers that expect a clean
    /// close use [`Client::expect_close`] instead).
    fn read_reply(&mut self) -> std::io::Result<String> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed the connection",
            ));
        }
        Ok(line.trim_end().to_string())
    }

    fn roundtrip(&mut self, line: &str) -> String {
        self.send(line);
        self.read_reply().expect("reply")
    }

    fn try_roundtrip(&mut self, line: &str) -> std::io::Result<String> {
        writeln!(self.writer, "{line}")?;
        self.read_reply()
    }

    /// The server must close this connection without sending anything
    /// more: EOF and a reset both qualify, extra data does not.
    fn expect_close(&mut self) {
        let mut rest = String::new();
        match self.reader.read_line(&mut rest) {
            Ok(0) | Err(_) => {}
            Ok(_) => panic!("expected a clean close, got extra data: {rest:?}"),
        }
    }
}

/// Poll `cond` (e.g. a server-side counter) up to a 5 s deadline.
fn wait_until(what: &str, cond: impl Fn() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(5);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        thread::sleep(Duration::from_millis(5));
    }
}

/// Tentpole stress test: 32 concurrent clients x 4 mixed requests each,
/// with 4 idle keep-alive connections pinning workers the whole time.
/// Every request gets a valid non-error reply, nothing is shed (the
/// bounds are sized above the offered load), shutdown lands within the
/// deadline with the idle peers still connected, and the engine's
/// counters account for every reply exactly once.
#[test]
fn stress_full_load_every_request_replied() {
    let config = ServeConfig { workers: 8, queue: 64, max_conns: 128, timeout: None };
    let server = Server::start(config);
    let addr = server.addr;

    // Idle keep-alives: connect, send nothing, stay open. Fewer than the
    // worker count, so they can pin workers without starving the pool.
    let mut idles: Vec<Client> = (0..4).map(|_| Client::connect(addr)).collect();

    let replies: Vec<Vec<String>> = thread::scope(|scope| {
        let handles: Vec<_> = (0..32)
            .map(|c| {
                scope.spawn(move || {
                    let mut client = Client::connect(addr);
                    (0..4).map(|i| client.roundtrip(MIX[(c + i) % MIX.len()])).collect()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    assert_eq!(replies.iter().map(Vec::len).sum::<usize>(), 128);
    for reply in replies.iter().flatten() {
        let json = Json::parse(reply).expect("every reply is one JSON line");
        assert!(json.get("error").is_none(), "unexpected error reply: {reply}");
    }

    // Shutdown with the idle connections still open: the pre-PR-3 hang.
    let mut ctl = Client::connect(addr);
    let bye = ctl.roundtrip(SHUTDOWN_LINE);
    assert!(bye.contains("true"), "{bye}");
    let engine = server.join_within(Duration::from_secs(10));
    for idle in &mut idles {
        idle.expect_close();
    }

    let stats = engine.serve_stats();
    assert_eq!(stats.accepted.get(), 37, "32 clients + 4 idle + ctl");
    assert_eq!(stats.shed.get(), 0, "load was below every bound");
    assert_eq!(stats.refused.get(), 0);
    assert_eq!(stats.timed_out.get(), 0);
    assert_eq!(stats.lines.get(), 129, "128 client replies + shutdown ack");
    assert!(stats.queue_peak() <= 64, "queue peak {} exceeded the bound", stats.queue_peak());

    // Counter accounting: every wire reply was either dispatched (and
    // counted per command) or coalesced onto another dispatch — plus the
    // one Metrics dispatch below. No request errored.
    let Response::Metrics { requests, .. } = engine.dispatch(&Request::Metrics).unwrap() else {
        panic!("not a metrics response");
    };
    let dispatched: u64 = requests.iter().filter(|(n, _)| *n != "errors").map(|&(_, n)| n).sum();
    let coalesced = stats.coalesced.get();
    assert_eq!(dispatched + coalesced, 129 + 1, "every reply accounted for exactly once");
    assert!(requests.iter().all(|(n, _)| *n != "errors"), "no request errored: {requests:?}");
    // The serve-side split agrees: every wire reply was computed by a
    // dispatch or coalesced onto one.
    assert_eq!(stats.dispatched.get() + coalesced, 129, "wire replies split exactly");
    // Every pooled hand-off went through the timed pop.
    assert_eq!(stats.queue_wait.count(), 37, "one queue-wait sample per accepted connection");
}

/// Result-store conservation under the full 32-client load: every
/// cacheable request (the sweep and explore in the mix) consults the
/// store exactly once, so `cache_hits + cache_misses == cache_lookups`
/// holds exactly in the live `{"cmd":"stats"}` snapshot, and the reply
/// accounting (`dispatched + coalesced == serve_replies`) stays exact
/// with store hits in the mix.
#[test]
fn stress_store_conservation_under_load() {
    let config = ServeConfig { workers: 8, queue: 64, max_conns: 128, timeout: None };
    let engine = Arc::new(Engine::analytics());
    let store = psim::store::ResultStore::memory(64, engine.registry());
    assert!(engine.attach_store(store));
    let server = Server::start_with(config, engine);
    let addr = server.addr;

    let replies: Vec<Vec<String>> = thread::scope(|scope| {
        let handles: Vec<_> = (0..32)
            .map(|c| {
                scope.spawn(move || {
                    let mut client = Client::connect(addr);
                    (0..4).map(|i| client.roundtrip(MIX[(c + i) % MIX.len()])).collect()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    assert_eq!(replies.iter().map(Vec::len).sum::<usize>(), 128);
    for reply in replies.iter().flatten() {
        let json = Json::parse(reply).expect("every reply is one JSON line");
        assert!(json.get("error").is_none(), "unexpected error reply: {reply}");
    }

    // The load is fully drained (every roundtrip joined), so the store
    // holds the sweep reply: one more repeat is a deterministic hit.
    let mut ctl = Client::connect(addr);
    let warm = ctl.roundtrip(SWEEP_LINE);
    assert!(Json::parse(&warm).expect("warm reply parses").get("error").is_none(), "{warm}");
    let snap = Json::parse(&ctl.roundtrip(r#"{"cmd":"stats"}"#)).expect("stats reply parses");
    let count = |key: &str| snap.get("counters").unwrap().get(key).unwrap().as_usize().unwrap();
    let (lookups, hits, misses) =
        (count("cache_lookups"), count("cache_hits"), count("cache_misses"));
    // 32 clients x 2 cacheable requests each (sweep + explore), plus the
    // deterministic warm repeat above.
    assert_eq!(lookups, 65, "every cacheable request consulted the store exactly once");
    assert_eq!(hits + misses, lookups, "conservation: every lookup hit or missed");
    assert!(misses >= 2, "the first sweep and explore must both have computed");
    assert!(hits >= 1, "the post-load repeat is a guaranteed store hit");
    assert_eq!(count("cache_invalidations"), 0, "in-memory store never invalidates");
    // Reply accounting with store hits in the mix: every wire reply was
    // dispatched (fresh, stored or trivial) or coalesced.
    let (dispatched, coalesced) =
        (count("serve_replies_dispatched"), count("serve_replies_coalesced"));
    assert_eq!(dispatched + coalesced, count("serve_replies"), "reply split accounts");

    let bye = ctl.roundtrip(SHUTDOWN_LINE);
    assert!(bye.contains("true"), "{bye}");
    server.join_within(Duration::from_secs(10));
}

/// `{"cmd":"shutdown"}` mid-load: clients still hammering the server are
/// cut off cleanly (EOF or reset, never a hang) and the server returns
/// within the deadline.
#[test]
fn shutdown_mid_load_returns_within_deadline() {
    let config = ServeConfig { workers: 4, queue: 32, max_conns: 64, timeout: None };
    let server = Server::start(config);
    let addr = server.addr;

    thread::scope(|scope| {
        for _ in 0..8 {
            scope.spawn(move || {
                let mut client = Client::connect(addr);
                for _ in 0..200 {
                    // Mid-shutdown a request may be answered, cut off, or
                    // refused — an error is a clean end, not a failure.
                    if client.try_roundtrip(VERSION_LINE).is_err() {
                        break;
                    }
                }
            });
        }
        thread::sleep(Duration::from_millis(30));
        let mut ctl = Client::connect(addr);
        let bye = ctl.roundtrip(SHUTDOWN_LINE);
        assert!(bye.contains("true"), "{bye}");
    });

    let engine = server.join_within(Duration::from_secs(10));
    let stats = engine.serve_stats();
    assert_eq!(stats.shed.get(), 0, "bounds were above the offered load");
    assert!(stats.lines.get() >= 1);
}

/// Backpressure property: 1 worker + queue of 1. Connection A pins the
/// worker, connection B fills the queue, and every connection beyond the
/// bound is shed immediately with the pinned `too_busy` fixture bytes —
/// `accepted + shed == K`, and the queue high-water mark never exceeds
/// its bound.
#[test]
fn saturation_sheds_with_too_busy_and_the_queue_stays_bounded() {
    let config = ServeConfig { workers: 1, queue: 1, max_conns: 64, timeout: None };
    let server = Server::start(config);
    let engine = server.engine.clone();

    // A occupies the only worker (kept alive after its reply).
    let mut a = Client::connect(server.addr);
    assert!(a.roundtrip(VERSION_LINE).contains("protocol"));

    // B occupies the only queue slot; its shutdown request sits buffered
    // in the socket until a worker finally pops it.
    let mut b = Client::connect(server.addr);
    b.send(SHUTDOWN_LINE);
    wait_until("connection B to be queued", || engine.serve_stats().accepted.get() == 2);

    // Saturated: every further connection is shed with the exact fixture
    // line, then closed. (Shed clients must not send first — the server
    // replies before reading, and unread data would reset the close.)
    let fixture = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/golden/protocol/serve/too_busy.txt"
    ))
    .expect("too_busy fixture");
    let expected = fixture.lines().nth(1).expect("fixture reply line");
    assert!(expected.contains(TOO_BUSY_MESSAGE), "fixture drifted from the API constant");
    for i in 0..14 {
        let mut shed = Client::connect(server.addr);
        assert_eq!(shed.read_reply().unwrap(), expected, "shed reply #{i}");
        shed.expect_close();
    }

    let stats = engine.serve_stats();
    let (accepted, shed) = (stats.accepted.get(), stats.shed.get());
    assert_eq!(accepted, 2);
    assert_eq!(shed, 14);
    assert_eq!(accepted + shed, 16, "burst of 16 split exactly into accepted + shed");
    assert_eq!(stats.queue_peak(), 1, "queue depth never exceeded its bound of 1");

    // Freeing the worker drains the queue: B's buffered shutdown is
    // finally served and brings the server down.
    drop(a);
    let bye = b.read_reply().expect("queued connection served after the worker freed up");
    assert!(bye.contains("true"), "{bye}");
    server.join_within(Duration::from_secs(10));
}

/// `--timeout-ms`: an idle peer cannot pin a worker forever — its read
/// deadline fires, the connection is closed and counted, and the worker
/// serves the next connection.
#[test]
fn per_request_timeout_reclaims_pinned_workers() {
    let timeout = Some(Duration::from_millis(150));
    let config = ServeConfig { workers: 1, queue: 4, max_conns: 8, timeout };
    let server = Server::start(config);
    let engine = server.engine.clone();

    let mut idle = Client::connect(server.addr);
    idle.expect_close(); // blocks until the server-side deadline fires

    let mut active = Client::connect(server.addr);
    let v = active.roundtrip(VERSION_LINE);
    assert!(v.contains("protocol"), "worker was not reclaimed: {v}");
    assert!(engine.serve_stats().timed_out.get() >= 1);

    let bye = active.roundtrip(SHUTDOWN_LINE);
    assert!(bye.contains("true"), "{bye}");
    server.join_within(Duration::from_secs(10));
}

/// Golden regression: all eleven protocol fixtures replay through the
/// pooled server (fresh engine per fixture, like the fixtures were
/// pinned) — ten byte-identical. The `stats` fixture is the one
/// legitimate exception: its reply snapshots the engine's own counters,
/// and the pooled path has already counted the accepted connection by
/// the time the snapshot is taken, so it is checked structurally
/// (byte-identity for stats is covered by `api_protocol.rs` and the CI
/// `psim request` smoke, both of which use the fresh-engine path the
/// fixture was pinned from).
#[test]
fn protocol_fixtures_replay_byte_identical_through_the_pooled_server() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/protocol");
    let mut seen = 0;
    for entry in std::fs::read_dir(dir).expect("fixture dir") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("txt") {
            continue;
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        let request = lines.next().expect("fixture request line");
        let expected = lines.next().expect("fixture reply line");

        let config = ServeConfig { workers: 2, queue: 8, max_conns: 16, timeout: None };
        let server = Server::start(config);
        let mut client = Client::connect(server.addr);
        let reply = client.roundtrip(request);
        if path.file_stem().and_then(|s| s.to_str()) == Some("stats") {
            let snap = Json::parse(&reply).expect("stats reply parses");
            assert_eq!(snap.get("schema").unwrap().as_usize(), Some(1), "{reply}");
            assert_eq!(snap.get("protocol").unwrap().as_usize(), Some(1), "{reply}");
            let counters = snap.get("counters").expect("counters section");
            assert_eq!(counters.get("api_requests_stats").unwrap().as_usize(), Some(1));
            assert_eq!(counters.get("serve_conns_accepted").unwrap().as_usize(), Some(1));
        } else {
            let drifted = format!("fixture {} drifted through the pooled server", path.display());
            assert_eq!(reply, expected, "{drifted}");
        }
        if path.file_stem().and_then(|s| s.to_str()) != Some("shutdown") {
            let bye = client.roundtrip(SHUTDOWN_LINE);
            assert!(bye.contains("true"), "{bye}");
        }
        server.join_within(Duration::from_secs(10));
        seen += 1;
    }
    assert_eq!(seen, 11, "expected all eleven pinned fixtures to replay");
}

/// End-to-end: the `psim bench` CLI against a live pooled server writes
/// a summary that passes the CI schema validator with exact accounting.
#[test]
fn bench_cli_produces_a_valid_summary_against_the_pooled_server() {
    let config = ServeConfig { workers: 4, queue: 16, max_conns: 64, timeout: None };
    let server = Server::start(config);
    let out = std::env::temp_dir().join("psim_stress_bench_out.json");
    let _ = std::fs::remove_file(&out);

    let port = server.addr.port().to_string();
    let argv: Vec<String> = [
        "bench",
        "--port",
        port.as_str(),
        "--clients",
        "2",
        "--requests",
        "20",
        "--mix",
        "version,sweep",
        "--out",
        out.to_str().unwrap(),
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    assert_eq!(psim::cli::run(&argv).unwrap(), 0);

    let text = std::fs::read_to_string(&out).expect("--out file written");
    let summary = Json::parse(text.trim()).expect("summary is one JSON line");
    psim::report::bench::validate_summary(&summary).expect("summary passes the CI validator");
    assert_eq!(summary.get("requests").unwrap().as_usize(), Some(20));
    assert_eq!(summary.get("served").unwrap().as_usize(), Some(20));
    assert_eq!(summary.get("errors").unwrap().as_usize(), Some(0));
    let _ = std::fs::remove_file(&out);

    // Live stats over the wire: the snapshot runs before the stats
    // request's own dispatched/replies increments, so with the bench
    // load drained the reply split is exact.
    let snap = psim::cli::commands::stats::fetch(server.addr.port()).expect("stats fetch");
    let count = |key: &str| snap.get("counters").unwrap().get(key).unwrap().as_usize().unwrap();
    let (dispatched, coalesced) =
        (count("serve_replies_dispatched"), count("serve_replies_coalesced"));
    assert_eq!(dispatched + coalesced, count("serve_replies"), "reply split accounts");
    assert!(count("serve_conns_accepted") >= 3, "bench clients + stats probe all counted");
    let queue = snap.get("histograms").unwrap().get("serve_queue_wait_us").unwrap();
    assert!(queue.get("count").unwrap().as_usize().unwrap() >= 3, "queue waits recorded");

    let mut ctl = Client::connect(server.addr);
    let bye = ctl.roundtrip(SHUTDOWN_LINE);
    assert!(bye.contains("true"), "{bye}");
    server.join_within(Duration::from_secs(10));
}

/// Without a server, `psim bench` fails fast with a pointed error
/// instead of spawning clients that all time out.
#[test]
fn bench_cli_fails_cleanly_without_a_server() {
    let (listener, port) = bind(0).unwrap();
    drop(listener); // the port is now (very likely) unbound
    let port = port.to_string();
    let args = ["bench", "--port", port.as_str(), "--requests", "1"];
    let argv: Vec<String> = args.iter().map(|s| s.to_string()).collect();
    let err = psim::cli::run(&argv).unwrap_err();
    assert!(err.to_string().contains("is `psim serve` running"), "{err}");
}
