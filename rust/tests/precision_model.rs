//! Precision-model contract tests (the wide-partial-sum byte accounting):
//!
//! * **compatibility invariant** — with all widths equal, byte totals ==
//!   element totals × width, for every cell of the full paper grid;
//! * **golden JSONL** — an 8/8/32/8 AlexNet sweep pinned byte-for-byte
//!   against `rust/tests/golden/alexnet_bits_8_8_32_8.jsonl` (values
//!   recomputed independently in Python; the same file CI diffs against
//!   the built binary);
//! * **headline effect** — on the AlexNet paper grid the active
//!   controller's *byte* saving strictly exceeds its *element* saving
//!   (per cell for the mode-agnostic strategies, and in aggregate over
//!   the whole grid including the mode-adaptive ones);
//! * **default-precision sweeps stay byte-identical** — no byte keys, no
//!   value drift;
//! * **simulator agreement** — the event simulator's per-region element
//!   counters priced by `DataTypes` equal the analytical byte model.

use psim::analytics::bandwidth::{layer_bandwidth_bytes, ControllerMode};
use psim::analytics::grid::{GridEngine, SweepSpec};
use psim::analytics::partition::Strategy;
use psim::models::{zoo, DataTypes};
use psim::sim::scheduler::{simulate_layer, SimConfig};

fn wide() -> DataTypes {
    DataTypes::parse("8:8:32:8").unwrap()
}

#[test]
fn uniform_widths_reproduce_element_totals_across_paper_grid() {
    // The compatibility invariant behind every pinned golden: a uniform
    // w-bit precision prices every cell at exactly (w/8) bytes/element.
    for bits in [8usize, 16] {
        let w = bits as f64 / 8.0;
        let spec = SweepSpec::paper_grid().with_datatypes(vec![DataTypes::uniform(bits)]);
        let grid = GridEngine::new().run_with_workers(&spec, 4);
        assert_eq!(grid.len(), 384);
        for cell in &grid.cells {
            assert_eq!(cell.total_bytes(), cell.total() * w, "{}", cell.key());
            assert_eq!(cell.input_bytes, cell.input * w, "{}", cell.key());
            assert_eq!(cell.min_bytes, cell.min_bw * w, "{}", cell.key());
            assert_eq!(cell.weight_bytes(), cell.weights_per_image() * w, "{}", cell.key());
        }
    }
}

#[test]
fn default_precision_grid_is_byte_identical_to_element_grid() {
    // datatypes is an explicit axis, but its default entry must leave
    // the JSONL stream untouched — byte for byte.
    let plain = GridEngine::new().run_with_workers(&SweepSpec::paper_grid(), 2).to_jsonl();
    let explicit = GridEngine::new()
        .run_with_workers(
            &SweepSpec::paper_grid().with_datatypes(vec![DataTypes::default()]),
            2,
        )
        .to_jsonl();
    assert_eq!(plain, explicit);
    assert!(!plain.contains("bits"), "default sweep leaked a precision key");
    assert!(!plain.contains("_bytes"), "default sweep leaked a byte key");
}

#[test]
fn alexnet_bits_jsonl_golden() {
    // Pinned 8/8/32/8 sweep (the CI smoke step diffs the same file
    // against the built binary). Values recomputed independently.
    let golden = include_str!("golden/alexnet_bits_8_8_32_8.jsonl");
    let spec = SweepSpec::new(vec![zoo::alexnet()])
        .with_macs(vec![512])
        .with_strategies(vec![Strategy::MaxInput, Strategy::Optimal])
        .with_modes(vec![ControllerMode::Passive, ControllerMode::Active])
        .with_datatypes(vec![wide()]);
    let jsonl = GridEngine::new().run_with_workers(&spec, 1).to_jsonl();
    assert_eq!(jsonl, golden);
    // and the stream is worker-count independent
    let eight = GridEngine::new().run_with_workers(&spec, 8).to_jsonl();
    assert_eq!(jsonl, eight);
}

/// Relative active-controller saving of a (strategy, P) pair on AlexNet,
/// in both currencies, with each cell evaluated under the given `dt`.
fn savings(engine: &GridEngine, p: usize, s: Strategy, dt: &DataTypes) -> (f64, f64, f64, f64) {
    let net = zoo::alexnet();
    let pa = engine.cell_fused_dt(&net, p, s, ControllerMode::Passive, 1, 1, dt);
    let ac = engine.cell_fused_dt(&net, p, s, ControllerMode::Active, 1, 1, dt);
    (pa.total(), ac.total(), pa.total_bytes(), ac.total_bytes())
}

#[test]
fn active_byte_saving_exceeds_element_saving_on_alexnet_grid() {
    // The paper's headline, restated in bytes: psums are the widest
    // tensor on the wire, and the active controller's saving is pure
    // psum traffic, so byte savings exceed element savings.
    //
    // Per cell this holds whenever passive and active share a partition
    // (the three mode-agnostic Table I heuristics); the mode-adaptive
    // `optimal`/`search` strategies re-tile per mode, so they are held
    // to the aggregate claim below.
    let engine = GridEngine::new();
    let dt = wide();
    let fixed = [Strategy::MaxInput, Strategy::MaxOutput, Strategy::EqualMacs];
    let mut checked = 0;
    for &p in &psim::analytics::paper::TABLE2_MACS {
        for &s in &fixed {
            let (pe, ae, pb, ab) = savings(&engine, p, s, &dt);
            let sv_e = (pe - ae) / pe;
            let sv_b = (pb - ab) / pb;
            if sv_e > 0.0 {
                assert!(
                    sv_b > sv_e,
                    "{s:?} P={p}: byte saving {sv_b:.4} <= element saving {sv_e:.4}"
                );
                checked += 1;
            } else {
                // no psum re-reads to save: both currencies agree on zero
                assert_eq!(sv_b, 0.0, "{s:?} P={p}");
            }
        }
    }
    assert!(checked >= 10, "only {checked} cells had a nonzero saving");

    // Aggregate over the WHOLE AlexNet paper grid (all four Table I
    // strategies, each cell under its own mode- and currency-optimal
    // partition): 43.3% of bytes saved vs 32.3% of elements.
    let mut te_p = 0.0;
    let mut te_a = 0.0;
    let mut tb_p = 0.0;
    let mut tb_a = 0.0;
    for &p in &psim::analytics::paper::TABLE2_MACS {
        for &s in &Strategy::TABLE1 {
            let (pe, ae, _, _) = savings(&engine, p, s, &DataTypes::default());
            let (_, _, pb, ab) = savings(&engine, p, s, &dt);
            te_p += pe;
            te_a += ae;
            tb_p += pb;
            tb_a += ab;
        }
    }
    let agg_e = (te_p - te_a) / te_p;
    let agg_b = (tb_p - tb_a) / tb_p;
    assert!(
        agg_b > agg_e,
        "aggregate byte saving {agg_b:.4} <= aggregate element saving {agg_e:.4}"
    );
    // the magnitudes themselves are pinned loosely as a sanity anchor
    // (recomputed independently in Python: 32.3% elements, 43.3% bytes)
    assert!((agg_e - 0.3231).abs() < 0.005, "element aggregate drifted: {agg_e}");
    assert!((agg_b - 0.4328).abs() < 0.005, "byte aggregate drifted: {agg_b}");
}

#[test]
fn simulator_and_analytical_byte_models_agree_across_zoo() {
    // For every layer of three structurally different networks, the
    // event simulator's per-region counters priced by DataTypes equal
    // the analytical byte decomposition exactly.
    let dt = wide();
    for net in [zoo::alexnet(), zoo::squeezenet1_0(), zoo::mobilenet_v1()] {
        for layer in &net.layers {
            for mode in ControllerMode::ALL {
                let mut cfg = SimConfig::new(1024, mode, Strategy::Optimal);
                cfg.bus = psim::sim::BusConfig::with_datatypes(&dt);
                let r = simulate_layer(layer, &cfg);
                let part = r.partition.unwrap();
                let bw = layer_bandwidth_bytes(layer, part.m, part.n, mode, &dt);
                assert_eq!(
                    r.stats.activation_bytes(&dt),
                    bw.activations(),
                    "{}/{} {mode:?}",
                    net.name,
                    layer.name
                );
                assert_eq!(r.stats.weight_bytes(&dt), bw.weights);
            }
        }
    }
}

#[test]
fn fused_sweep_composes_with_precision() {
    // The fusion and precision axes compose: fused 8/8/32/8 cells carry
    // both tags, save bytes relative to their unfused siblings, and stay
    // worker-count deterministic.
    let spec = SweepSpec::new(vec![zoo::alexnet()])
        .with_macs(vec![512])
        .with_strategies(vec![Strategy::Optimal])
        .with_modes(vec![ControllerMode::Passive])
        .with_fusion(vec![1, 2])
        .with_datatypes(vec![wide()]);
    let engine = GridEngine::new();
    let grid = engine.run_with_workers(&spec, 1);
    assert_eq!(grid.len(), 2);
    let (unfused, fused) = (&grid.cells[0], &grid.cells[1]);
    assert!(fused.key().contains("fused2") && fused.key().contains("8:8:32:8"));
    assert!(fused.total_bytes() < unfused.total_bytes());
    assert!(fused.total() < unfused.total());
    let json = fused.to_json();
    assert_eq!(json.get("fusion_depth").unwrap().as_usize(), Some(2));
    assert_eq!(json.get("bits").unwrap().as_str(), Some("8:8:32:8"));
    assert_eq!(grid.to_jsonl(), engine.run_with_workers(&spec, 8).to_jsonl());
}
