//! TOML-subset parser: `[section]`, `key = value`, `#` comments.
//! Values: i64, f64, bool, "quoted string". No arrays/tables-in-tables —
//! the project's configs don't need them.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// An integer literal.
    Int(i64),
    /// A float literal.
    Float(f64),
    /// `true`/`false`.
    Bool(bool),
    /// A quoted string.
    Str(String),
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Int(v) => write!(f, "{v}"),
            Value::Float(v) => write!(f, "{v}"),
            Value::Bool(v) => write!(f, "{v}"),
            Value::Str(v) => write!(f, "\"{v}\""),
        }
    }
}

/// Parse error with line number.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConfigError {
    /// 1-based line number.
    pub line: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ConfigError {}

/// A parsed document: `section.key -> value`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ConfigDoc {
    values: BTreeMap<(String, String), Value>,
}

impl ConfigDoc {
    /// Parse a document from text.
    pub fn parse(text: &str) -> Result<ConfigDoc, ConfigError> {
        let mut doc = ConfigDoc::default();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| ConfigError {
                        line: ln + 1,
                        msg: "unterminated section".into(),
                    })?
                    .trim();
                if name.is_empty() {
                    return Err(ConfigError { line: ln + 1, msg: "empty section name".into() });
                }
                section = name.to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| ConfigError { line: ln + 1, msg: "expected key = value".into() })?;
            let key = key.trim();
            if key.is_empty() {
                return Err(ConfigError { line: ln + 1, msg: "empty key".into() });
            }
            let value = Self::parse_value(val.trim()).ok_or_else(|| ConfigError {
                line: ln + 1,
                msg: format!("bad value: {}", val.trim()),
            })?;
            doc.values.insert((section.clone(), key.to_string()), value);
        }
        Ok(doc)
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> anyhow::Result<ConfigDoc> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Self::parse(&text)?)
    }

    fn parse_value(s: &str) -> Option<Value> {
        if let Some(stripped) = s.strip_prefix('"') {
            return stripped.strip_suffix('"').map(|v| Value::Str(v.to_string()));
        }
        match s {
            "true" => return Some(Value::Bool(true)),
            "false" => return Some(Value::Bool(false)),
            _ => {}
        }
        if let Ok(i) = s.parse::<i64>() {
            return Some(Value::Int(i));
        }
        if let Ok(f) = s.parse::<f64>() {
            return Some(Value::Float(f));
        }
        None
    }

    /// Raw value lookup.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.values.get(&(section.to_string(), key.to_string()))
    }

    /// Non-negative integer lookup.
    pub fn get_usize(&self, section: &str, key: &str) -> Option<usize> {
        match self.get(section, key)? {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    /// Float lookup (integers widen).
    pub fn get_f64(&self, section: &str, key: &str) -> Option<f64> {
        match self.get(section, key)? {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// Boolean lookup.
    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key)? {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// String lookup.
    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        match self.get(section, key)? {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// All keys in a section (for validation / error messages).
    pub fn section_keys(&self, section: &str) -> Vec<&str> {
        self.values
            .keys()
            .filter(|(s, _)| s == section)
            .map(|(_, k)| k.as_str())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
# accelerator under test
[accelerator]
p_macs = 2048
banks = 32
mode = "active"     # controller
utilization = 0.85
trace = false

[serve]
max_batch = 8
"#;

    #[test]
    fn parses_sections_and_types() {
        let d = ConfigDoc::parse(DOC).unwrap();
        assert_eq!(d.get_usize("accelerator", "p_macs"), Some(2048));
        assert_eq!(d.get_str("accelerator", "mode"), Some("active"));
        assert_eq!(d.get_f64("accelerator", "utilization"), Some(0.85));
        assert_eq!(d.get_bool("accelerator", "trace"), Some(false));
        assert_eq!(d.get_usize("serve", "max_batch"), Some(8));
        assert_eq!(d.get("serve", "nope"), None);
    }

    #[test]
    fn int_coerces_to_f64_not_vice_versa() {
        let d = ConfigDoc::parse("[s]\na = 3\nb = 1.5\n").unwrap();
        assert_eq!(d.get_f64("s", "a"), Some(3.0));
        assert_eq!(d.get_usize("s", "b"), None);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = ConfigDoc::parse("[ok]\nkey value\n").unwrap_err();
        assert_eq!(err.line, 2);
        let err = ConfigDoc::parse("[broken\n").unwrap_err();
        assert_eq!(err.line, 1);
        let err = ConfigDoc::parse("[s]\nx = @bad\n").unwrap_err();
        assert!(err.msg.contains("bad value"));
    }

    #[test]
    fn section_keys_listed() {
        let d = ConfigDoc::parse(DOC).unwrap();
        let mut keys = d.section_keys("accelerator");
        keys.sort();
        assert_eq!(keys, vec!["banks", "mode", "p_macs", "trace", "utilization"]);
    }

    #[test]
    fn malformed_input_never_panics() {
        // Pinned outcome of the lint PS100 audit: the parser already
        // returns typed errors (never panics) on every malformed form
        // below. Kept as a regression net so a future refactor cannot
        // quietly reintroduce an unwrap on this path.
        for src in [
            "[unterminated\n",
            "[s]\n= 1\n",
            "[s]\nx = \"unterminated\n",
            "[s]\nx = @@\n",
            "[]\nx = 1\n",
            "[s]\nx\n",
        ] {
            assert!(ConfigDoc::parse(src).is_err(), "{src:?} should error");
        }
        // Arbitrary bytes (a fuzz-shaped corpus, deterministic): parse
        // must return, Ok or Err, without panicking.
        for seed in 0_u64..64 {
            let mut x = seed.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(1);
            let bytes: Vec<u8> = (0..48)
                .map(|_| {
                    x ^= x << 13;
                    x ^= x >> 7;
                    x ^= x << 17;
                    (x & 0x7f) as u8
                })
                .collect();
            let doc = String::from_utf8_lossy(&bytes).into_owned();
            let _ = ConfigDoc::parse(&doc);
        }
    }

    #[test]
    fn negative_ints_not_usize() {
        let d = ConfigDoc::parse("[s]\nx = -5\n").unwrap();
        assert_eq!(d.get_usize("s", "x"), None);
        assert_eq!(d.get_f64("s", "x"), Some(-5.0));
    }
}
