//! Typed accelerator configuration over the TOML-subset document.

use anyhow::{bail, Result};

use crate::analytics::bandwidth::ControllerMode;
use crate::analytics::partition::Strategy;
use crate::sim::interconnect::BusConfig;
use crate::sim::scheduler::SimConfig;

use super::parser::ConfigDoc;

/// Accelerator-under-test knobs (the `[accelerator]` section).
#[derive(Clone, Debug)]
pub struct AccelConfig {
    /// MAC budget `P`.
    pub p_macs: usize,
    /// SRAM banks (power of two).
    pub banks: usize,
    /// Interconnect data-bus width, bytes per beat.
    pub bus_bytes: usize,
    /// Uniform element size on the bus, bytes.
    pub elem_bytes: usize,
    /// Memory-controller capability.
    pub mode: ControllerMode,
    /// Partitioning strategy.
    pub strategy: Strategy,
}

impl Default for AccelConfig {
    fn default() -> Self {
        AccelConfig {
            p_macs: 2048,
            banks: 32,
            bus_bytes: 16,
            elem_bytes: 2,
            mode: ControllerMode::Passive,
            strategy: Strategy::Optimal,
        }
    }
}

/// Parse a controller-mode name.
pub fn parse_mode(s: &str) -> Result<ControllerMode> {
    match s.to_ascii_lowercase().as_str() {
        "passive" => Ok(ControllerMode::Passive),
        "active" => Ok(ControllerMode::Active),
        other => bail!("unknown controller mode '{other}' (passive|active)"),
    }
}

/// Parse a strategy name.
pub fn parse_strategy(s: &str) -> Result<Strategy> {
    match s.to_ascii_lowercase().replace(['-', '_'], "").as_str() {
        "maxinput" => Ok(Strategy::MaxInput),
        "maxoutput" => Ok(Strategy::MaxOutput),
        "equalmacs" | "equal" => Ok(Strategy::EqualMacs),
        "optimal" | "thiswork" => Ok(Strategy::Optimal),
        "search" | "optimalsearch" => Ok(Strategy::OptimalSearch),
        other => bail!(
            "unknown strategy '{other}' (max-input|max-output|equal-macs|optimal|search)"
        ),
    }
}

impl AccelConfig {
    /// Build from a parsed document; unknown keys are rejected so typos
    /// fail loudly.
    pub fn from_doc(doc: &ConfigDoc) -> Result<AccelConfig> {
        const KNOWN: [&str; 6] = ["p_macs", "banks", "bus_bytes", "elem_bytes", "mode", "strategy"];
        for key in doc.section_keys("accelerator") {
            if !KNOWN.contains(&key) {
                bail!("unknown [accelerator] key '{key}' (known: {KNOWN:?})");
            }
        }
        let mut cfg = AccelConfig::default();
        if let Some(v) = doc.get_usize("accelerator", "p_macs") {
            cfg.p_macs = v;
        }
        if let Some(v) = doc.get_usize("accelerator", "banks") {
            cfg.banks = v;
        }
        if let Some(v) = doc.get_usize("accelerator", "bus_bytes") {
            cfg.bus_bytes = v;
        }
        if let Some(v) = doc.get_usize("accelerator", "elem_bytes") {
            cfg.elem_bytes = v;
        }
        if let Some(s) = doc.get_str("accelerator", "mode") {
            cfg.mode = parse_mode(s)?;
        }
        if let Some(s) = doc.get_str("accelerator", "strategy") {
            cfg.strategy = parse_strategy(s)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Reject impossible configurations.
    pub fn validate(&self) -> Result<()> {
        if self.p_macs == 0 {
            bail!("p_macs must be > 0");
        }
        if !self.banks.is_power_of_two() {
            bail!("banks must be a power of two, got {}", self.banks);
        }
        if self.bus_bytes == 0 || self.elem_bytes == 0 {
            bail!("bus_bytes and elem_bytes must be > 0");
        }
        Ok(())
    }

    /// Materialize the simulator configuration.
    pub fn sim_config(&self) -> SimConfig {
        let mut cfg = SimConfig::new(self.p_macs, self.mode, self.strategy);
        cfg.banks = self.banks;
        cfg.bus = BusConfig {
            bus_bytes: self.bus_bytes,
            elem_bytes: self.elem_bytes,
            ..BusConfig::default()
        };
        cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        AccelConfig::default().validate().unwrap();
    }

    #[test]
    fn from_doc_full() {
        let doc = ConfigDoc::parse(
            "[accelerator]\np_macs = 4096\nbanks = 16\nbus_bytes = 32\nelem_bytes = 1\nmode = \"active\"\nstrategy = \"max-input\"\n",
        )
        .unwrap();
        let cfg = AccelConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.p_macs, 4096);
        assert_eq!(cfg.banks, 16);
        assert_eq!(cfg.mode, ControllerMode::Active);
        assert_eq!(cfg.strategy, Strategy::MaxInput);
        let sim = cfg.sim_config();
        assert_eq!(sim.bus.bus_bytes, 32);
    }

    #[test]
    fn unknown_keys_rejected() {
        let doc = ConfigDoc::parse("[accelerator]\np_mac = 42\n").unwrap();
        assert!(AccelConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn invalid_banks_rejected() {
        let doc = ConfigDoc::parse("[accelerator]\nbanks = 12\n").unwrap();
        assert!(AccelConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn parse_helpers() {
        assert!(parse_mode("Active").is_ok());
        assert!(parse_mode("hybrid").is_err());
        assert_eq!(parse_strategy("this-work").unwrap(), Strategy::Optimal);
        assert_eq!(parse_strategy("EQUAL_MACS").unwrap(), Strategy::EqualMacs);
        assert!(parse_strategy("random").is_err());
    }
}
