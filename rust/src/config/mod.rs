//! Configuration system: a TOML-subset parser (sections, scalar keys) and
//! the typed accelerator/serving configs built on it.
//!
//! The offline vendor set has no serde/toml, so [`parser`] implements the
//! subset the project needs: `[section]` headers, `key = value` with
//! integer/float/boolean/string values, `#` comments. [`accel`] maps that
//! onto [`accel::AccelConfig`] (the knobs of the simulator and the
//! analytical model) with validation and defaults.

pub mod accel;
pub mod parser;

pub use accel::AccelConfig;
pub use parser::ConfigDoc;
