//! The paper's first-order analytical bandwidth model (Sections II–III).
//!
//! Everything in this module is pure arithmetic over
//! [`ConvLayer`](crate::models::ConvLayer) shapes — no simulation, no
//! tensors. The event-level simulator in [`crate::sim`] validates these
//! formulas transaction-by-transaction.
//!
//! * [`bandwidth`] — eqs. (2)–(4): input/output traffic of a tiled conv.
//! * [`partition`] — the four partitioning strategies of Table I.
//! * [`optimizer`] — eq. (7) closed form + the divisor-constrained search.
//! * [`sweep`] — network-level aggregation over MAC budgets/strategies.
//! * [`grid`] — the unified scenario-sweep engine: declarative
//!   [`grid::SweepSpec`] grids executed in parallel with per-shape
//!   memoization, streamed as deterministic JSONL. Every table/figure
//!   renderer and the `sweep` CLI/server command run on it.
//! * [`extensions`] — beyond the paper: perfect-fusion bound, weight
//!   traffic, batch amortization.
//! * [`spatial`] — beyond the paper: spatial (row-stripe) tiling with
//!   halo re-reads, and the SRAM-budget -> stripe-height tradeoff.
//! * [`fusion`] — beyond the paper: fused layer chains — receptive-field
//!   back-propagation, chain traffic (first input + last output + weight
//!   reloads per stripe) and the live-working-set feasibility check.
//! * [`paper`] — the published Tables I/II/III + Fig. 2 reference data.

pub mod bandwidth;
pub mod extensions;
pub mod fusion;
pub mod grid;
pub mod optimizer;
pub mod paper;
pub mod partition;
pub mod spatial;
pub mod sweep;

pub use bandwidth::{layer_bandwidth, Bandwidth, ControllerMode};
pub use fusion::{chain_bandwidth, chains, FusedBandwidth};
pub use grid::{GridCell, GridEngine, GridResult, SweepSpec};
pub use partition::{partition_layer, Partition, Strategy};
pub use sweep::{network_bandwidth, NetworkReport};
