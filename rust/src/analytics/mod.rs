//! The paper's first-order analytical bandwidth model (Sections II–III).
//!
//! Everything in this module is pure arithmetic over
//! [`ConvLayer`](crate::models::ConvLayer) shapes — no simulation, no
//! tensors. The event-level simulator in [`crate::sim`] validates these
//! formulas transaction-by-transaction.
//!
//! * [`bandwidth`] — eqs. (2)–(4): input/output traffic of a tiled conv.
//! * [`partition`] — the four partitioning strategies of Table I.
//! * [`optimizer`] — eq. (7) closed form + the divisor-constrained search.
//! * [`sweep`] — network-level aggregation over MAC budgets/strategies.
//! * [`grid`] — the unified scenario-sweep engine: declarative
//!   [`grid::SweepSpec`] grids executed in parallel with per-shape
//!   memoization, streamed as deterministic JSONL. Every table/figure
//!   renderer and the `sweep` CLI/server command run on it.
//! * [`extensions`] — beyond the paper: perfect-fusion bound, weight
//!   traffic, batch amortization.
//! * [`spatial`] — beyond the paper: spatial (row-stripe) tiling with
//!   halo re-reads, and the SRAM-budget -> stripe-height tradeoff.
//! * [`fusion`] — beyond the paper: fused layer chains — receptive-field
//!   back-propagation, chain traffic (first input + last output + weight
//!   reloads per stripe) and the live-working-set feasibility check.
//! * [`paper`] — the published Tables I/II/III + Fig. 2 reference data.
//!
//! The full derivation of eqs. 1–7 and the byte-weighted forms lives in
//! `docs/MODEL.md`; its worked AlexNet CONV2 example is pinned against
//! this crate by the doc-test below — every number in the example is
//! recomputed here and must appear verbatim in the document:
//!
//! ```
//! use psim::analytics::bandwidth::{layer_bandwidth, layer_bandwidth_bytes, ControllerMode};
//! use psim::models::{ConvLayer, DataTypes};
//!
//! let md = std::fs::read_to_string(concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/MODEL.md"))
//!     .expect("docs/MODEL.md exists");
//! let conv2 = ConvLayer::new("conv2", 27, 27, 64, 192, 5, 1, 2);
//! let dt = DataTypes::parse("8:8:32:8").unwrap();
//! let e = layer_bandwidth(&conv2, 16, 1, ControllerMode::Passive);
//! let p = layer_bandwidth_bytes(&conv2, 16, 1, ControllerMode::Passive, &dt);
//! let a = layer_bandwidth_bytes(&conv2, 16, 1, ControllerMode::Active, &dt);
//! for v in [
//!     e.input,            // eq. 2 elements (== bytes at 1 B/elem)
//!     e.output,           // eq. 3 elements, passive
//!     p.psum,             // passive psum bytes
//!     a.psum,             // active psum bytes
//!     p.ofmap,            // final-write bytes
//!     e.input + e.output, // passive element total
//!     p.activations(),    // passive byte total
//!     a.activations(),    // active byte total
//! ] {
//!     assert!(md.contains(&format!("{}", v as u64)), "MODEL.md missing {v}");
//! }
//! ```

pub mod bandwidth;
pub mod extensions;
pub mod fusion;
pub mod grid;
pub mod optimizer;
pub mod paper;
pub mod partition;
pub mod spatial;
pub mod sweep;

pub use bandwidth::{
    layer_bandwidth, layer_bandwidth_bytes, Bandwidth, ByteBandwidth, ControllerMode,
};
pub use fusion::{chain_bandwidth, chain_bandwidth_bytes, chains, FusedBandwidth};
pub use grid::{GridCell, GridEngine, GridResult, SweepSpec};
pub use partition::{partition_layer, partition_layer_bytes, Partition, Strategy};
pub use sweep::{network_bandwidth, NetworkReport};
