//! Extensions beyond the paper's model — the "future work" its
//! assumptions point at, quantified on the same descriptors.
//!
//! 1. **Layer fusion** (the paper assumes "no fused operations across
//!    layers"): if a consumer starts from the producer's on-chip output,
//!    the intermediate tensor never crosses the interconnect. We bound
//!    the benefit (perfect fusion) and the on-chip buffer it demands.
//! 2. **Weight traffic** (the paper tracks activations only): every
//!    weight is loaded exactly once per inference under the Section II
//!    loop nest (each weight belongs to exactly one `(co, ci)` tile), so
//!    weight traffic is partition-invariant — but it *amortizes across a
//!    batch*, which activation traffic does not.
//! 3. **Batch amortization**: per-image traffic as a function of batch.

use crate::models::Network;

/// Fusion bound for a network (activations, raw counts).
#[derive(Clone, Copy, Debug)]
pub struct FusionReport {
    /// Paper's floor: every tensor crosses the bus twice (write + read).
    pub unfused: f64,
    /// Perfect-fusion floor: only the image (read) and the last layer's
    /// output (write) cross the bus.
    pub fused: f64,
    /// On-chip buffer needed: the largest producer+consumer working set.
    pub required_buffer_elems: u64,
}

impl FusionReport {
    /// Fraction of the unfused traffic that perfect fusion removes.
    pub fn saving_fraction(&self) -> f64 {
        (self.unfused - self.fused) / self.unfused
    }
}

/// Perfect-fusion bound. Intermediates (every tensor that is both some
/// layer's output and another's input) stay on chip. With branching
/// topologies (inception/residual) a tensor may feed several consumers —
/// fusing removes the write plus *all* re-reads; our per-layer descriptor
/// list counts each consumer's read separately in `min_bandwidth`, so the
/// fused floor is simply image-in + final-out.
pub fn fusion_bound(net: &Network) -> FusionReport {
    let unfused = net.min_bandwidth() as f64;
    let image = net.layers.first().map(|l| l.input_activations()).unwrap_or(0);
    let last_out = net.layers.last().map(|l| l.output_activations()).unwrap_or(0);
    let fused = (image + last_out) as f64;
    // Working set: producing layer's input + output resident at once.
    let required_buffer_elems = net
        .layers
        .iter()
        .map(|l| l.input_activations() + l.output_activations())
        .max()
        .unwrap_or(0);
    FusionReport { unfused, fused, required_buffer_elems }
}

/// Weight traffic per inference (elements) — partition-invariant under
/// the Section II loop nest.
pub fn weight_traffic(net: &Network) -> u64 {
    net.total_weights()
}

/// Per-image total traffic at batch size `b`: activations are per-image;
/// weights amortize (loaded once per batch per tile when the batch is
/// processed before advancing tiles).
pub fn per_image_traffic(activations_per_image: f64, weights: u64, b: usize) -> f64 {
    assert!(b > 0);
    activations_per_image + weights as f64 / b as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn fusion_bound_basics() {
        let net = zoo::alexnet();
        let f = fusion_bound(&net);
        assert!(f.fused < f.unfused);
        // image 3*224*224 + conv5 out 256*13*13
        assert_eq!(f.fused, (3 * 224 * 224 + 256 * 13 * 13) as f64);
        assert!(f.saving_fraction() > 0.5, "{}", f.saving_fraction());
        assert!(f.required_buffer_elems > 0);
    }

    #[test]
    fn fusion_saving_monotone_sanity() {
        // Deeper nets with big intermediates save relatively more.
        let vgg = fusion_bound(&zoo::vgg16());
        assert!(vgg.saving_fraction() > 0.9);
    }

    #[test]
    fn weight_traffic_is_total_weights() {
        let net = zoo::resnet18();
        assert_eq!(weight_traffic(&net), net.total_weights());
    }

    #[test]
    fn batch_amortization() {
        let w = 1_000_000u64;
        let a = 5_000_000.0;
        let b1 = per_image_traffic(a, w, 1);
        let b8 = per_image_traffic(a, w, 8);
        assert!(b8 < b1);
        assert_eq!(b1 - a, 1_000_000.0);
        assert_eq!(b8 - a, 125_000.0);
    }

    #[test]
    #[should_panic]
    fn zero_batch_rejected() {
        per_image_traffic(1.0, 1, 0);
    }
}
