//! Eq. (7) and its integer adaptation, plus an exhaustive divisor search
//! used both as an ablation baseline and to validate that the closed form
//! lands on (or next to) the true discrete optimum.

use crate::models::ConvLayer;
use crate::util::mathx::{divisors, nearest_divisor_log};

use super::bandwidth::{layer_bandwidth, ControllerMode};
use super::partition::Partition;

/// The real-valued optimum of eq. (7) for a layer (per group).
///
/// Passive controller (paper eq. 7):
///   `m* = sqrt(2 * Wo*Ho * P / (Wi*Hi * K^2))`
///
/// Active controller: the psum read-back term disappears from `B(m)`
/// (`B_o = Wo*Ho*N*M/m`), so minimizing
/// `B(m) = Wi*Hi*M*N*K^2/P * m + Wo*Ho*N*M/m` gives the same expression
/// without the factor 2.
pub fn optimal_m_real(layer: &ConvLayer, p_macs: usize, mode: ControllerMode) -> f64 {
    let wo_ho = (layer.wo() * layer.ho()) as f64;
    let wi_hi = (layer.wi * layer.hi) as f64;
    let k2 = (layer.k * layer.k) as f64;
    let factor = match mode {
        ControllerMode::Passive => 2.0,
        ControllerMode::Active => 1.0,
    };
    (factor * wo_ho * p_macs as f64 / (wi_hi * k2)).sqrt()
}

/// Adapt the real-valued `m*` per the paper: clamp to `[1, M]` and snap to
/// a divisor of `M` (nearest in log space — the bandwidth terms scale as
/// `m` and `1/m`, so multiplicative distance is the right metric). The
/// result is further capped so at least one output map fits: `K^2 m <= P`.
pub fn adapt_m(layer: &ConvLayer, p_macs: usize, m_real: f64) -> usize {
    let mg = layer.m_per_group();
    let k2 = layer.k * layer.k;
    let cap = (p_macs / k2).max(1).min(mg);
    let clamped = m_real.clamp(1.0, cap as f64);
    let snapped = nearest_divisor_log(mg, clamped);
    if snapped <= cap {
        snapped
    } else {
        // nearest divisor overshot the MAC budget: take the largest
        // divisor within the cap.
        divisors(mg).into_iter().filter(|&d| d <= cap).max().unwrap_or(1)
    }
}

/// Given `m`, allocate the remaining MACs to output maps per eq. (5):
/// `n = P / (K^2 m)`, floored, clamped to `[1, N]`.
pub fn n_from_budget(layer: &ConvLayer, p_macs: usize, m: usize) -> usize {
    let k2 = layer.k * layer.k;
    (p_macs / (k2 * m)).max(1).min(layer.n_per_group())
}

/// The paper's partition (Section II): eq. (7) + integer adaptation.
pub fn optimal_partition(layer: &ConvLayer, p_macs: usize, mode: ControllerMode) -> Partition {
    let m = adapt_m(layer, p_macs, optimal_m_real(layer, p_macs, mode));
    Partition { m, n: n_from_budget(layer, p_macs, m) }
}

/// Exhaustive discrete optimum: `m` over divisors of `M` (integral psum
/// passes, the paper's adaptation rule) and `n` over the feasible range
/// `[1, min(N, P/(K^2 m))]` — the same feasible set the closed form draws
/// its floor-adapted `n` from. Used to (a) ablate the closed form and (b)
/// bound how much the integer adaptation gives away.
///
/// Perf note (EXPERIMENTS.md §Perf L3-1): bandwidth is monotone
/// non-increasing in `n` (it enters only through `ceil(N/n)` input
/// passes), so the inner dimension needs no scan — the feasible maximum
/// `n_cap` is optimal for every `m`. This replaced an `O(n_cap)` loop.
pub fn search_partition(layer: &ConvLayer, p_macs: usize, mode: ControllerMode) -> Partition {
    let mg = layer.m_per_group();
    let ng = layer.n_per_group();
    let k2 = layer.k * layer.k;
    let mut best = Partition { m: 1, n: 1 };
    let mut best_bw = f64::INFINITY;
    for m in divisors(mg) {
        if k2 * m > p_macs && m > 1 {
            break; // divisors ascending: no larger m fits either
        }
        let n = (p_macs / (k2 * m)).max(1).min(ng);
        let bw = layer_bandwidth(layer, m, n, mode).total();
        if bw < best_bw {
            best_bw = bw;
            best = Partition { m, n };
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ConvLayer;

    fn conv3() -> ConvLayer {
        // AlexNet conv3: 13x13, 192 -> 384, k3
        ConvLayer::new("conv3", 13, 13, 192, 384, 3, 1, 1)
    }

    #[test]
    fn eq7_hand_calc() {
        // m* = sqrt(2 * 169 * 512 / (169 * 9)) = sqrt(1024/9) = 10.666..
        let m = optimal_m_real(&conv3(), 512, ControllerMode::Passive);
        assert!((m - 10.666).abs() < 0.01, "got {m}");
        // active drops the factor 2: sqrt(512/9) = 7.54
        let ma = optimal_m_real(&conv3(), 512, ControllerMode::Active);
        assert!((ma - 7.542).abs() < 0.01, "got {ma}");
    }

    #[test]
    fn adapt_snaps_to_divisor() {
        let l = conv3();
        let m = adapt_m(&l, 512, 10.666);
        assert_eq!(192 % m, 0);
        // nearest divisors of 192 around 10.67 are 8 and 12; log-nearest is 12
        assert_eq!(m, 12);
    }

    #[test]
    fn adapt_respects_mac_budget() {
        // K=11 -> K^2=121; P=512 -> cap = 4; M=64
        let l = ConvLayer::new("c", 224, 224, 64, 64, 11, 4, 2);
        let m = adapt_m(&l, 512, 50.0);
        assert!(m * 121 <= 512);
        assert_eq!(64 % m, 0);
    }

    #[test]
    fn n_from_budget_clamps() {
        let l = conv3();
        assert_eq!(n_from_budget(&l, 512, 12), 4); // 512/(9*12) = 4.74 -> 4
        assert_eq!(n_from_budget(&l, 1_000_000, 192), 384); // clamped to N
        assert_eq!(n_from_budget(&l, 9, 1), 1); // at least 1
    }

    #[test]
    fn search_beats_or_matches_formula() {
        for p in [512usize, 2048, 16384] {
            for mode in ControllerMode::ALL {
                let l = conv3();
                let f = optimal_partition(&l, p, mode);
                let s = search_partition(&l, p, mode);
                let bf = layer_bandwidth(&l, f.m, f.n, mode).total();
                let bs = layer_bandwidth(&l, s.m, s.n, mode).total();
                assert!(bs <= bf + 1e-9, "search worse than formula at P={p}");
                // and the closed form should be within 25% of discrete optimum
                assert!(bf <= bs * 1.25, "formula {bf} far from optimum {bs}");
            }
        }
    }

    #[test]
    fn search_respects_constraint() {
        let l = conv3();
        let s = search_partition(&l, 512, ControllerMode::Passive);
        assert!(l.k * l.k * s.m * s.n <= 512);
    }

    #[test]
    fn infeasible_budget_degrades_to_unit_tile() {
        // K^2 = 121 > P = 100: must still run at m=n=1.
        let l = ConvLayer::new("c", 32, 32, 8, 8, 11, 1, 5);
        let s = search_partition(&l, 100, ControllerMode::Passive);
        assert_eq!((s.m, s.n), (1, 1));
        let f = optimal_partition(&l, 100, ControllerMode::Passive);
        assert_eq!((f.m, f.n), (1, 1));
    }
}
