//! Eq. (7) and its integer adaptation, plus an exhaustive divisor search
//! used both as an ablation baseline and to validate that the closed form
//! lands on (or next to) the true discrete optimum.

use crate::models::{ConvLayer, DataTypes};
use crate::util::mathx::{divisors, nearest_divisor_log};

use super::bandwidth::{layer_bandwidth, layer_bandwidth_bytes, ControllerMode};
use super::partition::Partition;

/// The real-valued optimum of eq. (7) for a layer (per group).
///
/// Passive controller (paper eq. 7):
///   `m* = sqrt(2 * Wo*Ho * P / (Wi*Hi * K^2))`
///
/// Active controller: the psum read-back term disappears from `B(m)`
/// (`B_o = Wo*Ho*N*M/m`), so minimizing
/// `B(m) = Wi*Hi*M*N*K^2/P * m + Wo*Ho*N*M/m` gives the same expression
/// without the factor 2.
pub fn optimal_m_real(layer: &ConvLayer, p_macs: usize, mode: ControllerMode) -> f64 {
    let wo_ho = (layer.wo() * layer.ho()) as f64;
    let wi_hi = (layer.wi * layer.hi) as f64;
    let k2 = (layer.k * layer.k) as f64;
    let factor = match mode {
        ControllerMode::Passive => 2.0,
        ControllerMode::Active => 1.0,
    };
    (factor * wo_ho * p_macs as f64 / (wi_hi * k2)).sqrt()
}

/// The real-valued optimum of eq. (7) under **byte** weighting.
///
/// Substituting `n = P/(K² m)` (eq. 5) into the byte-priced traffic gives
/// `B(m) = iB·Wi·Hi·M·N·K²/P · m + f·pB·Wo·Ho·N·M/m + const`, where `iB`/
/// `pB` are the ifmap/psum element sizes, `f = 2` passive / `1` active,
/// and the ofmap term is constant in `m`. Minimizing:
///
/// `m*_bytes = sqrt(f · (pB/iB) · Wo·Ho · P / (Wi·Hi · K²))`
///
/// — the element-model optimum scaled by `sqrt(pB/iB)`. With 8-bit
/// ifmaps and 32-bit psums the optimum shifts **2× higher**: wide psums
/// make psum passes costlier, so byte-optimal tiling buys more input maps
/// per iteration at the price of extra input re-reads.
///
/// ```
/// use psim::analytics::bandwidth::ControllerMode;
/// use psim::analytics::optimizer::{optimal_m_real, optimal_m_real_bytes};
/// use psim::models::{ConvLayer, DataTypes};
///
/// let l = ConvLayer::new("conv3", 13, 13, 192, 384, 3, 1, 1);
/// let dt = DataTypes::parse("8:8:32:8").unwrap();
/// let elem = optimal_m_real(&l, 512, ControllerMode::Passive);
/// let byte = optimal_m_real_bytes(&l, 512, ControllerMode::Passive, &dt);
/// assert_eq!(byte, elem * 2.0); // sqrt(32/8) = 2
/// // Uniform widths reduce to the element-model optimum exactly.
/// let uni = optimal_m_real_bytes(&l, 512, ControllerMode::Passive, &DataTypes::default());
/// assert_eq!(uni, elem);
/// ```
pub fn optimal_m_real_bytes(
    layer: &ConvLayer,
    p_macs: usize,
    mode: ControllerMode,
    dt: &DataTypes,
) -> f64 {
    let ratio = dt.psum_bytes() / dt.ifmap_bytes();
    optimal_m_real(layer, p_macs, mode) * ratio.sqrt()
}

/// Adapt the real-valued `m*` per the paper: clamp to `[1, M]` and snap to
/// a divisor of `M` (nearest in log space — the bandwidth terms scale as
/// `m` and `1/m`, so multiplicative distance is the right metric). The
/// result is further capped so at least one output map fits: `K^2 m <= P`.
pub fn adapt_m(layer: &ConvLayer, p_macs: usize, m_real: f64) -> usize {
    let mg = layer.m_per_group();
    let k2 = layer.k * layer.k;
    let cap = (p_macs / k2).max(1).min(mg);
    let clamped = m_real.clamp(1.0, cap as f64);
    let snapped = nearest_divisor_log(mg, clamped);
    if snapped <= cap {
        snapped
    } else {
        // nearest divisor overshot the MAC budget: take the largest
        // divisor within the cap.
        divisors(mg).into_iter().filter(|&d| d <= cap).max().unwrap_or(1)
    }
}

/// Given `m`, allocate the remaining MACs to output maps per eq. (5):
/// `n = P / (K^2 m)`, floored, clamped to `[1, N]`.
pub fn n_from_budget(layer: &ConvLayer, p_macs: usize, m: usize) -> usize {
    let k2 = layer.k * layer.k;
    (p_macs / (k2 * m)).max(1).min(layer.n_per_group())
}

/// The paper's partition (Section II): eq. (7) + integer adaptation.
pub fn optimal_partition(layer: &ConvLayer, p_macs: usize, mode: ControllerMode) -> Partition {
    let m = adapt_m(layer, p_macs, optimal_m_real(layer, p_macs, mode));
    Partition { m, n: n_from_budget(layer, p_macs, m) }
}

/// Byte-weighted closed-form partition: [`optimal_m_real_bytes`] + the
/// same integer adaptation and eq. 5 `n` allocation as the element model.
pub fn optimal_partition_bytes(
    layer: &ConvLayer,
    p_macs: usize,
    mode: ControllerMode,
    dt: &DataTypes,
) -> Partition {
    let m = adapt_m(layer, p_macs, optimal_m_real_bytes(layer, p_macs, mode, dt));
    Partition { m, n: n_from_budget(layer, p_macs, m) }
}

/// Exhaustive discrete optimum: `m` over divisors of `M` (integral psum
/// passes, the paper's adaptation rule) and `n` over the feasible range
/// `[1, min(N, P/(K^2 m))]` — the same feasible set the closed form draws
/// its floor-adapted `n` from. Used to (a) ablate the closed form and (b)
/// bound how much the integer adaptation gives away.
///
/// Perf note (EXPERIMENTS.md §Perf L3-1): bandwidth is monotone
/// non-increasing in `n` (it enters only through `ceil(N/n)` input
/// passes), so the inner dimension needs no scan — the feasible maximum
/// `n_cap` is optimal for every `m`. This replaced an `O(n_cap)` loop.
pub fn search_partition(layer: &ConvLayer, p_macs: usize, mode: ControllerMode) -> Partition {
    search_with_cost(layer, p_macs, |m, n| layer_bandwidth(layer, m, n, mode).total())
}

/// Exhaustive discrete optimum under the **byte** objective: the same
/// divisor-constrained feasible set as [`search_partition`], minimizing
/// activation bytes instead of elements. With uniform widths the
/// objective is a positive scaling of the element one, so the argmin (and
/// its first-match tie-breaking) is identical.
pub fn search_partition_bytes(
    layer: &ConvLayer,
    p_macs: usize,
    mode: ControllerMode,
    dt: &DataTypes,
) -> Partition {
    search_with_cost(layer, p_macs, |m, n| {
        layer_bandwidth_bytes(layer, m, n, mode, dt).activations()
    })
}

/// The shared divisor scan both searches run on, so the feasible-set
/// invariants live once: `m` over divisors of `M` ascending with the
/// early break (no larger divisor fits eq. 1 either), `n` at the feasible
/// maximum `min(N, P/(K² m))` (bandwidth is monotone non-increasing in
/// `n` — the Perf L3-1 argument — so the inner dimension needs no scan),
/// first strict improvement wins ties.
fn search_with_cost(
    layer: &ConvLayer,
    p_macs: usize,
    cost: impl Fn(usize, usize) -> f64,
) -> Partition {
    let mg = layer.m_per_group();
    let ng = layer.n_per_group();
    let k2 = layer.k * layer.k;
    let mut best = Partition { m: 1, n: 1 };
    let mut best_bw = f64::INFINITY;
    for m in divisors(mg) {
        if k2 * m > p_macs && m > 1 {
            break; // divisors ascending: no larger m fits either
        }
        let n = (p_macs / (k2 * m)).max(1).min(ng);
        let bw = cost(m, n);
        if bw < best_bw {
            best_bw = bw;
            best = Partition { m, n };
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ConvLayer;

    fn conv3() -> ConvLayer {
        // AlexNet conv3: 13x13, 192 -> 384, k3
        ConvLayer::new("conv3", 13, 13, 192, 384, 3, 1, 1)
    }

    #[test]
    fn eq7_hand_calc() {
        // m* = sqrt(2 * 169 * 512 / (169 * 9)) = sqrt(1024/9) = 10.666..
        let m = optimal_m_real(&conv3(), 512, ControllerMode::Passive);
        assert!((m - 10.666).abs() < 0.01, "got {m}");
        // active drops the factor 2: sqrt(512/9) = 7.54
        let ma = optimal_m_real(&conv3(), 512, ControllerMode::Active);
        assert!((ma - 7.542).abs() < 0.01, "got {ma}");
    }

    #[test]
    fn adapt_snaps_to_divisor() {
        let l = conv3();
        let m = adapt_m(&l, 512, 10.666);
        assert_eq!(192 % m, 0);
        // nearest divisors of 192 around 10.67 are 8 and 12; log-nearest is 12
        assert_eq!(m, 12);
    }

    #[test]
    fn adapt_respects_mac_budget() {
        // K=11 -> K^2=121; P=512 -> cap = 4; M=64
        let l = ConvLayer::new("c", 224, 224, 64, 64, 11, 4, 2);
        let m = adapt_m(&l, 512, 50.0);
        assert!(m * 121 <= 512);
        assert_eq!(64 % m, 0);
    }

    #[test]
    fn n_from_budget_clamps() {
        let l = conv3();
        assert_eq!(n_from_budget(&l, 512, 12), 4); // 512/(9*12) = 4.74 -> 4
        assert_eq!(n_from_budget(&l, 1_000_000, 192), 384); // clamped to N
        assert_eq!(n_from_budget(&l, 9, 1), 1); // at least 1
    }

    #[test]
    fn search_beats_or_matches_formula() {
        for p in [512usize, 2048, 16384] {
            for mode in ControllerMode::ALL {
                let l = conv3();
                let f = optimal_partition(&l, p, mode);
                let s = search_partition(&l, p, mode);
                let bf = layer_bandwidth(&l, f.m, f.n, mode).total();
                let bs = layer_bandwidth(&l, s.m, s.n, mode).total();
                assert!(bs <= bf + 1e-9, "search worse than formula at P={p}");
                // and the closed form should be within 25% of discrete optimum
                assert!(bf <= bs * 1.25, "formula {bf} far from optimum {bs}");
            }
        }
    }

    #[test]
    fn search_respects_constraint() {
        let l = conv3();
        let s = search_partition(&l, 512, ControllerMode::Passive);
        assert!(l.k * l.k * s.m * s.n <= 512);
    }

    #[test]
    fn byte_weighting_shifts_the_optimum_up() {
        // conv3 at P=512: element m* = 10.67 snaps to 12; under 8-bit
        // ifmaps / 32-bit psums m* doubles to 21.33 and snaps to 24 —
        // wide psums buy more input maps per pass.
        let l = conv3();
        let dt = DataTypes::parse("8:8:32:8").unwrap();
        let elem = optimal_partition(&l, 512, ControllerMode::Passive);
        let byte = optimal_partition_bytes(&l, 512, ControllerMode::Passive, &dt);
        assert_eq!(elem.m, 12);
        assert_eq!(byte.m, 24);
        assert!(byte.m > elem.m);
        // active mode: element 7.54 -> 8; byte 15.08 -> 16
        let ab = optimal_partition_bytes(&l, 512, ControllerMode::Active, &dt);
        assert_eq!(ab.m, 16);
    }

    #[test]
    fn byte_search_beats_or_matches_byte_formula() {
        let dt = DataTypes::parse("8:8:32:8").unwrap();
        for p in [512usize, 2048, 16384] {
            for mode in ControllerMode::ALL {
                let l = conv3();
                let f = optimal_partition_bytes(&l, p, mode, &dt);
                let s = search_partition_bytes(&l, p, mode, &dt);
                let bf = layer_bandwidth_bytes(&l, f.m, f.n, mode, &dt).activations();
                let bs = layer_bandwidth_bytes(&l, s.m, s.n, mode, &dt).activations();
                assert!(bs <= bf + 1e-9, "byte search worse than formula at P={p}");
                assert!(l.k * l.k * s.m * s.n <= p);
            }
        }
    }

    #[test]
    fn uniform_widths_reproduce_element_partitions() {
        // With all widths equal the byte objective is a positive scaling
        // of the element one: identical partitions, closed form or search.
        for bits in [8usize, 16] {
            let dt = DataTypes::uniform(bits);
            for p in [512usize, 2048] {
                for mode in ControllerMode::ALL {
                    let l = conv3();
                    assert_eq!(
                        optimal_partition_bytes(&l, p, mode, &dt),
                        optimal_partition(&l, p, mode),
                    );
                    assert_eq!(
                        search_partition_bytes(&l, p, mode, &dt),
                        search_partition(&l, p, mode),
                    );
                }
            }
        }
    }

    #[test]
    fn infeasible_budget_degrades_to_unit_tile() {
        // K^2 = 121 > P = 100: must still run at m=n=1.
        let l = ConvLayer::new("c", 32, 32, 8, 8, 11, 1, 5);
        let s = search_partition(&l, 100, ControllerMode::Passive);
        assert_eq!((s.m, s.n), (1, 1));
        let f = optimal_partition(&l, 100, ControllerMode::Passive);
        assert_eq!((f.m, f.n), (1, 1));
    }
}
