//! Spatial-tiling extension: the paper partitions only the channel
//! dimensions `(m, n)`; real accelerators also tile the `Wo x Ho` plane
//! when a full row set does not fit on chip. Spatial tiles overlap by
//! `K - 1` rows/cols of *halo*, so input traffic grows with the tile
//! count — a second-order term the paper's model omits. This module
//! quantifies it and finds the traffic-optimal row-tile height.
//!
//! Model: output rows are processed in horizontal stripes of height `T`
//! (full width). Each stripe needs `T*stride + K - stride` input rows, so
//! a stripe re-reads `K - stride` halo rows shared with its neighbour
//! (clamped at 0 for stride >= K). Channel partitioning composes
//! multiplicatively, exactly as in eqs. (2)-(3).

use crate::models::ConvLayer;

use super::bandwidth::{Bandwidth, ControllerMode};

/// Input rows needed by one output stripe of height `t`.
fn input_rows_for_stripe(layer: &ConvLayer, t: usize) -> usize {
    t * layer.stride + layer.k.saturating_sub(layer.stride)
}

/// Input rows touched per full pass over the output plane when striped at
/// height `t`: each stripe pulls its rows (with `K - stride` halo),
/// clamped to the physical row count. With a single stripe (`t = Ho`)
/// this is at most `Hi`. Shared with [`crate::dse::metrics`], whose halo
/// model is `rows_per_pass(t) - Hi` extra re-read rows (clamped at 0).
pub fn rows_per_pass(layer: &ConvLayer, t: usize) -> usize {
    let ho = layer.ho();
    debug_assert!(t >= 1 && t <= ho);
    let stripes = ho.div_ceil(t);
    let mut rows = 0usize;
    for s in 0..stripes {
        let t_eff = t.min(ho - s * t);
        rows += input_rows_for_stripe(layer, t_eff).min(layer.hi);
    }
    rows
}

/// Bandwidth of `layer` tiled as `(m, n)` channels x `t` output rows per
/// stripe. `t = Ho` reproduces [`super::bandwidth::layer_bandwidth`]
/// exactly (no halo).
pub fn layer_bandwidth_spatial(
    layer: &ConvLayer,
    m: usize,
    n: usize,
    t: usize,
    mode: ControllerMode,
) -> Bandwidth {
    let mg = layer.m_per_group();
    let ng = layer.n_per_group();
    let ho = layer.ho();
    assert!(m >= 1 && m <= mg, "m out of range");
    assert!(n >= 1 && n <= ng, "n out of range");
    assert!(t >= 1 && t <= ho, "t out of range [1,{ho}]");
    let g = layer.groups as f64;

    let out_iters = ng.div_ceil(n);
    let psum_iters = mg.div_ceil(m);

    let input = (layer.wi * rows_per_pass(layer, t) * mg) as f64 * out_iters as f64 * g;
    let wo_ho_ng = (layer.wo() * ho * ng) as f64;
    let output = match mode {
        ControllerMode::Passive => wo_ho_ng * (2 * psum_iters - 1) as f64 * g,
        ControllerMode::Active => wo_ho_ng * psum_iters as f64 * g,
    };
    Bandwidth { input, output }
}

/// Halo overhead of stripe height `t`: extra input traffic relative to
/// the unstriped plane, as a fraction (0 = free).
pub fn halo_overhead(layer: &ConvLayer, t: usize) -> f64 {
    let (mg, ng) = (layer.m_per_group(), layer.n_per_group());
    let full = layer_bandwidth_spatial(layer, mg, ng, layer.ho(), ControllerMode::Passive);
    let tiled = layer_bandwidth_spatial(layer, mg, ng, t, ControllerMode::Passive);
    (tiled.input - full.input) / full.input
}

/// On-chip working set (elements) for a stripe of height `t` with channel
/// tile `(m, n)`: input rows + psum stripe + weight tile.
pub fn stripe_working_set(layer: &ConvLayer, m: usize, n: usize, t: usize) -> u64 {
    let in_rows = input_rows_for_stripe(layer, t).min(layer.hi);
    (layer.wi * in_rows * m + layer.wo() * t * n + n * m * layer.k * layer.k) as u64
}

/// Smallest stripe height whose working set fits `budget_elems`, together
/// with its halo overhead — the knob an SRAM-constrained design would
/// turn. Returns `None` if even `t = 1` does not fit.
pub fn max_stripe_within(
    layer: &ConvLayer,
    m: usize,
    n: usize,
    budget_elems: u64,
) -> Option<(usize, f64)> {
    let ho = layer.ho();
    let mut best = None;
    for t in 1..=ho {
        if stripe_working_set(layer, m, n, t) <= budget_elems {
            best = Some(t);
        } else {
            break; // working set is monotone in t
        }
    }
    best.map(|t| (t, halo_overhead(layer, t)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::bandwidth::layer_bandwidth;
    use crate::models::ConvLayer;

    fn layer() -> ConvLayer {
        ConvLayer::new("c", 56, 56, 64, 128, 3, 1, 1)
    }

    #[test]
    fn full_stripe_matches_channel_only_model() {
        let l = layer();
        for mode in ControllerMode::ALL {
            let a = layer_bandwidth(&l, 16, 8, mode);
            let b = layer_bandwidth_spatial(&l, 16, 8, l.ho(), mode);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn halo_grows_as_stripes_shrink() {
        let l = layer();
        let mut prev = -1.0;
        for t in [56usize, 28, 14, 7, 4, 2, 1] {
            let ov = halo_overhead(&l, t);
            assert!(ov >= prev, "overhead not monotone at t={t}");
            assert!(ov >= 0.0);
            prev = ov;
        }
        // K=3,s=1: t=1 stripes read 3 rows per output row (≈3x near edges)
        assert!(halo_overhead(&l, 1) > 1.0);
        assert!(halo_overhead(&l, 56) < 1e-12);
    }

    #[test]
    fn rows_per_pass_caps_at_physical_rows() {
        let l = layer(); // 56x56, k3, s1, p1
        assert_eq!(rows_per_pass(&l, l.ho()), 56);
        // 2 stripes of 28: each pulls 28 + 2 halo rows, capped at 56
        assert_eq!(rows_per_pass(&l, 28), 60);
        // p=0 strided conv: a single full-height stripe touches fewer
        // rows than Hi (the floor-cropped tail row is never read).
        let s = ConvLayer::new("s", 224, 224, 3, 64, 7, 2, 0);
        assert!(rows_per_pass(&s, s.ho()) <= 224);
    }

    #[test]
    fn one_by_one_kernel_has_no_halo() {
        let l = ConvLayer::new("pw", 28, 28, 64, 64, 1, 1, 0);
        for t in [1usize, 4, 28] {
            assert_eq!(halo_overhead(&l, t), 0.0);
        }
    }

    #[test]
    fn strided_conv_shrinks_halo() {
        let s1 = ConvLayer::new("a", 56, 56, 8, 8, 3, 1, 1);
        let s2 = ConvLayer::new("b", 56, 56, 8, 8, 3, 2, 1);
        // halo rows = K - stride: 2 vs 1
        assert!(halo_overhead(&s2, 4) < halo_overhead(&s1, 4));
    }

    #[test]
    fn working_set_monotone_and_budget_search() {
        let l = layer();
        let mut prev = 0;
        for t in 1..=l.ho() {
            let ws = stripe_working_set(&l, 16, 8, t);
            assert!(ws >= prev);
            prev = ws;
        }
        // Big budget: whole plane fits -> no overhead.
        let (t, ov) = max_stripe_within(&l, 16, 8, u64::MAX).unwrap();
        assert_eq!(t, l.ho());
        assert_eq!(ov, 0.0);
        // Tiny budget: nothing fits.
        assert!(max_stripe_within(&l, 16, 8, 10).is_none());
        // Medium budget: some stripe with positive overhead.
        let ws_t4 = stripe_working_set(&l, 16, 8, 4);
        let (t4, ov4) = max_stripe_within(&l, 16, 8, ws_t4).unwrap();
        assert!(t4 >= 4);
        assert!(ov4 > 0.0);
    }
}
