//! Network-level aggregation: apply a strategy to every conv layer and sum
//! the traffic — the quantity the paper tabulates (million activations per
//! inference image).

use crate::models::{ConvLayer, Network};

use super::bandwidth::{layer_bandwidth, Bandwidth, ControllerMode};
use super::partition::{partition_layer, Partition, Strategy};

/// Per-layer outcome of a partitioning decision.
#[derive(Clone, Debug)]
pub struct LayerReport {
    /// The layer analyzed.
    pub layer: ConvLayer,
    /// The `(m, n)` tile the strategy chose.
    pub partition: Partition,
    /// Its eq. 2–3 traffic.
    pub bandwidth: Bandwidth,
}

/// Whole-network outcome.
#[derive(Clone, Debug)]
pub struct NetworkReport {
    /// Network name.
    pub network: String,
    /// MAC budget `P`.
    pub p_macs: usize,
    /// Partitioning strategy applied to every layer.
    pub strategy: Strategy,
    /// Memory-controller mode.
    pub mode: ControllerMode,
    /// Per-layer outcomes, in execution order.
    pub layers: Vec<LayerReport>,
}

impl NetworkReport {
    /// Total activations moved (inputs + outputs/psums).
    pub fn total(&self) -> f64 {
        self.layers.iter().map(|l| l.bandwidth.total()).sum()
    }

    /// Total in million activations (the paper's tabulated unit).
    pub fn total_mact(&self) -> f64 {
        self.total() / 1.0e6
    }

    /// Input-traffic share of the total (used in the ablation benches).
    pub fn input_fraction(&self) -> f64 {
        let i: f64 = self.layers.iter().map(|l| l.bandwidth.input).sum();
        i / self.total()
    }
}

/// Partition every layer of `net` and report the summed bandwidth.
///
/// ```
/// use psim::analytics::sweep::network_bandwidth;
/// use psim::analytics::{ControllerMode, Strategy};
/// use psim::models::zoo;
///
/// let net = zoo::alexnet();
/// let r = network_bandwidth(&net, 2048, Strategy::Optimal, ControllerMode::Passive);
/// assert_eq!(r.layers.len(), 5);
/// // Partitioned traffic can never beat the read-once/write-once floor.
/// assert!(r.total() >= net.min_bandwidth() as f64);
/// ```
pub fn network_bandwidth(
    net: &Network,
    p_macs: usize,
    strategy: Strategy,
    mode: ControllerMode,
) -> NetworkReport {
    let layers = net
        .layers
        .iter()
        .map(|layer| {
            let partition = partition_layer(layer, p_macs, strategy, mode);
            let bandwidth = layer_bandwidth(layer, partition.m, partition.n, mode);
            LayerReport { layer: layer.clone(), partition, bandwidth }
        })
        .collect();
    NetworkReport { network: net.name.clone(), p_macs, strategy, mode, layers }
}

/// The Table III floor for a network, in raw activations.
pub fn min_bandwidth(net: &Network) -> f64 {
    net.min_bandwidth() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn totals_are_sums_of_layers() {
        let net = zoo::alexnet();
        let r = network_bandwidth(&net, 2048, Strategy::Optimal, ControllerMode::Passive);
        let manual: f64 = r.layers.iter().map(|l| l.bandwidth.total()).sum();
        assert_eq!(r.total(), manual);
        assert_eq!(r.layers.len(), net.layers.len());
    }

    #[test]
    fn bandwidth_never_below_floor() {
        for net in zoo::paper_networks() {
            for p in [512usize, 2048, 16384] {
                for s in Strategy::TABLE1 {
                    for mode in ControllerMode::ALL {
                        let r = network_bandwidth(&net, p, s, mode);
                        assert!(
                            r.total() >= min_bandwidth(&net) - 1e-6,
                            "{} {:?} {:?} P={p}: {} < floor {}",
                            net.name,
                            s,
                            mode,
                            r.total(),
                            min_bandwidth(&net)
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn huge_mac_budget_approaches_floor() {
        // Paper Section IV: "with a very large number of MACs, it
        // approaches the minimum bandwidth as given in table III".
        let net = zoo::alexnet();
        let r = network_bandwidth(&net, 1 << 26, Strategy::OptimalSearch, ControllerMode::Passive);
        let floor = min_bandwidth(&net);
        assert!((r.total() - floor).abs() / floor < 1e-9, "{} vs {floor}", r.total());
    }

    #[test]
    fn active_le_passive_for_same_strategy() {
        for net in zoo::paper_networks() {
            for p in [512usize, 4096] {
                let pa = network_bandwidth(&net, p, Strategy::Optimal, ControllerMode::Passive);
                let ac = network_bandwidth(&net, p, Strategy::Optimal, ControllerMode::Active);
                assert!(
                    ac.total() <= pa.total() + 1e-6,
                    "{} P={p}: active {} > passive {}",
                    net.name,
                    ac.total(),
                    pa.total()
                );
            }
        }
    }

    #[test]
    fn more_macs_never_hurt_search_strategy() {
        let net = zoo::resnet18();
        let mut prev = f64::INFINITY;
        for p in [512usize, 1024, 2048, 4096, 8192, 16384] {
            let t = network_bandwidth(&net, p, Strategy::OptimalSearch, ControllerMode::Passive)
                .total();
            assert!(t <= prev + 1e-6, "P={p}: {t} > {prev}");
            prev = t;
        }
    }
}
