//! Eqs. (2)–(4): bandwidth of one tiled convolution layer.
//!
//! With `m` input maps and `n` output maps processed per iteration:
//!
//! * input maps are read `N/n` times:  `B_i = Wi*Hi*M * N/n`         (2)
//! * partial sums are written `M/m` times and read `M/m - 1` times:
//!   `B_o = Wo*Ho*N * (2*M/m - 1)`                                    (3)
//! * an **active** memory controller performs the read-add-write inside
//!   the SRAM controller, so only the writes cross the interconnect:
//!   `B_o = Wo*Ho*N * M/m`                                   (Section III)
//!
//! Grouped convolutions are handled per group (`M/g` in, `N/g` out) and
//! summed; the partition `(m, n)` applies within a group.

use crate::models::ConvLayer;

/// Whether the SRAM controller can fold the partial-sum addition locally.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ControllerMode {
    /// Conventional controller: psums are read back over the interconnect.
    Passive,
    /// Active controller (Section III): read-update-write happens inside
    /// the controller; only the write crosses the interconnect.
    Active,
}

impl ControllerMode {
    pub const ALL: [ControllerMode; 2] = [ControllerMode::Passive, ControllerMode::Active];

    pub fn label(&self) -> &'static str {
        match self {
            ControllerMode::Passive => "passive",
            ControllerMode::Active => "active",
        }
    }
}

/// Bandwidth decomposition for one layer (units: activations moved).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Bandwidth {
    /// Input-activation traffic, eq. (2).
    pub input: f64,
    /// Output/partial-sum traffic, eq. (3) or its active variant.
    pub output: f64,
}

impl Bandwidth {
    pub fn total(&self) -> f64 {
        self.input + self.output
    }
}

/// Compute the bandwidth of `layer` partitioned as `(m, n)` **per group**.
///
/// `m` must lie in `[1, M/g]` and `n` in `[1, N/g]`. Non-divisor `m`/`n`
/// are accepted in the first-order spirit of the paper: iteration counts
/// are the *ceilings* `ceil(M_g/m)`/`ceil(N_g/n)` (a partial tile costs a
/// full pass over the data it touches — matching what the simulator does).
///
/// ```
/// use psim::analytics::bandwidth::{layer_bandwidth, layer_min_bandwidth, ControllerMode};
/// use psim::models::ConvLayer;
///
/// // AlexNet conv3: 13x13, 192 -> 384, k3/p1.
/// let l = ConvLayer::new("conv3", 13, 13, 192, 384, 3, 1, 1);
/// // Full residency (m = M, n = N): everything read once, written once.
/// let bw = layer_bandwidth(&l, 192, 384, ControllerMode::Passive);
/// assert_eq!(bw.total(), layer_min_bandwidth(&l));
/// // The active controller halves the psum traffic of a 16-pass split.
/// let p = layer_bandwidth(&l, 12, 4, ControllerMode::Passive);
/// let a = layer_bandwidth(&l, 12, 4, ControllerMode::Active);
/// assert!(a.output < p.output);
/// ```
pub fn layer_bandwidth(layer: &ConvLayer, m: usize, n: usize, mode: ControllerMode) -> Bandwidth {
    let mg = layer.m_per_group();
    let ng = layer.n_per_group();
    assert!(m >= 1 && m <= mg, "m={m} out of range [1,{mg}] for {}", layer.name);
    assert!(n >= 1 && n <= ng, "n={n} out of range [1,{ng}] for {}", layer.name);
    let g = layer.groups as f64;

    // Iteration counts within a group.
    let out_iters = ng.div_ceil(n); // N_g / n, ceil
    let psum_iters = mg.div_ceil(m); // M_g / m, ceil

    let wi_hi_mg = (layer.wi * layer.hi * mg) as f64;
    let wo_ho_ng = (layer.wo() * layer.ho() * ng) as f64;

    let input = wi_hi_mg * out_iters as f64 * g;
    let output = match mode {
        ControllerMode::Passive => wo_ho_ng * (2 * psum_iters - 1) as f64 * g,
        ControllerMode::Active => wo_ho_ng * psum_iters as f64 * g,
    };
    Bandwidth { input, output }
}

/// The layer's floor traffic: everything read once + written once
/// (the per-layer term of Table III).
pub fn layer_min_bandwidth(layer: &ConvLayer) -> f64 {
    (layer.input_activations() + layer.output_activations()) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ConvLayer;

    fn layer() -> ConvLayer {
        // 13x13, 192 -> 384, k3/p1 (AlexNet conv3 shape)
        ConvLayer::new("c", 13, 13, 192, 384, 3, 1, 1)
    }

    #[test]
    fn full_residency_hits_floor() {
        // m=M, n=N: everything read once, written once.
        let l = layer();
        let bw = layer_bandwidth(&l, 192, 384, ControllerMode::Passive);
        assert_eq!(bw.total(), layer_min_bandwidth(&l));
    }

    #[test]
    fn eq2_eq3_match_hand_calc() {
        let l = layer();
        // m=12, n=4: input read 384/4=96 times, psums 192/12=16 iters.
        let bw = layer_bandwidth(&l, 12, 4, ControllerMode::Passive);
        assert_eq!(bw.input, (13 * 13 * 192) as f64 * 96.0);
        assert_eq!(bw.output, (13 * 13 * 384) as f64 * 31.0);
    }

    #[test]
    fn active_drops_psum_reads() {
        let l = layer();
        let p = layer_bandwidth(&l, 12, 4, ControllerMode::Passive);
        let a = layer_bandwidth(&l, 12, 4, ControllerMode::Active);
        assert_eq!(a.input, p.input);
        // active = writes only = (passive + Wo*Ho*N) / 2
        let wo_ho_n = (13 * 13 * 384) as f64;
        assert_eq!(a.output, (p.output + wo_ho_n) / 2.0);
    }

    #[test]
    fn m_equal_big_m_never_rereads_psums() {
        let l = layer();
        let p = layer_bandwidth(&l, 192, 1, ControllerMode::Passive);
        let a = layer_bandwidth(&l, 192, 1, ControllerMode::Active);
        // single psum iteration: passive == active
        assert_eq!(p.output, a.output);
    }

    #[test]
    fn non_divisor_uses_ceil_iterations() {
        let l = layer();
        // m=100 of 192 -> 2 psum iterations
        let bw = layer_bandwidth(&l, 100, 384, ControllerMode::Passive);
        assert_eq!(bw.output, (13 * 13 * 384) as f64 * 3.0);
    }

    #[test]
    fn grouped_conv_sums_groups() {
        // depthwise 3x3, 32 channels @112
        let dw = ConvLayer::grouped("dw", 112, 112, 32, 32, 3, 1, 1, 32);
        let bw = layer_bandwidth(&dw, 1, 1, ControllerMode::Passive);
        // each group: read Wi*Hi once, write Wo*Ho once (m=M_g -> no rereads)
        assert_eq!(bw.total(), (112 * 112 * 32 + 112 * 112 * 32) as f64);
    }

    #[test]
    #[should_panic]
    fn rejects_m_out_of_range() {
        layer_bandwidth(&layer(), 500, 1, ControllerMode::Passive);
    }
}
