//! Eqs. (2)–(4): bandwidth of one tiled convolution layer.
//!
//! With `m` input maps and `n` output maps processed per iteration:
//!
//! * input maps are read `N/n` times:  `B_i = Wi*Hi*M * N/n`         (2)
//! * partial sums are written `M/m` times and read `M/m - 1` times:
//!   `B_o = Wo*Ho*N * (2*M/m - 1)`                                    (3)
//! * an **active** memory controller performs the read-add-write inside
//!   the SRAM controller, so only the writes cross the interconnect:
//!   `B_o = Wo*Ho*N * M/m`                                   (Section III)
//!
//! Grouped convolutions are handled per group (`M/g` in, `N/g` out) and
//! summed; the partition `(m, n)` applies within a group.
//!
//! # Byte-weighted forms (`docs/MODEL.md` §Byte-level model)
//!
//! Partial sums are wider than activations (e.g. 32-bit accumulators vs
//! 8-bit ifmaps), so the same element counts cost different interconnect
//! *bytes* per tensor. With per-tensor widths
//! [`DataTypes`](crate::models::DataTypes) and `it = ceil(M/m)` psum
//! iterations, the output-side crossings decompose per output element as:
//!
//! * passive: `(it-1)` psum reads + `(it-1)` psum writes at psum width,
//!   plus one final quantized write at ofmap width;
//! * active: `(it-1)` psum writes at psum width plus one final write at
//!   ofmap width (the read-add happens inside the controller).
//!
//! The element counts are unchanged — only the pricing differs — and with
//! all widths equal to one byte the byte totals equal the element totals
//! exactly (the compatibility invariant pinned by
//! `rust/tests/precision_model.rs`).

use crate::models::{ConvLayer, DataTypes};

/// Whether the SRAM controller can fold the partial-sum addition locally.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ControllerMode {
    /// Conventional controller: psums are read back over the interconnect.
    Passive,
    /// Active controller (Section III): read-update-write happens inside
    /// the controller; only the write crosses the interconnect.
    Active,
}

impl ControllerMode {
    /// Both controller modes, passive first (table column order).
    pub const ALL: [ControllerMode; 2] = [ControllerMode::Passive, ControllerMode::Active];

    /// Stable wire/CLI token (`"passive"`/`"active"`).
    pub fn label(&self) -> &'static str {
        match self {
            ControllerMode::Passive => "passive",
            ControllerMode::Active => "active",
        }
    }
}

/// Bandwidth decomposition for one layer (units: activations moved).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Bandwidth {
    /// Input-activation traffic, eq. (2).
    pub input: f64,
    /// Output/partial-sum traffic, eq. (3) or its active variant.
    pub output: f64,
}

impl Bandwidth {
    /// Total traffic `B = B_i + B_o` (eq. 4), elements.
    pub fn total(&self) -> f64 {
        self.input + self.output
    }
}

/// Compute the bandwidth of `layer` partitioned as `(m, n)` **per group**.
///
/// `m` must lie in `[1, M/g]` and `n` in `[1, N/g]`. Non-divisor `m`/`n`
/// are accepted in the first-order spirit of the paper: iteration counts
/// are the *ceilings* `ceil(M_g/m)`/`ceil(N_g/n)` (a partial tile costs a
/// full pass over the data it touches — matching what the simulator does).
///
/// ```
/// use psim::analytics::bandwidth::{layer_bandwidth, layer_min_bandwidth, ControllerMode};
/// use psim::models::ConvLayer;
///
/// // AlexNet conv3: 13x13, 192 -> 384, k3/p1.
/// let l = ConvLayer::new("conv3", 13, 13, 192, 384, 3, 1, 1);
/// // Full residency (m = M, n = N): everything read once, written once.
/// let bw = layer_bandwidth(&l, 192, 384, ControllerMode::Passive);
/// assert_eq!(bw.total(), layer_min_bandwidth(&l));
/// // The active controller halves the psum traffic of a 16-pass split.
/// let p = layer_bandwidth(&l, 12, 4, ControllerMode::Passive);
/// let a = layer_bandwidth(&l, 12, 4, ControllerMode::Active);
/// assert!(a.output < p.output);
/// ```
pub fn layer_bandwidth(layer: &ConvLayer, m: usize, n: usize, mode: ControllerMode) -> Bandwidth {
    let mg = layer.m_per_group();
    let ng = layer.n_per_group();
    assert!(m >= 1 && m <= mg, "m={m} out of range [1,{mg}] for {}", layer.name);
    assert!(n >= 1 && n <= ng, "n={n} out of range [1,{ng}] for {}", layer.name);
    let g = layer.groups as f64;

    // Iteration counts within a group.
    let out_iters = ng.div_ceil(n); // N_g / n, ceil
    let psum_iters = mg.div_ceil(m); // M_g / m, ceil

    let wi_hi_mg = (layer.wi * layer.hi * mg) as f64;
    let wo_ho_ng = (layer.wo() * layer.ho() * ng) as f64;

    let input = wi_hi_mg * out_iters as f64 * g;
    let output = match mode {
        ControllerMode::Passive => wo_ho_ng * (2 * psum_iters - 1) as f64 * g,
        ControllerMode::Active => wo_ho_ng * psum_iters as f64 * g,
    };
    Bandwidth { input, output }
}

/// The layer's floor traffic: everything read once + written once
/// (the per-layer term of Table III).
pub fn layer_min_bandwidth(layer: &ConvLayer) -> f64 {
    (layer.input_activations() + layer.output_activations()) as f64
}

/// Byte-weighted bandwidth decomposition for one layer: the same element
/// counts as [`Bandwidth`], priced per tensor by a
/// [`DataTypes`](crate::models::DataTypes) precision. All quantities are
/// exact `f64` bytes (element counts × bits / 8).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ByteBandwidth {
    /// Input-activation bytes: eq. (2) elements × ifmap width.
    pub input: f64,
    /// Intermediate partial-sum bytes (reads + non-final writes) at psum
    /// width. Zero when a single pass suffices (`m = M`).
    pub psum: f64,
    /// Final quantized output writes at ofmap width (one per output
    /// element, either controller mode).
    pub ofmap: f64,
    /// Weight bytes: one load per weight element × weight width (weights
    /// are partition-invariant under the Section II loop nest).
    pub weights: f64,
}

impl ByteBandwidth {
    /// Activation bytes on the wire — the byte-currency analogue of the
    /// paper's tabulated `B_i + B_o` (weights excluded, as in the paper).
    pub fn activations(&self) -> f64 {
        self.input + self.psum + self.ofmap
    }

    /// Everything that crossed the interconnect, weights included.
    pub fn total(&self) -> f64 {
        self.input + self.psum + self.ofmap + self.weights
    }
}

/// Byte-weighted eqs. (2)–(3): the element counts of [`layer_bandwidth`]
/// priced per region by `dt`.
///
/// The decomposition keeps the element totals intact:
/// `psum_elems + ofmap_elems == B_o` for either controller mode, so with
/// uniform widths `w` the byte totals are exactly `w/8 ×` the element
/// totals.
///
/// ```
/// use psim::analytics::bandwidth::{layer_bandwidth, layer_bandwidth_bytes, ControllerMode};
/// use psim::models::{ConvLayer, DataTypes};
///
/// // AlexNet conv2: 27x27, 64 -> 192, k5/p2, tiled (m, n) = (16, 1).
/// let l = ConvLayer::new("conv2", 27, 27, 64, 192, 5, 1, 2);
/// let dt = DataTypes::parse("8:8:32:8").unwrap();
/// let b = layer_bandwidth_bytes(&l, 16, 1, ControllerMode::Passive, &dt);
/// // eq. 2: 27*27*64 * 192 input reads, one byte each.
/// assert_eq!(b.input, (27 * 27 * 64 * 192) as f64);
/// // it = 64/16 = 4 psum passes: 2*(4-1) psum crossings at 4 bytes ...
/// assert_eq!(b.psum, (27 * 27 * 192 * 6 * 4) as f64);
/// // ... plus one final 1-byte ofmap write per output element.
/// assert_eq!(b.ofmap, (27 * 27 * 192) as f64);
/// // The active controller halves the psum-byte term and nothing else.
/// let a = layer_bandwidth_bytes(&l, 16, 1, ControllerMode::Active, &dt);
/// assert_eq!(a.psum, b.psum / 2.0);
/// assert_eq!((a.input, a.ofmap), (b.input, b.ofmap));
/// // Uniform widths: bytes == elements × width.
/// let uni = layer_bandwidth_bytes(&l, 16, 1, ControllerMode::Passive, &DataTypes::uniform(16));
/// let e = layer_bandwidth(&l, 16, 1, ControllerMode::Passive);
/// assert_eq!(uni.activations(), e.total() * 2.0);
/// ```
pub fn layer_bandwidth_bytes(
    layer: &ConvLayer,
    m: usize,
    n: usize,
    mode: ControllerMode,
    dt: &DataTypes,
) -> ByteBandwidth {
    let mg = layer.m_per_group();
    let ng = layer.n_per_group();
    assert!(m >= 1 && m <= mg, "m={m} out of range [1,{mg}] for {}", layer.name);
    assert!(n >= 1 && n <= ng, "n={n} out of range [1,{ng}] for {}", layer.name);
    let g = layer.groups as f64;

    let out_iters = ng.div_ceil(n);
    let psum_iters = mg.div_ceil(m);

    let input_elems = (layer.wi * layer.hi * mg) as f64 * out_iters as f64 * g;
    let out_elems = (layer.wo() * layer.ho() * ng) as f64 * g;
    let psum_crossings = match mode {
        // (it-1) reads + (it-1) non-final writes per output element.
        ControllerMode::Passive => 2 * (psum_iters - 1),
        // (it-1) non-final writes; reads stay inside the controller.
        ControllerMode::Active => psum_iters - 1,
    };
    ByteBandwidth {
        input: input_elems * dt.ifmap_bytes(),
        psum: out_elems * psum_crossings as f64 * dt.psum_bytes(),
        ofmap: out_elems * dt.ofmap_bytes(),
        weights: layer.weights() as f64 * dt.weight_bytes(),
    }
}

/// The layer's byte floor: input read once at ifmap width, output written
/// once at ofmap width (no psum term — full residency never spills a
/// partial sum). The per-layer term of
/// [`Network::min_bandwidth_bytes`](crate::models::Network::min_bandwidth_bytes).
pub fn layer_min_bandwidth_bytes(layer: &ConvLayer, dt: &DataTypes) -> f64 {
    layer.input_activations() as f64 * dt.ifmap_bytes()
        + layer.output_activations() as f64 * dt.ofmap_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ConvLayer;

    fn layer() -> ConvLayer {
        // 13x13, 192 -> 384, k3/p1 (AlexNet conv3 shape)
        ConvLayer::new("c", 13, 13, 192, 384, 3, 1, 1)
    }

    #[test]
    fn full_residency_hits_floor() {
        // m=M, n=N: everything read once, written once.
        let l = layer();
        let bw = layer_bandwidth(&l, 192, 384, ControllerMode::Passive);
        assert_eq!(bw.total(), layer_min_bandwidth(&l));
    }

    #[test]
    fn eq2_eq3_match_hand_calc() {
        let l = layer();
        // m=12, n=4: input read 384/4=96 times, psums 192/12=16 iters.
        let bw = layer_bandwidth(&l, 12, 4, ControllerMode::Passive);
        assert_eq!(bw.input, (13 * 13 * 192) as f64 * 96.0);
        assert_eq!(bw.output, (13 * 13 * 384) as f64 * 31.0);
    }

    #[test]
    fn active_drops_psum_reads() {
        let l = layer();
        let p = layer_bandwidth(&l, 12, 4, ControllerMode::Passive);
        let a = layer_bandwidth(&l, 12, 4, ControllerMode::Active);
        assert_eq!(a.input, p.input);
        // active = writes only = (passive + Wo*Ho*N) / 2
        let wo_ho_n = (13 * 13 * 384) as f64;
        assert_eq!(a.output, (p.output + wo_ho_n) / 2.0);
    }

    #[test]
    fn m_equal_big_m_never_rereads_psums() {
        let l = layer();
        let p = layer_bandwidth(&l, 192, 1, ControllerMode::Passive);
        let a = layer_bandwidth(&l, 192, 1, ControllerMode::Active);
        // single psum iteration: passive == active
        assert_eq!(p.output, a.output);
    }

    #[test]
    fn non_divisor_uses_ceil_iterations() {
        let l = layer();
        // m=100 of 192 -> 2 psum iterations
        let bw = layer_bandwidth(&l, 100, 384, ControllerMode::Passive);
        assert_eq!(bw.output, (13 * 13 * 384) as f64 * 3.0);
    }

    #[test]
    fn grouped_conv_sums_groups() {
        // depthwise 3x3, 32 channels @112
        let dw = ConvLayer::grouped("dw", 112, 112, 32, 32, 3, 1, 1, 32);
        let bw = layer_bandwidth(&dw, 1, 1, ControllerMode::Passive);
        // each group: read Wi*Hi once, write Wo*Ho once (m=M_g -> no rereads)
        assert_eq!(bw.total(), (112 * 112 * 32 + 112 * 112 * 32) as f64);
    }

    #[test]
    #[should_panic]
    fn rejects_m_out_of_range() {
        layer_bandwidth(&layer(), 500, 1, ControllerMode::Passive);
    }

    #[test]
    fn byte_model_decomposition_conserves_elements() {
        // psum + ofmap element counts must re-compose to eq. 3's B_o in
        // both modes, for divisor and ragged partitions.
        let l = layer();
        let dt = DataTypes::parse("8:8:32:8").unwrap();
        for mode in ControllerMode::ALL {
            for (m, n) in [(12, 4), (100, 384), (192, 1), (1, 1)] {
                let e = layer_bandwidth(&l, m, n, mode);
                let b = layer_bandwidth_bytes(&l, m, n, mode, &dt);
                let psum_elems = b.psum / dt.psum_bytes();
                let ofmap_elems = b.ofmap / dt.ofmap_bytes();
                assert_eq!(psum_elems + ofmap_elems, e.output, "m={m} n={n} {mode:?}");
                assert_eq!(b.input / dt.ifmap_bytes(), e.input);
            }
        }
    }

    #[test]
    fn uniform_widths_scale_element_totals_exactly() {
        let l = layer();
        for bits in [8usize, 16, 24, 32] {
            let dt = DataTypes::uniform(bits);
            let w = bits as f64 / 8.0;
            for mode in ControllerMode::ALL {
                let e = layer_bandwidth(&l, 12, 4, mode);
                let b = layer_bandwidth_bytes(&l, 12, 4, mode, &dt);
                assert_eq!(b.activations(), e.total() * w, "bits={bits} {mode:?}");
            }
            assert_eq!(layer_min_bandwidth_bytes(&l, &dt), layer_min_bandwidth(&l) * w);
        }
    }

    #[test]
    fn fixed_partition_byte_saving_exceeds_element_saving() {
        // The headline effect: with psums wider than ifmaps/ofmaps, the
        // active controller's saving — pure psum traffic — is up-weighted
        // in byte currency, so (passive - active)/passive is strictly
        // larger in bytes than in elements whenever it > 1.
        let l = layer();
        let dt = DataTypes::parse("8:8:32:8").unwrap();
        for (m, n) in [(12, 4), (48, 8), (1, 384)] {
            let pe = layer_bandwidth(&l, m, n, ControllerMode::Passive).total();
            let ae = layer_bandwidth(&l, m, n, ControllerMode::Active).total();
            let pb = layer_bandwidth_bytes(&l, m, n, ControllerMode::Passive, &dt).activations();
            let ab = layer_bandwidth_bytes(&l, m, n, ControllerMode::Active, &dt).activations();
            let sv_e = (pe - ae) / pe;
            let sv_b = (pb - ab) / pb;
            assert!(sv_b > sv_e, "m={m} n={n}: byte {sv_b} <= element {sv_e}");
        }
    }

    #[test]
    fn wider_psums_never_reduce_byte_traffic() {
        let l = layer();
        let narrow = DataTypes::parse("8:8:16:8").unwrap();
        let wide = DataTypes::parse("8:8:32:8").unwrap();
        for mode in ControllerMode::ALL {
            let n8 = layer_bandwidth_bytes(&l, 12, 4, mode, &narrow);
            let w8 = layer_bandwidth_bytes(&l, 12, 4, mode, &wide);
            assert_eq!(w8.psum, 2.0 * n8.psum);
            assert_eq!(w8.input, n8.input);
            assert_eq!(w8.ofmap, n8.ofmap);
        }
    }
}
