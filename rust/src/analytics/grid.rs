//! The unified scenario-sweep engine: one declarative description of a
//! design-space grid (networks × MAC budgets × strategies × controller
//! modes × batch sizes × fusion depths × precisions), one parallel,
//! memoizing executor, one deterministic JSONL output format.
//!
//! Everything the paper tabulates is a slice of this grid — Table I is
//! `TABLE1_MACS × Strategy::TABLE1 × passive`, Table II is
//! `TABLE2_MACS × optimal × both modes`, Fig. 2 is derived from Table II —
//! so `report::{tables, compare, fig2}`, the `tables`/`analyze`/`sweep`
//! CLI commands and the `serve` protocol's `{"cmd":"sweep"}` request all
//! run on this engine instead of re-deriving cells ad hoc.
//!
//! Two properties make the engine fast and trustworthy:
//!
//! * **Shape memoization** — per-layer results are cached by layer *shape*
//!   (not name), and CNNs repeat conv shapes heavily (VGG's 3×3 stacks,
//!   ResNet's repeated blocks, the zoo across a grid), so the full paper
//!   grid collapses to a fraction of its raw layer-evaluation count.
//! * **Determinism** — every quantity is exact integer-valued `f64`
//!   arithmetic and [`parallel_map`] preserves input order, so the JSONL
//!   stream is byte-identical for any worker count (pinned by
//!   `rust/tests/grid_engine.rs`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::coordinator::parallel::{default_workers, parallel_map};
use crate::models::{ConvLayer, DataTypes, Network};
use crate::util::json::Json;

use super::bandwidth::{
    layer_bandwidth, layer_bandwidth_bytes, Bandwidth, ByteBandwidth, ControllerMode,
};
use super::fusion;
use super::paper;
use super::partition::{partition_layer, partition_layer_bytes, Partition, Strategy};

/// A declarative sweep: the Cartesian product of seven axes.
///
/// [`SweepSpec::paper_grid`] gives the paper's full evaluation grid
/// (8 zoo networks × 6 MAC budgets × 4 strategies × 2 controller modes);
/// builder methods narrow or extend any axis.
///
/// ```
/// use psim::analytics::grid::{GridEngine, SweepSpec};
/// use psim::analytics::{ControllerMode, Strategy};
/// use psim::models::zoo;
///
/// let spec = SweepSpec::new(vec![zoo::alexnet()])
///     .with_macs(vec![512, 2048])
///     .with_strategies(vec![Strategy::Optimal])
///     .with_modes(vec![ControllerMode::Passive]);
/// assert_eq!(spec.cell_count(), 2);
///
/// let grid = GridEngine::new().run(&spec);
/// assert_eq!(grid.cells.len(), 2);
/// // More MACs -> fewer re-reads -> less traffic (paper Table II).
/// assert!(grid.cells[1].total() < grid.cells[0].total());
/// ```
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// Networks to evaluate (resolved descriptors, not names).
    pub networks: Vec<Network>,
    /// MAC budgets `P` (eq. 1's constraint bound).
    pub mac_budgets: Vec<usize>,
    /// Partitioning strategies (Table I columns).
    pub strategies: Vec<Strategy>,
    /// Memory-controller modes (Table II columns).
    pub modes: Vec<ControllerMode>,
    /// Batch sizes (beyond the paper: weights amortize across a batch,
    /// activations do not — see [`crate::analytics::extensions`]).
    pub batch_sizes: Vec<usize>,
    /// Fusion depths (beyond the paper: chains of up to `d` consecutive
    /// layers evaluated in fused tiles keep intermediates on chip — see
    /// [`crate::analytics::fusion`]). Depth 1 is the paper's unfused
    /// model; it is the default and reproduces the unfused output
    /// byte-for-byte.
    pub fusion_depths: Vec<usize>,
    /// Per-tensor precisions (the paper's wide-partial-sum observation:
    /// psum crossings cost more bytes than activation crossings). The
    /// default single uniform-8-bit entry reproduces the element-count
    /// output byte-for-byte; non-default entries add byte-weighted keys
    /// to the JSONL and re-derive the `optimal`/`search` partitions under
    /// byte weighting.
    pub datatypes: Vec<DataTypes>,
}

impl SweepSpec {
    /// A spec over explicit networks with paper-grid defaults on the other
    /// axes: `TABLE2_MACS` budgets, the four Table I strategies, both
    /// controller modes, batch 1.
    pub fn new(networks: Vec<Network>) -> SweepSpec {
        SweepSpec {
            networks,
            mac_budgets: paper::TABLE2_MACS.to_vec(),
            strategies: Strategy::TABLE1.to_vec(),
            modes: ControllerMode::ALL.to_vec(),
            batch_sizes: vec![1],
            fusion_depths: vec![1],
            datatypes: vec![DataTypes::default()],
        }
    }

    /// The paper's full evaluation grid over the eight zoo networks.
    pub fn paper_grid() -> SweepSpec {
        SweepSpec::new(crate::models::zoo::paper_networks())
    }

    /// Replace the MAC-budget axis.
    pub fn with_macs(mut self, macs: Vec<usize>) -> SweepSpec {
        self.mac_budgets = macs;
        self
    }

    /// Replace the strategy axis.
    pub fn with_strategies(mut self, strategies: Vec<Strategy>) -> SweepSpec {
        self.strategies = strategies;
        self
    }

    /// Replace the controller-mode axis.
    pub fn with_modes(mut self, modes: Vec<ControllerMode>) -> SweepSpec {
        self.modes = modes;
        self
    }

    /// Replace the batch-size axis.
    pub fn with_batches(mut self, batch_sizes: Vec<usize>) -> SweepSpec {
        self.batch_sizes = batch_sizes;
        self
    }

    /// Replace the fusion-depth axis.
    pub fn with_fusion(mut self, fusion_depths: Vec<usize>) -> SweepSpec {
        self.fusion_depths = fusion_depths;
        self
    }

    /// Replace the precision axis (`--bits` on the CLI, `bits` on the
    /// wire).
    pub fn with_datatypes(mut self, datatypes: Vec<DataTypes>) -> SweepSpec {
        self.datatypes = datatypes;
        self
    }

    /// Number of grid cells this spec expands to. Saturates instead of
    /// wrapping, so a maliciously huge request cannot overflow past the
    /// dispatcher's size cap and slip through as a tiny count.
    pub fn cell_count(&self) -> usize {
        self.networks
            .len()
            .saturating_mul(self.mac_budgets.len())
            .saturating_mul(self.strategies.len())
            .saturating_mul(self.modes.len())
            .saturating_mul(self.batch_sizes.len())
            .saturating_mul(self.fusion_depths.len())
            .saturating_mul(self.datatypes.len())
    }

    /// Every axis non-empty and numerically sane.
    pub fn validate(&self) -> Result<()> {
        if self.networks.is_empty() {
            bail!("sweep spec has no networks");
        }
        if self.mac_budgets.is_empty() || self.mac_budgets.contains(&0) {
            bail!("sweep spec needs at least one MAC budget, all > 0");
        }
        if self.strategies.is_empty() {
            bail!("sweep spec has no strategies");
        }
        if self.modes.is_empty() {
            bail!("sweep spec has no controller modes");
        }
        if self.batch_sizes.is_empty() || self.batch_sizes.contains(&0) {
            bail!("sweep spec needs at least one batch size, all > 0");
        }
        if self.fusion_depths.is_empty() || self.fusion_depths.contains(&0) {
            bail!("sweep spec needs at least one fusion depth, all >= 1");
        }
        if self.datatypes.is_empty() {
            bail!("sweep spec needs at least one precision (bits) entry");
        }
        Ok(())
    }

    /// Build a spec from a JSON request object (the `serve` protocol's
    /// `{"cmd":"sweep", ...}` body). Every axis is optional and defaults
    /// to the paper grid; network names resolve through the zoo. All axis
    /// parsing delegates to [`crate::api::codec`], the single set of
    /// parsers shared with [`crate::dse::space::ExploreSpec`].
    ///
    /// Recognized axis keys: `networks` (names), `macs`, `strategies`,
    /// `modes`, `batches`, `fusion_depth` (a number or an array of
    /// depths), `bits` (a `"ifmap:weight:psum:ofmap"` precision string or
    /// an array of them), plus the protocol's `cmd`, `workers` and
    /// `protocol`. Unknown keys are rejected so a typo'd axis fails
    /// loudly instead of silently sweeping its full default.
    pub fn from_json(msg: &Json) -> Result<SweepSpec> {
        use crate::api::codec;
        const KNOWN: [&str; 10] = [
            "cmd",
            "networks",
            "macs",
            "strategies",
            "modes",
            "batches",
            "fusion_depth",
            "bits",
            "workers",
            "protocol",
        ];
        codec::reject_unknown_keys(msg, &KNOWN, "sweep")?;
        let mut spec = SweepSpec::paper_grid();
        if let Some(nets) = msg.get("networks") {
            spec.networks = codec::networks_axis(nets)?;
        }
        if let Some(macs) = msg.get("macs") {
            spec.mac_budgets = codec::usize_axis(macs, "macs", "non-negative")?;
        }
        if let Some(strats) = msg.get("strategies") {
            spec.strategies = codec::strategies_axis(strats)?;
        }
        if let Some(modes) = msg.get("modes") {
            spec.modes = codec::modes_axis(modes)?;
        }
        if let Some(batches) = msg.get("batches") {
            spec.batch_sizes = codec::usize_axis(batches, "batches", "positive")?;
        }
        if let Some(fusion) = msg.get("fusion_depth") {
            spec.fusion_depths = codec::fusion_axis(fusion)?;
        }
        if let Some(bits) = msg.get("bits") {
            spec.datatypes = codec::bits_axis(bits)?;
        }
        spec.validate()?;
        Ok(spec)
    }
}

impl Default for SweepSpec {
    fn default() -> SweepSpec {
        SweepSpec::paper_grid()
    }
}

/// One evaluated grid cell: a whole network under one scenario.
#[derive(Clone, Debug)]
pub struct GridCell {
    /// Network name (zoo spelling).
    pub network: String,
    /// MAC budget `P` this cell was evaluated under.
    pub p_macs: usize,
    /// Partitioning strategy.
    pub strategy: Strategy,
    /// Memory-controller mode.
    pub mode: ControllerMode,
    /// Batch size (amortizes weights only).
    pub batch: usize,
    /// Fusion depth (1 = the paper's unfused per-layer model).
    pub fusion_depth: usize,
    /// Per-tensor precision this cell was evaluated under (the default
    /// uniform 8-bit precision keeps the cell's JSONL byte-identical to
    /// the element-count format).
    pub dt: DataTypes,
    /// Input-activation traffic, activations (eq. 2 summed over layers;
    /// at fusion depth > 1, summed over chain inputs only).
    pub input: f64,
    /// Output/psum traffic, activations (eq. 3 or active variant, summed;
    /// at fusion depth > 1, summed over chain outputs only).
    pub output: f64,
    /// Input traffic in bytes (eq. 2 elements × ifmap width).
    pub input_bytes: f64,
    /// Intermediate psum crossings in bytes (psum width).
    pub psum_bytes: f64,
    /// Final output writes in bytes (ofmap width).
    pub ofmap_bytes: f64,
    /// Conv weight parameters of the network (amortize across `batch`).
    pub weights: u64,
    /// Table III floor for this network, activations.
    pub min_bw: f64,
    /// Table III floor in bytes (inputs at ifmap width + outputs at
    /// ofmap width; full residency spills no psums).
    pub min_bytes: f64,
}

impl GridCell {
    /// Total activation traffic (the paper's tabulated quantity, raw
    /// activations). Exactly equals
    /// [`network_bandwidth`](super::sweep::network_bandwidth)`.total()`
    /// for the same scenario — all terms are exact integer-valued `f64`s.
    pub fn total(&self) -> f64 {
        self.input + self.output
    }

    /// Weight traffic per image at this cell's batch size.
    pub fn weights_per_image(&self) -> f64 {
        self.weights as f64 / self.batch as f64
    }

    /// Activations + amortized weights per image (the extension metric).
    pub fn per_image_traffic(&self) -> f64 {
        super::extensions::per_image_traffic(self.total(), self.weights, self.batch)
    }

    /// Total activation **bytes** on the wire — the byte-currency
    /// analogue of [`GridCell::total`] (weights excluded, as in the
    /// paper's tables). Equals `total()` under the default precision.
    pub fn total_bytes(&self) -> f64 {
        self.input_bytes + self.psum_bytes + self.ofmap_bytes
    }

    /// Weight bytes per image at this cell's batch size — the byte
    /// analogue of [`GridCell::weights_per_image`] (weights amortize
    /// across a batch; activations do not).
    pub fn weight_bytes(&self) -> f64 {
        self.weights_per_image() * self.dt.weight_bytes()
    }

    /// Human/filterable cell key, e.g. `AlexNet|P2048|optimal|active|b1`
    /// (fused cells append `|fused2`, non-default precisions `|8:8:32:8`).
    pub fn key(&self) -> String {
        let mut key = format!(
            "{}|P{}|{}|{}|b{}",
            self.network,
            self.p_macs,
            self.strategy.slug(),
            self.mode.label(),
            self.batch
        );
        if self.fusion_depth > 1 {
            key.push_str(&format!("|fused{}", self.fusion_depth));
        }
        if !self.dt.is_default() {
            key.push_str(&format!("|{}", self.dt.label()));
        }
        key
    }

    /// Stable JSON encoding (object keys sort alphabetically, numbers are
    /// exact integers where integral) — one JSONL record. The
    /// `fusion_depth` key appears only on fused cells (depth > 1), and
    /// the byte-weighted keys (`bits`, `input_bytes`, `psum_bytes`,
    /// `ofmap_bytes`, `total_bytes`, `weight_bytes`, `min_bytes`) only
    /// when a non-default precision was requested — so default sweeps
    /// stay byte-identical to the pre-precision format.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("network", Json::Str(self.network.clone())),
            ("p_macs", Json::Num(self.p_macs as f64)),
            ("strategy", Json::Str(self.strategy.slug().to_string())),
            ("mode", Json::Str(self.mode.label().to_string())),
            ("batch", Json::Num(self.batch as f64)),
            ("input", Json::Num(self.input)),
            ("output", Json::Num(self.output)),
            ("total", Json::Num(self.total())),
            ("total_mact", Json::Num(self.total() / 1.0e6)),
            ("weights_per_image", Json::Num(self.weights_per_image())),
            ("min_bw", Json::Num(self.min_bw)),
        ];
        if self.fusion_depth > 1 {
            pairs.push(("fusion_depth", Json::Num(self.fusion_depth as f64)));
        }
        if !self.dt.is_default() {
            pairs.push(("bits", Json::Str(self.dt.label())));
            pairs.push(("input_bytes", Json::Num(self.input_bytes)));
            pairs.push(("psum_bytes", Json::Num(self.psum_bytes)));
            pairs.push(("ofmap_bytes", Json::Num(self.ofmap_bytes)));
            pairs.push(("total_bytes", Json::Num(self.total_bytes())));
            pairs.push(("weight_bytes", Json::Num(self.weight_bytes())));
            pairs.push(("min_bytes", Json::Num(self.min_bytes)));
        }
        Json::obj(pairs)
    }
}

/// The outcome of running a [`SweepSpec`]: cells in spec enumeration order
/// (networks, then budgets, then strategies, then modes, then batches,
/// then fusion depths).
#[derive(Clone, Debug)]
pub struct GridResult {
    /// Evaluated cells in spec enumeration order.
    pub cells: Vec<GridCell>,
}

impl GridResult {
    /// Look up one cell (the first match in enumeration order — i.e. the
    /// lowest fusion depth when a spec sweeps several).
    pub fn find(
        &self,
        network: &str,
        p_macs: usize,
        strategy: Strategy,
        mode: ControllerMode,
        batch: usize,
    ) -> Option<&GridCell> {
        self.cells.iter().find(|c| {
            c.network == network
                && c.p_macs == p_macs
                && c.strategy == strategy
                && c.mode == mode
                && c.batch == batch
        })
    }

    /// The whole grid as JSON-lines text (one object per cell, trailing
    /// newline). Byte-identical across worker counts.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for cell in &self.cells {
            out.push_str(&cell.to_json().to_string());
            out.push('\n');
        }
        out
    }

    /// Number of cells.
    pub fn len(&self) -> usize {
        self.cells.len()
    }

    /// Whether the grid has no cells.
    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }
}

/// Per-layer outcome, memoized by shape.
#[derive(Clone, Copy, Debug)]
pub struct LayerEval {
    /// The `(m, n)` tile the strategy chose (byte-weighted for the
    /// `optimal`/`search` strategies under a non-default precision).
    pub partition: Partition,
    /// Element traffic of that tile (eqs. 2–3).
    pub bandwidth: Bandwidth,
    /// Byte traffic of the same tile under the evaluation's precision.
    pub bytes: ByteBandwidth,
}

/// Memo key: the layer's *shape* (name erased) plus the scenario knobs
/// that determine its partition and bandwidth.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct ShapeKey {
    wi: usize,
    hi: usize,
    m: usize,
    n: usize,
    k: usize,
    stride: usize,
    pad: usize,
    groups: usize,
    p_macs: usize,
    strategy: Strategy,
    mode: ControllerMode,
    dt: DataTypes,
}

impl ShapeKey {
    fn new(
        layer: &ConvLayer,
        p_macs: usize,
        strategy: Strategy,
        mode: ControllerMode,
        dt: DataTypes,
    ) -> ShapeKey {
        ShapeKey {
            wi: layer.wi,
            hi: layer.hi,
            m: layer.m,
            n: layer.n,
            k: layer.k,
            stride: layer.stride,
            pad: layer.pad,
            groups: layer.groups,
            p_macs,
            strategy,
            mode,
            dt,
        }
    }
}

/// Upper bound on memoized layer evaluations. Long-lived engines (the
/// `serve` process) see arbitrary client-chosen `p_macs` values, so the
/// cache is epoch-flushed at this size instead of growing without limit.
/// Results are unaffected — a flush only costs recomputation.
const CACHE_CAP: usize = 1 << 18;

/// The sweep executor: a shared shape-memo cache plus a parallel runner.
///
/// Create one engine and reuse it across runs — the layer cache persists,
/// so later (overlapping) specs get answered mostly from memory (bounded
/// by `CACHE_CAP` entries). The engine is `Sync`; `run` fans cells out
/// over [`parallel_map`] worker threads that share the cache.
pub struct GridEngine {
    cache: Mutex<HashMap<ShapeKey, LayerEval>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl GridEngine {
    /// A fresh engine with an empty layer-shape cache.
    pub fn new() -> GridEngine {
        GridEngine {
            cache: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Evaluate one layer under one scenario at the default precision —
    /// see [`GridEngine::layer_eval_dt`].
    pub fn layer_eval(
        &self,
        layer: &ConvLayer,
        p_macs: usize,
        strategy: Strategy,
        mode: ControllerMode,
    ) -> LayerEval {
        self.layer_eval_dt(layer, p_macs, strategy, mode, &DataTypes::default())
    }

    /// Evaluate one layer under one scenario, through the shape cache.
    ///
    /// Two layers with identical shapes (any names, any networks) share
    /// one computation. A racing double-compute stores the same value, so
    /// results never depend on thread interleaving. Under the default
    /// precision the partition comes from the legacy element model
    /// (byte-identical goldens); non-default precisions route the
    /// `optimal`/`search` strategies through the byte-weighted optimum.
    pub fn layer_eval_dt(
        &self,
        layer: &ConvLayer,
        p_macs: usize,
        strategy: Strategy,
        mode: ControllerMode,
        dt: &DataTypes,
    ) -> LayerEval {
        let key = ShapeKey::new(layer, p_macs, strategy, mode, *dt);
        if let Some(hit) = self.cache.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return *hit;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let partition = if dt.is_default() {
            partition_layer(layer, p_macs, strategy, mode)
        } else {
            partition_layer_bytes(layer, p_macs, strategy, mode, dt)
        };
        let bandwidth = layer_bandwidth(layer, partition.m, partition.n, mode);
        let bytes = layer_bandwidth_bytes(layer, partition.m, partition.n, mode, dt);
        let eval = LayerEval { partition, bandwidth, bytes };
        let mut cache = self.cache.lock().unwrap();
        if cache.len() >= CACHE_CAP {
            cache.clear();
        }
        cache.insert(key, eval);
        eval
    }

    /// Evaluate one grid cell (a whole network under one unfused
    /// scenario). Equivalent to [`GridEngine::cell_fused`] at depth 1.
    pub fn cell(
        &self,
        net: &Network,
        p_macs: usize,
        strategy: Strategy,
        mode: ControllerMode,
        batch: usize,
    ) -> GridCell {
        self.cell_fused(net, p_macs, strategy, mode, batch, 1)
    }

    /// Evaluate one grid cell with layers fused in chains of up to
    /// `fusion_depth`, at the default precision — see
    /// [`GridEngine::cell_fused_dt`].
    pub fn cell_fused(
        &self,
        net: &Network,
        p_macs: usize,
        strategy: Strategy,
        mode: ControllerMode,
        batch: usize,
        fusion_depth: usize,
    ) -> GridCell {
        self.cell_fused_dt(net, p_macs, strategy, mode, batch, fusion_depth, &DataTypes::default())
    }

    /// Evaluate one grid cell with layers fused in chains of up to
    /// `fusion_depth`, under precision `dt`. Singleton chains go through
    /// the per-layer eq. 2–3 model (the shape memo cache), so depth 1
    /// *is* the unfused cell; longer chains charge only the chain input,
    /// the chain output and the (unstriped, so once-loaded) weights — see
    /// [`crate::analytics::fusion`]. Element and byte traffic are
    /// accumulated for the *same* partitions, so a cell is one design
    /// described in two currencies.
    #[allow(clippy::too_many_arguments)]
    pub fn cell_fused_dt(
        &self,
        net: &Network,
        p_macs: usize,
        strategy: Strategy,
        mode: ControllerMode,
        batch: usize,
        fusion_depth: usize,
        dt: &DataTypes,
    ) -> GridCell {
        let mut input = 0.0;
        let mut output = 0.0;
        let mut input_bytes = 0.0;
        let mut psum_bytes = 0.0;
        let mut ofmap_bytes = 0.0;
        for range in fusion::chains(net, fusion_depth) {
            let layers = &net.layers[range];
            if layers.len() == 1 {
                let eval = self.layer_eval_dt(&layers[0], p_macs, strategy, mode, dt);
                input += eval.bandwidth.input;
                output += eval.bandwidth.output;
                input_bytes += eval.bytes.input;
                psum_bytes += eval.bytes.psum;
                ofmap_bytes += eval.bytes.ofmap;
            } else {
                let parts: Vec<Partition> = layers
                    .iter()
                    .map(|l| self.layer_eval_dt(l, p_macs, strategy, mode, dt).partition)
                    .collect();
                let ho = layers.last().unwrap().ho();
                let fused = fusion::chain_bandwidth(layers, &parts, ho, mode);
                input += fused.input;
                output += fused.output;
                let fused_b = fusion::chain_bandwidth_bytes(layers, &parts, ho, mode, dt);
                input_bytes += fused_b.input;
                psum_bytes += fused_b.psum;
                ofmap_bytes += fused_b.ofmap;
            }
        }
        GridCell {
            network: net.name.clone(),
            p_macs,
            strategy,
            mode,
            batch,
            fusion_depth,
            dt: *dt,
            input,
            output,
            input_bytes,
            psum_bytes,
            ofmap_bytes,
            weights: net.total_weights(),
            min_bw: net.min_bandwidth() as f64,
            min_bytes: net.min_bandwidth_bytes(dt),
        }
    }

    /// Run a spec with the default worker count.
    pub fn run(&self, spec: &SweepSpec) -> GridResult {
        self.run_with_workers(spec, default_workers())
    }

    /// Run a spec over `workers` threads. Output order and content are
    /// independent of `workers`.
    ///
    /// # Panics
    ///
    /// Panics if the spec fails [`SweepSpec::validate`] (empty axis, zero
    /// MAC budget or batch size) — invalid specs would otherwise produce
    /// division-by-zero artifacts in the JSONL stream.
    pub fn run_with_workers(&self, spec: &SweepSpec, workers: usize) -> GridResult {
        spec.validate().expect("invalid sweep spec");
        type Job = (usize, usize, Strategy, ControllerMode, usize, usize, DataTypes);
        let mut jobs: Vec<Job> = Vec::new();
        for ni in 0..spec.networks.len() {
            for &p in &spec.mac_budgets {
                for &s in &spec.strategies {
                    for &mode in &spec.modes {
                        for &b in &spec.batch_sizes {
                            for &f in &spec.fusion_depths {
                                for &dt in &spec.datatypes {
                                    jobs.push((ni, p, s, mode, b, f, dt));
                                }
                            }
                        }
                    }
                }
            }
        }
        // Per-cell wall time feeds the host-side observability registry
        // (`grid_cell_eval_us`); the cells themselves stay byte-identical.
        let cell_hist = crate::obs::registry::global().histogram("grid_cell_eval_us");
        let cells = parallel_map(&jobs, workers.max(1), |&(ni, p, s, mode, b, f, dt)| {
            let started = std::time::Instant::now();
            let cell = self.cell_fused_dt(&spec.networks[ni], p, s, mode, b, f, &dt);
            let us = started.elapsed().as_micros() as u64;
            cell_hist.record(us);
            crate::obs::span::global().record_us(crate::obs::span::stage::GRID_CELL, us);
            cell
        });
        GridResult { cells }
    }

    /// `(hits, misses)` of the layer-shape cache since construction.
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }
}

impl Default for GridEngine {
    fn default() -> GridEngine {
        GridEngine::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::sweep::network_bandwidth;
    use crate::models::zoo;

    #[test]
    fn cell_matches_direct_computation() {
        let engine = GridEngine::new();
        let net = zoo::alexnet();
        for &p in &[512usize, 2048] {
            for mode in ControllerMode::ALL {
                let cell = engine.cell(&net, p, Strategy::Optimal, mode, 1);
                let direct = network_bandwidth(&net, p, Strategy::Optimal, mode);
                assert_eq!(cell.total(), direct.total());
                let di: f64 = direct.layers.iter().map(|l| l.bandwidth.input).sum();
                assert_eq!(cell.input, di);
            }
        }
    }

    #[test]
    fn shape_cache_collapses_repeats() {
        let engine = GridEngine::new();
        let spec = SweepSpec::new(vec![zoo::vgg16()])
            .with_macs(vec![2048])
            .with_strategies(vec![Strategy::Optimal])
            .with_modes(vec![ControllerMode::Passive]);
        let grid = engine.run_with_workers(&spec, 1);
        assert_eq!(grid.len(), 1);
        let (_, misses) = engine.cache_stats();
        // VGG-16 has 13 conv layers but only 9 distinct shapes.
        assert!(
            misses < zoo::vgg16().layers.len() as u64,
            "no shape sharing: {misses} misses"
        );
        // A second identical run is answered entirely from cache.
        engine.run_with_workers(&spec, 1);
        let (hits2, misses2) = engine.cache_stats();
        assert_eq!(misses2, misses);
        assert!(hits2 > 0);
    }

    #[test]
    fn batch_amortizes_weights_only() {
        let engine = GridEngine::new();
        let net = zoo::alexnet();
        let b1 = engine.cell(&net, 2048, Strategy::Optimal, ControllerMode::Passive, 1);
        let b8 = engine.cell(&net, 2048, Strategy::Optimal, ControllerMode::Passive, 8);
        assert_eq!(b1.total(), b8.total());
        assert_eq!(b1.weights_per_image(), 8.0 * b8.weights_per_image());
        assert!(b8.per_image_traffic() < b1.per_image_traffic());
        // weight_bytes is the byte analogue of weights_per_image, so it
        // amortizes across the batch the same way.
        assert_eq!(b1.weight_bytes(), 8.0 * b8.weight_bytes());
    }

    #[test]
    fn run_orders_cells_deterministically() {
        let spec = SweepSpec::new(vec![zoo::alexnet()])
            .with_macs(vec![512, 2048])
            .with_strategies(vec![Strategy::MaxInput, Strategy::Optimal])
            .with_modes(vec![ControllerMode::Passive]);
        let grid = GridEngine::new().run_with_workers(&spec, 4);
        let keys: Vec<String> = grid.cells.iter().map(|c| c.key()).collect();
        assert_eq!(
            keys,
            vec![
                "AlexNet|P512|max-input|passive|b1",
                "AlexNet|P512|optimal|passive|b1",
                "AlexNet|P2048|max-input|passive|b1",
                "AlexNet|P2048|optimal|passive|b1",
            ]
        );
        let find = |p| grid.find("AlexNet", p, Strategy::Optimal, ControllerMode::Passive, 1);
        assert!(find(2048).is_some());
        assert!(find(4096).is_none());
    }

    #[test]
    fn spec_from_json_defaults_and_overrides() {
        let msg = Json::parse(
            r#"{"cmd":"sweep","networks":["AlexNet","resnet18"],"macs":[512,1024],
                "strategies":["optimal","max-input"],"modes":["active"],"batches":[1,8]}"#,
        )
        .unwrap();
        let spec = SweepSpec::from_json(&msg).unwrap();
        assert_eq!(spec.networks.len(), 2);
        assert_eq!(spec.networks[1].name, "ResNet-18");
        assert_eq!(spec.mac_budgets, vec![512, 1024]);
        assert_eq!(spec.strategies, vec![Strategy::Optimal, Strategy::MaxInput]);
        assert_eq!(spec.modes, vec![ControllerMode::Active]);
        assert_eq!(spec.batch_sizes, vec![1, 8]);
        assert_eq!(spec.cell_count(), 2 * 2 * 2 * 2);

        let defaults = SweepSpec::from_json(&Json::parse(r#"{"cmd":"sweep"}"#).unwrap()).unwrap();
        assert_eq!(defaults.cell_count(), 8 * 6 * 4 * 2);
    }

    #[test]
    fn fused_cells_save_traffic_and_tag_their_records() {
        let engine = GridEngine::new();
        let net = zoo::alexnet();
        let unfused = engine.cell(&net, 512, Strategy::Optimal, ControllerMode::Passive, 1);
        let fused = engine.cell_fused(&net, 512, Strategy::Optimal, ControllerMode::Passive, 1, 2);
        // conv3->conv4 fuse: the intermediate's write + re-read vanish.
        assert!(fused.total() < unfused.total());
        assert_eq!(unfused.fusion_depth, 1);
        assert_eq!(fused.fusion_depth, 2);
        assert_eq!(fused.key(), "AlexNet|P512|optimal|passive|b1|fused2");
        assert!(!unfused.key().contains("fused"));
        // depth-1 JSONL carries no fusion key; fused records do.
        assert!(unfused.to_json().get("fusion_depth").is_none());
        assert_eq!(fused.to_json().get("fusion_depth").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn fusion_axis_sweeps_and_orders() {
        let spec = SweepSpec::new(vec![zoo::alexnet()])
            .with_macs(vec![512])
            .with_strategies(vec![Strategy::Optimal])
            .with_modes(vec![ControllerMode::Passive])
            .with_fusion(vec![1, 2]);
        assert_eq!(spec.cell_count(), 2);
        let engine = GridEngine::new();
        let a = engine.run_with_workers(&spec, 1);
        let b = engine.run_with_workers(&spec, 4);
        assert_eq!(a.to_jsonl(), b.to_jsonl());
        assert_eq!(a.cells[0].fusion_depth, 1);
        assert_eq!(a.cells[1].fusion_depth, 2);
        assert!(a.cells[1].total() < a.cells[0].total());
    }

    #[test]
    fn spec_from_json_fusion_depth() {
        let one =
            SweepSpec::from_json(&Json::parse(r#"{"cmd":"sweep","fusion_depth":2}"#).unwrap())
                .unwrap();
        assert_eq!(one.fusion_depths, vec![2]);
        let many =
            SweepSpec::from_json(&Json::parse(r#"{"cmd":"sweep","fusion_depth":[1,2,3]}"#).unwrap())
                .unwrap();
        assert_eq!(many.fusion_depths, vec![1, 2, 3]);
        for bad in [
            r#"{"cmd":"sweep","fusion_depth":0}"#,
            r#"{"cmd":"sweep","fusion_depth":[0]}"#,
            r#"{"cmd":"sweep","fusion_depth":[]}"#,
            r#"{"cmd":"sweep","fusion_depth":"two"}"#,
        ] {
            assert!(SweepSpec::from_json(&Json::parse(bad).unwrap()).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn bits_axis_sweeps_and_tags_records() {
        let dt = DataTypes::parse("8:8:32:8").unwrap();
        let spec = SweepSpec::new(vec![zoo::alexnet()])
            .with_macs(vec![512])
            .with_strategies(vec![Strategy::MaxInput])
            .with_modes(vec![ControllerMode::Passive])
            .with_datatypes(vec![DataTypes::default(), dt]);
        assert_eq!(spec.cell_count(), 2);
        let engine = GridEngine::new();
        let a = engine.run_with_workers(&spec, 1);
        let b = engine.run_with_workers(&spec, 4);
        assert_eq!(a.to_jsonl(), b.to_jsonl());
        // default cell: no byte keys; MaxInput partition is width-agnostic
        // so element traffic matches across precisions.
        let (def, wide) = (&a.cells[0], &a.cells[1]);
        assert!(def.to_json().get("bits").is_none());
        assert_eq!(wide.to_json().get("bits").unwrap().as_str(), Some("8:8:32:8"));
        assert_eq!(def.total(), wide.total());
        assert_eq!(def.total_bytes(), def.total());
        assert!(wide.total_bytes() > wide.total(), "4-byte psums must cost more bytes");
        assert!(wide.key().ends_with("|8:8:32:8"), "{}", wide.key());
        assert!(!def.key().contains(':'));
    }

    #[test]
    fn spec_from_json_bits() {
        let one = SweepSpec::from_json(
            &Json::parse(r#"{"cmd":"sweep","bits":"8:8:32:8"}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(one.datatypes, vec![DataTypes::parse("8:8:32:8").unwrap()]);
        let many = SweepSpec::from_json(
            &Json::parse(r#"{"cmd":"sweep","bits":["8:8:8:8","int8"]}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(
            many.datatypes,
            vec![DataTypes::default(), DataTypes::parse("8:8:32:8").unwrap()]
        );
        for bad in [
            r#"{"cmd":"sweep","bits":"8:8:32"}"#,
            r#"{"cmd":"sweep","bits":[]}"#,
            r#"{"cmd":"sweep","bits":[7]}"#,
            r#"{"cmd":"sweep","bits":"0:8:8:8"}"#,
        ] {
            assert!(SweepSpec::from_json(&Json::parse(bad).unwrap()).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn byte_partitioning_differs_only_for_optimizing_strategies() {
        // Non-default precision re-derives optimal/search partitions
        // under byte weighting; the fixed heuristics are unchanged.
        let engine = GridEngine::new();
        let net = zoo::alexnet();
        let dt = DataTypes::parse("8:8:32:8").unwrap();
        let conv3 = net.layer("conv3").unwrap();
        let e = engine.layer_eval(conv3, 512, Strategy::Optimal, ControllerMode::Passive);
        let b = engine.layer_eval_dt(conv3, 512, Strategy::Optimal, ControllerMode::Passive, &dt);
        assert_eq!(e.partition.m, 12);
        assert_eq!(b.partition.m, 24);
        let eh = engine.layer_eval(conv3, 512, Strategy::MaxInput, ControllerMode::Passive);
        let bh = engine.layer_eval_dt(conv3, 512, Strategy::MaxInput, ControllerMode::Passive, &dt);
        assert_eq!(eh.partition, bh.partition);
        // byte-optimal cells can only improve the byte total
        let ecell = engine.cell(&net, 512, Strategy::Optimal, ControllerMode::Passive, 1);
        let bcell =
            engine.cell_fused_dt(&net, 512, Strategy::Optimal, ControllerMode::Passive, 1, 1, &dt);
        // element-partitioned byte cost: reprice the element cells
        let mut elem_part_bytes = 0.0;
        let passive = ControllerMode::Passive;
        for l in &net.layers {
            let ev = engine.layer_eval(l, 512, Strategy::Optimal, passive);
            elem_part_bytes +=
                layer_bandwidth_bytes(l, ev.partition.m, ev.partition.n, passive, &dt)
                    .activations();
        }
        assert!(bcell.total_bytes() <= elem_part_bytes + 1e-9);
        assert_eq!(ecell.total_bytes(), ecell.total());
    }

    #[test]
    fn spec_from_json_rejects_bad_input() {
        for bad in [
            r#"{"networks":["NoSuchNet"]}"#,
            r#"{"macs":[0]}"#,
            r#"{"macs":[]}"#,
            r#"{"strategies":["voodoo"]}"#,
            r#"{"modes":["quantum"]}"#,
            r#"{"batches":[0]}"#,
            r#"{"networks":"AlexNet"}"#,
            r#"{"mac":[512]}"#,
            r#"{"cmd":"sweep","strategy":["optimal"]}"#,
        ] {
            let msg = Json::parse(bad).unwrap();
            assert!(SweepSpec::from_json(&msg).is_err(), "accepted {bad}");
        }
    }

    #[test]
    #[should_panic(expected = "invalid sweep spec")]
    fn run_rejects_invalid_spec() {
        let spec = SweepSpec::new(vec![zoo::alexnet()]).with_batches(vec![0]);
        GridEngine::new().run_with_workers(&spec, 1);
    }

    #[test]
    fn jsonl_is_stable_and_parseable() {
        let spec = SweepSpec::new(vec![zoo::alexnet()])
            .with_macs(vec![512])
            .with_strategies(vec![Strategy::Optimal])
            .with_modes(vec![ControllerMode::Passive]);
        let engine = GridEngine::new();
        let a = engine.run_with_workers(&spec, 1).to_jsonl();
        let b = engine.run_with_workers(&spec, 3).to_jsonl();
        assert_eq!(a, b);
        for line in a.lines() {
            let v = Json::parse(line).unwrap();
            assert!(v.get("network").is_some());
            assert!(v.get("total").unwrap().as_f64().unwrap() > 0.0);
        }
    }
}
