//! The four partitioning strategies compared in Table I, plus the
//! exhaustive divisor search as an ablation fifth.
//!
//! All strategies pick `(m, n)` per group under the MAC constraint
//! `K^2 * m * n <= P` (eq. 1). Channel counts are snapped to divisors of
//! `M`/`N` so iteration counts are integral (the paper's adaptation rule).

use crate::models::{ConvLayer, DataTypes};
use crate::util::mathx::divisors;

use super::bandwidth::ControllerMode;
use super::optimizer;

/// A per-iteration tile: `m` input maps x `n` output maps.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Partition {
    /// Input maps per iteration.
    pub m: usize,
    /// Output maps per iteration.
    pub n: usize,
}

impl Partition {
    /// MACs used per cycle by this tile for kernel size `k`.
    pub fn macs_used(&self, k: usize) -> usize {
        k * k * self.m * self.n
    }
}

/// Partitioning strategy (Table I columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Column 1: maximize input maps per iteration (fewest psum passes).
    MaxInput,
    /// Column 2: maximize output maps per iteration (fewest input passes).
    MaxOutput,
    /// Column 3: split the MAC budget evenly: `m ~= n ~= sqrt(P)/K`.
    EqualMacs,
    /// Column 4 ("This Work"): eq. (7) + integer adaptation.
    Optimal,
    /// Ablation: exhaustive discrete optimum over divisor pairs.
    OptimalSearch,
}

impl Strategy {
    /// The four strategies of Table I, in column order.
    pub const TABLE1: [Strategy; 4] =
        [Strategy::MaxInput, Strategy::MaxOutput, Strategy::EqualMacs, Strategy::Optimal];

    /// Human-facing name (Table I column header).
    pub fn label(&self) -> &'static str {
        match self {
            Strategy::MaxInput => "Max Input",
            Strategy::MaxOutput => "Max Output",
            Strategy::EqualMacs => "Equal MACs",
            Strategy::Optimal => "This Work",
            Strategy::OptimalSearch => "Search",
        }
    }

    /// Machine-friendly identifier that round-trips through
    /// [`crate::config::accel::parse_strategy`] — used by the sweep
    /// engine's JSONL output and the `serve` protocol.
    pub fn slug(&self) -> &'static str {
        match self {
            Strategy::MaxInput => "max-input",
            Strategy::MaxOutput => "max-output",
            Strategy::EqualMacs => "equal-macs",
            Strategy::Optimal => "optimal",
            Strategy::OptimalSearch => "search",
        }
    }
}

/// Largest divisor of `x` that is `<= cap` (falls back to 1).
fn largest_divisor_within(x: usize, cap: usize) -> usize {
    divisors(x).into_iter().filter(|&d| d <= cap).max().unwrap_or(1)
}

/// Choose the per-group tile `(m, n)` for `layer` under `p_macs`.
///
/// `mode` matters only for [`Strategy::Optimal`]/[`Strategy::OptimalSearch`]
/// (the optimum shifts when psum read-backs are free); the fixed heuristics
/// are controller-agnostic.
pub fn partition_layer(
    layer: &ConvLayer,
    p_macs: usize,
    strategy: Strategy,
    mode: ControllerMode,
) -> Partition {
    let mg = layer.m_per_group();
    let ng = layer.n_per_group();
    let k2 = layer.k * layer.k;
    let budget = (p_macs / k2).max(1); // max m*n

    match strategy {
        Strategy::MaxInput => {
            let m = largest_divisor_within(mg, budget);
            let n = largest_divisor_within(ng, budget / m);
            Partition { m, n }
        }
        Strategy::MaxOutput => {
            let n = largest_divisor_within(ng, budget);
            let m = largest_divisor_within(mg, budget / n);
            Partition { m, n }
        }
        Strategy::EqualMacs => {
            // Split the budget evenly: both sides get sqrt(P)/K.
            let side = (budget as f64).sqrt();
            let m = largest_divisor_within(mg, side.floor().max(1.0) as usize);
            // n may take up the slack m left on the table.
            let n = largest_divisor_within(ng, budget / m);
            Partition { m, n }
        }
        Strategy::Optimal => optimizer::optimal_partition(layer, p_macs, mode),
        Strategy::OptimalSearch => optimizer::search_partition(layer, p_macs, mode),
    }
}

/// Precision-aware [`partition_layer`]: the fixed heuristics are
/// width-agnostic (they never price traffic), while
/// [`Strategy::Optimal`]/[`Strategy::OptimalSearch`] optimize the
/// **byte** objective — the optimum shifts up by `sqrt(psum/ifmap)` when
/// psums are wider (see
/// [`optimizer::optimal_m_real_bytes`]). Under a uniform `dt` this is
/// exactly [`partition_layer`] for every strategy.
pub fn partition_layer_bytes(
    layer: &ConvLayer,
    p_macs: usize,
    strategy: Strategy,
    mode: ControllerMode,
    dt: &DataTypes,
) -> Partition {
    match strategy {
        Strategy::Optimal => optimizer::optimal_partition_bytes(layer, p_macs, mode, dt),
        Strategy::OptimalSearch => optimizer::search_partition_bytes(layer, p_macs, mode, dt),
        _ => partition_layer(layer, p_macs, strategy, mode),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::bandwidth::layer_bandwidth;
    use crate::models::ConvLayer;

    fn conv2() -> ConvLayer {
        // AlexNet conv2: 27x27, 64 -> 192, k5/p2
        ConvLayer::new("conv2", 27, 27, 64, 192, 5, 1, 2)
    }

    #[test]
    fn all_strategies_satisfy_constraint() {
        for net in crate::models::zoo::paper_networks() {
            for layer in &net.layers {
                for p in [512usize, 2048, 16384] {
                    for s in [
                        Strategy::MaxInput,
                        Strategy::MaxOutput,
                        Strategy::EqualMacs,
                        Strategy::Optimal,
                        Strategy::OptimalSearch,
                    ] {
                        let part = partition_layer(layer, p, s, ControllerMode::Passive);
                        let k2 = layer.k * layer.k;
                        // feasible unless even the unit tile exceeds P
                        if k2 <= p {
                            assert!(
                                part.macs_used(layer.k) <= p,
                                "{} {:?} P={p}: {:?} uses {} MACs",
                                layer.name,
                                s,
                                part,
                                part.macs_used(layer.k)
                            );
                        }
                        assert!(part.m >= 1 && part.m <= layer.m_per_group());
                        assert!(part.n >= 1 && part.n <= layer.n_per_group());
                        // m always snaps to a divisor of M (integral psum
                        // passes); n is floor-adapted for the optimal pair.
                        assert_eq!(layer.m_per_group() % part.m, 0);
                        if !matches!(s, Strategy::Optimal | Strategy::OptimalSearch) {
                            assert_eq!(layer.n_per_group() % part.n, 0);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn slugs_round_trip_through_parser() {
        for s in [
            Strategy::MaxInput,
            Strategy::MaxOutput,
            Strategy::EqualMacs,
            Strategy::Optimal,
            Strategy::OptimalSearch,
        ] {
            assert_eq!(crate::config::accel::parse_strategy(s.slug()).unwrap(), s);
        }
    }

    #[test]
    fn max_input_prefers_m() {
        let p = partition_layer(&conv2(), 512, Strategy::MaxInput, ControllerMode::Passive);
        // budget = 512/25 = 20 -> largest divisor of 64 <= 20 is 16
        assert_eq!(p, Partition { m: 16, n: 1 });
    }

    #[test]
    fn max_output_prefers_n() {
        let p = partition_layer(&conv2(), 512, Strategy::MaxOutput, ControllerMode::Passive);
        // largest divisor of 192 <= 20 is 16; then m budget 20/16 = 1
        assert_eq!(p, Partition { m: 1, n: 16 });
    }

    #[test]
    fn equal_macs_splits() {
        let p = partition_layer(&conv2(), 512, Strategy::EqualMacs, ControllerMode::Passive);
        // sqrt(20) = 4.47 -> m = 4; n budget = 20/4 = 5 -> largest div of 192 <= 5 is 4
        assert_eq!(p, Partition { m: 4, n: 4 });
    }

    #[test]
    fn optimal_no_worse_than_table1_heuristics() {
        // The paper's central claim (Table I): "This Work" <= the other
        // three, per layer and hence per network. Verify per-layer across
        // the zoo at the three Table I budgets — for the *search* variant,
        // which is guaranteed; the closed form is checked within 1%.
        for net in crate::models::zoo::paper_networks() {
            for layer in &net.layers {
                for p in [512usize, 2048, 16384] {
                    let best = |s: Strategy| {
                        let part = partition_layer(layer, p, s, ControllerMode::Passive);
                        layer_bandwidth(layer, part.m, part.n, ControllerMode::Passive).total()
                    };
                    let opt = best(Strategy::OptimalSearch);
                    for s in [Strategy::MaxInput, Strategy::MaxOutput, Strategy::EqualMacs] {
                        assert!(
                            opt <= best(s) + 1e-6,
                            "{}/{} P={p}: search {opt} > {:?}",
                            net.name,
                            layer.name,
                            s
                        );
                    }
                }
            }
        }
    }
}
