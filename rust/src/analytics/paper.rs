//! The numbers published in the paper (Tables I–III; Fig. 2 is derived
//! from Table II). Used by `psim validate`, the regression tests and
//! EXPERIMENTS.md to quantify how closely this implementation reproduces
//! the published evaluation.
//!
//! Units: million activations per inference image.

/// Paper's network order in every table.
pub const NETWORKS: [&str; 8] = [
    "AlexNet",
    "VGG-16",
    "SqueezeNet",
    "GoogleNet",
    "ResNet-18",
    "ResNet-50",
    "MobileNet",
    "MNASNet",
];

/// Table III: minimum bandwidth (read once + write once).
pub const TABLE3_MIN_BW: [(&str, f64); 8] = [
    ("AlexNet", 0.823),
    ("VGG-16", 20.095),
    ("SqueezeNet", 7.304),
    ("GoogleNet", 7.889),
    ("ResNet-18", 4.666),
    ("ResNet-50", 28.349),
    ("MobileNet", 10.273),
    ("MNASNet", 11.001),
];

/// MAC budgets of Table I columns.
pub const TABLE1_MACS: [usize; 3] = [512, 2048, 16384];

/// Table I rows: per network, for each P in [`TABLE1_MACS`], the four
/// strategies `[max_input, max_output, equal_macs, this_work]`.
pub const TABLE1: [(&str, [[f64; 4]; 3]); 8] = [
    ("AlexNet", [
        [61.9, 94.2, 26.2, 25.1],
        [52.2, 64.6, 13.0, 12.6],
        [9.2, 10.9, 7.3, 4.3],
    ]),
    ("VGG-16", [
        [1170.3, 1938.6, 494.2, 442.5],
        [909.5, 1309.3, 269.3, 237.2],
        [207.1, 241.1, 151.0, 83.5],
    ]),
    ("SqueezeNet", [
        [199.6, 244.8, 65.9, 52.0],
        [53.6, 105.2, 47.4, 26.2],
        [12.6, 17.3, 34.8, 11.1],
    ]),
    ("GoogleNet", [
        [431.7, 313.6, 102.5, 93.5],
        [174.6, 151.6, 61.2, 47.7],
        [23.8, 24.1, 41.6, 17.5],
    ]),
    ("ResNet-18", [
        [281.2, 315.8, 96.1, 88.9],
        [205.0, 191.6, 50.9, 46.8],
        [35.1, 31.7, 26.9, 16.0],
    ]),
    ("ResNet-50", [
        [5245.2, 5770.4, 1059.2, 952.6],
        [2909.0, 2830.4, 608.6, 479.5],
        [929.8, 682.5, 330.1, 168.5],
    ]),
    ("MobileNet", [
        [215.0, 209.2, 78.5, 68.3],
        [136.8, 116.2, 48.8, 35.0],
        [21.9, 21.0, 34.9, 16.1],
    ]),
    ("MNASNet", [
        [884.4, 1294.1, 405.3, 373.4],
        [722.0, 1030.3, 213.4, 183.0],
        [500.2, 516.3, 101.8, 66.0],
    ]),
];

/// MAC budgets of Table II columns.
pub const TABLE2_MACS: [usize; 6] = [512, 1024, 2048, 4096, 8192, 16384];

/// Table II: per network, passive then active controller bandwidth for
/// each P in [`TABLE2_MACS`] (optimal partitioning per mode).
pub const TABLE2: [(&str, [f64; 6], [f64; 6]); 8] = [
    (
        "AlexNet",
        [25.07, 17.54, 12.56, 8.89, 6.52, 4.32],
        [17.89, 12.62, 8.77, 6.38, 4.55, 3.51],
    ),
    (
        "VGG-16",
        [442.49, 321.79, 237.25, 169.43, 112.14, 83.54],
        [315.33, 225.44, 161.67, 123.36, 89.97, 63.67],
    ),
    (
        "SqueezeNet",
        [51.98, 37.47, 26.22, 20.04, 14.12, 11.10],
        [40.06, 27.35, 20.76, 14.87, 12.61, 9.78],
    ),
    (
        "GoogleNet",
        [93.46, 67.17, 47.65, 35.20, 23.23, 17.51],
        [69.90, 48.37, 35.77, 25.95, 20.63, 14.62],
    ),
    (
        "ResNet-18",
        [88.87, 63.56, 46.79, 32.86, 22.01, 16.02],
        [63.52, 45.53, 32.34, 24.74, 17.81, 12.90],
    ),
    (
        "ResNet-50",
        [952.60, 691.13, 479.50, 349.75, 232.82, 168.46],
        [691.98, 480.49, 346.77, 242.90, 183.09, 121.93],
    ),
    (
        "MobileNet",
        [68.53, 46.74, 35.14, 25.22, 21.00, 16.02],
        [50.90, 39.03, 27.69, 22.66, 17.82, 15.58],
    ),
    (
        "MNASNet",
        [373.41, 264.36, 183.01, 128.27, 92.35, 65.96],
        [258.91, 188.75, 131.06, 94.92, 67.80, 50.40],
    ),
];

/// Paper Table III lookup.
pub fn table3(network: &str) -> Option<f64> {
    TABLE3_MIN_BW.iter().find(|(n, _)| *n == network).map(|(_, v)| *v)
}

/// Paper Table I lookup: (network, P) -> [max_in, max_out, equal, this_work].
pub fn table1(network: &str, p_macs: usize) -> Option<[f64; 4]> {
    let pi = TABLE1_MACS.iter().position(|&p| p == p_macs)?;
    TABLE1.iter().find(|(n, _)| *n == network).map(|(_, rows)| rows[pi])
}

/// Paper Table II lookup: (network, P) -> (passive, active).
pub fn table2(network: &str, p_macs: usize) -> Option<(f64, f64)> {
    let pi = TABLE2_MACS.iter().position(|&p| p == p_macs)?;
    TABLE2.iter().find(|(n, _, _)| *n == network).map(|(_, pa, ac)| (pa[pi], ac[pi]))
}

/// Fig. 2's y-value: percentage saving of active vs passive.
pub fn fig2_saving(network: &str, p_macs: usize) -> Option<f64> {
    table2(network, p_macs).map(|(pa, ac)| (pa - ac) / pa * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookups_cover_all_networks() {
        for n in NETWORKS {
            assert!(table3(n).is_some(), "{n} missing from table3");
            for p in TABLE1_MACS {
                assert!(table1(n, p).is_some(), "{n}/{p} missing from table1");
            }
            for p in TABLE2_MACS {
                assert!(table2(n, p).is_some(), "{n}/{p} missing from table2");
            }
        }
    }

    #[test]
    fn unknown_network_is_none() {
        assert!(table3("LeNet").is_none());
        assert!(table1("LeNet", 512).is_none());
        assert!(table2("LeNet", 512).is_none());
    }

    #[test]
    fn table2_active_below_passive_everywhere() {
        for (_, pa, ac) in TABLE2 {
            for i in 0..6 {
                assert!(ac[i] < pa[i]);
            }
        }
    }

    #[test]
    fn table1_this_work_wins_table() {
        // The paper's headline: column 4 minimal in every cell.
        for (net, rows) in TABLE1 {
            for (pi, row) in rows.iter().enumerate() {
                for s in 0..3 {
                    assert!(
                        row[3] <= row[s],
                        "{net} P={} col{} {} < this-work {}",
                        TABLE1_MACS[pi],
                        s,
                        row[s],
                        row[3]
                    );
                }
            }
        }
    }

    #[test]
    fn fig2_savings_in_paper_band() {
        // Paper: gains 19-42% at small P, 2-38% at 16K.
        for n in NETWORKS {
            let s512 = fig2_saving(n, 512).unwrap();
            assert!((15.0..45.0).contains(&s512), "{n}: {s512}");
            let s16k = fig2_saving(n, 16384).unwrap();
            assert!((1.0..40.0).contains(&s16k), "{n}: {s16k}");
        }
    }

    #[test]
    fn table2_this_work_consistent_with_table1() {
        // Table II passive @ P in {512, 2048, 16384} should equal Table I
        // "This Work" (both are optimal partitioning, passive controller).
        for (net, rows) in TABLE1 {
            for (pi, &p) in TABLE1_MACS.iter().enumerate() {
                let (pa, _) = table2(net, p).unwrap();
                let tw = rows[pi][3];
                assert!(
                    (pa - tw).abs() < 0.06 + tw * 0.01,
                    "{net} P={p}: table2 {pa} vs table1 {tw}"
                );
            }
        }
    }
}
