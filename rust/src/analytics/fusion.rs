//! Inter-layer fusion: a first-order traffic model of *fused layer
//! chains*, extending the paper's per-layer analysis (eqs. 2–3) across
//! layer boundaries.
//!
//! The paper models each convolution in isolation: every intermediate
//! feature map is written to SRAM over the interconnect and read back by
//! the next layer. If `d` consecutive layers are instead evaluated in
//! fused spatial tiles — the direction of Shao et al. (interlayer
//! feature-map compression) and Stoutchinin et al. (optimal CNN
//! scheduling) — intermediates never cross the interconnect at all:
//!
//! * the final output plane is processed in horizontal stripes of height
//!   `t` (full width), like [`super::spatial`];
//! * each stripe's receptive field is back-propagated through the chain
//!   (stride/kernel-aware halo growth, [`stripe_spans`]) to find the rows
//!   of every intermediate plane — and of the chain input — it needs;
//! * interconnect traffic is charged only for the chain's first input
//!   (re-read `ceil(N_1/n_1)` times per stripe, eq. 2 applied to the
//!   stripe's rows), the last layer's psum protocol (eq. 3 or its active
//!   variant — stripe-invariant), and per-layer **weight reloads per
//!   stripe** (each stripe sweeps every `(co, ci)` tile of every layer);
//! * intermediates are free on the interconnect but must be *resident*:
//!   [`chain_working_set`] sizes the live stripe of every plane so an
//!   SRAM budget can veto a chain height ([`max_chain_stripe`]).
//!
//! A depth-1 chain with a single stripe degenerates to the per-layer
//! model: the input span covers the whole (used) plane, there is one
//! weight load, and the psum term is exactly eq. 3. The one caveat is a
//! floor-cropped strided head (`pad < (Hi + 2·pad − K) mod stride`):
//! eq. 2 charges the full `Wi·Hi` plane including tail rows the
//! convolution never touches, while the receptive-field model counts
//! only touched rows. The sweep engine therefore routes singleton chains
//! through [`layer_bandwidth`](super::bandwidth::layer_bandwidth)
//! directly, keeping depth-1 sweeps byte-identical to the unfused model.

use std::ops::Range;

use crate::models::{ConvLayer, DataTypes, Network};

use super::bandwidth::{ByteBandwidth, ControllerMode};
use super::partition::Partition;

/// Whether `next` can be fused directly after `prev`: the planes must
/// chain exactly (no pooling/reshape in between) and the channel counts
/// must agree.
pub fn can_chain(prev: &ConvLayer, next: &ConvLayer) -> bool {
    prev.wo() == next.wi && prev.ho() == next.hi && prev.n == next.m
}

/// Greedy maximal fusion chains of length `<= depth`, left to right, as
/// index ranges into `net.layers`. Every layer belongs to exactly one
/// chain; `depth <= 1` yields all singletons (the unfused model).
pub fn chains(net: &Network, depth: usize) -> Vec<Range<usize>> {
    let depth = depth.max(1);
    let mut out = Vec::new();
    let mut start = 0;
    while start < net.layers.len() {
        let mut end = start + 1;
        while end < net.layers.len()
            && end - start < depth
            && can_chain(&net.layers[end - 1], &net.layers[end])
        {
            end += 1;
        }
        out.push(start..end);
        start = end;
    }
    out
}

/// The input-row interval (inclusive, clamped to the physical plane)
/// that the contiguous output rows `[out_lo, out_hi]` of `layer` need:
/// `[out_lo·s − p, out_hi·s + K − 1 − p]` ∩ `[0, Hi − 1]`.
pub fn input_row_span(layer: &ConvLayer, out_lo: usize, out_hi: usize) -> (usize, usize) {
    debug_assert!(out_lo <= out_hi && out_hi < layer.ho());
    let last = layer.hi as i64 - 1;
    let lo = ((out_lo * layer.stride) as i64 - layer.pad as i64).clamp(0, last);
    let hi = ((out_hi * layer.stride + layer.k - 1) as i64 - layer.pad as i64).clamp(lo, last);
    (lo as usize, hi as usize)
}

/// Rows in an inclusive span.
pub fn span_rows(span: (usize, usize)) -> usize {
    span.1 - span.0 + 1
}

/// Required row spans, per plane, for the stripe `[y0, y1]` of the
/// chain's final output: `spans[d]` is the output stripe itself and
/// `spans[i]` (`i < d`) the rows of layer `i`'s *input* plane — so
/// `spans[0]` is the chain-input span. Each span is clamped to its
/// physical plane, so halo growth saturates at plane edges.
pub fn stripe_spans(chain: &[ConvLayer], y0: usize, y1: usize) -> Vec<(usize, usize)> {
    let d = chain.len();
    let mut spans = vec![(0, 0); d + 1];
    spans[d] = (y0, y1);
    for i in (0..d).rev() {
        let (lo, hi) = spans[i + 1];
        spans[i] = input_row_span(&chain[i], lo, hi);
    }
    spans
}

/// Interconnect traffic of one fused chain (activations + weights moved).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FusedBandwidth {
    /// Chain-input traffic: eq. 2 applied per stripe to the first layer.
    pub input: f64,
    /// Last layer's psum traffic: eq. 3 (or active variant) — the stripe
    /// split does not change the total element count.
    pub output: f64,
    /// Weight elements loaded: the whole chain's weights, once per stripe.
    pub weights: f64,
    /// Number of output stripes the chain was split into.
    pub stripes: usize,
}

impl FusedBandwidth {
    /// Activation traffic (the paper's tabulated unit, weights excluded).
    pub fn activations(&self) -> f64 {
        self.input + self.output
    }

    /// Everything that crossed the interconnect.
    pub fn total(&self) -> f64 {
        self.input + self.output + self.weights
    }
}

/// Traffic of `chain` partitioned per layer as `parts`, processed in
/// final-output stripes of height `t` (`t = Ho_d` means a single stripe).
///
/// All quantities are exact integer-valued `f64` arithmetic, so results
/// are platform- and worker-count-independent.
pub fn chain_bandwidth(
    chain: &[ConvLayer],
    parts: &[Partition],
    t: usize,
    mode: ControllerMode,
) -> FusedBandwidth {
    assert!(!chain.is_empty(), "empty fusion chain");
    assert_eq!(chain.len(), parts.len(), "one partition per chain layer");
    let first = &chain[0];
    let last = chain.last().unwrap();
    let ho = last.ho();
    assert!(t >= 1 && t <= ho, "t out of range [1,{ho}]");

    let stripes = ho.div_ceil(t);
    let mut input_rows = 0usize;
    for s in 0..stripes {
        let y0 = s * t;
        let y1 = (y0 + t - 1).min(ho - 1);
        input_rows += span_rows(stripe_spans(chain, y0, y1)[0]);
    }
    let out_iters_1 = first.n_per_group().div_ceil(parts[0].n);
    let input = (first.wi * input_rows * first.m_per_group()) as f64
        * out_iters_1 as f64
        * first.groups as f64;

    let psum_iters_d = last.m_per_group().div_ceil(parts[parts.len() - 1].m);
    let wo_ho_ng = (last.wo() * ho * last.n_per_group()) as f64;
    let output = match mode {
        ControllerMode::Passive => wo_ho_ng * (2 * psum_iters_d - 1) as f64 * last.groups as f64,
        ControllerMode::Active => wo_ho_ng * psum_iters_d as f64 * last.groups as f64,
    };

    let chain_weights: u64 = chain.iter().map(|l| l.weights()).sum();
    FusedBandwidth {
        input,
        output,
        weights: (stripes as u64 * chain_weights) as f64,
        stripes,
    }
}

/// Byte-weighted fused-chain traffic: the element counts of
/// [`chain_bandwidth`] priced per tensor by `dt`. The chain input is
/// ifmap-width, the last layer's intermediate psum crossings are
/// psum-width with one final ofmap-width write per output element (same
/// decomposition as
/// [`layer_bandwidth_bytes`](super::bandwidth::layer_bandwidth_bytes)),
/// and every reloaded weight is weight-width. Fusion's advantage
/// *compounds* under wide psums: the intermediate layers' psum protocols
/// vanish entirely, and those were the costliest bytes on the wire.
pub fn chain_bandwidth_bytes(
    chain: &[ConvLayer],
    parts: &[Partition],
    t: usize,
    mode: ControllerMode,
    dt: &DataTypes,
) -> ByteBandwidth {
    let elems = chain_bandwidth(chain, parts, t, mode);
    let last = chain.last().expect("empty fusion chain");
    let out_elems = (last.wo() * last.ho() * last.n) as f64;
    // chain_bandwidth's output = psum crossings + one final write per
    // output element; split the final writes out for ofmap pricing.
    let psum_elems = elems.output - out_elems;
    ByteBandwidth {
        input: elems.input * dt.ifmap_bytes(),
        psum: psum_elems * dt.psum_bytes(),
        ofmap: out_elems * dt.ofmap_bytes(),
        weights: elems.weights * dt.weight_bytes(),
    }
}

/// Live on-chip working set (elements) of the fused stripe `[y0, y1]`:
/// the streamed chain-input tile (`m_1` channels of its row span), every
/// intermediate plane at **full channel depth** (produced once, consumed
/// by every pass of its consumer), the final psum stripe (`n_d` channels)
/// and one weight tile per layer.
pub fn chain_working_set(chain: &[ConvLayer], parts: &[Partition], y0: usize, y1: usize) -> u64 {
    assert_eq!(chain.len(), parts.len());
    let d = chain.len();
    let spans = stripe_spans(chain, y0, y1);
    let mut ws = (chain[0].wi * span_rows(spans[0]) * parts[0].m) as u64;
    for i in 0..d - 1 {
        ws += (chain[i].wo() * span_rows(spans[i + 1]) * chain[i].n) as u64;
    }
    ws += (chain[d - 1].wo() * span_rows((y0, y1)) * parts[d - 1].n) as u64;
    for (l, p) in chain.iter().zip(parts) {
        ws += (p.m * p.n * l.k * l.k) as u64;
    }
    ws
}

/// Tallest final-output stripe height whose *worst* stripe working set
/// fits `budget_elems`. `None` when even one-row stripes do not fit (the
/// chain is infeasible at this SRAM capacity).
pub fn max_chain_stripe(
    chain: &[ConvLayer],
    parts: &[Partition],
    budget_elems: u64,
) -> Option<usize> {
    let ho = chain.last().expect("empty fusion chain").ho();
    (1..=ho).rev().find(|&t| {
        (0..ho.div_ceil(t)).all(|s| {
            let y0 = s * t;
            let y1 = (y0 + t - 1).min(ho - 1);
            chain_working_set(chain, parts, y0, y1) <= budget_elems
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::bandwidth::layer_bandwidth;
    use crate::models::zoo;

    fn pair() -> Vec<ConvLayer> {
        vec![
            ConvLayer::new("a", 13, 13, 192, 384, 3, 1, 1),
            ConvLayer::new("b", 13, 13, 384, 256, 3, 1, 1),
        ]
    }

    #[test]
    fn chain_compatibility() {
        let net = zoo::alexnet();
        // conv3 -> conv4 -> conv5 chain (13x13, channels agree); pooling
        // breaks conv1 -> conv2 and conv2 -> conv3.
        assert!(!can_chain(&net.layers[0], &net.layers[1]));
        assert!(!can_chain(&net.layers[1], &net.layers[2]));
        assert!(can_chain(&net.layers[2], &net.layers[3]));
        assert!(can_chain(&net.layers[3], &net.layers[4]));
    }

    #[test]
    fn greedy_chains_partition_the_network() {
        let net = zoo::alexnet();
        assert_eq!(chains(&net, 1), vec![0..1, 1..2, 2..3, 3..4, 4..5]);
        assert_eq!(chains(&net, 2), vec![0..1, 1..2, 2..4, 4..5]);
        assert_eq!(chains(&net, 3), vec![0..1, 1..2, 2..5]);
        assert_eq!(chains(&net, 99), vec![0..1, 1..2, 2..5]);
        // every depth covers every layer exactly once
        for d in 1..=4 {
            let total: usize = chains(&net, d).iter().map(|r| r.len()).sum();
            assert_eq!(total, net.layers.len());
        }
    }

    #[test]
    fn spans_grow_backward_and_clamp() {
        let chain = pair();
        // one output row of b needs 3 rows of a's output, which needs 5
        // rows of the chain input (k3/s1 halo growth), clamped at edges.
        let spans = stripe_spans(&chain, 6, 6);
        assert_eq!(spans[2], (6, 6));
        assert_eq!(spans[1], (5, 7));
        assert_eq!(spans[0], (4, 8));
        // edge stripes saturate at the plane boundary
        let top = stripe_spans(&chain, 0, 0);
        assert_eq!(top[1], (0, 1));
        assert_eq!(top[0], (0, 2));
    }

    #[test]
    fn strided_span_arithmetic() {
        // k5/s2/p2 @28 -> 14 outputs; rows [3,4] need inputs [4, 10].
        let l = ConvLayer::new("s", 28, 28, 8, 8, 5, 2, 2);
        assert_eq!(input_row_span(&l, 3, 4), (4, 10));
        assert_eq!(input_row_span(&l, 0, 0), (0, 2)); // pad-clamped
        assert_eq!(input_row_span(&l, 13, 13), (24, 27)); // tail-clamped
    }

    #[test]
    fn singleton_single_stripe_matches_eq2_eq3() {
        // stride-1 layers: the receptive-field model reproduces the
        // per-layer eqs. 2-3 exactly at t = Ho.
        let l = ConvLayer::new("c", 27, 27, 64, 192, 5, 1, 2);
        for mode in ControllerMode::ALL {
            for (m, n) in [(16, 1), (1, 16), (8, 12), (64, 192)] {
                let fused =
                    chain_bandwidth(std::slice::from_ref(&l), &[Partition { m, n }], l.ho(), mode);
                let bw = layer_bandwidth(&l, m, n, mode);
                assert_eq!(fused.input, bw.input);
                assert_eq!(fused.output, bw.output);
                assert_eq!(fused.stripes, 1);
                assert_eq!(fused.weights, l.weights() as f64);
            }
        }
    }

    #[test]
    fn fused_pair_drops_intermediate_traffic() {
        let chain = pair();
        let parts = [Partition { m: 48, n: 1 }, Partition { m: 48, n: 1 }];
        for mode in ControllerMode::ALL {
            let fused = chain_bandwidth(&chain, &parts, chain[1].ho(), mode);
            let a = layer_bandwidth(&chain[0], 48, 1, mode);
            let b = layer_bandwidth(&chain[1], 48, 1, mode);
            // first input + last output only; the intermediate's write
            // (a.output) and re-read (b.input) vanish.
            assert_eq!(fused.input, a.input);
            assert_eq!(fused.output, b.output);
            assert!(fused.activations() < a.total() + b.total());
        }
    }

    #[test]
    fn striping_reloads_weights_and_adds_halo() {
        let chain = pair();
        let parts = [Partition { m: 48, n: 4 }, Partition { m: 48, n: 4 }];
        let full = chain_bandwidth(&chain, &parts, 13, ControllerMode::Passive);
        let mut prev = full;
        for t in [7usize, 4, 2, 1] {
            let s = chain_bandwidth(&chain, &parts, t, ControllerMode::Passive);
            assert!(s.input >= prev.input, "halo not monotone at t={t}");
            assert!(s.weights > prev.weights || s.stripes == prev.stripes, "t={t}");
            // psum totals are stripe-invariant
            assert_eq!(s.output, full.output);
            prev = s;
        }
        let one = chain_bandwidth(&chain, &parts, 1, ControllerMode::Passive);
        assert_eq!(one.stripes, 13);
        assert_eq!(one.weights, (13u64 * (chain[0].weights() + chain[1].weights())) as f64);
    }

    #[test]
    fn working_set_and_stripe_search() {
        let chain = pair();
        let parts = [Partition { m: 48, n: 4 }, Partition { m: 48, n: 4 }];
        // monotone in stripe height at fixed origin
        let mut prev = 0;
        for t in 1..=13 {
            let ws = chain_working_set(&chain, &parts, 0, t - 1);
            assert!(ws >= prev, "t={t}");
            prev = ws;
        }
        assert_eq!(max_chain_stripe(&chain, &parts, u64::MAX), Some(13));
        assert_eq!(max_chain_stripe(&chain, &parts, 0), None);
        // a mid-size budget yields some 1 <= t < 13
        let mid = chain_working_set(&chain, &parts, 0, 5);
        let t = max_chain_stripe(&chain, &parts, mid).unwrap();
        assert!((1..13).contains(&t));
        // the returned height actually fits everywhere
        for s in 0..13usize.div_ceil(t) {
            let y0 = s * t;
            let y1 = (y0 + t - 1).min(12);
            assert!(chain_working_set(&chain, &parts, y0, y1) <= mid);
        }
    }

    #[test]
    fn chain_bytes_reprice_the_same_elements() {
        let chain = pair();
        let parts = [Partition { m: 48, n: 4 }, Partition { m: 48, n: 4 }];
        let dt = DataTypes::parse("8:8:32:8").unwrap();
        for t in [13usize, 5, 1] {
            for mode in ControllerMode::ALL {
                let e = chain_bandwidth(&chain, &parts, t, mode);
                let b = chain_bandwidth_bytes(&chain, &parts, t, mode, &dt);
                // element counts re-compose exactly under per-region widths
                assert_eq!(b.input / dt.ifmap_bytes(), e.input, "t={t} {mode:?}");
                assert_eq!(
                    b.psum / dt.psum_bytes() + b.ofmap / dt.ofmap_bytes(),
                    e.output,
                    "t={t} {mode:?}"
                );
                assert_eq!(b.weights, e.weights, "weight width is 1 byte here");
                // uniform widths: bytes == elements
                let uni = chain_bandwidth_bytes(&chain, &parts, t, mode, &DataTypes::default());
                assert_eq!(uni.activations(), e.activations());
                assert_eq!(uni.total(), e.total());
            }
        }
    }

    #[test]
    fn fused_chain_always_saves_bytes() {
        // Fusing removes the intermediate's psum protocol (psum-width
        // writes + reads) and its re-reads (ifmap-width), so the fused
        // byte total is strictly below the unfused one in every mode.
        // (Note the *fraction* saved need not exceed the element
        // fraction: the removed re-reads are cheap ifmap-width bytes.)
        let chain = pair();
        let parts = [Partition { m: 48, n: 4 }, Partition { m: 48, n: 4 }];
        let dt = DataTypes::parse("8:8:32:8").unwrap();
        for mode in ControllerMode::ALL {
            let fused = chain_bandwidth_bytes(&chain, &parts, 13, mode, &dt).activations();
            let a = crate::analytics::bandwidth::layer_bandwidth_bytes(&chain[0], 48, 4, mode, &dt);
            let b = crate::analytics::bandwidth::layer_bandwidth_bytes(&chain[1], 48, 4, mode, &dt);
            let unfused = a.activations() + b.activations();
            assert!(fused < unfused, "{mode:?}: fused {fused} >= unfused {unfused}");
        }
    }

    #[test]
    fn depthwise_layers_chain_too() {
        // MobileNet-style: pointwise feeding a depthwise of equal plane.
        let pw = ConvLayer::new("pw", 28, 28, 64, 128, 1, 1, 0);
        let dw = ConvLayer::grouped("dw", 28, 28, 128, 128, 3, 1, 1, 128);
        assert!(can_chain(&pw, &dw));
        let parts = [Partition { m: 16, n: 8 }, Partition { m: 1, n: 1 }];
        let fused = chain_bandwidth(&[pw.clone(), dw.clone()], &parts, 28, ControllerMode::Active);
        let a = layer_bandwidth(&pw, 16, 8, ControllerMode::Active);
        let b = layer_bandwidth(&dw, 1, 1, ControllerMode::Active);
        assert_eq!(fused.input, a.input);
        assert_eq!(fused.output, b.output);
    }
}
