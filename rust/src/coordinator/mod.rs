//! Layer-3 coordination: the serving/orchestration stack on top of the
//! PJRT runtime and the simulator.
//!
//! Architecture (all std-thread based; the offline vendor set has no
//! tokio — and none is needed at this scale):
//!
//! ```text
//!  submit()            mpsc                 mpsc
//!  clients  ──────▶  [Batcher thread] ──────▶ [Engine thread]
//!            req           │  size/deadline        │ owns Runtime
//!            + reply_tx    ▼  policy               ▼ (PJRT not Send-
//!                     dynamic batches          execute psimnet_bN
//!                                                  │
//!  clients  ◀──────────── per-request reply channels
//! ```
//!
//! * [`job`] — request/response types.
//! * [`batcher`] — dynamic batching: flush on size or deadline.
//! * [`engine`] — the worker that owns the PJRT runtime (actor model
//!   sidesteps `Send` questions about FFI handles).
//! * [`weights`] — deterministic synthetic PsimNet parameters (state).
//! * [`service`] — [`service::InferenceService`]: ties the threads
//!   together behind a `submit()` API.
//! * [`metrics`] — lock-free counters + latency histogram.
//! * [`parallel`] — scoped-thread fan-out used by sweeps and benches.
//! * [`pool`] — the bounded connection hand-off queue behind the pooled
//!   `psim serve` accept loop (non-blocking push = load shedding).

pub mod batcher;
pub mod engine;
pub mod job;
pub mod metrics;
pub mod parallel;
pub mod pool;
pub mod service;
pub mod weights;

pub use job::{InferRequest, InferResponse};
pub use metrics::Metrics;
pub use service::{InferenceService, ServiceConfig};
