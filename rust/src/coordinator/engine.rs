//! The engine thread: owns the PJRT runtime and executes batches.
//!
//! Owning the runtime on one thread (actor model) keeps the FFI handles
//! single-threaded; batches arrive over a channel and responses leave
//! through each request's reply channel. Batch-size dispatch: the engine
//! uses the `psimnet_b8` artifact for any batch of 2..=8 (padding with
//! zero images) and `psimnet_b1` for singles — one compiled executable
//! per batch shape, as PJRT requires static shapes.

use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::job::{InferRequest, InferResponse};
use super::metrics::Metrics;
use super::weights::PsimNetWeights;
use crate::runtime::{ArtifactDir, Runtime, Tensor};

/// Image shape served by PsimNet.
pub const IMAGE_SHAPE: [usize; 3] = [3, 32, 32];
const IMAGE_ELEMS: usize = 3 * 32 * 32;
/// The largest batch artifact.
pub const MAX_BATCH: usize = 8;

/// Run the engine loop until the batch channel disconnects.
///
/// The PJRT client handles are not `Send`, so the engine *constructs* the
/// runtime on its own thread (classic actor ownership) from the cloneable
/// artifact index.
pub fn run_engine(
    artifacts: ArtifactDir,
    weights: PsimNetWeights,
    batch_rx: Receiver<Vec<InferRequest>>,
    metrics: Arc<Metrics>,
) {
    let mut runtime = match Runtime::new(artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("engine: failed to create PJRT runtime: {e:#}");
            // Drain and drop everything so callers observe disconnects.
            while batch_rx.recv().is_ok() {
                metrics.record_error();
            }
            return;
        }
    };
    // Warm the executable cache up front so first-request latency doesn't
    // pay for compilation.
    for name in ["psimnet_b1", "psimnet_b8"] {
        if let Err(e) = runtime.load(name) {
            eprintln!("engine: failed to load {name}: {e:#}");
        }
    }
    // Perf (EXPERIMENTS.md §Perf RT-1): weights are constant for the
    // service lifetime — prepare their XLA literals once; only the image
    // tensor is converted per batch.
    let device_weights: Vec<crate::runtime::PreparedTensor> = match weights
        .tensors
        .iter()
        .map(|t| runtime.prepare(t))
        .collect::<anyhow::Result<Vec<_>>>()
    {
        Ok(ws) => ws,
        Err(e) => {
            eprintln!("engine: weight upload failed: {e:#}");
            while batch_rx.recv().is_ok() {
                metrics.record_error();
            }
            return;
        }
    };

    while let Ok(batch) = batch_rx.recv() {
        if batch.is_empty() {
            continue;
        }
        metrics.record_batch(batch.len());
        match execute_batch_on(&mut runtime, &device_weights, &batch) {
            Ok(logits_rows) => {
                for (req, logits) in batch.into_iter().zip(logits_rows) {
                    let resp = InferResponse {
                        id: req.id,
                        logits,
                        latency_us: req.enqueued.elapsed().as_micros() as u64,
                        batch_size: 0, // filled below
                    };
                    metrics.record_response(resp.latency_us);
                    let _ = req.reply.send(resp);
                }
            }
            Err(e) => {
                eprintln!("engine: batch failed: {e:#}");
                metrics.record_error();
                // Drop the requests; their reply channels disconnect and
                // callers observe the failure.
            }
        }
    }
}

/// Pack a batch's images into one `[B, 3, 32, 32]` tensor (zero-padded).
fn pack_images(batch: &[InferRequest], padded: usize) -> Result<Tensor> {
    let mut data = vec![0.0f32; padded * IMAGE_ELEMS];
    for (i, req) in batch.iter().enumerate() {
        anyhow::ensure!(
            req.image.shape == IMAGE_SHAPE,
            "request {}: image shape {:?} != {:?}",
            req.id,
            req.image.shape,
            IMAGE_SHAPE
        );
        data[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS].copy_from_slice(&req.image.data);
    }
    Tensor::new(vec![padded, 3, 32, 32], data)
}

fn unpack_logits(out: &[Tensor], batch_len: usize) -> Vec<Vec<f32>> {
    let logits = &out[0];
    let classes = logits.shape[1];
    (0..batch_len).map(|i| logits.data[i * classes..(i + 1) * classes].to_vec()).collect()
}

/// Execute one batch against prepared constant weights (the hot path).
pub fn execute_batch_on(
    runtime: &mut Runtime,
    device_weights: &[crate::runtime::PreparedTensor],
    batch: &[InferRequest],
) -> Result<Vec<Vec<f32>>> {
    use crate::runtime::Input;
    debug_assert!(!batch.is_empty() && batch.len() <= MAX_BATCH);
    let _t0 = Instant::now();
    let (entry, padded) =
        if batch.len() == 1 { ("psimnet_b1", 1) } else { ("psimnet_b8", MAX_BATCH) };
    let images = pack_images(batch, padded)?;
    let mut inputs: Vec<Input<'_>> = vec![Input::Host(&images)];
    inputs.extend(device_weights.iter().map(Input::Prepared));
    let out = runtime.execute_mixed(entry, &inputs)?;
    Ok(unpack_logits(&out, batch.len()))
}

/// Execute one batch re-sending host weights each call (kept as the
/// baseline for the §Perf RT-1 comparison and for one-shot uses).
pub fn execute_batch(
    runtime: &mut Runtime,
    weights: &PsimNetWeights,
    batch: &[InferRequest],
) -> Result<Vec<Vec<f32>>> {
    debug_assert!(!batch.is_empty() && batch.len() <= MAX_BATCH);
    let (entry, padded) =
        if batch.len() == 1 { ("psimnet_b1", 1) } else { ("psimnet_b8", MAX_BATCH) };
    let mut inputs = vec![pack_images(batch, padded)?];
    inputs.extend(weights.tensors.iter().cloned());
    let out = runtime.execute(entry, &inputs)?;
    Ok(unpack_logits(&out, batch.len()))
}

#[cfg(test)]
mod tests {
    // Engine tests that need real artifacts live in
    // rust/tests/coordinator_e2e.rs; shape-packing logic is covered there
    // end-to-end against the PJRT runtime.
}
