//! Dynamic batching: accumulate requests until the batch is full or the
//! oldest request has waited long enough, then flush to the engine.
//!
//! The policy is the classic size-or-deadline rule serving systems use
//! (vLLM-style continuous batching reduces to this for a single-stage
//! model): never hold a full batch, never hold a lone request longer than
//! `max_wait`.

use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use super::job::InferRequest;

/// Flush policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Hard cap on batch size (the largest AOT'd batch artifact).
    pub max_batch: usize,
    /// Deadline: oldest request never waits longer than this.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Run the batching loop: read requests from `rx`, emit batches to
/// `batch_tx`. Returns when `rx` disconnects (service shutdown).
pub fn run_batcher(
    rx: Receiver<InferRequest>,
    batch_tx: Sender<Vec<InferRequest>>,
    policy: BatchPolicy,
) {
    let mut pending: Vec<InferRequest> = Vec::with_capacity(policy.max_batch);
    loop {
        if pending.is_empty() {
            // Nothing buffered: block for the next request.
            match rx.recv() {
                Ok(req) => pending.push(req),
                Err(_) => return, // disconnected
            }
        }
        // Buffered: wait for more only until the oldest request's deadline.
        let deadline = pending[0].enqueued + policy.max_wait;
        while pending.len() < policy.max_batch {
            let now = Instant::now();
            let remaining = deadline.saturating_duration_since(now);
            if remaining.is_zero() {
                break;
            }
            match rx.recv_timeout(remaining) {
                Ok(req) => pending.push(req),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    let _ = batch_tx.send(std::mem::take(&mut pending));
                    return;
                }
            }
        }
        if batch_tx.send(std::mem::take(&mut pending)).is_err() {
            return; // engine gone
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Tensor;
    use std::sync::mpsc;

    fn req(id: u64, reply: mpsc::Sender<super::super::job::InferResponse>) -> InferRequest {
        InferRequest { id, image: Tensor::zeros(&[1]), reply, enqueued: Instant::now() }
    }

    #[test]
    fn flushes_full_batch_immediately() {
        let (tx, rx) = mpsc::channel();
        let (btx, brx) = mpsc::channel();
        let (rtx, _rrx) = mpsc::channel();
        for i in 0..8 {
            tx.send(req(i, rtx.clone())).unwrap();
        }
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_secs(10) };
        let h = std::thread::spawn(move || run_batcher(rx, btx, policy));
        let batch = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.len(), 8);
        drop(tx);
        h.join().unwrap();
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (tx, rx) = mpsc::channel();
        let (btx, brx) = mpsc::channel();
        let (rtx, _rrx) = mpsc::channel();
        tx.send(req(0, rtx.clone())).unwrap();
        tx.send(req(1, rtx.clone())).unwrap();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(20) };
        let h = std::thread::spawn(move || run_batcher(rx, btx, policy));
        let batch = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.len(), 2);
        drop(tx);
        h.join().unwrap();
    }

    #[test]
    fn drains_on_disconnect() {
        let (tx, rx) = mpsc::channel();
        let (btx, brx) = mpsc::channel();
        let (rtx, _rrx) = mpsc::channel();
        tx.send(req(0, rtx.clone())).unwrap();
        drop(tx);
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_secs(10) };
        run_batcher(rx, btx, policy);
        let batch = brx.recv().unwrap();
        assert_eq!(batch.len(), 1);
    }
}
