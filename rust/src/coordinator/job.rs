//! Request/response types flowing through the coordinator.

use std::sync::mpsc::Sender;
use std::time::Instant;

use crate::runtime::Tensor;

/// A single inference request (one image).
#[derive(Debug)]
pub struct InferRequest {
    /// Request id (unique per service).
    pub id: u64,
    /// The input image tensor.
    pub image: Tensor,
    /// Where the engine delivers the response.
    pub reply: Sender<InferResponse>,
    /// Enqueue timestamp (for end-to-end latency accounting).
    pub enqueued: Instant,
}

/// The engine's answer.
#[derive(Clone, Debug)]
pub struct InferResponse {
    /// The request id this answers.
    pub id: u64,
    /// Class logits (len = 10 for PsimNet).
    pub logits: Vec<f32>,
    /// End-to-end latency in microseconds (enqueue -> response built).
    pub latency_us: u64,
    /// How many requests shared the batch this one rode in.
    pub batch_size: usize,
}

impl InferResponse {
    /// Argmax class.
    pub fn top_class(&self) -> usize {
        self.logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_class_argmax() {
        let r = InferResponse {
            id: 1,
            logits: vec![0.1, 2.0, -1.0, 0.5],
            latency_us: 10,
            batch_size: 1,
        };
        assert_eq!(r.top_class(), 1);
    }
}
