//! The public face of the serving stack: spawn batcher + engine threads,
//! expose a `submit()` API, collect metrics, shut down cleanly on drop.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{run_batcher, BatchPolicy};
use super::engine::run_engine;
use super::job::{InferRequest, InferResponse};
use super::metrics::Metrics;
use super::weights::PsimNetWeights;
use crate::runtime::{ArtifactDir, Tensor};

/// Service construction parameters.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Largest batch the engine executes.
    pub max_batch: usize,
    /// Longest a request waits for batchmates.
    pub max_wait: Duration,
    /// Seed for the synthetic model weights.
    pub weight_seed: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { max_batch: 8, max_wait: Duration::from_millis(2), weight_seed: 42 }
    }
}

/// A running inference service (PsimNet over PJRT).
pub struct InferenceService {
    request_tx: Option<Sender<InferRequest>>,
    batcher: Option<JoinHandle<()>>,
    engine: Option<JoinHandle<()>>,
    next_id: AtomicU64,
    /// Shared serving metrics (exported via `{"cmd":"metrics"}`).
    pub metrics: Arc<Metrics>,
}

impl InferenceService {
    /// Start the service over an artifact directory.
    pub fn start(artifacts: ArtifactDir, cfg: ServiceConfig) -> Result<InferenceService> {
        let weights = PsimNetWeights::synthetic(&artifacts, cfg.weight_seed)?;
        let metrics = Arc::new(Metrics::new());

        let (request_tx, request_rx) = mpsc::channel::<InferRequest>();
        let (batch_tx, batch_rx) = mpsc::channel::<Vec<InferRequest>>();

        let policy = BatchPolicy { max_batch: cfg.max_batch.min(8), max_wait: cfg.max_wait };
        let batcher = std::thread::Builder::new()
            .name("psim-batcher".into())
            .spawn(move || run_batcher(request_rx, batch_tx, policy))?;

        let m = metrics.clone();
        let engine = std::thread::Builder::new()
            .name("psim-engine".into())
            .spawn(move || run_engine(artifacts, weights, batch_rx, m))?;

        Ok(InferenceService {
            request_tx: Some(request_tx),
            batcher: Some(batcher),
            engine: Some(engine),
            next_id: AtomicU64::new(0),
            metrics,
        })
    }

    /// Start with default config over `./artifacts`.
    pub fn start_default() -> Result<InferenceService> {
        Self::start(ArtifactDir::open_default()?, ServiceConfig::default())
    }

    /// Submit one image; returns a receiver for the response.
    pub fn submit(&self, image: Tensor) -> Receiver<InferResponse> {
        let (reply, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.record_request();
        let req = InferRequest { id, image, reply, enqueued: Instant::now() };
        if let Some(tx) = &self.request_tx {
            let _ = tx.send(req);
        }
        rx
    }

    /// Submit and block for the answer.
    pub fn infer(&self, image: Tensor) -> Result<InferResponse> {
        let rx = self.submit(image);
        rx.recv().map_err(|_| anyhow::anyhow!("service dropped the request (engine error)"))
    }
}

impl Drop for InferenceService {
    fn drop(&mut self) {
        // Disconnect the request channel; batcher drains, engine follows.
        self.request_tx.take();
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
        if let Some(h) = self.engine.take() {
            let _ = h.join();
        }
    }
}

// Integration coverage (real artifacts + PJRT) lives in
// rust/tests/coordinator_e2e.rs.
