//! Scoped-thread fan-out: run an indexed job over a worker pool.
//!
//! Used to parallelize table generation and simulator sweeps (each
//! (network, P, strategy) cell is independent). Plain `std::thread::scope`
//! + an atomic work index — no dependencies, no unsafe.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Map `f` over `items` using up to `workers` threads, preserving order.
pub fn parallel_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return items.iter().map(|t| f(t)).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });
    results.into_iter().map(|m| m.into_inner().unwrap().expect("job completed")).collect()
}

/// Default worker count: available parallelism minus one (leave a core
/// for the caller), at least 1.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get().saturating_sub(1).max(1)).unwrap_or(1)
}

/// Split `total` work items into exactly `parts` shares that sum to
/// `total`: the first `total % parts` shares take one extra item. This is
/// the distribution `psim infer` always used for its client threads,
/// extracted so the `psim bench` load generator shares it.
pub fn split_shares(total: usize, parts: usize) -> Vec<usize> {
    let parts = parts.max(1);
    (0..parts).map(|c| total / parts + usize::from(c < total % parts)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(&items, 8, |&x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn handles_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(parallel_map(&empty, 4, |&x| x).is_empty());
        assert_eq!(parallel_map(&[7], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn workers_capped_by_items() {
        // More workers than items must not deadlock or panic.
        let out = parallel_map(&[1, 2, 3], 64, |&x| x);
        assert_eq!(out, vec![1, 2, 3]);
    }

    #[test]
    fn split_shares_is_exact() {
        assert_eq!(split_shares(10, 3), vec![4, 3, 3]);
        assert_eq!(split_shares(3, 5), vec![1, 1, 1, 0, 0]);
        assert_eq!(split_shares(0, 4), vec![0, 0, 0, 0]);
        assert_eq!(split_shares(7, 0), vec![7], "zero parts clamps to one");
        for total in [0usize, 1, 16, 257, 1000] {
            for parts in 1..=17 {
                let shares = split_shares(total, parts);
                assert_eq!(shares.len(), parts);
                assert_eq!(shares.iter().sum::<usize>(), total, "{total}/{parts}");
                let (min, max) = (shares.iter().min().unwrap(), shares.iter().max().unwrap());
                assert!(max - min <= 1, "{total}/{parts}: uneven split {shares:?}");
            }
        }
    }

    #[test]
    fn really_runs_concurrently() {
        use std::sync::atomic::AtomicUsize;
        static PEAK: AtomicUsize = AtomicUsize::new(0);
        static CUR: AtomicUsize = AtomicUsize::new(0);
        let items: Vec<usize> = (0..16).collect();
        parallel_map(&items, 4, |_| {
            let c = CUR.fetch_add(1, Ordering::SeqCst) + 1;
            PEAK.fetch_max(c, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(5));
            CUR.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(PEAK.load(Ordering::SeqCst) >= 2, "no concurrency observed");
    }
}
