//! Model state: deterministic synthetic PsimNet parameters.
//!
//! The paper's analysis never depends on weight *values* (only shapes), so
//! the serving stack uses seeded synthetic weights — reproducible across
//! runs and matching the shapes recorded in the artifact manifest.

use anyhow::{anyhow, Result};

use crate::runtime::{ArtifactDir, Tensor};

/// PsimNet parameter set, in artifact input order (after the image).
#[derive(Clone, Debug)]
pub struct PsimNetWeights {
    /// Parameter tensors, in artifact input order.
    pub tensors: Vec<Tensor>,
    /// The seed the parameters were derived from.
    pub seed: u64,
}

impl PsimNetWeights {
    /// Derive shapes from the `psimnet_b1` manifest entry; fill with
    /// He-style random values from `seed`.
    pub fn synthetic(artifacts: &ArtifactDir, seed: u64) -> Result<PsimNetWeights> {
        let entry = artifacts
            .entry("psimnet_b1")
            .ok_or_else(|| anyhow!("psimnet_b1 missing from manifest"))?;
        if entry.inputs.len() < 2 {
            return Err(anyhow!("psimnet_b1 has no weight inputs"));
        }
        let tensors = entry.inputs[1..]
            .iter()
            .enumerate()
            .map(|(i, sig)| {
                // He-ish scale: sqrt(2 / fan_in) with fan_in = prod(shape[1..])
                let fan_in: usize = sig.shape[1..].iter().product::<usize>().max(1);
                let scale = (2.0 / fan_in as f32).sqrt();
                Tensor::random(&sig.shape, seed ^ ((i as u64 + 1) * 0x9E37), scale)
            })
            .collect();
        Ok(PsimNetWeights { tensors, seed })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn fake_artifacts() -> ArtifactDir {
        let dir = std::env::temp_dir().join("psim_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"fingerprint":"t","entries":[
              {"name":"psimnet_b1","file":"m.hlo.txt",
               "inputs":[{"shape":[1,3,32,32],"dtype":"float32"},
                          {"shape":[16,3,3,3],"dtype":"float32"},
                          {"shape":[10,16,1,1],"dtype":"float32"}],
               "outputs":[{"shape":[1,10],"dtype":"float32"}]}]}"#,
        )
        .unwrap();
        std::fs::write(dir.join("m.hlo.txt"), "HloModule m").unwrap();
        ArtifactDir::open(Path::new(&dir)).unwrap()
    }

    #[test]
    fn shapes_follow_manifest() {
        let w = PsimNetWeights::synthetic(&fake_artifacts(), 1).unwrap();
        assert_eq!(w.tensors.len(), 2);
        assert_eq!(w.tensors[0].shape, vec![16, 3, 3, 3]);
        assert_eq!(w.tensors[1].shape, vec![10, 16, 1, 1]);
    }

    #[test]
    fn deterministic_in_seed() {
        let a = PsimNetWeights::synthetic(&fake_artifacts(), 7).unwrap();
        let b = PsimNetWeights::synthetic(&fake_artifacts(), 7).unwrap();
        let c = PsimNetWeights::synthetic(&fake_artifacts(), 8).unwrap();
        assert_eq!(a.tensors[0], b.tensors[0]);
        assert_ne!(a.tensors[0], c.tensors[0]);
    }
}
