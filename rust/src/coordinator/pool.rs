//! A bounded MPMC hand-off queue for the pooled `psim serve` accept loop.
//!
//! The accept thread pushes connections with [`Bounded::try_push`] — which
//! **never blocks**: when the queue is at capacity the push fails and the
//! caller sheds the connection with a `too_busy` reply instead of queueing
//! unboundedly (the paper's lesson applied to the server: finite resources
//! need explicit pressure shaping, not implicit infinite buffers). Worker
//! threads block in [`Bounded::pop`] until an item or [`Bounded::close`]
//! arrives. Plain `Mutex<VecDeque>` + `Condvar` — no dependencies, no
//! unsafe, exactly as fast as it needs to be for a connection hand-off.
//!
//! Every item is stamped with its enqueue time, and [`Bounded::pop_timed`]
//! surfaces the queue-wait duration to the popping worker — that is the
//! `serve_queue_wait_us` histogram behind `{"cmd":"stats"}`, the number
//! that makes `--queue` depth tuning data-driven instead of guesswork.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

struct State<T> {
    items: VecDeque<(Instant, T)>,
    closed: bool,
}

/// A bounded queue: non-blocking producers, blocking consumers.
///
/// Capacity 0 is legal and means "shed everything" — every `try_push`
/// fails, which the serve smoke test uses to exercise the shed path
/// deterministically.
pub struct Bounded<T> {
    state: Mutex<State<T>>,
    takers: Condvar,
    capacity: usize,
}

impl<T> Bounded<T> {
    /// An empty queue holding at most `capacity` items.
    pub fn new(capacity: usize) -> Bounded<T> {
        Bounded {
            state: Mutex::new(State { items: VecDeque::new(), closed: false }),
            takers: Condvar::new(),
            capacity,
        }
    }

    /// Enqueue without blocking. `Ok(depth)` is the queue depth after the
    /// push (for high-water-mark accounting); `Err(item)` returns the
    /// item when the queue is full or closed, so the caller can shed it.
    pub fn try_push(&self, item: T) -> Result<usize, T> {
        let mut st = self.state.lock().unwrap();
        if st.closed || st.items.len() >= self.capacity {
            return Err(item);
        }
        st.items.push_back((Instant::now(), item));
        let depth = st.items.len();
        drop(st);
        self.takers.notify_one();
        Ok(depth)
    }

    /// Dequeue, blocking until an item is available. After [`Bounded::close`]
    /// the remaining items are drained in order, then every caller gets
    /// `None` — the worker-thread exit signal.
    pub fn pop(&self) -> Option<T> {
        self.pop_timed().map(|(item, _)| item)
    }

    /// [`Bounded::pop`] plus how long the item waited in the queue
    /// (enqueue stamp to hand-off), so the worker can record queue-wait
    /// latency.
    pub fn pop_timed(&self) -> Option<(T, Duration)> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some((queued_at, item)) = st.items.pop_front() {
                return Some((item, queued_at.elapsed()));
            }
            if st.closed {
                return None;
            }
            st = self.takers.wait(st).unwrap();
        }
    }

    /// Refuse further pushes and wake every blocked [`Bounded::pop`].
    /// Already-queued items are still handed out (the serve shutdown path
    /// relies on workers draining them so their sockets get deregistered).
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.takers.notify_all();
    }

    /// Items currently queued (racy by nature; for tests and accounting).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    /// Whether the queue is currently empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The fixed capacity bound.
    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn push_pop_fifo() {
        let q = Bounded::new(4);
        assert_eq!(q.try_push(1), Ok(1));
        assert_eq!(q.try_push(2), Ok(2));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert!(q.is_empty());
    }

    #[test]
    fn full_queue_rejects_without_blocking() {
        let q = Bounded::new(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        assert_eq!(q.try_push(3), Err(3));
        assert_eq!(q.len(), 2);
        // Popping frees a slot.
        assert_eq!(q.pop(), Some(1));
        assert!(q.try_push(3).is_ok());
    }

    #[test]
    fn zero_capacity_sheds_everything() {
        let q = Bounded::new(0);
        assert_eq!(q.try_push(42), Err(42));
        assert_eq!(q.capacity(), 0);
    }

    #[test]
    fn pop_timed_reports_the_queue_wait() {
        let q = Bounded::new(4);
        q.try_push(7).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        let (item, waited) = q.pop_timed().unwrap();
        assert_eq!(item, 7);
        assert!(waited >= std::time::Duration::from_millis(10), "waited {waited:?}");
        // A fresh push pops with (almost) no wait.
        q.try_push(8).unwrap();
        let (_, waited) = q.pop_timed().unwrap();
        assert!(waited < std::time::Duration::from_secs(5), "waited {waited:?}");
    }

    #[test]
    fn close_drains_then_yields_none() {
        let q = Bounded::new(4);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        q.close();
        assert_eq!(q.try_push(3), Err(3), "closed queue must refuse pushes");
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn close_unblocks_parked_consumers() {
        let q = Bounded::<u32>::new(4);
        let exited = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..3 {
                scope.spawn(|| {
                    assert_eq!(q.pop(), None);
                    exited.fetch_add(1, Ordering::SeqCst);
                });
            }
            std::thread::sleep(std::time::Duration::from_millis(50));
            q.close();
        });
        assert_eq!(exited.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn concurrent_producers_and_consumers_conserve_items() {
        let q = Bounded::new(8);
        let popped = AtomicUsize::new(0);
        let shed = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    while q.pop().is_some() {
                        popped.fetch_add(1, Ordering::SeqCst);
                    }
                });
            }
            scope.spawn(|| {
                for i in 0..1000u32 {
                    if q.try_push(i).is_err() {
                        shed.fetch_add(1, Ordering::SeqCst);
                    }
                }
                q.close();
            });
        });
        assert_eq!(popped.load(Ordering::SeqCst) + shed.load(Ordering::SeqCst), 1000);
    }
}
