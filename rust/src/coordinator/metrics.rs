//! Lock-free serving metrics: counters + a bucketed latency histogram.
//!
//! All atomics — safe to share across the batcher/engine/client threads
//! without a mutex on the hot path.

use std::sync::atomic::{AtomicU64, Ordering};

/// Log-spaced latency buckets in microseconds (upper bounds).
const BUCKETS_US: [u64; 16] = [
    50, 100, 200, 400, 800, 1_600, 3_200, 6_400, 12_800, 25_600, 51_200, 102_400, 204_800,
    409_600, 819_200, u64::MAX,
];

/// Shared metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests accepted.
    pub requests: AtomicU64,
    /// Responses delivered.
    pub responses: AtomicU64,
    /// Batches executed.
    pub batches: AtomicU64,
    /// Requests that rode in batches.
    pub batched_requests: AtomicU64,
    /// Execution failures.
    pub exec_errors: AtomicU64,
    latency_buckets: [AtomicU64; 16],
    latency_sum_us: AtomicU64,
}

impl Metrics {
    /// A zeroed metrics sink.
    pub fn new() -> Self {
        Self::default()
    }

    /// Count one accepted request.
    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one executed batch of `size` requests.
    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Count one delivered response and bucket its latency.
    pub fn record_response(&self, latency_us: u64) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(latency_us, Ordering::Relaxed);
        let idx = BUCKETS_US.iter().position(|&b| latency_us <= b).unwrap_or(15);
        self.latency_buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Count one execution failure.
    pub fn record_error(&self) {
        self.exec_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Mean batch size so far.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Mean end-to-end latency (µs).
    pub fn mean_latency_us(&self) -> f64 {
        let n = self.responses.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.latency_sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Approximate latency percentile from the histogram (returns the
    /// bucket's upper bound).
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        let total: u64 = self.latency_buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * p).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.latency_buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return BUCKETS_US[i];
            }
        }
        BUCKETS_US[15]
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "requests={} responses={} batches={} mean_batch={:.2} mean_latency={:.0}us p50<={}us p99<={}us errors={}",
            self.requests.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.mean_latency_us(),
            self.latency_percentile_us(0.50),
            self.latency_percentile_us(0.99),
            self.exec_errors.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_and_latency_accounting() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert!((m.mean_batch_size() - 6.0).abs() < 1e-12);
        for us in [100, 100, 100, 5_000] {
            m.record_response(us);
        }
        assert!((m.mean_latency_us() - 1325.0).abs() < 1e-9);
        assert_eq!(m.latency_percentile_us(0.5), 100);
        assert!(m.latency_percentile_us(0.99) >= 5_000);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.mean_batch_size(), 0.0);
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.latency_percentile_us(0.99), 0);
    }

    #[test]
    fn summary_renders() {
        let m = Metrics::new();
        m.record_request();
        m.record_response(77);
        assert!(m.summary().contains("requests=1"));
    }
}
