//! A single convolution layer descriptor and its derived quantities.

/// One convolution layer, in the paper's notation:
/// `M` input feature maps of `Wi x Hi`, `N` output maps of `Wo x Ho`,
/// kernel `K x K`. Extended with stride/padding/groups so the torchvision
/// architectures (strided convs, depthwise convs) are representable.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConvLayer {
    /// Human-readable layer name, e.g. `"conv2"`, `"layer3.1.conv2"`.
    pub name: String,
    /// Input spatial width `Wi`.
    pub wi: usize,
    /// Input spatial height `Hi`.
    pub hi: usize,
    /// Input channels `M`.
    pub m: usize,
    /// Output channels `N`.
    pub n: usize,
    /// Kernel size `K` (square kernels; the paper assumes `K x K`).
    pub k: usize,
    /// Stride (square).
    pub stride: usize,
    /// Zero padding (symmetric).
    pub pad: usize,
    /// Groups: 1 = dense conv, `m == n == groups` = depthwise.
    pub groups: usize,
}

impl ConvLayer {
    /// Construct a dense (groups=1) layer.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: &str,
        wi: usize,
        hi: usize,
        m: usize,
        n: usize,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> Self {
        Self::grouped(name, wi, hi, m, n, k, stride, pad, 1)
    }

    /// Construct a grouped layer (depthwise when `groups == m == n`).
    #[allow(clippy::too_many_arguments)]
    pub fn grouped(
        name: &str,
        wi: usize,
        hi: usize,
        m: usize,
        n: usize,
        k: usize,
        stride: usize,
        pad: usize,
        groups: usize,
    ) -> Self {
        assert!(wi > 0 && hi > 0 && m > 0 && n > 0 && k > 0 && stride > 0 && groups > 0,
            "invalid layer {name}");
        assert!(m % groups == 0 && n % groups == 0,
            "layer {name}: channels {m}->{n} not divisible by groups {groups}");
        assert!(wi + 2 * pad >= k && hi + 2 * pad >= k,
            "layer {name}: kernel {k} larger than padded input {wi}x{hi}+2*{pad}");
        ConvLayer {
            name: name.to_string(),
            wi,
            hi,
            m,
            n,
            k,
            stride,
            pad,
            groups,
        }
    }

    /// Output width: `floor((Wi + 2*pad - K)/stride) + 1`.
    pub fn wo(&self) -> usize {
        (self.wi + 2 * self.pad - self.k) / self.stride + 1
    }

    /// Output height.
    pub fn ho(&self) -> usize {
        (self.hi + 2 * self.pad - self.k) / self.stride + 1
    }

    /// Input activations touched once: `Wi*Hi*M`.
    pub fn input_activations(&self) -> u64 {
        self.wi as u64 * self.hi as u64 * self.m as u64
    }

    /// Output activations written once: `Wo*Ho*N`.
    pub fn output_activations(&self) -> u64 {
        self.wo() as u64 * self.ho() as u64 * self.n as u64
    }

    /// Input channels per group (`M/g`) — the paper's `M` within a group.
    pub fn m_per_group(&self) -> usize {
        self.m / self.groups
    }

    /// Output channels per group (`N/g`).
    pub fn n_per_group(&self) -> usize {
        self.n / self.groups
    }

    /// Whether this is a depthwise convolution.
    pub fn is_depthwise(&self) -> bool {
        self.groups > 1 && self.m_per_group() == 1 && self.n_per_group() == 1
    }

    /// Total multiply-accumulates for this layer:
    /// `Wo*Ho*N * (M/g) * K^2`.
    pub fn macs(&self) -> u64 {
        self.output_activations() * self.m_per_group() as u64 * (self.k * self.k) as u64
    }

    /// Weight-parameter count: `N * (M/g) * K^2`.
    pub fn weights(&self) -> u64 {
        self.n as u64 * self.m_per_group() as u64 * (self.k * self.k) as u64
    }

    /// The same layer with `groups` erased (treated as a dense `M -> N`
    /// conv). Activation *footprints* are identical; only the partitioning
    /// space and MAC count change. This is how the paper's own evaluation
    /// handled the grouped convs of MNASNet and ResNeXt-50 (see
    /// EXPERIMENTS.md §Calibration), so the paper-profile networks use it.
    pub fn dense_equivalent(&self) -> ConvLayer {
        ConvLayer { groups: 1, ..self.clone() }
    }
}

impl std::fmt::Display for ConvLayer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}: {}x{}x{} -> {}x{}x{} k{} s{} p{}{}",
            self.name,
            self.wi,
            self.hi,
            self.m,
            self.wo(),
            self.ho(),
            self.n,
            self.k,
            self.stride,
            self.pad,
            if self.groups > 1 { format!(" g{}", self.groups) } else { String::new() }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alexnet_conv1_dims() {
        // Conv2d(3, 64, kernel_size=11, stride=4, padding=2) @224 -> 55x55
        let l = ConvLayer::new("conv1", 224, 224, 3, 64, 11, 4, 2);
        assert_eq!(l.wo(), 55);
        assert_eq!(l.ho(), 55);
        assert_eq!(l.input_activations(), 3 * 224 * 224);
        assert_eq!(l.output_activations(), 64 * 55 * 55);
    }

    #[test]
    fn same_padding_preserves_dims() {
        let l = ConvLayer::new("c", 56, 56, 64, 64, 3, 1, 1);
        assert_eq!(l.wo(), 56);
        assert_eq!(l.ho(), 56);
    }

    #[test]
    fn strided_downsample() {
        let l = ConvLayer::new("ds", 56, 56, 64, 128, 1, 2, 0);
        assert_eq!(l.wo(), 28);
        assert_eq!(l.ho(), 28);
    }

    #[test]
    fn depthwise_flags_and_macs() {
        let l = ConvLayer::grouped("dw", 112, 112, 32, 32, 3, 1, 1, 32);
        assert!(l.is_depthwise());
        assert_eq!(l.m_per_group(), 1);
        // MACs: Wo*Ho*N * 1 * 9
        assert_eq!(l.macs(), 112 * 112 * 32 * 9);
        assert_eq!(l.weights(), 32 * 9);
    }

    #[test]
    fn macs_dense() {
        let l = ConvLayer::new("c", 14, 14, 512, 512, 3, 1, 1);
        assert_eq!(l.macs(), 14 * 14 * 512 * 512 * 9);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_groups() {
        ConvLayer::grouped("bad", 8, 8, 10, 10, 3, 1, 1, 3);
    }

    #[test]
    #[should_panic]
    fn rejects_kernel_bigger_than_input() {
        ConvLayer::new("bad", 2, 2, 8, 8, 7, 1, 0);
    }
}
