//! A single convolution layer descriptor and its derived quantities,
//! plus the [`DataTypes`] precision model (per-tensor element widths).

use anyhow::{bail, Result};

/// Per-tensor element widths in **bits** — the precision model behind the
/// byte-level traffic accounting.
///
/// The paper's central observation is that partial sums are *wider* than
/// activations (24–32-bit accumulators vs 8-bit ifmaps), so a psum
/// crossing the interconnect costs disproportionately more **bytes** than
/// an input activation. Element-count models (eqs. 2–4) cannot see this;
/// `DataTypes` carries the widths so every layer of the stack can weight
/// traffic in bytes (see `docs/MODEL.md` §Byte-level model).
///
/// The default is uniform 8-bit (one byte per element), under which byte
/// totals equal element totals exactly — the compatibility contract every
/// pinned golden relies on.
///
/// ```
/// use psim::models::DataTypes;
///
/// let dt = DataTypes::parse("8:8:32:8").unwrap();
/// assert_eq!((dt.ifmap_bits, dt.weight_bits, dt.psum_bits, dt.ofmap_bits), (8, 8, 32, 8));
/// assert_eq!(dt.psum_bytes(), 4.0);
/// assert!(!dt.is_default());
/// assert_eq!(dt.label(), "8:8:32:8");
/// assert!(DataTypes::default().is_default());
/// // One element of a uniform-width type is width/8 bytes:
/// assert_eq!(DataTypes::uniform(16).ifmap_bytes(), 2.0);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct DataTypes {
    /// Input-feature-map (activation) element width, bits.
    pub ifmap_bits: usize,
    /// Weight element width, bits.
    pub weight_bits: usize,
    /// Partial-sum (accumulator) element width, bits.
    pub psum_bits: usize,
    /// Output-feature-map element width, bits (post ReLU/quantization).
    pub ofmap_bits: usize,
}

impl DataTypes {
    /// Uniform width: every tensor `bits` wide.
    pub fn uniform(bits: usize) -> DataTypes {
        DataTypes { ifmap_bits: bits, weight_bits: bits, psum_bits: bits, ofmap_bits: bits }
    }

    /// Construct from explicit widths, validating each is in `1..=64`.
    pub fn new(ifmap: usize, weight: usize, psum: usize, ofmap: usize) -> Result<DataTypes> {
        for (name, bits) in [("ifmap", ifmap), ("weight", weight), ("psum", psum), ("ofmap", ofmap)]
        {
            if bits == 0 || bits > 64 {
                bail!("{name} width must be 1..=64 bits, got {bits}");
            }
        }
        Ok(DataTypes { ifmap_bits: ifmap, weight_bits: weight, psum_bits: psum, ofmap_bits: ofmap })
    }

    /// Parse `"ifmap:weight:psum:ofmap"` (bits, e.g. `"8:8:32:8"`), or the
    /// presets `"int8"` (8:8:32:8) and `"fp16"` (16:16:32:16).
    pub fn parse(s: &str) -> Result<DataTypes> {
        match s.trim().to_ascii_lowercase().as_str() {
            "int8" => return DataTypes::new(8, 8, 32, 8),
            "fp16" => return DataTypes::new(16, 16, 32, 16),
            _ => {}
        }
        let parts: Vec<&str> = s.trim().split(':').collect();
        if parts.len() != 4 {
            bail!("bits spec '{s}' must be ifmap:weight:psum:ofmap (e.g. 8:8:32:8) or a preset");
        }
        let mut bits = [0usize; 4];
        for (slot, part) in bits.iter_mut().zip(&parts) {
            *slot = part
                .trim()
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("bad width '{part}' in bits spec '{s}'"))?;
        }
        DataTypes::new(bits[0], bits[1], bits[2], bits[3])
    }

    /// Canonical wire/display form, `"8:8:32:8"`. Round-trips through
    /// [`DataTypes::parse`].
    pub fn label(&self) -> String {
        format!("{}:{}:{}:{}", self.ifmap_bits, self.weight_bits, self.psum_bits, self.ofmap_bits)
    }

    /// Whether this is the compatibility default (uniform 8-bit). Only
    /// non-default precisions add byte keys to JSONL/tables.
    pub fn is_default(&self) -> bool {
        *self == DataTypes::default()
    }

    /// Whether all four widths are equal (byte totals are then element
    /// totals × width/8 exactly).
    pub fn is_uniform(&self) -> bool {
        self.ifmap_bits == self.weight_bits
            && self.weight_bits == self.psum_bits
            && self.psum_bits == self.ofmap_bits
    }

    /// Ifmap element size in bytes (exact `f64`: bits / 8).
    pub fn ifmap_bytes(&self) -> f64 {
        self.ifmap_bits as f64 / 8.0
    }

    /// Weight element size in bytes.
    pub fn weight_bytes(&self) -> f64 {
        self.weight_bits as f64 / 8.0
    }

    /// Psum element size in bytes.
    pub fn psum_bytes(&self) -> f64 {
        self.psum_bits as f64 / 8.0
    }

    /// Ofmap element size in bytes.
    pub fn ofmap_bytes(&self) -> f64 {
        self.ofmap_bits as f64 / 8.0
    }
}

impl Default for DataTypes {
    /// Uniform 8-bit: one byte per element, so byte totals equal element
    /// totals and no byte keys are emitted.
    fn default() -> DataTypes {
        DataTypes::uniform(8)
    }
}

impl std::fmt::Display for DataTypes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

/// One convolution layer, in the paper's notation:
/// `M` input feature maps of `Wi x Hi`, `N` output maps of `Wo x Ho`,
/// kernel `K x K`. Extended with stride/padding/groups so the torchvision
/// architectures (strided convs, depthwise convs) are representable.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConvLayer {
    /// Human-readable layer name, e.g. `"conv2"`, `"layer3.1.conv2"`.
    pub name: String,
    /// Input spatial width `Wi`.
    pub wi: usize,
    /// Input spatial height `Hi`.
    pub hi: usize,
    /// Input channels `M`.
    pub m: usize,
    /// Output channels `N`.
    pub n: usize,
    /// Kernel size `K` (square kernels; the paper assumes `K x K`).
    pub k: usize,
    /// Stride (square).
    pub stride: usize,
    /// Zero padding (symmetric).
    pub pad: usize,
    /// Groups: 1 = dense conv, `m == n == groups` = depthwise.
    pub groups: usize,
}

impl ConvLayer {
    /// Construct a dense (groups=1) layer, panicking on invalid shapes.
    ///
    /// Zoo builders and tests use this for brevity; anything fed by
    /// hostile input (config files, the wire protocol) must go through
    /// [`ConvLayer::try_new`] instead so bad shapes error cleanly.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: &str,
        wi: usize,
        hi: usize,
        m: usize,
        n: usize,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> Self {
        Self::grouped(name, wi, hi, m, n, k, stride, pad, 1)
    }

    /// Construct a grouped layer (depthwise when `groups == m == n`),
    /// panicking on invalid shapes — the trusted-input counterpart of
    /// [`ConvLayer::try_grouped`].
    #[allow(clippy::too_many_arguments)]
    pub fn grouped(
        name: &str,
        wi: usize,
        hi: usize,
        m: usize,
        n: usize,
        k: usize,
        stride: usize,
        pad: usize,
        groups: usize,
    ) -> Self {
        Self::try_grouped(name, wi, hi, m, n, k, stride, pad, groups)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallibly construct a dense (groups=1) layer — the entry point for
    /// hostile input (config files, protocol requests).
    #[allow(clippy::too_many_arguments)]
    pub fn try_new(
        name: &str,
        wi: usize,
        hi: usize,
        m: usize,
        n: usize,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> Result<Self> {
        Self::try_grouped(name, wi, hi, m, n, k, stride, pad, 1)
    }

    /// Fallibly construct a grouped layer, validating the shape: every
    /// dimension positive, channels divisible by `groups`, and the kernel
    /// no larger than the padded input.
    #[allow(clippy::too_many_arguments)]
    pub fn try_grouped(
        name: &str,
        wi: usize,
        hi: usize,
        m: usize,
        n: usize,
        k: usize,
        stride: usize,
        pad: usize,
        groups: usize,
    ) -> Result<Self> {
        if !(wi > 0 && hi > 0 && m > 0 && n > 0 && k > 0 && stride > 0 && groups > 0) {
            bail!("invalid layer {name}");
        }
        if m % groups != 0 || n % groups != 0 {
            bail!("layer {name}: channels {m}->{n} not divisible by groups {groups}");
        }
        if wi + 2 * pad < k || hi + 2 * pad < k {
            bail!("layer {name}: kernel {k} larger than padded input {wi}x{hi}+2*{pad}");
        }
        Ok(ConvLayer {
            name: name.to_string(),
            wi,
            hi,
            m,
            n,
            k,
            stride,
            pad,
            groups,
        })
    }

    /// Output width: `floor((Wi + 2*pad - K)/stride) + 1`.
    pub fn wo(&self) -> usize {
        (self.wi + 2 * self.pad - self.k) / self.stride + 1
    }

    /// Output height.
    pub fn ho(&self) -> usize {
        (self.hi + 2 * self.pad - self.k) / self.stride + 1
    }

    /// Input activations touched once: `Wi*Hi*M`.
    pub fn input_activations(&self) -> u64 {
        self.wi as u64 * self.hi as u64 * self.m as u64
    }

    /// Output activations written once: `Wo*Ho*N`.
    pub fn output_activations(&self) -> u64 {
        self.wo() as u64 * self.ho() as u64 * self.n as u64
    }

    /// Input channels per group (`M/g`) — the paper's `M` within a group.
    pub fn m_per_group(&self) -> usize {
        self.m / self.groups
    }

    /// Output channels per group (`N/g`).
    pub fn n_per_group(&self) -> usize {
        self.n / self.groups
    }

    /// Whether this is a depthwise convolution.
    pub fn is_depthwise(&self) -> bool {
        self.groups > 1 && self.m_per_group() == 1 && self.n_per_group() == 1
    }

    /// Total multiply-accumulates for this layer:
    /// `Wo*Ho*N * (M/g) * K^2`.
    pub fn macs(&self) -> u64 {
        self.output_activations() * self.m_per_group() as u64 * (self.k * self.k) as u64
    }

    /// Weight-parameter count: `N * (M/g) * K^2`.
    pub fn weights(&self) -> u64 {
        self.n as u64 * self.m_per_group() as u64 * (self.k * self.k) as u64
    }

    /// The same layer with `groups` erased (treated as a dense `M -> N`
    /// conv). Activation *footprints* are identical; only the partitioning
    /// space and MAC count change. This is how the paper's own evaluation
    /// handled the grouped convs of MNASNet and ResNeXt-50 (see
    /// EXPERIMENTS.md §Calibration), so the paper-profile networks use it.
    pub fn dense_equivalent(&self) -> ConvLayer {
        ConvLayer { groups: 1, ..self.clone() }
    }
}

impl std::fmt::Display for ConvLayer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}: {}x{}x{} -> {}x{}x{} k{} s{} p{}{}",
            self.name,
            self.wi,
            self.hi,
            self.m,
            self.wo(),
            self.ho(),
            self.n,
            self.k,
            self.stride,
            self.pad,
            if self.groups > 1 { format!(" g{}", self.groups) } else { String::new() }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn datatypes_parse_and_label_round_trip() {
        let dt = DataTypes::parse("8:8:32:8").unwrap();
        assert_eq!(dt, DataTypes::new(8, 8, 32, 8).unwrap());
        assert_eq!(DataTypes::parse(&dt.label()).unwrap(), dt);
        assert_eq!(DataTypes::parse("int8").unwrap(), dt);
        assert_eq!(DataTypes::parse("fp16").unwrap(), DataTypes::new(16, 16, 32, 16).unwrap());
        assert_eq!(DataTypes::parse(" 8 : 8 : 24 : 8 ").unwrap().psum_bits, 24);
        for bad in ["", "8:8:32", "8:8:32:8:1", "0:8:8:8", "8:8:65:8", "a:8:8:8"] {
            assert!(DataTypes::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn datatypes_default_is_uniform_one_byte() {
        let dt = DataTypes::default();
        assert!(dt.is_default() && dt.is_uniform());
        assert_eq!(dt.ifmap_bytes(), 1.0);
        assert_eq!(dt.psum_bytes(), 1.0);
        assert!(!DataTypes::parse("8:8:32:8").unwrap().is_default());
        assert!(!DataTypes::parse("8:8:32:8").unwrap().is_uniform());
        assert!(DataTypes::uniform(16).is_uniform());
        assert!(!DataTypes::uniform(16).is_default());
        // 24-bit psums are 3 bytes exactly (f64 division by 8 is exact)
        assert_eq!(DataTypes::parse("8:8:24:8").unwrap().psum_bytes(), 3.0);
    }

    #[test]
    fn alexnet_conv1_dims() {
        // Conv2d(3, 64, kernel_size=11, stride=4, padding=2) @224 -> 55x55
        let l = ConvLayer::new("conv1", 224, 224, 3, 64, 11, 4, 2);
        assert_eq!(l.wo(), 55);
        assert_eq!(l.ho(), 55);
        assert_eq!(l.input_activations(), 3 * 224 * 224);
        assert_eq!(l.output_activations(), 64 * 55 * 55);
    }

    #[test]
    fn same_padding_preserves_dims() {
        let l = ConvLayer::new("c", 56, 56, 64, 64, 3, 1, 1);
        assert_eq!(l.wo(), 56);
        assert_eq!(l.ho(), 56);
    }

    #[test]
    fn strided_downsample() {
        let l = ConvLayer::new("ds", 56, 56, 64, 128, 1, 2, 0);
        assert_eq!(l.wo(), 28);
        assert_eq!(l.ho(), 28);
    }

    #[test]
    fn depthwise_flags_and_macs() {
        let l = ConvLayer::grouped("dw", 112, 112, 32, 32, 3, 1, 1, 32);
        assert!(l.is_depthwise());
        assert_eq!(l.m_per_group(), 1);
        // MACs: Wo*Ho*N * 1 * 9
        assert_eq!(l.macs(), 112 * 112 * 32 * 9);
        assert_eq!(l.weights(), 32 * 9);
    }

    #[test]
    fn macs_dense() {
        let l = ConvLayer::new("c", 14, 14, 512, 512, 3, 1, 1);
        assert_eq!(l.macs(), 14 * 14 * 512 * 512 * 9);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_groups() {
        ConvLayer::grouped("bad", 8, 8, 10, 10, 3, 1, 1, 3);
    }

    #[test]
    #[should_panic]
    fn rejects_kernel_bigger_than_input() {
        ConvLayer::new("bad", 2, 2, 8, 8, 7, 1, 0);
    }

    #[test]
    fn try_constructors_error_instead_of_panicking() {
        // The same three shape violations the panicking wrappers trap,
        // surfaced as clean errors for hostile-input paths.
        let err = ConvLayer::try_new("z", 0, 8, 8, 8, 3, 1, 1).unwrap_err();
        assert!(err.to_string().contains("invalid layer z"), "{err}");
        let err = ConvLayer::try_grouped("g", 8, 8, 10, 10, 3, 1, 1, 3).unwrap_err();
        assert!(err.to_string().contains("not divisible by groups"), "{err}");
        let err = ConvLayer::try_new("k", 2, 2, 8, 8, 7, 1, 0).unwrap_err();
        assert!(err.to_string().contains("larger than padded input"), "{err}");
        // And the happy path agrees with the panicking constructor.
        let a = ConvLayer::try_grouped("dw", 112, 112, 32, 32, 3, 1, 1, 32).unwrap();
        let b = ConvLayer::grouped("dw", 112, 112, 32, 32, 3, 1, 1, 32);
        assert_eq!(a, b);
    }
}
