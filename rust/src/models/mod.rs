//! CNN workload descriptors.
//!
//! The paper's analysis depends only on the *shapes* of the convolution
//! layers (input/output spatial dims, channel counts, kernel size, groups),
//! never on weights or activations. [`ConvLayer`] captures exactly that,
//! and [`zoo`] provides torchvision-faithful definitions of the eight
//! networks evaluated in the paper (Tables I–III) at 224x224 input.

pub mod layer;
pub mod network;
pub mod zoo;

pub use layer::{ConvLayer, DataTypes};
pub use network::Network;
