//! Workload descriptors: conv layers, the GEMM/attention operator
//! abstraction, and the network zoo.
//!
//! The paper's analysis depends only on the *shapes* of the operators
//! (spatial dims, channel counts, kernel size, groups — or GEMM
//! M/K/N), never on weights or activations. [`ConvLayer`] captures a
//! convolution; [`Op`] generalizes to GEMM and attention by lowering
//! them onto the 1×1-conv equations (see [`op`]); [`zoo`] provides
//! torchvision-faithful definitions of the eight networks evaluated in
//! the paper (Tables I–III) at 224x224 input, plus extension networks
//! including a GEMM/attention ViT-Tiny.

pub mod layer;
pub mod network;
pub mod op;
pub mod zoo;

pub use layer::{ConvLayer, DataTypes};
pub use network::Network;
pub use op::{Op, OpKind};
