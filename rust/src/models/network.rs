//! A network = named ordered list of operators, plus aggregate queries.
//!
//! The typed [`Op`] list is the source of truth; the lowered
//! [`ConvLayer`] list (`layers`) is what every analytical/simulated
//! consumer evaluates. Conv-only networks lower to themselves, so the
//! two views coincide for the paper's eight CNNs and every pre-existing
//! golden stays byte-identical.

use super::layer::{ConvLayer, DataTypes};
use super::op::Op;

/// A network's operator stack (conv-only CNNs, GEMM/attention
/// transformers, or a mix), with the lowered conv view alongside.
#[derive(Clone, Debug)]
pub struct Network {
    /// Paper-facing name, e.g. `"AlexNet"`.
    pub name: String,
    /// Lowered conv layers in execution order — the representation the
    /// analytics/sim/dse stack consumes (see [`Op::lower`]).
    pub layers: Vec<ConvLayer>,
    /// Typed operators in execution order — the source of truth
    /// `layers` is lowered from. For conv-only networks this is one
    /// [`Op::Conv`] per layer.
    pub ops: Vec<Op>,
}

impl Network {
    /// A named network over a non-empty conv stack (each layer becomes
    /// one [`Op::Conv`]).
    pub fn new(name: &str, layers: Vec<ConvLayer>) -> Self {
        assert!(!layers.is_empty(), "network {name} has no layers");
        let ops = layers.iter().cloned().map(Op::Conv).collect();
        Network { name: name.to_string(), layers, ops }
    }

    /// A named network over a non-empty operator list; `layers` is the
    /// concatenated lowering in execution order.
    pub fn from_ops(name: &str, ops: Vec<Op>) -> Self {
        assert!(!ops.is_empty(), "network {name} has no ops");
        let layers = ops.iter().flat_map(Op::lower).collect();
        Network { name: name.to_string(), layers, ops }
    }

    /// Minimum bandwidth (activations moved if every tensor is read once
    /// and written once — the paper's Table III quantity):
    /// `sum_l (Wi*Hi*M + Wo*Ho*N)`.
    pub fn min_bandwidth(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| l.input_activations() + l.output_activations())
            .sum()
    }

    /// The Table III floor in **bytes**: every input read once at ifmap
    /// width, every output written once at ofmap width. Full residency
    /// means no partial sum ever crosses the interconnect, so the floor
    /// carries no psum-width term. Equals [`Network::min_bandwidth`] under
    /// the default (uniform one-byte) [`DataTypes`].
    pub fn min_bandwidth_bytes(&self, dt: &DataTypes) -> f64 {
        self.layers
            .iter()
            .map(|l| {
                l.input_activations() as f64 * dt.ifmap_bytes()
                    + l.output_activations() as f64 * dt.ofmap_bytes()
            })
            .sum()
    }

    /// Total MACs over all (lowered) conv layers.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Total conv weights.
    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(|l| l.weights()).sum()
    }

    /// Find a (lowered) layer by name.
    pub fn layer(&self, name: &str) -> Option<&ConvLayer> {
        self.layers.iter().find(|l| l.name == name)
    }

    /// Find an operator by name.
    pub fn op(&self, name: &str) -> Option<&Op> {
        self.ops.iter().find(|o| o.name() == name)
    }

    /// The network with every layer's `groups` erased — see
    /// [`ConvLayer::dense_equivalent`]. Minimum bandwidth is unchanged;
    /// partitioned bandwidth generally grows. GEMM/attention ops carry
    /// no groups and pass through untouched.
    pub fn dense_equivalent(&self) -> Network {
        Network {
            name: self.name.clone(),
            layers: self.layers.iter().map(|l| l.dense_equivalent()).collect(),
            ops: self
                .ops
                .iter()
                .map(|o| match o {
                    Op::Conv(l) => Op::Conv(l.dense_equivalent()),
                    other => other.clone(),
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Network {
        Network::new(
            "tiny",
            vec![
                ConvLayer::new("c1", 8, 8, 3, 16, 3, 1, 1),
                ConvLayer::new("c2", 8, 8, 16, 32, 3, 1, 1),
            ],
        )
    }

    #[test]
    fn min_bandwidth_sums_layers() {
        let n = tiny();
        let expect = (8 * 8 * 3 + 8 * 8 * 16) + (8 * 8 * 16 + 8 * 8 * 32);
        assert_eq!(n.min_bandwidth(), expect as u64);
    }

    #[test]
    fn min_bandwidth_bytes_weights_tensors_independently() {
        let n = tiny();
        // default precision: bytes == elements
        assert_eq!(n.min_bandwidth_bytes(&DataTypes::default()), n.min_bandwidth() as f64);
        // psum width does NOT appear in the floor (full residency)
        let wide_psum = DataTypes::parse("8:8:32:8").unwrap();
        assert_eq!(n.min_bandwidth_bytes(&wide_psum), n.min_bandwidth() as f64);
        // 16-bit ofmaps double the write half only
        let wide_out = DataTypes::new(8, 8, 32, 16).unwrap();
        let ins = (8 * 8 * 3 + 8 * 8 * 16) as f64;
        let outs = (8 * 8 * 16 + 8 * 8 * 32) as f64;
        assert_eq!(n.min_bandwidth_bytes(&wide_out), ins + 2.0 * outs);
    }

    #[test]
    fn layer_lookup() {
        let n = tiny();
        assert!(n.layer("c2").is_some());
        assert!(n.layer("nope").is_none());
    }

    #[test]
    fn macs_accumulate() {
        let n = tiny();
        assert_eq!(n.total_macs(), (8 * 8 * 16 * 3 * 9 + 8 * 8 * 32 * 16 * 9) as u64);
    }

    #[test]
    fn conv_networks_carry_one_conv_op_per_layer() {
        let n = tiny();
        assert_eq!(n.ops.len(), n.layers.len());
        assert!(n.ops.iter().all(|o| matches!(o, Op::Conv(_))));
        assert!(n.op("c1").is_some());
        assert!(n.op("nope").is_none());
    }

    #[test]
    fn from_ops_lowers_in_execution_order() {
        let n = Network::from_ops(
            "mixed",
            vec![
                Op::Conv(ConvLayer::new("stem", 8, 8, 3, 16, 3, 1, 1)),
                Op::gemm("fc", 64, 16, 32).unwrap(),
                Op::attention("attn", 64, 2, 32, 16).unwrap(),
            ],
        );
        assert_eq!(n.ops.len(), 3);
        // stem + fc + (3 proj + 2 heads × 2 + out proj) attention layers.
        assert_eq!(n.layers.len(), 1 + 1 + 8);
        assert_eq!(n.layers[0].name, "stem");
        assert_eq!(n.layers[1].name, "fc");
        assert!(n.layers[2].name.starts_with("attn."));
        // Aggregates agree between the op view and the lowered view.
        let op_macs: u64 = n.ops.iter().map(Op::macs).sum();
        assert_eq!(n.total_macs(), op_macs);
        // Dense-equivalent passes non-conv ops through untouched.
        let d = n.dense_equivalent();
        assert_eq!(d.layers.len(), n.layers.len());
        assert_eq!(d.ops.len(), n.ops.len());
    }

    #[test]
    #[should_panic]
    fn empty_network_rejected() {
        Network::new("empty", vec![]);
    }

    #[test]
    #[should_panic]
    fn empty_op_network_rejected() {
        Network::from_ops("empty", vec![]);
    }
}
