//! A network = named ordered list of conv layers, plus aggregate queries.

use super::layer::{ConvLayer, DataTypes};

/// A CNN's convolution stack (the only part the paper's analysis touches).
#[derive(Clone, Debug)]
pub struct Network {
    /// Paper-facing name, e.g. `"AlexNet"`.
    pub name: String,
    /// Conv layers in execution order.
    pub layers: Vec<ConvLayer>,
}

impl Network {
    /// A named network over a non-empty conv stack.
    pub fn new(name: &str, layers: Vec<ConvLayer>) -> Self {
        assert!(!layers.is_empty(), "network {name} has no layers");
        Network { name: name.to_string(), layers }
    }

    /// Minimum bandwidth (activations moved if every tensor is read once
    /// and written once — the paper's Table III quantity):
    /// `sum_l (Wi*Hi*M + Wo*Ho*N)`.
    pub fn min_bandwidth(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| l.input_activations() + l.output_activations())
            .sum()
    }

    /// The Table III floor in **bytes**: every input read once at ifmap
    /// width, every output written once at ofmap width. Full residency
    /// means no partial sum ever crosses the interconnect, so the floor
    /// carries no psum-width term. Equals [`Network::min_bandwidth`] under
    /// the default (uniform one-byte) [`DataTypes`].
    pub fn min_bandwidth_bytes(&self, dt: &DataTypes) -> f64 {
        self.layers
            .iter()
            .map(|l| {
                l.input_activations() as f64 * dt.ifmap_bytes()
                    + l.output_activations() as f64 * dt.ofmap_bytes()
            })
            .sum()
    }

    /// Total MACs over all conv layers.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Total conv weights.
    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(|l| l.weights()).sum()
    }

    /// Find a layer by name.
    pub fn layer(&self, name: &str) -> Option<&ConvLayer> {
        self.layers.iter().find(|l| l.name == name)
    }

    /// The network with every layer's `groups` erased — see
    /// [`ConvLayer::dense_equivalent`]. Minimum bandwidth is unchanged;
    /// partitioned bandwidth generally grows.
    pub fn dense_equivalent(&self) -> Network {
        Network {
            name: self.name.clone(),
            layers: self.layers.iter().map(|l| l.dense_equivalent()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Network {
        Network::new(
            "tiny",
            vec![
                ConvLayer::new("c1", 8, 8, 3, 16, 3, 1, 1),
                ConvLayer::new("c2", 8, 8, 16, 32, 3, 1, 1),
            ],
        )
    }

    #[test]
    fn min_bandwidth_sums_layers() {
        let n = tiny();
        let expect = (8 * 8 * 3 + 8 * 8 * 16) + (8 * 8 * 16 + 8 * 8 * 32);
        assert_eq!(n.min_bandwidth(), expect as u64);
    }

    #[test]
    fn min_bandwidth_bytes_weights_tensors_independently() {
        let n = tiny();
        // default precision: bytes == elements
        assert_eq!(n.min_bandwidth_bytes(&DataTypes::default()), n.min_bandwidth() as f64);
        // psum width does NOT appear in the floor (full residency)
        let wide_psum = DataTypes::parse("8:8:32:8").unwrap();
        assert_eq!(n.min_bandwidth_bytes(&wide_psum), n.min_bandwidth() as f64);
        // 16-bit ofmaps double the write half only
        let wide_out = DataTypes::new(8, 8, 32, 16).unwrap();
        let ins = (8 * 8 * 3 + 8 * 8 * 16) as f64;
        let outs = (8 * 8 * 16 + 8 * 8 * 32) as f64;
        assert_eq!(n.min_bandwidth_bytes(&wide_out), ins + 2.0 * outs);
    }

    #[test]
    fn layer_lookup() {
        let n = tiny();
        assert!(n.layer("c2").is_some());
        assert!(n.layer("nope").is_none());
    }

    #[test]
    fn macs_accumulate() {
        let n = tiny();
        assert_eq!(n.total_macs(), (8 * 8 * 16 * 3 * 9 + 8 * 8 * 32 * 16 * 9) as u64);
    }

    #[test]
    #[should_panic]
    fn empty_network_rejected() {
        Network::new("empty", vec![]);
    }
}
