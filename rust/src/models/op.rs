//! The operator abstraction: conv, GEMM and attention workloads behind
//! one type, all lowered onto the paper's conv equations.
//!
//! The paper's bandwidth model (eqs. 2–4) and the eq.-7 optimum apply to
//! any operator that accumulates over a reduction dimension and spills
//! wide partial sums — a GEMM is exactly the 1×1-conv special case:
//!
//! ```text
//! Gemm { m_rows, k_dim, n_cols }
//!   ≡ ConvLayer { wi: 1, hi: m_rows, m: k_dim, n: n_cols, k: 1, s: 1 }
//! ```
//!
//! Under that mapping eq. 2 reads `B_i = m_rows·k_dim·ceil(n_cols/n)`
//! (the A matrix re-read once per B-column block), eq. 3 reads
//! `B_o = m_rows·n_cols·(2·ceil(k_dim/m)−1)` (C-tile partial sums written
//! and read back once per K-slice), and eq. 7's `m*` optimizes the
//! K-dimension split — element-for-element what the conv equations give,
//! pinned by `rust/tests/op_equivalence.rs`. An attention layer is a
//! fixed DAG of GEMMs (QKV projections, per-head `Q·Kᵀ` and `attn·V`,
//! output projection), so it lowers to a list of 1×1 convs; softmax and
//! residual adds are elementwise and carry no reduction, so the
//! first-order model ignores them (as it ignores pooling/ReLU for CNNs).
//!
//! [`Op::lower`] is the single bridge: everything downstream of
//! [`Network`](super::Network) (analytics, sim, dse, report) consumes the
//! lowered [`ConvLayer`] list, so conv networks reproduce their pinned
//! goldens byte-for-byte and the new workload classes ride the same
//! equations, byte model and memo cache.

use anyhow::{bail, Result};

use super::layer::ConvLayer;

/// The workload class of an [`Op`] (stable lowercase labels for tables
/// and the wire).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// A convolution layer.
    Conv,
    /// A dense matrix multiply.
    Gemm,
    /// A multi-head self-attention layer.
    Attention,
}

impl OpKind {
    /// Stable lowercase label (`"conv"`/`"gemm"`/`"attention"`).
    pub fn label(&self) -> &'static str {
        match self {
            OpKind::Conv => "conv",
            OpKind::Gemm => "gemm",
            OpKind::Attention => "attention",
        }
    }
}

/// One operator of a [`Network`](super::Network): the typed source of
/// truth a network is built from, lowered to [`ConvLayer`]s for every
/// downstream consumer (see the module docs for the mapping).
#[derive(Clone, Debug)]
pub enum Op {
    /// A convolution layer — lowers to itself.
    Conv(ConvLayer),
    /// A dense GEMM `C[m_rows×n_cols] = A[m_rows×k_dim] · W[k_dim×n_cols]`
    /// with `A` as the streamed activation and `W` as weights.
    Gemm {
        /// Operator name (becomes the lowered layer name).
        name: String,
        /// Output rows (the streamed/batch-like dimension, e.g. tokens).
        m_rows: usize,
        /// Reduction depth — the dimension partial sums accumulate over.
        k_dim: usize,
        /// Output columns (weight-stationary dimension).
        n_cols: usize,
    },
    /// Multi-head self-attention over `seq` tokens of width `d_model`,
    /// with `heads` heads of width `d_head`. Lowers to the GEMM DAG
    /// `3× QKV projection, per-head Q·Kᵀ and attn·V, output projection`.
    Attention {
        /// Operator name (prefix of the lowered layer names).
        name: String,
        /// Sequence length (tokens, incl. any class token).
        seq: usize,
        /// Number of attention heads.
        heads: usize,
        /// Model (residual-stream) width.
        d_model: usize,
        /// Per-head width.
        d_head: usize,
    },
}

impl Op {
    /// Wrap a conv layer (always valid — the layer validated on
    /// construction).
    pub fn conv(layer: ConvLayer) -> Op {
        Op::Conv(layer)
    }

    /// Fallibly construct a GEMM op (every dimension must be positive) —
    /// hostile-input entry point, like [`ConvLayer::try_new`].
    pub fn gemm(name: &str, m_rows: usize, k_dim: usize, n_cols: usize) -> Result<Op> {
        if m_rows == 0 || k_dim == 0 || n_cols == 0 {
            bail!("invalid gemm {name}: dimensions {m_rows}x{k_dim}x{n_cols} must be positive");
        }
        Ok(Op::Gemm { name: name.to_string(), m_rows, k_dim, n_cols })
    }

    /// Fallibly construct an attention op (every dimension must be
    /// positive).
    pub fn attention(
        name: &str,
        seq: usize,
        heads: usize,
        d_model: usize,
        d_head: usize,
    ) -> Result<Op> {
        if seq == 0 || heads == 0 || d_model == 0 || d_head == 0 {
            bail!(
                "invalid attention {name}: seq={seq} heads={heads} \
                 d_model={d_model} d_head={d_head} must be positive"
            );
        }
        Ok(Op::Attention { name: name.to_string(), seq, heads, d_model, d_head })
    }

    /// Operator name.
    pub fn name(&self) -> &str {
        match self {
            Op::Conv(l) => &l.name,
            Op::Gemm { name, .. } | Op::Attention { name, .. } => name,
        }
    }

    /// Workload class.
    pub fn kind(&self) -> OpKind {
        match self {
            Op::Conv(_) => OpKind::Conv,
            Op::Gemm { .. } => OpKind::Gemm,
            Op::Attention { .. } => OpKind::Attention,
        }
    }

    /// The attention GEMM DAG in execution order (empty for other kinds):
    /// Q/K/V projections, then per-head `Q·Kᵀ` (scores) and `attn·V`
    /// (context), then the output projection. Softmax is elementwise and
    /// carries no reduction, so it contributes no GEMM.
    fn attention_gemms(&self) -> Vec<Op> {
        let Op::Attention { name, seq, heads, d_model, d_head } = self else {
            return Vec::new();
        };
        let (seq, heads, d_model, d_head) = (*seq, *heads, *d_model, *d_head);
        let inner = heads * d_head;
        let mut gemms = Vec::with_capacity(4 + 2 * heads);
        for proj in ["q", "k", "v"] {
            gemms.push(Op::Gemm {
                name: format!("{name}.{proj}"),
                m_rows: seq,
                k_dim: d_model,
                n_cols: inner,
            });
        }
        for h in 0..heads {
            // Q·Kᵀ: every pair of tokens, reduced over the head width.
            gemms.push(Op::Gemm {
                name: format!("{name}.h{h}.score"),
                m_rows: seq,
                k_dim: d_head,
                n_cols: seq,
            });
            // attn·V: context vectors, reduced over the sequence.
            gemms.push(Op::Gemm {
                name: format!("{name}.h{h}.ctx"),
                m_rows: seq,
                k_dim: seq,
                n_cols: d_head,
            });
        }
        gemms.push(Op::Gemm {
            name: format!("{name}.proj"),
            m_rows: seq,
            k_dim: inner,
            n_cols: d_model,
        });
        gemms
    }

    /// Lower to the conv layers every downstream consumer evaluates: a
    /// conv to itself, a GEMM to its 1×1-conv equivalent (`hi = m_rows`,
    /// `m = k_dim`, `n = n_cols` — so spatial striping tiles the GEMM's
    /// row dimension and eq. 3 prices its K-dimension partial sums), an
    /// attention op to its lowered GEMM DAG.
    ///
    /// The worked `d_model = 192` example of `docs/MODEL.md` ("GEMM and
    /// attention on the same equations"), pinned:
    ///
    /// ```
    /// use psim::analytics::bandwidth::{layer_bandwidth, layer_bandwidth_bytes, ControllerMode};
    /// use psim::analytics::partition::{partition_layer, partition_layer_bytes, Strategy};
    /// use psim::models::{DataTypes, Op};
    ///
    /// // ViT-Tiny's MLP fc1: C[197×768] = A[197×192] · W[192×768], P = 512.
    /// let fc1 = Op::gemm("fc1", 197, 192, 768).unwrap();
    /// let layers = fc1.lower();
    /// let l = &layers[0];
    /// let mode = ControllerMode::Passive;
    ///
    /// // Element optimum: eq. 7 collapses to m* = sqrt(2·512) = 32.
    /// let p = partition_layer(l, 512, Strategy::Optimal, mode);
    /// assert_eq!((p.m, p.n), (32, 16));
    /// let bw = layer_bandwidth(l, p.m, p.n, mode);
    /// assert_eq!(bw.input, 1815552.0);  // eq. 2: 197·192·ceil(768/16)
    /// assert_eq!(bw.output, 1664256.0); // eq. 3: 197·768·(2·ceil(192/32)−1)
    ///
    /// // Byte optimum under wide psums: m*_bytes = 2·m* = 64.
    /// let dt = DataTypes::parse("8:8:32:8").unwrap();
    /// let pb = partition_layer_bytes(l, 512, Strategy::Optimal, mode, &dt);
    /// assert_eq!((pb.m, pb.n), (64, 8));
    /// let bytes = layer_bandwidth_bytes(l, pb.m, pb.n, mode, &dt);
    /// assert_eq!(bytes.input, 3631104.0);
    /// assert_eq!(bytes.psum, 2420736.0);
    /// assert_eq!(bytes.ofmap, 151296.0);
    /// assert_eq!(bytes.input + bytes.psum + bytes.ofmap, 6203136.0);
    /// ```
    pub fn lower(&self) -> Vec<ConvLayer> {
        match self {
            Op::Conv(l) => vec![l.clone()],
            Op::Gemm { name, m_rows, k_dim, n_cols } => {
                vec![ConvLayer::new(name, 1, *m_rows, *k_dim, *n_cols, 1, 1, 0)]
            }
            Op::Attention { .. } => {
                self.attention_gemms().iter().flat_map(|g| g.lower()).collect()
            }
        }
    }

    /// Input activations streamed in once: `Wi·Hi·M` per conv,
    /// `m_rows·k_dim` per GEMM, summed over the lowered DAG for
    /// attention (each stage's input counted once, intermediates
    /// included).
    pub fn input_activations(&self) -> u64 {
        match self {
            Op::Conv(l) => l.input_activations(),
            Op::Gemm { m_rows, k_dim, .. } => *m_rows as u64 * *k_dim as u64,
            Op::Attention { .. } => self.attention_gemms().iter().map(Op::input_activations).sum(),
        }
    }

    /// Output activations written once: `Wo·Ho·N` per conv,
    /// `m_rows·n_cols` per GEMM, summed over the lowered DAG for
    /// attention.
    pub fn output_activations(&self) -> u64 {
        match self {
            Op::Conv(l) => l.output_activations(),
            Op::Gemm { m_rows, n_cols, .. } => *m_rows as u64 * *n_cols as u64,
            Op::Attention { .. } => self.attention_gemms().iter().map(Op::output_activations).sum(),
        }
    }

    /// Weight parameters: `N·(M/g)·K²` per conv, `k_dim·n_cols` per GEMM.
    /// Attention weights are its four projection GEMMs; the per-head
    /// `Q·Kᵀ`/`attn·V` stages multiply two *activations* and carry no
    /// weights — the lowered model streams one operand as eq. 2 input
    /// and treats the other as the layer's (once-loaded) kernel, which
    /// is exactly how a weight-stationary array executes them.
    pub fn weights(&self) -> u64 {
        match self {
            Op::Conv(l) => l.weights(),
            Op::Gemm { k_dim, n_cols, .. } => *k_dim as u64 * *n_cols as u64,
            Op::Attention { heads, d_model, d_head, .. } => {
                // q + k + v + proj: 4 × d_model·(heads·d_head).
                4 * *d_model as u64 * (*heads as u64 * *d_head as u64)
            }
        }
    }

    /// Total multiply-accumulates: `Wo·Ho·N·(M/g)·K²` per conv,
    /// `m_rows·k_dim·n_cols` per GEMM, summed over the DAG for attention.
    pub fn macs(&self) -> u64 {
        match self {
            Op::Conv(l) => l.macs(),
            Op::Gemm { m_rows, k_dim, n_cols, .. } => {
                *m_rows as u64 * *k_dim as u64 * *n_cols as u64
            }
            Op::Attention { .. } => self.attention_gemms().iter().map(Op::macs).sum(),
        }
    }

    /// Reduction depth: how many products accumulate into one output
    /// element — `(M/g)·K²` per conv, `k_dim` per GEMM, the deepest
    /// lowered stage for attention. This is the dimension eq. 3's
    /// `it = ceil(M/m)` splits, i.e. what makes partial sums spill.
    pub fn reduction_depth(&self) -> u64 {
        match self {
            Op::Conv(l) => l.m_per_group() as u64 * (l.k * l.k) as u64,
            Op::Gemm { k_dim, .. } => *k_dim as u64,
            Op::Attention { .. } => {
                self.attention_gemms().iter().map(Op::reduction_depth).max().unwrap_or(0)
            }
        }
    }

    /// Partial-sum footprint: live accumulator elements while the op's
    /// widest stage computes — its output elements (`Wo·Ho·N` /
    /// `m_rows·n_cols`), each held at psum width until the final
    /// quantized write. For attention this is the largest lowered stage
    /// (the `seq×seq` score matrix once `seq > heads·d_head`).
    pub fn psum_footprint(&self) -> u64 {
        match self {
            Op::Conv(_) | Op::Gemm { .. } => self.output_activations(),
            Op::Attention { .. } => {
                self.attention_gemms().iter().map(Op::psum_footprint).max().unwrap_or(0)
            }
        }
    }
}

impl std::fmt::Display for Op {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Op::Conv(l) => write!(f, "{l}"),
            Op::Gemm { name, m_rows, k_dim, n_cols } => {
                write!(f, "{name}: gemm {m_rows}x{k_dim} . {k_dim}x{n_cols}")
            }
            Op::Attention { name, seq, heads, d_model, d_head } => {
                write!(f, "{name}: attention seq{seq} h{heads} d{d_model}/{d_head}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gemm() -> Op {
        Op::gemm("fc", 197, 192, 768).unwrap()
    }

    fn attn() -> Op {
        Op::attention("attn", 197, 3, 192, 64).unwrap()
    }

    #[test]
    fn gemm_lowers_to_one_by_one_conv() {
        let layers = gemm().lower();
        assert_eq!(layers.len(), 1);
        let l = &layers[0];
        assert_eq!((l.wi, l.hi, l.m, l.n), (1, 197, 192, 768));
        assert_eq!((l.k, l.stride, l.pad, l.groups), (1, 1, 0, 1));
        assert_eq!((l.wo(), l.ho()), (1, 197));
    }

    #[test]
    fn gemm_derived_quantities_match_lowered_conv() {
        let op = gemm();
        let layers = op.lower();
        let l = &layers[0];
        assert_eq!(op.input_activations(), l.input_activations());
        assert_eq!(op.output_activations(), l.output_activations());
        assert_eq!(op.weights(), l.weights());
        assert_eq!(op.macs(), l.macs());
        assert_eq!(op.reduction_depth(), l.m as u64);
        assert_eq!(op.psum_footprint(), l.output_activations());
    }

    #[test]
    fn conv_op_is_transparent() {
        let l = ConvLayer::new("conv3", 13, 13, 192, 384, 3, 1, 1);
        let op = Op::conv(l.clone());
        assert_eq!(op.kind(), OpKind::Conv);
        assert_eq!(op.lower(), vec![l.clone()]);
        assert_eq!(op.macs(), l.macs());
        assert_eq!(op.reduction_depth(), (192 * 9) as u64);
        assert_eq!(op.psum_footprint(), l.output_activations());
    }

    #[test]
    fn attention_lowering_has_the_textbook_shape() {
        let op = attn();
        let layers = op.lower();
        // 3 projections + 3 heads × (score + ctx) + output projection.
        assert_eq!(layers.len(), 3 + 3 * 2 + 1);
        // MACs: 4·seq·d_model·inner + heads·2·seq²·d_head.
        let proj = 4u64 * 197 * 192 * 192;
        let heads = 3u64 * 2 * 197 * 197 * 64;
        assert_eq!(op.macs(), proj + heads);
        assert_eq!(op.macs(), layers.iter().map(|l| l.macs()).sum::<u64>());
        // Weights: the four projections only.
        assert_eq!(op.weights(), 4 * 192 * 192);
        let lowered_weights: u64 = layers.iter().map(|l| l.weights()).sum();
        // The lowered model charges the score/ctx "kernels" as weights
        // (they are really the K/V activations): strictly more.
        assert!(lowered_weights > op.weights());
        // Deepest reduction: the ctx stage reduces over seq=197 > 192.
        assert_eq!(op.reduction_depth(), 197);
        // Widest psum stage: the 197×197 score matrix.
        assert_eq!(op.psum_footprint(), 197 * 197);
        // Aggregates delegate to the same DAG as lower().
        assert_eq!(
            op.input_activations(),
            layers.iter().map(|l| l.input_activations()).sum::<u64>()
        );
        assert_eq!(
            op.output_activations(),
            layers.iter().map(|l| l.output_activations()).sum::<u64>()
        );
    }

    #[test]
    fn lowered_attention_names_are_unique() {
        let names: Vec<String> = attn().lower().into_iter().map(|l| l.name).collect();
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(names.len(), dedup.len(), "{names:?}");
    }

    #[test]
    fn constructors_reject_zero_dimensions() {
        assert!(Op::gemm("z", 0, 192, 768).is_err());
        assert!(Op::gemm("z", 197, 192, 0).is_err());
        assert!(Op::attention("z", 197, 0, 192, 64).is_err());
        let err = Op::attention("z", 0, 3, 192, 64).unwrap_err();
        assert!(err.to_string().contains("invalid attention z"), "{err}");
    }

    #[test]
    fn kind_labels_are_stable() {
        assert_eq!(Op::conv(ConvLayer::new("c", 8, 8, 3, 8, 3, 1, 1)).kind().label(), "conv");
        assert_eq!(gemm().kind().label(), "gemm");
        assert_eq!(attn().kind().label(), "attention");
    }
}
