//! torchvision AlexNet `features` conv stack (the 64-channel variant).
//!
//! Resolution trace @224: conv1(k11,s4,p2)->55, pool->27, conv2->27,
//! pool->13, conv3..5 -> 13.

use crate::models::{ConvLayer, Network};

/// AlexNet's five-conv stack (paper profile).
pub fn alexnet() -> Network {
    Network::new(
        "AlexNet",
        vec![
            ConvLayer::new("conv1", 224, 224, 3, 64, 11, 4, 2),
            ConvLayer::new("conv2", 27, 27, 64, 192, 5, 1, 2),
            ConvLayer::new("conv3", 13, 13, 192, 384, 3, 1, 1),
            ConvLayer::new("conv4", 13, 13, 384, 256, 3, 1, 1),
            ConvLayer::new("conv5", 13, 13, 256, 256, 3, 1, 1),
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_alexnet_min_bw() {
        // Paper Table III: 0.823 M activations/inference.
        let bw = alexnet().min_bandwidth() as f64 / 1e6;
        assert!((bw - 0.823).abs() < 0.001, "got {bw}");
    }

    #[test]
    fn five_conv_layers() {
        assert_eq!(alexnet().layers.len(), 5);
    }

    #[test]
    fn conv1_output_is_55() {
        let net = alexnet();
        assert_eq!(net.layers[0].wo(), 55);
    }
}
