//! torchvision SqueezeNet 1.0.
//!
//! conv1 (k7,s2, no pad) @224 -> 109, max-pools are k3/s2 with
//! ceil_mode=True: 109 -> 54 -> 27 -> 13. Fire modules: squeeze 1x1 then
//! parallel expand1x1 + expand3x3(p1), channel-concat. The final 1x1
//! 512->1000 classifier conv is included — calibration against Table III
//! (7.304 M) requires it (without it the total is 7.048 M).

use crate::models::{ConvLayer, Network};

/// Append one fire module's three convs.
fn fire(layers: &mut Vec<ConvLayer>, id: usize, res: usize, cin: usize, s1: usize, e: usize) {
    layers.push(ConvLayer::new(&format!("fire{id}.squeeze"), res, res, cin, s1, 1, 1, 0));
    layers.push(ConvLayer::new(&format!("fire{id}.expand1x1"), res, res, s1, e, 1, 1, 0));
    layers.push(ConvLayer::new(&format!("fire{id}.expand3x3"), res, res, s1, e, 3, 1, 1));
}

/// SqueezeNet 1.0's conv stack (paper profile).
pub fn squeezenet1_0() -> Network {
    let mut layers = vec![ConvLayer::new("conv1", 224, 224, 3, 96, 7, 2, 0)];
    // pool1: 109 -> 54 (ceil_mode)
    fire(&mut layers, 2, 54, 96, 16, 64); // out 128
    fire(&mut layers, 3, 54, 128, 16, 64); // out 128
    fire(&mut layers, 4, 54, 128, 32, 128); // out 256
    // pool2: 54 -> 27
    fire(&mut layers, 5, 27, 256, 32, 128); // out 256
    fire(&mut layers, 6, 27, 256, 48, 192); // out 384
    fire(&mut layers, 7, 27, 384, 48, 192); // out 384
    fire(&mut layers, 8, 27, 384, 64, 256); // out 512
    // pool3: 27 -> 13
    fire(&mut layers, 9, 13, 512, 64, 256); // out 512
    layers.push(ConvLayer::new("classifier", 13, 13, 512, 1000, 1, 1, 0));
    Network::new("SqueezeNet", layers)
}

/// SqueezeNet 1.1 (extension network): 3x3/s2 conv1 with 64 channels and
/// earlier pooling — same accuracy as 1.0 at ~2.4x less compute.
pub fn squeezenet1_1() -> Network {
    let mut layers = vec![ConvLayer::new("conv1", 224, 224, 3, 64, 3, 2, 0)]; // ->111
    // pool1 (ceil): 111 -> 55
    fire(&mut layers, 2, 55, 64, 16, 64); // out 128
    fire(&mut layers, 3, 55, 128, 16, 64); // out 128
    // pool2: 55 -> 27
    fire(&mut layers, 4, 27, 128, 32, 128); // out 256
    fire(&mut layers, 5, 27, 256, 32, 128); // out 256
    // pool3: 27 -> 13
    fire(&mut layers, 6, 13, 256, 48, 192); // out 384
    fire(&mut layers, 7, 13, 384, 48, 192); // out 384
    fire(&mut layers, 8, 13, 384, 64, 256); // out 512
    fire(&mut layers, 9, 13, 512, 64, 256); // out 512
    layers.push(ConvLayer::new("classifier", 13, 13, 512, 1000, 1, 1, 0));
    Network::new("SqueezeNet1.1", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_squeezenet_min_bw() {
        // Paper Table III: 7.304 M activations/inference.
        let bw = squeezenet1_0().min_bandwidth() as f64 / 1e6;
        assert!((bw - 7.304).abs() < 0.02, "got {bw}");
    }

    #[test]
    fn layer_count() {
        // conv1 + 8 fires x 3 + classifier = 26
        assert_eq!(squeezenet1_0().layers.len(), 26);
    }

    #[test]
    fn squeezenet11_structure() {
        let net = squeezenet1_1();
        assert_eq!(net.layers.len(), 26);
        assert_eq!(net.layers[0].wo(), 111);
        // 1.1 moves less data than 1.0
        assert!(net.min_bandwidth() < squeezenet1_0().min_bandwidth());
    }

    #[test]
    fn conv1_resolution() {
        let net = squeezenet1_0();
        assert_eq!(net.layers[0].wo(), 109);
    }

    #[test]
    fn fire_concat_channels_feed_next() {
        let net = squeezenet1_0();
        // fire3.squeeze input channels must be fire2's concat = 2 * 64.
        assert_eq!(net.layer("fire3.squeeze").unwrap().m, 128);
        assert_eq!(net.layer("fire9.squeeze").unwrap().m, 512);
    }
}
