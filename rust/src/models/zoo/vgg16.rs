//! torchvision VGG-16 (configuration "D"): thirteen 3x3/p1 convs in five
//! blocks separated by 2x2 max-pools.
//!
//! Calibration note: the paper's Table III reports 20.095 M activations
//! for VGG-16 while this (standard) definition yields 22.629 M. AlexNet,
//! ResNet-18 and others match the torchvision definitions exactly, so we
//! keep the canonical config-D stack and record the delta in
//! EXPERIMENTS.md rather than reverse-engineering a non-standard VGG.

use crate::models::{ConvLayer, Network};

fn vgg_stack(name: &str, cfg: &[(usize, &[usize])]) -> Network {
    let mut layers = Vec::new();
    let mut cin = 3usize;
    for (b, (res, widths)) in cfg.iter().enumerate() {
        for (i, &cout) in widths.iter().enumerate() {
            layers.push(ConvLayer::new(
                &format!("conv{}_{}", b + 1, i + 1),
                *res,
                *res,
                cin,
                cout,
                3,
                1,
                1,
            ));
            cin = cout;
        }
    }
    Network::new(name, layers)
}

/// Canonical VGG-16 (configuration D, 13 convs).
pub fn vgg16() -> Network {
    vgg_stack(
        "VGG-16",
        &[
            (224, &[64, 64]),
            (112, &[128, 128]),
            (56, &[256, 256, 256]),
            (28, &[512, 512, 512]),
            (14, &[512, 512, 512]),
        ],
    )
}

/// VGG-11 (configuration A, 8 convs) — extension network.
pub fn vgg11() -> Network {
    vgg_stack(
        "VGG-11",
        &[
            (224, &[64]),
            (112, &[128]),
            (56, &[256, 256]),
            (28, &[512, 512]),
            (14, &[512, 512]),
        ],
    )
}

/// VGG-19 (configuration E, 16 convs) — extension network.
pub fn vgg19() -> Network {
    vgg_stack(
        "VGG-19",
        &[
            (224, &[64, 64]),
            (112, &[128, 128]),
            (56, &[256, 256, 256, 256]),
            (28, &[512, 512, 512, 512]),
            (14, &[512, 512, 512, 512]),
        ],
    )
}

/// VGG-13 (configuration B, 10 convs).
///
/// Calibration shows the paper's "VGG-16" rows were computed on these
/// shapes: Table III prints 20.095 M (VGG-13 = 20.020 M, -0.4%; true
/// VGG-16 = 22.629 M, +12.6%), and the Table II sweep fits within a few
/// percent for VGG-13 but is ~1.5x off for config D. The paper profile
/// therefore evaluates VGG-13 under the "VGG-16" label; this function
/// keeps its honest name.
pub fn vgg13() -> Network {
    vgg_stack(
        "VGG-13",
        &[
            (224, &[64, 64]),
            (112, &[128, 128]),
            (56, &[256, 256]),
            (28, &[512, 512]),
            (14, &[512, 512]),
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thirteen_convs() {
        assert_eq!(vgg16().layers.len(), 13);
    }

    #[test]
    fn canonical_min_bw() {
        // Standard config-D value; the paper prints 20.095 (see module doc).
        let bw = vgg16().min_bandwidth() as f64 / 1e6;
        assert!((bw - 22.629).abs() < 0.01, "got {bw}");
    }

    #[test]
    fn vgg13_matches_paper_table3() {
        let bw = vgg13().min_bandwidth() as f64 / 1e6;
        assert!((bw - 20.020).abs() < 0.001, "got {bw}");
        assert!((bw - 20.095).abs() / 20.095 < 0.005, "got {bw} vs paper 20.095");
    }

    #[test]
    fn vgg13_has_ten_convs() {
        assert_eq!(vgg13().layers.len(), 10);
    }

    #[test]
    fn vgg_family_sizes() {
        assert_eq!(vgg11().layers.len(), 8);
        assert_eq!(vgg19().layers.len(), 16);
        // monotone: deeper config -> more bandwidth
        assert!(vgg11().min_bandwidth() < vgg13().min_bandwidth());
        assert!(vgg13().min_bandwidth() < vgg16().min_bandwidth());
        assert!(vgg16().min_bandwidth() < vgg19().min_bandwidth());
    }

    #[test]
    fn channel_chain() {
        let net = vgg16();
        assert_eq!(net.layers[0].m, 3);
        assert_eq!(net.layers[12].n, 512);
        for w in net.layers.windows(2) {
            // blocks chain: next input channels == previous output channels
            assert_eq!(w[1].m, w[0].n);
        }
    }

    #[test]
    fn all_same_padding() {
        for l in vgg16().layers {
            assert_eq!(l.wo(), l.wi);
            assert_eq!(l.k, 3);
        }
    }
}
