//! torchvision-faithful conv-layer definitions of the eight CNNs the paper
//! evaluates (Tables I–III), at 224x224 RGB input.
//!
//! Why torchvision: the paper's Table III minimum-bandwidth numbers match
//! the torchvision model definitions exactly for AlexNet (0.823 M
//! activations requires conv1 = 64 channels, i.e. the torchvision AlexNet,
//! not the original 96-channel one) and ResNet-18 (4.666 M matches the
//! v1.5 BasicBlock stack including downsample 1x1 convs). We therefore
//! encode all eight networks from the torchvision sources; residual
//! deviations from the paper are recorded in EXPERIMENTS.md.
//!
//! Only convolution layers are listed (the paper's analysis covers conv
//! only); pooling is applied implicitly by giving the next layer the
//! pooled input resolution. Classifier/aux convs are included only where
//! calibration against Table III shows the paper counted them.

mod alexnet;
mod googlenet;
mod mnasnet;
mod mobilenet_v1;
mod mobilenet_v2;
mod resnet;
mod squeezenet;
mod vgg16;
mod vit;

pub use alexnet::alexnet;
pub use googlenet::googlenet;
pub use mnasnet::mnasnet1_0;
pub use mobilenet_v1::mobilenet_v1;
pub use mobilenet_v2::mobilenet_v2;
pub use resnet::{resnet18, resnet34, resnet50, resnet50_classic};
pub use squeezenet::{squeezenet1_0, squeezenet1_1};
pub use vgg16::{vgg11, vgg13, vgg16, vgg19};
pub use vit::vit_tiny;

use super::network::Network;

/// The eight networks under their paper labels, with the *calibrated*
/// shapes that reproduce the published Tables I–III (the "paper profile").
///
/// Forensic findings from calibrating against Table III + the Table II
/// sweep (full derivation in EXPERIMENTS.md §Calibration):
///
/// * "AlexNet", "SqueezeNet", "GoogleNet", "ResNet-18": torchvision
///   definitions, faithful.
/// * "VGG-16" is actually **VGG-13** (min BW 20.020 vs printed 20.095;
///   true VGG-16 gives 22.629).
/// * "ResNet-50" is **ResNeXt-50 32x4d** (exact Table III match at
///   28.349 M) with groups *ignored* in the partitioning math.
/// * "MobileNet" is MobileNet**V1** (10.186 vs printed 10.273; V2 gives
///   13.444), with groups respected.
/// * "MNASNet" is torchvision mnasnet1_0 with groups *ignored*
///   (dense-equivalent fits Table II within ~2%; faithful grouping is
///   ~10x lower).
pub fn paper_networks() -> Vec<Network> {
    vec![
        alexnet(),
        relabel(vgg13(), "VGG-16"),
        squeezenet1_0(),
        googlenet(),
        resnet18(),
        resnet50().dense_equivalent(),
        mobilenet_v1(),
        mnasnet1_0().dense_equivalent(),
    ]
}

/// The same eight networks with their *architecturally faithful* shapes
/// (true VGG-16, grouped ResNeXt/MNASNet convs). Min bandwidth matches
/// [`paper_networks`] except VGG; partitioned bandwidth is what a real
/// accelerator exploiting group structure would see.
pub fn faithful_networks() -> Vec<Network> {
    vec![
        alexnet(),
        vgg16(),
        squeezenet1_0(),
        googlenet(),
        resnet18(),
        resnet50(),
        mobilenet_v1(),
        mnasnet1_0(),
    ]
}

/// Extra networks beyond the paper's eight (extensions/ablations),
/// including the GEMM/attention [`vit_tiny`] transformer.
pub fn extra_networks() -> Vec<Network> {
    vec![
        mobilenet_v2(),
        resnet34(),
        resnet50_classic(),
        squeezenet1_1(),
        vgg11(),
        vgg13(),
        vgg19(),
        vit_tiny(),
    ]
}

fn relabel(mut net: Network, name: &str) -> Network {
    net.name = name.to_string();
    net
}

/// Canonical form for name matching: lowercase, punctuation stripped.
fn normalize(name: &str) -> String {
    name.to_ascii_lowercase().replace(['-', '_', '.'], "")
}

/// Look up a network by (case-insensitive) name — paper profile first,
/// then the extension networks.
pub fn by_name(name: &str) -> Option<Network> {
    let key = normalize(name);
    paper_networks()
        .into_iter()
        .chain(extra_networks())
        .find(|n| normalize(&n.name) == key)
}

/// Look up among the *architecturally faithful* eight (same matching
/// rules as [`by_name`]); `None` if the name isn't one of them.
pub fn faithful_by_name(name: &str) -> Option<Network> {
    let key = normalize(name);
    faithful_networks().into_iter().find(|n| normalize(&n.name) == key)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eight_networks_in_paper_order() {
        let names: Vec<String> = paper_networks().into_iter().map(|n| n.name).collect();
        assert_eq!(
            names,
            vec![
                "AlexNet",
                "VGG-16",
                "SqueezeNet",
                "GoogleNet",
                "ResNet-18",
                "ResNet-50",
                "MobileNet",
                "MNASNet"
            ]
        );
    }

    #[test]
    fn lookup_tolerates_punctuation() {
        assert!(by_name("resnet-18").is_some());
        assert!(by_name("ResNet_18").is_some());
        assert!(by_name("RESNET18").is_some());
        assert!(by_name("resnet34").is_some(), "extras are searchable");
        assert!(by_name("SqueezeNet1.1").is_some());
        assert!(by_name("vit_tiny").is_some(), "CLI spelling of ViT-Tiny");
        assert!(by_name("ViT-Tiny").is_some());
        assert!(by_name("resnet101").is_none());
    }

    #[test]
    fn faithful_lookup_shadows_paper_profile() {
        // Faithful ResNet-50 is grouped ResNeXt; the paper profile erases
        // groups. The faithful lookup must return the grouped one.
        let f = faithful_by_name("resnet50").unwrap();
        assert!(f.layers.iter().any(|l| l.groups > 1));
        assert!(faithful_by_name("resnet34").is_none(), "extras are not in the faithful eight");
        assert!(faithful_by_name("VGG-16").unwrap().layers.len() == 13, "true config D");
    }

    #[test]
    fn all_layer_names_unique_per_network() {
        for net in paper_networks() {
            let mut names: Vec<&str> = net.layers.iter().map(|l| l.name.as_str()).collect();
            let before = names.len();
            names.sort();
            names.dedup();
            assert_eq!(before, names.len(), "duplicate layer names in {}", net.name);
        }
    }

    #[test]
    fn spatial_chains_are_consistent() {
        // Within each network, every layer's input resolution must be
        // reachable from some previous layer's output (or be the 224 image
        // or a pooled version of a previous output). Weak but useful check:
        // resolutions never increase along the layer list.
        for net in paper_networks() {
            let mut max_seen = 224usize;
            for l in &net.layers {
                assert!(
                    l.wi <= max_seen,
                    "{}: layer {} input {} exceeds any prior resolution",
                    net.name,
                    l.name,
                    l.wi
                );
                max_seen = max_seen.max(l.wo());
            }
        }
    }
}
