//! torchvision MNASNet 1.0 (the paper's [15] reference).
//!
//! Stem 3->32 k3/s2; separable conv (dw 3x3 + 1x1 -> 16); six stacks of
//! inverted residuals with (exp, kernel, stride, out, repeats):
//! (3,3,2,24,3) (3,5,2,40,3) (6,5,2,80,3) (6,3,1,96,2) (6,5,2,192,4)
//! (6,3,1,320,1); head 320->1280 1x1.

use crate::models::{ConvLayer, Network};

#[allow(clippy::too_many_arguments)]
fn inverted_residual(
    layers: &mut Vec<ConvLayer>,
    name: &str,
    res: usize,
    cin: usize,
    cout: usize,
    exp: usize,
    k: usize,
    s: usize,
) -> usize {
    let hidden = cin * exp;
    layers.push(ConvLayer::new(&format!("{name}.expand"), res, res, cin, hidden, 1, 1, 0));
    layers.push(ConvLayer::grouped(
        &format!("{name}.dw"),
        res,
        res,
        hidden,
        hidden,
        k,
        s,
        k / 2,
        hidden,
    ));
    let r = layers.last().unwrap().wo();
    layers.push(ConvLayer::new(&format!("{name}.project"), r, r, hidden, cout, 1, 1, 0));
    r
}

/// MNASNet 1.0's conv stack (paper profile).
pub fn mnasnet1_0() -> Network {
    let mut layers = vec![ConvLayer::new("stem", 224, 224, 3, 32, 3, 2, 1)]; // ->112
    // Separable conv: depthwise 3x3 s1 on 32ch, project to 16.
    layers.push(ConvLayer::grouped("sep.dw", 112, 112, 32, 32, 3, 1, 1, 32));
    layers.push(ConvLayer::new("sep.project", 112, 112, 32, 16, 1, 1, 0));

    let stacks: &[(usize, usize, usize, usize, usize)] = &[
        // (exp, kernel, stride, cout, repeats)
        (3, 3, 2, 24, 3),
        (3, 5, 2, 40, 3),
        (6, 5, 2, 80, 3),
        (6, 3, 1, 96, 2),
        (6, 5, 2, 192, 4),
        (6, 3, 1, 320, 1),
    ];
    let mut res = 112;
    let mut cin = 16;
    let mut blk = 0usize;
    for &(exp, k, s, cout, n) in stacks {
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            res =
                inverted_residual(&mut layers, &format!("ir{blk}"), res, cin, cout, exp, k, stride);
            cin = cout;
            blk += 1;
        }
    }
    layers.push(ConvLayer::new("head", res, res, 320, 1280, 1, 1, 0));
    Network::new("MNASNet", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_mnasnet_min_bw() {
        // Paper Table III: 11.001 M activations/inference.
        let bw = mnasnet1_0().min_bandwidth() as f64 / 1e6;
        assert!((bw - 11.001).abs() < 0.05, "got {bw}");
    }

    #[test]
    fn layer_count() {
        // stem + sep(2) + 16 blocks x 3 + head = 1 + 2 + 48 + 1 = 52
        assert_eq!(mnasnet1_0().layers.len(), 52);
    }

    #[test]
    fn five_by_five_depthwise_present() {
        let net = mnasnet1_0();
        assert!(net.layers.iter().any(|l| l.k == 5 && l.is_depthwise()));
    }

    #[test]
    fn final_resolution_is_7() {
        assert_eq!(mnasnet1_0().layers.last().unwrap().wo(), 7);
    }
}
