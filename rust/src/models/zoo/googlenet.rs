//! torchvision GoogLeNet (Inception v1), aux classifiers excluded
//! (inference path only).
//!
//! Resolution trace @224: conv1(k7,s2,p3)->112, pool->56, conv2(1x1),
//! conv3(3x3,p1)->56, pool->28, inception 3a/3b @28, pool->14,
//! 4a..4e @14, pool->7, 5a/5b @7.
//!
//! torchvision's Inception branch3 uses a 3x3 kernel (not the paper-named
//! 5x5) — we follow torchvision, consistent with the Table III
//! calibration of the other networks.

use crate::models::{ConvLayer, Network};

/// (ch1x1, ch3x3red, ch3x3, ch5x5red, ch5x5, pool_proj)
struct Inc(usize, usize, usize, usize, usize, usize);

fn inception(layers: &mut Vec<ConvLayer>, name: &str, res: usize, cin: usize, c: Inc) -> usize {
    let Inc(c1, c3r, c3, c5r, c5, pp) = c;
    layers.push(ConvLayer::new(&format!("{name}.b1"), res, res, cin, c1, 1, 1, 0));
    layers.push(ConvLayer::new(&format!("{name}.b2a"), res, res, cin, c3r, 1, 1, 0));
    layers.push(ConvLayer::new(&format!("{name}.b2b"), res, res, c3r, c3, 3, 1, 1));
    layers.push(ConvLayer::new(&format!("{name}.b3a"), res, res, cin, c5r, 1, 1, 0));
    // torchvision uses kernel_size=3 here (historical quirk of the port).
    layers.push(ConvLayer::new(&format!("{name}.b3b"), res, res, c5r, c5, 3, 1, 1));
    // branch4 = maxpool(3,s1,p1) then 1x1 proj; pool keeps dims.
    layers.push(ConvLayer::new(&format!("{name}.b4"), res, res, cin, pp, 1, 1, 0));
    c1 + c3 + c5 + pp
}

/// GoogleNet's conv stack (paper profile).
pub fn googlenet() -> Network {
    let mut layers = vec![
        ConvLayer::new("conv1", 224, 224, 3, 64, 7, 2, 3), // ->112
        // maxpool1 (ceil): 112 -> 56
        ConvLayer::new("conv2", 56, 56, 64, 64, 1, 1, 0),
        ConvLayer::new("conv3", 56, 56, 64, 192, 3, 1, 1),
        // maxpool2: 56 -> 28
    ];
    let mut c = 192;
    c = inception(&mut layers, "3a", 28, c, Inc(64, 96, 128, 16, 32, 32));
    c = inception(&mut layers, "3b", 28, c, Inc(128, 128, 192, 32, 96, 64));
    // maxpool3: 28 -> 14
    c = inception(&mut layers, "4a", 14, c, Inc(192, 96, 208, 16, 48, 64));
    c = inception(&mut layers, "4b", 14, c, Inc(160, 112, 224, 24, 64, 64));
    c = inception(&mut layers, "4c", 14, c, Inc(128, 128, 256, 24, 64, 64));
    c = inception(&mut layers, "4d", 14, c, Inc(112, 144, 288, 32, 64, 64));
    c = inception(&mut layers, "4e", 14, c, Inc(256, 160, 320, 32, 128, 128));
    // maxpool4: 14 -> 7
    c = inception(&mut layers, "5a", 7, c, Inc(256, 160, 320, 32, 128, 128));
    c = inception(&mut layers, "5b", 7, c, Inc(384, 192, 384, 48, 128, 128));
    assert_eq!(c, 1024);
    Network::new("GoogleNet", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_googlenet_min_bw() {
        // Paper Table III: 7.889 M activations/inference.
        let bw = googlenet().min_bandwidth() as f64 / 1e6;
        assert!((bw - 7.889).abs() < 0.05, "got {bw}");
    }

    #[test]
    fn layer_count() {
        // 3 stem convs + 9 inceptions x 6 convs = 57
        assert_eq!(googlenet().layers.len(), 57);
    }

    #[test]
    fn inception_channel_chain() {
        let net = googlenet();
        // 3a input = 192, 3b input = 256, 4a input = 480
        assert_eq!(net.layer("3a.b1").unwrap().m, 192);
        assert_eq!(net.layer("3b.b1").unwrap().m, 256);
        assert_eq!(net.layer("4a.b1").unwrap().m, 480);
        assert_eq!(net.layer("5b.b1").unwrap().m, 832);
    }
}
