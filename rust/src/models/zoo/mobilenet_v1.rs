//! MobileNet (V1) — the network the paper actually evaluated.
//!
//! Calibration: the paper cites the MobileNetV2 paper [14] but its
//! Table III value (10.273 M) matches the **V1** architecture
//! (10.186 M, -0.8%), while torchvision MobileNetV2 gives 13.444 M
//! (+31%). We therefore expose V1 as the paper's "MobileNet" row and keep
//! [`super::mobilenet_v2`] available as a ninth network for extensions.
//!
//! V1: stem 3->32 k3/s2, then 13 depthwise-separable blocks
//! (dw 3x3 + pw 1x1): 32->64, /2 ->128, 128, /2 ->256, 256, /2 ->512,
//! 5x 512, /2 ->1024, 1024.

use crate::models::{ConvLayer, Network};

/// Append one depthwise-separable block; returns output resolution.
fn dw_sep(
    layers: &mut Vec<ConvLayer>,
    id: usize,
    res: usize,
    cin: usize,
    cout: usize,
    stride: usize,
) -> usize {
    layers.push(ConvLayer::grouped(&format!("ds{id}.dw"), res, res, cin, cin, 3, stride, 1, cin));
    let r = layers.last().unwrap().wo();
    layers.push(ConvLayer::new(&format!("ds{id}.pw"), r, r, cin, cout, 1, 1, 0));
    r
}

/// MobileNet v1's conv stack (paper profile).
pub fn mobilenet_v1() -> Network {
    let mut layers = vec![ConvLayer::new("stem", 224, 224, 3, 32, 3, 2, 1)]; // ->112
    // (cout, stride) for the 13 separable blocks.
    let blocks: &[(usize, usize)] = &[
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    let mut res = 112;
    let mut cin = 32;
    for (i, &(cout, s)) in blocks.iter().enumerate() {
        res = dw_sep(&mut layers, i + 1, res, cin, cout, s);
        cin = cout;
    }
    Network::new("MobileNet", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_mobilenet_min_bw() {
        // Paper Table III: 10.273; V1 computes 10.186 (-0.8%), the closest
        // of the MobileNet family by far (V2 is +31%).
        let bw = mobilenet_v1().min_bandwidth() as f64 / 1e6;
        assert!((bw - 10.186).abs() < 0.005, "got {bw}");
        assert!((bw - 10.273).abs() / 10.273 < 0.01, "got {bw} vs paper 10.273");
    }

    #[test]
    fn layer_count() {
        // stem + 13 blocks x 2 = 27
        assert_eq!(mobilenet_v1().layers.len(), 27);
    }

    #[test]
    fn resolution_trace_ends_at_7() {
        assert_eq!(mobilenet_v1().layers.last().unwrap().wo(), 7);
    }

    #[test]
    fn depthwise_alternates_with_pointwise() {
        let net = mobilenet_v1();
        for (i, l) in net.layers.iter().enumerate().skip(1) {
            if i % 2 == 1 {
                assert!(l.is_depthwise(), "{} should be depthwise", l.name);
            } else {
                assert_eq!(l.k, 1, "{} should be pointwise", l.name);
            }
        }
    }
}
