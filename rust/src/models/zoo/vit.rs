//! ViT-Tiny (patch 16, 224×224): the transformer workload built from the
//! GEMM/attention operator abstraction.
//!
//! Shape source: the DeiT-Tiny/ViT-Ti configuration — a 16×16 conv patch
//! embed (3 → 192), then 12 encoder blocks over `seq = 14·14 + 1 = 197`
//! tokens of width `d_model = 192`, each block = 3-head self-attention
//! (`d_head = 64`) + a 4× MLP (192 → 768 → 192). LayerNorm, softmax and
//! residual adds are elementwise (no reduction dimension, no partial
//! sums) and are ignored exactly as pooling/ReLU are for the CNNs.
//!
//! Everything lowers onto the 1×1-conv equations via [`Op::lower`], so
//! the K-dimension partial-sum traffic of every GEMM rides the paper's
//! eqs. 2–4 and the byte model unchanged.

use crate::models::{ConvLayer, Network, Op};

/// ViT-Tiny/16 @224: 1 conv patch embed + 12 × (attention, MLP fc1,
/// MLP fc2) — 37 ops lowering to 145 conv-equivalent layers.
pub fn vit_tiny() -> Network {
    const SEQ: usize = 197; // 14×14 patches + class token
    const D_MODEL: usize = 192;
    const HEADS: usize = 3;
    const D_HEAD: usize = 64;
    const D_MLP: usize = 768;

    let mut ops = vec![Op::Conv(ConvLayer::new("patch_embed", 224, 224, 3, D_MODEL, 16, 16, 0))];
    for b in 0..12 {
        ops.push(
            Op::attention(&format!("block{b}.attn"), SEQ, HEADS, D_MODEL, D_HEAD)
                .expect("static shape"),
        );
        ops.push(
            Op::gemm(&format!("block{b}.mlp.fc1"), SEQ, D_MODEL, D_MLP).expect("static shape"),
        );
        ops.push(
            Op::gemm(&format!("block{b}.mlp.fc2"), SEQ, D_MLP, D_MODEL).expect("static shape"),
        );
    }
    Network::from_ops("ViT-Tiny", ops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::OpKind;

    #[test]
    fn op_and_layer_counts() {
        let net = vit_tiny();
        assert_eq!(net.ops.len(), 1 + 12 * 3);
        // patch embed + 12 × (10 attention layers + 2 MLP GEMMs).
        assert_eq!(net.layers.len(), 1 + 12 * 12);
        let kinds: Vec<OpKind> = net.ops.iter().map(|o| o.kind()).collect();
        assert_eq!(kinds.iter().filter(|k| **k == OpKind::Conv).count(), 1);
        assert_eq!(kinds.iter().filter(|k| **k == OpKind::Attention).count(), 12);
        assert_eq!(kinds.iter().filter(|k| **k == OpKind::Gemm).count(), 24);
    }

    #[test]
    fn macs_match_the_published_flop_count() {
        // Patch embed 14²·192·3·16² + 12 × (attention QKV/proj + per-head
        // score/ctx + MLP) = 1.2535 GMACs — the ViT-Ti/DeiT-Ti ballpark
        // (published ~1.26 GFLOPs/2, which also counts norms + head).
        let patch = 14u64 * 14 * 192 * 3 * 256;
        let attn = 4u64 * 197 * 192 * 192 + 3 * 2 * 197 * 197 * 64;
        let mlp = 2u64 * 197 * 192 * 768;
        let expect = patch + 12 * (attn + mlp);
        assert_eq!(vit_tiny().total_macs(), expect);
        assert_eq!(expect, 1_253_491_200);
    }

    #[test]
    fn parameter_count_is_vit_tiny() {
        // Op-view weights (true parameters): patch embed + per block
        // 4·192² attention + 2·192·768 MLP = 5.456 M — ViT-Ti's ~5.7 M
        // less the norms/pos-embed/classifier this model ignores.
        let expect = 147_456u64 + 12 * (4 * 192 * 192 + 2 * 192 * 768);
        let got: u64 = vit_tiny().ops.iter().map(Op::weights).sum();
        assert_eq!(got, expect);
        assert_eq!(expect, 5_455_872);
    }

    #[test]
    fn lowered_layer_names_unique() {
        let net = vit_tiny();
        let mut names: Vec<&str> = net.layers.iter().map(|l| l.name.as_str()).collect();
        let before = names.len();
        names.sort();
        names.dedup();
        assert_eq!(before, names.len());
    }
}
