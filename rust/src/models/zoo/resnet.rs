//! torchvision ResNet-18 (BasicBlock) and ResNet-50 (Bottleneck, v1.5:
//! the stride sits on the 3x3 conv).
//!
//! ResNet-18's Table III value (4.666 M) matches this definition exactly,
//! including the 1x1 downsample convs on the first block of layers 2-4.

use crate::models::{ConvLayer, Network};

/// Two 3x3 convs + optional 1x1 downsample (stride s on conv1).
fn basic_block(
    layers: &mut Vec<ConvLayer>,
    name: &str,
    res: usize,
    cin: usize,
    cout: usize,
    stride: usize,
) {
    layers.push(ConvLayer::new(&format!("{name}.conv1"), res, res, cin, cout, 3, stride, 1));
    let r2 = layers.last().unwrap().wo();
    layers.push(ConvLayer::new(&format!("{name}.conv2"), r2, r2, cout, cout, 3, 1, 1));
    if stride != 1 || cin != cout {
        layers.push(ConvLayer::new(&format!("{name}.down"), res, res, cin, cout, 1, stride, 0));
    }
}

/// 1x1 reduce -> 3x3 (stride here, v1.5; optionally grouped) -> 1x1
/// expand + downsample. `cout` is the block's output channel count.
#[allow(clippy::too_many_arguments)]
fn bottleneck(
    layers: &mut Vec<ConvLayer>,
    name: &str,
    res: usize,
    cin: usize,
    width: usize,
    cout: usize,
    stride: usize,
    groups: usize,
) {
    layers.push(ConvLayer::new(&format!("{name}.conv1"), res, res, cin, width, 1, 1, 0));
    layers.push(ConvLayer::grouped(
        &format!("{name}.conv2"),
        res,
        res,
        width,
        width,
        3,
        stride,
        1,
        groups,
    ));
    let r2 = layers.last().unwrap().wo();
    layers.push(ConvLayer::new(&format!("{name}.conv3"), r2, r2, width, cout, 1, 1, 0));
    if stride != 1 || cin != cout {
        layers.push(ConvLayer::new(&format!("{name}.down"), res, res, cin, cout, 1, stride, 0));
    }
}

/// Shared BasicBlock-stack builder (ResNet-18/34).
fn basic_net(name: &str, blocks_per_stage: [usize; 4]) -> Network {
    let mut layers = vec![ConvLayer::new("conv1", 224, 224, 3, 64, 7, 2, 3)]; // ->112
    // maxpool: 112 -> 56
    let stages: &[(usize, usize, usize)] = &[(1, 64, 56), (2, 128, 56), (3, 256, 28), (4, 512, 14)];
    let mut cin = 64;
    for (si, &(idx, cout, res_in)) in stages.iter().enumerate() {
        let stride = if idx == 1 { 1 } else { 2 };
        basic_block(&mut layers, &format!("layer{idx}.0"), res_in, cin, cout, stride);
        let res = if stride == 2 { res_in / 2 } else { res_in };
        for b in 1..blocks_per_stage[si] {
            basic_block(&mut layers, &format!("layer{idx}.{b}"), res, cout, cout, 1);
        }
        cin = cout;
    }
    Network::new(name, layers)
}

/// ResNet-18's conv stack (paper profile).
pub fn resnet18() -> Network {
    basic_net("ResNet-18", [2, 2, 2, 2])
}

/// ResNet-34 (extension network — not in the paper's tables).
pub fn resnet34() -> Network {
    basic_net("ResNet-34", [3, 4, 6, 3])
}

/// Shared bottleneck-stack builder for the 50-layer networks.
/// `width_mult`: bottleneck width = stage_base * width_mult / 64 (64 for
/// classic ResNet-50, 128 for ResNeXt-50 32x4d), `groups` applies to the
/// 3x3 conv.
fn bottleneck_50(name: &str, base_width: usize, groups: usize) -> Network {
    let mut layers = vec![ConvLayer::new("conv1", 224, 224, 3, 64, 7, 2, 3)]; // ->112
    // maxpool: 112 -> 56
    // (stage idx, stage base channels, blocks, input res, first stride)
    let stages: &[(usize, usize, usize, usize, usize)] = &[
        (1, 64, 3, 56, 1),
        (2, 128, 4, 56, 2),
        (3, 256, 6, 28, 2),
        (4, 512, 3, 14, 2),
    ];
    let mut cin = 64;
    for &(idx, base, blocks, res_in, stride) in stages {
        let width = base * base_width / 64;
        let cout = base * 4;
        bottleneck(&mut layers, &format!("layer{idx}.0"), res_in, cin, width, cout, stride, groups);
        let res = if stride == 2 { res_in / 2 } else { res_in };
        cin = cout;
        for b in 1..blocks {
            bottleneck(&mut layers, &format!("layer{idx}.{b}"), res, cin, width, cout, 1, groups);
        }
    }
    Network::new(name, layers)
}

/// The paper's "ResNet-50" row.
///
/// Calibration: the classic torchvision ResNet-50 yields 21.776 M minimum
/// bandwidth, but the paper's Table III prints 28.349 M — which matches
/// **ResNeXt-50 32x4d** (torchvision `resnext50_32x4d`) *exactly*
/// (28.349440 M). The paper evidently pulled the ResNeXt variant; we
/// reproduce that so the partitioning tables line up, and keep the classic
/// variant available as [`resnet50_classic`].
pub fn resnet50() -> Network {
    bottleneck_50("ResNet-50", 128, 32)
}

/// Classic torchvision ResNet-50 (kept for extension experiments).
pub fn resnet50_classic() -> Network {
    bottleneck_50("ResNet-50-classic", 64, 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_resnet18_min_bw() {
        // Paper Table III: 4.666 M activations/inference (exact match).
        let bw = resnet18().min_bandwidth() as f64 / 1e6;
        assert!((bw - 4.666).abs() < 0.001, "got {bw}");
    }

    #[test]
    fn table3_resnet50_min_bw() {
        // Paper Table III: 28.349 M — matches ResNeXt-50 32x4d exactly.
        let bw = resnet50().min_bandwidth() as f64 / 1e6;
        assert!((bw - 28.349).abs() < 0.001, "got {bw}");
    }

    #[test]
    fn classic_resnet50_differs() {
        // The classic variant is what "ResNet-50" usually means; the paper's
        // number matches the ResNeXt shapes instead (see module docs).
        let bw = resnet50_classic().min_bandwidth() as f64 / 1e6;
        assert!((bw - 21.776).abs() < 0.001, "got {bw}");
    }

    #[test]
    fn resnext_conv2_is_grouped() {
        let net = resnet50();
        let c2 = net.layer("layer1.0.conv2").unwrap();
        assert_eq!(c2.groups, 32);
        assert_eq!(c2.m, 128);
        assert_eq!(c2.m_per_group(), 4);
    }

    #[test]
    fn resnet34_structure() {
        let net = resnet34();
        // conv1 + (3+4+6+3) x 2 convs + 3 downsamples = 1 + 32 + 3 = 36
        assert_eq!(net.layers.len(), 36);
        let bw = net.min_bandwidth() as f64 / 1e6;
        assert!((bw - 7.175).abs() < 0.01, "got {bw}");
    }

    #[test]
    fn resnet18_layer_count() {
        // conv1 + (2+2+2+2) blocks x 2 convs + 3 downsamples = 1+16+3 = 20
        assert_eq!(resnet18().layers.len(), 20);
    }

    #[test]
    fn resnet50_layer_count() {
        // conv1 + (3+4+6+3) x 3 convs + 4 downsamples = 1 + 48 + 4 = 53
        assert_eq!(resnet50().layers.len(), 53);
    }

    #[test]
    fn resnet50_first_stage_has_stride1_downsample() {
        let net = resnet50();
        let d = net.layer("layer1.0.down").unwrap();
        assert_eq!(d.stride, 1);
        assert_eq!(d.m, 64);
        assert_eq!(d.n, 256);
    }

    #[test]
    fn v1_5_stride_on_3x3() {
        let net = resnet50();
        let c1 = net.layer("layer2.0.conv1").unwrap();
        let c2 = net.layer("layer2.0.conv2").unwrap();
        assert_eq!(c1.stride, 1);
        assert_eq!(c2.stride, 2);
        assert_eq!(c2.wo(), 28);
        // ResNeXt widths: layer2 bottleneck width = 128 * 128/64 = 256
        assert_eq!(c1.n, 256);
    }
}
