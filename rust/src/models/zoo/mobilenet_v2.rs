//! torchvision MobileNetV2 (the paper's [14] reference).
//!
//! Inverted residual (t, c, n, s) settings from the MobileNetV2 paper:
//! (1,16,1,1) (6,24,2,2) (6,32,3,2) (6,64,4,2) (6,96,3,1) (6,160,3,2)
//! (6,320,1,1), stem 3->32 k3/s2, head 320->1280 1x1.

use crate::models::{ConvLayer, Network};

/// Append one inverted-residual block: optional 1x1 expand, depthwise 3x3
/// (stride s), 1x1 project. Returns (output res, output channels).
fn inverted_residual(
    layers: &mut Vec<ConvLayer>,
    name: &str,
    res: usize,
    cin: usize,
    cout: usize,
    t: usize,
    s: usize,
) -> usize {
    let hidden = cin * t;
    if t != 1 {
        layers.push(ConvLayer::new(&format!("{name}.expand"), res, res, cin, hidden, 1, 1, 0));
    }
    layers.push(ConvLayer::grouped(
        &format!("{name}.dw"),
        res,
        res,
        hidden,
        hidden,
        3,
        s,
        1,
        hidden,
    ));
    let r = layers.last().unwrap().wo();
    layers.push(ConvLayer::new(&format!("{name}.project"), r, r, hidden, cout, 1, 1, 0));
    r
}

/// MobileNet v2's conv stack (faithful extra).
pub fn mobilenet_v2() -> Network {
    let mut layers = vec![ConvLayer::new("stem", 224, 224, 3, 32, 3, 2, 1)]; // ->112
    let settings: &[(usize, usize, usize, usize)] = &[
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut res = 112;
    let mut cin = 32;
    let mut blk = 0usize;
    for &(t, c, n, s) in settings {
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            res = inverted_residual(&mut layers, &format!("ir{blk}"), res, cin, c, t, stride);
            cin = c;
            blk += 1;
        }
    }
    layers.push(ConvLayer::new("head", res, res, 320, 1280, 1, 1, 0));
    Network::new("MobileNetV2", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v2_min_bw_is_not_the_paper_row() {
        // The paper's "MobileNet" row (10.273 M) matches V1, not V2 —
        // V2 computes to 13.444 M. Kept as an extension network.
        let bw = mobilenet_v2().min_bandwidth() as f64 / 1e6;
        assert!((bw - 13.444).abs() < 0.001, "got {bw}");
    }

    #[test]
    fn layer_count() {
        // stem + block convs + head. Block convs: first block (t=1) has 2,
        // the other 16 blocks have 3 => 2 + 48 = 50; total 52.
        assert_eq!(mobilenet_v2().layers.len(), 52);
    }

    #[test]
    fn depthwise_layers_are_depthwise() {
        let net = mobilenet_v2();
        let dws: Vec<_> = net.layers.iter().filter(|l| l.name.ends_with(".dw")).collect();
        assert_eq!(dws.len(), 17);
        assert!(dws.iter().all(|l| l.is_depthwise()));
    }

    #[test]
    fn final_resolution_is_7() {
        let net = mobilenet_v2();
        assert_eq!(net.layers.last().unwrap().wo(), 7);
    }
}
