//! The typed Request/Response facade — ONE entry point for the CLI, the
//! `serve` protocol and library embedders.
//!
//! Every question the cost model answers (sweep a grid, explore a
//! frontier, fuse a chain, regenerate a paper table, run inference) is a
//! [`Request`] variant; every answer is a [`Response`]; every failure is
//! an [`ApiError`] with a stable machine-readable code. The [`Engine`]
//! dispatcher owns the shared layer-shape cache, the per-request size
//! caps and per-request metrics, so a new axis or command lands once and
//! every frontend picks it up.
//!
//! * [`codec`] — JSON decode/encode (the serve wire protocol), including
//!   the single set of axis parsers that `SweepSpec::from_json` and
//!   `ExploreSpec::from_json` delegate to, and the optional `protocol`
//!   version field.
//! * [`request`]/[`response`] — the typed surface and its documentation
//!   ([`protocol_table`] generates the README's protocol table).
//! * [`engine`] — [`Engine::dispatch`] and [`Engine::handle_line`].
//!
//! # Embedding
//!
//! ```
//! use psim::analytics::grid::SweepSpec;
//! use psim::analytics::{ControllerMode, Strategy};
//! use psim::api::{Engine, Request, Response};
//! use psim::models::zoo;
//!
//! let engine = Engine::analytics();
//!
//! // Typed request in, typed response out:
//! let spec = SweepSpec::new(vec![zoo::alexnet()])
//!     .with_macs(vec![512, 2048])
//!     .with_strategies(vec![Strategy::Optimal])
//!     .with_modes(vec![ControllerMode::Passive]);
//! let resp = engine.dispatch(&Request::Sweep { spec, workers: Some(2) }).unwrap();
//! let Response::Sweep { grid, .. } = resp else { unreachable!() };
//! assert_eq!(grid.len(), 2);
//!
//! // Or straight from a protocol JSON line (what `serve` does):
//! let (reply, shutdown) = engine.handle_line(r#"{"cmd":"version"}"#);
//! assert_eq!(reply.get("protocol").unwrap().as_usize(), Some(psim::api::PROTOCOL_VERSION));
//! assert!(!shutdown);
//! ```
//!
//! The README's protocol table is generated from the [`Request`] enum's
//! documentation rows and pinned by this doc-test, so the two cannot
//! drift:
//!
//! ```
//! let readme =
//!     std::fs::read_to_string(concat!(env!("CARGO_MANIFEST_DIR"), "/../README.md")).unwrap();
//! assert!(readme.contains(&psim::api::protocol_table()), "README protocol table is stale");
//! ```
//!
//! `docs/PROTOCOL.md` is the full wire reference: the same generated
//! table plus one example per command lifted verbatim from the pinned
//! fixtures in `rust/tests/golden/protocol/`. This doc-test pins the
//! document against both, so it can drift from neither the enum nor the
//! fixtures:
//!
//! ```
//! let root = concat!(env!("CARGO_MANIFEST_DIR"), "/..");
//! let doc = std::fs::read_to_string(format!("{root}/docs/PROTOCOL.md"))
//!     .expect("docs/PROTOCOL.md exists");
//! assert!(doc.contains(&psim::api::protocol_table()), "PROTOCOL.md table is stale");
//! for cmd in psim::api::COMMANDS.iter().map(|c| c.cmd) {
//!     assert!(doc.contains(&format!("### `{cmd}`")), "PROTOCOL.md missing section for {cmd}");
//!     let fixture = std::fs::read_to_string(
//!         format!("{root}/rust/tests/golden/protocol/{cmd}.txt"),
//!     )
//!     .unwrap_or_else(|_| panic!("fixture for {cmd}"));
//!     for line in fixture.lines() {
//!         assert!(doc.contains(line), "PROTOCOL.md {cmd} example drifted from its fixture");
//!     }
//! }
//! // The serve-concurrency section documents load shedding with the
//! // pinned `too_busy` fixture, byte-for-byte.
//! let shed = std::fs::read_to_string(
//!     format!("{root}/rust/tests/golden/protocol/serve/too_busy.txt"),
//! )
//! .expect("too_busy fixture");
//! for line in shed.lines() {
//!     assert!(doc.contains(line), "PROTOCOL.md too_busy example drifted from its fixture");
//! }
//! assert!(doc.contains("too_busy"), "PROTOCOL.md must document the too_busy error code");
//! ```

pub mod codec;
pub mod engine;
pub mod error;
pub mod request;
pub mod response;

pub use engine::{Engine, ServeStats, IMAGE_ELEMS, MAX_REQUEST_CELLS};
pub use error::{ApiError, ErrorCode, TOO_BUSY_MESSAGE};
pub use request::{protocol_table, Request, TableKind, COMMANDS};
pub use response::Response;

/// The wire-protocol version every frontend speaks. Bumped only on a
/// breaking change to request/response shapes; additive fields do not
/// bump it. Reported by `psim --version`, `{"cmd":"version"}` and
/// accepted back via the optional `"protocol"` request field.
pub const PROTOCOL_VERSION: usize = 1;

/// Version of the `{"cmd":"stats"}` snapshot schema, reported as its
/// `"schema"` field. Bumped only when an existing key changes meaning
/// or disappears; new metrics are additive and do not bump it.
pub const STATS_SCHEMA_VERSION: usize = 1;

/// The crate version (from `Cargo.toml`), reported alongside
/// [`PROTOCOL_VERSION`].
pub const CRATE_VERSION: &str = env!("CARGO_PKG_VERSION");

/// The `psim --version` line: crate + protocol version from the one pair
/// of constants above.
pub fn version_line() -> String {
    format!("psim {CRATE_VERSION} (protocol {PROTOCOL_VERSION})")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn version_line_carries_both_versions() {
        let line = version_line();
        assert!(line.contains(CRATE_VERSION));
        assert!(line.contains(&PROTOCOL_VERSION.to_string()));
    }
}
