//! The API error type: a stable machine-readable code plus the
//! human-readable message every frontend already shows.
//!
//! The `code` is part of the wire protocol — clients branch on it, so the
//! variants are append-only. The `message` keeps the text the pre-facade
//! `serve` protocol emitted (`{"error": "..."}`) byte-compatible on the
//! common paths (bad JSON, unknown cmd, validation, caps, inference
//! unavailable) — only unknown-key diagnostics now also list the
//! `protocol` key. `code` is the additive, stable alternative to
//! matching on message substrings.

use std::fmt;

use crate::util::json::Json;

/// Stable machine-readable error category. The wire form is
/// [`ErrorCode::as_str`]; variants are append-only (removing or renaming
/// one breaks deployed clients).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ErrorCode {
    /// The request could not be decoded or failed validation.
    BadRequest,
    /// The request is well-formed but expands past the per-request cap.
    TooLarge,
    /// An `{"image": ...}` request reached a host without a PJRT stack.
    InferenceUnavailable,
    /// The request was valid but the engine failed to serve it.
    Internal,
    /// The server is saturated (connection queue full or connection
    /// limit reached) and shed the request instead of queueing it.
    TooBusy,
}

impl ErrorCode {
    /// The wire token, e.g. `"bad_request"`.
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::TooLarge => "too_large",
            ErrorCode::InferenceUnavailable => "inference_unavailable",
            ErrorCode::Internal => "internal",
            ErrorCode::TooBusy => "too_busy",
        }
    }
}

/// The canonical `too_busy` message. One fixed string (pinned by the
/// `rust/tests/golden/protocol/serve/too_busy.txt` fixture) so shed
/// replies are byte-identical no matter which saturation path fired.
pub const TOO_BUSY_MESSAGE: &str = "server at capacity, try again later";

/// A dispatch failure: stable `code`, byte-compatible `message`.
#[derive(Clone, Debug)]
pub struct ApiError {
    /// Stable machine-readable error class.
    pub code: ErrorCode,
    /// Human-facing message (byte-compatible with legacy replies).
    pub message: String,
}

impl ApiError {
    /// An error with an explicit code.
    pub fn new(code: ErrorCode, message: impl Into<String>) -> ApiError {
        ApiError { code, message: message.into() }
    }

    /// A validation/decode failure carrying an `anyhow` chain, formatted
    /// exactly as the pre-facade serve loop did (`{err:#}`).
    pub fn bad(err: anyhow::Error) -> ApiError {
        ApiError::new(ErrorCode::BadRequest, format!("{err:#}"))
    }

    /// A `bad_request` with a literal message.
    pub fn bad_msg(message: impl Into<String>) -> ApiError {
        ApiError::new(ErrorCode::BadRequest, message)
    }

    /// A `too_large` rejection (request-size cap).
    pub fn too_large(message: impl Into<String>) -> ApiError {
        ApiError::new(ErrorCode::TooLarge, message)
    }

    /// An `internal` failure carrying an `anyhow` chain.
    pub fn internal(err: anyhow::Error) -> ApiError {
        ApiError::new(ErrorCode::Internal, format!("{err:#}"))
    }

    /// The canonical load-shedding reply ([`TOO_BUSY_MESSAGE`]): emitted
    /// by the pooled server when the connection queue is full.
    pub fn too_busy() -> ApiError {
        ApiError::new(ErrorCode::TooBusy, TOO_BUSY_MESSAGE)
    }

    /// The wire reply: `{"code": "...", "error": "..."}`. The `error`
    /// field carries the exact pre-facade text; `code` is additive.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("code", Json::Str(self.code.as_str().to_string())),
            ("error", Json::Str(self.message.clone())),
        ])
    }
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.message)
    }
}

impl std::error::Error for ApiError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_stable_tokens() {
        assert_eq!(ErrorCode::BadRequest.as_str(), "bad_request");
        assert_eq!(ErrorCode::TooLarge.as_str(), "too_large");
        assert_eq!(ErrorCode::InferenceUnavailable.as_str(), "inference_unavailable");
        assert_eq!(ErrorCode::Internal.as_str(), "internal");
        assert_eq!(ErrorCode::TooBusy.as_str(), "too_busy");
    }

    #[test]
    fn too_busy_reply_is_one_fixed_line() {
        assert_eq!(
            ApiError::too_busy().to_json().to_string(),
            r#"{"code":"too_busy","error":"server at capacity, try again later"}"#
        );
    }

    #[test]
    fn json_reply_keeps_error_text_and_adds_code() {
        let e = ApiError::bad_msg("missing 'image' array");
        assert_eq!(
            e.to_json().to_string(),
            r#"{"code":"bad_request","error":"missing 'image' array"}"#
        );
        assert_eq!(e.to_string(), "missing 'image' array");
    }
}
