//! The dispatcher: one engine every frontend drives.
//!
//! [`Engine`] owns the long-lived [`GridEngine`] layer-shape cache (so
//! repeated requests get warmer regardless of which frontend they arrive
//! through), the per-request size caps (previously enforced by `serve`
//! only — now every frontend gets them), the optional PJRT inference
//! stack, and per-request metrics.
//!
//! For concurrent hosts (the pooled `psim serve`) the engine also
//! coalesces identical in-flight analytics requests
//! ([`Engine::handle_line_shared`]): byte-identical request lines that
//! arrive while the first is still computing share one computation and
//! fan the reply out, and [`ServeStats`] counts the serve-side lifecycle
//! (accepted/shed/refused/timed-out connections, coalesced replies,
//! queue high-water mark) without touching the wire `metrics` reply.
//!
//! Every engine owns a private [`Registry`]: request counters, serve
//! counters, per-command latency histograms and the pool queue-wait
//! histogram all live there, and `{"cmd":"stats"}` renders it as one
//! versioned sorted-key snapshot. Per-engine (not process-global) on
//! purpose — `cargo test` runs many engines concurrently in one
//! process, and the pinned stats fixture needs a fresh engine to be
//! byte-reproducible.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

use anyhow::Result;

use crate::analytics::grid::GridEngine;
use crate::coordinator::parallel::default_workers;
use crate::coordinator::{InferenceService, ServiceConfig};
use crate::dse::explore as dse_explore;
use crate::obs::metrics::{Counter, Gauge, Histogram};
use crate::obs::registry::{register_catalog, Registry};
use crate::obs::span;
use crate::report::{
    analyze as report_analyze, fig2, fusion as report_fusion, tables, zoo as report_zoo,
};
use crate::runtime::{ArtifactDir, Tensor};
use crate::util::json::Json;
use crate::util::sync::{lock_unpoisoned, wait_unpoisoned};

use super::codec;
use super::error::{ApiError, ErrorCode};
use super::request::{Request, TableKind};
use super::response::Response;

/// Inference request payload size (CIFAR-shaped 3×32×32 image).
pub const IMAGE_ELEMS: usize = 3 * 32 * 32;

/// Largest grid (sweep) or candidate set (explore) a single request may
/// expand to, enforced in [`Engine::dispatch`] for every frontend.
pub const MAX_REQUEST_CELLS: usize = 100_000;

/// Resolve a request's optional worker count: default to machine
/// parallelism, clamp to the per-request cap. One policy for every
/// frontend, so it cannot drift.
pub fn effective_workers(requested: Option<usize>) -> usize {
    requested.unwrap_or_else(default_workers).clamp(1, 64)
}

/// Per-command request counters (and an error total), surfaced through
/// `{"cmd":"metrics"}`. Registry-backed: each slot is the
/// `api_requests_<cmd>` counter of the engine's [`Registry`], so the
/// legacy `metrics` reply and the `stats` snapshot read one source of
/// truth.
struct Counters {
    sweep: Arc<Counter>,
    explore: Arc<Counter>,
    fusion: Arc<Counter>,
    analyze: Arc<Counter>,
    tables: Arc<Counter>,
    infer: Arc<Counter>,
    metrics: Arc<Counter>,
    stats: Arc<Counter>,
    version: Arc<Counter>,
    shutdown: Arc<Counter>,
    errors: Arc<Counter>,
}

impl Counters {
    fn new(reg: &Registry) -> Counters {
        let c = |cmd: &str| reg.counter(&format!("api_requests_{cmd}"));
        Counters {
            sweep: c("sweep"),
            explore: c("explore"),
            fusion: c("fusion"),
            analyze: c("analyze"),
            tables: c("tables"),
            infer: c("infer"),
            metrics: c("metrics"),
            stats: c("stats"),
            version: c("version"),
            shutdown: c("shutdown"),
            errors: reg.counter("api_errors"),
        }
    }

    fn slots(&self) -> [(&'static str, &Arc<Counter>); 11] {
        [
            ("sweep", &self.sweep),
            ("explore", &self.explore),
            ("fusion", &self.fusion),
            ("analyze", &self.analyze),
            ("tables", &self.tables),
            ("infer", &self.infer),
            ("metrics", &self.metrics),
            ("stats", &self.stats),
            ("version", &self.version),
            ("shutdown", &self.shutdown),
            ("errors", &self.errors),
        ]
    }

    fn count(&self, cmd: &str) {
        for (name, slot) in self.slots() {
            if name == cmd {
                slot.inc();
                return;
            }
        }
    }

    /// Non-zero counters only, in slot order (the JSON object sorts).
    fn snapshot(&self) -> Vec<(&'static str, u64)> {
        self.slots()
            .into_iter()
            .map(|(name, slot)| (name, slot.get()))
            .filter(|&(_, n)| n > 0)
            .collect()
    }
}

/// Per-command dispatch-latency histograms (`api_latency_us_<cmd>`),
/// recorded by [`Engine::dispatch`] *after* `dispatch_inner` returns so
/// a stats snapshot never observes its own in-flight dispatch (the
/// pinned stats fixture depends on that).
struct CommandLatency {
    slots: [(&'static str, Arc<Histogram>); 10],
}

impl CommandLatency {
    fn new(reg: &Registry) -> CommandLatency {
        let h = |cmd: &str| reg.histogram(&format!("api_latency_us_{cmd}"));
        CommandLatency {
            slots: [
                ("sweep", h("sweep")),
                ("explore", h("explore")),
                ("fusion", h("fusion")),
                ("analyze", h("analyze")),
                ("tables", h("tables")),
                ("infer", h("infer")),
                ("metrics", h("metrics")),
                ("stats", h("stats")),
                ("version", h("version")),
                ("shutdown", h("shutdown")),
            ],
        }
    }

    fn observe(&self, cmd: &str, us: u64) {
        for (name, hist) in &self.slots {
            if *name == cmd {
                hist.record(us);
                return;
            }
        }
    }
}

/// Serve-side lifecycle counters, owned by the engine so the pooled
/// server, tests and embedders read one source of truth. Registry-backed
/// (`serve_*` metrics), so the same values reach `{"cmd":"stats"}` —
/// but deliberately NOT part of the wire `{"cmd":"metrics"}` reply: the
/// pre-existing protocol golden fixtures pin that reply byte-exactly
/// against a fresh engine, and connection accounting is a host concern,
/// not a protocol one.
pub struct ServeStats {
    /// Connections admitted into the worker pool (served or queued).
    pub accepted: Arc<Counter>,
    /// Connections shed with a `too_busy` reply (queue full or
    /// `--max-conns` reached).
    pub shed: Arc<Counter>,
    /// Connections refused because the socket could not be tracked
    /// (`try_clone` failed, e.g. fd exhaustion) — previously silent.
    pub refused: Arc<Counter>,
    /// Connections closed by the per-request `--timeout-ms` deadline.
    pub timed_out: Arc<Counter>,
    /// Replies written by pool workers (every request on an accepted
    /// connection produces exactly one).
    pub lines: Arc<Counter>,
    /// Replies answered by another connection's in-flight computation
    /// (see [`Engine::handle_line_shared`]).
    pub coalesced: Arc<Counter>,
    /// Replies computed by a fresh dispatch (everything
    /// [`Engine::handle_line_shared`] returns that was not coalesced,
    /// decode errors included). Incremented after the reply is built,
    /// so `dispatched + coalesced == lines` holds whenever no request
    /// is in flight — the CI stats smoke asserts exactly that.
    pub dispatched: Arc<Counter>,
    /// Time connections spent parked in the bounded hand-off queue
    /// (`serve_queue_wait_us`), recorded by the popping worker.
    pub queue_wait: Arc<Histogram>,
    queue_peak: Arc<Gauge>,
}

impl ServeStats {
    /// Serve counters backed by `reg`'s `serve_*` metrics.
    pub fn new(reg: &Registry) -> ServeStats {
        ServeStats {
            accepted: reg.counter("serve_conns_accepted"),
            shed: reg.counter("serve_conns_shed"),
            refused: reg.counter("serve_conns_refused"),
            timed_out: reg.counter("serve_conns_timed_out"),
            lines: reg.counter("serve_replies"),
            coalesced: reg.counter("serve_replies_coalesced"),
            dispatched: reg.counter("serve_replies_dispatched"),
            queue_wait: reg.histogram("serve_queue_wait_us"),
            queue_peak: reg.gauge("serve_queue_depth_peak"),
        }
    }

    /// Record an observed queue depth, keeping the high-water mark.
    pub fn note_queue_depth(&self, depth: usize) {
        self.queue_peak.note_max(depth as u64);
    }

    /// The queue high-water mark: the deepest the bounded connection
    /// queue ever got. Never exceeds the configured bound — the
    /// backpressure property test asserts exactly that.
    pub fn queue_peak(&self) -> u64 {
        self.queue_peak.get()
    }

    /// One human-readable line for the shutdown banner.
    pub fn summary(&self) -> String {
        format!(
            "conns accepted={} shed={} refused={} timed_out={}; \
             replies={} ({} coalesced); queue peak={}",
            self.accepted.get(),
            self.shed.get(),
            self.refused.get(),
            self.timed_out.get(),
            self.lines.get(),
            self.coalesced.get(),
            self.queue_peak.get(),
        )
    }
}

/// One in-flight coalescable computation: the leader fills `done` and
/// notifies; followers wait on the condvar and clone the reply.
#[derive(Default)]
struct Flight {
    done: Mutex<Option<(Json, bool)>>,
    cv: Condvar,
}

impl Flight {
    fn fill(&self, value: (Json, bool)) {
        *lock_unpoisoned(&self.done) = Some(value);
        self.cv.notify_all();
    }

    fn wait(&self) -> (Json, bool) {
        let mut done = lock_unpoisoned(&self.done);
        loop {
            if let Some(value) = done.as_ref() {
                return value.clone();
            }
            done = wait_unpoisoned(&self.cv, done);
        }
    }
}

/// The typed facade every frontend dispatches through.
///
/// Create one engine and keep it alive: the grid cache persists across
/// requests (`serve` holds one for its whole lifetime; the CLI commands
/// hold one per invocation).
pub struct Engine {
    grid: GridEngine,
    service: Option<InferenceService>,
    /// Why inference is unavailable (the real artifact-load error), so
    /// per-request failures report the actual cause, not a guess.
    inference_error: Option<String>,
    registry: Registry,
    counters: Counters,
    latency: CommandLatency,
    serve: ServeStats,
    /// Coalescing map: request line -> the in-flight computation for it.
    inflight: Mutex<HashMap<String, Arc<Flight>>>,
    /// Optional content-addressed result store: the coalescer dedupes
    /// in-flight duplicates, the store dedupes across time and restarts.
    store: OnceLock<crate::store::ResultStore>,
}

impl Engine {
    /// An analytics-only engine: every command works except `infer`
    /// (which reports `inference_unavailable`). This is the embedding
    /// entry point for library callers and tests.
    pub fn analytics() -> Engine {
        Engine::assemble(None, None)
    }

    fn assemble(service: Option<InferenceService>, inference_error: Option<String>) -> Engine {
        // Eager catalog registration gives even a fresh engine the full
        // all-zero metric set, so the stats snapshot shape is stable.
        let registry = Registry::new();
        register_catalog(&registry);
        let counters = Counters::new(&registry);
        let latency = CommandLatency::new(&registry);
        let serve = ServeStats::new(&registry);
        Engine {
            grid: GridEngine::new(),
            service,
            inference_error,
            registry,
            counters,
            latency,
            serve,
            inflight: Mutex::new(HashMap::new()),
            store: OnceLock::new(),
        }
    }

    /// Build an engine with the PJRT inference stack, degrading to
    /// analytics-only (with the load error recorded) when the artifact
    /// directory is unavailable.
    pub fn start(max_batch: usize) -> Result<Engine> {
        let (service, inference_error) = match ArtifactDir::open_default() {
            Ok(artifacts) => (
                Some(InferenceService::start(
                    artifacts,
                    ServiceConfig { max_batch, ..ServiceConfig::default() },
                )?),
                None,
            ),
            Err(e) => (None, Some(format!("{e:#}"))),
        };
        Ok(Engine::assemble(service, inference_error))
    }

    /// Whether `{"image": ...}` requests can be served.
    pub fn has_inference(&self) -> bool {
        self.service.is_some()
    }

    /// Why inference is disabled (`None` when it is available).
    pub fn inference_error(&self) -> Option<&str> {
        self.inference_error.as_deref()
    }

    /// The inference service's metrics summary, when inference is up.
    pub fn service_metrics(&self) -> Option<String> {
        self.service.as_ref().map(|s| s.metrics.summary())
    }

    /// `(hits, misses)` of the shared layer-shape cache.
    pub fn cache_stats(&self) -> (u64, u64) {
        self.grid.cache_stats()
    }

    /// The serve-side lifecycle counters (host-facing; on the wire only
    /// through `{"cmd":"stats"}`). The pooled server increments these;
    /// tests and embedders read them.
    pub fn serve_stats(&self) -> &ServeStats {
        &self.serve
    }

    /// The engine's metric registry — every counter and histogram the
    /// `{"cmd":"stats"}` snapshot renders, for embedders that want the
    /// Prometheus exposition or direct handles.
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// The underlying grid engine (for callers composing their own
    /// analytics on the shared cache).
    pub fn grid(&self) -> &GridEngine {
        &self.grid
    }

    /// Attach a content-addressed result store. At most one store per
    /// engine lifetime: returns `false` (and drops `store`) if one is
    /// already attached. Build the store against [`Engine::registry`] so
    /// its `cache_*` counters land in this engine's stats snapshot.
    pub fn attach_store(&self, store: crate::store::ResultStore) -> bool {
        self.store.set(store).is_ok()
    }

    /// The attached result store, if any.
    pub fn store(&self) -> Option<&crate::store::ResultStore> {
        self.store.get()
    }

    /// Replay a stored reply for `req`, if a store is attached, the
    /// request is cacheable and the store holds a valid entry. The
    /// stored payload re-parses to `Json` so hits render through the
    /// same display path as fresh replies (byte-stable by the JSON
    /// round-trip invariant pinned in `util::json`).
    fn store_lookup(&self, req: &Request) -> Option<Json> {
        let store = self.store.get()?;
        let key = crate::store::canon::cache_key(req)?;
        let payload = store.lookup(&key)?;
        // An unparseable payload cannot happen for bytes the store
        // validated, but degrade to a fresh dispatch rather than trust.
        Json::parse(&payload).ok()
    }

    /// Record a successful reply in the attached store (no-op without a
    /// store or for non-cacheable requests).
    fn store_record(&self, req: &Request, reply: &Json) {
        let Some(store) = self.store.get() else { return };
        let Some(key) = crate::store::canon::cache_key(req) else { return };
        store.insert(&key, &reply.to_string());
    }

    /// Dispatch one typed request. Every frontend funnels through here,
    /// so the size caps, worker policy and metrics apply uniformly.
    pub fn dispatch(&self, req: &Request) -> Result<Response, ApiError> {
        self.counters.count(req.cmd());
        let started = Instant::now();
        let result = self.dispatch_inner(req);
        // Recorded after dispatch_inner: a stats snapshot built inside
        // it must not observe its own in-flight dispatch (the pinned
        // stats fixture depends on that).
        let us = started.elapsed().as_micros() as u64;
        self.latency.observe(req.cmd(), us);
        span::global().record_us(span::stage::DISPATCH, us);
        if result.is_err() {
            self.counters.errors.inc();
        }
        result
    }

    /// Decode, dispatch and encode one JSON-lines request. Errors become
    /// `{"code": ..., "error": ...}` replies. The bool asks the host to
    /// stop serving (a `shutdown` request was acknowledged).
    ///
    /// With a store attached, a cacheable request whose canonical form
    /// was answered before replays the stored bytes and skips dispatch
    /// entirely — no per-command counter, no latency observation, no
    /// grid work. Only successful replies are recorded.
    pub fn handle_line(&self, line: &str) -> (Json, bool) {
        let req = match codec::decode_line(line) {
            Ok(req) => req,
            Err(e) => {
                self.counters.errors.inc();
                return Engine::encode(Err(e));
            }
        };
        if let Some(reply) = self.store_lookup(&req) {
            return (reply, false);
        }
        let result = self.dispatch(&req);
        let ok = result.is_ok();
        let value = Engine::encode(result);
        if ok {
            self.store_record(&req, &value.0);
        }
        value
    }

    /// [`Engine::handle_line`] with in-flight coalescing for concurrent
    /// hosts: when several connections submit **byte-identical** analytics
    /// lines (`sweep`/`explore`/`fusion`/`analyze`/`tables`) at the same
    /// time, exactly one computes and the rest wait for — and share — its
    /// reply. Stateful and trivial commands (`infer`, `metrics`,
    /// `version`, `shutdown`) and undecodable lines always dispatch
    /// directly. The reply bytes are identical to [`Engine::handle_line`]
    /// for a leader; followers additionally bump
    /// [`ServeStats::coalesced`] and skip the per-command counter (the
    /// computation was counted once, by the leader). With a store
    /// attached, a stored reply short-circuits before the rendezvous —
    /// the coalescer dedupes in-flight duplicates, the store dedupes
    /// across time and process restarts.
    pub fn handle_line_shared(&self, line: &str) -> (Json, bool) {
        let decode_started = Instant::now();
        let decoded = codec::decode_line(line);
        span::global().record_us(span::stage::DECODE, decode_started.elapsed().as_micros() as u64);
        let req = match decoded {
            Ok(req) => req,
            Err(e) => {
                self.counters.errors.inc();
                let value = Engine::encode_timed(Err(e));
                // Error replies are still written replies; counted after
                // encoding, like every dispatched path below.
                self.serve.dispatched.inc();
                return value;
            }
        };
        // The store sits in front of the coalescer: a stored reply needs
        // no rendezvous (there is nothing in flight to share). A hit is
        // a written reply, so it still counts as dispatched — that keeps
        // `dispatched + coalesced == lines` exact.
        if let Some(reply) = self.store_lookup(&req) {
            self.serve.dispatched.inc();
            return (reply, false);
        }
        if !Engine::coalescable(&req) {
            let value = Engine::encode_timed(self.dispatch(&req));
            // Counted after the reply is built so a stats snapshot never
            // includes its own (still in-flight) request — that keeps
            // `dispatched + coalesced == lines` exact at snapshot time.
            self.serve.dispatched.inc();
            return value;
        }
        let key = line.trim();
        let (flight, leader) = {
            let mut map = lock_unpoisoned(&self.inflight);
            match map.get(key) {
                Some(flight) => (flight.clone(), false),
                None => {
                    let flight = Arc::new(Flight::default());
                    map.insert(key.to_string(), flight.clone());
                    (flight, true)
                }
            }
        };
        if !leader {
            self.serve.coalesced.inc();
            return flight.wait();
        }
        // The guard guarantees the flight is filled and the map entry
        // removed even if the computation panics — followers must never
        // wait forever on a leader that died.
        let guard = FlightGuard { engine: self, key, flight, filled: false };
        let result = self.dispatch(&req);
        let ok = result.is_ok();
        let value = Engine::encode_timed(result);
        if ok {
            self.store_record(&req, &value.0);
        }
        self.serve.dispatched.inc();
        guard.fill(value)
    }

    /// Whether identical concurrent requests may share one computation:
    /// pure analytics only. `infer`/`metrics`/`shutdown` are stateful and
    /// `version` is cheaper than the rendezvous.
    fn coalescable(req: &Request) -> bool {
        matches!(
            req,
            Request::Sweep { .. }
                | Request::Explore { .. }
                | Request::Fusion { .. }
                | Request::Analyze { .. }
                | Request::Tables { .. }
        )
    }

    fn encode(result: Result<Response, ApiError>) -> (Json, bool) {
        match result {
            Ok(resp) => {
                let stop = matches!(resp, Response::Shutdown);
                (resp.to_json(), stop)
            }
            Err(e) => (e.to_json(), false),
        }
    }

    /// [`Engine::encode`] with the `encode` span recorded (serve path).
    fn encode_timed(result: Result<Response, ApiError>) -> (Json, bool) {
        let started = Instant::now();
        let value = Engine::encode(result);
        span::global().record_us(span::stage::ENCODE, started.elapsed().as_micros() as u64);
        value
    }

    fn dispatch_inner(&self, req: &Request) -> Result<Response, ApiError> {
        match req {
            Request::Sweep { spec, workers } => {
                spec.validate().map_err(ApiError::bad)?;
                if spec.cell_count() > MAX_REQUEST_CELLS {
                    return Err(ApiError::too_large(format!(
                        "sweep expands to {} cells (limit {MAX_REQUEST_CELLS})",
                        spec.cell_count()
                    )));
                }
                let workers = effective_workers(*workers);
                let (hits_before, misses_before) = self.grid.cache_stats();
                let grid = self.grid.run_with_workers(spec, workers);
                let (hits_after, misses_after) = self.grid.cache_stats();
                Ok(Response::Sweep {
                    grid,
                    cache_hits: hits_after.saturating_sub(hits_before),
                    cache_misses: misses_after.saturating_sub(misses_before),
                })
            }
            Request::Explore { spec, workers } => {
                spec.validate().map_err(ApiError::bad)?;
                if spec.candidate_count() > MAX_REQUEST_CELLS {
                    return Err(ApiError::too_large(format!(
                        "explore expands to {} candidates (limit {MAX_REQUEST_CELLS})",
                        spec.candidate_count()
                    )));
                }
                let workers = effective_workers(*workers);
                let result = dse_explore::explore(&self.grid, spec, workers);
                Ok(Response::Explore { result })
            }
            Request::Fusion { networks, depth, p_macs, strategy, mode, dt } => {
                if networks.is_empty() {
                    return Err(ApiError::bad_msg("fusion request has no networks"));
                }
                if *depth < 1 {
                    return Err(ApiError::bad_msg("fusion depth must be >= 1"));
                }
                if *p_macs == 0 {
                    return Err(ApiError::bad_msg("MAC budget must be > 0"));
                }
                let table = report_fusion::fusion_table_dt(
                    &self.grid,
                    networks,
                    *depth,
                    *p_macs,
                    *strategy,
                    *mode,
                    dt,
                );
                let note = report_fusion::summarize(networks.len(), *depth, *p_macs);
                Ok(Response::Table { table, note })
            }
            Request::Analyze { network, p_macs, strategy, mode, dt } => {
                if *p_macs == 0 {
                    return Err(ApiError::bad_msg("MAC budget must be > 0"));
                }
                let (table, note) = report_analyze::analyze_table_dt(
                    &self.grid,
                    network,
                    *p_macs,
                    *strategy,
                    *mode,
                    dt,
                );
                Ok(Response::Table { table, note })
            }
            Request::Tables { table, faithful } => {
                if *faithful && matches!(table, TableKind::Fig2 | TableKind::Fig2Ascii) {
                    // Fail loudly rather than silently serve the
                    // non-faithful figure (the paper-profile Fig. 2 is
                    // the only one the crate renders).
                    return Err(ApiError::bad_msg("fig2 has no faithful variant"));
                }
                let nets = faithful.then(crate::models::zoo::faithful_networks);
                Ok(match table {
                    TableKind::Table1 => Response::Table {
                        table: match &nets {
                            Some(nets) => tables::table1_for(nets),
                            None => tables::table1(),
                        },
                        note: String::new(),
                    },
                    TableKind::Table2 => Response::Table {
                        table: match &nets {
                            Some(nets) => tables::table2_for(nets),
                            None => tables::table2(),
                        },
                        note: String::new(),
                    },
                    TableKind::Table3 => Response::Table {
                        table: match &nets {
                            Some(nets) => tables::table3_for(nets),
                            None => tables::table3(),
                        },
                        note: String::new(),
                    },
                    TableKind::Fig2 => {
                        Response::Table { table: fig2::fig2_table(), note: String::new() }
                    }
                    TableKind::Fig2Ascii => Response::Text { text: fig2::fig2_ascii() },
                })
            }
            Request::Zoo => {
                // Static listing (no engine state, no knobs): cheaper
                // than the coalescing rendezvous, so it dispatches
                // directly like `version`, and needs no new metric —
                // count/observe no-op on commands outside the catalog.
                let (table, note) = report_zoo::zoo_table();
                Ok(Response::Table { table, note })
            }
            Request::Infer { image } => {
                let service = self.service.as_ref().ok_or_else(|| {
                    ApiError::new(
                        ErrorCode::InferenceUnavailable,
                        format!(
                            "inference unavailable: {}",
                            self.inference_error.as_deref().unwrap_or("service not started")
                        ),
                    )
                })?;
                if image.len() != IMAGE_ELEMS {
                    return Err(ApiError::bad_msg(format!(
                        "image must have {IMAGE_ELEMS} floats, got {}",
                        image.len()
                    )));
                }
                let tensor =
                    Tensor::new(vec![3, 32, 32], image.clone()).map_err(ApiError::internal)?;
                let resp = service.infer(tensor).map_err(ApiError::internal)?;
                Ok(Response::Infer(resp))
            }
            Request::Metrics => {
                let summary = match &self.service {
                    Some(service) => service.metrics.summary(),
                    None => "inference disabled (analytics-only mode)".to_string(),
                };
                Ok(Response::Metrics { summary, requests: self.counters.snapshot() })
            }
            Request::Stats => Ok(Response::Stats { snapshot: self.stats_snapshot() }),
            Request::Version => Ok(Response::Version),
            Request::Shutdown => Ok(Response::Shutdown),
        }
    }

    /// The `{"cmd":"stats"}` document: the registry snapshot (sorted
    /// keys) plus the protocol and stats-schema versions. Additive-only:
    /// new metrics appear as new keys without bumping `schema`.
    fn stats_snapshot(&self) -> Json {
        // The snapshot is an object by construction; the fallback keeps
        // this path panic-free (lint PS100) rather than asserting it.
        let mut snap = match self.registry.snapshot_json() {
            Json::Obj(snap) => snap,
            _ => std::collections::BTreeMap::new(),
        };
        snap.insert("protocol".to_string(), Json::Num(super::PROTOCOL_VERSION as f64));
        snap.insert("schema".to_string(), Json::Num(super::STATS_SCHEMA_VERSION as f64));
        Json::Obj(snap)
    }
}

/// Completion guard for a coalescing leader: `fill` publishes the real
/// reply; if the leader unwinds first, `Drop` publishes an `internal`
/// error instead so followers wake rather than hang, then removes the
/// map entry either way.
struct FlightGuard<'a> {
    engine: &'a Engine,
    key: &'a str,
    flight: Arc<Flight>,
    filled: bool,
}

impl FlightGuard<'_> {
    fn fill(mut self, value: (Json, bool)) -> (Json, bool) {
        self.complete(value.clone());
        value
    }

    fn complete(&mut self, value: (Json, bool)) {
        if self.filled {
            return;
        }
        self.filled = true;
        self.flight.fill(value);
        lock_unpoisoned(&self.engine.inflight).remove(self.key);
    }
}

impl Drop for FlightGuard<'_> {
    fn drop(&mut self) {
        let poisoned =
            ApiError::internal(anyhow::anyhow!("request computation panicked")).to_json();
        self.complete((poisoned, false));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::bandwidth::ControllerMode;
    use crate::analytics::grid::SweepSpec;
    use crate::analytics::partition::Strategy;
    use crate::models::zoo;

    fn small_sweep() -> SweepSpec {
        SweepSpec::new(vec![zoo::alexnet()])
            .with_macs(vec![512])
            .with_strategies(vec![Strategy::Optimal])
            .with_modes(vec![ControllerMode::Passive])
    }

    #[test]
    fn dispatch_sweep_returns_cells_and_cache_deltas() {
        let engine = Engine::analytics();
        let req = Request::Sweep { spec: small_sweep(), workers: Some(1) };
        let Response::Sweep { grid, cache_hits, cache_misses } = engine.dispatch(&req).unwrap()
        else {
            panic!("not a sweep response");
        };
        assert_eq!(grid.len(), 1);
        assert_eq!((cache_hits, cache_misses), (0, 5));
        // A second identical request is answered from the shared cache.
        let Response::Sweep { cache_hits, cache_misses, .. } = engine.dispatch(&req).unwrap()
        else {
            panic!("not a sweep response");
        };
        assert_eq!((cache_hits, cache_misses), (5, 0));
    }

    #[test]
    fn caps_apply_to_both_sweep_and_explore() {
        let engine = Engine::analytics();
        let spec = SweepSpec::new(vec![zoo::alexnet()]).with_batches((1..=2101).collect());
        assert!(spec.cell_count() > MAX_REQUEST_CELLS);
        let err = engine.dispatch(&Request::Sweep { spec, workers: Some(1) }).unwrap_err();
        assert_eq!(err.code, ErrorCode::TooLarge);

        let spec = crate::dse::space::ExploreSpec::new(vec![zoo::alexnet()])
            .with_macs((1..=3200).collect());
        assert!(spec.candidate_count() > MAX_REQUEST_CELLS);
        let err = engine.dispatch(&Request::Explore { spec, workers: Some(1) }).unwrap_err();
        assert_eq!(err.code, ErrorCode::TooLarge);
    }

    #[test]
    fn overflowing_axis_products_saturate_into_the_cap() {
        // 2^16-entry axes multiply past 2^64; wrapping arithmetic would
        // fold the product to a tiny count and slip under the cap —
        // cell_count/candidate_count must saturate instead.
        let engine = Engine::analytics();
        let spec = SweepSpec::new(vec![zoo::alexnet()])
            .with_macs(vec![512; 1 << 16])
            .with_strategies(vec![Strategy::Optimal; 1 << 16])
            .with_batches(vec![1; 1 << 16])
            .with_fusion(vec![1; 1 << 16]);
        assert_eq!(spec.cell_count(), usize::MAX);
        let err = engine.dispatch(&Request::Sweep { spec, workers: Some(1) }).unwrap_err();
        assert_eq!(err.code, ErrorCode::TooLarge);

        let spec = crate::dse::space::ExploreSpec::new(vec![zoo::alexnet()])
            .with_macs(vec![512; 1 << 16])
            .with_sram(vec![crate::dse::budget::SramBudget::Unlimited; 1 << 16])
            .with_strategies(vec![Strategy::Optimal; 1 << 16])
            .with_fusion(vec![1; 1 << 16]);
        assert_eq!(spec.candidate_count(), usize::MAX);
        let err = engine.dispatch(&Request::Explore { spec, workers: Some(1) }).unwrap_err();
        assert_eq!(err.code, ErrorCode::TooLarge);
    }

    #[test]
    fn fig2_rejects_the_faithful_flag_loudly() {
        let engine = Engine::analytics();
        for kind in [TableKind::Fig2, TableKind::Fig2Ascii] {
            let err = engine
                .dispatch(&Request::Tables { table: kind, faithful: true })
                .unwrap_err();
            assert_eq!(err.code, ErrorCode::BadRequest);
            assert_eq!(err.message, "fig2 has no faithful variant");
        }
        // The paper tables do have faithful variants.
        let ok = engine.dispatch(&Request::Tables { table: TableKind::Table3, faithful: true });
        assert!(ok.is_ok());
    }

    #[test]
    fn invalid_specs_are_bad_requests_not_panics() {
        let engine = Engine::analytics();
        let spec = SweepSpec::new(vec![zoo::alexnet()]).with_batches(vec![0]);
        let err = engine.dispatch(&Request::Sweep { spec, workers: None }).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
    }

    #[test]
    fn metrics_count_requests_and_errors() {
        let engine = Engine::analytics();
        engine.dispatch(&Request::Version).unwrap();
        engine.dispatch(&Request::Version).unwrap();
        let _ = engine.handle_line("not json");
        let Response::Metrics { summary, requests } =
            engine.dispatch(&Request::Metrics).unwrap()
        else {
            panic!("not a metrics response");
        };
        assert!(summary.contains("disabled"));
        assert_eq!(requests, vec![("metrics", 1), ("version", 2), ("errors", 1)]);
    }

    #[test]
    fn zoo_lists_networks_without_touching_the_metric_catalog() {
        let engine = Engine::analytics();
        let (reply, stop) = engine.handle_line(r#"{"cmd":"zoo"}"#);
        assert!(!stop);
        let table = reply.get("table").unwrap().as_str().unwrap();
        assert!(table.contains("ViT-Tiny"), "{table}");
        assert!(table.contains("AlexNet"), "{table}");
        assert!(reply.get("note").unwrap().as_str().unwrap().contains("networks"));
        // `zoo` is deliberately outside the pinned metric catalog
        // (count/observe no-op on it): the stats snapshot shape — pinned
        // by the stats fixture — must not grow a zoo entry.
        let (stats, _) = engine.handle_line(r#"{"cmd":"stats"}"#);
        assert!(stats.get("counters").unwrap().get("api_requests_zoo").is_none());
        assert!(stats.get("histograms").unwrap().get("api_latency_us_zoo").is_none());
    }

    #[test]
    fn infer_without_service_reports_unavailable() {
        let engine = Engine::analytics();
        let err = engine.dispatch(&Request::Infer { image: vec![0.0; IMAGE_ELEMS] }).unwrap_err();
        assert_eq!(err.code, ErrorCode::InferenceUnavailable);
        assert!(err.message.contains("inference unavailable"), "{err}");
    }

    #[test]
    fn workers_policy_is_shared() {
        assert_eq!(effective_workers(Some(0)), 1);
        assert_eq!(effective_workers(Some(3)), 3);
        assert_eq!(effective_workers(Some(1000)), 64);
        assert!(effective_workers(None) >= 1);
    }

    const SWEEP_LINE: &str = r#"{"cmd":"sweep","networks":["AlexNet"],"macs":[512],
                                 "strategies":["optimal"],"modes":["passive"]}"#;

    #[test]
    fn shared_handler_matches_handle_line_bytes() {
        // Leader path: reply bytes identical to the plain handler, for
        // analytics, trivial and undecodable lines alike.
        for line in [SWEEP_LINE, r#"{"cmd":"version"}"#, "not json", r#"{"cmd":"tables"}"#] {
            let (plain, stop_a) = Engine::analytics().handle_line(line);
            let (shared, stop_b) = Engine::analytics().handle_line_shared(line);
            assert_eq!(plain.to_string(), shared.to_string(), "{line}");
            assert_eq!(stop_a, stop_b);
        }
    }

    #[test]
    fn shared_handler_cleans_up_the_inflight_map() {
        let engine = Engine::analytics();
        let _ = engine.handle_line_shared(SWEEP_LINE);
        assert!(engine.inflight.lock().unwrap().is_empty());
        assert_eq!(engine.serve_stats().coalesced.get(), 0);
    }

    /// Deterministic follower rendezvous: pre-insert the flight (what a
    /// leader does first), start a follower, then publish a marker reply.
    /// The follower must return the marker — proof it shared the flight
    /// instead of computing — regardless of thread timing.
    #[test]
    fn concurrent_identical_requests_share_one_flight() {
        let engine = Engine::analytics();
        let key = SWEEP_LINE.trim();
        let flight = Arc::new(Flight::default());
        engine.inflight.lock().unwrap().insert(key.to_string(), flight.clone());

        let marker = Json::obj(vec![("marker", Json::Bool(true))]);
        std::thread::scope(|scope| {
            let follower = scope.spawn(|| engine.handle_line_shared(SWEEP_LINE));
            // Publish the marker; the follower picks it up whether it is
            // already parked on the condvar or yet to arrive.
            flight.fill((marker.clone(), false));
            let (reply, stop) = follower.join().unwrap();
            assert_eq!(reply.to_string(), marker.to_string());
            assert!(!stop);
        });
        assert_eq!(engine.serve_stats().coalesced.get(), 1);
        // The follower never dispatched: no sweep was counted.
        assert_eq!(engine.counters.sweep.get(), 0);
        engine.inflight.lock().unwrap().remove(key);
    }

    #[test]
    fn burst_of_identical_requests_agrees_on_the_reply() {
        let engine = Engine::analytics();
        let replies: Vec<String> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8)
                .map(|_| scope.spawn(|| engine.handle_line_shared(SWEEP_LINE).0.to_string()))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // Every reply is a real sweep result... but cache deltas differ
        // between a cold leader and later runs, so compare the cells only.
        for reply in &replies {
            let json = Json::parse(reply).unwrap();
            assert_eq!(json.get("count").unwrap().as_usize(), Some(1), "{reply}");
        }
        assert!(engine.inflight.lock().unwrap().is_empty());
        let coalesced = engine.serve_stats().coalesced.get();
        let dispatched = engine.counters.sweep.get();
        assert_eq!(coalesced + dispatched, 8, "every request was answered exactly once");
        assert!(dispatched >= 1);
        // The serve-side reply accounting agrees: every reply was either
        // freshly dispatched or coalesced.
        assert_eq!(engine.serve_stats().dispatched.get() + coalesced, 8);
    }

    #[test]
    fn serve_stats_track_peak_and_summarize() {
        let stats = ServeStats::new(&Registry::new());
        stats.note_queue_depth(3);
        stats.note_queue_depth(1);
        assert_eq!(stats.queue_peak(), 3);
        stats.accepted.add(2);
        stats.shed.inc();
        let line = stats.summary();
        assert!(line.contains("accepted=2"), "{line}");
        assert!(line.contains("shed=1"), "{line}");
        assert!(line.contains("queue peak=3"), "{line}");
    }

    #[test]
    fn stats_snapshot_is_deterministic_on_a_fresh_engine() {
        let engine = Engine::analytics();
        let (reply, stop) = engine.handle_line(r#"{"cmd":"stats"}"#);
        assert!(!stop);
        let counters = reply.get("counters").unwrap();
        // The stats request itself was counted before dispatch_inner ran…
        assert_eq!(counters.get("api_requests_stats").unwrap().as_usize(), Some(1));
        assert_eq!(counters.get("serve_conns_accepted").unwrap().as_usize(), Some(0));
        assert_eq!(counters.get("serve_replies_dispatched").unwrap().as_usize(), Some(0));
        // …but its latency is recorded only after the snapshot was built.
        let hist = reply.get("histograms").unwrap().get("api_latency_us_stats").unwrap();
        assert_eq!(hist.get("count").unwrap().as_usize(), Some(0));
        assert_eq!(reply.get("protocol").unwrap().as_usize(), Some(super::super::PROTOCOL_VERSION));
        assert_eq!(
            reply.get("schema").unwrap().as_usize(),
            Some(super::super::STATS_SCHEMA_VERSION)
        );
    }

    #[test]
    fn latency_histograms_record_completed_dispatches() {
        let engine = Engine::analytics();
        engine.dispatch(&Request::Version).unwrap();
        engine.dispatch(&Request::Version).unwrap();
        let (first, _) = engine.handle_line(r#"{"cmd":"stats"}"#);
        let version = first.get("histograms").unwrap().get("api_latency_us_version").unwrap();
        assert_eq!(version.get("count").unwrap().as_usize(), Some(2));
        // A second snapshot sees the first stats dispatch completed.
        let (second, _) = engine.handle_line(r#"{"cmd":"stats"}"#);
        let stats = second.get("histograms").unwrap().get("api_latency_us_stats").unwrap();
        assert_eq!(stats.get("count").unwrap().as_usize(), Some(1));
        let requests = second.get("counters").unwrap().get("api_requests_stats").unwrap();
        assert_eq!(requests.as_usize(), Some(2));
    }

    #[test]
    fn shared_handler_counts_dispatched_replies() {
        let engine = Engine::analytics();
        let _ = engine.handle_line_shared(r#"{"cmd":"version"}"#);
        let _ = engine.handle_line_shared("not json");
        let _ = engine.handle_line_shared(SWEEP_LINE);
        assert_eq!(engine.serve_stats().dispatched.get(), 3);
        assert_eq!(engine.serve_stats().coalesced.get(), 0);
    }

    fn engine_with_memory_store() -> Engine {
        let engine = Engine::analytics();
        let store = crate::store::ResultStore::memory(8, engine.registry());
        assert!(engine.attach_store(store));
        engine
    }

    #[test]
    fn attach_store_accepts_exactly_one_store() {
        let engine = engine_with_memory_store();
        let second = crate::store::ResultStore::memory(8, engine.registry());
        assert!(!engine.attach_store(second));
        assert!(engine.store().is_some());
    }

    #[test]
    fn store_hit_replays_bytes_and_skips_dispatch() {
        let engine = engine_with_memory_store();
        let (cold, _) = engine.handle_line(SWEEP_LINE);
        let (warm, _) = engine.handle_line(SWEEP_LINE);
        assert_eq!(cold.to_string(), warm.to_string());
        // The warm reply never dispatched: one sweep counted, and its
        // latency histogram saw exactly one observation.
        assert_eq!(engine.counters.sweep.get(), 1);
        let c = engine.store().unwrap().counters();
        assert_eq!((c.lookups.get(), c.hits.get(), c.misses.get()), (2, 1, 1));
    }

    #[test]
    fn store_hit_counts_as_dispatched_on_the_shared_path() {
        let engine = engine_with_memory_store();
        let _ = engine.handle_line_shared(SWEEP_LINE);
        let _ = engine.handle_line_shared(SWEEP_LINE);
        // Both replies were written: dispatched covers fresh AND stored
        // replies, so `dispatched + coalesced == lines` stays exact.
        assert_eq!(engine.serve_stats().dispatched.get(), 2);
        assert_eq!(engine.serve_stats().coalesced.get(), 0);
        assert_eq!(engine.counters.sweep.get(), 1);
        assert_eq!(engine.store().unwrap().counters().hits.get(), 1);
    }

    #[test]
    fn spelling_variants_share_one_store_entry() {
        let engine = engine_with_memory_store();
        let a = r#"{"cmd":"tables","table":"table3"}"#;
        let b = r#"{"table":"table3","cmd":"tables","faithful":false}"#;
        let (cold, _) = engine.handle_line(a);
        let (warm, _) = engine.handle_line(b);
        assert_eq!(cold.to_string(), warm.to_string());
        assert_eq!(engine.store().unwrap().counters().hits.get(), 1);
    }

    #[test]
    fn error_replies_are_never_cached() {
        let engine = engine_with_memory_store();
        let bad = r#"{"cmd":"sweep","networks":["AlexNet"],"batches":[0]}"#;
        let (first, _) = engine.handle_line(bad);
        let (second, _) = engine.handle_line(bad);
        assert!(first.get("error").is_some(), "{first}");
        assert_eq!(first.to_string(), second.to_string());
        let c = engine.store().unwrap().counters();
        // Both attempts missed and neither recorded a reply.
        assert_eq!((c.hits.get(), c.misses.get()), (0, 2));
    }
}
