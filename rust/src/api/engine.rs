//! The dispatcher: one engine every frontend drives.
//!
//! [`Engine`] owns the long-lived [`GridEngine`] layer-shape cache (so
//! repeated requests get warmer regardless of which frontend they arrive
//! through), the per-request size caps (previously enforced by `serve`
//! only — now every frontend gets them), the optional PJRT inference
//! stack, and per-request metrics.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::Result;

use crate::analytics::grid::GridEngine;
use crate::coordinator::parallel::default_workers;
use crate::coordinator::{InferenceService, ServiceConfig};
use crate::dse::explore as dse_explore;
use crate::report::{analyze as report_analyze, fig2, fusion as report_fusion, tables};
use crate::runtime::{ArtifactDir, Tensor};
use crate::util::json::Json;

use super::codec;
use super::error::{ApiError, ErrorCode};
use super::request::{Request, TableKind};
use super::response::Response;

/// Inference request payload size (CIFAR-shaped 3×32×32 image).
pub const IMAGE_ELEMS: usize = 3 * 32 * 32;

/// Largest grid (sweep) or candidate set (explore) a single request may
/// expand to, enforced in [`Engine::dispatch`] for every frontend.
pub const MAX_REQUEST_CELLS: usize = 100_000;

/// Resolve a request's optional worker count: default to machine
/// parallelism, clamp to the per-request cap. One policy for every
/// frontend, so it cannot drift.
pub fn effective_workers(requested: Option<usize>) -> usize {
    requested.unwrap_or_else(default_workers).clamp(1, 64)
}

/// Per-command request counters (and an error total), surfaced through
/// `{"cmd":"metrics"}`.
#[derive(Default)]
struct Counters {
    sweep: AtomicU64,
    explore: AtomicU64,
    fusion: AtomicU64,
    analyze: AtomicU64,
    tables: AtomicU64,
    infer: AtomicU64,
    metrics: AtomicU64,
    version: AtomicU64,
    shutdown: AtomicU64,
    errors: AtomicU64,
}

impl Counters {
    fn slots(&self) -> [(&'static str, &AtomicU64); 10] {
        [
            ("sweep", &self.sweep),
            ("explore", &self.explore),
            ("fusion", &self.fusion),
            ("analyze", &self.analyze),
            ("tables", &self.tables),
            ("infer", &self.infer),
            ("metrics", &self.metrics),
            ("version", &self.version),
            ("shutdown", &self.shutdown),
            ("errors", &self.errors),
        ]
    }

    fn count(&self, cmd: &str) {
        for (name, slot) in self.slots() {
            if name == cmd {
                slot.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
    }

    /// Non-zero counters only, in slot order (the JSON object sorts).
    fn snapshot(&self) -> Vec<(&'static str, u64)> {
        self.slots()
            .into_iter()
            .map(|(name, slot)| (name, slot.load(Ordering::Relaxed)))
            .filter(|&(_, n)| n > 0)
            .collect()
    }
}

/// The typed facade every frontend dispatches through.
///
/// Create one engine and keep it alive: the grid cache persists across
/// requests (`serve` holds one for its whole lifetime; the CLI commands
/// hold one per invocation).
pub struct Engine {
    grid: GridEngine,
    service: Option<InferenceService>,
    /// Why inference is unavailable (the real artifact-load error), so
    /// per-request failures report the actual cause, not a guess.
    inference_error: Option<String>,
    counters: Counters,
}

impl Engine {
    /// An analytics-only engine: every command works except `infer`
    /// (which reports `inference_unavailable`). This is the embedding
    /// entry point for library callers and tests.
    pub fn analytics() -> Engine {
        Engine {
            grid: GridEngine::new(),
            service: None,
            inference_error: None,
            counters: Counters::default(),
        }
    }

    /// Build an engine with the PJRT inference stack, degrading to
    /// analytics-only (with the load error recorded) when the artifact
    /// directory is unavailable.
    pub fn start(max_batch: usize) -> Result<Engine> {
        let (service, inference_error) = match ArtifactDir::open_default() {
            Ok(artifacts) => (
                Some(InferenceService::start(
                    artifacts,
                    ServiceConfig { max_batch, ..ServiceConfig::default() },
                )?),
                None,
            ),
            Err(e) => (None, Some(format!("{e:#}"))),
        };
        Ok(Engine {
            grid: GridEngine::new(),
            service,
            inference_error,
            counters: Counters::default(),
        })
    }

    /// Whether `{"image": ...}` requests can be served.
    pub fn has_inference(&self) -> bool {
        self.service.is_some()
    }

    /// Why inference is disabled (`None` when it is available).
    pub fn inference_error(&self) -> Option<&str> {
        self.inference_error.as_deref()
    }

    /// The inference service's metrics summary, when inference is up.
    pub fn service_metrics(&self) -> Option<String> {
        self.service.as_ref().map(|s| s.metrics.summary())
    }

    /// `(hits, misses)` of the shared layer-shape cache.
    pub fn cache_stats(&self) -> (u64, u64) {
        self.grid.cache_stats()
    }

    /// The underlying grid engine (for callers composing their own
    /// analytics on the shared cache).
    pub fn grid(&self) -> &GridEngine {
        &self.grid
    }

    /// Dispatch one typed request. Every frontend funnels through here,
    /// so the size caps, worker policy and metrics apply uniformly.
    pub fn dispatch(&self, req: &Request) -> Result<Response, ApiError> {
        self.counters.count(req.cmd());
        let result = self.dispatch_inner(req);
        if result.is_err() {
            self.counters.errors.fetch_add(1, Ordering::Relaxed);
        }
        result
    }

    /// Decode, dispatch and encode one JSON-lines request. Errors become
    /// `{"code": ..., "error": ...}` replies. The bool asks the host to
    /// stop serving (a `shutdown` request was acknowledged).
    pub fn handle_line(&self, line: &str) -> (Json, bool) {
        let result = match codec::decode_line(line) {
            Ok(req) => self.dispatch(&req),
            Err(e) => {
                self.counters.errors.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        };
        match result {
            Ok(resp) => {
                let stop = matches!(resp, Response::Shutdown);
                (resp.to_json(), stop)
            }
            Err(e) => (e.to_json(), false),
        }
    }

    fn dispatch_inner(&self, req: &Request) -> Result<Response, ApiError> {
        match req {
            Request::Sweep { spec, workers } => {
                spec.validate().map_err(ApiError::bad)?;
                if spec.cell_count() > MAX_REQUEST_CELLS {
                    return Err(ApiError::too_large(format!(
                        "sweep expands to {} cells (limit {MAX_REQUEST_CELLS})",
                        spec.cell_count()
                    )));
                }
                let workers = effective_workers(*workers);
                let (hits_before, misses_before) = self.grid.cache_stats();
                let grid = self.grid.run_with_workers(spec, workers);
                let (hits_after, misses_after) = self.grid.cache_stats();
                Ok(Response::Sweep {
                    grid,
                    cache_hits: hits_after.saturating_sub(hits_before),
                    cache_misses: misses_after.saturating_sub(misses_before),
                })
            }
            Request::Explore { spec, workers } => {
                spec.validate().map_err(ApiError::bad)?;
                if spec.candidate_count() > MAX_REQUEST_CELLS {
                    return Err(ApiError::too_large(format!(
                        "explore expands to {} candidates (limit {MAX_REQUEST_CELLS})",
                        spec.candidate_count()
                    )));
                }
                let workers = effective_workers(*workers);
                let result = dse_explore::explore(&self.grid, spec, workers);
                Ok(Response::Explore { result })
            }
            Request::Fusion { networks, depth, p_macs, strategy, mode, dt } => {
                if networks.is_empty() {
                    return Err(ApiError::bad_msg("fusion request has no networks"));
                }
                if *depth < 1 {
                    return Err(ApiError::bad_msg("fusion depth must be >= 1"));
                }
                if *p_macs == 0 {
                    return Err(ApiError::bad_msg("MAC budget must be > 0"));
                }
                let table = report_fusion::fusion_table_dt(
                    &self.grid,
                    networks,
                    *depth,
                    *p_macs,
                    *strategy,
                    *mode,
                    dt,
                );
                let note = report_fusion::summarize(networks.len(), *depth, *p_macs);
                Ok(Response::Table { table, note })
            }
            Request::Analyze { network, p_macs, strategy, mode, dt } => {
                if *p_macs == 0 {
                    return Err(ApiError::bad_msg("MAC budget must be > 0"));
                }
                let (table, note) = report_analyze::analyze_table_dt(
                    &self.grid,
                    network,
                    *p_macs,
                    *strategy,
                    *mode,
                    dt,
                );
                Ok(Response::Table { table, note })
            }
            Request::Tables { table, faithful } => {
                if *faithful && matches!(table, TableKind::Fig2 | TableKind::Fig2Ascii) {
                    // Fail loudly rather than silently serve the
                    // non-faithful figure (the paper-profile Fig. 2 is
                    // the only one the crate renders).
                    return Err(ApiError::bad_msg("fig2 has no faithful variant"));
                }
                let nets = faithful.then(crate::models::zoo::faithful_networks);
                Ok(match table {
                    TableKind::Table1 => Response::Table {
                        table: match &nets {
                            Some(nets) => tables::table1_for(nets),
                            None => tables::table1(),
                        },
                        note: String::new(),
                    },
                    TableKind::Table2 => Response::Table {
                        table: match &nets {
                            Some(nets) => tables::table2_for(nets),
                            None => tables::table2(),
                        },
                        note: String::new(),
                    },
                    TableKind::Table3 => Response::Table {
                        table: match &nets {
                            Some(nets) => tables::table3_for(nets),
                            None => tables::table3(),
                        },
                        note: String::new(),
                    },
                    TableKind::Fig2 => {
                        Response::Table { table: fig2::fig2_table(), note: String::new() }
                    }
                    TableKind::Fig2Ascii => Response::Text { text: fig2::fig2_ascii() },
                })
            }
            Request::Infer { image } => {
                let service = self.service.as_ref().ok_or_else(|| {
                    ApiError::new(
                        ErrorCode::InferenceUnavailable,
                        format!(
                            "inference unavailable: {}",
                            self.inference_error.as_deref().unwrap_or("service not started")
                        ),
                    )
                })?;
                if image.len() != IMAGE_ELEMS {
                    return Err(ApiError::bad_msg(format!(
                        "image must have {IMAGE_ELEMS} floats, got {}",
                        image.len()
                    )));
                }
                let tensor =
                    Tensor::new(vec![3, 32, 32], image.clone()).map_err(ApiError::internal)?;
                let resp = service.infer(tensor).map_err(ApiError::internal)?;
                Ok(Response::Infer(resp))
            }
            Request::Metrics => {
                let summary = match &self.service {
                    Some(service) => service.metrics.summary(),
                    None => "inference disabled (analytics-only mode)".to_string(),
                };
                Ok(Response::Metrics { summary, requests: self.counters.snapshot() })
            }
            Request::Version => Ok(Response::Version),
            Request::Shutdown => Ok(Response::Shutdown),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::bandwidth::ControllerMode;
    use crate::analytics::grid::SweepSpec;
    use crate::analytics::partition::Strategy;
    use crate::models::zoo;

    fn small_sweep() -> SweepSpec {
        SweepSpec::new(vec![zoo::alexnet()])
            .with_macs(vec![512])
            .with_strategies(vec![Strategy::Optimal])
            .with_modes(vec![ControllerMode::Passive])
    }

    #[test]
    fn dispatch_sweep_returns_cells_and_cache_deltas() {
        let engine = Engine::analytics();
        let req = Request::Sweep { spec: small_sweep(), workers: Some(1) };
        let Response::Sweep { grid, cache_hits, cache_misses } = engine.dispatch(&req).unwrap()
        else {
            panic!("not a sweep response");
        };
        assert_eq!(grid.len(), 1);
        assert_eq!((cache_hits, cache_misses), (0, 5));
        // A second identical request is answered from the shared cache.
        let Response::Sweep { cache_hits, cache_misses, .. } = engine.dispatch(&req).unwrap()
        else {
            panic!("not a sweep response");
        };
        assert_eq!((cache_hits, cache_misses), (5, 0));
    }

    #[test]
    fn caps_apply_to_both_sweep_and_explore() {
        let engine = Engine::analytics();
        let spec = SweepSpec::new(vec![zoo::alexnet()]).with_batches((1..=2101).collect());
        assert!(spec.cell_count() > MAX_REQUEST_CELLS);
        let err = engine.dispatch(&Request::Sweep { spec, workers: Some(1) }).unwrap_err();
        assert_eq!(err.code, ErrorCode::TooLarge);

        let spec = crate::dse::space::ExploreSpec::new(vec![zoo::alexnet()])
            .with_macs((1..=3200).collect());
        assert!(spec.candidate_count() > MAX_REQUEST_CELLS);
        let err = engine.dispatch(&Request::Explore { spec, workers: Some(1) }).unwrap_err();
        assert_eq!(err.code, ErrorCode::TooLarge);
    }

    #[test]
    fn overflowing_axis_products_saturate_into_the_cap() {
        // 2^16-entry axes multiply past 2^64; wrapping arithmetic would
        // fold the product to a tiny count and slip under the cap —
        // cell_count/candidate_count must saturate instead.
        let engine = Engine::analytics();
        let spec = SweepSpec::new(vec![zoo::alexnet()])
            .with_macs(vec![512; 1 << 16])
            .with_strategies(vec![Strategy::Optimal; 1 << 16])
            .with_batches(vec![1; 1 << 16])
            .with_fusion(vec![1; 1 << 16]);
        assert_eq!(spec.cell_count(), usize::MAX);
        let err = engine.dispatch(&Request::Sweep { spec, workers: Some(1) }).unwrap_err();
        assert_eq!(err.code, ErrorCode::TooLarge);

        let spec = crate::dse::space::ExploreSpec::new(vec![zoo::alexnet()])
            .with_macs(vec![512; 1 << 16])
            .with_sram(vec![crate::dse::budget::SramBudget::Unlimited; 1 << 16])
            .with_strategies(vec![Strategy::Optimal; 1 << 16])
            .with_fusion(vec![1; 1 << 16]);
        assert_eq!(spec.candidate_count(), usize::MAX);
        let err = engine.dispatch(&Request::Explore { spec, workers: Some(1) }).unwrap_err();
        assert_eq!(err.code, ErrorCode::TooLarge);
    }

    #[test]
    fn fig2_rejects_the_faithful_flag_loudly() {
        let engine = Engine::analytics();
        for kind in [TableKind::Fig2, TableKind::Fig2Ascii] {
            let err = engine
                .dispatch(&Request::Tables { table: kind, faithful: true })
                .unwrap_err();
            assert_eq!(err.code, ErrorCode::BadRequest);
            assert_eq!(err.message, "fig2 has no faithful variant");
        }
        // The paper tables do have faithful variants.
        let ok = engine.dispatch(&Request::Tables { table: TableKind::Table3, faithful: true });
        assert!(ok.is_ok());
    }

    #[test]
    fn invalid_specs_are_bad_requests_not_panics() {
        let engine = Engine::analytics();
        let spec = SweepSpec::new(vec![zoo::alexnet()]).with_batches(vec![0]);
        let err = engine.dispatch(&Request::Sweep { spec, workers: None }).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
    }

    #[test]
    fn metrics_count_requests_and_errors() {
        let engine = Engine::analytics();
        engine.dispatch(&Request::Version).unwrap();
        engine.dispatch(&Request::Version).unwrap();
        let _ = engine.handle_line("not json");
        let Response::Metrics { summary, requests } =
            engine.dispatch(&Request::Metrics).unwrap()
        else {
            panic!("not a metrics response");
        };
        assert!(summary.contains("disabled"));
        assert_eq!(requests, vec![("metrics", 1), ("version", 2), ("errors", 1)]);
    }

    #[test]
    fn infer_without_service_reports_unavailable() {
        let engine = Engine::analytics();
        let err = engine.dispatch(&Request::Infer { image: vec![0.0; IMAGE_ELEMS] }).unwrap_err();
        assert_eq!(err.code, ErrorCode::InferenceUnavailable);
        assert!(err.message.contains("inference unavailable"), "{err}");
    }

    #[test]
    fn workers_policy_is_shared() {
        assert_eq!(effective_workers(Some(0)), 1);
        assert_eq!(effective_workers(Some(3)), 3);
        assert_eq!(effective_workers(Some(1000)), 64);
        assert!(effective_workers(None) >= 1);
    }
}
