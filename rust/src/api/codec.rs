//! The protocol codec: ONE set of JSON axis parsers shared by every
//! request shape, plus the [`Request`] decode/encode pair.
//!
//! Before the facade, `SweepSpec::from_json` and `ExploreSpec::from_json`
//! each carried their own copies of the network/MAC/strategy/mode/fusion
//! parsing; a new axis (or a message tweak) had to land twice. Both spec
//! parsers now delegate to the helpers here, and new frontends get the
//! same accept/reject behavior for free.
//!
//! Requests may carry an optional `"protocol"` field; when present it
//! must equal [`PROTOCOL_VERSION`](super::PROTOCOL_VERSION), so clients
//! can pin the dialect they were written against and fail loudly on a
//! mismatch instead of misparsing replies.

use anyhow::{anyhow, bail, ensure, Result};

use crate::analytics::bandwidth::ControllerMode;
use crate::analytics::grid::SweepSpec;
use crate::analytics::partition::Strategy;
use crate::config::accel::{parse_mode, parse_strategy};
use crate::dse::budget::{parse_sram, SramBudget};
use crate::dse::pareto::{parse_objective, Objective};
use crate::dse::space::ExploreSpec;
use crate::models::{zoo, DataTypes, Network};
use crate::util::json::Json;

use super::error::ApiError;
use super::request::{Request, TableKind};
use super::PROTOCOL_VERSION;

// ---------------------------------------------------------------------
// Shared axis parsers (the single source of truth for every spec parser)
// ---------------------------------------------------------------------

/// Reject keys outside `known`, so a typo'd axis fails loudly instead of
/// silently sweeping its full default. `what` names the request shape in
/// the message (e.g. "sweep", "explore").
pub fn reject_unknown_keys(msg: &Json, known: &[&str], what: &str) -> Result<()> {
    if let Json::Obj(map) = msg {
        for key in map.keys() {
            if !known.contains(&key.as_str()) {
                bail!("unknown {what} key '{key}' (known: {known:?})");
            }
        }
    }
    Ok(())
}

/// A `networks` axis: an array of names resolved through the zoo.
pub fn networks_axis(v: &Json) -> Result<Vec<Network>> {
    let names = v.as_arr().ok_or_else(|| anyhow!("'networks' must be an array"))?;
    names
        .iter()
        .map(|n| {
            let name = n.as_str().ok_or_else(|| anyhow!("'networks' entries must be strings"))?;
            zoo::by_name(name)
                .ok_or_else(|| anyhow!("unknown network '{name}' — see `psim networks`"))
        })
        .collect()
}

/// An integer axis (`macs`, `batches`, ...): an array of whole numbers.
/// `adjective` names the acceptance class in the error message
/// ("non-negative", "positive") — kept per-axis so existing client-facing
/// messages stay byte-identical.
pub fn usize_axis(v: &Json, key: &str, adjective: &str) -> Result<Vec<usize>> {
    let arr = v.as_arr().ok_or_else(|| anyhow!("'{key}' must be an array"))?;
    arr.iter()
        .map(|x| {
            x.as_usize().ok_or_else(|| anyhow!("'{key}' entries must be {adjective} integers"))
        })
        .collect()
}

/// A `strategies` axis: an array of strategy names.
pub fn strategies_axis(v: &Json) -> Result<Vec<Strategy>> {
    str_axis(v, "strategies", parse_strategy)
}

/// A `modes` axis: an array of controller-mode names.
pub fn modes_axis(v: &Json) -> Result<Vec<ControllerMode>> {
    str_axis(v, "modes", parse_mode)
}

/// An `objectives` axis: an array of objective names.
pub fn objectives_axis(v: &Json) -> Result<Vec<Objective>> {
    str_axis(v, "objectives", parse_objective)
}

fn str_axis<T>(v: &Json, key: &str, parse: impl Fn(&str) -> Result<T>) -> Result<Vec<T>> {
    let arr = v.as_arr().ok_or_else(|| anyhow!("'{key}' must be an array"))?;
    arr.iter()
        .map(|x| {
            let s = x.as_str().ok_or_else(|| anyhow!("'{key}' entries must be strings"))?;
            parse(s)
        })
        .collect()
}

/// An `sram` axis: element counts or strings like `"64k"`/`"unlimited"`.
pub fn sram_axis(v: &Json) -> Result<Vec<SramBudget>> {
    let arr = v.as_arr().ok_or_else(|| anyhow!("'sram' must be an array"))?;
    arr.iter()
        .map(|x| match x {
            Json::Num(_) => x
                .as_usize()
                .map(|e| SramBudget::Elems(e as u64))
                .ok_or_else(|| anyhow!("'sram' numbers must be non-negative integers")),
            Json::Str(s) => parse_sram(s),
            _ => Err(anyhow!("'sram' entries must be numbers or strings")),
        })
        .collect()
}

/// A fusion-depth axis: a single positive integer or an array of them.
/// Shared by the sweep (`fusion_depth`) and explore (`fusion`) parsers.
pub fn fusion_axis(v: &Json) -> Result<Vec<usize>> {
    let bad = || anyhow!("fusion depth must be a positive integer or an array of them");
    match v {
        Json::Num(_) => Ok(vec![v.as_usize().filter(|d| *d > 0).ok_or_else(bad)?]),
        Json::Arr(arr) => {
            arr.iter().map(|d| d.as_usize().filter(|d| *d > 0).ok_or_else(bad)).collect()
        }
        _ => Err(bad()),
    }
}

/// A `bits` precision axis: a single `"ifmap:weight:psum:ofmap"` string
/// (or preset) or an array of them — the sweep protocol's precision axis.
pub fn bits_axis(v: &Json) -> Result<Vec<DataTypes>> {
    match v {
        Json::Str(s) => Ok(vec![DataTypes::parse(s)?]),
        Json::Arr(arr) => {
            if arr.is_empty() {
                bail!("'bits' array must not be empty");
            }
            arr.iter()
                .map(|x| {
                    let s = x.as_str().ok_or_else(|| {
                        anyhow!("'bits' entries must be strings like \"8:8:32:8\"")
                    })?;
                    DataTypes::parse(s)
                })
                .collect()
        }
        _ => Err(anyhow!("'bits' must be a precision string like \"8:8:32:8\" or an array")),
    }
}

/// A single `bits` precision field (explore/analyze/fusion: one pricing
/// currency per request, so arrays are rejected).
pub fn bits_field(v: &Json) -> Result<DataTypes> {
    let s = v
        .as_str()
        .ok_or_else(|| anyhow!("'bits' must be a single precision string like \"8:8:32:8\""))?;
    DataTypes::parse(s)
}

/// The optional `workers` request field (the engine applies the default
/// and the clamp, so the policy cannot drift between frontends).
pub fn workers_field(msg: &Json) -> Result<Option<usize>> {
    msg.get("workers")
        .map(|w| w.as_usize().ok_or_else(|| anyhow!("'workers' must be a positive integer")))
        .transpose()
}

/// Validate the optional `protocol` field against this build's version.
pub fn check_protocol(msg: &Json) -> Result<()> {
    if let Some(v) = msg.get("protocol") {
        let got = v.as_usize().ok_or_else(|| anyhow!("'protocol' must be an integer"))?;
        ensure!(
            got == PROTOCOL_VERSION,
            "unsupported protocol version {got} (this build speaks {PROTOCOL_VERSION})"
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Request decode
// ---------------------------------------------------------------------

/// Decode one raw protocol line (parse + [`decode_request`]).
pub fn decode_line(line: &str) -> Result<Request, ApiError> {
    let msg = Json::parse(line).map_err(|e| ApiError::bad_msg(format!("bad json: {e}")))?;
    decode_request(&msg)
}

/// Decode a parsed request object into a typed [`Request`]. An object
/// with a `cmd` field is a command; anything else must be an
/// `{"image": [...]}` inference request.
pub fn decode_request(msg: &Json) -> Result<Request, ApiError> {
    check_protocol(msg).map_err(ApiError::bad)?;
    if let Some(cmd) = msg.get("cmd").and_then(|c| c.as_str()) {
        return match cmd {
            "sweep" => Ok(Request::Sweep {
                spec: SweepSpec::from_json(msg).map_err(ApiError::bad)?,
                workers: workers_field(msg).map_err(ApiError::bad)?,
            }),
            "explore" => Ok(Request::Explore {
                spec: ExploreSpec::from_json(msg).map_err(ApiError::bad)?,
                workers: workers_field(msg).map_err(ApiError::bad)?,
            }),
            "fusion" => decode_fusion(msg).map_err(ApiError::bad),
            "analyze" => decode_analyze(msg).map_err(ApiError::bad),
            "tables" => decode_tables(msg).map_err(ApiError::bad),
            "zoo" => Ok(Request::Zoo),
            "metrics" => Ok(Request::Metrics),
            "stats" => Ok(Request::Stats),
            "version" => Ok(Request::Version),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(ApiError::bad_msg(format!("unknown cmd '{other}'"))),
        };
    }
    let image = msg
        .get("image")
        .and_then(|i| i.as_arr())
        .ok_or_else(|| ApiError::bad_msg("missing 'image' array"))?;
    Ok(Request::Infer { image: image.iter().map(|v| v.as_f64().unwrap_or(0.0) as f32).collect() })
}

fn required_str<'a>(msg: &'a Json, key: &str) -> Result<&'a str> {
    msg.get(key)
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("'{key}' is required and must be a string"))
}

fn opt_usize(msg: &Json, key: &str) -> Result<Option<usize>> {
    msg.get(key)
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("'{key}' must be a non-negative integer")))
        .transpose()
}

fn opt_strategy(msg: &Json) -> Result<Option<Strategy>> {
    msg.get("strategy")
        .map(|v| {
            let s = v.as_str().ok_or_else(|| anyhow!("'strategy' must be a string"))?;
            parse_strategy(s)
        })
        .transpose()
}

fn opt_mode(msg: &Json) -> Result<Option<ControllerMode>> {
    msg.get("mode")
        .map(|v| {
            let s = v.as_str().ok_or_else(|| anyhow!("'mode' must be a string"))?;
            parse_mode(s)
        })
        .transpose()
}

fn opt_bits(msg: &Json) -> Result<DataTypes> {
    msg.get("bits").map(bits_field).transpose().map(|dt| dt.unwrap_or_default())
}

fn decode_fusion(msg: &Json) -> Result<Request> {
    const KNOWN: [&str; 8] =
        ["cmd", "networks", "depth", "macs", "strategy", "mode", "bits", "protocol"];
    reject_unknown_keys(msg, &KNOWN, "fusion")?;
    Ok(Request::Fusion {
        networks: match msg.get("networks") {
            Some(v) => networks_axis(v)?,
            None => zoo::paper_networks(),
        },
        depth: opt_usize(msg, "depth")?.unwrap_or(2),
        p_macs: opt_usize(msg, "macs")?.unwrap_or(1024),
        strategy: opt_strategy(msg)?.unwrap_or(Strategy::Optimal),
        mode: opt_mode(msg)?.unwrap_or(ControllerMode::Passive),
        dt: opt_bits(msg)?,
    })
}

fn decode_analyze(msg: &Json) -> Result<Request> {
    const KNOWN: [&str; 7] = ["cmd", "network", "macs", "strategy", "mode", "bits", "protocol"];
    reject_unknown_keys(msg, &KNOWN, "analyze")?;
    let name = required_str(msg, "network")?;
    Ok(Request::Analyze {
        network: zoo::by_name(name)
            .ok_or_else(|| anyhow!("unknown network '{name}' — see `psim networks`"))?,
        p_macs: opt_usize(msg, "macs")?.unwrap_or(2048),
        strategy: opt_strategy(msg)?.unwrap_or(Strategy::Optimal),
        mode: opt_mode(msg)?.unwrap_or(ControllerMode::Passive),
        dt: opt_bits(msg)?,
    })
}

fn decode_tables(msg: &Json) -> Result<Request> {
    const KNOWN: [&str; 4] = ["cmd", "table", "faithful", "protocol"];
    reject_unknown_keys(msg, &KNOWN, "tables")?;
    let table = TableKind::parse(required_str(msg, "table")?)?;
    let faithful = match msg.get("faithful") {
        None => false,
        Some(Json::Bool(b)) => *b,
        Some(_) => bail!("'faithful' must be a boolean"),
    };
    Ok(Request::Tables { table, faithful })
}

// ---------------------------------------------------------------------
// Request encode
// ---------------------------------------------------------------------

/// Encode a typed [`Request`] back to its protocol JSON. Command requests
/// carry an explicit `protocol` field; `decode_request(&encode_request(r))`
/// round-trips byte-for-byte (pinned by `rust/tests/api_protocol.rs`).
pub fn encode_request(req: &Request) -> Json {
    let cmd = |name: &str| ("cmd", Json::Str(name.to_string()));
    let proto = ("protocol", Json::Num(PROTOCOL_VERSION as f64));
    let names =
        |nets: &[Network]| Json::Arr(nets.iter().map(|n| Json::Str(n.name.clone())).collect());
    let nums = |xs: &[usize]| Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect());
    let strs = |xs: Vec<&str>| Json::Arr(xs.into_iter().map(|s| Json::Str(s.into())).collect());
    match req {
        Request::Sweep { spec, workers } => {
            let mut pairs = vec![
                cmd("sweep"),
                proto,
                ("networks", names(&spec.networks)),
                ("macs", nums(&spec.mac_budgets)),
                ("strategies", strs(spec.strategies.iter().map(|s| s.slug()).collect())),
                ("modes", strs(spec.modes.iter().map(|m| m.label()).collect())),
                ("batches", nums(&spec.batch_sizes)),
                ("fusion_depth", nums(&spec.fusion_depths)),
            ];
            // Additive: the bits axis only appears when it differs from
            // the default single-entry axis, keeping pre-precision
            // request bytes (and their pinned fixtures) intact. Length
            // matters too: a multi-entry all-default axis yields more
            // cells, so omitting it would be lossy.
            let non_default =
                spec.datatypes.first().is_some_and(|dt| !dt.is_default());
            if spec.datatypes.len() != 1 || non_default {
                pairs.push((
                    "bits",
                    Json::Arr(spec.datatypes.iter().map(|dt| Json::Str(dt.label())).collect()),
                ));
            }
            if let Some(w) = workers {
                pairs.push(("workers", Json::Num(*w as f64)));
            }
            Json::obj(pairs)
        }
        Request::Explore { spec, workers } => {
            let mut pairs = vec![
                cmd("explore"),
                proto,
                ("networks", names(&spec.networks)),
                ("macs", nums(&spec.mac_budgets)),
                (
                    "sram",
                    Json::Arr(spec.sram_budgets.iter().map(|s| Json::Str(s.label())).collect()),
                ),
                ("strategies", strs(spec.strategies.iter().map(|s| s.slug()).collect())),
                ("modes", strs(spec.modes.iter().map(|m| m.label()).collect())),
                ("fusion", nums(&spec.fusion_depths)),
                ("objectives", strs(spec.objectives.iter().map(|o| o.label()).collect())),
            ];
            if !spec.datatypes.is_default() {
                pairs.push(("bits", Json::Str(spec.datatypes.label())));
            }
            if let Some(w) = workers {
                pairs.push(("workers", Json::Num(*w as f64)));
            }
            Json::obj(pairs)
        }
        Request::Fusion { networks, depth, p_macs, strategy, mode, dt } => {
            let mut pairs = vec![
                cmd("fusion"),
                proto,
                ("networks", names(networks)),
                ("depth", Json::Num(*depth as f64)),
                ("macs", Json::Num(*p_macs as f64)),
                ("strategy", Json::Str(strategy.slug().to_string())),
                ("mode", Json::Str(mode.label().to_string())),
            ];
            if !dt.is_default() {
                pairs.push(("bits", Json::Str(dt.label())));
            }
            Json::obj(pairs)
        }
        Request::Analyze { network, p_macs, strategy, mode, dt } => {
            let mut pairs = vec![
                cmd("analyze"),
                proto,
                ("network", Json::Str(network.name.clone())),
                ("macs", Json::Num(*p_macs as f64)),
                ("strategy", Json::Str(strategy.slug().to_string())),
                ("mode", Json::Str(mode.label().to_string())),
            ];
            if !dt.is_default() {
                pairs.push(("bits", Json::Str(dt.label())));
            }
            Json::obj(pairs)
        }
        Request::Tables { table, faithful } => Json::obj(vec![
            cmd("tables"),
            proto,
            ("table", Json::Str(table.name().to_string())),
            ("faithful", Json::Bool(*faithful)),
        ]),
        Request::Infer { image } => Json::obj(vec![(
            "image",
            Json::Arr(image.iter().map(|&v| Json::Num(v as f64)).collect()),
        )]),
        Request::Zoo => Json::obj(vec![cmd("zoo"), proto]),
        Request::Metrics => Json::obj(vec![cmd("metrics"), proto]),
        Request::Stats => Json::obj(vec![cmd("stats"), proto]),
        Request::Version => Json::obj(vec![cmd("version"), proto]),
        Request::Shutdown => Json::obj(vec![cmd("shutdown"), proto]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::error::ErrorCode;

    #[test]
    fn protocol_field_is_checked() {
        assert!(check_protocol(&Json::parse(r#"{"cmd":"version"}"#).unwrap()).is_ok());
        assert!(check_protocol(&Json::parse(r#"{"protocol":1}"#).unwrap()).is_ok());
        assert!(check_protocol(&Json::parse(r#"{"protocol":2}"#).unwrap()).is_err());
        assert!(check_protocol(&Json::parse(r#"{"protocol":"x"}"#).unwrap()).is_err());
        let err = decode_line(r#"{"cmd":"version","protocol":99}"#).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("unsupported protocol version 99"), "{err}");
    }

    #[test]
    fn decode_dispatches_on_cmd() {
        assert!(matches!(decode_line(r#"{"cmd":"metrics"}"#), Ok(Request::Metrics)));
        assert!(matches!(decode_line(r#"{"cmd":"stats"}"#), Ok(Request::Stats)));
        assert!(matches!(decode_line(r#"{"cmd":"version"}"#), Ok(Request::Version)));
        assert!(matches!(decode_line(r#"{"cmd":"shutdown"}"#), Ok(Request::Shutdown)));
        let err = decode_line(r#"{"cmd":"bogus"}"#).unwrap_err();
        assert_eq!(err.message, "unknown cmd 'bogus'");
        let err = decode_line("not json").unwrap_err();
        assert!(err.message.starts_with("bad json: "), "{err}");
        assert_eq!(decode_line("{}").unwrap_err().message, "missing 'image' array");
    }

    #[test]
    fn fusion_and_analyze_decode_defaults() {
        let Request::Fusion { networks, depth, p_macs, strategy, mode, dt } =
            decode_line(r#"{"cmd":"fusion"}"#).unwrap()
        else {
            panic!("not a fusion request");
        };
        assert_eq!(networks.len(), 8);
        assert_eq!((depth, p_macs), (2, 1024));
        assert_eq!(strategy, Strategy::Optimal);
        assert_eq!(mode, ControllerMode::Passive);
        assert!(dt.is_default());

        let Request::Analyze { network, p_macs, dt, .. } =
            decode_line(r#"{"cmd":"analyze","network":"resnet18","macs":512}"#).unwrap()
        else {
            panic!("not an analyze request");
        };
        assert_eq!(network.name, "ResNet-18");
        assert_eq!(p_macs, 512);
        assert!(dt.is_default());
        assert!(decode_line(r#"{"cmd":"analyze"}"#).is_err());
        assert!(decode_line(r#"{"cmd":"analyze","network":"Nope"}"#).is_err());
        assert!(decode_line(r#"{"cmd":"fusion","warp":9}"#).is_err());
    }

    #[test]
    fn bits_decode_and_encode_round_trip() {
        use crate::models::DataTypes;
        // decode: all four request shapes accept `bits`
        let Request::Analyze { dt, .. } =
            decode_line(r#"{"cmd":"analyze","network":"AlexNet","bits":"8:8:32:8"}"#).unwrap()
        else {
            panic!("not an analyze request");
        };
        assert_eq!(dt, DataTypes::parse("8:8:32:8").unwrap());
        let Request::Fusion { dt, .. } =
            decode_line(r#"{"cmd":"fusion","bits":"int8"}"#).unwrap()
        else {
            panic!("not a fusion request");
        };
        assert_eq!(dt, DataTypes::parse("8:8:32:8").unwrap());
        let Request::Sweep { spec, .. } =
            decode_line(r#"{"cmd":"sweep","bits":["8:8:8:8","8:8:32:8"]}"#).unwrap()
        else {
            panic!("not a sweep request");
        };
        assert_eq!(spec.datatypes.len(), 2);
        let Request::Explore { spec, .. } =
            decode_line(r#"{"cmd":"explore","bits":"8:8:32:8"}"#).unwrap()
        else {
            panic!("not an explore request");
        };
        assert!(!spec.datatypes.is_default());
        // bad precisions fail loudly on every shape
        assert!(decode_line(r#"{"cmd":"sweep","bits":"8:8"}"#).is_err());
        assert!(decode_line(r#"{"cmd":"explore","bits":["8:8:32:8"]}"#).is_err());
        assert!(decode_line(r#"{"cmd":"analyze","network":"AlexNet","bits":4}"#).is_err());

        // encode: the bits key appears only for non-default precisions
        let req = decode_line(r#"{"cmd":"sweep","networks":["AlexNet"]}"#).unwrap();
        assert!(encode_request(&req).get("bits").is_none());
        let req = decode_line(r#"{"cmd":"sweep","networks":["AlexNet"],"bits":"8:8:32:8"}"#)
            .unwrap();
        let enc = encode_request(&req);
        assert_eq!(enc.get("bits").unwrap().as_arr().unwrap().len(), 1);
        // decode(encode(r)) is stable for the precision-carrying shapes
        let again = decode_request(&enc).unwrap();
        assert_eq!(encode_request(&again).to_string(), enc.to_string());

        // a multi-entry all-default axis changes the cell count, so the
        // encoder must keep it (length matters, not just the widths)
        let req = decode_line(
            r#"{"cmd":"sweep","networks":["AlexNet"],"bits":["8:8:8:8","8:8:8:8"]}"#,
        )
        .unwrap();
        let enc = encode_request(&req);
        assert_eq!(enc.get("bits").unwrap().as_arr().unwrap().len(), 2);
        let Request::Sweep { spec, .. } = decode_request(&enc).unwrap() else {
            panic!("not a sweep request");
        };
        assert_eq!(spec.datatypes.len(), 2);
    }

    #[test]
    fn tables_decode() {
        let Request::Tables { table, faithful } =
            decode_line(r#"{"cmd":"tables","table":"fig2-ascii","faithful":true}"#).unwrap()
        else {
            panic!("not a tables request");
        };
        assert_eq!(table, TableKind::Fig2Ascii);
        assert!(faithful);
        assert!(decode_line(r#"{"cmd":"tables"}"#).is_err());
        assert!(decode_line(r#"{"cmd":"tables","table":"table9"}"#).is_err());
        assert!(decode_line(r#"{"cmd":"tables","table":"table1","faithful":1}"#).is_err());
    }
}
