//! The typed response surface and its stable JSON encoding.
//!
//! [`Response::to_json`] reproduces the pre-facade `serve` reply shapes
//! byte-for-byte (object keys sort alphabetically through
//! [`Json::obj`]); new fields are additive only, so deployed JSON-lines
//! clients keep parsing.

use crate::analytics::grid::GridResult;
use crate::coordinator::InferResponse;
use crate::dse::explore::ExploreResult;
use crate::util::json::Json;
use crate::util::tablefmt::Table;

/// One API reply. CLI frontends render the typed payload (markdown, CSV,
/// JSONL); `serve` and `psim request` emit [`Response::to_json`].
#[derive(Clone, Debug)]
pub enum Response {
    /// A sweep's grid cells plus the layer-cache deltas this request saw
    /// (approximate when sweeps run concurrently — the cache is shared,
    /// and that sharing is the point).
    Sweep { grid: GridResult, cache_hits: u64, cache_misses: u64 },
    /// An exploration's Pareto frontier and its evaluation counters.
    Explore { result: ExploreResult },
    /// A rendered table (fusion, analyze, tables) plus a one-line note
    /// (empty when the command has none).
    Table { table: Table, note: String },
    /// A plain-text payload (`fig2-ascii`).
    Text { text: String },
    /// A functional inference result.
    Infer(InferResponse),
    /// Engine/server metrics: the inference summary line plus per-command
    /// request counters (only non-zero ones appear on the wire).
    Metrics { summary: String, requests: Vec<(&'static str, u64)> },
    /// An observability snapshot, pre-rendered by the engine from its
    /// registry (sorted keys; versioned via `"protocol"`/`"schema"`).
    Stats {
        /// The full snapshot document, emitted verbatim.
        snapshot: Json,
    },
    /// Crate + protocol version.
    Version,
    /// Acknowledges a shutdown request; the host owning the socket (or
    /// stdin loop) decides what "stop serving" means.
    Shutdown,
}

impl Response {
    /// The stable wire encoding (one JSON object; keys sorted).
    pub fn to_json(&self) -> Json {
        match self {
            Response::Sweep { grid, cache_hits, cache_misses } => Json::obj(vec![
                ("cells", Json::Arr(grid.cells.iter().map(|c| c.to_json()).collect())),
                ("count", Json::Num(grid.len() as f64)),
                ("cache_hits", Json::Num(*cache_hits as f64)),
                ("cache_misses", Json::Num(*cache_misses as f64)),
            ]),
            Response::Explore { result } => Json::obj(vec![
                ("frontier", Json::Arr(result.frontier.iter().map(|f| f.to_json()).collect())),
                ("count", Json::Num(result.frontier.len() as f64)),
                ("candidates", Json::Num(result.candidates as f64)),
                ("evaluated", Json::Num(result.evaluated as f64)),
                ("pruned", Json::Num(result.pruned.len() as f64)),
                ("infeasible", Json::Num(result.infeasible as f64)),
            ]),
            Response::Table { table, note } => {
                let mut pairs = vec![("table", Json::Str(table.to_markdown()))];
                if !note.is_empty() {
                    pairs.push(("note", Json::Str(note.clone())));
                }
                Json::obj(pairs)
            }
            Response::Text { text } => Json::obj(vec![("text", Json::Str(text.clone()))]),
            Response::Infer(resp) => Json::obj(vec![
                ("id", Json::Num(resp.id as f64)),
                ("class", Json::Num(resp.top_class() as f64)),
                (
                    "logits",
                    Json::Arr(resp.logits.iter().map(|&v| Json::Num(v as f64)).collect()),
                ),
                ("latency_us", Json::Num(resp.latency_us as f64)),
            ]),
            Response::Metrics { summary, requests } => Json::obj(vec![
                ("metrics", Json::Str(summary.clone())),
                (
                    "requests",
                    Json::obj(
                        requests.iter().map(|&(cmd, n)| (cmd, Json::Num(n as f64))).collect(),
                    ),
                ),
            ]),
            Response::Stats { snapshot } => snapshot.clone(),
            Response::Version => Json::obj(vec![
                ("version", Json::Str(super::CRATE_VERSION.to_string())),
                ("protocol", Json::Num(super::PROTOCOL_VERSION as f64)),
            ]),
            Response::Shutdown => Json::obj(vec![("ok", Json::Bool(true))]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shutdown_and_version_shapes() {
        assert_eq!(Response::Shutdown.to_json().to_string(), r#"{"ok":true}"#);
        let v = Response::Version.to_json();
        assert_eq!(v.get("protocol").unwrap().as_usize(), Some(super::super::PROTOCOL_VERSION));
        assert_eq!(v.get("version").unwrap().as_str(), Some(super::super::CRATE_VERSION));
    }

    #[test]
    fn table_note_is_omitted_when_empty() {
        let mut table = Table::new(vec!["a"]);
        table.row(vec!["1"]);
        let bare = Response::Table { table: table.clone(), note: String::new() };
        assert!(bare.to_json().get("note").is_none());
        let with = Response::Table { table, note: "hi".to_string() };
        assert_eq!(with.to_json().get("note").unwrap().as_str(), Some("hi"));
    }

    #[test]
    fn stats_emits_its_snapshot_verbatim() {
        let snapshot = Json::obj(vec![("protocol", Json::Num(1.0)), ("schema", Json::Num(1.0))]);
        let s = Response::Stats { snapshot };
        assert_eq!(s.to_json().to_string(), r#"{"protocol":1,"schema":1}"#);
    }

    #[test]
    fn metrics_requests_are_an_object() {
        let m = Response::Metrics {
            summary: "s".to_string(),
            requests: vec![("sweep", 2), ("metrics", 1)],
        };
        assert_eq!(
            m.to_json().to_string(),
            r#"{"metrics":"s","requests":{"metrics":1,"sweep":2}}"#
        );
    }
}
