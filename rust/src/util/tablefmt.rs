//! Aligned table rendering: markdown (for terminals / EXPERIMENTS.md) and
//! CSV (for plotting Fig. 2 elsewhere). All paper tables are emitted
//! through this module so formatting is uniform and golden-testable.

/// A simple column-aligned table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    /// Append one row (must match the header width).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    /// Number of data rows.
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Column-aligned GitHub-flavoured markdown.
    pub fn to_markdown(&self) -> String {
        let ncol = self.header.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize], out: &mut String| {
            out.push('|');
            for (c, w) in cells.iter().zip(widths) {
                out.push(' ');
                out.push_str(c);
                for _ in c.chars().count()..*w {
                    out.push(' ');
                }
                out.push_str(" |");
            }
            out.push('\n');
        };
        fmt_row(&self.header, &widths, &mut out);
        out.push('|');
        for w in &widths {
            out.push_str(&"-".repeat(w + 2));
            out.push('|');
        }
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &widths, &mut out);
        }
        out
    }

    /// RFC-4180-ish CSV (quotes cells containing comma/quote/newline).
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains([',', '"', '\n']) {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a bandwidth value in "million activations" with paper-style
/// precision: 1 decimal for Table I, 2 decimals for Table II/III.
pub fn mact(v: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, v / 1.0e6)
}

/// Format a ratio as a percentage with 1 decimal.
pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_alignment() {
        let mut t = Table::new(vec!["CNN", "BW"]);
        t.row(vec!["AlexNet", "0.823"]);
        t.row(vec!["VGG-16", "20.095"]);
        let md = t.to_markdown();
        let lines: Vec<&str> = md.lines().collect();
        assert_eq!(lines.len(), 4);
        // all lines the same width
        assert!(lines.windows(2).all(|w| w[0].chars().count() == w[1].chars().count()));
        assert!(lines[0].contains("CNN"));
        assert!(lines[3].contains("20.095"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["x,y", "q\"z"]);
        let csv = t.to_csv();
        assert_eq!(csv, "a,b\n\"x,y\",\"q\"\"z\"\n");
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(mact(25_070_000.0, 2), "25.07");
        assert_eq!(mact(823_000.0, 3), "0.823");
        assert_eq!(pct(0.4012), "40.1%");
    }
}
