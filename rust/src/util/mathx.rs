//! Small numeric helpers shared by the analytics engine.

/// All positive divisors of `x`, ascending. `divisors(12) = [1,2,3,4,6,12]`.
pub fn divisors(x: usize) -> Vec<usize> {
    assert!(x > 0, "divisors of 0 undefined");
    let mut lo = Vec::new();
    let mut hi = Vec::new();
    let mut d = 1usize;
    while d * d <= x {
        if x % d == 0 {
            lo.push(d);
            if d != x / d {
                hi.push(x / d);
            }
        }
        d += 1;
    }
    hi.reverse();
    lo.extend(hi);
    lo
}

/// The divisor of `x` nearest to `target` in log-space (ties -> smaller).
///
/// Log-space distance is the natural metric here: bandwidth terms scale as
/// `m` and `1/m`, so being 2x over is as bad as being 2x under.
pub fn nearest_divisor_log(x: usize, target: f64) -> usize {
    assert!(x > 0);
    let t = target.max(1e-12).ln();
    let mut best = 1usize;
    let mut best_d = f64::INFINITY;
    for d in divisors(x) {
        let dist = ((d as f64).ln() - t).abs();
        if dist < best_d {
            best_d = dist;
            best = d;
        }
    }
    best
}

/// Greatest common divisor.
pub fn gcd(mut a: usize, mut b: usize) -> usize {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

/// Ceiling division for usize. Delegates to [`usize::div_ceil`]: the
/// hand-rolled `(a + b - 1) / b` overflows for `a > usize::MAX - b + 1`
/// (panic in debug, silent wrap to 0 in release).
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Relative difference |a-b| / max(|a|,|b|,eps).
pub fn rel_diff(a: f64, b: f64) -> f64 {
    let denom = a.abs().max(b.abs()).max(1e-12);
    (a - b).abs() / denom
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn divisors_basic() {
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(13), vec![1, 13]);
        assert_eq!(divisors(64), vec![1, 2, 4, 8, 16, 32, 64]);
    }

    #[test]
    fn divisors_cover_product_pairs() {
        for x in 1..200usize {
            let ds = divisors(x);
            for &d in &ds {
                assert_eq!(x % d, 0);
                assert!(ds.contains(&(x / d)));
            }
            // sorted ascending, unique
            assert!(ds.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn nearest_divisor_log_cases() {
        // divisors of 96: 1,2,3,4,6,8,12,16,24,32,48,96
        assert_eq!(nearest_divisor_log(96, 5.0), 6); // |ln5-ln6| < |ln5-ln4|
        assert_eq!(nearest_divisor_log(96, 100.0), 96);
        assert_eq!(nearest_divisor_log(96, 0.2), 1);
        assert_eq!(nearest_divisor_log(7, 3.0), 7); // ln3 vs ln1/ln7: 1.099 vs 0.847 -> 7
    }

    #[test]
    fn gcd_cases() {
        assert_eq!(gcd(12, 18), 6);
        assert_eq!(gcd(7, 13), 1);
        assert_eq!(gcd(0, 5), 5);
        assert_eq!(gcd(5, 0), 5);
    }

    #[test]
    fn ceil_div_cases() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(1, 100), 1);
        assert_eq!(ceil_div(0, 7), 0);
    }

    #[test]
    fn ceil_div_does_not_overflow_near_usize_max() {
        // Regression: `(a + b - 1) / b` overflowed on all of these.
        assert_eq!(ceil_div(usize::MAX, 1), usize::MAX);
        assert_eq!(ceil_div(usize::MAX, 2), usize::MAX / 2 + 1);
        assert_eq!(ceil_div(usize::MAX, usize::MAX), 1);
        assert_eq!(ceil_div(usize::MAX - 1, usize::MAX), 1);
        assert_eq!(ceil_div(usize::MAX, usize::MAX - 1), 2);
    }

    #[test]
    fn rel_diff_cases() {
        assert!(rel_diff(100.0, 100.0) < 1e-12);
        assert!((rel_diff(100.0, 90.0) - 0.1).abs() < 1e-9);
    }
}
