//! Deterministic PRNG: splitmix64 seeding + xoshiro256** core.
//!
//! Used for synthetic weights/activations (bandwidth never depends on
//! values, but the functional path needs reproducible tensors) and for the
//! in-tree property-test harness. Algorithms follow Blackman & Vigna's
//! public-domain reference implementations.

/// xoshiro256** PRNG with splitmix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform u64 in `[0, bound)` via Lemire's multiply-shift (bound > 0).
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit multiply keeps the distribution unbiased enough for tests.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[-scale, scale)` — synthetic weights/activations.
    pub fn f32_sym(&mut self, scale: f32) -> f32 {
        (self.f64() as f32 * 2.0 - 1.0) * scale
    }

    /// Fill a buffer with symmetric uniform f32 values.
    pub fn fill_f32(&mut self, buf: &mut [f32], scale: f32) {
        for v in buf.iter_mut() {
            *v = self.f32_sym(scale);
        }
    }

    /// Pick a random element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(11);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        // mean of U[0,1) should be close to 0.5
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn fill_f32_symmetric() {
        let mut r = Rng::new(13);
        let mut buf = vec![0f32; 4096];
        r.fill_f32(&mut buf, 0.5);
        assert!(buf.iter().all(|v| (-0.5..0.5).contains(v)));
        let mean: f32 = buf.iter().sum::<f32>() / buf.len() as f32;
        assert!(mean.abs() < 0.05);
    }
}
