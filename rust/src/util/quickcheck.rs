//! A tiny property-based testing harness (proptest is not available in the
//! offline vendor set). Deterministic: every case derives from a fixed
//! seed, and failures report the case index + seed so they can be replayed
//! exactly with `forall_seeded`.

use super::prng::Rng;

/// Default number of cases per property.
pub const DEFAULT_CASES: usize = 256;

/// Run `prop` on `cases` inputs drawn by `gen` from a seeded RNG.
///
/// Panics with the failing case index and seed on the first failure.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    forall_seeded(name, 0xC0FFEE, cases, &mut gen, &mut prop);
}

/// Like [`forall`] but with an explicit seed (used to replay failures).
pub fn forall_seeded<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    gen: &mut impl FnMut(&mut Rng) -> T,
    prop: &mut impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        // Each case gets an independent stream so generators that consume a
        // variable number of draws don't couple cases together.
        let mut rng = Rng::new(seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed {seed:#x})\n\
                 input: {input:?}\nreason: {msg}"
            );
        }
    }
}

/// Assert helper returning Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err(format!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall("sum-commutes", 64, |r| (r.range(0, 100), r.range(0, 100)), |(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failures() {
        forall("always-fails", 8, |r| r.range(0, 10), |_| Err("nope".into()));
    }

    #[test]
    fn deterministic_inputs() {
        let mut seen_a: Vec<usize> = Vec::new();
        forall("collect-a", 16, |r| r.range(0, 1000), |v| {
            seen_a.push(*v);
            Ok(())
        });
        let mut seen_b: Vec<usize> = Vec::new();
        forall("collect-b", 16, |r| r.range(0, 1000), |v| {
            seen_b.push(*v);
            Ok(())
        });
        assert_eq!(seen_a, seen_b);
    }
}
