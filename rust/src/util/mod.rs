//! Self-contained utility substrate.
//!
//! The offline build environment ships only the `xla` crate's vendored
//! dependency closure, so the usual ecosystem crates (serde, rand, clap,
//! criterion, proptest) are unavailable. Everything the rest of the crate
//! needs from them is implemented here, small and auditable:
//!
//! * [`prng`] — splitmix64/xoshiro256** deterministic PRNG.
//! * [`json`] — minimal JSON writer + parser (artifact manifests, reports).
//! * [`tablefmt`] — aligned markdown/CSV table rendering.
//! * [`quickcheck`] — a tiny property-based testing harness.
//! * [`benchkit`] — a criterion-like micro-benchmark harness
//!   (warmup, N samples, mean/median/stddev, throughput).
//! * [`mathx`] — small numeric helpers (divisors, log-space distance).
//! * [`sync`] — poison-tolerant `Mutex`/`Condvar` helpers for the
//!   panic-free serve path.

pub mod benchkit;
pub mod json;
pub mod mathx;
pub mod prng;
pub mod quickcheck;
pub mod sync;
pub mod tablefmt;
