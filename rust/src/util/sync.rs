//! Poison-tolerant synchronization helpers.
//!
//! The serve path must stay panic-free on hostile input (lint pass
//! `PS100`), and `Mutex::lock().unwrap()` is a deferred panic: one
//! panicking lock holder anywhere would poison the lock and cascade the
//! crash into every worker that touches it afterwards. The state guarded
//! on that path (connection registries, the request-coalescing map)
//! stays consistent entry-by-entry even across a holder's unwind, so
//! recovering the guard is strictly better than taking the whole server
//! down.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};

/// `mutex.lock()` that survives poisoning by adopting the inner guard.
pub fn lock_unpoisoned<T>(mutex: &Mutex<T>) -> MutexGuard<'_, T> {
    mutex.lock().unwrap_or_else(PoisonError::into_inner)
}

/// `condvar.wait(guard)` that survives poisoning the same way.
pub fn wait_unpoisoned<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use std::sync::{Arc, Mutex};

    use super::lock_unpoisoned;

    #[test]
    fn lock_unpoisoned_recovers_after_holder_panic() {
        let shared = Arc::new(Mutex::new(7_u32));
        let poisoner = Arc::clone(&shared);
        let _ = std::thread::spawn(move || {
            let _guard = poisoner.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(shared.is_poisoned(), "holder panic must poison the lock");
        assert_eq!(*lock_unpoisoned(&shared), 7);
        *lock_unpoisoned(&shared) = 8;
        assert_eq!(*lock_unpoisoned(&shared), 8);
    }
}
