//! A criterion-like micro-benchmark harness (criterion is not available in
//! the offline vendor set). Warmup, fixed sample count, mean/median/stddev,
//! optional throughput. Used by all `rust/benches/*.rs` targets
//! (`harness = false`).

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Result statistics for one benchmark.
#[derive(Clone, Debug)]
pub struct Stats {
    /// Benchmark name.
    pub name: String,
    /// Timed samples taken.
    pub samples: usize,
    /// Mean sample time.
    pub mean: Duration,
    /// Median sample time.
    pub median: Duration,
    /// Sample standard deviation.
    pub stddev: Duration,
    /// Fastest sample.
    pub min: Duration,
    /// Slowest sample.
    pub max: Duration,
    /// Optional elements-per-iteration for throughput reporting.
    pub elements: Option<u64>,
}

impl Stats {
    /// Elements per second, if `elements` was set.
    pub fn throughput(&self) -> Option<f64> {
        self.elements.map(|e| e as f64 / self.mean.as_secs_f64())
    }
}

/// Benchmark runner with uniform reporting.
pub struct Bench {
    warmup: Duration,
    samples: usize,
    results: Vec<Stats>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    /// A runner with default (or `PSIM_BENCH_QUICK`) settings.
    pub fn new() -> Self {
        // Honour the libtest `--bench` / filter args passively: we accept
        // and ignore them so `cargo bench` works unmodified.
        let quick = std::env::var("PSIM_BENCH_QUICK").is_ok();
        Bench {
            warmup: if quick { Duration::from_millis(20) } else { Duration::from_millis(200) },
            samples: if quick { 10 } else { 30 },
            results: Vec::new(),
        }
    }

    /// Time `f`, which is run repeatedly; returns and records stats.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Stats {
        self.run_with_elements(name, None, &mut f)
    }

    /// Time `f` and report throughput as `elements`/iteration/second.
    pub fn run_throughput<T>(
        &mut self,
        name: &str,
        elements: u64,
        mut f: impl FnMut() -> T,
    ) -> &Stats {
        self.run_with_elements(name, Some(elements), &mut f)
    }

    fn run_with_elements<T>(
        &mut self,
        name: &str,
        elements: Option<u64>,
        f: &mut impl FnMut() -> T,
    ) -> &Stats {
        // Warmup until the warmup budget elapses (at least one iteration).
        let wstart = Instant::now();
        let mut warm_iters = 0u64;
        while wstart.elapsed() < self.warmup || warm_iters == 0 {
            black_box(f());
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        // Choose an inner iteration count so each sample is >= ~1ms.
        let per_iter = wstart.elapsed().as_secs_f64() / warm_iters as f64;
        let inner = ((1.0e-3 / per_iter.max(1e-9)).ceil() as u64).clamp(1, 1_000_000);

        let mut times: Vec<Duration> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..inner {
                black_box(f());
            }
            times.push(t0.elapsed() / inner as u32);
        }
        times.sort();
        let mean_ns = times.iter().map(|d| d.as_nanos()).sum::<u128>() / times.len() as u128;
        let mean = Duration::from_nanos(mean_ns as u64);
        let median = times[times.len() / 2];
        let var = times
            .iter()
            .map(|d| {
                let x = d.as_nanos() as f64 - mean_ns as f64;
                x * x
            })
            .sum::<f64>()
            / times.len() as f64;
        let stats = Stats {
            name: name.to_string(),
            samples: self.samples,
            mean,
            median,
            stddev: Duration::from_nanos(var.sqrt() as u64),
            min: times[0],
            max: *times.last().unwrap(),
            elements: elements.map(|e| e * inner).map(|_| elements.unwrap()),
        };
        println!("{}", format_stats(&stats));
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// All recorded results.
    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Print a closing summary table.
    pub fn finish(&self) {
        println!("\n== bench summary ({} benchmarks) ==", self.results.len());
        for s in &self.results {
            println!("{}", format_stats(s));
        }
    }
}

/// Nearest-rank percentile over a **sorted ascending** sample set:
/// `percentile(s, 0.5)` is the median, `percentile(s, 0.99)` the p99.
/// Exact sample values (no interpolation, no histogram bucketing — the
/// `coordinator::Metrics` histogram rounds to bucket bounds; `psim bench`
/// wants the raw samples it actually measured). Empty input yields 0.
pub fn percentile(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let n = sorted.len();
    let rank = ((p * n as f64).ceil() as usize).clamp(1, n);
    sorted[rank - 1]
}

fn human(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

fn format_stats(s: &Stats) -> String {
    let tp = match s.throughput() {
        Some(t) if t >= 1e9 => format!("  [{:.2} Gelem/s]", t / 1e9),
        Some(t) if t >= 1e6 => format!("  [{:.2} Melem/s]", t / 1e6),
        Some(t) if t >= 1e3 => format!("  [{:.2} Kelem/s]", t / 1e3),
        Some(t) => format!("  [{t:.2} elem/s]"),
        None => String::new(),
    };
    format!(
        "bench {:<44} mean {:>10}  median {:>10}  sd {:>10}  (min {} / max {}, n={}){}",
        s.name,
        human(s.mean),
        human(s.median),
        human(s.stddev),
        human(s.min),
        human(s.max),
        s.samples,
        tp
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_records_stats() {
        std::env::set_var("PSIM_BENCH_QUICK", "1");
        let mut b = Bench::new();
        let s = b.run("noop-ish", || 1 + 1).clone();
        assert_eq!(s.samples, 10);
        assert!(s.min <= s.median && s.median <= s.max);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn throughput_positive() {
        std::env::set_var("PSIM_BENCH_QUICK", "1");
        let mut b = Bench::new();
        let s = b.run_throughput("sum-1k", 1000, || (0..1000u64).sum::<u64>()).clone();
        assert!(s.throughput().unwrap() > 0.0);
    }

    #[test]
    fn percentile_is_nearest_rank() {
        let s: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&s, 0.50), 50);
        assert_eq!(percentile(&s, 0.95), 95);
        assert_eq!(percentile(&s, 0.99), 99);
        assert_eq!(percentile(&s, 1.0), 100);
        assert_eq!(percentile(&s, 0.0), 1);
        assert_eq!(percentile(&[], 0.5), 0);
        assert_eq!(percentile(&[7], 0.99), 7);
        // Nearest-rank on a 3-sample set: p50 is the 2nd sample.
        assert_eq!(percentile(&[10, 20, 30], 0.5), 20);
    }

    #[test]
    fn human_units() {
        assert_eq!(human(Duration::from_nanos(12)), "12 ns");
        assert!(human(Duration::from_micros(12)).ends_with("µs"));
        assert!(human(Duration::from_millis(12)).ends_with("ms"));
        assert!(human(Duration::from_secs(2)).ends_with(" s"));
    }
}
