//! Minimal JSON: a value model, a writer, and a recursive-descent parser.
//!
//! Scope: what the crate needs — artifact manifests written by the Python
//! AOT pipeline (`artifacts/manifest.json`), machine-readable report dumps,
//! and config snapshots. Supports the full JSON grammar except `\u` escapes
//! beyond the BMP (surrogate pairs are passed through unvalidated).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) for stable output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// `true`/`false`.
    Bool(bool),
    /// A number (all JSON numbers are `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (keys sorted for stable output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs (keys sort on output).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// The number value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as a non-negative integer, if it is one exactly.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object field lookup (None for non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Parse a JSON document from text.
    ///
    /// Nesting is capped at [`MAX_DEPTH`] levels: the parser recurses
    /// per nesting level, and hostile input like 100k `[`s must come
    /// back as a clean [`JsonError`], not a stack-overflow abort.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0, depth: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    /// Byte offset of the error.
    pub at: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Maximum container nesting [`Json::parse`] accepts. Far deeper than
/// any legitimate psim document (requests nest 2–3 levels) while small
/// enough that parse recursion can never exhaust the thread stack.
pub const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { at: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("too deeply nested"));
        }
        self.depth += 1;
        let v = match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        };
        self.depth -= 1;
        v
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    /// Compact single-line rendering.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_value(self, &mut s);
        f.write_str(&s)
    }
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_value(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "\"hi\\n\""] {
            let v = Json::parse(src).unwrap();
            let back = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, back);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(), Some("c"));
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn numbers_exponents() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("-2.5E-2").unwrap().as_f64(), Some(-0.025));
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Json::parse("3").unwrap().as_usize(), Some(3));
        assert_eq!(Json::parse("3.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-3").unwrap().as_usize(), None);
    }

    #[test]
    fn hostile_deep_nesting_errors_cleanly() {
        // Regression (lint PS100 hardening): before the MAX_DEPTH cap,
        // these exact bytes crashed the process with a stack-overflow
        // abort instead of returning a JsonError.
        for open in ["[", "{\"k\":"] {
            let hostile = open.repeat(100_000);
            let err = Json::parse(&hostile).unwrap_err();
            assert!(err.msg.contains("too deeply nested"), "{err}");
        }
    }

    #[test]
    fn nesting_inside_the_cap_still_parses() {
        let depth = MAX_DEPTH - 1;
        let doc = format!("{}0{}", "[".repeat(depth), "]".repeat(depth));
        assert!(Json::parse(&doc).is_ok());
        let doc = format!("{}0{}", "[".repeat(depth + 1), "]".repeat(depth + 1));
        assert!(Json::parse(&doc).is_err());
    }

    #[test]
    fn malformed_numbers_error_cleanly() {
        // Regression companions to the number() from_utf8 hardening:
        // every truncated or bare-sign form must be a clean error.
        for src in ["-", "1e", "1e+", ".5", "--1"] {
            assert!(Json::parse(src).is_err(), "{src:?} should not parse");
        }
    }

    #[test]
    fn display_compact_stable() {
        let v = Json::obj(vec![
            ("b", Json::Num(2.0)),
            ("a", Json::Arr(vec![Json::Bool(true), Json::Null])),
        ]);
        // BTreeMap => keys sorted
        assert_eq!(v.to_string(), r#"{"a":[true,null],"b":2}"#);
    }
}
