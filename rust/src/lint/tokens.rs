//! The hand-rolled Rust-source tokenizer behind every lint pass.
//!
//! Deliberately not a parser: the passes only need a token stream in
//! which comments, string/char literals and lifetimes can never be
//! confused with code — the classic failure mode of grep-based checks.
//! Handles line comments, nested block comments, plain/raw/byte string
//! literals (including multi-hash raw strings and `\`-continuations),
//! char-literal-vs-lifetime disambiguation, and keeps 1-based line/col
//! spans in characters so diagnostics point at the offending token.

/// Token classification, as coarse as the passes need.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// `// ...` (doc comments included).
    LineComment,
    /// `/* ... */`, nesting handled.
    BlockComment,
    /// String literal: plain, raw (`r#"..."#`) or byte (`b"..."`).
    Str,
    /// Char or byte-char literal (`'x'`, `b'\n'`).
    Char,
    /// Lifetime (`'a`, `'static`).
    Lifetime,
    /// Identifier or keyword.
    Ident,
    /// Numeric literal.
    Num,
    /// Any single punctuation character.
    Punct,
}

/// One token with its character span (1-based line/col, end exclusive).
#[derive(Clone, Debug)]
pub struct Tok {
    /// Classification.
    pub kind: TokKind,
    /// Raw source text of the token.
    pub text: String,
    /// 1-based start line.
    pub line: usize,
    /// 1-based start column (chars).
    pub col: usize,
    /// 1-based end line.
    pub end_line: usize,
    /// 1-based end column (chars, exclusive).
    pub end_col: usize,
}

impl Tok {
    /// Is this a comment or a code token? Passes scan code tokens only;
    /// the allowlist scans comments.
    pub fn is_comment(&self) -> bool {
        matches!(self.kind, TokKind::LineComment | TokKind::BlockComment)
    }
}

/// The literal value of a string token: prefix (`r`/`b`/`br`), hash
/// guards and quotes stripped, escapes left as written (the passes
/// compare metric/command names, which never need escapes).
pub fn str_value(tok: &Tok) -> &str {
    let mut s = tok.text.as_str();
    for prefix in ["br", "rb", "b", "r"] {
        if let Some(rest) = s.strip_prefix(prefix) {
            if rest.starts_with(['"', '#']) {
                s = rest;
                break;
            }
        }
    }
    s = s.trim_matches('#');
    s = s.strip_prefix('"').unwrap_or(s);
    s.strip_suffix('"').unwrap_or(s)
}

struct Scanner {
    chars: Vec<char>,
    i: usize,
    line: usize,
    col: usize,
}

impl Scanner {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.i + ahead).copied()
    }

    fn bump(&mut self) {
        if self.peek(0) == Some('\n') {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        self.i += 1;
    }

    fn bump_n(&mut self, n: usize) {
        for _ in 0..n {
            self.bump();
        }
    }

    /// Consume a quoted literal starting at the opening quote; `\` keeps
    /// escaped quotes (and line continuations) inside the token.
    fn quoted(&mut self, quote: char) {
        self.bump(); // opening quote
        while let Some(c) = self.peek(0) {
            if c == '\\' {
                self.bump();
                self.bump();
                continue;
            }
            self.bump();
            if c == quote {
                return;
            }
        }
    }

    /// Consume a raw string body: the `#` guards and opening quote are
    /// next; scan to `"` followed by the same number of `#`s.
    fn raw_quoted(&mut self) {
        let mut hashes = 0;
        while self.peek(hashes) == Some('#') {
            hashes += 1;
        }
        self.bump_n(hashes + 1); // guards + opening quote
        while let Some(c) = self.peek(0) {
            if c == '"' && (0..hashes).all(|h| self.peek(1 + h) == Some('#')) {
                self.bump_n(1 + hashes);
                return;
            }
            self.bump();
        }
    }
}

/// Tokenize Rust source. Never fails: unterminated constructs just end
/// their token at end-of-file (the compiler owns rejecting them).
pub fn tokenize(text: &str) -> Vec<Tok> {
    let mut s = Scanner { chars: text.chars().collect(), i: 0, line: 1, col: 1 };
    let mut toks = Vec::new();
    while let Some(c) = s.peek(0) {
        let (si, sl, sc) = (s.i, s.line, s.col);
        let kind = match c {
            '\n' | ' ' | '\t' | '\r' => {
                s.bump();
                continue;
            }
            '/' if s.peek(1) == Some('/') => {
                while s.peek(0).is_some_and(|c| c != '\n') {
                    s.bump();
                }
                TokKind::LineComment
            }
            '/' if s.peek(1) == Some('*') => {
                let mut depth = 0_usize;
                while let Some(c) = s.peek(0) {
                    if c == '/' && s.peek(1) == Some('*') {
                        depth += 1;
                        s.bump_n(2);
                    } else if c == '*' && s.peek(1) == Some('/') {
                        depth -= 1;
                        s.bump_n(2);
                        if depth == 0 {
                            break;
                        }
                    } else {
                        s.bump();
                    }
                }
                TokKind::BlockComment
            }
            '"' => {
                s.quoted('"');
                TokKind::Str
            }
            'r' | 'b' => {
                // Possible literal prefix: r" r#" b" br" br#" b'
                let mut p = 1;
                if (c == 'b' && s.peek(1) == Some('r')) || (c == 'r' && s.peek(1) == Some('b')) {
                    p = 2;
                }
                let mut hashes = 0;
                while s.peek(p + hashes) == Some('#') {
                    hashes += 1;
                }
                let raw = c == 'r' || p == 2;
                if raw && s.peek(p + hashes) == Some('"') {
                    s.bump_n(p);
                    s.raw_quoted();
                    TokKind::Str
                } else if c == 'b' && p == 1 && s.peek(1) == Some('"') {
                    s.bump();
                    s.quoted('"');
                    TokKind::Str
                } else if c == 'b' && p == 1 && s.peek(1) == Some('\'') {
                    s.bump();
                    s.quoted('\'');
                    TokKind::Char
                } else {
                    while s.peek(0).is_some_and(|c| c.is_alphanumeric() || c == '_') {
                        s.bump();
                    }
                    TokKind::Ident
                }
            }
            '\'' => {
                // Char literal vs lifetime: escapes and the `'x'` shape
                // are chars; otherwise consume a lifetime identifier.
                if s.peek(1) == Some('\\') || s.peek(2) == Some('\'') {
                    s.quoted('\'');
                    TokKind::Char
                } else {
                    s.bump();
                    while s.peek(0).is_some_and(|c| c.is_alphanumeric() || c == '_') {
                        s.bump();
                    }
                    TokKind::Lifetime
                }
            }
            c if c.is_alphabetic() || c == '_' => {
                while s.peek(0).is_some_and(|c| c.is_alphanumeric() || c == '_') {
                    s.bump();
                }
                TokKind::Ident
            }
            c if c.is_ascii_digit() => {
                while let Some(c) = s.peek(0) {
                    // Stop before `..` so ranges stay punctuation.
                    if c == '.' && s.peek(1) == Some('.') {
                        break;
                    }
                    if !(c.is_alphanumeric() || c == '_' || c == '.') {
                        break;
                    }
                    s.bump();
                }
                TokKind::Num
            }
            _ => {
                s.bump();
                TokKind::Punct
            }
        };
        toks.push(Tok {
            kind,
            text: s.chars[si..s.i].iter().collect(),
            line: sl,
            col: sc,
            end_line: s.line,
            end_col: s.col,
        });
    }
    toks
}

/// One `// lint:allow(CODE, reason)` directive found in a comment.
#[derive(Clone, Debug)]
pub struct Allow {
    /// The lint code being suppressed.
    pub code: String,
    /// The mandatory human reason.
    pub reason: String,
    /// Line the directive itself is on.
    pub line: usize,
    /// Line whose findings it suppresses (its own line when trailing
    /// code, the next line when the comment stands alone).
    pub covered_line: usize,
    /// Parsed cleanly with a known code and a non-empty reason.
    pub well_formed: bool,
}

/// A tokenized source file plus the derived facts the passes need.
#[derive(Debug)]
pub struct ScannedFile {
    /// Path relative to the lint root, `/`-separated.
    pub rel: String,
    /// Source split on `\n` (for the format gate).
    pub lines: Vec<String>,
    /// The full token stream.
    pub toks: Vec<Tok>,
    /// `(first_line, last_line)` of `#[cfg(test)]`/`#[test]` blocks.
    pub test_regions: Vec<(usize, usize)>,
    /// Every `lint:allow` directive in the file.
    pub allows: Vec<Allow>,
}

impl ScannedFile {
    /// Scan `text` as the file `rel`. `known_codes` validates allow
    /// directives.
    pub fn scan(rel: &str, text: &str, known_codes: &[&str]) -> ScannedFile {
        let toks = tokenize(text);
        let test_regions = find_test_regions(&toks);
        let allows = find_allows(&toks, known_codes);
        ScannedFile {
            rel: rel.to_string(),
            lines: text.split('\n').map(str::to_string).collect(),
            toks,
            test_regions,
            allows,
        }
    }

    /// Code tokens only (comments stripped), the view passes scan.
    pub fn code(&self) -> Vec<&Tok> {
        self.toks.iter().filter(|t| !t.is_comment()).collect()
    }

    /// Is the line inside a `#[cfg(test)]` / `#[test]` region?
    pub fn in_test(&self, line: usize) -> bool {
        self.test_regions.iter().any(|&(a, b)| (a..=b).contains(&line))
    }
}

/// Brace-match the block following each `#[cfg(test)]` or `#[test]`
/// attribute. A `;` before the `{` means the attribute decorated a
/// statement, not a block — skip it.
fn find_test_regions(toks: &[Tok]) -> Vec<(usize, usize)> {
    let code: Vec<&Tok> = toks.iter().filter(|t| !t.is_comment()).collect();
    let mut regions = Vec::new();
    let mut i = 0;
    while i < code.len() {
        let span = attr_span(&code, i);
        let Some(span) = span else {
            i += 1;
            continue;
        };
        let mut j = i + span;
        while j < code.len() && code[j].text != "{" && code[j].text != ";" {
            j += 1;
        }
        if j >= code.len() || code[j].text == ";" {
            i += span;
            continue;
        }
        let mut depth = 0_isize;
        let mut k = j;
        while k < code.len() {
            match code[k].text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            k += 1;
        }
        if k < code.len() {
            regions.push((code[i].line, code[k].end_line));
            i = k + 1;
        } else {
            i += span;
        }
    }
    regions
}

/// If `code[i..]` starts a `#[cfg(test)]` or `#[test]` attribute,
/// return its token length.
fn attr_span(code: &[&Tok], i: usize) -> Option<usize> {
    let text = |k: usize| code.get(i + k).map(|t| t.text.as_str());
    if text(0) != Some("#") || text(1) != Some("[") {
        return None;
    }
    if text(2) == Some("test") && text(3) == Some("]") {
        return Some(4);
    }
    if text(2) == Some("cfg")
        && text(3) == Some("(")
        && text(4) == Some("test")
        && text(5) == Some(")")
        && text(6) == Some("]")
    {
        return Some(7);
    }
    None
}

/// Extract `lint:allow(CODE, reason)` directives from comment tokens.
fn find_allows(toks: &[Tok], known_codes: &[&str]) -> Vec<Allow> {
    const MARKER: &str = "lint:allow(";
    let mut allows = Vec::new();
    for (idx, tok) in toks.iter().enumerate() {
        if !tok.is_comment() {
            continue;
        }
        // The directive must open the comment (`// lint:allow(...)`);
        // prose that merely mentions the marker mid-sentence is not one.
        let head = tok.text.trim_start_matches(['/', '*', '!']).trim_start();
        if !head.starts_with(MARKER) {
            continue;
        }
        let rest = &head[MARKER.len()..];
        let closed = rest.find(')');
        let body = &rest[..closed.unwrap_or(rest.len())];
        let (code, reason) = match body.split_once(',') {
            Some((code, reason)) => (code.trim(), reason.trim()),
            None => (body.trim(), ""),
        };
        let well_formed = closed.is_some() && known_codes.contains(&code) && !reason.is_empty();
        // Trailing comment (code earlier on the same line) covers its
        // own line; a standalone comment line covers the next line.
        let trailing = toks[..idx]
            .iter()
            .any(|t| !t.is_comment() && t.end_line == tok.line && t.col < tok.col);
        let covered_line = if trailing { tok.line } else { tok.line + 1 };
        allows.push(Allow {
            code: code.to_string(),
            reason: reason.to_string(),
            line: tok.line,
            covered_line,
            well_formed,
        });
    }
    allows
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        tokenize(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn strings_and_comments_are_opaque() {
        let src = "let a = \"x.unwrap() // not code\"; // real comment\n";
        let toks = kinds(src);
        assert!(toks
            .iter()
            .any(|(k, t)| *k == TokKind::Str && t.contains("unwrap")));
        assert!(toks
            .iter()
            .any(|(k, t)| *k == TokKind::LineComment && t.contains("real comment")));
        // The unwrap inside the string never shows up as an ident.
        assert!(!toks.iter().any(|(k, t)| *k == TokKind::Ident && t == "unwrap"));
    }

    #[test]
    fn raw_and_byte_strings() {
        let toks = kinds(r####"let s = r#"embedded "quote" ok"#; let b = b"bytes";"####);
        let strs: Vec<_> =
            toks.iter().filter(|(k, _)| *k == TokKind::Str).collect();
        assert_eq!(strs.len(), 2);
        assert!(strs[0].1.contains("embedded"));
        assert_eq!(strs[1].1, "b\"bytes\"");
    }

    #[test]
    fn char_vs_lifetime() {
        let toks = kinds("let c: char = 'x'; fn f<'a>(v: &'a str) {} let n = '\\n';");
        let chars = toks.iter().filter(|(k, _)| *k == TokKind::Char).count();
        let lifetimes = toks.iter().filter(|(k, _)| *k == TokKind::Lifetime).count();
        assert_eq!((chars, lifetimes), (2, 2));
    }

    #[test]
    fn nested_block_comments() {
        let toks = kinds("/* outer /* inner */ still comment */ let x = 1;");
        assert_eq!(toks.iter().filter(|(k, _)| *k == TokKind::BlockComment).count(), 1);
        assert!(toks.iter().any(|(k, t)| *k == TokKind::Ident && t == "let"));
    }

    #[test]
    fn spans_are_one_based_chars() {
        let toks = tokenize("ab\n  cd");
        assert_eq!((toks[0].line, toks[0].col), (1, 1));
        assert_eq!((toks[1].line, toks[1].col), (2, 3));
    }

    #[test]
    fn test_regions_cover_cfg_test_blocks() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\n";
        let f = ScannedFile::scan("x.rs", src, &["PS100"]);
        assert!(!f.in_test(1));
        assert!(f.in_test(3));
        assert!(f.in_test(4));
    }

    #[test]
    fn cfg_test_on_statement_is_not_a_region() {
        let src = "#[cfg(test)]\nuse std::fmt;\nfn live() { body(); }\n";
        let f = ScannedFile::scan("x.rs", src, &["PS100"]);
        assert!(!f.in_test(3));
    }

    #[test]
    fn allow_directives_parse_and_attach() {
        let src = "let a = 1; // lint:allow(PS100, trusted table)\n\
                   // lint:allow(PS500, generated line)\n\
                   let b = 2;\n\
                   // lint:allow(BOGUS, nope)\n\
                   // lint:allow(PS100)\n";
        let f = ScannedFile::scan("x.rs", src, &["PS100", "PS500"]);
        assert_eq!(f.allows.len(), 4);
        assert!(f.allows[0].well_formed);
        assert_eq!(f.allows[0].covered_line, 1); // trailing: same line
        assert!(f.allows[1].well_formed);
        assert_eq!(f.allows[1].covered_line, 3); // standalone: next line
        assert!(!f.allows[2].well_formed); // unknown code
        assert!(!f.allows[3].well_formed); // missing reason
    }

    #[test]
    fn str_value_strips_quotes_and_prefixes() {
        let cases = [
            ("\"plain\"", "plain"),
            ("r\"raw\"", "raw"),
            ("r#\"guarded\"#", "guarded"),
            ("b\"bytes\"", "bytes"),
        ];
        for (src, want) in cases {
            let toks = tokenize(src);
            assert_eq!(str_value(&toks[0]), want, "{src}");
        }
    }
}
