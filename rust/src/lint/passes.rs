//! The lint passes. Each is a pure function over [`ScannedFile`]s (plus
//! whatever repo metadata its invariant spans) appending [`Finding`]s;
//! all filesystem walking happens in [`super::run`], so the passes are
//! unit-testable on in-memory sources.

use std::collections::{BTreeMap, BTreeSet};

use super::tokens::{str_value, ScannedFile, Tok, TokKind};
use super::Finding;

/// Macros PS100 treats as a panic on the hostile path.
const PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];

fn push(out: &mut Vec<Finding>, code: &'static str, rel: &str, t: &Tok, message: String) {
    out.push(Finding { code, path: rel.to_string(), line: t.line, col: t.col, message });
}

/// PS100: no `unwrap`/`expect`/panicking macros/indexing-by-literal in
/// a hostile-input module (test regions excluded — tests panic freely).
pub(crate) fn panic_freedom(f: &ScannedFile, out: &mut Vec<Finding>) {
    let code = f.code();
    for (i, t) in code.iter().enumerate() {
        if f.in_test(t.line) {
            continue;
        }
        if t.kind == TokKind::Ident
            && (t.text == "unwrap" || t.text == "expect")
            && i > 0
            && code[i - 1].text == "."
            && code.get(i + 1).is_some_and(|n| n.text == "(")
        {
            let msg = format!("`.{}()` on the hostile-input path", t.text);
            push(out, "PS100", &f.rel, t, msg);
        }
        if t.kind == TokKind::Ident
            && PANIC_MACROS.contains(&t.text.as_str())
            && code.get(i + 1).is_some_and(|n| n.text == "!")
        {
            let msg = format!("`{}!` on the hostile-input path", t.text);
            push(out, "PS100", &f.rel, t, msg);
        }
        if t.text == "["
            && i > 0
            && (code[i - 1].kind == TokKind::Ident
                || code[i - 1].text == ")"
                || code[i - 1].text == "]")
            && code.get(i + 1).is_some_and(|n| n.kind == TokKind::Num)
            && code.get(i + 2).is_some_and(|n| n.text == "]")
        {
            let msg = "indexing by integer literal on the hostile-input path".to_string();
            push(out, "PS100", &f.rel, t, msg);
        }
    }
}

/// PS200: inside size-accounting functions (name ends with `_count`),
/// bare `+`/`*` on request-derived sizes must be `checked_`/
/// `saturating_` calls instead.
pub(crate) fn overflow_surface(f: &ScannedFile, out: &mut Vec<Finding>) {
    let code = f.code();
    let mut i = 0;
    while i < code.len() {
        let is_size_fn = code[i].text == "fn"
            && code
                .get(i + 1)
                .is_some_and(|n| n.kind == TokKind::Ident && n.text.ends_with("_count"))
            && !f.in_test(code[i].line);
        if !is_size_fn {
            i += 1;
            continue;
        }
        let name = code[i + 1].text.clone();
        let mut j = i + 2;
        while j < code.len() && code[j].text != "{" {
            j += 1;
        }
        let mut depth = 0_isize;
        let mut k = j;
        while k < code.len() {
            let t = code[k];
            match t.text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                "+" | "*" if depth > 0 && k > 0 => {
                    let prev = code[k - 1];
                    let unary_ctx = matches!(
                        prev.text.as_str(),
                        "+" | "*" | "=" | "(" | "," | "<" | ">" | "&" | "return"
                    );
                    let binary = !unary_ctx
                        && (matches!(prev.kind, TokKind::Ident | TokKind::Num)
                            || prev.text == ")"
                            || prev.text == "]");
                    if binary {
                        let msg = format!(
                            "unchecked `{}` in size-accounting fn `{name}`",
                            t.text
                        );
                        push(out, "PS200", &f.rel, t, msg);
                    }
                }
                _ => {}
            }
            k += 1;
        }
        i = k + 1;
    }
}

/// PS500: the format gate — `max_width`-char line limit and trailing
/// whitespace, except where the overflow lives inside a string literal
/// (unbreakable by rustfmt too).
pub(crate) fn format_gate(f: &ScannedFile, max_width: usize, out: &mut Vec<Finding>) {
    let spans = line_str_spans(f);
    let in_str = |line: usize, col: usize| {
        spans
            .get(&line)
            .is_some_and(|v| v.iter().any(|&(a, b)| (a..b).contains(&col)))
    };
    for (idx, raw) in f.lines.iter().enumerate() {
        let line_no = idx + 1;
        let text = raw.strip_suffix('\r').unwrap_or(raw);
        let width = text.chars().count();
        if width > max_width && !in_str(line_no, max_width + 1) {
            out.push(Finding {
                code: "PS500",
                path: f.rel.clone(),
                line: line_no,
                col: max_width + 1,
                message: format!("line is {width} chars (limit {max_width})"),
            });
        }
        if text.ends_with([' ', '\t']) && !in_str(line_no, width) {
            out.push(Finding {
                code: "PS500",
                path: f.rel.clone(),
                line: line_no,
                col: width,
                message: "trailing whitespace".to_string(),
            });
        }
    }
}

/// Char-column ranges (half-open, 1-based) covered by string literals,
/// per line — multi-line strings cover whole interior lines.
fn line_str_spans(f: &ScannedFile) -> BTreeMap<usize, Vec<(usize, usize)>> {
    let mut spans: BTreeMap<usize, Vec<(usize, usize)>> = BTreeMap::new();
    for t in &f.toks {
        if t.kind != TokKind::Str {
            continue;
        }
        if t.line == t.end_line {
            spans.entry(t.line).or_default().push((t.col, t.end_col));
        } else {
            spans.entry(t.line).or_default().push((t.col, usize::MAX));
            for line in t.line + 1..t.end_line {
                spans.entry(line).or_default().push((1, usize::MAX));
            }
            spans.entry(t.end_line).or_default().push((1, t.end_col));
        }
    }
    spans
}

/// The first string literal inside the balanced parens opening at
/// `code[open]` — the metric-name argument of a registry call.
fn first_str_in_parens<'c>(code: &[&'c Tok], open: usize) -> Option<&'c Tok> {
    let mut depth = 0_isize;
    let mut k = open;
    while k < code.len() {
        match code[k].text.as_str() {
            "(" => depth += 1,
            ")" => {
                depth -= 1;
                if depth == 0 {
                    return None;
                }
            }
            _ if code[k].kind == TokKind::Str && depth >= 1 => return Some(code[k]),
            _ => {}
        }
        k += 1;
    }
    None
}

/// Does a `format!`-style literal (`{..}` wildcards) match `name`?
fn pattern_matches(pat: &str, name: &str) -> bool {
    let mut parts = Vec::new();
    let mut rest = pat;
    while let Some((head, tail)) = rest.split_once('{') {
        parts.push(head);
        rest = tail.split_once('}').map_or("", |(_, after)| after);
    }
    parts.push(rest);
    let mut pos = 0;
    let last = parts.len() - 1;
    for (idx, part) in parts.iter().enumerate() {
        if idx == 0 {
            if !name.starts_with(part) {
                return false;
            }
            pos = part.len();
        } else if idx == last {
            return name.ends_with(part) && name.len() - part.len() >= pos;
        } else {
            match name[pos..].find(part) {
                Some(at) => pos += at + part.len(),
                None => return false,
            }
        }
    }
    true
}

/// PS300: both directions of metric-catalog sync. Catalog names come
/// from the plain `counter(`/`gauge(`/`histogram(` constructor calls in
/// the registry source; recording sites are the `.counter(`/`.gauge(`/
/// `.histogram(` method calls everywhere else. A literal containing
/// `{..}` is a format pattern and covers every catalog name it matches.
pub(crate) fn catalog_sync(files: &[ScannedFile], registry_rel: &str, out: &mut Vec<Finding>) {
    let Some(reg) = files.iter().find(|f| f.rel == registry_rel) else {
        return;
    };
    let is_metric_call = |t: &Tok| {
        t.kind == TokKind::Ident
            && matches!(t.text.as_str(), "counter" | "gauge" | "histogram")
    };
    let mut catalog: BTreeMap<String, (usize, usize)> = BTreeMap::new();
    let code = reg.code();
    for (i, t) in code.iter().enumerate() {
        if is_metric_call(t)
            && code.get(i + 1).is_some_and(|n| n.text == "(")
            && (i == 0 || code[i - 1].text != ".")
            && !reg.in_test(t.line)
        {
            if let Some(lit) = first_str_in_parens(&code, i + 1) {
                catalog.insert(str_value(lit).to_string(), (lit.line, lit.col));
            }
        }
    }
    let mut recorded: Vec<(String, usize, usize, String)> = Vec::new();
    for f in files {
        if f.rel == reg.rel {
            continue;
        }
        let code = f.code();
        for (i, t) in code.iter().enumerate() {
            if is_metric_call(t)
                && i > 0
                && code[i - 1].text == "."
                && code.get(i + 1).is_some_and(|n| n.text == "(")
                && !f.in_test(t.line)
            {
                if let Some(lit) = first_str_in_parens(&code, i + 1) {
                    recorded.push((
                        str_value(lit).to_string(),
                        lit.line,
                        lit.col,
                        f.rel.clone(),
                    ));
                }
            }
        }
    }
    for (name, line, col, rel) in &recorded {
        let covered = if name.contains('{') {
            catalog.keys().any(|entry| pattern_matches(name, entry))
        } else {
            catalog.contains_key(name)
        };
        if !covered {
            out.push(Finding {
                code: "PS300",
                path: rel.clone(),
                line: *line,
                col: *col,
                message: format!("metric \"{name}\" recorded but absent from the METRICS catalog"),
            });
        }
    }
    for (entry, (line, col)) in &catalog {
        let hit = recorded.iter().any(|(name, ..)| {
            (name.contains('{') && pattern_matches(name, entry)) || name == entry
        });
        if !hit {
            out.push(Finding {
                code: "PS300",
                path: reg.rel.clone(),
                line: *line,
                col: *col,
                message: format!("METRICS entry \"{entry}\" is never recorded"),
            });
        }
    }
}

/// PS400: every protocol command (the `cmd: "..."` rows of the typed
/// `COMMANDS` table) has a PROTOCOL.md section, a PROTOCOL.md table row
/// and a golden fixture; no orphan fixtures exist.
pub(crate) fn protocol_sync(
    files: &[ScannedFile],
    request_rel: &str,
    protocol_doc: &str,
    fixtures: &[String],
    fixtures_rel: &str,
    out: &mut Vec<Finding>,
) {
    let Some(req) = files.iter().find(|f| f.rel == request_rel) else {
        return;
    };
    let code = req.code();
    let mut cmds: Vec<(String, usize, usize)> = Vec::new();
    for (i, t) in code.iter().enumerate() {
        if t.kind == TokKind::Ident
            && t.text == "cmd"
            && code.get(i + 1).is_some_and(|n| n.text == ":")
            && code.get(i + 2).is_some_and(|n| n.kind == TokKind::Str)
            && !req.in_test(t.line)
        {
            let lit = code[i + 2];
            cmds.push((str_value(lit).to_string(), lit.line, lit.col));
        }
    }
    for (cmd, line, col) in &cmds {
        let checks = [
            (format!("### `{cmd}`"), "PROTOCOL.md section"),
            (format!("| `{cmd}` |"), "PROTOCOL.md table row"),
        ];
        for (needle, what) in checks {
            if !protocol_doc.contains(&needle) {
                out.push(Finding {
                    code: "PS400",
                    path: req.rel.clone(),
                    line: *line,
                    col: *col,
                    message: format!("command \"{cmd}\" has no {what}"),
                });
            }
        }
        if !fixtures.iter().any(|f| f == &format!("{cmd}.txt")) {
            out.push(Finding {
                code: "PS400",
                path: req.rel.clone(),
                line: *line,
                col: *col,
                message: format!("command \"{cmd}\" has no golden fixture {cmd}.txt"),
            });
        }
    }
    let known: BTreeSet<&str> = cmds.iter().map(|(c, ..)| c.as_str()).collect();
    for fixture in fixtures {
        let stem = fixture.strip_suffix(".txt").unwrap_or(fixture);
        if !known.contains(stem) {
            out.push(Finding {
                code: "PS400",
                path: format!("{fixtures_rel}/{fixture}"),
                line: 1,
                col: 1,
                message: format!("orphan protocol fixture {fixture}: no matching command"),
            });
        }
    }
}

/// One file under the golden tree, pre-split for reference matching.
#[derive(Clone, Debug)]
pub struct GoldenEntry {
    /// Path relative to the lint root.
    pub rel: String,
    /// Basename (`sweep.txt`).
    pub name: String,
    /// Parent directory relative to the golden tree's own parent
    /// (`golden/protocol`), the form references use.
    pub parent_rel: String,
}

/// PS600: every golden file is referenced somewhere — by basename, by a
/// directory glob (`golden/protocol/*.txt`), or by a directory-level
/// reference (the quoted directory path a test enumerates at runtime).
pub(crate) fn orphan_goldens(golden: &[GoldenEntry], corpus: &str, out: &mut Vec<Finding>) {
    for g in golden {
        let ext = g.name.rsplit_once('.').map_or("", |(_, e)| e);
        let covered = corpus.contains(&g.name)
            || corpus.contains(&format!("{}\"", g.parent_rel))
            || corpus.contains(&format!("{}/*.{ext}", g.parent_rel));
        if !covered {
            out.push(Finding {
                code: "PS600",
                path: g.rel.clone(),
                line: 1,
                col: 1,
                message: format!(
                    "golden file {} is referenced by no test, CI step or doc",
                    g.name
                ),
            });
        }
    }
}

/// Apply the allowlist: drop findings covered by a well-formed
/// `lint:allow` on the right line with the right code, then add PS000
/// findings for malformed and stale directives.
pub(crate) fn apply_allows(files: &[&ScannedFile], findings: Vec<Finding>) -> Vec<Finding> {
    let mut allowed: BTreeSet<(String, usize, String)> = BTreeSet::new();
    for f in files {
        for a in &f.allows {
            if a.well_formed {
                allowed.insert((f.rel.clone(), a.covered_line, a.code.clone()));
            }
        }
    }
    let mut used: BTreeSet<(String, usize, String)> = BTreeSet::new();
    let mut kept = Vec::new();
    for finding in findings {
        let key = (finding.path.clone(), finding.line, finding.code.to_string());
        if allowed.contains(&key) {
            used.insert(key);
        } else {
            kept.push(finding);
        }
    }
    for f in files {
        for a in &f.allows {
            if !a.well_formed {
                kept.push(Finding {
                    code: "PS000",
                    path: f.rel.clone(),
                    line: a.line,
                    col: 1,
                    message: "malformed lint:allow directive (need a known code and a reason)"
                        .to_string(),
                });
            } else if !used.contains(&(f.rel.clone(), a.covered_line, a.code.clone())) {
                kept.push(Finding {
                    code: "PS000",
                    path: f.rel.clone(),
                    line: a.line,
                    col: 1,
                    message: format!(
                        "stale lint:allow({}): it suppresses nothing",
                        a.code
                    ),
                });
            }
        }
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(rel: &str, src: &str) -> ScannedFile {
        ScannedFile::scan(rel, src, &super::super::known_codes())
    }

    #[test]
    fn panic_freedom_flags_each_shape() {
        let src = "fn f(v: &[u8]) {\n\
                   let a = v.first().unwrap();\n\
                   let b = v.get(1).expect(\"x\");\n\
                   if v.is_empty() { panic!(\"no\"); }\n\
                   let c = v[0];\n\
                   }\n";
        let f = scan("h.rs", src);
        let mut out = Vec::new();
        panic_freedom(&f, &mut out);
        assert_eq!(out.len(), 4, "{out:?}");
        assert!(out.iter().all(|x| x.code == "PS100"));
    }

    #[test]
    fn panic_freedom_skips_tests_and_unwrap_or() {
        let src = "fn f(n: Option<u32>) -> u32 { n.unwrap_or(0) }\n\
                   #[cfg(test)]\nmod tests {\n\
                   #[test]\nfn t() { Some(1).unwrap(); }\n}\n";
        let f = scan("h.rs", src);
        let mut out = Vec::new();
        panic_freedom(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn overflow_surface_flags_bare_ops_in_count_fns() {
        let src = "fn cell_count(a: usize, b: usize) -> usize { a * b + 1 }\n\
                   fn unrelated(a: usize) -> usize { a * 3 }\n";
        let f = scan("s.rs", src);
        let mut out = Vec::new();
        overflow_surface(&f, &mut out);
        assert_eq!(out.len(), 2, "{out:?}");
        assert!(out.iter().all(|x| x.code == "PS200" && x.message.contains("cell_count")));
    }

    #[test]
    fn overflow_surface_accepts_saturating() {
        let src = "fn cell_count(a: usize, b: usize) -> usize {\n\
                   a.saturating_mul(b).saturating_add(1)\n}\n";
        let f = scan("s.rs", src);
        let mut out = Vec::new();
        overflow_surface(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn format_gate_respects_string_literals() {
        let long_code = format!("let x = 1; {}\n", "// padding padding padding".repeat(4));
        let long_str = format!("let s = \"{}\";\n", "x".repeat(120));
        let trailing = "let y = 2; \n";
        let f = scan("w.rs", &format!("{long_code}{long_str}{trailing}"));
        let mut out = Vec::new();
        format_gate(&f, 100, &mut out);
        // Long code line and trailing whitespace flagged; the long
        // string literal line is exempt.
        assert_eq!(out.len(), 2, "{out:?}");
        assert_eq!(out[0].line, 1);
        assert_eq!(out[1].line, 3);
    }

    #[test]
    fn catalog_sync_finds_both_directions() {
        let registry = "pub const METRICS: [M; 2] = [\n\
                        counter(\"hits\", \"Hits.\"),\n\
                        counter(\"misses\", \"Misses.\"),\n];\n";
        let user = "fn f(reg: &R) { reg.counter(\"hits\").inc(); \
                    reg.counter(\"unknown\").inc(); }\n";
        let files =
            vec![scan("reg.rs", registry), scan("user.rs", user)];
        let mut out = Vec::new();
        catalog_sync(&files, "reg.rs", &mut out);
        assert_eq!(out.len(), 2, "{out:?}");
        assert!(out.iter().any(|x| x.message.contains("unknown")));
        assert!(out.iter().any(|x| x.message.contains("misses")));
    }

    #[test]
    fn catalog_sync_format_patterns_cover_families() {
        let registry = "pub const METRICS: [M; 2] = [\n\
                        counter(\"req_a\", \"A.\"),\ncounter(\"req_b\", \"B.\"),\n];\n";
        let user = "fn f(reg: &R, cmd: &str) { \
                    reg.counter(&format!(\"req_{cmd}\")).inc(); }\n";
        let files = vec![scan("reg.rs", registry), scan("user.rs", user)];
        let mut out = Vec::new();
        catalog_sync(&files, "reg.rs", &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn protocol_sync_checks_doc_and_fixtures() {
        let request = "pub const COMMANDS: [C; 2] = [\n\
                       C { cmd: \"alpha\" },\nC { cmd: \"beta\" },\n];\n";
        let doc = "| `alpha` |\n### `alpha`\n";
        let fixtures = vec!["alpha.txt".to_string(), "gamma.txt".to_string()];
        let files = vec![scan("req.rs", request)];
        let mut out = Vec::new();
        protocol_sync(&files, "req.rs", doc, &fixtures, "golden/protocol", &mut out);
        // beta: no section, no row, no fixture; gamma: orphan.
        assert_eq!(out.len(), 4, "{out:?}");
        assert!(out.iter().any(|x| x.message.contains("orphan")));
    }

    #[test]
    fn orphan_goldens_accepts_all_reference_forms() {
        let golden = vec![
            GoldenEntry {
                rel: "tests/golden/a.jsonl".into(),
                name: "a.jsonl".into(),
                parent_rel: "golden".into(),
            },
            GoldenEntry {
                rel: "tests/golden/protocol/b.txt".into(),
                name: "b.txt".into(),
                parent_rel: "golden/protocol".into(),
            },
            GoldenEntry {
                rel: "tests/golden/protocol/orphan.txt".into(),
                name: "orphan.txt".into(),
                parent_rel: "golden/protocol".into(),
            },
        ];
        // a.jsonl by basename; b.txt would be covered by either a
        // dir-level reference or a glob; orphan.txt... is not, because
        // the corpus below names fixtures one by one.
        let corpus = "diff a.jsonl out\nreplay b.txt\n";
        let mut out = Vec::new();
        orphan_goldens(&golden, corpus, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].path.contains("orphan"));
        let mut out = Vec::new();
        orphan_goldens(&golden, "read_dir(\"tests/golden/protocol\")\na.jsonl", &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn allows_suppress_and_go_stale() {
        let src = "fn f(v: &[u8]) {\n\
                   let a = v.first().unwrap(); // lint:allow(PS100, trusted static table)\n\
                   let b = 1; // lint:allow(PS100, nothing to suppress here)\n\
                   }\n";
        let f = scan("h.rs", src);
        let mut out = Vec::new();
        panic_freedom(&f, &mut out);
        assert_eq!(out.len(), 1);
        let kept = apply_allows(&[&f], out);
        // The real finding is suppressed; the stale allow surfaces.
        assert_eq!(kept.len(), 1, "{kept:?}");
        assert_eq!(kept[0].code, "PS000");
        assert!(kept[0].message.contains("stale"));
    }

    #[test]
    fn pattern_matching_is_anchored() {
        assert!(pattern_matches("api_requests_{cmd}", "api_requests_sweep"));
        assert!(!pattern_matches("api_requests_{cmd}", "serve_api_requests_x"));
        assert!(pattern_matches("{a}_us", "wait_us"));
        assert!(!pattern_matches("{a}_us", "wait_ms"));
    }
}
