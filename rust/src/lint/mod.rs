//! `psim-lint`: the repo-invariant static analyzer behind `psim lint`.
//!
//! The build container has no rustfmt/clippy, the serve surface feeds
//! hostile bytes into hand-rolled parsers, and a growing set of
//! cross-file contracts (protocol commands ↔ PROTOCOL.md ↔ golden
//! fixtures; the typed `METRICS` catalog ↔ recorded metric names) was
//! enforced only by convention. This subsystem makes those conventions
//! machine-checked in the repo's zero-dependency style: a hand-rolled
//! tokenizer ([`tokens`]) that can never confuse comments or string
//! literals with code, feeding the typed pass registry ([`PASSES`],
//! executed by [`passes`]). Every finding carries a stable code, a
//! span-accurate `path:line:col`, and respects the
//! `// lint:allow(CODE, reason)` allowlist. `psim lint` runs the whole
//! registry over the tree and CI gates on zero findings; the seeded
//! fixtures under `rust/tests/lint_fixtures/` prove each pass fails
//! when it should.
//!
//! `docs/LINTS.md` is generated from the registry and this doc-test
//! keeps it honest — the pass table and every per-pass section must
//! appear verbatim:
//!
//! ```
//! let root = concat!(env!("CARGO_MANIFEST_DIR"), "/..");
//! let doc = std::fs::read_to_string(format!("{root}/docs/LINTS.md"))
//!     .expect("docs/LINTS.md exists");
//! assert!(doc.contains(&psim::lint::lints_table()), "LINTS.md pass table is stale");
//! assert!(doc.contains(&psim::lint::lints_doc()), "LINTS.md pass sections are stale");
//! ```

/// The pass implementations (`PS000`–`PS600`) and allowlist audit.
pub mod passes;
/// The hand-rolled lexer: spans, test regions, allow directives.
pub mod tokens;

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;
use passes::GoldenEntry;
use tokens::ScannedFile;

/// One entry of the typed pass registry: everything `docs/LINTS.md`,
/// `--fix-hints` and the JSON report need to describe a pass.
#[derive(Clone, Copy, Debug)]
pub struct PassDesc {
    /// Stable finding code (`PS100`).
    pub code: &'static str,
    /// Short pass name.
    pub name: &'static str,
    /// One-line invariant, for the summary table.
    pub summary: &'static str,
    /// Why the invariant exists.
    pub rationale: &'static str,
    /// An example diagnostic, verbatim shape.
    pub example: &'static str,
    /// How to fix a finding.
    pub hint: &'static str,
}

/// The pass registry, in code order. `PS000` is the meta-pass over the
/// allowlist itself; `PS100`–`PS600` are the repo invariants.
pub const PASSES: [PassDesc; 7] = [
    PassDesc {
        code: "PS000",
        name: "allowlist hygiene",
        summary: "every `lint:allow` parses, names a known code, gives a reason and suppresses a real finding",
        rationale: "An allowlist stays trustworthy only while every entry is live. A directive that no longer suppresses anything is a stale exemption waiting to hide a future regression, and a malformed one suppresses nothing while looking like it does.",
        example: "rust/src/lib.rs:41:1: PS000 stale lint:allow(PS100): it suppresses nothing",
        hint: "delete the stale directive, or fix its code and give a reason",
    },
    PassDesc {
        code: "PS100",
        name: "panic freedom",
        summary: "no `unwrap`/`expect`/panicking macros/indexing-by-literal in hostile-input modules",
        rationale: "The serve path feeds attacker-controlled bytes from an open socket into hand-rolled parsers (`api::codec`, `util::json`, `config::parser`) and the dispatch/serve machinery around them; any panic there is a remote crash. Errors must flow back as typed `ApiError` replies, and lock poisoning must be recovered (`util::sync`), never unwrapped. Test regions are exempt — tests panic freely.",
        example: "rust/src/api/engine.rs:262:27: PS100 `.unwrap()` on the hostile-input path",
        hint: "return a typed ApiError, or recover locks via util::sync::lock_unpoisoned",
    },
    PassDesc {
        code: "PS200",
        name: "overflow surface",
        summary: "size-accounting fns (`*_count`) use `checked_`/`saturating_` arithmetic only",
        rationale: "Request axes multiply into the cell/candidate counts that gate the per-request size caps. A wrapped `*` lets a maliciously huge request overflow past `MAX_REQUEST_CELLS` and masquerade as a tiny one — the PR-4 `cell_count` hardening, generalized to every function whose name ends in `_count`.",
        example: "rust/src/dse/space.rs:194:42: PS200 unchecked `+` in size-accounting fn `candidate_count`",
        hint: "use saturating_add/saturating_mul (or checked_* with an explicit error)",
    },
    PassDesc {
        code: "PS300",
        name: "metrics catalog sync",
        summary: "every recorded metric name exists in `obs::registry::METRICS`, and vice versa",
        rationale: "The typed METRICS catalog is the contract behind docs/OBSERVABILITY.md and the stats snapshot schema. A recorder writing an uncataloged name (or a catalog row nothing records) silently splits the live snapshot from its documentation. Dynamic names built with `format!` match as anchored `{..}` wildcards against the catalog.",
        example: "rust/src/api/engine.rs:84:35: PS300 metric \"api_request\" recorded but absent from the METRICS catalog",
        hint: "add the name to obs::registry::METRICS, or fix the recording site",
    },
    PassDesc {
        code: "PS400",
        name: "protocol sync",
        summary: "every protocol command has a PROTOCOL.md section, a table row and a golden fixture; no orphan fixtures",
        rationale: "The wire surface is pinned three ways — the typed `COMMANDS` table in `api::request`, docs/PROTOCOL.md, and the golden fixtures CI replays byte-for-byte. Drift between them is exactly the class of silent break the protocol smoke exists to catch, so the lint closes the triangle in both directions.",
        example: "rust/src/api/request.rs:160:18: PS400 command \"sweep\" has no golden fixture sweep.txt",
        hint: "add the PROTOCOL.md section/row and a rust/tests/golden/protocol fixture",
    },
    PassDesc {
        code: "PS500",
        name: "format gate",
        summary: "100-col line limit and no trailing whitespace (string-literal spans exempt)",
        rationale: "The offline build container has no rustfmt, so the repo's 100-column convention is enforced here, over sources, tests, benches and examples alike. Overflow inside a string literal is exempt because rustfmt cannot break it either.",
        example: "rust/src/api/request.rs:57:101: PS500 line is 113 chars (limit 100)",
        hint: "wrap the line at 100 columns and strip trailing whitespace",
    },
    PassDesc {
        code: "PS600",
        name: "orphan goldens",
        summary: "every file under `rust/tests/golden/` is replayed by a test, CI step or doc",
        rationale: "A golden fixture that nothing replays is dead weight that still looks authoritative: when a rename or a removed smoke step strands one, its pinned bytes stop guarding anything. A file counts as referenced by basename, by a directory glob (`golden/protocol/*.txt`), or by a quoted directory path a test enumerates at runtime.",
        example: "rust/tests/golden/old.jsonl:1:1: PS600 golden file old.jsonl is referenced by no test, CI step or doc",
        hint: "replay the fixture from a test or CI smoke step, or delete it",
    },
];

/// The registry's codes, for allow-directive validation.
pub(crate) fn known_codes() -> Vec<&'static str> {
    PASSES.iter().map(|p| p.code).collect()
}

/// The fix hint for a code (empty for unknown codes).
pub fn hint_for(code: &str) -> &'static str {
    PASSES.iter().find(|p| p.code == code).map_or("", |p| p.hint)
}

/// The markdown summary table of every pass, embedded verbatim in
/// `docs/LINTS.md` (the module doc-test pins it).
pub fn lints_table() -> String {
    let mut out = String::from("| code | pass | invariant |\n| --- | --- | --- |\n");
    for p in &PASSES {
        out.push_str(&format!("| `{}` | {} | {} |\n", p.code, p.name, p.summary));
    }
    out
}

/// The per-pass sections of `docs/LINTS.md`, generated from the
/// registry (the module doc-test pins them verbatim).
pub fn lints_doc() -> String {
    let mut out = String::new();
    for p in &PASSES {
        out.push_str(&format!("### `{}` — {}\n\n", p.code, p.name));
        out.push_str(&format!("**Invariant.** {}\n\n", p.summary));
        out.push_str(&format!("{}\n\n", p.rationale));
        out.push_str("**Example diagnostic:**\n\n");
        out.push_str(&format!("```text\n{}\n```\n\n", p.example));
        out.push_str(&format!(
            "**Allowlist:** `// lint:allow({}, reason)` on the offending line, or \
             alone on the line above it. The reason is mandatory and the directive \
             must suppress a real finding, or `PS000` flags it.\n\n",
            p.code
        ));
        out.push_str(&format!("**Fix hint** (`--fix-hints`): {}.\n\n", p.hint));
    }
    out
}

/// One finding: stable code, repo-relative span, message.
#[derive(Clone, Debug)]
pub struct Finding {
    /// The pass code (`PS100`).
    pub code: &'static str,
    /// Path relative to the lint root, `/`-separated.
    pub path: String,
    /// 1-based line.
    pub line: usize,
    /// 1-based character column.
    pub col: usize,
    /// What is wrong at that span.
    pub message: String,
}

/// Where the lint looks. [`LintConfig::repo`] is the real layout;
/// tests point the fields at seeded mini-trees instead.
#[derive(Clone, Debug)]
pub struct LintConfig {
    /// Every path below is resolved against this root.
    pub root: PathBuf,
    /// Directories of Rust sources getting the full semantic passes.
    pub src_dirs: Vec<PathBuf>,
    /// Directories getting the format gate only (tests, benches,
    /// examples — all-test code the semantic passes would skip anyway).
    pub fmt_dirs: Vec<PathBuf>,
    /// Path suffixes of the hostile-input modules PS100 covers.
    pub hostile: Vec<String>,
    /// PS500 line limit.
    pub max_width: usize,
    /// The METRICS catalog source (PS300), relative to `root`.
    pub registry: Option<PathBuf>,
    /// The COMMANDS table source (PS400), relative to `root`.
    pub request: Option<PathBuf>,
    /// The protocol reference document (PS400).
    pub protocol_doc: Option<PathBuf>,
    /// The protocol golden fixture directory (PS400).
    pub fixtures_dir: Option<PathBuf>,
    /// The golden tree (PS600).
    pub golden_dir: Option<PathBuf>,
    /// Files/directories whose text counts as references for PS600.
    pub ref_paths: Vec<PathBuf>,
    /// Directory basenames skipped by every walk (seeded violation
    /// fixtures must not lint the real tree's run).
    pub exclude_dirs: Vec<String>,
}

impl LintConfig {
    /// The real repository layout rooted at `root`.
    pub fn repo(root: &Path) -> LintConfig {
        let hostile = [
            "src/api/codec.rs",
            "src/api/engine.rs",
            "src/api/error.rs",
            "src/api/request.rs",
            "src/util/json.rs",
            "src/config/parser.rs",
            "src/cli/commands/serve.rs",
            "src/cli/commands/request.rs",
            "src/cli/commands/cache.rs",
            "src/store/mod.rs",
            "src/store/artifact.rs",
            "src/store/canon.rs",
            "src/store/digest.rs",
            "src/store/lru.rs",
        ];
        LintConfig {
            root: root.to_path_buf(),
            src_dirs: vec![PathBuf::from("rust/src")],
            fmt_dirs: vec![
                PathBuf::from("rust/tests"),
                PathBuf::from("rust/benches"),
                PathBuf::from("examples"),
            ],
            hostile: hostile.iter().map(|s| s.to_string()).collect(),
            max_width: 100,
            registry: Some(PathBuf::from("rust/src/obs/registry.rs")),
            request: Some(PathBuf::from("rust/src/api/request.rs")),
            protocol_doc: Some(PathBuf::from("docs/PROTOCOL.md")),
            fixtures_dir: Some(PathBuf::from("rust/tests/golden/protocol")),
            golden_dir: Some(PathBuf::from("rust/tests/golden")),
            ref_paths: vec![
                PathBuf::from("rust/tests"),
                PathBuf::from("docs"),
                PathBuf::from("README.md"),
                PathBuf::from(".github/workflows/ci.yml"),
            ],
            exclude_dirs: vec!["lint_fixtures".to_string(), "golden".to_string()],
        }
    }
}

/// A completed lint run.
#[derive(Debug)]
pub struct Report {
    /// Non-allowlisted findings, sorted by `(path, line, col, code)`.
    pub findings: Vec<Finding>,
    /// How many Rust files were scanned.
    pub files_scanned: usize,
}

impl Report {
    /// The machine-readable report: `{"schema":1, "count":N,
    /// "findings":[{code,path,line,col,message,hint}, ...]}`.
    pub fn to_json(&self) -> Json {
        let findings = self
            .findings
            .iter()
            .map(|f| {
                Json::obj(vec![
                    ("code", Json::Str(f.code.to_string())),
                    ("path", Json::Str(f.path.clone())),
                    ("line", Json::Num(f.line as f64)),
                    ("col", Json::Num(f.col as f64)),
                    ("message", Json::Str(f.message.clone())),
                    ("hint", Json::Str(hint_for(f.code).to_string())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::Num(1.0)),
            ("count", Json::Num(self.findings.len() as f64)),
            ("findings", Json::Arr(findings)),
        ])
    }
}

/// Run every pass under `cfg` and return the sorted report.
pub fn run(cfg: &LintConfig) -> Result<Report> {
    let known = known_codes();
    let src_files = scan_tree(cfg, &cfg.src_dirs, &known)?;
    let fmt_files = scan_tree(cfg, &cfg.fmt_dirs, &known)?;
    let mut findings = Vec::new();

    for f in &src_files {
        if cfg.hostile.iter().any(|h| f.rel.ends_with(h.as_str())) {
            passes::panic_freedom(f, &mut findings);
        }
        passes::overflow_surface(f, &mut findings);
        passes::format_gate(f, cfg.max_width, &mut findings);
    }
    for f in &fmt_files {
        passes::format_gate(f, cfg.max_width, &mut findings);
    }

    if let Some(registry) = &cfg.registry {
        passes::catalog_sync(&src_files, &rel_str(registry), &mut findings);
    }
    if let Some(request) = &cfg.request {
        let doc = match &cfg.protocol_doc {
            Some(p) => std::fs::read_to_string(cfg.root.join(p)).unwrap_or_default(),
            None => String::new(),
        };
        let (fixtures, fixtures_rel) = match &cfg.fixtures_dir {
            Some(dir) => (list_txt(&cfg.root.join(dir))?, rel_str(dir)),
            None => (Vec::new(), String::new()),
        };
        passes::protocol_sync(
            &src_files,
            &rel_str(request),
            &doc,
            &fixtures,
            &fixtures_rel,
            &mut findings,
        );
    }
    if let Some(golden_dir) = &cfg.golden_dir {
        let golden = golden_entries(&cfg.root, golden_dir)?;
        let corpus = reference_corpus(cfg)?;
        passes::orphan_goldens(&golden, &corpus, &mut findings);
    }

    let all: Vec<&ScannedFile> = src_files.iter().chain(fmt_files.iter()).collect();
    let mut findings = passes::apply_allows(&all, findings);
    findings.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.col, a.code).cmp(&(b.path.as_str(), b.line, b.col, b.code))
    });
    Ok(Report { findings, files_scanned: all.len() })
}

fn rel_str(path: &Path) -> String {
    path.to_string_lossy().replace('\\', "/")
}

/// Scan every `.rs` file under the given root-relative directories,
/// skipping excluded basenames; missing directories are fine.
fn scan_tree(cfg: &LintConfig, dirs: &[PathBuf], known: &[&str]) -> Result<Vec<ScannedFile>> {
    let mut files = Vec::new();
    for dir in dirs {
        let abs = cfg.root.join(dir);
        if !abs.is_dir() {
            continue;
        }
        for path in walk_sorted(&abs, &cfg.exclude_dirs)? {
            if path.extension().is_some_and(|e| e == "rs") {
                let text = std::fs::read_to_string(&path)
                    .with_context(|| format!("reading {}", path.display()))?;
                let rel = rel_str(path.strip_prefix(&cfg.root).unwrap_or(&path));
                files.push(ScannedFile::scan(&rel, &text, known));
            }
        }
    }
    Ok(files)
}

/// Depth-first sorted walk, skipping excluded directory basenames.
fn walk_sorted(dir: &Path, exclude: &[String]) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("walking {}", dir.display()))?
        .collect::<std::io::Result<_>>()?;
    entries.sort_by_key(|e| e.file_name());
    for entry in entries {
        let path = entry.path();
        let name = entry.file_name().to_string_lossy().to_string();
        if path.is_dir() {
            if !exclude.contains(&name) {
                out.extend(walk_sorted(&path, exclude)?);
            }
        } else {
            out.push(path);
        }
    }
    Ok(out)
}

/// `.txt` basenames directly inside `dir` (not subdirectories).
fn list_txt(dir: &Path) -> Result<Vec<String>> {
    let mut out = Vec::new();
    if !dir.is_dir() {
        return Ok(out);
    }
    for path in std::fs::read_dir(dir)
        .with_context(|| format!("listing {}", dir.display()))?
        .collect::<std::io::Result<Vec<_>>>()?
    {
        let p = path.path();
        if p.is_file() && p.extension().is_some_and(|e| e == "txt") {
            if let Some(name) = p.file_name() {
                out.push(name.to_string_lossy().to_string());
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Every file under the golden tree, with the parent path form
/// references use (relative to the golden tree's own parent).
fn golden_entries(root: &Path, golden_dir: &Path) -> Result<Vec<GoldenEntry>> {
    let abs = root.join(golden_dir);
    if !abs.is_dir() {
        return Ok(Vec::new());
    }
    let base = abs.parent().map(Path::to_path_buf).unwrap_or_else(|| abs.clone());
    let mut out = Vec::new();
    for path in walk_sorted(&abs, &[])? {
        let rel = rel_str(path.strip_prefix(root).unwrap_or(&path));
        let name = path.file_name().map(|n| n.to_string_lossy().to_string()).unwrap_or_default();
        let parent = path.parent().unwrap_or(&abs);
        let parent_rel = rel_str(parent.strip_prefix(&base).unwrap_or(parent));
        out.push(GoldenEntry { rel, name, parent_rel });
    }
    Ok(out)
}

/// Concatenate every PS600 reference source (tests, docs, CI config),
/// walking directories recursively minus the excluded basenames.
fn reference_corpus(cfg: &LintConfig) -> Result<String> {
    let mut seen = BTreeSet::new();
    let mut corpus = String::new();
    for rel in &cfg.ref_paths {
        let abs = cfg.root.join(rel);
        let files = if abs.is_dir() {
            walk_sorted(&abs, &cfg.exclude_dirs)?
        } else if abs.is_file() {
            vec![abs]
        } else {
            continue;
        };
        for path in files {
            if seen.insert(path.clone()) {
                if let Ok(text) = std::fs::read_to_string(&path) {
                    corpus.push_str(&text);
                    corpus.push('\n');
                }
            }
        }
    }
    Ok(corpus)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_codes_are_unique_and_sorted() {
        let codes: Vec<_> = PASSES.iter().map(|p| p.code).collect();
        let mut sorted = codes.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(codes, sorted, "pass codes must be unique and in order");
    }

    #[test]
    fn docs_cover_every_pass() {
        let table = lints_table();
        let doc = lints_doc();
        for p in &PASSES {
            assert!(table.contains(p.code), "{} missing from table", p.code);
            assert!(doc.contains(&format!("### `{}` — {}", p.code, p.name)));
            assert!(doc.contains(p.rationale), "{} rationale missing", p.code);
            assert!(doc.contains(p.example), "{} example missing", p.code);
        }
    }

    #[test]
    fn hints_resolve() {
        assert!(hint_for("PS100").contains("ApiError"));
        assert_eq!(hint_for("nope"), "");
    }

    #[test]
    fn report_json_shape() {
        let report = Report {
            findings: vec![Finding {
                code: "PS500",
                path: "x.rs".into(),
                line: 3,
                col: 101,
                message: "line is 110 chars (limit 100)".into(),
            }],
            files_scanned: 1,
        };
        let json = report.to_json();
        assert_eq!(json.get("schema").and_then(Json::as_usize), Some(1));
        assert_eq!(json.get("count").and_then(Json::as_usize), Some(1));
        let arr = json.get("findings").and_then(Json::as_arr).expect("findings array");
        assert_eq!(arr[0].get("code").and_then(Json::as_str), Some("PS500"));
        assert_eq!(arr[0].get("line").and_then(Json::as_usize), Some(3));
        assert!(arr[0].get("hint").is_some());
    }
}
