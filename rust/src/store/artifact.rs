//! The on-disk half of the result store: one artifact file per cached
//! reply, named by its canonical-request digest.
//!
//! An artifact is two lines of UTF-8:
//!
//! 1. the **manifest** — a sorted-key JSON object carrying the store
//!    schema version, the crate and protocol versions that produced the
//!    reply, the full canonical request line, its digest, an FNV-1a
//!    checksum of the payload, and the creation time;
//! 2. the **payload** — the reply's JSON line, verbatim (replies are
//!    single-line by construction).
//!
//! Reads are hostile-input paths: a store directory may hold truncated,
//! bit-flipped, renamed, foreign-version or outright garbage files, and
//! [`inspect`] must classify every one as [`ArtifactState::Invalid`]
//! with a reason — never panic, never let stale bytes through. Any
//! mismatch (schema, protocol, crate version, digest, checksum,
//! filename) invalidates; the caller treats that as a miss and
//! recomputes.

use std::fs;
use std::path::{Path, PathBuf};
use std::time::{SystemTime, UNIX_EPOCH};

use crate::api::{CRATE_VERSION, PROTOCOL_VERSION};
use crate::util::json::Json;

use super::digest::digest_hex;

/// Version of the on-disk artifact layout. Bumping it invalidates every
/// existing artifact (they are re-derived caches, never primary data).
pub const STORE_SCHEMA_VERSION: usize = 1;

/// Artifact filename extension (`<digest>.psart`).
pub const ARTIFACT_EXT: &str = "psart";

/// The parsed manifest line of an artifact (field order here matches
/// the sorted key order on disk).
pub struct Manifest {
    /// The canonical request line the payload answers.
    pub canonical: String,
    /// FNV-1a hex digest of the payload bytes.
    pub checksum: String,
    /// Crate version that wrote the artifact (`crate` on disk).
    pub crate_version: String,
    /// Creation time, seconds since the Unix epoch.
    pub created_unix: u64,
    /// FNV-1a hex digest of `canonical` (also the filename stem).
    pub digest: String,
    /// Protocol version the payload speaks.
    pub protocol: usize,
    /// On-disk layout version ([`STORE_SCHEMA_VERSION`]).
    pub schema: usize,
}

impl Manifest {
    /// The sorted-key JSON object written as an artifact's first line.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("canonical", Json::Str(self.canonical.clone())),
            ("checksum", Json::Str(self.checksum.clone())),
            ("crate", Json::Str(self.crate_version.clone())),
            ("created_unix", Json::Num(self.created_unix as f64)),
            ("digest", Json::Str(self.digest.clone())),
            ("protocol", Json::Num(self.protocol as f64)),
            ("schema", Json::Num(self.schema as f64)),
        ])
    }

    /// Parse a manifest object, rejecting missing or mistyped fields.
    pub fn from_json(json: &Json) -> Result<Manifest, String> {
        let str_field = |key: &str| {
            json.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("manifest field '{key}' missing or not a string"))
        };
        let num_field = |key: &str| {
            json.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("manifest field '{key}' missing or not an integer"))
        };
        Ok(Manifest {
            canonical: str_field("canonical")?,
            checksum: str_field("checksum")?,
            crate_version: str_field("crate")?,
            created_unix: num_field("created_unix")? as u64,
            digest: str_field("digest")?,
            protocol: num_field("protocol")?,
            schema: num_field("schema")?,
        })
    }
}

/// The outcome of validating one artifact file.
pub enum ArtifactState {
    /// Every check passed; the payload may be served.
    Valid {
        /// The validated manifest.
        manifest: Manifest,
        /// The reply payload (line 2, verbatim).
        payload: String,
    },
    /// The artifact was rejected and must be treated as absent.
    Invalid {
        /// Why validation failed (for `psim cache verify` output).
        reason: String,
    },
}

/// Where the artifact for `digest` lives under `dir`.
pub fn artifact_path(dir: &Path, digest: &str) -> PathBuf {
    dir.join(format!("{digest}.{ARTIFACT_EXT}"))
}

/// Seconds since the Unix epoch (0 if the clock is before the epoch —
/// creation time is informational metadata, never validated).
pub fn now_unix() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_secs()).unwrap_or(0)
}

/// Write the artifact for `(canonical, payload)` under `dir`,
/// overwriting any previous (possibly invalid) artifact at the same
/// digest. Returns the path written.
pub fn write(dir: &Path, canonical: &str, payload: &str) -> std::io::Result<PathBuf> {
    let digest = digest_hex(canonical.as_bytes());
    let manifest = Manifest {
        canonical: canonical.to_string(),
        checksum: digest_hex(payload.as_bytes()),
        crate_version: CRATE_VERSION.to_string(),
        created_unix: now_unix(),
        digest: digest.clone(),
        protocol: PROTOCOL_VERSION,
        schema: STORE_SCHEMA_VERSION,
    };
    let path = artifact_path(dir, &digest);
    fs::write(&path, format!("{}\n{payload}\n", manifest.to_json()))?;
    Ok(path)
}

/// Validate one artifact file end to end. Every failure mode — I/O
/// error, wrong line count, garbage manifest, any version/spec/digest
/// mismatch — comes back as [`ArtifactState::Invalid`] with a reason.
pub fn inspect(path: &Path) -> ArtifactState {
    let invalid = |reason: String| ArtifactState::Invalid { reason };
    let text = match fs::read_to_string(path) {
        Ok(text) => text,
        Err(e) => return invalid(format!("unreadable: {e}")),
    };
    let mut lines = text.lines();
    let (Some(manifest_line), Some(payload), None) = (lines.next(), lines.next(), lines.next())
    else {
        return invalid("expected exactly two lines (manifest, payload)".to_string());
    };
    let json = match Json::parse(manifest_line) {
        Ok(json) => json,
        Err(e) => return invalid(format!("manifest is not valid JSON: {e}")),
    };
    let manifest = match Manifest::from_json(&json) {
        Ok(manifest) => manifest,
        Err(reason) => return invalid(reason),
    };
    if manifest.schema != STORE_SCHEMA_VERSION {
        return invalid(format!(
            "store schema {} (this build writes {STORE_SCHEMA_VERSION})",
            manifest.schema
        ));
    }
    if manifest.protocol != PROTOCOL_VERSION {
        return invalid(format!(
            "protocol {} (this build speaks {PROTOCOL_VERSION})",
            manifest.protocol
        ));
    }
    if manifest.crate_version != CRATE_VERSION {
        return invalid(format!(
            "crate version {} (this build is {CRATE_VERSION})",
            manifest.crate_version
        ));
    }
    if manifest.digest != digest_hex(manifest.canonical.as_bytes()) {
        return invalid("digest does not match the canonical request".to_string());
    }
    if manifest.checksum != digest_hex(payload.as_bytes()) {
        return invalid("payload checksum mismatch".to_string());
    }
    // A renamed artifact must not answer another request's digest.
    let stem = path.file_stem().and_then(|s| s.to_str());
    if stem != Some(manifest.digest.as_str()) {
        return invalid("filename does not match the manifest digest".to_string());
    }
    ArtifactState::Valid { manifest, payload: payload.to_string() }
}

/// Scan a store directory: every `*.psart` file, sorted by path, with
/// its validation state. Files without the artifact extension are
/// ignored (they are not ours to judge or to garbage-collect).
pub fn scan(dir: &Path) -> std::io::Result<Vec<(PathBuf, ArtifactState)>> {
    let mut paths: Vec<PathBuf> = fs::read_dir(dir)?
        .filter_map(|entry| entry.ok())
        .map(|entry| entry.path())
        .filter(|path| path.extension().and_then(|e| e.to_str()) == Some(ARTIFACT_EXT))
        .collect();
    paths.sort();
    Ok(paths
        .into_iter()
        .map(|path| {
            let state = inspect(&path);
            (path, state)
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_store(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("psim_artifact_{tag}_{}_{}", std::process::id(), now_unix()));
        fs::create_dir_all(&dir).expect("create temp store dir");
        dir
    }

    #[test]
    fn write_then_inspect_round_trips() {
        let dir = temp_store("roundtrip");
        let canonical = r#"{"cmd":"tables","faithful":false,"protocol":1,"table":"table3"}"#;
        let payload = r#"{"table":"..."}"#;
        let path = write(&dir, canonical, payload).expect("write artifact");
        match inspect(&path) {
            ArtifactState::Valid { manifest, payload: got } => {
                assert_eq!(manifest.canonical, canonical);
                assert_eq!(got, payload);
                assert_eq!(manifest.schema, STORE_SCHEMA_VERSION);
                assert_eq!(manifest.protocol, PROTOCOL_VERSION);
                assert_eq!(manifest.crate_version, CRATE_VERSION);
            }
            ArtifactState::Invalid { reason } => panic!("fresh artifact invalid: {reason}"),
        }
        let entries = scan(&dir).expect("scan");
        assert_eq!(entries.len(), 1);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn renamed_artifacts_are_invalid() {
        let dir = temp_store("rename");
        let path = write(&dir, "request-a", "reply-a").expect("write artifact");
        let forged = dir.join(format!("{}.{ARTIFACT_EXT}", "0".repeat(16)));
        fs::rename(&path, &forged).expect("rename artifact");
        match inspect(&forged) {
            ArtifactState::Invalid { reason } => {
                assert!(reason.contains("filename"), "{reason}");
            }
            ArtifactState::Valid { .. } => panic!("renamed artifact validated"),
        }
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_invalid_not_a_panic() {
        let state = inspect(Path::new("/nonexistent/psim/deadbeefdeadbeef.psart"));
        assert!(matches!(state, ArtifactState::Invalid { .. }));
    }
}
