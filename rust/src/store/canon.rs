//! Request canonicalization: every equivalent spelling of a request —
//! shuffled JSON keys, permuted axis lists, elided-vs-explicit default
//! fields — maps to ONE canonical line, so the store's content address
//! is spelling-invariant.
//!
//! The pipeline is deliberately boring: decode already erased JSON key
//! order (objects live in a `BTreeMap`) and expanded every default, so
//! canonicalization is just a normalized clone ([`canonical_request`]:
//! axes sorted, the execution-only `workers` knob stripped) re-encoded
//! through [`codec::encode_request`] (sorted keys, explicit `protocol`
//! field, single-line output).
//!
//! One caveat the tests pin: grid cells are emitted in the spec's
//! enumeration order, so requests that differ only in axis *order*
//! share one cache entry and all receive the **first-computed**
//! rendering. That is the point of content addressing — the rows are
//! the same set — but a client that depends on row order across
//! differently-ordered spellings should not share a store. Duplicate
//! axis entries are kept (they change cell counts, so they are not
//! equivalent spellings).

use crate::api::codec;
use crate::api::Request;

/// Whether `req`'s reply may be memoized: the pure-analytics commands
/// (`sweep`/`explore`/`fusion`/`analyze`/`tables`), mirroring the
/// coalescer's set. `zoo` and `version` are static but cheaper than the
/// cache; `infer`, `metrics`, `stats` and `shutdown` are stateful, so
/// replaying an old reply would lie.
pub fn cacheable(req: &Request) -> bool {
    matches!(
        req,
        Request::Sweep { .. }
            | Request::Explore { .. }
            | Request::Fusion { .. }
            | Request::Analyze { .. }
            | Request::Tables { .. }
    )
}

/// A normalized clone of `req`: networks sorted by name, numeric axes
/// ascending, strategies by slug, modes/objectives/SRAM budgets and
/// precision axes by label, and the `workers` execution knob stripped
/// (it changes scheduling, never reply bytes — pinned by the grid
/// engine's worker-invariance golden).
pub fn canonical_request(req: &Request) -> Request {
    let mut req = req.clone();
    match &mut req {
        Request::Sweep { spec, workers } => {
            spec.networks.sort_by(|a, b| a.name.cmp(&b.name));
            spec.mac_budgets.sort_unstable();
            spec.strategies.sort_by_key(|s| s.slug());
            spec.modes.sort_by_key(|m| m.label());
            spec.batch_sizes.sort_unstable();
            spec.fusion_depths.sort_unstable();
            spec.datatypes.sort_by_key(|dt| dt.label());
            *workers = None;
        }
        Request::Explore { spec, workers } => {
            spec.networks.sort_by(|a, b| a.name.cmp(&b.name));
            spec.mac_budgets.sort_unstable();
            spec.sram_budgets.sort_by_key(|s| s.label());
            spec.strategies.sort_by_key(|s| s.slug());
            spec.modes.sort_by_key(|m| m.label());
            spec.fusion_depths.sort_unstable();
            spec.objectives.sort_by_key(|o| o.label());
            *workers = None;
        }
        Request::Fusion { networks, .. } => {
            networks.sort_by(|a, b| a.name.cmp(&b.name));
        }
        _ => {}
    }
    req
}

/// The canonical line: [`canonical_request`] re-encoded through the
/// protocol codec's sorted-key single-line JSON. Defined for every
/// request shape (the pinned-hash tests cover all decodable fixtures);
/// the store itself only ever keys on [`cache_key`].
pub fn canonical_line(req: &Request) -> String {
    codec::encode_request(&canonical_request(req)).to_string()
}

/// The store key: `Some(canonical line)` for [`cacheable`] requests,
/// `None` otherwise.
pub fn cache_key(req: &Request) -> Option<String> {
    if cacheable(req) {
        Some(canonical_line(req))
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::codec::decode_line;

    #[test]
    fn axis_order_and_key_order_are_erased() {
        let a = decode_line(r#"{"cmd":"sweep","macs":[1024,512],"networks":["AlexNet"]}"#)
            .unwrap();
        let b = decode_line(r#"{"networks":["AlexNet"],"cmd":"sweep","macs":[512,1024]}"#)
            .unwrap();
        assert_eq!(canonical_line(&a), canonical_line(&b));
    }

    #[test]
    fn workers_is_not_part_of_the_identity() {
        let a = decode_line(r#"{"cmd":"sweep","networks":["AlexNet"],"workers":1}"#).unwrap();
        let b = decode_line(r#"{"cmd":"sweep","networks":["AlexNet"],"workers":8}"#).unwrap();
        let c = decode_line(r#"{"cmd":"sweep","networks":["AlexNet"]}"#).unwrap();
        assert_eq!(canonical_line(&a), canonical_line(&b));
        assert_eq!(canonical_line(&a), canonical_line(&c));
    }

    #[test]
    fn duplicate_axis_entries_are_distinct_spellings() {
        // [512,512] evaluates twice as many cells as [512]; the two are
        // NOT equivalent and must not share a cache entry.
        let once = decode_line(r#"{"cmd":"sweep","networks":["AlexNet"],"macs":[512]}"#).unwrap();
        let twice =
            decode_line(r#"{"cmd":"sweep","networks":["AlexNet"],"macs":[512,512]}"#).unwrap();
        assert_ne!(canonical_line(&once), canonical_line(&twice));
    }

    #[test]
    fn only_pure_analytics_requests_are_cacheable() {
        let cacheable_lines = [
            r#"{"cmd":"sweep"}"#,
            r#"{"cmd":"explore"}"#,
            r#"{"cmd":"fusion"}"#,
            r#"{"cmd":"analyze","network":"AlexNet"}"#,
            r#"{"cmd":"tables","table":"table1"}"#,
        ];
        for line in cacheable_lines {
            let req = decode_line(line).unwrap();
            assert!(cacheable(&req), "{line}");
            assert!(cache_key(&req).is_some(), "{line}");
        }
        for line in [
            r#"{"cmd":"zoo"}"#,
            r#"{"cmd":"metrics"}"#,
            r#"{"cmd":"stats"}"#,
            r#"{"cmd":"version"}"#,
            r#"{"cmd":"shutdown"}"#,
        ] {
            let req = decode_line(line).unwrap();
            assert!(!cacheable(&req), "{line}");
            assert!(cache_key(&req).is_none(), "{line}");
        }
    }

    #[test]
    fn canonical_line_is_idempotent() {
        let req =
            decode_line(r#"{"cmd":"explore","networks":["VGG-16","AlexNet"],"workers":4}"#)
                .unwrap();
        let line = canonical_line(&req);
        let again = decode_line(&line).unwrap();
        assert_eq!(canonical_line(&again), line);
    }
}
