//! The in-memory half of the result store: a bounded LRU from request
//! digest to reply payload.
//!
//! Deliberately simple — a `HashMap` plus a monotone use tick, evicting
//! the least-recently-used entry with an `O(n)` scan when the bound is
//! exceeded. The capacity is small (default
//! [`super::DEFAULT_CAPACITY`]) and hits are `O(1)`, so the scan only
//! ever runs on an insert that crossed the bound.
//!
//! Every entry stores the full canonical request line next to the
//! payload: a lookup whose canonical form differs from the stored one
//! (a digest collision) is a miss, never a foreign reply.

use std::collections::HashMap;

struct Entry {
    canonical: String,
    payload: String,
    last_used: u64,
}

/// A bounded digest → reply-payload map with least-recently-used
/// eviction.
pub struct Lru {
    capacity: usize,
    tick: u64,
    entries: HashMap<u64, Entry>,
}

impl Lru {
    /// An empty LRU holding at most `capacity` entries (clamped to at
    /// least 1 — a zero-capacity cache would evict its own insert).
    pub fn new(capacity: usize) -> Lru {
        Lru { capacity: capacity.max(1), tick: 0, entries: HashMap::new() }
    }

    /// Number of resident entries (never exceeds the capacity).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the cache holds no entries.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The configured entry bound.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Look up `digest`, refreshing its recency on a hit. The stored
    /// canonical line must equal `canonical` — a colliding digest is a
    /// miss by construction.
    pub fn get(&mut self, digest: u64, canonical: &str) -> Option<String> {
        self.tick += 1;
        let tick = self.tick;
        match self.entries.get_mut(&digest) {
            Some(entry) if entry.canonical == canonical => {
                entry.last_used = tick;
                Some(entry.payload.clone())
            }
            _ => None,
        }
    }

    /// Insert (or overwrite) the entry for `digest`, then evict
    /// least-recently-used entries until the bound holds. Returns how
    /// many entries were evicted (0 or 1 in practice).
    pub fn insert(&mut self, digest: u64, canonical: &str, payload: &str) -> u64 {
        self.tick += 1;
        self.entries.insert(
            digest,
            Entry {
                canonical: canonical.to_string(),
                payload: payload.to_string(),
                last_used: self.tick,
            },
        );
        let mut evicted = 0;
        while self.entries.len() > self.capacity {
            let oldest = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(digest, _)| *digest);
            match oldest {
                Some(victim) => {
                    self.entries.remove(&victim);
                    evicted += 1;
                }
                None => break,
            }
        }
        evicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_bounds_the_entry_count() {
        let mut lru = Lru::new(4);
        let mut evicted = 0;
        for i in 0..10u64 {
            evicted += lru.insert(i, &format!("c{i}"), "p");
        }
        assert_eq!(lru.len(), 4);
        assert_eq!(evicted, 6);
    }

    #[test]
    fn recently_used_entries_survive_eviction() {
        let mut lru = Lru::new(2);
        lru.insert(1, "a", "pa");
        lru.insert(2, "b", "pb");
        // Touch 1 so 2 becomes the LRU victim.
        assert_eq!(lru.get(1, "a").as_deref(), Some("pa"));
        lru.insert(3, "c", "pc");
        assert_eq!(lru.get(1, "a").as_deref(), Some("pa"));
        assert_eq!(lru.get(2, "b"), None, "the LRU entry was evicted");
        assert_eq!(lru.get(3, "c").as_deref(), Some("pc"));
    }

    #[test]
    fn colliding_canonicals_never_share_an_entry() {
        let mut lru = Lru::new(4);
        lru.insert(7, "request-a", "reply-a");
        // Same digest, different canonical form: a miss, not reply-a.
        assert_eq!(lru.get(7, "request-b"), None);
        assert_eq!(lru.get(7, "request-a").as_deref(), Some("reply-a"));
    }

    #[test]
    fn overwrite_replaces_without_growing() {
        let mut lru = Lru::new(2);
        lru.insert(1, "a", "old");
        let evicted = lru.insert(1, "a", "new");
        assert_eq!(evicted, 0);
        assert_eq!(lru.len(), 1);
        assert_eq!(lru.get(1, "a").as_deref(), Some("new"));
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let mut lru = Lru::new(0);
        assert_eq!(lru.capacity(), 1);
        lru.insert(1, "a", "pa");
        assert_eq!(lru.get(1, "a").as_deref(), Some("pa"));
    }
}
