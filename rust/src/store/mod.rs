//! Content-addressed result store: memoize full reply lines keyed by
//! the canonical form of the request that produced them.
//!
//! The serve path already dedupes *in-flight* duplicates through the
//! engine's coalescer; the store dedupes *across time and process
//! restarts*. The two compose: a burst of identical requests folds to
//! one dispatch (coalescer), and the next identical request — seconds
//! or days later, same process or a fresh one — replays the stored
//! bytes without touching the grid engine at all (store).
//!
//! Layers, bottom up:
//!
//! - [`digest`] — hand-rolled FNV-1a 64-bit content address;
//! - [`canon`] — request canonicalization (spelling-invariant keys);
//! - [`lru`] — the bounded in-memory payload cache;
//! - [`artifact`] — the optional on-disk artifact format (versioned
//!   manifest + payload, validated on every read);
//! - [`ResultStore`] — the engine-facing facade tying them together
//!   and keeping the `cache_*` counters honest.
//!
//! Accounting invariants (pinned by `tests/store_cache.rs`): every
//! lookup increments exactly one of `cache_hits`/`cache_misses`, so
//! `cache_hits + cache_misses == cache_lookups`; `cache_invalidations`
//! counts rejected artifacts and is always ≤ `cache_misses` (a
//! rejected artifact falls through to the miss path and recomputes).

pub mod artifact;
pub mod canon;
pub mod digest;
pub mod lru;

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::obs::metrics::Counter;
use crate::obs::registry::Registry;
use crate::util::sync::lock_unpoisoned;

use artifact::ArtifactState;
use lru::Lru;

/// Default in-memory entry bound for a [`ResultStore`].
pub const DEFAULT_CAPACITY: usize = 1024;

/// The store's metric handles, registered in the engine's registry so
/// they surface through `{"cmd":"stats"}` and the METRICS catalog.
pub struct CacheCounters {
    /// Cacheable requests that consulted the store.
    pub lookups: Arc<Counter>,
    /// Lookups answered from a stored reply.
    pub hits: Arc<Counter>,
    /// Lookups that required a fresh dispatch.
    pub misses: Arc<Counter>,
    /// Entries evicted by the in-memory LRU bound.
    pub evictions: Arc<Counter>,
    /// Stored artifacts rejected by validation and recomputed.
    pub invalidations: Arc<Counter>,
}

impl CacheCounters {
    /// Register the `cache_*` counters in `reg`.
    pub fn new(reg: &Registry) -> CacheCounters {
        CacheCounters {
            lookups: reg.counter("cache_lookups"),
            hits: reg.counter("cache_hits"),
            misses: reg.counter("cache_misses"),
            evictions: reg.counter("cache_evictions"),
            invalidations: reg.counter("cache_invalidations"),
        }
    }
}

/// A bounded reply memo: in-memory LRU, optionally backed by an
/// on-disk artifact directory that survives process restarts.
pub struct ResultStore {
    lru: Mutex<Lru>,
    dir: Option<PathBuf>,
    counters: CacheCounters,
}

impl ResultStore {
    /// An in-memory-only store (no artifacts, nothing survives the
    /// process), registering its counters in `reg`.
    pub fn memory(capacity: usize, reg: &Registry) -> ResultStore {
        ResultStore {
            lru: Mutex::new(Lru::new(capacity)),
            dir: None,
            counters: CacheCounters::new(reg),
        }
    }

    /// A store backed by the artifact directory `dir` (created if
    /// absent), registering its counters in `reg`.
    pub fn open(dir: &Path, capacity: usize, reg: &Registry) -> std::io::Result<ResultStore> {
        std::fs::create_dir_all(dir)?;
        Ok(ResultStore {
            lru: Mutex::new(Lru::new(capacity)),
            dir: Some(dir.to_path_buf()),
            counters: CacheCounters::new(reg),
        })
    }

    /// The artifact directory, if this store persists to disk.
    pub fn dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    /// The store's metric handles.
    pub fn counters(&self) -> &CacheCounters {
        &self.counters
    }

    /// Look up the reply for a canonical request line. Checks the
    /// in-memory LRU first, then the artifact directory; a valid
    /// on-disk artifact re-warms the LRU. Exactly one of
    /// `cache_hits`/`cache_misses` is incremented per call.
    pub fn lookup(&self, canonical: &str) -> Option<String> {
        self.counters.lookups.inc();
        let digest = digest::fnv1a_64(canonical.as_bytes());
        if let Some(payload) = lock_unpoisoned(&self.lru).get(digest, canonical) {
            self.counters.hits.inc();
            return Some(payload);
        }
        if let Some(dir) = &self.dir {
            let path = artifact::artifact_path(dir, &digest::hex16(digest));
            if path.exists() {
                match artifact::inspect(&path) {
                    ArtifactState::Valid { manifest, payload }
                        if manifest.canonical == canonical =>
                    {
                        let evicted =
                            lock_unpoisoned(&self.lru).insert(digest, canonical, &payload);
                        self.counters.evictions.add(evicted);
                        self.counters.hits.inc();
                        return Some(payload);
                    }
                    // A valid artifact answering a different canonical
                    // form is a digest collision: reject it like any
                    // other mismatch and recompute.
                    ArtifactState::Valid { .. } | ArtifactState::Invalid { .. } => {
                        self.counters.invalidations.inc();
                    }
                }
            }
        }
        self.counters.misses.inc();
        None
    }

    /// Record the reply for a canonical request line: insert into the
    /// LRU and, when disk-backed, (re)write the artifact — overwriting
    /// any invalid file that just failed validation at this digest.
    pub fn insert(&self, canonical: &str, payload: &str) {
        let digest = digest::fnv1a_64(canonical.as_bytes());
        let evicted = lock_unpoisoned(&self.lru).insert(digest, canonical, payload);
        self.counters.evictions.add(evicted);
        if let Some(dir) = &self.dir {
            // A failed artifact write degrades the store to in-memory
            // for this entry; it must never fail the request itself.
            let _ = artifact::write(dir, canonical, payload);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_accounting_is_conserved() {
        let reg = Registry::new();
        let store = ResultStore::memory(4, &reg);
        assert!(store.lookup("a").is_none());
        store.insert("a", "pa");
        assert_eq!(store.lookup("a").as_deref(), Some("pa"));
        assert!(store.lookup("b").is_none());
        let c = store.counters();
        assert_eq!(c.lookups.get(), 3);
        assert_eq!(c.hits.get(), 1);
        assert_eq!(c.misses.get(), 2);
        assert_eq!(c.hits.get() + c.misses.get(), c.lookups.get());
    }

    #[test]
    fn eviction_counter_tracks_the_lru_bound() {
        let reg = Registry::new();
        let store = ResultStore::memory(2, &reg);
        for i in 0..5 {
            store.insert(&format!("req-{i}"), "p");
        }
        assert_eq!(store.counters().evictions.get(), 3);
    }

    #[test]
    fn disk_backed_store_survives_a_fresh_lru() {
        let dir = std::env::temp_dir().join(format!(
            "psim_store_warm_{}_{}",
            std::process::id(),
            artifact::now_unix()
        ));
        let reg = Registry::new();
        let store = ResultStore::open(&dir, 4, &reg).expect("open store");
        store.insert("req", "reply");
        drop(store);
        // A fresh store over the same directory (cold LRU) hits disk.
        let reg2 = Registry::new();
        let store = ResultStore::open(&dir, 4, &reg2).expect("reopen store");
        assert_eq!(store.lookup("req").as_deref(), Some("reply"));
        let c = store.counters();
        assert_eq!(c.hits.get(), 1);
        assert_eq!(c.invalidations.get(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
