//! Hand-rolled FNV-1a 64-bit digest — the store's content address.
//!
//! FNV-1a is the right tool here: zero dependencies, a dozen lines,
//! deterministic across platforms, and fast on the short canonical
//! request lines it hashes. It is **not** cryptographic — the store
//! never trusts the digest alone: every lookup re-checks the stored
//! canonical form against the request's (see
//! [`super::lru::Lru::get`] and the manifest validation in
//! [`super::artifact`]), so even a deliberate collision can only ever
//! miss, never serve foreign bytes.

/// The FNV-1a 64-bit offset basis.
pub const FNV_OFFSET_BASIS: u64 = 0xcbf2_9ce4_8422_2325;

/// The FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over `bytes`: XOR each byte into the hash, then multiply by
/// the FNV prime (wrapping, as the algorithm specifies).
pub fn fnv1a_64(bytes: &[u8]) -> u64 {
    let mut hash = FNV_OFFSET_BASIS;
    for b in bytes {
        hash ^= u64::from(*b);
        hash = hash.wrapping_mul(FNV_PRIME);
    }
    hash
}

/// Render a digest as 16 lowercase hex characters (the artifact
/// filename stem and every manifest digest/checksum field).
pub fn hex16(x: u64) -> String {
    format!("{x:016x}")
}

/// [`fnv1a_64`] rendered through [`hex16`].
pub fn digest_hex(bytes: &[u8]) -> String {
    hex16(fnv1a_64(bytes))
}

/// Parse a [`hex16`] rendering back to its `u64` (`None` unless the
/// input is exactly 16 lowercase hex characters).
pub fn parse_hex16(s: &str) -> Option<u64> {
    if s.len() != 16 || !s.bytes().all(|b| b.is_ascii_digit() || (b'a'..=b'f').contains(&b)) {
        return None;
    }
    u64::from_str_radix(s, 16).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_published_fnv1a_vectors() {
        // Reference values from the FNV specification (Fowler/Noll/Vo).
        assert_eq!(fnv1a_64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a_64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a_64(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn hex_round_trips() {
        for x in [0, 1, 0xdead_beef, u64::MAX, fnv1a_64(b"psim")] {
            let hex = hex16(x);
            assert_eq!(hex.len(), 16);
            assert_eq!(parse_hex16(&hex), Some(x));
        }
        assert_eq!(parse_hex16("short"), None);
        assert_eq!(parse_hex16("00000000DEADBEEF"), None, "uppercase is not canonical");
        assert_eq!(parse_hex16("00000000deadbeez"), None);
    }

    #[test]
    fn digest_is_byte_sensitive() {
        assert_ne!(fnv1a_64(b"{\"cmd\":\"sweep\"}"), fnv1a_64(b"{\"cmd\":\"sweeq\"}"));
        assert_eq!(digest_hex(b"x"), hex16(fnv1a_64(b"x")));
    }
}
