//! # PSIM — Partial-Sum Impact Simulator
//!
//! A production-grade reproduction of *"On the Impact of Partial Sums on
//! Interconnect Bandwidth and Memory Accesses in a DNN Accelerator"*
//! (M. Chandra, ICIIS 2020).
//!
//! The crate has four pillars:
//!
//! * [`models`] — the typed operator abstraction (conv / GEMM /
//!   attention, lowered onto the conv equations by [`models::Op`]) and
//!   the network zoo: the paper's eight CNNs plus extensions including
//!   a GEMM/attention ViT-Tiny.
//! * [`analytics`] — the paper's first-order bandwidth model: partitioning
//!   strategies (eqs. 1–7), active-memory-controller model, sweeps, and
//!   the unified [`analytics::grid`] scenario-sweep engine (declarative
//!   grids, parallel execution, per-shape memoization, JSONL output).
//! * [`sim`] — an event-level accelerator simulator (MAC array, SRAM,
//!   AXI-like interconnect with sideband commands, passive/active memory
//!   controller) that validates the analytical model transaction-by-
//!   transaction.
//! * [`dse`] — the design-space explorer: Pareto frontiers over MAC
//!   budget × SRAM capacity × strategy × controller mode, with
//!   admissible-bound pruning over the grid engine's memo cache.
//! * [`coordinator`] + [`runtime`] — a Rust execution stack that runs the
//!   tiled convolutions *functionally* through AOT-compiled XLA artifacts
//!   (JAX/Pallas at build time, PJRT at run time; Python never on the
//!   request path).
//! * [`api`] — the typed Request/Response facade over all of the above:
//!   ONE versioned entry point ([`api::Engine::dispatch`]) shared by the
//!   CLI, the `serve` protocol and library embedders. This is the
//!   documented embedding surface — see the [`api`] module docs for a
//!   runnable example.
//!
//! Supporting modules: [`config`] (accelerator/workload config files),
//! [`report`] (paper table/figure renderers), [`store`] (the
//! content-addressed result store behind `--store` and `psim cache`),
//! [`util`] (offline-friendly
//! substrate: PRNG, JSON, table formatting, property-test + bench
//! harnesses), [`cli`] (the `psim` binary's command surface), and
//! [`lint`] (the repo-invariant static analyzer behind `psim lint`,
//! CI-blocking; see `docs/LINTS.md`).
//!
//! Reference documents: `docs/MODEL.md` (the full equation derivations,
//! element and byte forms), `docs/PROTOCOL.md` (the wire reference) and
//! `docs/ARCHITECTURE.md` (the data flow) — each pinned against this
//! crate by doc-tests so they cannot drift.

#![forbid(unsafe_code)]
#![warn(missing_docs)]

pub mod analytics;
/// The typed Request/Response facade (the embedding surface).
pub mod api;
/// The `psim` binary's command-line surface.
pub mod cli;
/// Accelerator/workload configuration files.
pub mod config;
/// The serving stack: batching, engine threads, metrics.
pub mod coordinator;
/// The design-space explorer (Pareto frontiers).
pub mod dse;
/// The repo-invariant static analyzer behind `psim lint`.
pub mod lint;
/// Workload descriptors (conv/GEMM/attention ops) and the precision
/// model.
pub mod models;
/// Observability: metrics, span tracing, stats snapshot registry.
pub mod obs;
/// Paper table/figure renderers.
pub mod report;
/// The PJRT execution runtime over AOT artifacts.
pub mod runtime;
/// The event-level accelerator simulator.
pub mod sim;
/// Content-addressed result store (reply memoization + artifacts).
pub mod store;
/// Offline-friendly substrate: PRNG, JSON, tables, harnesses.
pub mod util;
