//! PJRT runtime: load and execute the AOT artifacts from the Rust hot
//! path. Python never runs here — the HLO text under `artifacts/` is the
//! entire interface to the build-time JAX/Pallas stack.
//!
//! * [`tensor`] — a minimal host tensor (`f32`, row-major) + Literal
//!   conversion.
//! * [`artifact`] — `manifest.json` parsing and artifact discovery.
//! * [`client`] — PJRT client wrapper with a compiled-executable cache.

pub mod artifact;
pub mod client;
pub mod tensor;

pub use artifact::{ArtifactDir, Entry};
pub use client::{Input, PreparedTensor, Runtime};
pub use tensor::Tensor;
