//! Artifact discovery: parse `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) and resolve entry points to HLO text files.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Tensor signature in the manifest.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSig {
    /// Tensor dimensions.
    pub shape: Vec<usize>,
    /// Element dtype name (e.g. `"float32"`).
    pub dtype: String,
}

impl TensorSig {
    fn from_json(j: &Json) -> Result<TensorSig> {
        let shape = j
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| anyhow!("missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .and_then(|d| d.as_str())
            .ok_or_else(|| anyhow!("missing dtype"))?
            .to_string();
        Ok(TensorSig { shape, dtype })
    }

    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT entry point.
#[derive(Clone, Debug)]
pub struct Entry {
    /// Entry-point name (e.g. `"psimnet_b1"`).
    pub name: String,
    /// Path to the compiled HLO text.
    pub path: PathBuf,
    /// Input signatures, in call order.
    pub inputs: Vec<TensorSig>,
    /// Output signatures.
    pub outputs: Vec<TensorSig>,
}

/// A parsed artifact directory.
#[derive(Clone, Debug)]
pub struct ArtifactDir {
    /// The directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Build fingerprint from the manifest.
    pub fingerprint: String,
    /// Entry points listed in the manifest.
    pub entries: Vec<Entry>,
}

impl ArtifactDir {
    /// Load and validate `dir/manifest.json`.
    pub fn open(dir: &Path) -> Result<ArtifactDir> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!("reading {} (run `make artifacts`)", manifest_path.display())
        })?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let fingerprint = j
            .get("fingerprint")
            .and_then(|f| f.as_str())
            .unwrap_or("unknown")
            .to_string();
        let mut entries = Vec::new();
        for e in j
            .get("entries")
            .and_then(|e| e.as_arr())
            .ok_or_else(|| anyhow!("manifest has no entries"))?
        {
            let name = e
                .get("name")
                .and_then(|n| n.as_str())
                .ok_or_else(|| anyhow!("entry missing name"))?
                .to_string();
            let file = e
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| anyhow!("entry {name} missing file"))?;
            let path = dir.join(file);
            if !path.exists() {
                bail!("artifact {} listed in manifest but missing on disk", path.display());
            }
            let sigs = |key: &str| -> Result<Vec<TensorSig>> {
                e.get(key)
                    .and_then(|x| x.as_arr())
                    .ok_or_else(|| anyhow!("entry missing {key}"))?
                    .iter()
                    .map(TensorSig::from_json)
                    .collect()
            };
            let (inputs, outputs) = (sigs("inputs")?, sigs("outputs")?);
            entries.push(Entry { name, path, inputs, outputs });
        }
        Ok(ArtifactDir { dir: dir.to_path_buf(), fingerprint, entries })
    }

    /// Default location: `$PSIM_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<ArtifactDir> {
        let dir = std::env::var("PSIM_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::open(Path::new(&dir))
    }

    /// Entry-point lookup by name.
    pub fn entry(&self, name: &str) -> Option<&Entry> {
        self.entries.iter().find(|e| e.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("psim_manifest_test_ok");
        write_manifest(
            &dir,
            r#"{"fingerprint":"abc","entries":[
                {"name":"f","file":"f.hlo.txt",
                 "inputs":[{"shape":[2,3],"dtype":"float32"}],
                 "outputs":[{"shape":[2],"dtype":"float32"}]}]}"#,
        );
        std::fs::write(dir.join("f.hlo.txt"), "HloModule f").unwrap();
        let a = ArtifactDir::open(&dir).unwrap();
        assert_eq!(a.fingerprint, "abc");
        let e = a.entry("f").unwrap();
        assert_eq!(e.inputs[0].shape, vec![2, 3]);
        assert_eq!(e.inputs[0].elements(), 6);
        assert!(a.entry("missing").is_none());
    }

    #[test]
    fn rejects_missing_file() {
        let dir = std::env::temp_dir().join("psim_manifest_test_missing");
        write_manifest(
            &dir,
            r#"{"fingerprint":"x","entries":[
                {"name":"g","file":"g.hlo.txt","inputs":[],"outputs":[]}]}"#,
        );
        let _ = std::fs::remove_file(dir.join("g.hlo.txt"));
        assert!(ArtifactDir::open(&dir).is_err());
    }

    #[test]
    fn rejects_absent_dir() {
        assert!(ArtifactDir::open(Path::new("/nonexistent/psim")).is_err());
    }
}
