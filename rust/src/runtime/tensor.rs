//! Host-side tensor: `f32`, row-major, shape-checked — the coordinator's
//! currency when talking to the PJRT runtime.

use anyhow::{bail, Result};

/// A dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    /// Dimensions, outermost first.
    pub shape: Vec<usize>,
    /// Row-major element data (`len == shape.product()`).
    pub data: Vec<f32>,
}

impl Tensor {
    /// A tensor from shape + data (lengths must agree).
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let want: usize = shape.iter().product();
        if want != data.len() {
            bail!("shape {:?} needs {} elements, got {}", shape, want, data.len());
        }
        Ok(Tensor { shape, data })
    }

    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// Deterministic synthetic tensor (He-style scale) from a seed.
    pub fn random(shape: &[usize], seed: u64, scale: f32) -> Tensor {
        let mut rng = crate::util::prng::Rng::new(seed);
        let mut data = vec![0.0f32; shape.iter().product()];
        rng.fill_f32(&mut data, scale);
        Tensor { shape: shape.to_vec(), data }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Convert to an XLA literal of the same shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }

    /// Build from an XLA literal (must be f32).
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Tensor::new(dims, data)
    }

    /// Simple order-dependent checksum used by tests/benches to compare
    /// runs without shipping an oracle to the Rust side.
    pub fn checksum(&self) -> f64 {
        self.data
            .iter()
            .enumerate()
            .map(|(i, &v)| v as f64 * ((i % 97) as f64 + 1.0))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_shape() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn zeros_and_random() {
        let z = Tensor::zeros(&[4, 4]);
        assert_eq!(z.len(), 16);
        assert!(z.data.iter().all(|&v| v == 0.0));
        let r1 = Tensor::random(&[4, 4], 7, 0.5);
        let r2 = Tensor::random(&[4, 4], 7, 0.5);
        assert_eq!(r1, r2);
        assert!(r1.data.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn checksum_discriminates() {
        let a = Tensor::random(&[8], 1, 1.0);
        let b = Tensor::random(&[8], 2, 1.0);
        assert_ne!(a.checksum(), b.checksum());
    }

    #[test]
    fn literal_roundtrip() {
        let t = Tensor::random(&[2, 3, 4], 42, 1.0);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }
}
