//! The PJRT execution client: compile HLO-text artifacts once, cache the
//! executables, execute with host tensors.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `PjRtClient::compile` -> `execute`.
//! Entry points were lowered with `return_tuple=True`, so results unwrap
//! with `to_tuple`.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::artifact::{ArtifactDir, Entry};
use super::tensor::Tensor;

/// Compiled-executable cache keyed by entry-point name.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts: ArtifactDir,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Cumulative execution statistics.
    pub execs: u64,
    /// Cumulative execution time, nanoseconds.
    pub exec_nanos: u128,
    /// Cumulative compile time, nanoseconds.
    pub compile_nanos: u128,
}

impl Runtime {
    /// Create a CPU PJRT client over an artifact directory.
    pub fn new(artifacts: ArtifactDir) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            artifacts,
            cache: HashMap::new(),
            execs: 0,
            exec_nanos: 0,
            compile_nanos: 0,
        })
    }

    /// Open `./artifacts` (or `$PSIM_ARTIFACTS`).
    pub fn open_default() -> Result<Runtime> {
        Runtime::new(ArtifactDir::open_default()?)
    }

    /// The PJRT platform name (e.g. `"cpu"`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The artifact directory this runtime serves.
    pub fn artifacts(&self) -> &ArtifactDir {
        &self.artifacts
    }

    /// Entry-point signature lookup.
    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.artifacts
            .entry(name)
            .ok_or_else(|| anyhow!("no artifact entry '{name}' (have: {:?})",
                self.artifacts.entries.iter().map(|e| &e.name).collect::<Vec<_>>()))
    }

    /// Compile (or fetch from cache) an entry point.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let entry = self.entry(name)?.clone();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            entry.path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", entry.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        self.compile_nanos += t0.elapsed().as_nanos();
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an entry point with shape-checked inputs.
    pub fn execute(&mut self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.load(name)?;
        let entry = self.entry(name)?;
        if inputs.len() != entry.inputs.len() {
            return Err(anyhow!(
                "{name}: expected {} inputs, got {}",
                entry.inputs.len(),
                inputs.len()
            ));
        }
        for (i, (t, sig)) in inputs.iter().zip(&entry.inputs).enumerate() {
            if t.shape != sig.shape {
                return Err(anyhow!(
                    "{name}: input {i} shape {:?} != expected {:?}",
                    t.shape,
                    sig.shape
                ));
            }
        }
        let n_outputs = entry.outputs.len();

        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let exe = self.cache.get(name).expect("loaded above");
        let t0 = Instant::now();
        let result = exe.execute::<xla::Literal>(&literals)?;
        let lit = result[0][0].to_literal_sync()?;
        self.exec_nanos += t0.elapsed().as_nanos();
        self.execs += 1;

        // return_tuple=True: unwrap the tuple into output tensors.
        let parts = lit.to_tuple()?;
        if parts.len() != n_outputs {
            return Err(anyhow!(
                "{name}: expected {} outputs, got {}",
                n_outputs,
                parts.len()
            ));
        }
        parts.iter().map(Tensor::from_literal).collect()
    }

    /// Mean execution latency so far.
    pub fn mean_exec_micros(&self) -> f64 {
        if self.execs == 0 {
            return 0.0;
        }
        self.exec_nanos as f64 / self.execs as f64 / 1000.0
    }

    /// Prepare a constant tensor once for repeated execution.
    ///
    /// Perf note (EXPERIMENTS.md §Perf RT-1): serving re-converts the
    /// model weights to XLA literals on every call through `execute`
    /// (two full copies per tensor); preparing them once removes that
    /// per-request work. True device-buffer residency via `execute_b`
    /// was attempted and *reverted*: xla_extension 0.5.1 corrupts output
    /// buffer metadata on the second buffer-based execution
    /// (`Check failed: literal.size_bytes() == b->size()` in
    /// abstract_tfrt_cpu_buffer.cc) — see §Perf RT-1's negative result.
    pub fn prepare(&self, t: &Tensor) -> Result<PreparedTensor> {
        Ok(PreparedTensor { lit: t.to_literal()?, shape: t.shape.clone() })
    }

    /// Execute with a mix of fresh host inputs and pre-prepared constant
    /// inputs. `inputs[i]` must match the entry's i-th signature.
    pub fn execute_mixed(&mut self, name: &str, inputs: &[Input<'_>]) -> Result<Vec<Tensor>> {
        self.load(name)?;
        let entry = self.entry(name)?;
        if inputs.len() != entry.inputs.len() {
            return Err(anyhow!(
                "{name}: expected {} inputs, got {}",
                entry.inputs.len(),
                inputs.len()
            ));
        }
        for (i, (input, sig)) in inputs.iter().zip(&entry.inputs).enumerate() {
            let shape = match input {
                Input::Host(t) => &t.shape,
                Input::Prepared(d) => &d.shape,
            };
            if shape != &sig.shape {
                return Err(anyhow!(
                    "{name}: input {i} shape {:?} != expected {:?}",
                    shape,
                    sig.shape
                ));
            }
        }
        let n_outputs = entry.outputs.len();

        // Convert only the fresh host inputs; prepared literals are reused.
        let mut owned: Vec<Option<xla::Literal>> = Vec::with_capacity(inputs.len());
        for input in inputs {
            owned.push(match input {
                Input::Host(t) => Some(t.to_literal()?),
                Input::Prepared(_) => None,
            });
        }
        let args: Vec<&xla::Literal> = inputs
            .iter()
            .zip(&owned)
            .map(|(input, up)| match (input, up) {
                (Input::Prepared(d), _) => &d.lit,
                (Input::Host(_), Some(l)) => l,
                (Input::Host(_), None) => unreachable!("converted above"),
            })
            .collect();
        let exe = self.cache.get(name).expect("loaded above");
        let t0 = Instant::now();
        let result = exe.execute::<&xla::Literal>(&args)?;
        let lit = result[0][0].to_literal_sync()?;
        self.exec_nanos += t0.elapsed().as_nanos();
        self.execs += 1;

        let parts = lit.to_tuple()?;
        if parts.len() != n_outputs {
            return Err(anyhow!("{name}: expected {n_outputs} outputs, got {}", parts.len()));
        }
        parts.iter().map(Tensor::from_literal).collect()
    }
}

/// A constant input converted to XLA-literal form once (see
/// [`Runtime::prepare`]).
pub struct PreparedTensor {
    lit: xla::Literal,
    shape: Vec<usize>,
}

impl PreparedTensor {
    /// The prepared tensor's shape.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }
}

/// One input to [`Runtime::execute_mixed`].
pub enum Input<'a> {
    /// Fresh per-call host data (converted on the spot).
    Host(&'a Tensor),
    /// Pre-converted constant (weights).
    Prepared(&'a PreparedTensor),
}

// Tests that need real artifacts live in rust/tests/runtime_artifacts.rs
// (they require `make artifacts` to have run; unit tests here stay
// artifact-free).
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_lookup_error_is_informative() {
        let dir = std::env::temp_dir().join("psim_rt_empty");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"fingerprint":"x","entries":[]}"#).unwrap();
        let art = ArtifactDir::open(&dir).unwrap();
        let mut rt = Runtime::new(art).unwrap();
        let err = rt.execute("nope", &[]).unwrap_err().to_string();
        assert!(err.contains("no artifact entry"), "{err}");
    }
}
