//! Banked SRAM model with per-bank access counters.
//!
//! Addresses are interleaved across banks at word granularity. The model
//! tracks access counts (the paper's power proxy) and bank conflicts under
//! a simple simultaneous-access model: a burst of `E` elements spread over
//! `B` banks completes in `ceil(E/B)` bank cycles.

/// Region tags used for accounting (which tensor a access belongs to).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Region {
    Input,
    Weight,
    Psum,
}

impl Region {
    pub const ALL: [Region; 3] = [Region::Input, Region::Weight, Region::Psum];

    pub fn label(&self) -> &'static str {
        match self {
            Region::Input => "input",
            Region::Weight => "weight",
            Region::Psum => "psum",
        }
    }
}

/// Per-region, per-direction access counters over a banked array.
#[derive(Clone, Debug)]
pub struct Sram {
    banks: usize,
    reads: [u64; 3],
    writes: [u64; 3],
    bank_cycles: u64,
}

impl Sram {
    /// `banks` must be a power of two (word-interleaved banking).
    pub fn new(banks: usize) -> Self {
        assert!(banks > 0 && banks.is_power_of_two(), "banks must be a power of two");
        Sram { banks, reads: [0; 3], writes: [0; 3], bank_cycles: 0 }
    }

    fn idx(region: Region) -> usize {
        match region {
            Region::Input => 0,
            Region::Weight => 1,
            Region::Psum => 2,
        }
    }

    /// Record a read burst of `elements` from `region`.
    pub fn read(&mut self, region: Region, elements: u64) {
        self.reads[Self::idx(region)] += elements;
        self.bank_cycles += elements.div_ceil(self.banks as u64);
    }

    /// Record a write burst of `elements` into `region`.
    pub fn write(&mut self, region: Region, elements: u64) {
        self.writes[Self::idx(region)] += elements;
        self.bank_cycles += elements.div_ceil(self.banks as u64);
    }

    /// Total reads of a region.
    pub fn reads(&self, region: Region) -> u64 {
        self.reads[Self::idx(region)]
    }

    /// Total writes to a region.
    pub fn writes(&self, region: Region) -> u64 {
        self.writes[Self::idx(region)]
    }

    /// Every array access (read + write), all regions.
    pub fn total_accesses(&self) -> u64 {
        self.reads.iter().sum::<u64>() + self.writes.iter().sum::<u64>()
    }

    /// Bank-cycle occupancy (the array-side time model).
    pub fn bank_cycles(&self) -> u64 {
        self.bank_cycles
    }

    pub fn banks(&self) -> usize {
        self.banks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_per_region() {
        let mut s = Sram::new(8);
        s.read(Region::Input, 100);
        s.read(Region::Input, 50);
        s.write(Region::Psum, 30);
        assert_eq!(s.reads(Region::Input), 150);
        assert_eq!(s.writes(Region::Psum), 30);
        assert_eq!(s.reads(Region::Psum), 0);
        assert_eq!(s.total_accesses(), 180);
    }

    #[test]
    fn bank_cycles_ceil() {
        let mut s = Sram::new(8);
        s.read(Region::Weight, 17); // ceil(17/8) = 3
        assert_eq!(s.bank_cycles(), 3);
        s.write(Region::Psum, 8); // +1
        assert_eq!(s.bank_cycles(), 4);
    }

    #[test]
    fn more_banks_fewer_cycles() {
        let mut a = Sram::new(4);
        let mut b = Sram::new(32);
        a.read(Region::Input, 1000);
        b.read(Region::Input, 1000);
        assert!(b.bank_cycles() < a.bank_cycles());
    }

    #[test]
    #[should_panic]
    fn rejects_non_power_of_two() {
        Sram::new(12);
    }
}
