//! Banked SRAM model with per-bank access counters.
//!
//! Addresses are interleaved across banks at word granularity. The model
//! tracks access counts (the paper's power proxy) and bank conflicts under
//! a simple simultaneous-access model: a burst of `E` elements spread over
//! `B` banks completes in `ceil(E/B)` bank cycles.

/// Region tags used for accounting (which tensor a access belongs to).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Region {
    /// Input-activation region.
    Input,
    /// Weight region.
    Weight,
    /// Partial-sum / output region.
    Psum,
}

impl Region {
    /// Every region, in counter order.
    pub const ALL: [Region; 3] = [Region::Input, Region::Weight, Region::Psum];

    /// Stable lowercase name.
    pub fn label(&self) -> &'static str {
        match self {
            Region::Input => "input",
            Region::Weight => "weight",
            Region::Psum => "psum",
        }
    }
}

/// Bank word width (bits) the width-aware occupancy model packs into —
/// matches the 32-bit reference the energy constants are normalized to.
pub const BANK_WORD_BITS: usize = 32;

/// Per-region, per-direction access counters over a banked array.
#[derive(Clone, Debug)]
pub struct Sram {
    banks: usize,
    /// Optional per-region element widths (bits), indexed like `reads`.
    /// `None` = the legacy one-element-per-bank-word model.
    region_bits: Option<[usize; 3]>,
    reads: [u64; 3],
    writes: [u64; 3],
    bank_cycles: u64,
}

impl Sram {
    /// `banks` must be a power of two (word-interleaved banking). One
    /// element occupies one bank word (the width-agnostic legacy model).
    pub fn new(banks: usize) -> Self {
        assert!(banks > 0 && banks.is_power_of_two(), "banks must be a power of two");
        Sram { banks, region_bits: None, reads: [0; 3], writes: [0; 3], bank_cycles: 0 }
    }

    /// A width-aware array: a burst of `E` elements of `b` bits occupies
    /// `ceil(E·b / 32)` bank words, so wide psums take proportionally
    /// more bank cycles than narrow activations. `widths` is
    /// `[input, weight, psum]` bits (the psum region also holds the
    /// quantized ofmap — its banks are provisioned for the wide case).
    pub fn with_region_bits(banks: usize, widths: [usize; 3]) -> Self {
        let mut s = Sram::new(banks);
        s.region_bits = Some(widths);
        s
    }

    /// An empty array with this one's configuration (per-layer reset).
    pub fn fresh(&self) -> Self {
        Sram { reads: [0; 3], writes: [0; 3], bank_cycles: 0, ..*self }
    }

    fn idx(region: Region) -> usize {
        match region {
            Region::Input => 0,
            Region::Weight => 1,
            Region::Psum => 2,
        }
    }

    /// Bank cycles one burst of `elements` in `region` occupies.
    fn burst_cycles(&self, region: Region, elements: u64) -> u64 {
        let words = match self.region_bits {
            None => elements,
            Some(widths) => {
                (elements * widths[Self::idx(region)] as u64).div_ceil(BANK_WORD_BITS as u64)
            }
        };
        words.div_ceil(self.banks as u64)
    }

    /// Record a read burst of `elements` from `region`.
    pub fn read(&mut self, region: Region, elements: u64) {
        self.reads[Self::idx(region)] += elements;
        self.bank_cycles += self.burst_cycles(region, elements);
    }

    /// Record a write burst of `elements` into `region`.
    pub fn write(&mut self, region: Region, elements: u64) {
        self.writes[Self::idx(region)] += elements;
        self.bank_cycles += self.burst_cycles(region, elements);
    }

    /// Total reads of a region.
    pub fn reads(&self, region: Region) -> u64 {
        self.reads[Self::idx(region)]
    }

    /// Total writes to a region.
    pub fn writes(&self, region: Region) -> u64 {
        self.writes[Self::idx(region)]
    }

    /// Every array access (read + write), all regions.
    pub fn total_accesses(&self) -> u64 {
        self.reads.iter().sum::<u64>() + self.writes.iter().sum::<u64>()
    }

    /// Bank-cycle occupancy (the array-side time model).
    pub fn bank_cycles(&self) -> u64 {
        self.bank_cycles
    }

    /// The bank count.
    pub fn banks(&self) -> usize {
        self.banks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_per_region() {
        let mut s = Sram::new(8);
        s.read(Region::Input, 100);
        s.read(Region::Input, 50);
        s.write(Region::Psum, 30);
        assert_eq!(s.reads(Region::Input), 150);
        assert_eq!(s.writes(Region::Psum), 30);
        assert_eq!(s.reads(Region::Psum), 0);
        assert_eq!(s.total_accesses(), 180);
    }

    #[test]
    fn bank_cycles_ceil() {
        let mut s = Sram::new(8);
        s.read(Region::Weight, 17); // ceil(17/8) = 3
        assert_eq!(s.bank_cycles(), 3);
        s.write(Region::Psum, 8); // +1
        assert_eq!(s.bank_cycles(), 4);
    }

    #[test]
    fn more_banks_fewer_cycles() {
        let mut a = Sram::new(4);
        let mut b = Sram::new(32);
        a.read(Region::Input, 1000);
        b.read(Region::Input, 1000);
        assert!(b.bank_cycles() < a.bank_cycles());
    }

    #[test]
    #[should_panic]
    fn rejects_non_power_of_two() {
        Sram::new(12);
    }

    #[test]
    fn width_aware_banking_charges_wide_regions_more() {
        // 8 banks of 32-bit words: 17 psum elements at 32b = 17 words
        // -> 3 cycles; 17 input elements at 8b = ceil(136/32) = 5 words
        // -> 1 cycle.
        let mut s = Sram::with_region_bits(8, [8, 8, 32]);
        s.read(Region::Psum, 17);
        assert_eq!(s.bank_cycles(), 3);
        s.read(Region::Input, 17);
        assert_eq!(s.bank_cycles(), 4);
        // counters stay in elements regardless of widths
        assert_eq!(s.reads(Region::Psum), 17);
        assert_eq!(s.reads(Region::Input), 17);
        // all-32-bit widths reproduce the legacy model exactly
        let mut wide = Sram::with_region_bits(8, [32, 32, 32]);
        let mut legacy = Sram::new(8);
        for e in [1u64, 7, 8, 9, 1000] {
            wide.read(Region::Weight, e);
            legacy.read(Region::Weight, e);
        }
        assert_eq!(wide.bank_cycles(), legacy.bank_cycles());
    }

    #[test]
    fn fresh_keeps_config_clears_counters() {
        let mut s = Sram::with_region_bits(8, [8, 8, 32]);
        s.read(Region::Psum, 100);
        let f = s.fresh();
        assert_eq!(f.total_accesses(), 0);
        assert_eq!(f.bank_cycles(), 0);
        assert_eq!(f.banks(), 8);
        // width config survives the reset
        let mut f2 = f;
        f2.read(Region::Psum, 17);
        assert_eq!(f2.bank_cycles(), 3);
    }
}
