//! The tiled loop-nest executor: runs Section II's partitioned convolution
//! on the modeled machine, emitting every interconnect transaction.
//!
//! Loop order (the paper's code listing, output-block outermost):
//!
//! ```text
//! for g in groups:
//!   for co_block in ceil(N_g / n):          # output-map partition
//!     for ci_block in ceil(M_g / m):        # input-map partition
//!       DMA-in  input tile  (m_eff planes)  -> Bi
//!       DMA-in  weight tile (n_eff x m_eff x K^2)
//!       compute Wo x Ho positions on the MAC array
//!       psum update:
//!         passive: [read psums] + write psums
//!         active:  write psums with Add/AddRelu sideband command
//! ```
//!
//! The per-transaction counts reproduce eqs. (2)–(3) *exactly* — that is
//! the simulator's contract with [`crate::analytics`], enforced by
//! `rust/tests/sim_vs_model.rs`.

use crate::analytics::bandwidth::ControllerMode;
use crate::analytics::partition::{partition_layer, Partition, Strategy};
use crate::models::{ConvLayer, Network};
use crate::util::mathx::ceil_div;

use super::controller::{MemController, MemOp};
use super::energy::EnergyModel;
use super::interconnect::{BusConfig, Interconnect};
use super::mac_array::MacArray;
use super::sram::Region;
use super::stats::SimStats;
use super::trace::{Event, Kind, Trace};

/// Full simulator configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// MAC budget `P`.
    pub p_macs: usize,
    /// Memory-controller capability.
    pub mode: ControllerMode,
    /// Partitioning strategy choosing `(m, n)` per layer.
    pub strategy: Strategy,
    /// Interconnect geometry.
    pub bus: BusConfig,
    /// SRAM banks (power of two).
    pub banks: usize,
    /// Energy constants.
    pub energy: EnergyModel,
    /// Trace capacity (0 = off).
    pub trace_cap: usize,
}

impl SimConfig {
    /// Defaults (16 B bus, 32 banks, default energy, tracing off)
    /// with the given compute/controller/policy knobs.
    pub fn new(p_macs: usize, mode: ControllerMode, strategy: Strategy) -> Self {
        SimConfig {
            p_macs,
            mode,
            strategy,
            bus: BusConfig::default(),
            banks: 32,
            energy: EnergyModel::default(),
            trace_cap: 0,
        }
    }
}

/// Result of simulating one layer (or a merged network run).
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Roll-up counters of the run.
    pub stats: SimStats,
    /// The partition the strategy chose (per layer; `None` for merged).
    pub partition: Option<Partition>,
    /// Transaction trace (empty unless `trace_cap > 0`).
    pub trace: Trace,
}

/// Simulate one layer under `cfg`. Every bus transaction is accounted;
/// activation traffic matches `analytics::layer_bandwidth` exactly.
pub fn simulate_layer(layer: &ConvLayer, cfg: &SimConfig) -> SimResult {
    let partition = partition_layer(layer, cfg.p_macs, cfg.strategy, cfg.mode);
    simulate_layer_with(layer, cfg, partition)
}

/// Simulate one layer with an explicit `(m, n)` tile.
pub fn simulate_layer_with(layer: &ConvLayer, cfg: &SimConfig, part: Partition) -> SimResult {
    let mut stats = SimStats::default();
    let mut trace = Trace::new(cfg.trace_cap);
    let mut bus = Interconnect::default();
    let mut ctrl = MemController::with_region_bits(cfg.mode, cfg.banks, cfg.bus.region_bits);
    let mac = MacArray::new(cfg.p_macs);
    // Per-region element widths (None = the uniform elem_bytes pricing).
    let rb = cfg.bus.region_bits;
    let input_bits = rb.map(|r| r.input);
    let weight_bits = rb.map(|r| r.weight);
    let psum_bits = rb.map(|r| r.psum);
    let ofmap_bits = rb.map(|r| r.ofmap);

    let mg = layer.m_per_group();
    let ng = layer.n_per_group();
    let (wo, ho) = (layer.wo(), layer.ho());
    let ci_blocks = ceil_div(mg, part.m);
    let co_blocks = ceil_div(ng, part.n);

    // Identical-groups fast path (EXPERIMENTS.md §Perf L3-2): every group
    // of a grouped conv runs the same (co, ci) schedule over the same
    // shapes, so we simulate ONE group and scale the counters by `g` —
    // exact, and turns depthwise layers (g up to 1152) from g full loop
    // nests into one. The per-group loop is kept only when tracing, so
    // traces still show group boundaries.
    let sim_groups = if cfg.trace_cap > 0 { layer.groups } else { 1 };
    for _g in 0..sim_groups {
        for co in 0..co_blocks {
            let n_eff = part.n.min(ng - co * part.n);
            for ci in 0..ci_blocks {
                let m_eff = part.m.min(mg - ci * part.m);
                let iter = (co * ci_blocks + ci) as u32;

                // --- input tile in (full input planes of the m_eff maps) ---
                let in_elems = (layer.wi * layer.hi * m_eff) as u64;
                bus.read_wide(&cfg.bus, in_elems, input_bits, &mut stats);
                ctrl.bus_read(Region::Input, in_elems, &mut stats);
                trace.record(Event {
                    iter,
                    kind: Kind::Read,
                    region: Region::Input,
                    elements: in_elems,
                    op: MemOp::Normal,
                });

                // --- weight tile in ---
                let w_elems = (n_eff * m_eff * layer.k * layer.k) as u64;
                bus.read_wide(&cfg.bus, w_elems, weight_bits, &mut stats);
                ctrl.bus_read(Region::Weight, w_elems, &mut stats);

                // --- compute ---
                stats.compute_cycles += mac.iteration_cycles(wo, ho);
                stats.macs += mac.iteration_macs(wo, ho, layer.k, m_eff, n_eff);

                // --- psum update ---
                let ps_elems = (wo * ho * n_eff) as u64;
                let first = ci == 0;
                let last = ci == ci_blocks - 1;
                // The final write of an accumulation chain carries the
                // quantized ofmap; every other crossing is psum-width
                // (see docs/MODEL.md §Byte-level model).
                let wbits = if last { ofmap_bits } else { psum_bits };
                if last {
                    stats.ofmap_writes += ps_elems;
                }
                match (cfg.mode, first) {
                    (_, true) => {
                        // First pass initializes; no previous psum exists.
                        bus.write_wide(&cfg.bus, ps_elems, wbits, MemOp::Init, &mut stats);
                        ctrl.bus_write(Region::Psum, ps_elems, MemOp::Init, &mut stats);
                        trace.record(Event {
                            iter,
                            kind: Kind::Write,
                            region: Region::Psum,
                            elements: ps_elems,
                            op: MemOp::Init,
                        });
                    }
                    (ControllerMode::Passive, false) => {
                        // Read-back over the bus, then write the update.
                        bus.read_wide(&cfg.bus, ps_elems, psum_bits, &mut stats);
                        ctrl.bus_read(Region::Psum, ps_elems, &mut stats);
                        trace.record(Event {
                            iter,
                            kind: Kind::Read,
                            region: Region::Psum,
                            elements: ps_elems,
                            op: MemOp::Normal,
                        });
                        bus.write_wide(&cfg.bus, ps_elems, wbits, MemOp::Normal, &mut stats);
                        ctrl.bus_write(Region::Psum, ps_elems, MemOp::Normal, &mut stats);
                        trace.record(Event {
                            iter,
                            kind: Kind::Write,
                            region: Region::Psum,
                            elements: ps_elems,
                            op: MemOp::Normal,
                        });
                    }
                    (ControllerMode::Active, false) => {
                        // Single write with a sideband command; the read
                        // happens inside the controller.
                        let op = if last { MemOp::AddRelu } else { MemOp::Add };
                        bus.write_wide(&cfg.bus, ps_elems, wbits, op, &mut stats);
                        ctrl.bus_write(Region::Psum, ps_elems, op, &mut stats);
                        trace.record(Event {
                            iter,
                            kind: Kind::Write,
                            region: Region::Psum,
                            elements: ps_elems,
                            op,
                        });
                    }
                }
            }
        }
        // Groups are independent accumulation domains.
        ctrl.finish_layer(&mut stats);
    }

    stats.bus_cycles = stats.bus_cycles.max(bus.busy_cycles());
    if sim_groups != layer.groups {
        stats.scale(layer.groups as u64 / sim_groups as u64);
    }
    // Surface ring-buffer truncation only when tracing is on: a disabled
    // trace "drops" every event by design, which is not a signal.
    if cfg.trace_cap > 0 {
        stats.trace_dropped = trace.dropped();
    }
    stats.energy_pj = match &cfg.bus.region_bits {
        Some(rb) => cfg.energy.energy_pj_wide(&stats, rb),
        None => cfg.energy.energy_pj(&stats),
    };
    SimResult { stats, partition: Some(part), trace }
}

/// Simulate every layer of a network and merge the counters.
pub fn simulate_network(net: &Network, cfg: &SimConfig) -> SimResult {
    simulate_network_detailed(net, cfg).0
}

/// Like [`simulate_network`], but also return each layer's individual
/// result — `psim simulate --trace` shows per-layer traces without
/// paying for a second full simulation pass.
pub fn simulate_network_detailed(net: &Network, cfg: &SimConfig) -> (SimResult, Vec<SimResult>) {
    let mut stats = SimStats::default();
    let mut bus_cycles = 0u64;
    let mut layers = Vec::with_capacity(net.layers.len());
    for layer in &net.layers {
        let r = simulate_layer(layer, cfg);
        bus_cycles += r.stats.bus_cycles;
        let mut s = r.stats;
        // bus_cycles must *sum* across layers (they run sequentially);
        // merge() sums everything already, but each layer's bus_cycles was
        // max()ed against SRAM occupancy inside — keep the sum.
        s.bus_cycles = 0;
        stats.merge(&s);
        layers.push(r);
    }
    stats.bus_cycles = bus_cycles;
    stats.energy_pj = match &cfg.bus.region_bits {
        Some(rb) => cfg.energy.energy_pj_wide(&stats, rb),
        None => cfg.energy.energy_pj(&stats),
    };
    (SimResult { stats, partition: None, trace: Trace::off() }, layers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::bandwidth::layer_bandwidth;

    fn conv3() -> ConvLayer {
        ConvLayer::new("conv3", 13, 13, 192, 384, 3, 1, 1)
    }

    #[test]
    fn matches_analytics_exactly_passive() {
        let l = conv3();
        let cfg = SimConfig::new(512, ControllerMode::Passive, Strategy::Optimal);
        let r = simulate_layer(&l, &cfg);
        let p = r.partition.unwrap();
        let bw = layer_bandwidth(&l, p.m, p.n, ControllerMode::Passive);
        assert_eq!(r.stats.input_reads as f64, bw.input);
        assert_eq!(r.stats.output_traffic() as f64, bw.output);
    }

    #[test]
    fn matches_analytics_exactly_active() {
        let l = conv3();
        let cfg = SimConfig::new(512, ControllerMode::Active, Strategy::Optimal);
        let r = simulate_layer(&l, &cfg);
        let p = r.partition.unwrap();
        let bw = layer_bandwidth(&l, p.m, p.n, ControllerMode::Active);
        assert_eq!(r.stats.input_reads as f64, bw.input);
        assert_eq!(r.stats.output_traffic() as f64, bw.output);
        // the reads the active controller absorbed:
        assert_eq!(r.stats.internal_psum_reads, r.stats.controller_adds);
        assert!(r.stats.psum_reads == 0);
    }

    #[test]
    fn non_divisor_partition_still_exact() {
        // m=9 does not divide 192 (ceil blocks, ragged tail); n=7 ragged.
        let l = conv3();
        let cfg = SimConfig::new(1 << 20, ControllerMode::Passive, Strategy::Optimal);
        let part = Partition { m: 9, n: 7 };
        let r = simulate_layer_with(&l, &cfg, part);
        let bw = layer_bandwidth(&l, 9, 7, ControllerMode::Passive);
        // Bi uses ceil(N/n) full-input passes; effective channel counts
        // make the last block smaller — totals must still match the
        // analytical ceil formulation on the output side, and the input
        // side re-reads all M maps per output block.
        assert_eq!(r.stats.input_reads as f64, bw.input);
        assert_eq!(r.stats.output_traffic() as f64, bw.output);
    }

    #[test]
    fn grouped_layer_sums_groups() {
        let dw = ConvLayer::grouped("dw", 56, 56, 64, 64, 3, 1, 1, 64);
        let cfg = SimConfig::new(512, ControllerMode::Passive, Strategy::Optimal);
        let r = simulate_layer(&dw, &cfg);
        let p = r.partition.unwrap();
        let bw = layer_bandwidth(&dw, p.m, p.n, ControllerMode::Passive);
        assert_eq!(r.stats.activation_traffic() as f64, bw.total());
    }

    #[test]
    fn relu_applied_once_per_output_element_active() {
        let l = conv3();
        let cfg = SimConfig::new(512, ControllerMode::Active, Strategy::Optimal);
        let r = simulate_layer(&l, &cfg);
        let p = r.partition.unwrap();
        // ReLU fires on the last ci block only -> once per output element,
        // unless the layer needed a single pass (then Init wrote it all).
        if ceil_div(l.m_per_group(), p.m) > 1 {
            assert_eq!(r.stats.controller_relus, l.output_activations());
        }
    }

    #[test]
    fn weights_counted_but_separate() {
        let l = conv3();
        let cfg = SimConfig::new(512, ControllerMode::Passive, Strategy::Optimal);
        let r = simulate_layer(&l, &cfg);
        let p = r.partition.unwrap();
        // Each (co, ci) iteration moves n_eff*m_eff*K^2 weights; with
        // divisor m and floor n the blocks are mostly uniform — just check
        // the total equals blocks x tile (ragged-aware lower bound).
        assert!(r.stats.weight_reads >= l.weights());
        assert!(!matches!(p.m, 0));
    }

    #[test]
    fn mac_count_is_layer_macs() {
        // MACs executed must equal the layer's true MAC count regardless
        // of partitioning (work is conserved).
        let l = conv3();
        for p in [512usize, 2048, 16384] {
            let cfg = SimConfig::new(p, ControllerMode::Passive, Strategy::Optimal);
            let r = simulate_layer(&l, &cfg);
            assert_eq!(r.stats.macs, l.macs(), "P={p}");
        }
    }

    #[test]
    fn network_run_sums_layers() {
        let net = crate::models::zoo::alexnet();
        let cfg = SimConfig::new(2048, ControllerMode::Active, Strategy::Optimal);
        let whole = simulate_network(&net, &cfg);
        let mut manual = 0u64;
        for l in &net.layers {
            manual += simulate_layer(l, &cfg).stats.activation_traffic();
        }
        assert_eq!(whole.stats.activation_traffic(), manual);
        assert_eq!(whole.stats.macs, net.total_macs());
        // the detailed variant merges to the same totals and keeps one
        // result per layer (the --trace path rides on this)
        let (whole2, layers) = simulate_network_detailed(&net, &cfg);
        assert_eq!(whole2.stats, whole.stats);
        assert_eq!(layers.len(), net.layers.len());
    }

    #[test]
    fn trace_dropped_surfaces_in_stats() {
        let l = conv3();
        let mut cfg = SimConfig::new(512, ControllerMode::Passive, Strategy::Optimal);
        cfg.trace_cap = 4;
        let r = simulate_layer(&l, &cfg);
        assert_eq!(r.stats.trace_dropped, r.trace.dropped());
        assert!(r.stats.trace_dropped > 0, "a 4-slot ring must overflow here");
        // tracing off: nothing is "lost", so nothing is reported
        let cfg_off = SimConfig::new(512, ControllerMode::Passive, Strategy::Optimal);
        assert_eq!(simulate_layer(&l, &cfg_off).stats.trace_dropped, 0);
    }

    #[test]
    fn ofmap_writes_are_one_per_output_element() {
        let l = conv3();
        for mode in ControllerMode::ALL {
            for p in [512usize, 1 << 22] {
                let cfg = SimConfig::new(p, mode, Strategy::Optimal);
                let r = simulate_layer(&l, &cfg);
                assert_eq!(r.stats.ofmap_writes, l.output_activations(), "{mode:?} P={p}");
                assert!(r.stats.ofmap_writes <= r.stats.psum_writes);
            }
        }
        // grouped convs scale the sub-count with g like everything else
        let dw = ConvLayer::grouped("dw", 56, 56, 64, 64, 3, 1, 1, 64);
        let cfg = SimConfig::new(512, ControllerMode::Passive, Strategy::Optimal);
        assert_eq!(simulate_layer(&dw, &cfg).stats.ofmap_writes, dw.output_activations());
    }

    #[test]
    fn byte_traffic_matches_analytical_byte_model() {
        use crate::analytics::bandwidth::layer_bandwidth_bytes;
        use crate::models::DataTypes;
        let l = conv3();
        let dt = DataTypes::parse("8:8:32:8").unwrap();
        for mode in ControllerMode::ALL {
            for part in [Partition { m: 12, n: 4 }, Partition { m: 9, n: 7 }] {
                let mut cfg = SimConfig::new(1 << 20, mode, Strategy::Optimal);
                cfg.bus = crate::sim::interconnect::BusConfig::with_datatypes(&dt);
                let r = simulate_layer_with(&l, &cfg, part);
                let bw = layer_bandwidth_bytes(&l, part.m, part.n, mode, &dt);
                assert_eq!(r.stats.activation_bytes(&dt), bw.activations(), "{part:?} {mode:?}");
                assert_eq!(r.stats.weight_bytes(&dt) as u64, r.stats.weight_reads);
            }
        }
    }

    #[test]
    fn one_byte_bus_beats_equal_total_bytes() {
        // With a 1-byte bus every beat carries exactly one byte, so the
        // simulator's width-aware beat count must equal the analytical
        // byte totals (activations + weights) exactly.
        use crate::analytics::bandwidth::layer_bandwidth_bytes;
        use crate::models::DataTypes;
        let l = conv3();
        let dt = DataTypes::parse("8:8:32:8").unwrap();
        for mode in ControllerMode::ALL {
            let mut cfg = SimConfig::new(512, mode, Strategy::Optimal);
            cfg.bus = crate::sim::interconnect::BusConfig::with_datatypes(&dt);
            cfg.bus.bus_bytes = 1;
            let r = simulate_layer(&l, &cfg);
            let p = r.partition.unwrap();
            let bw = layer_bandwidth_bytes(&l, p.m, p.n, mode, &dt);
            assert_eq!(r.stats.bus_beats as f64, bw.total(), "{mode:?}");
        }
    }

    #[test]
    fn default_bus_is_width_agnostic() {
        // No region widths configured: beats, energy and counters are
        // the legacy uniform-elem_bytes model (pinned goldens depend on
        // this).
        let l = conv3();
        let cfg = SimConfig::new(512, ControllerMode::Passive, Strategy::Optimal);
        assert!(cfg.bus.region_bits.is_none());
        let r = simulate_layer(&l, &cfg);
        // ofmap_writes is a new sub-count but doesn't change any total
        assert_eq!(r.stats.activation_traffic(), {
            let p = r.partition.unwrap();
            layer_bandwidth(&l, p.m, p.n, ControllerMode::Passive).total() as u64
        });
    }

    #[test]
    fn trace_records_psum_protocol() {
        let l = ConvLayer::new("c", 8, 8, 8, 8, 3, 1, 1);
        let mut cfg = SimConfig::new(72, ControllerMode::Active, Strategy::Optimal);
        cfg.trace_cap = 1024;
        let r = simulate_layer(&l, &cfg);
        let evs = r.trace.events();
        // first psum event is Init, subsequent are Add/AddRelu
        let psums: Vec<_> =
            evs.iter().filter(|e| e.region == Region::Psum).collect();
        assert!(psums[0].op == MemOp::Init);
        assert!(psums.iter().skip(1).all(|e| e.op.is_accumulate() || e.op == MemOp::Init));
    }
}
