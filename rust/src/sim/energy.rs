//! Per-access energy model.
//!
//! The paper's closing argument is that fewer memory accesses means less
//! power. We attach first-order per-event energies (45 nm CACTI-class
//! ratios, normalized to a 32-bit SRAM read = 5 pJ; the *ratios* are what
//! matter, as with the bandwidth model). Interconnect transfers are priced
//! several times an SRAM access, consistent with the paper's preference
//! for keeping psum updates inside the controller.

/// Energy cost constants in picojoules per event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EnergyModel {
    /// SRAM array read, per element.
    pub sram_read_pj: f64,
    /// SRAM array write, per element.
    pub sram_write_pj: f64,
    /// Interconnect transfer, per data beat (bus-width word).
    pub bus_beat_pj: f64,
    /// One MAC operation.
    pub mac_pj: f64,
    /// Controller-internal add (active mode), per element.
    pub ctrl_add_pj: f64,
    /// Controller-internal ReLU (active mode), per element.
    pub ctrl_relu_pj: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            sram_read_pj: 5.0,
            sram_write_pj: 5.5,
            bus_beat_pj: 20.0,
            mac_pj: 0.9,
            ctrl_add_pj: 0.4,
            ctrl_relu_pj: 0.1,
        }
    }
}

impl EnergyModel {
    /// Energy of a whole run given its counters.
    pub fn energy_pj(&self, s: &crate::sim::stats::SimStats) -> f64 {
        // Every element that crossed the bus also touched the SRAM array
        // (read on its way out, write on its way in); internal psum reads
        // touch the array only.
        let sram_reads =
            s.input_reads + s.psum_reads + s.weight_reads + s.internal_psum_reads;
        let sram_writes = s.psum_writes;
        sram_reads as f64 * self.sram_read_pj
            + sram_writes as f64 * self.sram_write_pj
            + s.bus_beats as f64 * self.bus_beat_pj
            + s.macs as f64 * self.mac_pj
            + s.controller_adds as f64 * self.ctrl_add_pj
            + s.controller_relus as f64 * self.ctrl_relu_pj
    }

    /// Width-aware energy: per-element SRAM and controller costs scale
    /// linearly with the element's width relative to the 32-bit reference
    /// the constants are normalized to (first-order CACTI-style scaling —
    /// a 32-bit psum read costs 4× an 8-bit activation read). Bus energy
    /// is per **beat**, and beats are already width-aware when the
    /// scheduler prices regions via
    /// [`RegionBits`](crate::sim::interconnect::RegionBits), so it needs
    /// no extra factor. With every region at 32 bits this is exactly
    /// [`EnergyModel::energy_pj`].
    pub fn energy_pj_wide(
        &self,
        s: &crate::sim::stats::SimStats,
        rb: &crate::sim::interconnect::RegionBits,
    ) -> f64 {
        let w = |bits: usize| bits as f64 / 32.0;
        let read_cost = (s.input_reads as f64 * w(rb.input)
            + s.weight_reads as f64 * w(rb.weight)
            + (s.psum_reads + s.internal_psum_reads) as f64 * w(rb.psum))
            * self.sram_read_pj;
        let write_cost = ((s.psum_writes - s.ofmap_writes) as f64 * w(rb.psum)
            + s.ofmap_writes as f64 * w(rb.ofmap))
            * self.sram_write_pj;
        read_cost
            + write_cost
            + s.bus_beats as f64 * self.bus_beat_pj
            + s.macs as f64 * self.mac_pj
            + s.controller_adds as f64 * w(rb.psum) * self.ctrl_add_pj
            + s.controller_relus as f64 * w(rb.psum) * self.ctrl_relu_pj
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::stats::SimStats;

    #[test]
    fn zero_run_zero_energy() {
        assert_eq!(EnergyModel::default().energy_pj(&SimStats::default()), 0.0);
    }

    #[test]
    fn active_controller_saves_energy_for_same_work() {
        let e = EnergyModel::default();
        // Passive: psum crosses the bus twice (read + write).
        let passive = SimStats {
            psum_reads: 1000,
            psum_writes: 1000,
            bus_beats: 2000,
            ..Default::default()
        };
        // Active: read stays internal; only writes cross the bus.
        let active = SimStats {
            psum_writes: 1000,
            internal_psum_reads: 1000,
            controller_adds: 1000,
            bus_beats: 1000,
            ..Default::default()
        };
        assert!(e.energy_pj(&active) < e.energy_pj(&passive));
    }

    #[test]
    fn wide_energy_scales_with_region_widths() {
        use crate::sim::interconnect::RegionBits;
        let e = EnergyModel::default();
        let s = SimStats {
            input_reads: 100,
            psum_reads: 50,
            psum_writes: 60,
            ofmap_writes: 10,
            weight_reads: 40,
            bus_beats: 7,
            ..Default::default()
        };
        // all-32-bit regions reproduce the uniform model exactly
        let r32 = RegionBits { input: 32, weight: 32, psum: 32, ofmap: 32 };
        assert!((e.energy_pj_wide(&s, &r32) - e.energy_pj(&s)).abs() < 1e-9);
        // narrowing activations to 8 bits cuts their SRAM cost 4x
        let r8 = RegionBits { input: 8, weight: 8, psum: 32, ofmap: 8 };
        assert!(e.energy_pj_wide(&s, &r8) < e.energy_pj_wide(&s, &r32));
        let expect_reads = (100.0 * 0.25 + 40.0 * 0.25 + 50.0 * 1.0) * e.sram_read_pj;
        let expect_writes = (50.0 * 1.0 + 10.0 * 0.25) * e.sram_write_pj;
        let expect = expect_reads + expect_writes + 7.0 * e.bus_beat_pj;
        assert!((e.energy_pj_wide(&s, &r8) - expect).abs() < 1e-9);
    }

    #[test]
    fn energy_scales_linearly() {
        let e = EnergyModel::default();
        let s1 = SimStats { input_reads: 100, bus_beats: 100, ..Default::default() };
        let s2 = SimStats { input_reads: 200, bus_beats: 200, ..Default::default() };
        let (e1, e2) = (e.energy_pj(&s1), e.energy_pj(&s2));
        assert!((e2 - 2.0 * e1).abs() < 1e-9);
    }
}
