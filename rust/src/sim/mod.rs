//! Event-level accelerator simulator — the substrate the paper assumes.
//!
//! The paper's analysis is first-order arithmetic; a credible system needs
//! the machine it describes. This module models the Fig. 1 SoC:
//!
//! ```text
//!   +----------------+   AXI4-like bus    +-------------------+
//!   | Compute engine |<==================>| SRAM controller   |
//!   |  (MAC array,   |   AW/W/B/AR/R +    |  passive | ACTIVE |
//!   |   tile sched.) |   AWUSER sideband  |  + SRAM banks     |
//!   +----------------+                    +-------------------+
//! ```
//!
//! * [`mac_array`] — the P-MAC compute engine: occupancy and cycle model.
//! * [`sram`] — banked SRAM with per-bank read/write counters.
//! * [`controller`] — the memory controller; the **active** variant folds
//!   `Add`/`AddRelu` commands (from the AWUSER sideband) into a local
//!   read-modify-write so psum read-backs never cross the interconnect.
//! * [`interconnect`] — the bus: channel beats, sideband signals, cycle
//!   accounting and contention.
//! * [`scheduler`] — executes the tiled loop nest of Section II for a
//!   layer partitioned as `(m, n)`, emitting every transaction.
//! * [`dma`] — burst planner turning tile requests into bus bursts.
//! * [`energy`] — per-access energy model (the paper's power argument).
//! * [`stats`] — roll-up counters; the quantities Tables I/II tabulate.
//! * [`trace`] — optional transaction trace for debugging/golden tests.
//!
//! The headline invariant, enforced by `rust/tests/sim_vs_model.rs` and
//! unit tests here: **simulated activation traffic equals the analytical
//! model of [`crate::analytics`] exactly** for every (layer, partition,
//! controller mode).

pub mod controller;
pub mod dma;
pub mod energy;
pub mod interconnect;
pub mod mac_array;
pub mod scheduler;
pub mod sram;
pub mod stats;
pub mod trace;

pub use controller::{MemController, MemOp};
pub use interconnect::{BusConfig, RegionBits};
pub use scheduler::{simulate_layer, simulate_network, SimConfig, SimResult};
pub use stats::SimStats;
