//! The compute engine: a `P`-MAC array executing one `(m, n)` tile per
//! pass over the output plane.
//!
//! Occupancy model: the array sustains `K^2 * m * n` useful MACs/cycle
//! (the tile's footprint), so one iteration over a `Wo x Ho` output block
//! takes `Wo*Ho` cycles regardless of how full the array is — utilization
//! is `K^2*m*n / P`, which is exactly the PE-utilization the paper says
//! partitioning trades against bandwidth.

/// Per-iteration compute accounting.
#[derive(Clone, Copy, Debug)]
pub struct MacArray {
    p_macs: usize,
}

impl MacArray {
    /// An array of `p_macs` multipliers.
    pub fn new(p_macs: usize) -> Self {
        assert!(p_macs > 0);
        MacArray { p_macs }
    }

    /// The array's MAC budget `P`.
    pub fn p_macs(&self) -> usize {
        self.p_macs
    }

    /// Cycles to sweep one tile iteration: `Wo*Ho` output positions, one
    /// column of the systolic array per position per cycle.
    pub fn iteration_cycles(&self, wo: usize, ho: usize) -> u64 {
        (wo * ho) as u64
    }

    /// Useful MACs in one iteration: every output position accumulates
    /// `K^2 * m_eff` products for each of `n_eff` output maps.
    pub fn iteration_macs(
        &self,
        wo: usize,
        ho: usize,
        k: usize,
        m_eff: usize,
        n_eff: usize,
    ) -> u64 {
        (wo * ho) as u64 * (k * k * m_eff * n_eff) as u64
    }

    /// Whether a tile fits the array (eq. 1).
    pub fn fits(&self, k: usize, m: usize, n: usize) -> bool {
        k * k * m * n <= self.p_macs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_is_eq1() {
        let a = MacArray::new(512);
        assert!(a.fits(3, 8, 7)); // 9*56 = 504
        assert!(!a.fits(3, 8, 8)); // 9*64 = 576
        assert!(a.fits(11, 3, 1)); // 363
    }

    #[test]
    fn cycle_and_mac_accounting() {
        let a = MacArray::new(1024);
        assert_eq!(a.iteration_cycles(13, 13), 169);
        assert_eq!(a.iteration_macs(13, 13, 3, 12, 4), 169 * 9 * 48);
    }

    #[test]
    #[should_panic]
    fn zero_macs_rejected() {
        MacArray::new(0);
    }
}
