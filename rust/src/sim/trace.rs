//! Optional transaction trace — a ring buffer of the most recent bus
//! transactions, used by golden tests and `psim simulate --trace`.

use super::controller::MemOp;
use super::sram::Region;

/// One recorded bus transaction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    /// Iteration index (co_block * ci_blocks + ci_block).
    pub iter: u32,
    /// Read or write.
    pub kind: Kind,
    /// Which tensor region it touched.
    pub region: Region,
    /// Elements moved.
    pub elements: u64,
    /// Sideband command carried (writes).
    pub op: MemOp,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
/// Transaction direction.
pub enum Kind {
    /// A read burst (AR + R).
    Read,
    /// A write burst (AW + W + B).
    Write,
}

/// Bounded trace recorder (keeps the last `cap` events).
#[derive(Clone, Debug)]
pub struct Trace {
    cap: usize,
    events: Vec<Event>,
    dropped: u64,
}

impl Trace {
    /// A ring keeping the last `cap` events (0 = disabled).
    pub fn new(cap: usize) -> Self {
        Trace { cap, events: Vec::new(), dropped: 0 }
    }

    /// A disabled trace that records nothing.
    pub fn off() -> Self {
        Trace::new(0)
    }

    /// Record one event, evicting the oldest when full.
    pub fn record(&mut self, e: Event) {
        if self.cap == 0 {
            self.dropped += 1;
            return;
        }
        if self.events.len() == self.cap {
            self.events.remove(0);
            self.dropped += 1;
        }
        self.events.push(e);
    }

    /// The retained events, oldest first.
    pub fn events(&self) -> &[Event] {
        &self.events
    }

    /// Events evicted (or discarded while disabled).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Render a human-readable dump.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        if self.dropped > 0 {
            out.push_str(&format!("... {} earlier events dropped ...\n", self.dropped));
        }
        for e in &self.events {
            out.push_str(&format!(
                "iter {:>5} {:5} {:6} {:>8} elems  op={:?}\n",
                e.iter,
                match e.kind {
                    Kind::Read => "READ",
                    Kind::Write => "WRITE",
                },
                e.region.label(),
                e.elements,
                e.op
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(iter: u32) -> Event {
        Event { iter, kind: Kind::Read, region: Region::Input, elements: 8, op: MemOp::Normal }
    }

    #[test]
    fn ring_buffer_keeps_latest() {
        let mut t = Trace::new(3);
        for i in 0..5 {
            t.record(ev(i));
        }
        assert_eq!(t.events().len(), 3);
        assert_eq!(t.events()[0].iter, 2);
        assert_eq!(t.dropped(), 2);
    }

    #[test]
    fn off_records_nothing() {
        let mut t = Trace::off();
        t.record(ev(0));
        assert!(t.events().is_empty());
        assert_eq!(t.dropped(), 1);
    }

    #[test]
    fn dump_mentions_drops() {
        let mut t = Trace::new(1);
        t.record(ev(0));
        t.record(ev(1));
        assert!(t.dump().contains("1 earlier events dropped"));
    }
}
