//! DMA burst planner: turns tile-shaped tensor requests into interconnect
//! bursts. Tensors are stored channel-major (CHW), so a tile of `c`
//! channels over the full `W x H` plane is `c` contiguous runs — one burst
//! chain per channel, subject to the bus's max burst length.

use super::interconnect::{BusConfig, Interconnect};

/// A planned transfer: total elements and the burst count it needs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Transfer {
    pub elements: u64,
    pub bursts: u64,
}

/// Plan reading/writing `channels` full planes of `w*h` elements.
pub fn plane_transfer(cfg: &BusConfig, channels: usize, w: usize, h: usize) -> Transfer {
    let per_chan = (w * h) as u64;
    let bursts_per_chan = Interconnect::bursts(cfg, per_chan);
    Transfer {
        elements: per_chan * channels as u64,
        bursts: bursts_per_chan * channels as u64,
    }
}

/// Plan a weight-tile transfer: `n * m * k * k` contiguous elements.
pub fn weight_transfer(cfg: &BusConfig, m: usize, n: usize, k: usize) -> Transfer {
    let elements = (n * m * k * k) as u64;
    Transfer { elements, bursts: Interconnect::bursts(cfg, elements) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plane_transfer_counts() {
        let cfg = BusConfig::default(); // 8 elems/beat, 256 beats/burst
        let t = plane_transfer(&cfg, 4, 13, 13);
        assert_eq!(t.elements, 4 * 169);
        // 169 elems = 22 beats -> 1 burst per channel
        assert_eq!(t.bursts, 4);
    }

    #[test]
    fn long_planes_split() {
        let cfg = BusConfig::default();
        // 224*224 = 50176 elems = 6272 beats -> ceil(6272/256) = 25 bursts
        let t = plane_transfer(&cfg, 1, 224, 224);
        assert_eq!(t.bursts, 25);
    }

    #[test]
    fn weight_tiles_are_one_chain() {
        let cfg = BusConfig::default();
        let t = weight_transfer(&cfg, 12, 4, 3);
        assert_eq!(t.elements, 432);
        assert_eq!(t.bursts, 1); // 54 beats
    }
}
