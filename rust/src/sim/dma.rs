//! DMA burst planner: turns tile-shaped tensor requests into interconnect
//! bursts. Tensors are stored channel-major (CHW), so a tile of `c`
//! channels over the full `W x H` plane is `c` contiguous runs — one burst
//! chain per channel, subject to the bus's max burst length.

use super::interconnect::{BusConfig, Interconnect};

/// A planned transfer: total elements and the burst count it needs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Transfer {
    /// Elements moved.
    pub elements: u64,
    /// Bus bursts the transfer splits into.
    pub bursts: u64,
}

/// Plan reading/writing `channels` full planes of `w*h` elements.
pub fn plane_transfer(cfg: &BusConfig, channels: usize, w: usize, h: usize) -> Transfer {
    plane_transfer_wide(cfg, channels, w, h, None)
}

/// Width-aware [`plane_transfer`]: elements are `bits` wide (`None` =
/// the bus's uniform `elem_bytes`). Wide psum planes split into more
/// bursts than narrow activation planes of the same shape.
pub fn plane_transfer_wide(
    cfg: &BusConfig,
    channels: usize,
    w: usize,
    h: usize,
    bits: Option<usize>,
) -> Transfer {
    let per_chan = (w * h) as u64;
    let bursts_per_chan = Interconnect::bursts_wide(cfg, per_chan, bits);
    Transfer {
        elements: per_chan * channels as u64,
        bursts: bursts_per_chan * channels as u64,
    }
}

/// Plan a weight-tile transfer: `n * m * k * k` contiguous elements.
pub fn weight_transfer(cfg: &BusConfig, m: usize, n: usize, k: usize) -> Transfer {
    weight_transfer_wide(cfg, m, n, k, None)
}

/// Width-aware [`weight_transfer`] (`None` = uniform `elem_bytes`).
pub fn weight_transfer_wide(
    cfg: &BusConfig,
    m: usize,
    n: usize,
    k: usize,
    bits: Option<usize>,
) -> Transfer {
    let elements = (n * m * k * k) as u64;
    Transfer { elements, bursts: Interconnect::bursts_wide(cfg, elements, bits) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plane_transfer_counts() {
        let cfg = BusConfig::default(); // 8 elems/beat, 256 beats/burst
        let t = plane_transfer(&cfg, 4, 13, 13);
        assert_eq!(t.elements, 4 * 169);
        // 169 elems = 22 beats -> 1 burst per channel
        assert_eq!(t.bursts, 4);
    }

    #[test]
    fn long_planes_split() {
        let cfg = BusConfig::default();
        // 224*224 = 50176 elems = 6272 beats -> ceil(6272/256) = 25 bursts
        let t = plane_transfer(&cfg, 1, 224, 224);
        assert_eq!(t.bursts, 25);
    }

    #[test]
    fn weight_tiles_are_one_chain() {
        let cfg = BusConfig::default();
        let t = weight_transfer(&cfg, 12, 4, 3);
        assert_eq!(t.elements, 432);
        assert_eq!(t.bursts, 1); // 54 beats
    }

    #[test]
    fn wide_psum_planes_need_more_bursts() {
        let cfg = BusConfig::default(); // 16B bus, 256 beats/burst
        // 224x224 plane: at 8 bits 50176 B = 3136 beats -> 13 bursts;
        // at 32 bits 200704 B = 12544 beats -> 49 bursts.
        let narrow = plane_transfer_wide(&cfg, 1, 224, 224, Some(8));
        let wide = plane_transfer_wide(&cfg, 1, 224, 224, Some(32));
        assert_eq!(narrow.bursts, 13);
        assert_eq!(wide.bursts, 49);
        assert_eq!(narrow.elements, wide.elements);
        // None reproduces the uniform pricing exactly
        assert_eq!(plane_transfer_wide(&cfg, 3, 13, 13, None), plane_transfer(&cfg, 3, 13, 13));
        assert_eq!(
            weight_transfer_wide(&cfg, 12, 4, 3, None),
            weight_transfer(&cfg, 12, 4, 3)
        );
    }
}
