//! The SRAM memory controller — passive, or *active* per Section III.
//!
//! The active controller accepts a command on the write channel's
//! sideband (AXI4 `awuser`): [`MemOp::Add`] makes it read the stored
//! partial sum, add the incoming data, and write back — all inside the
//! controller, so the read never crosses the interconnect.
//! [`MemOp::AddRelu`] additionally applies the activation on the final
//! accumulation (the paper's "Activation" offload). [`MemOp::Normal`] is
//! a plain store; [`MemOp::Init`] is a store that also marks the region
//! initialized (guards against accumulate-before-init bugs).

use super::interconnect::RegionBits;
use super::sram::{Region, Sram};
use super::stats::SimStats;
use crate::analytics::bandwidth::ControllerMode;

/// Sideband command accompanying a write burst (AXI4 `awuser` encoding).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MemOp {
    /// Plain write.
    Normal,
    /// First psum write of an accumulation chain.
    Init,
    /// controller-side read-add-write (active mode).
    Add,
    /// Add, then apply ReLU — final accumulation of a layer.
    AddRelu,
}

impl MemOp {
    /// Encoded AWUSER word (2 bits used; modeled as one sideband word).
    pub fn encode(&self) -> u8 {
        match self {
            MemOp::Normal => 0b00,
            MemOp::Init => 0b01,
            MemOp::Add => 0b10,
            MemOp::AddRelu => 0b11,
        }
    }

    /// Decode an AWUSER word back into a [`MemOp`].
    pub fn decode(bits: u8) -> Option<MemOp> {
        match bits & 0b11 {
            0b00 => Some(MemOp::Normal),
            0b01 => Some(MemOp::Init),
            0b10 => Some(MemOp::Add),
            _ => Some(MemOp::AddRelu),
        }
    }

    /// Does this op require controller-side arithmetic?
    pub fn is_accumulate(&self) -> bool {
        matches!(self, MemOp::Add | MemOp::AddRelu)
    }
}

/// The memory controller in front of the SRAM banks.
#[derive(Clone, Debug)]
pub struct MemController {
    mode: ControllerMode,
    sram: Sram,
    psum_initialized: bool,
}

impl MemController {
    /// A controller over a width-agnostic banked array.
    pub fn new(mode: ControllerMode, banks: usize) -> Self {
        MemController { mode, sram: Sram::new(banks), psum_initialized: false }
    }

    /// A controller whose array charges bank cycles per region width
    /// (`None` = the legacy width-agnostic model). The psum region is
    /// provisioned at psum width — the physically wide banks are exactly
    /// what makes keeping psum round-trips local worthwhile.
    pub fn with_region_bits(mode: ControllerMode, banks: usize, rb: Option<RegionBits>) -> Self {
        let sram = match rb {
            None => Sram::new(banks),
            Some(rb) => Sram::with_region_bits(banks, [rb.input, rb.weight, rb.psum]),
        };
        MemController { mode, sram, psum_initialized: false }
    }

    /// The controller's capability.
    pub fn mode(&self) -> ControllerMode {
        self.mode
    }

    /// Handle a read request arriving over the interconnect.
    /// Returns the element count that crossed the bus (== `elements`).
    pub fn bus_read(&mut self, region: Region, elements: u64, stats: &mut SimStats) -> u64 {
        self.sram.read(region, elements);
        match region {
            Region::Input => stats.input_reads += elements,
            Region::Weight => stats.weight_reads += elements,
            Region::Psum => {
                assert!(
                    self.psum_initialized,
                    "psum read before any write — scheduler bug"
                );
                stats.psum_reads += elements;
            }
        }
        elements
    }

    /// Handle a write burst arriving over the interconnect with a sideband
    /// command. Panics if an accumulate op reaches a passive controller —
    /// the scheduler must not issue commands the hardware lacks.
    pub fn bus_write(
        &mut self,
        region: Region,
        elements: u64,
        op: MemOp,
        stats: &mut SimStats,
    ) {
        match op {
            MemOp::Normal | MemOp::Init => {
                self.sram.write(region, elements);
                if region == Region::Psum {
                    stats.psum_writes += elements;
                    self.psum_initialized = true;
                }
            }
            MemOp::Add | MemOp::AddRelu => {
                assert_eq!(
                    self.mode,
                    ControllerMode::Active,
                    "accumulate command sent to a passive controller"
                );
                assert_eq!(region, Region::Psum, "accumulate only defined for psums");
                assert!(self.psum_initialized, "accumulate before init");
                // Internal read-modify-write: the read hits the array but
                // not the interconnect — the paper's saved bandwidth.
                self.sram.read(region, elements);
                self.sram.write(region, elements);
                stats.internal_psum_reads += elements;
                stats.psum_writes += elements;
                stats.controller_adds += elements;
                if op == MemOp::AddRelu {
                    stats.controller_relus += elements;
                }
            }
        }
    }

    /// Finish a layer: fold the SRAM-side counters into `stats` and reset
    /// per-layer state.
    pub fn finish_layer(&mut self, stats: &mut SimStats) {
        stats.sram_accesses += self.sram.total_accesses();
        // array occupancy folds into the bus-side time model downstream
        stats.bus_cycles = stats.bus_cycles.max(self.sram.bank_cycles());
        self.sram = self.sram.fresh();
        self.psum_initialized = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        for op in [MemOp::Normal, MemOp::Init, MemOp::Add, MemOp::AddRelu] {
            assert_eq!(MemOp::decode(op.encode()), Some(op));
        }
    }

    #[test]
    fn active_add_keeps_read_off_the_bus() {
        let mut c = MemController::new(ControllerMode::Active, 8);
        let mut s = SimStats::default();
        c.bus_write(Region::Psum, 100, MemOp::Init, &mut s);
        c.bus_write(Region::Psum, 100, MemOp::Add, &mut s);
        assert_eq!(s.psum_reads, 0); // nothing crossed the bus as a read
        assert_eq!(s.internal_psum_reads, 100);
        assert_eq!(s.psum_writes, 200);
        assert_eq!(s.controller_adds, 100);
    }

    #[test]
    fn passive_roundtrips_over_the_bus() {
        let mut c = MemController::new(ControllerMode::Passive, 8);
        let mut s = SimStats::default();
        c.bus_write(Region::Psum, 100, MemOp::Init, &mut s);
        c.bus_read(Region::Psum, 100, &mut s);
        c.bus_write(Region::Psum, 100, MemOp::Normal, &mut s);
        assert_eq!(s.psum_reads, 100);
        assert_eq!(s.psum_writes, 200);
        assert_eq!(s.internal_psum_reads, 0);
    }

    #[test]
    #[should_panic(expected = "accumulate command sent to a passive controller")]
    fn passive_rejects_add() {
        let mut c = MemController::new(ControllerMode::Passive, 8);
        let mut s = SimStats::default();
        c.bus_write(Region::Psum, 10, MemOp::Init, &mut s);
        c.bus_write(Region::Psum, 10, MemOp::Add, &mut s);
    }

    #[test]
    #[should_panic(expected = "accumulate before init")]
    fn add_requires_init() {
        let mut c = MemController::new(ControllerMode::Active, 8);
        let mut s = SimStats::default();
        c.bus_write(Region::Psum, 10, MemOp::Add, &mut s);
    }

    #[test]
    fn relu_counted_once_on_final_pass() {
        let mut c = MemController::new(ControllerMode::Active, 8);
        let mut s = SimStats::default();
        c.bus_write(Region::Psum, 50, MemOp::Init, &mut s);
        c.bus_write(Region::Psum, 50, MemOp::Add, &mut s);
        c.bus_write(Region::Psum, 50, MemOp::AddRelu, &mut s);
        assert_eq!(s.controller_relus, 50);
        assert_eq!(s.controller_adds, 100);
    }

    #[test]
    fn finish_layer_accumulates_and_resets() {
        let mut c = MemController::new(ControllerMode::Active, 8);
        let mut s = SimStats::default();
        c.bus_write(Region::Psum, 100, MemOp::Init, &mut s);
        c.finish_layer(&mut s);
        assert_eq!(s.sram_accesses, 100);
        // after reset, accumulate-before-init fires again
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut s2 = SimStats::default();
            c.bus_write(Region::Psum, 1, MemOp::Add, &mut s2);
        }));
        assert!(r.is_err());
    }
}
