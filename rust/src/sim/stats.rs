//! Roll-up counters for a simulation run. Units are *elements*
//! (activations/weights) for traffic counters — the unit the paper
//! tabulates — with byte/beat/cycle/energy derived views.

use crate::models::DataTypes;

/// Counters accumulated while simulating one layer or a whole network.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SimStats {
    /// Input activations read across the interconnect (eq. 2's `B_i`).
    pub input_reads: u64,
    /// Partial sums read across the interconnect (passive mode only).
    pub psum_reads: u64,
    /// Partial sums / outputs written across the interconnect.
    pub psum_writes: u64,
    /// Final (quantized) output writes — the last write of each
    /// accumulation chain. A **sub-count** of `psum_writes`, split out so
    /// byte accounting can price final writes at ofmap width and the
    /// rest at psum width; never added to element totals.
    pub ofmap_writes: u64,
    /// Weight elements read across the interconnect.
    pub weight_reads: u64,
    /// Reads the *active* controller performed internally (these hit the
    /// SRAM array but never the interconnect — the paper's saved traffic).
    pub internal_psum_reads: u64,
    /// Additions folded into the controller (active mode).
    pub controller_adds: u64,
    /// ReLU activations folded into the controller (active mode).
    pub controller_relus: u64,
    /// Data beats that crossed the interconnect.
    pub bus_beats: u64,
    /// Address/command handshakes on the interconnect.
    pub bus_transactions: u64,
    /// Sideband (AWUSER) command words carried.
    pub sideband_words: u64,
    /// MAC operations executed.
    pub macs: u64,
    /// Compute-engine cycles (MAC array occupancy model).
    pub compute_cycles: u64,
    /// Interconnect busy cycles (beat count / channel width model).
    pub bus_cycles: u64,
    /// SRAM accesses (reads + writes, incl. controller-internal ones).
    pub sram_accesses: u64,
    /// Trace events evicted by the bounded ring buffer (0 when tracing is
    /// disabled — an off trace loses nothing worth reporting). Surfaced
    /// so `psim simulate --trace` shows truncation instead of silently
    /// capping.
    pub trace_dropped: u64,
    /// Energy estimate in picojoules.
    pub energy_pj: f64,
}

impl SimStats {
    /// Activation traffic that crossed the interconnect — the quantity
    /// Tables I/II report (`B_i + B_o`). Weights excluded, as in the paper.
    pub fn activation_traffic(&self) -> u64 {
        self.input_reads + self.psum_reads + self.psum_writes
    }

    /// Output-side traffic (`B_o`): psum reads + writes on the bus.
    pub fn output_traffic(&self) -> u64 {
        self.psum_reads + self.psum_writes
    }

    /// Activation traffic in **bytes** under a [`DataTypes`] precision:
    /// inputs at ifmap width, intermediate psum crossings at psum width,
    /// final writes at ofmap width. Agrees exactly with
    /// [`layer_bandwidth_bytes`](crate::analytics::bandwidth::layer_bandwidth_bytes)
    /// for the same partition (pinned by `rust/tests/precision_model.rs`),
    /// and equals [`SimStats::activation_traffic`] under the default
    /// uniform one-byte precision.
    pub fn activation_bytes(&self, dt: &DataTypes) -> f64 {
        debug_assert!(self.ofmap_writes <= self.psum_writes);
        self.input_reads as f64 * dt.ifmap_bytes()
            + (self.psum_reads + self.psum_writes - self.ofmap_writes) as f64 * dt.psum_bytes()
            + self.ofmap_writes as f64 * dt.ofmap_bytes()
    }

    /// Weight traffic in bytes under a [`DataTypes`] precision.
    pub fn weight_bytes(&self, dt: &DataTypes) -> f64 {
        self.weight_reads as f64 * dt.weight_bytes()
    }

    /// Total wall-clock cycles under the max(compute, bus) overlap model.
    pub fn total_cycles(&self) -> u64 {
        self.compute_cycles.max(self.bus_cycles)
    }

    /// MAC-array utilization in [0, 1]: useful MACs per issued capacity.
    pub fn mac_utilization(&self, p_macs: usize) -> f64 {
        if self.compute_cycles == 0 {
            return 0.0;
        }
        self.macs as f64 / (self.compute_cycles as f64 * p_macs as f64)
    }

    /// Scale every counter by `f` — used by the scheduler's identical-
    /// groups fast path (a grouped conv's `g` groups are indistinguishable
    /// accumulation domains, so one simulated group times `g` is exact).
    /// `energy_pj` is intentionally untouched: it is derived *after*
    /// scaling by the energy model.
    pub fn scale(&mut self, f: u64) {
        self.input_reads *= f;
        self.psum_reads *= f;
        self.psum_writes *= f;
        self.ofmap_writes *= f;
        self.weight_reads *= f;
        self.internal_psum_reads *= f;
        self.controller_adds *= f;
        self.controller_relus *= f;
        self.bus_beats *= f;
        self.bus_transactions *= f;
        self.sideband_words *= f;
        self.macs *= f;
        self.compute_cycles *= f;
        self.bus_cycles *= f;
        self.sram_accesses *= f;
        self.trace_dropped *= f;
    }

    /// Merge another run's counters into this one.
    pub fn merge(&mut self, other: &SimStats) {
        self.input_reads += other.input_reads;
        self.psum_reads += other.psum_reads;
        self.psum_writes += other.psum_writes;
        self.ofmap_writes += other.ofmap_writes;
        self.weight_reads += other.weight_reads;
        self.internal_psum_reads += other.internal_psum_reads;
        self.controller_adds += other.controller_adds;
        self.controller_relus += other.controller_relus;
        self.bus_beats += other.bus_beats;
        self.bus_transactions += other.bus_transactions;
        self.sideband_words += other.sideband_words;
        self.macs += other.macs;
        self.compute_cycles += other.compute_cycles;
        self.bus_cycles += other.bus_cycles;
        self.sram_accesses += other.sram_accesses;
        self.trace_dropped += other.trace_dropped;
        self.energy_pj += other.energy_pj;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_adds_counters() {
        let mut a =
            SimStats { input_reads: 10, psum_writes: 5, energy_pj: 1.5, ..Default::default() };
        let b = SimStats { input_reads: 3, psum_reads: 2, energy_pj: 0.5, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.input_reads, 13);
        assert_eq!(a.psum_reads, 2);
        assert_eq!(a.psum_writes, 5);
        assert!((a.energy_pj - 2.0).abs() < 1e-12);
    }

    #[test]
    fn traffic_views() {
        let s = SimStats {
            input_reads: 100,
            psum_reads: 40,
            psum_writes: 50,
            weight_reads: 7,
            ..Default::default()
        };
        assert_eq!(s.activation_traffic(), 190);
        assert_eq!(s.output_traffic(), 90);
    }

    #[test]
    fn activation_bytes_prices_regions_independently() {
        let dt = DataTypes::parse("8:8:32:8").unwrap();
        let s = SimStats {
            input_reads: 100,
            psum_reads: 30,
            psum_writes: 40,  // 10 of which are final ofmap writes
            ofmap_writes: 10,
            weight_reads: 8,
            ..Default::default()
        };
        // 100*1 + (30 + 40 - 10)*4 + 10*1 = 350
        assert_eq!(s.activation_bytes(&dt), 350.0);
        assert_eq!(s.weight_bytes(&dt), 8.0);
        // default precision: bytes == elements
        assert_eq!(s.activation_bytes(&DataTypes::default()), s.activation_traffic() as f64);
    }

    #[test]
    fn utilization_bounds() {
        let s = SimStats { macs: 512 * 100, compute_cycles: 100, ..Default::default() };
        assert!((s.mac_utilization(512) - 1.0).abs() < 1e-12);
        assert_eq!(SimStats::default().mac_utilization(512), 0.0);
    }

    #[test]
    fn overlap_cycle_model() {
        let s = SimStats { compute_cycles: 10, bus_cycles: 25, ..Default::default() };
        assert_eq!(s.total_cycles(), 25);
    }
}
