//! AXI4-like interconnect model.
//!
//! Five channels (AW/W/B/AR/R) with a configurable data width. We model
//! throughput, not per-beat timing: a burst of `E` elements of `elem_bytes`
//! each takes `ceil(E*elem_bytes / bus_bytes)` data beats plus one
//! address handshake; write bursts additionally carry one AWUSER sideband
//! word (the active-controller command — the paper's point is that this
//! costs *no extra data bandwidth* because user signals ride the existing
//! infrastructure). Read and write channels are independent (full-duplex),
//! so bus occupancy is the max of the two directions.

use super::controller::MemOp;
use super::stats::SimStats;

/// Interconnect configuration.
#[derive(Clone, Copy, Debug)]
pub struct BusConfig {
    /// Data bytes per beat (AXI data-bus width), e.g. 16 = 128-bit.
    pub bus_bytes: usize,
    /// Bytes per element (activation/weight), e.g. 2 = fp16/int16.
    pub elem_bytes: usize,
    /// Max beats per burst (AXI4: 256). Longer transfers split.
    pub max_burst_beats: usize,
}

impl Default for BusConfig {
    fn default() -> Self {
        BusConfig { bus_bytes: 16, elem_bytes: 2, max_burst_beats: 256 }
    }
}

/// Tracks channel occupancy for one simulation.
#[derive(Clone, Debug, Default)]
pub struct Interconnect {
    read_beats: u64,
    write_beats: u64,
}

impl Interconnect {
    /// Beats needed to move `elements`.
    pub fn beats(cfg: &BusConfig, elements: u64) -> u64 {
        (elements * cfg.elem_bytes as u64).div_ceil(cfg.bus_bytes as u64)
    }

    /// Transactions (bursts) needed to move `elements` given max burst len.
    pub fn bursts(cfg: &BusConfig, elements: u64) -> u64 {
        Self::beats(cfg, elements).div_ceil(cfg.max_burst_beats as u64).max(
            if elements == 0 { 0 } else { 1 },
        )
    }

    /// Account a read burst (AR + R beats).
    pub fn read(&mut self, cfg: &BusConfig, elements: u64, stats: &mut SimStats) {
        let beats = Self::beats(cfg, elements);
        self.read_beats += beats;
        stats.bus_beats += beats;
        stats.bus_transactions += Self::bursts(cfg, elements);
    }

    /// Account a write burst (AW + W beats + B), carrying `op` on AWUSER.
    pub fn write(&mut self, cfg: &BusConfig, elements: u64, op: MemOp, stats: &mut SimStats) {
        let beats = Self::beats(cfg, elements);
        self.write_beats += beats;
        stats.bus_beats += beats;
        let bursts = Self::bursts(cfg, elements);
        stats.bus_transactions += bursts;
        // One sideband command word per burst; Normal writes don't need
        // a command (the controller defaults to store).
        if op != MemOp::Normal {
            stats.sideband_words += bursts;
        }
    }

    /// Bus busy cycles: channels are independent, so the max direction.
    pub fn busy_cycles(&self) -> u64 {
        self.read_beats.max(self.write_beats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> BusConfig {
        BusConfig::default() // 16B bus, 2B elements -> 8 elems/beat
    }

    #[test]
    fn beats_round_up() {
        assert_eq!(Interconnect::beats(&cfg(), 8), 1);
        assert_eq!(Interconnect::beats(&cfg(), 9), 2);
        assert_eq!(Interconnect::beats(&cfg(), 0), 0);
    }

    #[test]
    fn bursts_split_at_max_len() {
        // 256 beats/burst * 8 elems/beat = 2048 elements per burst
        assert_eq!(Interconnect::bursts(&cfg(), 2048), 1);
        assert_eq!(Interconnect::bursts(&cfg(), 2049), 2);
        assert_eq!(Interconnect::bursts(&cfg(), 0), 0);
    }

    #[test]
    fn sideband_rides_writes_only_when_commanded() {
        let mut ic = Interconnect::default();
        let mut s = SimStats::default();
        ic.write(&cfg(), 100, MemOp::Normal, &mut s);
        assert_eq!(s.sideband_words, 0);
        ic.write(&cfg(), 100, MemOp::Add, &mut s);
        assert_eq!(s.sideband_words, 1);
        ic.read(&cfg(), 100, &mut s);
        assert_eq!(s.sideband_words, 1); // reads never carry commands
    }

    #[test]
    fn full_duplex_occupancy() {
        let mut ic = Interconnect::default();
        let mut s = SimStats::default();
        ic.read(&cfg(), 800, &mut s); // 100 beats
        ic.write(&cfg(), 240, MemOp::Normal, &mut s); // 30 beats
        assert_eq!(ic.busy_cycles(), 100); // max(100, 30)
    }
}
