//! AXI4-like interconnect model.
//!
//! Five channels (AW/W/B/AR/R) with a configurable data width. We model
//! throughput, not per-beat timing: a burst of `E` elements of `elem_bytes`
//! each takes `ceil(E*elem_bytes / bus_bytes)` data beats plus one
//! address handshake; write bursts additionally carry one AWUSER sideband
//! word (the active-controller command — the paper's point is that this
//! costs *no extra data bandwidth* because user signals ride the existing
//! infrastructure). Read and write channels are independent (full-duplex),
//! so bus occupancy is the max of the two directions.

use crate::models::DataTypes;

use super::controller::MemOp;
use super::stats::SimStats;

/// Per-region element widths in **bits** for width-aware beat packing —
/// the simulator-side mirror of [`DataTypes`]: wide psums take more beats
/// per element than narrow activations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RegionBits {
    /// Input-activation element width.
    pub input: usize,
    /// Weight element width.
    pub weight: usize,
    /// Partial-sum element width.
    pub psum: usize,
    /// Final (quantized) output element width.
    pub ofmap: usize,
}

impl RegionBits {
    /// Widths from a [`DataTypes`] precision.
    pub fn from_datatypes(dt: &DataTypes) -> RegionBits {
        RegionBits {
            input: dt.ifmap_bits,
            weight: dt.weight_bits,
            psum: dt.psum_bits,
            ofmap: dt.ofmap_bits,
        }
    }

    /// The inverse of [`RegionBits::from_datatypes`].
    pub fn to_datatypes(&self) -> DataTypes {
        DataTypes {
            ifmap_bits: self.input,
            weight_bits: self.weight,
            psum_bits: self.psum,
            ofmap_bits: self.ofmap,
        }
    }
}

/// Interconnect configuration.
#[derive(Clone, Copy, Debug)]
pub struct BusConfig {
    /// Data bytes per beat (AXI data-bus width), e.g. 16 = 128-bit.
    pub bus_bytes: usize,
    /// Bytes per element (activation/weight), e.g. 2 = fp16/int16 — the
    /// uniform pricing used when `region_bits` is unset.
    pub elem_bytes: usize,
    /// Max beats per burst (AXI4: 256). Longer transfers split.
    pub max_burst_beats: usize,
    /// Per-region element widths. `None` (the default) prices every
    /// region at `elem_bytes` — byte-identical to the pre-precision
    /// simulator; `Some` packs each region's elements at its own width
    /// so beat counts agree with the analytical byte model.
    pub region_bits: Option<RegionBits>,
}

impl Default for BusConfig {
    fn default() -> Self {
        BusConfig { bus_bytes: 16, elem_bytes: 2, max_burst_beats: 256, region_bits: None }
    }
}

impl BusConfig {
    /// A default-geometry bus pricing each region at the widths of `dt`.
    pub fn with_datatypes(dt: &DataTypes) -> BusConfig {
        BusConfig { region_bits: Some(RegionBits::from_datatypes(dt)), ..BusConfig::default() }
    }
}

/// Tracks channel occupancy for one simulation.
#[derive(Clone, Debug, Default)]
pub struct Interconnect {
    read_beats: u64,
    write_beats: u64,
}

impl Interconnect {
    /// Beats needed to move `elements` at the uniform `elem_bytes` width.
    pub fn beats(cfg: &BusConfig, elements: u64) -> u64 {
        (elements * cfg.elem_bytes as u64).div_ceil(cfg.bus_bytes as u64)
    }

    /// Beats needed to move `elements` of `bits`-wide data (`None` falls
    /// back to the uniform [`Interconnect::beats`] pricing). Exact:
    /// `ceil(elements·bits / (bus_bytes·8))`.
    pub fn beats_wide(cfg: &BusConfig, elements: u64, bits: Option<usize>) -> u64 {
        match bits {
            None => Self::beats(cfg, elements),
            Some(b) => (elements * b as u64).div_ceil(cfg.bus_bytes as u64 * 8),
        }
    }

    /// Transactions (bursts) needed to move `elements` given max burst len.
    pub fn bursts(cfg: &BusConfig, elements: u64) -> u64 {
        Self::bursts_wide(cfg, elements, None)
    }

    /// Width-aware burst count (`None` = uniform `elem_bytes` pricing).
    pub fn bursts_wide(cfg: &BusConfig, elements: u64, bits: Option<usize>) -> u64 {
        Self::beats_wide(cfg, elements, bits)
            .div_ceil(cfg.max_burst_beats as u64)
            .max(if elements == 0 { 0 } else { 1 })
    }

    /// Account a read burst (AR + R beats) at the uniform width.
    pub fn read(&mut self, cfg: &BusConfig, elements: u64, stats: &mut SimStats) {
        self.read_wide(cfg, elements, None, stats);
    }

    /// Account a read burst of `bits`-wide elements.
    pub fn read_wide(
        &mut self,
        cfg: &BusConfig,
        elements: u64,
        bits: Option<usize>,
        stats: &mut SimStats,
    ) {
        let beats = Self::beats_wide(cfg, elements, bits);
        self.read_beats += beats;
        stats.bus_beats += beats;
        stats.bus_transactions += Self::bursts_wide(cfg, elements, bits);
    }

    /// Account a write burst (AW + W beats + B), carrying `op` on AWUSER,
    /// at the uniform width.
    pub fn write(&mut self, cfg: &BusConfig, elements: u64, op: MemOp, stats: &mut SimStats) {
        self.write_wide(cfg, elements, None, op, stats);
    }

    /// Account a write burst of `bits`-wide elements with a sideband op.
    pub fn write_wide(
        &mut self,
        cfg: &BusConfig,
        elements: u64,
        bits: Option<usize>,
        op: MemOp,
        stats: &mut SimStats,
    ) {
        let beats = Self::beats_wide(cfg, elements, bits);
        self.write_beats += beats;
        stats.bus_beats += beats;
        let bursts = Self::bursts_wide(cfg, elements, bits);
        stats.bus_transactions += bursts;
        // One sideband command word per burst; Normal writes don't need
        // a command (the controller defaults to store).
        if op != MemOp::Normal {
            stats.sideband_words += bursts;
        }
    }

    /// Bus busy cycles: channels are independent, so the max direction.
    pub fn busy_cycles(&self) -> u64 {
        self.read_beats.max(self.write_beats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> BusConfig {
        BusConfig::default() // 16B bus, 2B elements -> 8 elems/beat
    }

    #[test]
    fn beats_round_up() {
        assert_eq!(Interconnect::beats(&cfg(), 8), 1);
        assert_eq!(Interconnect::beats(&cfg(), 9), 2);
        assert_eq!(Interconnect::beats(&cfg(), 0), 0);
    }

    #[test]
    fn bursts_split_at_max_len() {
        // 256 beats/burst * 8 elems/beat = 2048 elements per burst
        assert_eq!(Interconnect::bursts(&cfg(), 2048), 1);
        assert_eq!(Interconnect::bursts(&cfg(), 2049), 2);
        assert_eq!(Interconnect::bursts(&cfg(), 0), 0);
    }

    #[test]
    fn sideband_rides_writes_only_when_commanded() {
        let mut ic = Interconnect::default();
        let mut s = SimStats::default();
        ic.write(&cfg(), 100, MemOp::Normal, &mut s);
        assert_eq!(s.sideband_words, 0);
        ic.write(&cfg(), 100, MemOp::Add, &mut s);
        assert_eq!(s.sideband_words, 1);
        ic.read(&cfg(), 100, &mut s);
        assert_eq!(s.sideband_words, 1); // reads never carry commands
    }

    #[test]
    fn wide_beats_pack_per_region_width() {
        let cfg = cfg(); // 16B bus = 128 bits/beat
        // 32-bit psums: 4 elements per beat
        assert_eq!(Interconnect::beats_wide(&cfg, 4, Some(32)), 1);
        assert_eq!(Interconnect::beats_wide(&cfg, 5, Some(32)), 2);
        // 8-bit activations: 16 per beat
        assert_eq!(Interconnect::beats_wide(&cfg, 16, Some(8)), 1);
        // 24-bit (3-byte) psums: floor(128/24) is fractional packing —
        // the model packs bits, not elements: 6 elements = 144 bits = 2 beats
        assert_eq!(Interconnect::beats_wide(&cfg, 6, Some(24)), 2);
        // None falls back to the uniform elem_bytes pricing exactly
        assert_eq!(Interconnect::beats_wide(&cfg, 9, None), Interconnect::beats(&cfg, 9));
        // elem_bytes=2 equals bits=16 pricing
        assert_eq!(Interconnect::beats_wide(&cfg, 9, Some(16)), Interconnect::beats(&cfg, 9));
    }

    #[test]
    fn with_datatypes_sets_region_widths() {
        let dt = crate::models::DataTypes::parse("8:8:32:8").unwrap();
        let cfg = BusConfig::with_datatypes(&dt);
        let rb = cfg.region_bits.unwrap();
        assert_eq!((rb.input, rb.weight, rb.psum, rb.ofmap), (8, 8, 32, 8));
        assert!(BusConfig::default().region_bits.is_none());
    }

    #[test]
    fn full_duplex_occupancy() {
        let mut ic = Interconnect::default();
        let mut s = SimStats::default();
        ic.read(&cfg(), 800, &mut s); // 100 beats
        ic.write(&cfg(), 240, MemOp::Normal, &mut s); // 30 beats
        assert_eq!(ic.busy_cycles(), 100); // max(100, 30)
    }
}
