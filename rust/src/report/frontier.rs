//! Frontier rendering: the `psim explore --table` markdown table and the
//! one-line run summary shared by the CLI and serve logs.

use crate::dse::explore::ExploreResult;
use crate::util::tablefmt::{mact, pct, Table};

/// One row per frontier point: scope, design point, all four objectives.
pub fn frontier_table(result: &ExploreResult) -> Table {
    let mut t = Table::new(vec![
        "network",
        "P",
        "SRAM",
        "strategy",
        "mode",
        "fused",
        "BW (M)",
        "SRAM acc (M)",
        "energy (mJ)",
        "MAC util",
    ]);
    for fp in &result.frontier {
        t.row(vec![
            fp.scope.clone(),
            fp.point.p_macs.to_string(),
            fp.point.sram.label(),
            fp.point.strategy.slug().to_string(),
            fp.point.mode.label().to_string(),
            fp.point.fusion.to_string(),
            mact(fp.objectives.bandwidth, 2),
            mact(fp.objectives.sram_accesses, 2),
            format!("{:.3}", fp.objectives.energy_pj / 1e9),
            pct(fp.objectives.mac_utilization),
        ]);
    }
    t
}

/// One-line run summary (stderr / serve shutdown line).
pub fn summarize(result: &ExploreResult) -> String {
    format!(
        "explore: {} candidates, {} evaluated, {} pruned, {} infeasible; frontier {} points",
        result.candidates,
        result.evaluated,
        result.pruned.len(),
        result.infeasible,
        result.frontier.len()
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::grid::GridEngine;
    use crate::dse::explore::explore;
    use crate::dse::space::ExploreSpec;
    use crate::models::zoo;

    #[test]
    fn table_and_summary_render() {
        let spec = ExploreSpec::new(vec![zoo::alexnet()]).with_macs(vec![512, 2048]);
        let result = explore(&GridEngine::new(), &spec, 2);
        let t = frontier_table(&result);
        assert_eq!(t.n_rows(), result.frontier.len());
        let md = t.to_markdown();
        assert!(md.contains("AlexNet"));
        assert!(md.contains("MAC util"));
        let s = summarize(&result);
        assert!(s.starts_with("explore: "));
        assert!(s.contains("frontier"));
    }
}
