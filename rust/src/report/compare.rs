//! Cell-by-cell comparison of this implementation against the paper's
//! published numbers — the data behind `psim validate` and EXPERIMENTS.md.

use crate::analytics::bandwidth::ControllerMode;
use crate::analytics::grid::{GridEngine, SweepSpec};
use crate::analytics::paper;
use crate::analytics::partition::Strategy;
use crate::models::zoo;
use crate::util::mathx::rel_diff;
use crate::util::tablefmt::Table;

/// One compared cell.
#[derive(Clone, Debug)]
pub struct Cell {
    /// Which paper table the cell is from (`"I"`, `"II"`, `"III"`).
    pub table: &'static str,
    /// Network name.
    pub network: String,
    /// Human label of the cell's scenario (P, strategy, mode).
    pub setting: String,
    /// The published value (M activations).
    pub paper: f64,
    /// This implementation's value (M activations).
    pub ours: f64,
}

impl Cell {
    /// Relative difference |paper − ours| / max(|paper|, |ours|).
    pub fn rel_diff(&self) -> f64 {
        rel_diff(self.paper, self.ours)
    }
}

/// Compare every cell of Tables I, II and III.
///
/// Both table grids run through one [`GridEngine`], so the overlapping
/// scenarios (Table II's passive/optimal cells at the Table I budgets)
/// and every repeated conv shape are computed once.
pub fn compare_all() -> Vec<Cell> {
    let nets = zoo::paper_networks();
    let engine = GridEngine::new();
    let grid1 = engine.run(
        &SweepSpec::new(nets.clone())
            .with_macs(paper::TABLE1_MACS.to_vec())
            .with_strategies(Strategy::TABLE1.to_vec())
            .with_modes(vec![ControllerMode::Passive]),
    );
    let grid2 = engine.run(
        &SweepSpec::new(nets.clone())
            .with_macs(paper::TABLE2_MACS.to_vec())
            .with_strategies(vec![Strategy::Optimal])
            .with_modes(ControllerMode::ALL.to_vec()),
    );

    let mut cells = Vec::new();
    for net in &nets {
        // Table III
        cells.push(Cell {
            table: "III",
            network: net.name.clone(),
            setting: "min".into(),
            paper: paper::table3(&net.name).unwrap(),
            ours: net.min_bandwidth() as f64 / 1e6,
        });
        // Table I
        for &p in &paper::TABLE1_MACS {
            let row = paper::table1(&net.name, p).unwrap();
            for (si, s) in Strategy::TABLE1.iter().enumerate() {
                let cell =
                    grid1.find(&net.name, p, *s, ControllerMode::Passive, 1).expect("grid cell");
                cells.push(Cell {
                    table: "I",
                    network: net.name.clone(),
                    setting: format!("P={p} {}", s.label()),
                    paper: row[si],
                    ours: cell.total() / 1e6,
                });
            }
        }
        // Table II
        for &p in &paper::TABLE2_MACS {
            let (pa, ac) = paper::table2(&net.name, p).unwrap();
            for (mode, val) in [(ControllerMode::Passive, pa), (ControllerMode::Active, ac)] {
                let cell =
                    grid2.find(&net.name, p, Strategy::Optimal, mode, 1).expect("grid cell");
                cells.push(Cell {
                    table: "II",
                    network: net.name.clone(),
                    setting: format!("P={p} {}", mode.label()),
                    paper: val,
                    ours: cell.total() / 1e6,
                });
            }
        }
    }
    cells
}

/// Aggregate statistics of a comparison run.
#[derive(Clone, Copy, Debug)]
pub struct Summary {
    /// Cells compared.
    pub cells: usize,
    /// Median relative difference.
    pub median_rel_diff: f64,
    /// Mean relative difference.
    pub mean_rel_diff: f64,
    /// Cells within 5% of the paper.
    pub within_5pct: usize,
    /// Cells within 15% of the paper.
    pub within_15pct: usize,
    /// Largest relative difference.
    pub worst: f64,
}

/// Summarize a set of compared cells.
pub fn summarize(cells: &[Cell]) -> Summary {
    assert!(!cells.is_empty());
    let mut diffs: Vec<f64> = cells.iter().map(|c| c.rel_diff()).collect();
    diffs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        cells: cells.len(),
        median_rel_diff: diffs[diffs.len() / 2],
        mean_rel_diff: diffs.iter().sum::<f64>() / diffs.len() as f64,
        within_5pct: diffs.iter().filter(|d| **d <= 0.05).count(),
        within_15pct: diffs.iter().filter(|d| **d <= 0.15).count(),
        worst: *diffs.last().unwrap(),
    }
}

/// Render the comparison as a markdown table (sorted worst-first when
/// `worst_first`, else paper order).
pub fn to_table(cells: &[Cell], worst_first: bool) -> Table {
    let mut t = Table::new(vec!["Table", "CNN", "Setting", "Paper", "Ours", "Δ%"]);
    let mut sorted: Vec<&Cell> = cells.iter().collect();
    if worst_first {
        sorted.sort_by(|a, b| b.rel_diff().partial_cmp(&a.rel_diff()).unwrap());
    }
    for c in sorted {
        t.row(vec![
            c.table.to_string(),
            c.network.clone(),
            c.setting.clone(),
            format!("{:.2}", c.paper),
            format!("{:.2}", c.ours),
            format!("{:+.1}", (c.ours - c.paper) / c.paper * 100.0),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_published_cell() {
        let cells = compare_all();
        // 8 nets x (1 + 3*4 + 6*2) = 8 x 25 = 200 cells
        assert_eq!(cells.len(), 200);
    }

    #[test]
    fn summary_consistency() {
        let cells = compare_all();
        let s = summarize(&cells);
        assert_eq!(s.cells, 200);
        assert!(s.within_5pct <= s.within_15pct);
        assert!(s.median_rel_diff <= s.worst);
    }

    #[test]
    fn render_is_complete() {
        let cells = compare_all();
        let t = to_table(&cells, true);
        assert_eq!(t.n_rows(), cells.len());
    }
}
