//! Regenerators for every table and figure in the paper's evaluation
//! (Section IV), plus paper-vs-ours comparison reports.
//!
//! * [`tables::table1`] — BW by partitioning strategy x P (Table I).
//! * [`tables::table2`] — passive vs active controller x P (Table II).
//! * [`tables::table3`] — minimum BW per network (Table III).
//! * [`fig2`] — % saving of the active controller (Fig. 2), markdown
//!   series + CSV + an ASCII chart for terminals.
//! * [`compare`] — cell-by-cell deviation against the published numbers.
//! * [`frontier`] — Pareto-frontier table/summary for `psim explore`.
//! * [`fusion`] — fused-vs-unfused bandwidth table for `psim fusion`.
//! * [`analyze`] — per-layer partition/bandwidth table for `psim analyze`.
//! * [`bench`] — the `psim bench` JSON summary (the `BENCH_serve.json`
//!   perf-trajectory schema) and its CI validator.
//! * [`zoo`] — the network-zoo listing for `psim zoo` (per-op kind
//!   counts and MAC/param/activation totals).

pub mod analyze;
pub mod bench;
pub mod compare;
pub mod fig2;
pub mod frontier;
pub mod fusion;
pub mod tables;
pub mod zoo;
