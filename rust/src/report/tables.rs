//! Renderers that regenerate the paper's Tables I–III from the analytical
//! model. Each returns a [`Table`] so callers choose markdown or CSV.
//!
//! Tables I and II are slices of the unified sweep grid: both build a
//! [`SweepSpec`] and format what [`GridEngine`] returns (parallel workers,
//! shared layer-shape cache), instead of re-deriving cells locally.

use crate::analytics::bandwidth::ControllerMode;
use crate::analytics::grid::{GridEngine, SweepSpec};
use crate::analytics::paper;
use crate::analytics::partition::Strategy;
use crate::models::zoo;
use crate::models::Network;
use crate::util::tablefmt::{mact, Table};

/// Table I over an explicit network list.
pub fn table1_for(nets: &[Network]) -> Table {
    let mut header = vec!["CNN".to_string()];
    for p in paper::TABLE1_MACS {
        for s in Strategy::TABLE1 {
            header.push(format!("P={p} {}", s.label()));
        }
    }
    let mut t = Table::new(header);
    let engine = GridEngine::new();
    let spec = SweepSpec::new(nets.to_vec())
        .with_macs(paper::TABLE1_MACS.to_vec())
        .with_strategies(Strategy::TABLE1.to_vec())
        .with_modes(vec![ControllerMode::Passive]);
    let grid = engine.run(&spec);
    for net in nets {
        let mut row = vec![net.name.clone()];
        for p in paper::TABLE1_MACS {
            for s in Strategy::TABLE1 {
                let cell =
                    grid.find(&net.name, p, s, ControllerMode::Passive, 1).expect("grid cell");
                row.push(mact(cell.total(), 1));
            }
        }
        t.row(row);
    }
    t
}

/// Table I: bandwidth by partitioning strategy for P in `TABLE1_MACS`.
pub fn table1() -> Table {
    table1_for(&zoo::paper_networks())
}

/// Table II over an explicit network list.
pub fn table2_for(nets: &[Network]) -> Table {
    let mut header = vec!["CNN".to_string()];
    for mode in ControllerMode::ALL {
        for p in paper::TABLE2_MACS {
            header.push(format!("{} {p}", mode.label()));
        }
    }
    let mut t = Table::new(header);
    let engine = GridEngine::new();
    let spec = SweepSpec::new(nets.to_vec())
        .with_macs(paper::TABLE2_MACS.to_vec())
        .with_strategies(vec![Strategy::Optimal])
        .with_modes(ControllerMode::ALL.to_vec());
    let grid = engine.run(&spec);
    for net in nets {
        let mut row = vec![net.name.clone()];
        for mode in ControllerMode::ALL {
            for p in paper::TABLE2_MACS {
                let cell = grid.find(&net.name, p, Strategy::Optimal, mode, 1).expect("grid cell");
                row.push(mact(cell.total(), 2));
            }
        }
        t.row(row);
    }
    t
}

/// Table II: passive vs active controller, optimal partitioning per mode.
pub fn table2() -> Table {
    table2_for(&zoo::paper_networks())
}

/// Table III over an explicit network list.
pub fn table3_for(nets: &[Network]) -> Table {
    let mut t = Table::new(vec!["CNN", "BW (M activations/inference)"]);
    for net in nets {
        t.row(vec![net.name.clone(), mact(net.min_bandwidth() as f64, 3)]);
    }
    t
}

/// Table III: minimum bandwidth (everything read once + written once).
pub fn table3() -> Table {
    table3_for(&zoo::paper_networks())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_shape() {
        let t = table1();
        assert_eq!(t.n_rows(), 8);
        let md = t.to_markdown();
        assert!(md.contains("This Work"));
        assert!(md.contains("AlexNet"));
    }

    #[test]
    fn table2_shape() {
        let t = table2();
        assert_eq!(t.n_rows(), 8);
        assert!(t.to_markdown().contains("passive 512"));
    }

    #[test]
    fn table3_matches_paper_within_tolerance() {
        // Collective regression: six of eight rows match the paper to
        // <=1%; VGG-16 and MobileNet carry documented deltas (see zoo).
        let nets = zoo::paper_networks();
        let mut close = 0;
        for net in &nets {
            let ours = net.min_bandwidth() as f64 / 1e6;
            let theirs = paper::table3(&net.name).unwrap();
            if (ours - theirs).abs() / theirs < 0.01 {
                close += 1;
            }
        }
        assert!(close >= 6, "only {close}/8 Table III rows within 1%");
    }
}
