//! Per-layer analysis rendering: the partition/bandwidth table behind
//! `psim analyze` and the protocol's `{"cmd":"analyze"}` request.

use crate::analytics::bandwidth::ControllerMode;
use crate::analytics::grid::GridEngine;
use crate::analytics::optimizer;
use crate::analytics::partition::Strategy;
use crate::models::Network;
use crate::util::tablefmt::{mact, Table};

/// One row per conv layer: shape, chosen partition `(m, n)`, the real
/// eq. 7 optimum, MAC utilization and the eq. 2/3 traffic. Returns the
/// table plus the one-line network summary. Rows come from the engine's
/// memoized evaluator, so repeated shapes (ResNet blocks, VGG stacks)
/// are computed once — and a long-lived engine answers warm.
pub fn analyze_table(
    engine: &GridEngine,
    net: &Network,
    p_macs: usize,
    strategy: Strategy,
    mode: ControllerMode,
) -> (Table, String) {
    let mut t = Table::new(vec![
        "layer", "shape", "m", "n", "m* (eq.7)", "MAC util", "B_i (M)", "B_o (M)", "B (M)",
    ]);
    let mut total = 0.0;
    for layer in &net.layers {
        let eval = engine.layer_eval(layer, p_macs, strategy, mode);
        let (part, bw) = (eval.partition, eval.bandwidth);
        let m_star = optimizer::optimal_m_real(layer, p_macs, mode);
        total += bw.total();
        t.row(vec![
            layer.name.clone(),
            format!("{}x{}x{}→{}x{}x{} k{}{}",
                layer.wi, layer.hi, layer.m, layer.wo(), layer.ho(), layer.n, layer.k,
                if layer.groups > 1 { format!(" g{}", layer.groups) } else { String::new() }),
            part.m.to_string(),
            part.n.to_string(),
            format!("{m_star:.2}"),
            format!("{:.0}%", (layer.k * layer.k * part.m * part.n) as f64 / p_macs as f64 * 100.0),
            mact(bw.input, 2),
            mact(bw.output, 2),
            mact(bw.total(), 2),
        ]);
    }
    let note = format!(
        "{} @ P={p_macs}, {} controller, {} strategy: total {} M activations \
         (floor {} M)",
        net.name,
        mode.label(),
        strategy.label(),
        mact(total, 2),
        mact(net.min_bandwidth() as f64, 3),
    );
    (t, note)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn one_row_per_layer_with_summary() {
        let engine = GridEngine::new();
        let net = zoo::alexnet();
        let (table, note) =
            analyze_table(&engine, &net, 512, Strategy::Optimal, ControllerMode::Passive);
        assert_eq!(table.n_rows(), net.layers.len());
        assert!(note.starts_with("AlexNet @ P=512, passive controller"), "{note}");
        assert!(note.contains("(floor 0.823 M)"), "{note}");
    }
}
