//! Per-layer analysis rendering: the partition/bandwidth table behind
//! `psim analyze` and the protocol's `{"cmd":"analyze"}` request.

use crate::analytics::bandwidth::ControllerMode;
use crate::analytics::grid::GridEngine;
use crate::analytics::optimizer;
use crate::analytics::partition::Strategy;
use crate::models::{DataTypes, Network};
use crate::util::tablefmt::{mact, Table};

/// [`analyze_table_dt`] at the default precision.
pub fn analyze_table(
    engine: &GridEngine,
    net: &Network,
    p_macs: usize,
    strategy: Strategy,
    mode: ControllerMode,
) -> (Table, String) {
    analyze_table_dt(engine, net, p_macs, strategy, mode, &DataTypes::default())
}

/// One row per conv layer: shape, chosen partition `(m, n)`, the real
/// eq. 7 optimum, MAC utilization and the eq. 2/3 traffic. Returns the
/// table plus the one-line network summary. Rows come from the engine's
/// memoized evaluator, so repeated shapes (ResNet blocks, VGG stacks)
/// are computed once — and a long-lived engine answers warm.
///
/// A non-default `dt` appends a byte-traffic column (`B (MB)`), switches
/// the eq. 7 column to the byte-weighted optimum, and extends the
/// summary with byte totals — additively, so default output is
/// byte-identical to the pre-precision table.
pub fn analyze_table_dt(
    engine: &GridEngine,
    net: &Network,
    p_macs: usize,
    strategy: Strategy,
    mode: ControllerMode,
    dt: &DataTypes,
) -> (Table, String) {
    let precision = !dt.is_default();
    let mut headers = vec![
        "layer", "shape", "m", "n", "m* (eq.7)", "MAC util", "B_i (M)", "B_o (M)", "B (M)",
    ];
    if precision {
        headers.push("B (MB)");
    }
    let mut t = Table::new(headers);
    let mut total = 0.0;
    let mut total_bytes = 0.0;
    for layer in &net.layers {
        let eval = engine.layer_eval_dt(layer, p_macs, strategy, mode, dt);
        let (part, bw) = (eval.partition, eval.bandwidth);
        let m_star = if precision {
            optimizer::optimal_m_real_bytes(layer, p_macs, mode, dt)
        } else {
            optimizer::optimal_m_real(layer, p_macs, mode)
        };
        total += bw.total();
        total_bytes += eval.bytes.activations();
        let mut row = vec![
            layer.name.clone(),
            format!("{}x{}x{}→{}x{}x{} k{}{}",
                layer.wi, layer.hi, layer.m, layer.wo(), layer.ho(), layer.n, layer.k,
                if layer.groups > 1 { format!(" g{}", layer.groups) } else { String::new() }),
            part.m.to_string(),
            part.n.to_string(),
            format!("{m_star:.2}"),
            format!("{:.0}%", (layer.k * layer.k * part.m * part.n) as f64 / p_macs as f64 * 100.0),
            mact(bw.input, 2),
            mact(bw.output, 2),
            mact(bw.total(), 2),
        ];
        if precision {
            row.push(mact(eval.bytes.activations(), 2));
        }
        t.row(row);
    }
    let mut note = format!(
        "{} @ P={p_macs}, {} controller, {} strategy: total {} M activations \
         (floor {} M)",
        net.name,
        mode.label(),
        strategy.label(),
        mact(total, 2),
        mact(net.min_bandwidth() as f64, 3),
    );
    if precision {
        note.push_str(&format!(
            "; bits {}: {} MB on the wire (byte floor {} MB)",
            dt.label(),
            mact(total_bytes, 2),
            mact(net.min_bandwidth_bytes(dt), 3),
        ));
    }
    (t, note)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn one_row_per_layer_with_summary() {
        let engine = GridEngine::new();
        let net = zoo::alexnet();
        let (table, note) =
            analyze_table(&engine, &net, 512, Strategy::Optimal, ControllerMode::Passive);
        assert_eq!(table.n_rows(), net.layers.len());
        assert!(note.starts_with("AlexNet @ P=512, passive controller"), "{note}");
        assert!(note.contains("(floor 0.823 M)"), "{note}");
        // no byte column or byte summary under the default precision
        assert!(!table.to_markdown().contains("B (MB)"));
        assert!(!note.contains("bits"));
    }

    #[test]
    fn precision_adds_byte_column_and_summary() {
        let engine = GridEngine::new();
        let net = zoo::alexnet();
        let dt = DataTypes::parse("8:8:32:8").unwrap();
        let (table, note) =
            analyze_table_dt(&engine, &net, 512, Strategy::Optimal, ControllerMode::Passive, &dt);
        assert_eq!(table.n_rows(), net.layers.len());
        assert!(table.to_markdown().contains("B (MB)"), "{}", table.to_markdown());
        assert!(note.contains("bits 8:8:32:8"), "{note}");
        assert!(note.contains("byte floor 0.823 MB"), "{note}");
    }
}
