//! The `psim bench` JSON summary: one flat object per run, the repo's
//! perf-trajectory record format.
//!
//! `BENCH_serve.json` at the repo root is an append-only JSON array —
//! one summary per PR, each produced by `psim bench --out` (or carried
//! forward as an unmeasured baseline tagged `"measured": false`). CI
//! re-runs a short bench against the pooled server and validates the
//! fresh summary with [`validate_summary`] and the checked-in history
//! with [`validate_history`] (schema gated, numbers recorded). The key
//! list is additionally pinned by the
//! `rust/tests/golden/protocol/serve/bench_summary.txt` fixture so the
//! schema cannot drift silently.

use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::api::PROTOCOL_VERSION;
use crate::util::benchkit::percentile;
use crate::util::json::Json;

/// Every key of the bench summary object, sorted (the serializer sorts
/// object keys, so this is also the output order). Append-only.
pub const SUMMARY_KEYS: [&str; 14] = [
    "bench",
    "clients",
    "duration_s",
    "errors",
    "latency_mean_us",
    "latency_p50_us",
    "latency_p95_us",
    "latency_p99_us",
    "mix",
    "protocol",
    "requests",
    "served",
    "shed",
    "throughput_rps",
];

/// One completed load-generator run, merged over all client threads.
pub struct BenchRun {
    /// Concurrent client connections.
    pub clients: usize,
    /// The `--mix` string the run used (verbatim).
    pub mix: String,
    /// Requests attempted (= served + shed + errors).
    pub requests: usize,
    /// Requests answered with a non-error reply.
    pub served: u64,
    /// Requests answered with `code:"too_busy"` (load shedding).
    pub shed: u64,
    /// Requests that failed (error reply, I/O error, or malformed reply).
    pub errors: u64,
    /// Wall-clock time for the whole run.
    pub wall: Duration,
    /// Per-reply round-trip latencies, µs (unsorted; one per reply).
    pub latencies_us: Vec<u64>,
}

impl BenchRun {
    /// The JSON summary object ([`SUMMARY_KEYS`] shape).
    pub fn summary(&self) -> Json {
        let mut lat = self.latencies_us.clone();
        lat.sort_unstable();
        let mean = if lat.is_empty() {
            0
        } else {
            (lat.iter().sum::<u64>() as f64 / lat.len() as f64).round() as u64
        };
        let wall_s = self.wall.as_secs_f64();
        let throughput = self.served as f64 / wall_s.max(1e-9);
        Json::obj(vec![
            ("bench", Json::Str("serve".into())),
            ("clients", Json::Num(self.clients as f64)),
            ("duration_s", Json::Num(round_to(wall_s, 1000.0))),
            ("errors", Json::Num(self.errors as f64)),
            ("latency_mean_us", Json::Num(mean as f64)),
            ("latency_p50_us", Json::Num(percentile(&lat, 0.50) as f64)),
            ("latency_p95_us", Json::Num(percentile(&lat, 0.95) as f64)),
            ("latency_p99_us", Json::Num(percentile(&lat, 0.99) as f64)),
            ("mix", Json::Str(self.mix.clone())),
            ("protocol", Json::Num(PROTOCOL_VERSION as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("served", Json::Num(self.served as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("throughput_rps", Json::Num(round_to(throughput, 10.0))),
        ])
    }

    /// One human-readable line for stderr (the JSON goes to stdout).
    pub fn human_line(&self) -> String {
        let mut lat = self.latencies_us.clone();
        lat.sort_unstable();
        format!(
            "bench: {} requests over {} clients in {:.3}s: {} served, {} shed, {} errors; \
             {:.1} rps, p50/p95/p99 = {}/{}/{} us",
            self.requests,
            self.clients,
            self.wall.as_secs_f64(),
            self.served,
            self.shed,
            self.errors,
            self.served as f64 / self.wall.as_secs_f64().max(1e-9),
            percentile(&lat, 0.50),
            percentile(&lat, 0.95),
            percentile(&lat, 0.99),
        )
    }
}

fn round_to(x: f64, scale: f64) -> f64 {
    (x * scale).round() / scale
}

/// Validate a bench summary object: exact [`SUMMARY_KEYS`] key set,
/// numeric fields numeric and non-negative, percentiles ordered,
/// `served + shed + errors == requests`, matching protocol version.
/// This is what the CI bench smoke step runs against both the fresh
/// summary and the checked-in `BENCH_serve.json`.
pub fn validate_summary(summary: &Json) -> Result<()> {
    let Json::Obj(map) = summary else {
        bail!("bench summary must be a JSON object");
    };
    let keys: Vec<&str> = map.keys().map(String::as_str).collect();
    ensure!(keys == SUMMARY_KEYS, "bench summary keys {keys:?} != {SUMMARY_KEYS:?}");
    ensure!(
        summary.get("bench").and_then(Json::as_str) == Some("serve"),
        "bench field must be \"serve\""
    );
    ensure!(
        summary.get("mix").and_then(Json::as_str).is_some_and(|m| !m.is_empty()),
        "mix must be a non-empty string"
    );
    ensure!(
        summary.get("protocol").and_then(Json::as_usize) == Some(PROTOCOL_VERSION),
        "protocol must be {PROTOCOL_VERSION}"
    );
    let num = |key: &str| -> Result<f64> {
        let Some(n) = summary.get(key).and_then(Json::as_f64) else {
            bail!("{key} must be a number");
        };
        ensure!(n >= 0.0, "{key} must be non-negative, got {n}");
        Ok(n)
    };
    let (p50, p95, p99) =
        (num("latency_p50_us")?, num("latency_p95_us")?, num("latency_p99_us")?);
    ensure!(p50 <= p95 && p95 <= p99, "percentiles out of order: {p50}/{p95}/{p99}");
    num("latency_mean_us")?;
    num("duration_s")?;
    num("clients")?;
    num("throughput_rps")?;
    let (requests, served, shed, errors) =
        (num("requests")?, num("served")?, num("shed")?, num("errors")?);
    ensure!(
        served + shed + errors == requests,
        "accounting broken: served {served} + shed {shed} + errors {errors} != requests {requests}"
    );
    Ok(())
}

/// Validate the append-only `BENCH_serve.json` history: a non-empty
/// JSON array with one [`validate_summary`]-clean entry per PR. Each
/// entry may carry an extra `"measured": bool` marker (`false` means
/// the numbers were carried forward from an earlier environment, not
/// re-measured); the marker is stripped before schema validation so
/// the summary key set stays exact.
pub fn validate_history(history: &Json) -> Result<()> {
    let Some(entries) = history.as_arr() else {
        bail!("bench history must be a JSON array of summaries");
    };
    ensure!(!entries.is_empty(), "bench history must hold at least one entry");
    for (i, entry) in entries.iter().enumerate() {
        let Json::Obj(map) = entry else {
            bail!("bench history entry {i} must be an object");
        };
        let mut map = map.clone();
        if let Some(flag) = map.remove("measured") {
            ensure!(
                matches!(flag, Json::Bool(_)),
                "bench history entry {i}: \"measured\" must be a bool"
            );
        }
        validate_summary(&Json::Obj(map)).with_context(|| format!("bench history entry {i}"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run() -> BenchRun {
        BenchRun {
            clients: 4,
            mix: "sweep,explore,version".into(),
            requests: 10,
            served: 8,
            shed: 2,
            errors: 0,
            wall: Duration::from_millis(250),
            latencies_us: vec![900, 100, 500, 300, 700, 200, 400, 600, 800, 1000],
        }
    }

    #[test]
    fn summary_matches_the_pinned_key_list() {
        let summary = run().summary();
        validate_summary(&summary).unwrap();
        let Json::Obj(map) = &summary else { panic!("not an object") };
        let keys: Vec<&str> = map.keys().map(String::as_str).collect();
        assert_eq!(keys, SUMMARY_KEYS);
        // ... and the key list matches the golden fixture, one key per
        // line, so the schema is pinned on disk for CI.
        let fixture = concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/tests/golden/protocol/serve/bench_summary.txt"
        );
        let text = std::fs::read_to_string(fixture).expect("bench_summary fixture");
        let pinned: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
        assert_eq!(pinned, SUMMARY_KEYS, "fixture drifted from SUMMARY_KEYS");
    }

    #[test]
    fn summary_computes_exact_percentiles_and_throughput() {
        let summary = run().summary();
        assert_eq!(summary.get("latency_p50_us").unwrap().as_usize(), Some(500));
        assert_eq!(summary.get("latency_p95_us").unwrap().as_usize(), Some(1000));
        assert_eq!(summary.get("latency_p99_us").unwrap().as_usize(), Some(1000));
        assert_eq!(summary.get("latency_mean_us").unwrap().as_usize(), Some(550));
        // 8 served / 0.25 s = 32 rps; duration rounds to 3 decimals.
        assert_eq!(summary.get("throughput_rps").unwrap().as_f64(), Some(32.0));
        assert_eq!(summary.get("duration_s").unwrap().as_f64(), Some(0.25));
        assert_eq!(summary.get("served").unwrap().as_usize(), Some(8));
        assert_eq!(summary.get("shed").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn validator_rejects_malformed_summaries() {
        // Wrong accounting.
        let mut bad = run();
        bad.errors = 5;
        assert!(validate_summary(&bad.summary()).is_err());
        // Missing key.
        let Json::Obj(mut map) = run().summary() else { panic!() };
        map.remove("shed");
        assert!(validate_summary(&Json::Obj(map)).is_err());
        // Extra key.
        let Json::Obj(mut map) = run().summary() else { panic!() };
        map.insert("zzz_extra".into(), Json::Num(1.0));
        assert!(validate_summary(&Json::Obj(map)).is_err());
        // Wrong bench tag.
        let Json::Obj(mut map) = run().summary() else { panic!() };
        map.insert("bench".into(), Json::Str("other".into()));
        assert!(validate_summary(&Json::Obj(map)).is_err());
        // Percentiles out of order.
        let Json::Obj(mut map) = run().summary() else { panic!() };
        map.insert("latency_p50_us".into(), Json::Num(9999.0));
        assert!(validate_summary(&Json::Obj(map)).is_err());
        // Not an object at all.
        assert!(validate_summary(&Json::Num(3.0)).is_err());
    }

    #[test]
    fn empty_run_is_still_schema_valid() {
        let empty = BenchRun {
            clients: 1,
            mix: "version".into(),
            requests: 0,
            served: 0,
            shed: 0,
            errors: 0,
            wall: Duration::from_millis(1),
            latencies_us: vec![],
        };
        validate_summary(&empty.summary()).unwrap();
        assert_eq!(empty.summary().get("latency_p99_us").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn checked_in_history_validates() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
        let text = std::fs::read_to_string(path).expect("BENCH_serve.json at the repo root");
        let history = Json::parse(&text).expect("BENCH_serve.json parses");
        validate_history(&history).unwrap();
        // The baseline entry is explicitly tagged as carried forward.
        let first = &history.as_arr().unwrap()[0];
        assert_eq!(first.get("measured"), Some(&Json::Bool(false)));
    }

    #[test]
    fn history_validator_rejects_bad_shapes() {
        assert!(validate_history(&Json::Num(1.0)).is_err(), "non-array");
        assert!(validate_history(&Json::Arr(vec![])).is_err(), "empty history");
        assert!(validate_history(&Json::Arr(vec![Json::Num(1.0)])).is_err(), "non-object entry");
        // A "measured" marker is tolerated (and stripped) ...
        let Json::Obj(mut map) = run().summary() else { panic!() };
        map.insert("measured".into(), Json::Bool(false));
        validate_history(&Json::Arr(vec![Json::Obj(map)])).unwrap();
        // ... but only as a bool.
        let Json::Obj(mut map) = run().summary() else { panic!() };
        map.insert("measured".into(), Json::Num(0.0));
        assert!(validate_history(&Json::Arr(vec![Json::Obj(map)])).is_err());
    }

    #[test]
    fn human_line_reports_the_headline_numbers() {
        let line = run().human_line();
        assert!(line.contains("8 served"), "{line}");
        assert!(line.contains("2 shed"), "{line}");
        assert!(line.contains("p50/p95/p99 = 500/1000/1000 us"), "{line}");
    }
}
