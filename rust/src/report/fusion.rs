//! Fusion rendering: the per-network fused-vs-unfused bandwidth table
//! behind `psim fusion`, comparing the paper's per-layer model against
//! [`crate::analytics::fusion`] chains at a given depth.

use crate::analytics::bandwidth::ControllerMode;
use crate::analytics::fusion::chains;
use crate::analytics::grid::GridEngine;
use crate::analytics::partition::Strategy;
use crate::models::{DataTypes, Network};
use crate::util::tablefmt::{mact, pct, Table};

/// [`fusion_table_dt`] at the default precision.
pub fn fusion_table(
    engine: &GridEngine,
    nets: &[Network],
    depth: usize,
    p_macs: usize,
    strategy: Strategy,
    mode: ControllerMode,
) -> Table {
    fusion_table_dt(engine, nets, depth, p_macs, strategy, mode, &DataTypes::default())
}

/// One row per network: chain structure, unfused vs fused activation
/// traffic (in M activations) and the fraction saved. Depth-1 rows save
/// exactly 0% by construction. A non-default `dt` appends byte-traffic
/// columns (fused vs unfused MB and the byte saving) — additively, so
/// default output is byte-identical to the pre-precision table.
pub fn fusion_table_dt(
    engine: &GridEngine,
    nets: &[Network],
    depth: usize,
    p_macs: usize,
    strategy: Strategy,
    mode: ControllerMode,
    dt: &DataTypes,
) -> Table {
    let precision = !dt.is_default();
    let mut headers = vec![
        "network".to_string(),
        "chains".to_string(),
        "longest".to_string(),
        "unfused BW (M)".to_string(),
        format!("fused d={depth} (M)"),
        "saved".to_string(),
    ];
    if precision {
        headers.push("unfused (MB)".to_string());
        headers.push(format!("fused d={depth} (MB)"));
        headers.push("saved (B)".to_string());
    }
    let mut t = Table::new(headers);
    for net in nets {
        let chain_list = chains(net, depth);
        let longest = chain_list.iter().map(|r| r.len()).max().unwrap_or(0);
        let unfused_cell = engine.cell_fused_dt(net, p_macs, strategy, mode, 1, 1, dt);
        let fused_cell = engine.cell_fused_dt(net, p_macs, strategy, mode, 1, depth, dt);
        let (unfused, fused) = (unfused_cell.total(), fused_cell.total());
        let mut row = vec![
            net.name.clone(),
            chain_list.len().to_string(),
            longest.to_string(),
            mact(unfused, 2),
            mact(fused, 2),
            pct((unfused - fused) / unfused),
        ];
        if precision {
            let (ub, fb) = (unfused_cell.total_bytes(), fused_cell.total_bytes());
            row.push(mact(ub, 2));
            row.push(mact(fb, 2));
            row.push(pct((ub - fb) / ub));
        }
        t.row(row);
    }
    t
}

/// One-line run summary for logs/stderr.
pub fn summarize(nets: usize, depth: usize, p_macs: usize) -> String {
    format!("fusion: {nets} networks at depth {depth}, P={p_macs}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn table_shows_savings_and_structure() {
        let engine = GridEngine::new();
        let nets = vec![zoo::alexnet(), zoo::vgg16()];
        let t = fusion_table(&engine, &nets, 2, 1024, Strategy::Optimal, ControllerMode::Passive);
        assert_eq!(t.n_rows(), 2);
        let md = t.to_markdown();
        assert!(md.contains("AlexNet"));
        assert!(md.contains("fused d=2"));
        // AlexNet: 4 chains at depth 2 (conv3+conv4 fuse), longest = 2
        assert!(md.contains("| 4"), "{md}");
        assert!(summarize(2, 2, 1024).contains("depth 2"));
    }

    #[test]
    fn precision_appends_byte_columns() {
        let engine = GridEngine::new();
        let nets = vec![zoo::alexnet()];
        let dt = DataTypes::parse("8:8:32:8").unwrap();
        let t = fusion_table_dt(
            &engine,
            &nets,
            2,
            1024,
            Strategy::Optimal,
            ControllerMode::Passive,
            &dt,
        );
        let md = t.to_markdown();
        assert!(md.contains("unfused (MB)"), "{md}");
        assert!(md.contains("fused d=2 (MB)"), "{md}");
        // default precision keeps the original shape
        let plain =
            fusion_table(&engine, &nets, 2, 1024, Strategy::Optimal, ControllerMode::Passive);
        assert!(!plain.to_markdown().contains("MB"));
    }

    #[test]
    fn depth_one_saves_nothing() {
        let engine = GridEngine::new();
        let nets = vec![zoo::alexnet()];
        let t = fusion_table(&engine, &nets, 1, 1024, Strategy::Optimal, ControllerMode::Passive);
        assert!(t.to_markdown().contains("0.0%"), "{}", t.to_markdown());
    }
}
