//! The network-zoo listing behind `psim zoo` and the protocol's
//! `{"cmd":"zoo"}` request: one row per registered network with per-op
//! kind counts and MAC/weight/activation totals, generated from the
//! typed [`Op`](crate::models::Op) lists (not the lowered layers).

use crate::models::{zoo, Network, Op, OpKind};
use crate::util::tablefmt::{mact, Table};

/// One row per network — the paper's eight, then the extensions in zoo
/// registration order. Columns from the typed op view:
///
/// * `ops` + per-kind counts (`conv`/`gemm`/`attention`);
/// * `layers` — conv-equivalent layers after [`Op::lower`];
/// * `MACs (M)` — op-view MACs (equals the lowered total);
/// * `params (M)` — true weight parameters (attention counts its four
///   projections only, not the lowered score/ctx pseudo-kernels);
/// * `acts (M)` — activations read + written once (the Table III floor).
///
/// Returns the table plus a one-line summary note.
///
/// The README's `psim zoo` excerpt is pinned against this table (and
/// `docs/PROTOCOL.md` embeds the whole reply via its fixture doc-test),
/// so neither can drift from the code:
///
/// ```
/// let readme =
///     std::fs::read_to_string(concat!(env!("CARGO_MANIFEST_DIR"), "/../README.md")).unwrap();
/// let (table, _) = psim::report::zoo::zoo_table();
/// let md = table.to_markdown();
/// let mut pinned = 0;
/// for row in md.lines().filter(|l| l.contains("AlexNet ") || l.contains("ViT-Tiny")) {
///     assert!(readme.contains(row), "README zoo excerpt is stale: {row}");
///     pinned += 1;
/// }
/// assert_eq!(pinned, 2);
/// let header = md.lines().next().unwrap();
/// assert!(readme.contains(header), "README zoo header is stale");
/// ```
pub fn zoo_table() -> (Table, String) {
    let mut t = Table::new(vec![
        "network", "ops", "conv", "gemm", "attention", "layers", "MACs (M)", "params (M)",
        "acts (M)",
    ]);
    let paper = zoo::paper_networks();
    let extras = zoo::extra_networks();
    let n_paper = paper.len();
    let n_extras = extras.len();
    for net in paper.iter().chain(extras.iter()) {
        t.row(zoo_row(net));
    }
    let note = format!(
        "{} networks: {n_paper} paper profiles + {n_extras} extensions; totals from the \
         typed op view (docs/MODEL.md maps gemm/attention onto eqs. 2-4)",
        n_paper + n_extras,
    );
    (t, note)
}

fn zoo_row(net: &Network) -> Vec<String> {
    let count = |kind: OpKind| net.ops.iter().filter(|o| o.kind() == kind).count();
    let macs: u64 = net.ops.iter().map(Op::macs).sum();
    let params: u64 = net.ops.iter().map(Op::weights).sum();
    let acts: u64 = net.ops.iter().map(|o| o.input_activations() + o.output_activations()).sum();
    vec![
        net.name.clone(),
        net.ops.len().to_string(),
        count(OpKind::Conv).to_string(),
        count(OpKind::Gemm).to_string(),
        count(OpKind::Attention).to_string(),
        net.layers.len().to_string(),
        mact(macs as f64, 1),
        mact(params as f64, 2),
        mact(acts as f64, 2),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_row_per_registered_network() {
        let (table, note) = zoo_table();
        let expect = zoo::paper_networks().len() + zoo::extra_networks().len();
        assert_eq!(table.n_rows(), expect);
        assert!(note.starts_with(&format!("{expect} networks")), "{note}");
    }

    #[test]
    fn conv_networks_report_pure_conv_counts() {
        let (table, _) = zoo_table();
        let md = table.to_markdown();
        let alexnet = md.lines().find(|l| l.contains("AlexNet")).unwrap();
        let cells: Vec<&str> = alexnet.split('|').map(str::trim).collect();
        // | network | ops | conv | gemm | attention | layers | ...
        assert_eq!(&cells[2..7], &["5", "5", "0", "0", "5"]);
    }

    #[test]
    fn vit_row_reports_the_op_mix_and_true_params() {
        let (table, _) = zoo_table();
        let md = table.to_markdown();
        let vit = md.lines().find(|l| l.contains("ViT-Tiny")).unwrap();
        let cells: Vec<&str> = vit.split('|').map(str::trim).collect();
        assert_eq!(&cells[2..7], &["37", "1", "24", "12", "145"]);
        // 1253.5 M MACs, 5.46 M true params (not the lowered pseudo-kernels).
        assert_eq!(cells[7], "1253.5");
        assert_eq!(cells[8], "5.46");
    }

    #[test]
    fn activations_column_is_the_table_iii_floor() {
        // Op-view activation totals delegate to the same DAG lower() uses,
        // so the column equals Network::min_bandwidth for every network.
        for net in zoo::paper_networks().iter().chain(zoo::extra_networks().iter()) {
            let acts: u64 =
                net.ops.iter().map(|o| o.input_activations() + o.output_activations()).sum();
            assert_eq!(acts, net.min_bandwidth(), "{}", net.name);
        }
    }
}
