//! Fig. 2: percentage bandwidth saving of the active memory controller
//! versus a passive one, per network, over the MAC budget sweep.

use crate::analytics::bandwidth::ControllerMode;
use crate::analytics::grid::{GridEngine, SweepSpec};
use crate::analytics::paper;
use crate::analytics::partition::Strategy;
use crate::models::zoo;
use crate::util::tablefmt::Table;

/// One network's saving series over `TABLE2_MACS`.
#[derive(Clone, Debug)]
pub struct SavingSeries {
    /// Network name.
    pub network: String,
    /// (P, saving-percent) points.
    pub points: Vec<(usize, f64)>,
}

/// Compute the Fig. 2 series for all eight networks (one sweep-engine run
/// over `TABLE2_MACS x optimal x both modes`).
pub fn fig2_series() -> Vec<SavingSeries> {
    let nets = zoo::paper_networks();
    let engine = GridEngine::new();
    let grid = engine.run(
        &SweepSpec::new(nets.clone())
            .with_macs(paper::TABLE2_MACS.to_vec())
            .with_strategies(vec![Strategy::Optimal])
            .with_modes(ControllerMode::ALL.to_vec()),
    );
    nets.iter()
        .map(|net| {
            let points = paper::TABLE2_MACS
                .iter()
                .map(|&p| {
                    let pa = grid
                        .find(&net.name, p, Strategy::Optimal, ControllerMode::Passive, 1)
                        .expect("grid cell")
                        .total();
                    let ac = grid
                        .find(&net.name, p, Strategy::Optimal, ControllerMode::Active, 1)
                        .expect("grid cell")
                        .total();
                    (p, (pa - ac) / pa * 100.0)
                })
                .collect();
            SavingSeries { network: net.name.clone(), points }
        })
        .collect()
}

/// Fig. 2 as a table (rows = networks, columns = MAC budgets).
pub fn fig2_table() -> Table {
    let mut header = vec!["CNN".to_string()];
    header.extend(paper::TABLE2_MACS.iter().map(|p| format!("{p} MACs")));
    let mut t = Table::new(header);
    for s in fig2_series() {
        let mut row = vec![s.network.clone()];
        row.extend(s.points.iter().map(|(_, v)| format!("{v:.1}%")));
        t.row(row);
    }
    t
}

/// A rough ASCII rendering of Fig. 2 (terminal-friendly bar chart,
/// one row per network per P).
pub fn fig2_ascii() -> String {
    let mut out = String::new();
    out.push_str("Percentage bandwidth saving with active SRAM controller\n");
    for s in fig2_series() {
        out.push_str(&format!("\n{}\n", s.network));
        for (p, v) in &s.points {
            let bar = "#".repeat((v / 2.0).round().max(0.0) as usize);
            out.push_str(&format!("  {p:>6} MACs |{bar:<25}| {v:.1}%\n"));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_cover_all_networks_and_budgets() {
        let s = fig2_series();
        assert_eq!(s.len(), 8);
        assert!(s.iter().all(|x| x.points.len() == paper::TABLE2_MACS.len()));
    }

    #[test]
    fn savings_are_positive_and_bounded() {
        // Active controller can at most halve the output traffic, so the
        // saving is within (0, 50]% of total.
        for s in fig2_series() {
            for &(p, v) in &s.points {
                assert!(v > 0.0 && v <= 50.0, "{} P={p}: {v}%", s.network);
            }
        }
    }

    #[test]
    fn paper_band_at_512_macs() {
        // Paper: "gain is significantly higher at 19-42% for more
        // constrained compute" — allow a small modelling margin.
        for s in fig2_series() {
            let (_, v) = s.points[0];
            assert!((15.0..=47.0).contains(&v), "{} @512: {v}%", s.network);
        }
    }

    #[test]
    fn ascii_chart_renders() {
        let a = fig2_ascii();
        assert!(a.contains("AlexNet"));
        assert!(a.contains("16384 MACs"));
    }
}
