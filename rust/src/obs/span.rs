//! Lightweight scoped span timers feeding a bounded ring buffer.
//!
//! Same discipline as [`crate::sim::trace::Trace`]: a fixed capacity,
//! oldest-first eviction, and an explicit `dropped` counter so a
//! saturated log is visible instead of silent. Capacity 0 disables
//! recording entirely (every record counts as dropped).
//!
//! Stage names are `&'static str` constants (see [`stage`]) so
//! recording never allocates; the per-stage glossary lives in
//! `docs/OBSERVABILITY.md`.

use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Canonical stage names recorded by the serving and analytics paths.
pub mod stage {
    /// Time a queued connection waited in the bounded hand-off queue
    /// between the accept loop and a pooled worker.
    pub const QUEUE_WAIT: &str = "queue_wait";
    /// Decoding one request line into a typed [`crate::api::Request`].
    pub const DECODE: &str = "decode";
    /// Dispatching a typed request through the engine (compute included).
    pub const DISPATCH: &str = "dispatch";
    /// Encoding the typed reply back to a JSON line.
    pub const ENCODE: &str = "encode";
    /// Writing the reply line to the client socket.
    pub const WRITE: &str = "write";
    /// Evaluating one sweep grid cell (`analytics::grid`).
    pub const GRID_CELL: &str = "grid_cell";
    /// Evaluating one exact-evaluation chunk in `dse::explore`.
    pub const DSE_CHUNK: &str = "dse_chunk";
}

/// One recorded span: a stage name plus its duration in microseconds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanRecord {
    /// Which stage this span timed (one of the [`stage`] constants).
    pub stage: &'static str,
    /// Wall-clock duration in microseconds.
    pub dur_us: u64,
}

#[derive(Debug, Default)]
struct Inner {
    ring: VecDeque<SpanRecord>,
    dropped: u64,
}

/// A bounded ring buffer of recent spans with a dropped counter.
#[derive(Debug)]
pub struct SpanLog {
    cap: usize,
    inner: Mutex<Inner>,
}

impl SpanLog {
    /// A log retaining at most `cap` recent spans (0 disables).
    pub fn new(cap: usize) -> SpanLog {
        SpanLog { cap, inner: Mutex::new(Inner::default()) }
    }

    /// Record a finished span. Evicts the oldest retained span (and
    /// bumps `dropped`) when full; with capacity 0 every record drops.
    pub fn record_us(&self, stage: &'static str, dur_us: u64) {
        let mut inner = self.inner.lock().expect("span log lock");
        if self.cap == 0 {
            inner.dropped += 1;
            return;
        }
        if inner.ring.len() == self.cap {
            inner.ring.pop_front();
            inner.dropped += 1;
        }
        inner.ring.push_back(SpanRecord { stage, dur_us });
    }

    /// Start a scoped timer; the span records itself on drop.
    pub fn time(&self, stage: &'static str) -> SpanTimer<'_> {
        SpanTimer { log: self, stage, started: Instant::now() }
    }

    /// Number of spans currently retained.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("span log lock").ring.len()
    }

    /// True when no spans are retained.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total spans evicted or rejected since creation.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().expect("span log lock").dropped
    }

    /// Copy of the retained spans, oldest first.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        self.inner.lock().expect("span log lock").ring.iter().copied().collect()
    }

    /// Aggregate the retained spans: `(stage, count, total_us)` sorted
    /// by stage name.
    pub fn stage_totals(&self) -> Vec<(&'static str, u64, u64)> {
        let mut totals: BTreeMap<&'static str, (u64, u64)> = BTreeMap::new();
        for span in self.inner.lock().expect("span log lock").ring.iter() {
            let entry = totals.entry(span.stage).or_insert((0, 0));
            entry.0 += 1;
            entry.1 += span.dur_us;
        }
        totals.into_iter().map(|(stage, (count, total))| (stage, count, total)).collect()
    }
}

/// Scoped timer returned by [`SpanLog::time`]; records on drop.
#[must_use = "the span records its duration when dropped"]
#[derive(Debug)]
pub struct SpanTimer<'a> {
    log: &'a SpanLog,
    stage: &'static str,
    started: Instant,
}

impl Drop for SpanTimer<'_> {
    fn drop(&mut self) {
        self.log.record_us(self.stage, self.started.elapsed().as_micros() as u64);
    }
}

/// The process-global span log (capacity 4096) shared by serve, grid
/// and dse instrumentation. Host-side observability only: nothing in
/// the wire protocol reads it, so concurrent tests sharing it cannot
/// perturb pinned replies.
pub fn global() -> &'static SpanLog {
    static GLOBAL: OnceLock<SpanLog> = OnceLock::new();
    GLOBAL.get_or_init(|| SpanLog::new(4096))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_evicts_oldest_and_counts_drops() {
        let log = SpanLog::new(2);
        log.record_us("a", 1);
        log.record_us("b", 2);
        assert_eq!(log.dropped(), 0);
        log.record_us("c", 3);
        assert_eq!(log.dropped(), 1);
        let spans = log.snapshot();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].stage, "b");
        assert_eq!(spans[1].stage, "c");
    }

    #[test]
    fn zero_capacity_drops_everything() {
        let log = SpanLog::new(0);
        log.record_us("a", 1);
        log.record_us("b", 2);
        assert!(log.is_empty());
        assert_eq!(log.dropped(), 2);
    }

    #[test]
    fn scoped_timer_records_on_drop() {
        let log = SpanLog::new(8);
        {
            let _span = log.time(stage::DECODE);
        }
        let spans = log.snapshot();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].stage, stage::DECODE);
    }

    #[test]
    fn stage_totals_aggregate_sorted_by_stage() {
        let log = SpanLog::new(8);
        log.record_us("write", 5);
        log.record_us("decode", 2);
        log.record_us("decode", 3);
        assert_eq!(log.stage_totals(), vec![("decode", 2, 5), ("write", 1, 5)]);
    }
}
