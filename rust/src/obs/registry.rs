//! Named-metric registry with sorted-key JSON and Prometheus-style
//! text renderings, plus the typed catalog of every metric this crate
//! records.
//!
//! Two instances matter in practice:
//!
//! * each [`crate::api::Engine`] owns a private [`Registry`] so the
//!   `{"cmd":"stats"}` reply is deterministic per engine — crucially,
//!   `cargo test` runs many engines concurrently in one process, and
//!   the pinned stats fixture would be unreproducible against shared
//!   state;
//! * [`global`] is the process-wide registry for code with no engine
//!   in reach (per-cell grid timings, per-chunk dse timings) — host
//!   observability only, never rendered onto the wire.
//!
//! Registration is register-or-get by name, so eager catalog
//! registration (for a complete, stable snapshot shape) and lazy
//! handle lookup compose.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::metrics::{bucket_bound, Counter, Gauge, Histogram, BUCKETS};
use crate::util::json::Json;

/// What kind of metric a catalog entry names.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    /// Monotone event count.
    Counter,
    /// Set / high-water-marked value.
    Gauge,
    /// Log-2-bucket latency histogram (microseconds).
    Histogram,
}

impl MetricKind {
    fn label(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }
}

/// One row of the metric catalog: a name, its kind, which registry
/// carries it, and a one-line description.
#[derive(Clone, Copy, Debug)]
pub struct MetricDesc {
    /// Metric name as it appears in snapshots and the exposition.
    pub name: &'static str,
    /// Counter, gauge or histogram.
    pub kind: MetricKind,
    /// `"engine"` (per-[`crate::api::Engine`], on the wire via
    /// `{"cmd":"stats"}`) or `"process"` (global registry, host-side).
    pub scope: &'static str,
    /// One-line description for the docs table.
    pub help: &'static str,
}

const fn counter(name: &'static str, scope: &'static str, help: &'static str) -> MetricDesc {
    MetricDesc { name, kind: MetricKind::Counter, scope, help }
}

const fn gauge(name: &'static str, scope: &'static str, help: &'static str) -> MetricDesc {
    MetricDesc { name, kind: MetricKind::Gauge, scope, help }
}

const fn histogram(name: &'static str, scope: &'static str, help: &'static str) -> MetricDesc {
    MetricDesc { name, kind: MetricKind::Histogram, scope, help }
}

/// Every metric this crate records, sorted by name. Engine-scoped
/// entries are eagerly registered by [`register_catalog`] so the
/// `{"cmd":"stats"}` snapshot always carries the full, stable key set;
/// process-scoped entries appear in [`global`] once their recorder
/// first runs.
pub const METRICS: [MetricDesc; 37] = [
    counter("api_errors", "engine", "Requests that returned a protocol error reply"),
    histogram("api_latency_us_analyze", "engine", "Dispatch latency of `analyze` requests"),
    histogram("api_latency_us_explore", "engine", "Dispatch latency of `explore` requests"),
    histogram("api_latency_us_fusion", "engine", "Dispatch latency of `fusion` requests"),
    histogram("api_latency_us_infer", "engine", "Dispatch latency of `infer` requests"),
    histogram("api_latency_us_metrics", "engine", "Dispatch latency of `metrics` requests"),
    histogram("api_latency_us_shutdown", "engine", "Dispatch latency of `shutdown` requests"),
    histogram("api_latency_us_stats", "engine", "Dispatch latency of `stats` requests"),
    histogram("api_latency_us_sweep", "engine", "Dispatch latency of `sweep` requests"),
    histogram("api_latency_us_tables", "engine", "Dispatch latency of `tables` requests"),
    histogram("api_latency_us_version", "engine", "Dispatch latency of `version` requests"),
    counter("api_requests_analyze", "engine", "`analyze` requests dispatched"),
    counter("api_requests_explore", "engine", "`explore` requests dispatched"),
    counter("api_requests_fusion", "engine", "`fusion` requests dispatched"),
    counter("api_requests_infer", "engine", "`infer` requests dispatched"),
    counter("api_requests_metrics", "engine", "`metrics` requests dispatched"),
    counter("api_requests_shutdown", "engine", "`shutdown` requests dispatched"),
    counter("api_requests_stats", "engine", "`stats` requests dispatched"),
    counter("api_requests_sweep", "engine", "`sweep` requests dispatched"),
    counter("api_requests_tables", "engine", "`tables` requests dispatched"),
    counter("api_requests_version", "engine", "`version` requests dispatched"),
    counter("cache_evictions", "engine", "Result-store entries evicted by the LRU bound"),
    counter("cache_hits", "engine", "Result-store lookups answered from a stored reply"),
    counter("cache_invalidations", "engine", "Stored artifacts rejected by validation, recomputed"),
    counter("cache_lookups", "engine", "Result-store lookups (cacheable requests seen)"),
    counter("cache_misses", "engine", "Result-store lookups that required a fresh dispatch"),
    histogram("dse_chunk_eval_us", "process", "Exact evaluation time per explore chunk"),
    histogram("grid_cell_eval_us", "process", "Evaluation time per sweep grid cell"),
    counter("serve_conns_accepted", "engine", "Connections accepted into the worker pool"),
    counter("serve_conns_refused", "engine", "Connections refused during shutdown"),
    counter("serve_conns_shed", "engine", "Connections shed with `too_busy`"),
    counter("serve_conns_timed_out", "engine", "Connections closed by the idle read timeout"),
    gauge("serve_queue_depth_peak", "engine", "High-water mark of the bounded hand-off queue"),
    histogram("serve_queue_wait_us", "engine", "Time connections waited in the hand-off queue"),
    counter("serve_replies", "engine", "Reply lines written to clients"),
    counter("serve_replies_coalesced", "engine", "Replies served from an in-flight leader"),
    counter("serve_replies_dispatched", "engine", "Replies computed by a fresh dispatch"),
];

/// Markdown table of [`METRICS`] — pinned verbatim into
/// `docs/OBSERVABILITY.md` by the `obs` module doc-test so the docs
/// cannot drift from the typed catalog.
pub fn metrics_table() -> String {
    let mut out = String::from("| metric | kind | scope | description |\n|---|---|---|---|\n");
    for m in &METRICS {
        out.push_str(&format!(
            "| `{}` | {} | {} | {} |\n",
            m.name,
            m.kind.label(),
            m.scope,
            m.help
        ));
    }
    out
}

/// Register every engine-scoped catalog entry into `reg`, giving a
/// fresh engine a complete all-zero snapshot shape.
pub fn register_catalog(reg: &Registry) {
    for m in METRICS.iter().filter(|m| m.scope == "engine") {
        match m.kind {
            MetricKind::Counter => {
                reg.counter(m.name);
            }
            MetricKind::Gauge => {
                reg.gauge(m.name);
            }
            MetricKind::Histogram => {
                reg.histogram(m.name);
            }
        }
    }
}

/// A set of named metrics. Lookup is mutex-guarded (cold path: once
/// per handle, at registration); the returned `Arc` handles record
/// lock-free.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Register-or-get the counter called `name`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut map = self.counters.lock().expect("registry lock");
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    /// Register-or-get the gauge called `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut map = self.gauges.lock().expect("registry lock");
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    /// Register-or-get the histogram called `name`.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut map = self.histograms.lock().expect("registry lock");
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    /// Sorted-key JSON snapshot:
    /// `{"counters":{...},"gauges":{...},"histograms":{...}}`.
    pub fn snapshot_json(&self) -> Json {
        let counters: BTreeMap<String, Json> = self
            .counters
            .lock()
            .expect("registry lock")
            .iter()
            .map(|(name, c)| (name.clone(), Json::Num(c.get() as f64)))
            .collect();
        let gauges: BTreeMap<String, Json> = self
            .gauges
            .lock()
            .expect("registry lock")
            .iter()
            .map(|(name, g)| (name.clone(), Json::Num(g.get() as f64)))
            .collect();
        let histograms: BTreeMap<String, Json> = self
            .histograms
            .lock()
            .expect("registry lock")
            .iter()
            .map(|(name, h)| (name.clone(), h.snapshot_json()))
            .collect();
        Json::obj(vec![
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("histograms", Json::Obj(histograms)),
        ])
    }

    /// Prometheus-style text exposition: `# TYPE` lines, plain
    /// `name value` samples, and cumulative `_bucket{le="..."}` /
    /// `_sum` / `_count` lines per histogram.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().expect("registry lock").iter() {
            out.push_str(&format!("# TYPE {name} counter\n{name} {}\n", c.get()));
        }
        for (name, g) in self.gauges.lock().expect("registry lock").iter() {
            out.push_str(&format!("# TYPE {name} gauge\n{name} {}\n", g.get()));
        }
        for (name, h) in self.histograms.lock().expect("registry lock").iter() {
            out.push_str(&format!("# TYPE {name} histogram\n"));
            let mut cum = 0u64;
            for (i, n) in h.bucket_counts().iter().enumerate() {
                cum += n;
                if i + 1 == BUCKETS {
                    out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {cum}\n"));
                } else {
                    out.push_str(&format!("{name}_bucket{{le=\"{}\"}} {cum}\n", bucket_bound(i)));
                }
            }
            out.push_str(&format!("{name}_sum {}\n{name}_count {}\n", h.sum(), h.count()));
        }
        out
    }
}

/// The process-global registry for recorders with no engine in reach
/// (grid cells, dse chunks). Never rendered onto the wire.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_is_sorted_and_unique_by_name() {
        for pair in METRICS.windows(2) {
            assert!(pair[0].name < pair[1].name, "METRICS out of order at {}", pair[1].name);
        }
    }

    #[test]
    fn register_or_get_returns_the_same_handle() {
        let reg = Registry::new();
        let a = reg.counter("x");
        let b = reg.counter("x");
        a.inc();
        assert_eq!(b.get(), 1);
    }

    #[test]
    fn snapshot_json_has_sorted_sections() {
        let reg = Registry::new();
        reg.counter("b").add(2);
        reg.counter("a").inc();
        reg.gauge("g").set(7);
        reg.histogram("h").record(3);
        let snap = reg.snapshot_json().to_string();
        assert!(snap.starts_with(r#"{"counters":{"a":1,"b":2},"gauges":{"g":7},"histograms":"#));
        assert!(snap.contains(r#""h":{"count":1,"max_us":3,"mean_us":3"#));
    }

    #[test]
    fn prometheus_exposition_renders_cumulative_buckets() {
        let reg = Registry::new();
        reg.counter("hits").add(4);
        let h = reg.histogram("lat");
        h.record(1);
        h.record(100);
        let text = reg.render_prometheus();
        assert!(text.contains("# TYPE hits counter\nhits 4\n"));
        assert!(text.contains("lat_bucket{le=\"1\"} 1\n"));
        assert!(text.contains("lat_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("lat_sum 101\nlat_count 2\n"));
    }

    #[test]
    fn catalog_registration_matches_engine_scope() {
        let reg = Registry::new();
        register_catalog(&reg);
        let snap = reg.snapshot_json().to_string();
        for m in &METRICS {
            if m.scope == "engine" {
                assert!(snap.contains(&format!("\"{}\":", m.name)), "{} missing", m.name);
            } else {
                assert!(!snap.contains(m.name), "{} should not be engine-scoped", m.name);
            }
        }
    }
}
