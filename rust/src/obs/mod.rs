//! Observability: metrics, span tracing and the stats snapshot
//! registry.
//!
//! Three zero-dependency pieces:
//!
//! * [`metrics`] — typed counters, gauges and log-2-bucket latency
//!   histograms (lock-free increments, mergeable across threads);
//! * [`span`] — scoped stage timers feeding a bounded ring buffer with
//!   an explicit `dropped` counter (the sim-trace discipline);
//! * [`registry`] — named-metric registries rendering a sorted-key JSON
//!   snapshot and a Prometheus-style text exposition, plus the typed
//!   [`registry::METRICS`] catalog every recorder registers from.
//!
//! The wiring: each [`crate::api::Engine`] owns a registry (per-command
//! latency histograms, request counters, serve counters, pool
//! queue-wait) and answers `{"cmd":"stats"}` from it; `analytics::grid`
//! and `dse::explore` time their cells/chunks into the process-global
//! registry and span log. `docs/OBSERVABILITY.md` is the human
//! reference.
//!
//! That document is generated from the typed catalog and the pinned
//! stats fixture, and this doc-test keeps it honest — the metric table
//! must appear verbatim, and so must every line of the
//! `{"cmd":"stats"}` golden fixture:
//!
//! ```
//! let root = concat!(env!("CARGO_MANIFEST_DIR"), "/..");
//! let doc = std::fs::read_to_string(format!("{root}/docs/OBSERVABILITY.md"))
//!     .expect("docs/OBSERVABILITY.md exists");
//! assert!(
//!     doc.contains(&psim::obs::registry::metrics_table()),
//!     "OBSERVABILITY.md metric table is stale"
//! );
//! let fixture = std::fs::read_to_string(format!("{root}/rust/tests/golden/protocol/stats.txt"))
//!     .expect("stats fixture");
//! for line in fixture.lines() {
//!     assert!(doc.contains(line), "OBSERVABILITY.md stats example drifted from its fixture");
//! }
//! for stage in ["queue_wait", "decode", "dispatch", "encode", "write", "grid_cell", "dse_chunk"] {
//!     assert!(doc.contains(&format!("`{stage}`")), "OBSERVABILITY.md missing stage {stage}");
//! }
//! ```

pub mod metrics;
pub mod registry;
pub mod span;
