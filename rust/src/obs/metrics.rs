//! Typed metric primitives: counters, gauges, and log-2-bucket latency
//! histograms.
//!
//! Everything here is lock-free (plain `AtomicU64` increments) so the
//! hot paths — `api::Engine::dispatch`, the pooled `serve` workers, the
//! sweep/explore closures — can record without contention. Histograms
//! are mergeable across threads: per-thread instances can be folded
//! into one with [`Histogram::merge`] and the result is identical to a
//! single-thread recording of the union (bucket counts, count, sum and
//! max are all additive or max-combining).
//!
//! The histogram generalizes [`crate::util::benchkit::percentile`]
//! (nearest-rank on a sorted slice) onto fixed log-2 buckets: the rank
//! rule is the same, but the walk runs over cumulative bucket counts
//! and returns the matched bucket's upper bound — within one bucket
//! width of the raw-sample percentile by construction.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// Number of log-2 histogram buckets. Bucket `i` (for `0 < i <
/// BUCKETS-1`) holds values in `[2^(i-1), 2^i - 1]`; bucket 0 holds
/// exactly 0 and the last bucket is the overflow bucket. 32 buckets
/// cover `[0, 2^30]` microseconds (~18 minutes) before overflow.
pub const BUCKETS: usize = 32;

/// Upper bound (inclusive) of bucket `i`. The overflow bucket reports
/// `u64::MAX`; the Prometheus exposition renders it as `+Inf`.
pub fn bucket_bound(i: usize) -> u64 {
    if i + 1 >= BUCKETS {
        u64::MAX
    } else if i == 0 {
        0
    } else {
        (1u64 << i) - 1
    }
}

/// Bucket index for a recorded value: 0 for 0, else `bit_length(v)`
/// clamped into the overflow bucket.
fn bucket_index(v: u64) -> usize {
    ((64 - v.leading_zeros()) as usize).min(BUCKETS - 1)
}

/// A monotonically increasing event counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    /// A counter at zero.
    pub fn new() -> Counter {
        Counter::default()
    }

    /// Add 1 and return the new value (handy for "how many so far" logs).
    pub fn inc(&self) -> u64 {
        self.value.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// A gauge: a value that can be set or high-water-marked.
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicU64,
}

impl Gauge {
    /// A gauge at zero.
    pub fn new() -> Gauge {
        Gauge::default()
    }

    /// Overwrite the value.
    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
    }

    /// Raise the value to `v` if `v` is larger (high-water mark).
    pub fn note_max(&self, v: u64) {
        self.value.fetch_max(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// A fixed-bucket log-2 latency histogram (values in microseconds by
/// convention — the snapshot keys say so explicitly).
///
/// Reads under concurrent writes are racy-but-monotone: a snapshot may
/// miss in-flight increments but never observes torn values.
#[derive(Debug)]
pub struct Histogram {
    counts: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Record one value.
    pub fn record(&self, v: u64) {
        self.counts[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Fold another histogram into this one (per-thread aggregation).
    pub fn merge(&self, other: &Histogram) {
        for (mine, theirs) in self.counts.iter().zip(other.counts.iter()) {
            mine.fetch_add(theirs.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max.fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of recorded values.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Largest recorded value (0 when empty).
    pub fn max_value(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Truncating mean of recorded values (0 when empty).
    pub fn mean(&self) -> u64 {
        let n = self.count();
        if n == 0 { 0 } else { self.sum() / n }
    }

    /// Raw bucket counts (index `i` per [`bucket_bound`]).
    pub fn bucket_counts(&self) -> [u64; BUCKETS] {
        std::array::from_fn(|i| self.counts[i].load(Ordering::Relaxed))
    }

    /// Nearest-rank percentile over the buckets: the same rank rule as
    /// [`crate::util::benchkit::percentile`], walked over cumulative
    /// bucket counts. Returns the matched bucket's upper bound clamped
    /// to the observed max — at most one bucket width above the
    /// raw-sample percentile.
    pub fn percentile(&self, p: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((p * n as f64).ceil() as u64).clamp(1, n);
        let mut cum = 0u64;
        for i in 0..BUCKETS {
            cum += self.counts[i].load(Ordering::Relaxed);
            if cum >= rank {
                return bucket_bound(i).min(self.max_value());
            }
        }
        // Racy snapshot (count ahead of bucket increments): report max.
        self.max_value()
    }

    /// Sorted-key JSON summary — the per-histogram object in the
    /// `{"cmd":"stats"}` snapshot. Bucket detail stays out of the wire
    /// schema; it is available via the Prometheus exposition.
    pub fn snapshot_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("max_us", Json::Num(self.max_value() as f64)),
            ("mean_us", Json::Num(self.mean() as f64)),
            ("p50_us", Json::Num(self.percentile(0.50) as f64)),
            ("p95_us", Json::Num(self.percentile(0.95) as f64)),
            ("p99_us", Json::Num(self.percentile(0.99) as f64)),
            ("sum_us", Json::Num(self.sum() as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_bounds_are_monotone_and_cover_u64() {
        for i in 1..BUCKETS {
            assert!(bucket_bound(i) > bucket_bound(i - 1), "bucket {i} not monotone");
        }
        assert_eq!(bucket_bound(0), 0);
        assert_eq!(bucket_bound(BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn bucket_index_matches_bounds() {
        for v in [0u64, 1, 2, 3, 4, 7, 8, 1000, u64::MAX] {
            let i = bucket_index(v);
            assert!(v <= bucket_bound(i), "v={v} above bound of bucket {i}");
            if i > 0 {
                assert!(v > bucket_bound(i - 1), "v={v} should be in an earlier bucket than {i}");
            }
        }
    }

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        assert_eq!(c.inc(), 1);
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.note_max(3);
        g.note_max(1);
        assert_eq!(g.get(), 3);
        g.set(2);
        assert_eq!(g.get(), 2);
    }

    #[test]
    fn empty_histogram_reports_zeroes() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(0.99), 0);
        assert_eq!(h.mean(), 0);
        assert_eq!(
            h.snapshot_json().to_string(),
            r#"{"count":0,"max_us":0,"mean_us":0,"p50_us":0,"p95_us":0,"p99_us":0,"sum_us":0}"#
        );
    }

    #[test]
    fn percentile_is_clamped_to_the_observed_max() {
        let h = Histogram::new();
        h.record(1000); // bucket upper bound 1023
        assert_eq!(h.percentile(0.5), 1000);
        assert_eq!(h.max_value(), 1000);
    }

    #[test]
    fn merge_is_additive() {
        let (a, b) = (Histogram::new(), Histogram::new());
        for v in [1u64, 5, 9] {
            a.record(v);
        }
        for v in [2u64, 1_000_000] {
            b.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), 5);
        assert_eq!(a.sum(), 1 + 5 + 9 + 2 + 1_000_000);
        assert_eq!(a.max_value(), 1_000_000);
    }
}
