//! The explorer: admissible bound → prune → exact evaluation → Pareto
//! frontier, fanned out over [`crate::coordinator::parallel`] workers
//! with byte-identical output for any worker count.
//!
//! Algorithm, per scope (each network alone, plus the whole-zoo
//! aggregate when several networks are explored):
//!
//! 1. **Bound** every candidate with the channel-only eqs. 2–3 cost
//!    ([`super::metrics::scope_bound_stats`], served by the grid
//!    engine's layer-shape memo cache) — a vector that is component-wise
//!    `<=` the exact one, computed in parallel for all candidates.
//! 2. **Prune** a candidate when its bound is already dominated by an
//!    exactly-evaluated design: since `bound <= exact`, dominance over
//!    the bound implies dominance over the exact vector — the prune is
//!    lossless (pinned by `rust/tests/dse_frontier.rs`).
//! 3. **Evaluate** the survivors exactly (SRAM-striped metrics) in
//!    fixed-size chunks over the worker pool; the archive of exact
//!    vectors grows in candidate order, so decisions are deterministic.
//! 4. **Extract** the frontier: the non-dominated archive entries, in
//!    candidate order.

use crate::analytics::grid::GridEngine;
use crate::coordinator::parallel::parallel_map;
use crate::models::{DataTypes, Network};
use crate::sim::interconnect::BusConfig;
use crate::util::json::Json;

use super::budget::SramBudget;
use super::metrics::{scope_bound_stats, scope_stats};
use super::pareto::{dominates, pareto_indices, Objectives};
use super::space::{DesignPoint, ExploreSpec};

/// Scope label of the whole-zoo aggregate frontier (objectives summed
/// over every network in the spec).
pub const ZOO_SCOPE: &str = "zoo";

/// Candidates considered per pruning round. Fixed (not worker-derived) so
/// prune decisions — and therefore the output bytes — are identical for
/// any `--workers` value.
const CHUNK: usize = 16;

/// One frontier member.
#[derive(Clone, Debug)]
pub struct FrontierPoint {
    /// Network name, or [`ZOO_SCOPE`] for the whole-zoo aggregate.
    pub scope: String,
    /// The winning hardware/policy candidate.
    pub point: DesignPoint,
    /// Its objective vector.
    pub objectives: Objectives,
    /// The precision the exploration was priced under.
    pub dt: DataTypes,
}

impl FrontierPoint {
    /// Stable JSONL record. Every number is integer-valued (energy in
    /// whole picojoules, utilization in parts-per-million), so the bytes
    /// are platform- and worker-count-independent. The `fusion` key
    /// appears only on fused points (depth > 1) and the `bits`/
    /// `bandwidth_bytes` keys only under a non-default precision,
    /// keeping default frontiers byte-identical to earlier formats.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("network", Json::Str(self.scope.clone())),
            ("p_macs", Json::Num(self.point.p_macs as f64)),
            ("sram", Json::Str(self.point.sram.label())),
            ("strategy", Json::Str(self.point.strategy.slug().to_string())),
            ("mode", Json::Str(self.point.mode.label().to_string())),
            ("bandwidth", Json::Num(self.objectives.bandwidth)),
            ("sram_accesses", Json::Num(self.objectives.sram_accesses)),
            ("energy_pj", Json::Num(self.objectives.energy_pj.round())),
            ("mac_util_ppm", Json::Num((self.objectives.mac_utilization * 1e6).round())),
        ];
        if self.point.fusion > 1 {
            pairs.push(("fusion", Json::Num(self.point.fusion as f64)));
        }
        if !self.dt.is_default() {
            pairs.push(("bits", Json::Str(self.dt.label())));
            pairs.push(("bandwidth_bytes", Json::Num(self.objectives.bandwidth_bytes)));
        }
        Json::obj(pairs)
    }
}

/// A candidate skipped because its admissible bound was already dominated
/// by an exactly-evaluated design.
#[derive(Clone, Debug)]
pub struct PrunedPoint {
    /// Network name, or [`ZOO_SCOPE`].
    pub scope: String,
    /// The candidate that was skipped.
    pub point: DesignPoint,
}

/// Everything one exploration produced.
#[derive(Clone, Debug)]
pub struct ExploreResult {
    /// Frontier members: per-scope frontiers concatenated in scope order
    /// (networks in spec order, then the zoo aggregate), candidate
    /// enumeration order within each scope.
    pub frontier: Vec<FrontierPoint>,
    /// Exact evaluations performed (including infeasible discoveries).
    pub evaluated: usize,
    /// Candidates pruned on their bound, without exact evaluation.
    pub pruned: Vec<PrunedPoint>,
    /// Candidates whose SRAM budget cannot hold even one-row stripes.
    pub infeasible: usize,
    /// Total candidates considered (scopes × design points).
    pub candidates: usize,
}

impl ExploreResult {
    /// The frontier as JSON-lines text (one record per point, trailing
    /// newline). Byte-identical across worker counts.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for fp in &self.frontier {
            out.push_str(&fp.to_json().to_string());
            out.push('\n');
        }
        out
    }

    /// Frontier members of one scope, in candidate order.
    pub fn frontier_for(&self, scope: &str) -> Vec<&FrontierPoint> {
        self.frontier.iter().filter(|f| f.scope == scope).collect()
    }
}

/// Explore `spec` over `workers` threads. Output order and content are
/// independent of `workers`.
///
/// # Panics
///
/// Panics if the spec fails [`ExploreSpec::validate`] — CLI and serve
/// validate first, so an invalid spec here is a programming error.
pub fn explore(engine: &GridEngine, spec: &ExploreSpec, workers: usize) -> ExploreResult {
    spec.validate().expect("invalid explore spec");
    // The default precision keeps the legacy uniform-elem_bytes bus so
    // pinned frontiers stay byte-identical; a non-default precision
    // prices each region at its own width (and the same `dt` selects
    // byte-weighted partitions inside scope_stats).
    let dt = spec.datatypes;
    let bus =
        if dt.is_default() { BusConfig::default() } else { BusConfig::with_datatypes(&dt) };
    let points = spec.points();
    let workers = workers.max(1);

    // Scopes: each network alone, plus the whole-zoo aggregate.
    let mut scopes: Vec<(String, Vec<&Network>)> =
        spec.networks.iter().map(|n| (n.name.clone(), vec![n])).collect();
    if spec.networks.len() > 1 {
        scopes.push((ZOO_SCOPE.to_string(), spec.networks.iter().collect()));
    }

    // Phase 1: admissible bounds for every (scope, point), in parallel.
    let mut bound_jobs: Vec<(usize, usize)> = Vec::with_capacity(scopes.len() * points.len());
    for si in 0..scopes.len() {
        for pi in 0..points.len() {
            bound_jobs.push((si, pi));
        }
    }
    let bounds: Vec<Objectives> = parallel_map(&bound_jobs, workers, |&(si, pi)| {
        let stats = scope_bound_stats(engine, &scopes[si].1, &points[pi], &bus);
        Objectives::from_stats_dt(&stats, points[pi].p_macs, &dt)
    });

    // Phase 2: chunked exact evaluation with archive-based pruning.
    // Per-chunk wall time feeds the host-side observability registry
    // (`dse_chunk_eval_us`); the frontier itself is unaffected.
    let chunk_hist = crate::obs::registry::global().histogram("dse_chunk_eval_us");
    let mut frontier = Vec::new();
    let mut pruned = Vec::new();
    let mut evaluated = 0usize;
    let mut infeasible = 0usize;

    for (si, (scope_name, nets)) in scopes.iter().enumerate() {
        // Exact vectors in candidate order: (point index, objectives).
        let mut archive: Vec<(usize, Objectives)> = Vec::new();
        for chunk_start in (0..points.len()).step_by(CHUNK) {
            let chunk_end = (chunk_start + CHUNK).min(points.len());
            let mut survivors: Vec<usize> = Vec::new();
            for pi in chunk_start..chunk_end {
                let bound = &bounds[si * points.len() + pi];
                if archive.iter().any(|(_, e)| dominates(e, bound, &spec.objectives)) {
                    pruned.push(PrunedPoint { scope: scope_name.clone(), point: points[pi] });
                } else {
                    survivors.push(pi);
                }
            }
            let chunk_started = std::time::Instant::now();
            let exacts: Vec<Option<Objectives>> = parallel_map(&survivors, workers, |&pi| {
                // An unconstrained candidate's bound IS its exact vector
                // (no striping to apply) — don't evaluate it twice.
                if points[pi].sram == SramBudget::Unlimited {
                    return Some(bounds[si * points.len() + pi]);
                }
                scope_stats(engine, nets, &points[pi], &bus)
                    .map(|s| Objectives::from_stats_dt(&s, points[pi].p_macs, &dt))
            });
            let chunk_us = chunk_started.elapsed().as_micros() as u64;
            chunk_hist.record(chunk_us);
            crate::obs::span::global().record_us(crate::obs::span::stage::DSE_CHUNK, chunk_us);
            for (pi, exact) in survivors.iter().zip(&exacts) {
                evaluated += 1;
                match exact {
                    Some(o) => archive.push((*pi, *o)),
                    None => infeasible += 1,
                }
            }
        }
        let objs: Vec<Objectives> = archive.iter().map(|(_, o)| *o).collect();
        for idx in pareto_indices(&objs, &spec.objectives) {
            let (pi, o) = archive[idx];
            frontier.push(FrontierPoint {
                scope: scope_name.clone(),
                point: points[pi],
                objectives: o,
                dt,
            });
        }
    }

    ExploreResult {
        frontier,
        evaluated,
        pruned,
        infeasible,
        candidates: scopes.len() * points.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::bandwidth::ControllerMode;
    use crate::analytics::partition::Strategy;
    use crate::dse::budget::SramBudget;
    use crate::models::zoo;

    #[test]
    fn active_dominates_passive_for_fixed_partition() {
        // MaxInput picks the same (m, n) in both modes; the active
        // controller then strictly wins on bandwidth and energy at equal
        // utilization and SRAM accesses, so only 'active' can survive.
        let spec = ExploreSpec::new(vec![zoo::alexnet()])
            .with_macs(vec![512])
            .with_sram(vec![SramBudget::Unlimited])
            .with_strategies(vec![Strategy::MaxInput]);
        let result = explore(&GridEngine::new(), &spec, 1);
        assert_eq!(result.candidates, 2);
        let modes: Vec<&str> = result.frontier.iter().map(|f| f.point.mode.label()).collect();
        assert_eq!(modes, vec!["active"]);
    }

    #[test]
    fn frontier_covers_every_scope_and_zoo() {
        let spec = ExploreSpec::new(vec![zoo::alexnet(), zoo::resnet18()])
            .with_macs(vec![512, 2048])
            .with_sram(vec![SramBudget::Unlimited])
            .with_strategies(vec![Strategy::Optimal]);
        let result = explore(&GridEngine::new(), &spec, 2);
        assert_eq!(result.candidates, 3 * 4);
        for scope in ["AlexNet", "ResNet-18", ZOO_SCOPE] {
            assert!(!result.frontier_for(scope).is_empty(), "no frontier for {scope}");
        }
        // a bigger MAC budget strictly improves bandwidth, so at least
        // two points (P=512 high-util vs P=2048 low-bandwidth) coexist
        assert!(result.frontier_for("AlexNet").len() >= 2);
    }

    #[test]
    fn tiny_sram_counts_infeasible() {
        let spec = ExploreSpec::new(vec![zoo::alexnet()])
            .with_macs(vec![1024])
            .with_sram(vec![SramBudget::Elems(16)])
            .with_strategies(vec![Strategy::Optimal])
            .with_modes(vec![ControllerMode::Passive]);
        let result = explore(&GridEngine::new(), &spec, 1);
        assert_eq!(result.infeasible, 1);
        assert!(result.frontier.is_empty());
    }

    #[test]
    fn accounting_adds_up() {
        let spec = ExploreSpec::new(vec![zoo::squeezenet1_0()]);
        let result = explore(&GridEngine::new(), &spec, 3);
        assert_eq!(result.candidates, spec.candidate_count());
        assert_eq!(result.evaluated + result.pruned.len(), result.candidates);
        // the admissible bound must actually prune something on the
        // default axes (dominated passive/heuristic cells abound)
        assert!(!result.pruned.is_empty(), "bound pruned nothing");
    }

    #[test]
    fn fusion_axis_joins_the_frontier() {
        // With unlimited SRAM and a fixed partition policy, the fused
        // design strictly wins bandwidth at equal utilization, so every
        // frontier point is fused — and carries the `fusion` JSONL key.
        let spec = ExploreSpec::new(vec![zoo::alexnet()])
            .with_macs(vec![1024])
            .with_sram(vec![SramBudget::Unlimited])
            .with_strategies(vec![Strategy::Optimal])
            .with_modes(vec![ControllerMode::Active])
            .with_fusion(vec![1, 2]);
        let result = explore(&GridEngine::new(), &spec, 1);
        assert_eq!(result.candidates, 2);
        assert!(!result.frontier.is_empty());
        assert!(result.frontier.iter().all(|f| f.point.fusion == 2));
        for fp in &result.frontier {
            assert_eq!(fp.to_json().get("fusion").unwrap().as_usize(), Some(2));
        }
        // worker-count independence holds on a fused space too
        let spec = spec.with_sram(vec![SramBudget::Unlimited, SramBudget::Elems(1 << 16)]);
        let one = explore(&GridEngine::new(), &spec, 1);
        let four = explore(&GridEngine::new(), &spec, 4);
        assert_eq!(one.to_jsonl(), four.to_jsonl());
    }

    #[test]
    fn bytes_objective_and_bits_tag_the_frontier() {
        use crate::dse::pareto::Objective;
        let dt = DataTypes::parse("8:8:32:8").unwrap();
        let spec = ExploreSpec::new(vec![zoo::alexnet()])
            .with_macs(vec![1024])
            .with_sram(vec![SramBudget::Unlimited])
            .with_strategies(vec![Strategy::MaxInput])
            .with_datatypes(dt)
            .with_objectives(vec![Objective::BandwidthBytes, Objective::Utilization]);
        let result = explore(&GridEngine::new(), &spec, 1);
        assert!(!result.frontier.is_empty());
        for fp in &result.frontier {
            let j = fp.to_json();
            assert_eq!(j.get("bits").unwrap().as_str(), Some("8:8:32:8"));
            let bytes = j.get("bandwidth_bytes").unwrap().as_f64().unwrap();
            let elems = j.get("bandwidth").unwrap().as_f64().unwrap();
            assert!(bytes > elems, "32-bit psums must cost more bytes than elements");
        }
        // fixed partition (MaxInput is mode-agnostic): the active
        // controller's byte saving dominates, so only 'active' survives
        // the bytes objective.
        let modes: Vec<&str> = result.frontier.iter().map(|f| f.point.mode.label()).collect();
        assert_eq!(modes, vec!["active"]);
        // default precision leaves the keys off
        let plain = explore(&GridEngine::new(), &ExploreSpec::new(vec![zoo::alexnet()]), 1);
        assert!(plain.frontier.iter().all(|f| f.to_json().get("bits").is_none()));
        // worker-count independence holds under a non-default precision
        let one = explore(&GridEngine::new(), &spec, 1);
        let four = explore(&GridEngine::new(), &spec, 4);
        assert_eq!(one.to_jsonl(), four.to_jsonl());
    }

    #[test]
    fn byte_bound_stays_admissible_under_wide_psums() {
        // The pruning bound must remain component-wise <= the exact
        // vector when regions are priced at their own widths.
        use crate::dse::metrics::{scope_bound_stats, scope_stats};
        use crate::sim::interconnect::BusConfig;
        let net = zoo::alexnet();
        let engine = GridEngine::new();
        let dt = DataTypes::parse("8:8:32:8").unwrap();
        let bus = BusConfig::with_datatypes(&dt);
        for fusion in [1usize, 2] {
            for mode in crate::analytics::bandwidth::ControllerMode::ALL {
                let point = crate::dse::space::DesignPoint {
                    p_macs: 1024,
                    sram: SramBudget::Elems(1 << 16),
                    strategy: Strategy::Optimal,
                    mode,
                    fusion,
                };
                let bound = scope_bound_stats(&engine, &[&net], &point, &bus);
                let Some(exact) = scope_stats(&engine, &[&net], &point, &bus) else {
                    continue;
                };
                assert!(bound.activation_bytes(&dt) <= exact.activation_bytes(&dt));
                assert!(bound.bus_beats <= exact.bus_beats);
                assert!(bound.energy_pj <= exact.energy_pj);
                assert_eq!(bound.macs, exact.macs);
            }
        }
    }

    #[test]
    #[should_panic(expected = "invalid explore spec")]
    fn invalid_spec_panics() {
        let spec = ExploreSpec::new(vec![zoo::alexnet()]).with_macs(vec![]);
        explore(&GridEngine::new(), &spec, 1);
    }
}
