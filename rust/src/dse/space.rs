//! The design space: hardware/policy axes, their deterministic
//! enumeration, and the serve-protocol spec parser.
//!
//! A [`DesignPoint`] is one hardware/policy candidate — MAC budget `P`,
//! on-chip SRAM capacity, partitioning strategy, controller mode, and
//! inter-layer fusion depth. The per-layer partition parameters `(m, n)`
//! and stripe height `t` are not axes: they are chosen *within* each
//! point (strategy under eq. 1 for the channels, tallest-fitting stripe
//! under the SRAM budget for the plane or the fused chain), exactly as a
//! compiler would configure a fixed chip.

use anyhow::{bail, Result};

use crate::analytics::bandwidth::ControllerMode;
use crate::analytics::paper;
use crate::analytics::partition::Strategy;
use crate::models::{DataTypes, Network};
use crate::util::json::Json;

use super::budget::{SramBudget, DEFAULT_SRAM_BUDGETS};
use super::pareto::Objective;

/// One hardware/policy candidate.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct DesignPoint {
    /// MAC budget `P` (eq. 1's constraint bound).
    pub p_macs: usize,
    /// On-chip SRAM capacity.
    pub sram: SramBudget,
    /// Per-layer channel-partitioning policy.
    pub strategy: Strategy,
    /// Memory-controller capability.
    pub mode: ControllerMode,
    /// Inter-layer fusion depth (1 = the paper's unfused model; `d > 1`
    /// evaluates chains of up to `d` layers in fused tiles — see
    /// [`crate::analytics::fusion`]).
    pub fusion: usize,
}

impl DesignPoint {
    /// Human/filterable key, e.g. `P1024|sram:unlimited|optimal|active`
    /// (fused points append `|fused2` etc.).
    pub fn key(&self) -> String {
        let mut key = format!(
            "P{}|sram:{}|{}|{}",
            self.p_macs,
            self.sram.label(),
            self.strategy.slug(),
            self.mode.label()
        );
        if self.fusion > 1 {
            key.push_str(&format!("|fused{}", self.fusion));
        }
        key
    }
}

/// A declarative exploration space: the Cartesian product of four
/// hardware/policy axes over a set of networks, plus the objective mask
/// the Pareto frontier is computed over.
///
/// ```
/// use psim::dse::space::ExploreSpec;
/// use psim::models::zoo;
///
/// let spec = ExploreSpec::new(vec![zoo::alexnet()]);
/// // 6 MAC budgets x 4 SRAM budgets x 4 strategies x 2 modes
/// assert_eq!(spec.points_per_network(), 192);
/// assert_eq!(spec.points().len(), 192);
/// ```
#[derive(Clone, Debug)]
pub struct ExploreSpec {
    /// Networks to explore (resolved descriptors, not names).
    pub networks: Vec<Network>,
    /// MAC budgets `P`.
    pub mac_budgets: Vec<usize>,
    /// On-chip SRAM capacities.
    pub sram_budgets: Vec<SramBudget>,
    /// Partitioning strategies.
    pub strategies: Vec<Strategy>,
    /// Memory-controller modes.
    pub modes: Vec<ControllerMode>,
    /// Inter-layer fusion depths (default: `[1]`, the unfused model).
    pub fusion_depths: Vec<usize>,
    /// Objectives the frontier is computed over (default: all four).
    pub objectives: Vec<Objective>,
    /// Per-tensor precision the whole exploration is priced under (not an
    /// axis: one currency per frontier). The default uniform 8-bit keeps
    /// frontiers byte-identical to the element model; wide psums shift
    /// byte-optimal partitions and enable the `bandwidth-bytes`
    /// objective's re-ranking.
    pub datatypes: DataTypes,
}

impl ExploreSpec {
    /// A spec over explicit networks with default axes: the paper's six
    /// Table II MAC budgets, [`DEFAULT_SRAM_BUDGETS`], the four Table I
    /// strategies, both controller modes, all four objectives.
    pub fn new(networks: Vec<Network>) -> ExploreSpec {
        ExploreSpec {
            networks,
            mac_budgets: paper::TABLE2_MACS.to_vec(),
            sram_budgets: DEFAULT_SRAM_BUDGETS.to_vec(),
            strategies: Strategy::TABLE1.to_vec(),
            modes: ControllerMode::ALL.to_vec(),
            fusion_depths: vec![1],
            objectives: Objective::ALL.to_vec(),
            datatypes: DataTypes::default(),
        }
    }

    /// The default space over the paper's eight networks.
    pub fn paper_space() -> ExploreSpec {
        ExploreSpec::new(crate::models::zoo::paper_networks())
    }

    /// Replace the MAC-budget axis.
    pub fn with_macs(mut self, macs: Vec<usize>) -> ExploreSpec {
        self.mac_budgets = macs;
        self
    }

    /// Replace the SRAM-capacity axis.
    pub fn with_sram(mut self, sram: Vec<SramBudget>) -> ExploreSpec {
        self.sram_budgets = sram;
        self
    }

    /// Replace the strategy axis.
    pub fn with_strategies(mut self, strategies: Vec<Strategy>) -> ExploreSpec {
        self.strategies = strategies;
        self
    }

    /// Replace the controller-mode axis.
    pub fn with_modes(mut self, modes: Vec<ControllerMode>) -> ExploreSpec {
        self.modes = modes;
        self
    }

    /// Replace the objective mask.
    pub fn with_objectives(mut self, objectives: Vec<Objective>) -> ExploreSpec {
        self.objectives = objectives;
        self
    }

    /// Replace the fusion-depth axis.
    pub fn with_fusion(mut self, fusion_depths: Vec<usize>) -> ExploreSpec {
        self.fusion_depths = fusion_depths;
        self
    }

    /// Set the pricing precision (`--bits` on the CLI, `bits` on the
    /// wire).
    pub fn with_datatypes(mut self, datatypes: DataTypes) -> ExploreSpec {
        self.datatypes = datatypes;
        self
    }

    /// Design points in enumeration order (MACs, then SRAM, then
    /// strategy, then mode, then fusion depth) — the order frontier
    /// output follows.
    pub fn points(&self) -> Vec<DesignPoint> {
        let mut out = Vec::with_capacity(self.points_per_network());
        for &p_macs in &self.mac_budgets {
            for &sram in &self.sram_budgets {
                for &strategy in &self.strategies {
                    for &mode in &self.modes {
                        for &fusion in &self.fusion_depths {
                            out.push(DesignPoint { p_macs, sram, strategy, mode, fusion });
                        }
                    }
                }
            }
        }
        out
    }

    /// Candidates per exploration scope. Saturates instead of wrapping,
    /// so a maliciously huge request cannot overflow past the
    /// dispatcher's size cap and slip through as a tiny count.
    pub fn points_per_network(&self) -> usize {
        self.mac_budgets
            .len()
            .saturating_mul(self.sram_budgets.len())
            .saturating_mul(self.strategies.len())
            .saturating_mul(self.modes.len())
            .saturating_mul(self.fusion_depths.len())
    }

    /// Total candidates the explorer will consider: one scope per network
    /// plus, with several networks, the whole-zoo aggregate scope.
    pub fn candidate_count(&self) -> usize {
        let scopes = self.networks.len().saturating_add(usize::from(self.networks.len() > 1));
        scopes.saturating_mul(self.points_per_network())
    }

    /// Every axis non-empty and numerically sane.
    pub fn validate(&self) -> Result<()> {
        if self.networks.is_empty() {
            bail!("explore spec has no networks");
        }
        if self.mac_budgets.is_empty() || self.mac_budgets.contains(&0) {
            bail!("explore spec needs at least one MAC budget, all > 0");
        }
        if self.sram_budgets.is_empty() {
            bail!("explore spec has no SRAM budgets");
        }
        if self.sram_budgets.iter().any(|s| s.elems() == Some(0)) {
            bail!("SRAM budgets must be > 0 elements");
        }
        if self.strategies.is_empty() {
            bail!("explore spec has no strategies");
        }
        if self.modes.is_empty() {
            bail!("explore spec has no controller modes");
        }
        if self.fusion_depths.is_empty() || self.fusion_depths.contains(&0) {
            bail!("explore spec needs at least one fusion depth, all >= 1");
        }
        if self.objectives.is_empty() {
            bail!("explore spec has no objectives");
        }
        Ok(())
    }

    /// Build a spec from a JSON request object (the serve protocol's
    /// `{"cmd":"explore", ...}` body). Every axis is optional and
    /// defaults to the paper space; unknown keys are rejected. All axis
    /// parsing delegates to [`crate::api::codec`], the single set of
    /// parsers shared with [`crate::analytics::grid::SweepSpec`].
    ///
    /// Axis keys: `networks` (names), `macs`, `sram` (element counts or
    /// strings like `"64k"`/`"unlimited"`), `strategies`, `modes`,
    /// `fusion` (a depth or an array of depths), `objectives`, `bits` (a
    /// single `"ifmap:weight:psum:ofmap"` precision string — one pricing
    /// currency per frontier, plus the protocol's `cmd`, `workers` and
    /// `protocol`).
    pub fn from_json(msg: &Json) -> Result<ExploreSpec> {
        use crate::api::codec;
        const KNOWN: [&str; 11] = [
            "cmd",
            "networks",
            "macs",
            "sram",
            "strategies",
            "modes",
            "fusion",
            "objectives",
            "bits",
            "workers",
            "protocol",
        ];
        codec::reject_unknown_keys(msg, &KNOWN, "explore")?;
        let mut spec = ExploreSpec::paper_space();
        if let Some(nets) = msg.get("networks") {
            spec.networks = codec::networks_axis(nets)?;
        }
        if let Some(macs) = msg.get("macs") {
            spec.mac_budgets = codec::usize_axis(macs, "macs", "non-negative")?;
        }
        if let Some(sram) = msg.get("sram") {
            spec.sram_budgets = codec::sram_axis(sram)?;
        }
        if let Some(strats) = msg.get("strategies") {
            spec.strategies = codec::strategies_axis(strats)?;
        }
        if let Some(modes) = msg.get("modes") {
            spec.modes = codec::modes_axis(modes)?;
        }
        if let Some(fusion) = msg.get("fusion") {
            spec.fusion_depths = codec::fusion_axis(fusion)?;
        }
        if let Some(objs) = msg.get("objectives") {
            spec.objectives = codec::objectives_axis(objs)?;
        }
        if let Some(bits) = msg.get("bits") {
            spec.datatypes = codec::bits_field(bits)?;
        }
        spec.validate()?;
        Ok(spec)
    }
}

impl Default for ExploreSpec {
    fn default() -> ExploreSpec {
        ExploreSpec::paper_space()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn points_enumerate_in_axis_order() {
        let spec = ExploreSpec::new(vec![zoo::alexnet()])
            .with_macs(vec![512, 2048])
            .with_sram(vec![SramBudget::Unlimited, SramBudget::Elems(65536)])
            .with_strategies(vec![Strategy::Optimal])
            .with_modes(vec![ControllerMode::Passive, ControllerMode::Active]);
        let keys: Vec<String> = spec.points().iter().map(|p| p.key()).collect();
        assert_eq!(
            keys,
            vec![
                "P512|sram:unlimited|optimal|passive",
                "P512|sram:unlimited|optimal|active",
                "P512|sram:65536|optimal|passive",
                "P512|sram:65536|optimal|active",
                "P2048|sram:unlimited|optimal|passive",
                "P2048|sram:unlimited|optimal|active",
                "P2048|sram:65536|optimal|passive",
                "P2048|sram:65536|optimal|active",
            ]
        );
        assert_eq!(spec.points_per_network(), 8);
        // single network: no zoo scope
        assert_eq!(spec.candidate_count(), 8);
    }

    #[test]
    fn zoo_scope_counts_once_extra() {
        let spec = ExploreSpec::paper_space();
        assert_eq!(spec.points_per_network(), 6 * 4 * 4 * 2);
        assert_eq!(spec.candidate_count(), (8 + 1) * 192);
    }

    #[test]
    fn from_json_defaults_and_overrides() {
        let msg = Json::parse(
            r#"{"cmd":"explore","networks":["AlexNet"],"macs":[1024],
                "sram":["unlimited",65536,"64k"],"strategies":["optimal"],
                "modes":["active"],"objectives":["bandwidth","energy"]}"#,
        )
        .unwrap();
        let spec = ExploreSpec::from_json(&msg).unwrap();
        assert_eq!(spec.networks.len(), 1);
        assert_eq!(spec.mac_budgets, vec![1024]);
        assert_eq!(
            spec.sram_budgets,
            vec![SramBudget::Unlimited, SramBudget::Elems(65536), SramBudget::Elems(65536)]
        );
        assert_eq!(spec.objectives, vec![Objective::Bandwidth, Objective::Energy]);

        let defaults =
            ExploreSpec::from_json(&Json::parse(r#"{"cmd":"explore"}"#).unwrap()).unwrap();
        assert_eq!(defaults.points_per_network(), 192);
        assert_eq!(defaults.objectives, Objective::ALL.to_vec());
    }

    #[test]
    fn fusion_axis_enumerates_and_parses() {
        let spec = ExploreSpec::new(vec![zoo::alexnet()])
            .with_macs(vec![1024])
            .with_sram(vec![SramBudget::Unlimited])
            .with_strategies(vec![Strategy::Optimal])
            .with_modes(vec![ControllerMode::Active])
            .with_fusion(vec![1, 2]);
        let keys: Vec<String> = spec.points().iter().map(|p| p.key()).collect();
        assert_eq!(
            keys,
            vec![
                "P1024|sram:unlimited|optimal|active",
                "P1024|sram:unlimited|optimal|active|fused2",
            ]
        );
        assert_eq!(spec.points_per_network(), 2);

        let msg =
            Json::parse(r#"{"cmd":"explore","networks":["AlexNet"],"fusion":[1,2]}"#).unwrap();
        assert_eq!(ExploreSpec::from_json(&msg).unwrap().fusion_depths, vec![1, 2]);
        let one = Json::parse(r#"{"cmd":"explore","fusion":3}"#).unwrap();
        assert_eq!(ExploreSpec::from_json(&one).unwrap().fusion_depths, vec![3]);
    }

    #[test]
    fn from_json_bits_field() {
        let msg =
            Json::parse(r#"{"cmd":"explore","networks":["AlexNet"],"bits":"8:8:32:8"}"#).unwrap();
        let spec = ExploreSpec::from_json(&msg).unwrap();
        assert_eq!(spec.datatypes, DataTypes::parse("8:8:32:8").unwrap());
        let defaults =
            ExploreSpec::from_json(&Json::parse(r#"{"cmd":"explore"}"#).unwrap()).unwrap();
        assert!(defaults.datatypes.is_default());
        for bad in [r#"{"bits":"8:8"}"#, r#"{"bits":["8:8:32:8"]}"#, r#"{"bits":7}"#] {
            assert!(ExploreSpec::from_json(&Json::parse(bad).unwrap()).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn from_json_rejects_bad_input() {
        for bad in [
            r#"{"networks":["NoSuchNet"]}"#,
            r#"{"macs":[0]}"#,
            r#"{"sram":[0]}"#,
            r#"{"sram":[true]}"#,
            r#"{"sram":"64k"}"#,
            r#"{"objectives":["latency"]}"#,
            r#"{"objectives":[]}"#,
            r#"{"fusion":0}"#,
            r#"{"fusion":[0]}"#,
            r#"{"fusion":"deep"}"#,
            r#"{"cmd":"explore","mac":[512]}"#,
        ] {
            let msg = Json::parse(bad).unwrap();
            assert!(ExploreSpec::from_json(&msg).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn validate_catches_empty_axes() {
        assert!(ExploreSpec::new(vec![]).validate().is_err());
        assert!(ExploreSpec::new(vec![zoo::alexnet()]).with_macs(vec![]).validate().is_err());
        assert!(ExploreSpec::new(vec![zoo::alexnet()])
            .with_sram(vec![SramBudget::Elems(0)])
            .validate()
            .is_err());
        assert!(ExploreSpec::new(vec![zoo::alexnet()]).with_objectives(vec![]).validate().is_err());
        assert!(ExploreSpec::paper_space().validate().is_ok());
    }
}
