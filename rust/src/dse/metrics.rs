//! Closed-form candidate metrics: the event simulator's [`SimStats`]
//! derived analytically from a layer's partition — no loop nest executed.
//!
//! Contract (pinned by `rust/tests/dse_frontier.rs`): for an unstriped
//! layer (`t = Ho`) every counter equals what
//! [`crate::sim::scheduler::simulate_layer_with`] produces, field for
//! field — the DSE scores candidates with simulator-exact numbers at
//! analytical cost. The ragged tails of non-divisor partitions are
//! reproduced by grouping the `(co, ci)` blocks into at most four
//! distinct `(m_eff, n_eff)` combinations.
//!
//! When an SRAM budget forces striping (`t < Ho`), the stripes' halo
//! rows are modeled as `rows_per_pass(t) - Hi` extra input rows per
//! `(co, ci)` pass (clamped at 0), carried as one extra read burst. Every
//! delta is non-negative, so an SRAM-constrained candidate can never
//! score better than its unconstrained counterpart — which is what makes
//! the explorer's channel-only bound admissible for pruning.

use crate::analytics::bandwidth::ControllerMode;
use crate::analytics::fusion;
use crate::analytics::grid::GridEngine;
use crate::analytics::partition::Partition;
use crate::analytics::spatial::{max_stripe_within, rows_per_pass};
use crate::models::{ConvLayer, Network};
use crate::sim::energy::EnergyModel;
use crate::sim::interconnect::{BusConfig, Interconnect};
use crate::sim::stats::SimStats;
use crate::util::mathx::ceil_div;

use super::budget::SramBudget;
use super::space::DesignPoint;

/// Ragged-tail block structure of a `ceil(total/size)` split:
/// `[(size, blocks - 1), (tail, 1)]` plus the block count — the
/// `(channels, occurrences)` representation [`layer_stats`] and
/// [`fused_chain_stats`] iterate over.
fn blocks(total: usize, size: usize) -> ([(u64, u64); 2], usize) {
    let n = ceil_div(total, size);
    let tail = total - (n - 1) * size;
    ([(size as u64, (n - 1) as u64), (tail as u64, 1u64)], n)
}

/// Exact counters for one layer tiled as `(m, n)` channels with output
/// stripes of height `t` (`t = Ho` means unstriped). `bus_cycles` and
/// `energy_pj` are left 0 — energy is priced once over a whole scope.
pub fn layer_stats(
    layer: &ConvLayer,
    m: usize,
    n: usize,
    t: usize,
    mode: ControllerMode,
    bus: &BusConfig,
) -> SimStats {
    let mg = layer.m_per_group();
    let ng = layer.n_per_group();
    let (wo, ho) = (layer.wo(), layer.ho());
    let k2 = (layer.k * layer.k) as u64;

    let (m_blocks, ci_blocks) = blocks(mg, m);
    let (n_blocks, co_blocks) = blocks(ng, n);

    let wi_hi = (layer.wi * layer.hi) as u64;
    let wo_ho = (wo * ho) as u64;
    let halo_rows = rows_per_pass(layer, t).saturating_sub(layer.hi) as u64;

    // Per-region element widths (None = the uniform elem_bytes pricing),
    // mirroring the scheduler's beat accounting exactly.
    let rb = bus.region_bits;
    let input_bits = rb.map(|r| r.input);
    let weight_bits = rb.map(|r| r.weight);
    let psum_bits = rb.map(|r| r.psum);
    let ofmap_bits = rb.map(|r| r.ofmap);

    let mut s = SimStats::default();

    // Input tiles: one burst of `m_eff` full planes per (co, ci), plus
    // one halo re-read burst when striping re-reads rows.
    for &(me, count) in &m_blocks {
        let occ = count * co_blocks as u64;
        let elems = wi_hi * me;
        s.input_reads += occ * elems;
        s.bus_beats += occ * Interconnect::beats_wide(bus, elems, input_bits);
        s.bus_transactions += occ * Interconnect::bursts_wide(bus, elems, input_bits);
        if halo_rows > 0 {
            let halo = layer.wi as u64 * halo_rows * me;
            s.input_reads += occ * halo;
            s.bus_beats += occ * Interconnect::beats_wide(bus, halo, input_bits);
            s.bus_transactions += occ * Interconnect::bursts_wide(bus, halo, input_bits);
        }
    }

    // Weight tiles: one burst of `n_eff * m_eff * K^2` per (co, ci).
    for &(ne, cn) in &n_blocks {
        for &(me, cm) in &m_blocks {
            let occ = cn * cm;
            let elems = ne * me * k2;
            s.weight_reads += occ * elems;
            s.bus_beats += occ * Interconnect::beats_wide(bus, elems, weight_bits);
            s.bus_transactions += occ * Interconnect::bursts_wide(bus, elems, weight_bits);
        }
    }

    // Psum protocol per co block: an Init write, then per later ci pass
    // either a bus read + write (passive) or one Add/AddRelu write whose
    // read stays inside the controller (active). The final write of each
    // chain carries the quantized ofmap (ofmap width); all other
    // crossings are psum width.
    for &(ne, cn) in &n_blocks {
        let elems = wo_ho * ne;
        let pbeats = Interconnect::beats_wide(bus, elems, psum_bits);
        let pbursts = Interconnect::bursts_wide(bus, elems, psum_bits);
        let obeats = Interconnect::beats_wide(bus, elems, ofmap_bits);
        let obursts = Interconnect::bursts_wide(bus, elems, ofmap_bits);
        let later = (ci_blocks - 1) as u64;
        s.psum_writes += cn * ci_blocks as u64 * elems;
        s.ofmap_writes += cn * elems;
        s.bus_beats += cn * (later * pbeats + obeats);
        s.bus_transactions += cn * (later * pbursts + obursts);
        match mode {
            ControllerMode::Passive => {
                // Only the Init write carries a sideband command (it is
                // the final, ofmap-width write when one pass suffices).
                s.sideband_words += cn * if ci_blocks == 1 { obursts } else { pbursts };
                s.psum_reads += cn * later * elems;
                s.bus_beats += cn * later * pbeats;
                s.bus_transactions += cn * later * pbursts;
            }
            ControllerMode::Active => {
                // Every write carries a command (Init, Add or AddRelu).
                s.sideband_words += cn * (later * pbursts + obursts);
                s.internal_psum_reads += cn * later * elems;
                s.controller_adds += cn * later * elems;
                if ci_blocks > 1 {
                    s.controller_relus += cn * elems;
                }
            }
        }
    }

    // Compute: work is conserved across partitions; each (co, ci) pass
    // sweeps the whole output plane.
    s.macs = wo_ho * k2 * mg as u64 * ng as u64;
    s.compute_cycles = (co_blocks * ci_blocks) as u64 * wo_ho;

    // SRAM array accesses: every bus element touches the array once; the
    // active controller's internal read-modify-write adds its reads (the
    // matching write is the bus write, already counted in psum_writes).
    s.sram_accesses =
        s.input_reads + s.weight_reads + s.psum_reads + s.psum_writes + s.internal_psum_reads;

    // Groups are identical accumulation domains (the simulator's
    // fast path): one group's counters times g.
    s.scale(layer.groups as u64);
    s
}

/// The stripe height for `layer` under `sram`: `Ho` when unconstrained,
/// otherwise the tallest stripe whose working set fits. `None` when even
/// a one-row stripe exceeds the budget (the candidate is infeasible).
pub fn stripe_height(layer: &ConvLayer, m: usize, n: usize, sram: SramBudget) -> Option<usize> {
    match sram {
        SramBudget::Unlimited => Some(layer.ho()),
        SramBudget::Elems(b) => max_stripe_within(layer, m, n, b).map(|(t, _)| t),
    }
}

/// The final-output stripe height for a fused `chain` under `sram`:
/// `Ho_d` (one stripe) when unconstrained, otherwise the tallest height
/// whose live chain working set
/// ([`crate::analytics::fusion::chain_working_set`]) fits every stripe.
/// `None` when even one-row stripes exceed the budget.
pub fn chain_stripe_height(
    chain: &[ConvLayer],
    parts: &[Partition],
    sram: SramBudget,
) -> Option<usize> {
    match sram {
        SramBudget::Unlimited => Some(chain.last().expect("empty chain").ho()),
        SramBudget::Elems(b) => fusion::max_chain_stripe(chain, parts, b),
    }
}

/// Exact counters for one fused chain partitioned per layer as `parts`,
/// processed in final-output stripes of height `t`.
///
/// First-order fusion contract (see [`crate::analytics::fusion`]): the
/// interconnect carries only the chain input (per stripe, with halo and
/// the first layer's `co`-block re-reads), every layer's weight tiles
/// *once per stripe*, and the last layer's psum protocol; intermediates
/// stay in on-chip buffers and are charged to feasibility
/// ([`chain_stripe_height`]), not to traffic. Compute is conserved, so
/// MAC utilization matches the unfused candidate. Striping only adds
/// traffic (halo rows, weight reloads, burst splits), which keeps the
/// explorer's unlimited-SRAM bound admissible at every fusion depth.
pub fn fused_chain_stats(
    chain: &[ConvLayer],
    parts: &[Partition],
    t: usize,
    mode: ControllerMode,
    bus: &BusConfig,
) -> SimStats {
    assert_eq!(chain.len(), parts.len());
    let d = chain.len();
    let first = &chain[0];
    let last = &chain[d - 1];
    let ho = last.ho();
    let mut s = SimStats::default();

    let rb = bus.region_bits;
    let input_bits = rb.map(|r| r.input);
    let weight_bits = rb.map(|r| r.weight);
    let psum_bits = rb.map(|r| r.psum);
    let ofmap_bits = rb.map(|r| r.ofmap);

    let (m_blocks_1, _) = blocks(first.m_per_group(), parts[0].m);
    let co_1 = ceil_div(first.n_per_group(), parts[0].n) as u64;
    let g1 = first.groups as u64;
    let (n_blocks_d, ci_d) = {
        let (nb, _) = blocks(last.n_per_group(), parts[d - 1].n);
        (nb, ceil_div(last.m_per_group(), parts[d - 1].m) as u64)
    };
    let gd = last.groups as u64;

    for stripe in 0..ho.div_ceil(t) {
        let y0 = stripe * t;
        let y1 = (y0 + t - 1).min(ho - 1);
        let spans = fusion::stripe_spans(chain, y0, y1);

        // Chain input: one burst of `m_eff` planes of the stripe's rows
        // per (co, ci) of the first layer.
        let in_rows = fusion::span_rows(spans[0]) as u64;
        for &(me, count) in &m_blocks_1 {
            let occ = count * co_1 * g1;
            let elems = first.wi as u64 * in_rows * me;
            s.input_reads += occ * elems;
            s.bus_beats += occ * Interconnect::beats_wide(bus, elems, input_bits);
            s.bus_transactions += occ * Interconnect::bursts_wide(bus, elems, input_bits);
        }

        // Weight reloads: every stripe sweeps every (co, ci) tile of
        // every layer in the chain.
        for (l, p) in chain.iter().zip(parts) {
            let (mb, _) = blocks(l.m_per_group(), p.m);
            let (nb, _) = blocks(l.n_per_group(), p.n);
            let k2 = (l.k * l.k) as u64;
            let gi = l.groups as u64;
            for &(ne, cn) in &nb {
                for &(me, cm) in &mb {
                    let occ = cn * cm * gi;
                    let elems = ne * me * k2;
                    s.weight_reads += occ * elems;
                    s.bus_beats += occ * Interconnect::beats_wide(bus, elems, weight_bits);
                    s.bus_transactions += occ * Interconnect::bursts_wide(bus, elems, weight_bits);
                }
            }
        }

        // Last layer's psum protocol, per stripe (total elements are
        // stripe-invariant; beats/bursts split per stripe). The final
        // write per chain is the quantized ofmap stripe.
        let t_eff = (y1 - y0 + 1) as u64;
        for &(ne, cn) in &n_blocks_d {
            let cn = cn * gd;
            let elems = last.wo() as u64 * t_eff * ne;
            let pbeats = Interconnect::beats_wide(bus, elems, psum_bits);
            let pbursts = Interconnect::bursts_wide(bus, elems, psum_bits);
            let obeats = Interconnect::beats_wide(bus, elems, ofmap_bits);
            let obursts = Interconnect::bursts_wide(bus, elems, ofmap_bits);
            let later = ci_d - 1;
            s.psum_writes += cn * ci_d * elems;
            s.ofmap_writes += cn * elems;
            s.bus_beats += cn * (later * pbeats + obeats);
            s.bus_transactions += cn * (later * pbursts + obursts);
            match mode {
                ControllerMode::Passive => {
                    s.sideband_words += cn * if ci_d == 1 { obursts } else { pbursts };
                    s.psum_reads += cn * later * elems;
                    s.bus_beats += cn * later * pbeats;
                    s.bus_transactions += cn * later * pbursts;
                }
                ControllerMode::Active => {
                    s.sideband_words += cn * (later * pbursts + obursts);
                    s.internal_psum_reads += cn * later * elems;
                    s.controller_adds += cn * later * elems;
                    if ci_d > 1 {
                        s.controller_relus += cn * elems;
                    }
                }
            }
        }
    }

    // Compute is conserved across fusion: each layer still sweeps its
    // whole output plane over its (co, ci) blocks.
    for (l, p) in chain.iter().zip(parts) {
        let wo_ho = (l.wo() * l.ho()) as u64;
        let gi = l.groups as u64;
        s.macs += wo_ho
            * (l.k * l.k) as u64
            * l.m_per_group() as u64
            * l.n_per_group() as u64
            * gi;
        let passes = (ceil_div(l.m_per_group(), p.m) * ceil_div(l.n_per_group(), p.n)) as u64;
        s.compute_cycles += passes * wo_ho * gi;
    }

    s.sram_accesses =
        s.input_reads + s.weight_reads + s.psum_reads + s.psum_writes + s.internal_psum_reads;
    s
}

/// Evaluate one candidate over a scope (one network, or several for the
/// whole-zoo aggregate): the network splits into fusion chains of up to
/// `point.fusion` layers ([`crate::analytics::fusion::chains`]);
/// partitions come from the grid engine's layer-shape memo cache,
/// counters from [`layer_stats`] (singleton chains — exactly the
/// pre-fusion path) or [`fused_chain_stats`] (longer chains), energy
/// from [`crate::sim::energy::EnergyModel`] priced once over the merged
/// counters. `None` when any layer or chain cannot fit the SRAM budget.
pub fn scope_stats(
    engine: &GridEngine,
    nets: &[&Network],
    point: &DesignPoint,
    bus: &BusConfig,
) -> Option<SimStats> {
    // The bus carries the precision: region widths select byte-weighted
    // partitions (for the optimizing strategies) and width-scaled energy.
    let dt = bus.region_bits.map(|rb| rb.to_datatypes()).unwrap_or_default();
    let mut total = SimStats::default();
    for net in nets {
        for range in fusion::chains(net, point.fusion) {
            let chain = &net.layers[range];
            if chain.len() == 1 {
                let layer = &chain[0];
                let eval =
                    engine.layer_eval_dt(layer, point.p_macs, point.strategy, point.mode, &dt);
                let (m, n) = (eval.partition.m, eval.partition.n);
                let t = stripe_height(layer, m, n, point.sram)?;
                total.merge(&layer_stats(layer, m, n, t, point.mode, bus));
            } else {
                let parts: Vec<Partition> = chain
                    .iter()
                    .map(|l| {
                        engine
                            .layer_eval_dt(l, point.p_macs, point.strategy, point.mode, &dt)
                            .partition
                    })
                    .collect();
                let t = chain_stripe_height(chain, &parts, point.sram)?;
                total.merge(&fused_chain_stats(chain, &parts, t, point.mode, bus));
            }
        }
    }
    total.energy_pj = match &bus.region_bits {
        Some(rb) => EnergyModel::default().energy_pj_wide(&total, rb),
        None => EnergyModel::default().energy_pj(&total),
    };
    Some(total)
}

/// The candidate's admissible lower bound: the same evaluation (at the
/// same fusion depth) with the SRAM constraint lifted — channel-only
/// eqs. 2–3 traffic, no halo, single-stripe chains with one weight load.
/// Component-wise `bound <= exact`, and utilization is identical, so a
/// candidate whose bound is dominated by an exactly-evaluated design is
/// provably dominated itself.
pub fn scope_bound_stats(
    engine: &GridEngine,
    nets: &[&Network],
    point: &DesignPoint,
    bus: &BusConfig,
) -> SimStats {
    let unconstrained = DesignPoint { sram: SramBudget::Unlimited, ..*point };
    scope_stats(engine, nets, &unconstrained, bus).expect("unstriped evaluation always feasible")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::bandwidth::layer_bandwidth;
    use crate::analytics::partition::{Partition, Strategy};
    use crate::models::zoo;
    use crate::sim::scheduler::{simulate_layer_with, SimConfig};

    fn assert_matches_sim(layer: &ConvLayer, part: Partition, mode: ControllerMode, p: usize) {
        let cfg = SimConfig::new(p, mode, Strategy::Optimal);
        let mut sim = simulate_layer_with(layer, &cfg, part).stats;
        // Out of the closed form's scope: per-layer time/energy roll-ups.
        sim.bus_cycles = 0;
        sim.energy_pj = 0.0;
        let dse = layer_stats(layer, part.m, part.n, layer.ho(), mode, &cfg.bus);
        assert_eq!(dse, sim, "{} {:?} P={p} {:?}", layer.name, part, mode);
    }

    #[test]
    fn unstriped_counters_equal_simulator() {
        let conv3 = ConvLayer::new("conv3", 13, 13, 192, 384, 3, 1, 1);
        for mode in ControllerMode::ALL {
            // divisor partition, ragged partition, single-pass partition
            assert_matches_sim(&conv3, Partition { m: 12, n: 4 }, mode, 512);
            assert_matches_sim(&conv3, Partition { m: 9, n: 7 }, mode, 1 << 20);
            assert_matches_sim(&conv3, Partition { m: 192, n: 384 }, mode, 1 << 22);
        }
        // grouped conv exercises the g-scaling path
        let dw = ConvLayer::grouped("dw", 56, 56, 64, 64, 3, 1, 1, 64);
        for mode in ControllerMode::ALL {
            assert_matches_sim(&dw, Partition { m: 1, n: 1 }, mode, 512);
        }
    }

    #[test]
    fn unstriped_bandwidth_equals_eq2_eq3() {
        let l = ConvLayer::new("c", 27, 27, 64, 192, 5, 1, 2);
        for mode in ControllerMode::ALL {
            let s = layer_stats(&l, 16, 4, l.ho(), mode, &BusConfig::default());
            let bw = layer_bandwidth(&l, 16, 4, mode);
            assert_eq!(s.input_reads as f64, bw.input);
            assert_eq!((s.psum_reads + s.psum_writes) as f64, bw.output);
        }
    }

    #[test]
    fn striping_only_adds() {
        let l = ConvLayer::new("c", 56, 56, 64, 128, 3, 1, 1);
        let bus = BusConfig::default();
        let free = layer_stats(&l, 16, 8, l.ho(), ControllerMode::Passive, &bus);
        let mut prev = free;
        for t in [28usize, 7, 1] {
            let tight = layer_stats(&l, 16, 8, t, ControllerMode::Passive, &bus);
            assert!(tight.input_reads >= prev.input_reads, "t={t}");
            assert!(tight.bus_beats >= prev.bus_beats, "t={t}");
            assert!(tight.sram_accesses >= prev.sram_accesses, "t={t}");
            // psum/compute sides are stripe-invariant
            assert_eq!(tight.psum_writes, free.psum_writes);
            assert_eq!(tight.compute_cycles, free.compute_cycles);
            prev = tight;
        }
    }

    #[test]
    fn scope_bound_is_admissible() {
        let net = zoo::alexnet();
        let engine = GridEngine::new();
        let bus = BusConfig::default();
        let nets = [&net];
        for mode in ControllerMode::ALL {
            let point = DesignPoint {
                p_macs: 1024,
                sram: SramBudget::Elems(1 << 16),
                strategy: Strategy::Optimal,
                mode,
                fusion: 1,
            };
            let bound = scope_bound_stats(&engine, &nets, &point, &bus);
            let exact = scope_stats(&engine, &nets, &point, &bus).expect("feasible");
            assert!(bound.activation_traffic() <= exact.activation_traffic());
            assert!(bound.sram_accesses <= exact.sram_accesses);
            assert!(bound.energy_pj <= exact.energy_pj);
            assert_eq!(bound.compute_cycles, exact.compute_cycles);
            assert_eq!(bound.macs, exact.macs);
        }
    }

    #[test]
    fn infeasible_budget_reports_none() {
        let net = zoo::alexnet();
        let engine = GridEngine::new();
        let point = DesignPoint {
            p_macs: 1024,
            sram: SramBudget::Elems(16),
            strategy: Strategy::Optimal,
            mode: ControllerMode::Passive,
            fusion: 1,
        };
        assert!(scope_stats(&engine, &[&net], &point, &BusConfig::default()).is_none());
    }

    #[test]
    fn fused_scope_cuts_activation_traffic() {
        let net = zoo::alexnet();
        let engine = GridEngine::new();
        let bus = BusConfig::default();
        for mode in ControllerMode::ALL {
            let base = DesignPoint {
                p_macs: 1024,
                sram: SramBudget::Unlimited,
                strategy: Strategy::Optimal,
                mode,
                fusion: 1,
            };
            let fused = DesignPoint { fusion: 2, ..base };
            let u = scope_stats(&engine, &[&net], &base, &bus).unwrap();
            let f = scope_stats(&engine, &[&net], &fused, &bus).unwrap();
            // the conv3->conv4 intermediate never crosses the bus
            assert!(f.activation_traffic() < u.activation_traffic());
            // unstriped: weights still load exactly once
            assert_eq!(f.weight_reads, u.weight_reads);
            // compute conserved -> identical utilization
            assert_eq!(f.compute_cycles, u.compute_cycles);
            assert_eq!(f.macs, u.macs);
        }
    }

    #[test]
    fn fused_bound_is_admissible_under_sram_pressure() {
        let net = zoo::alexnet();
        let engine = GridEngine::new();
        let bus = BusConfig::default();
        for sram in [SramBudget::Elems(1 << 16), SramBudget::Elems(1 << 14)] {
            let point = DesignPoint {
                p_macs: 1024,
                sram,
                strategy: Strategy::Optimal,
                mode: ControllerMode::Active,
                fusion: 3,
            };
            let bound = scope_bound_stats(&engine, &[&net], &point, &bus);
            let Some(exact) = scope_stats(&engine, &[&net], &point, &bus) else {
                continue; // infeasible at this budget: nothing to bound
            };
            assert!(bound.activation_traffic() <= exact.activation_traffic());
            assert!(bound.weight_reads <= exact.weight_reads);
            assert!(bound.sram_accesses <= exact.sram_accesses);
            assert!(bound.bus_beats <= exact.bus_beats);
            assert!(bound.energy_pj <= exact.energy_pj);
            assert_eq!(bound.compute_cycles, exact.compute_cycles);
        }
    }

    #[test]
    fn fused_chain_stats_matches_chain_bandwidth() {
        // The SimStats closed form and the analytics-level FusedBandwidth
        // agree on every traffic component, striped or not.
        let chain = [
            ConvLayer::new("a", 13, 13, 192, 384, 3, 1, 1),
            ConvLayer::new("b", 13, 13, 384, 256, 3, 1, 1),
        ];
        let parts = [Partition { m: 48, n: 4 }, Partition { m: 48, n: 4 }];
        let bus = BusConfig::default();
        for t in [13usize, 5, 1] {
            for mode in ControllerMode::ALL {
                let s = fused_chain_stats(&chain, &parts, t, mode, &bus);
                let bw = fusion::chain_bandwidth(&chain, &parts, t, mode);
                assert_eq!(s.input_reads as f64, bw.input, "t={t} {mode:?}");
                assert_eq!((s.psum_reads + s.psum_writes) as f64, bw.output, "t={t} {mode:?}");
                assert_eq!(s.weight_reads as f64, bw.weights, "t={t} {mode:?}");
            }
        }
    }
}
