//! Hardware budgets beyond the MAC count: the on-chip SRAM capacity axis
//! and the textual constraint grammar shared by `psim explore
//! --constraints` and the serve protocol's `{"cmd":"explore"}` request.
//!
//! SRAM capacity is measured in *elements* (the unit of the whole
//! bandwidth model — bytes divide out everywhere). A budget constrains
//! each layer's resident working set (input stripe + psum stripe + weight
//! tile, [`crate::analytics::spatial::stripe_working_set`]); the explorer
//! picks the tallest output stripe that fits and pays the halo re-reads.

use anyhow::{anyhow, bail, Result};

use crate::config::accel::{parse_mode, parse_strategy};

use super::space::ExploreSpec;

/// On-chip SRAM capacity, in elements.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SramBudget {
    /// No capacity constraint: every layer runs unstriped (`t = Ho`).
    Unlimited,
    /// At most this many resident elements per layer working set.
    Elems(u64),
}

impl SramBudget {
    /// Stable textual form — also the JSONL `sram` field value and the
    /// token [`parse_sram`] accepts back.
    pub fn label(&self) -> String {
        match self {
            SramBudget::Unlimited => "unlimited".to_string(),
            SramBudget::Elems(e) => e.to_string(),
        }
    }

    /// The element cap, `None` when unconstrained.
    pub fn elems(&self) -> Option<u64> {
        match self {
            SramBudget::Unlimited => None,
            SramBudget::Elems(e) => Some(*e),
        }
    }
}

/// Default SRAM axis: unconstrained, plus three capacities bracketing
/// realistic on-chip buffers (at 2 B/element, 64Ki elements = 128 KiB).
pub const DEFAULT_SRAM_BUDGETS: [SramBudget; 4] = [
    SramBudget::Unlimited,
    SramBudget::Elems(1 << 20),
    SramBudget::Elems(1 << 18),
    SramBudget::Elems(1 << 16),
];

/// Parse one SRAM budget token: `unlimited` (or `inf`/`none`), or an
/// element count with an optional binary suffix (`64k`, `1m`, `2g`).
pub fn parse_sram(s: &str) -> Result<SramBudget> {
    let t = s.trim().to_ascii_lowercase();
    if matches!(t.as_str(), "unlimited" | "inf" | "none") {
        return Ok(SramBudget::Unlimited);
    }
    let (digits, mult): (&str, u64) = if let Some(p) = t.strip_suffix('k') {
        (p, 1 << 10)
    } else if let Some(p) = t.strip_suffix('m') {
        (p, 1 << 20)
    } else if let Some(p) = t.strip_suffix('g') {
        (p, 1 << 30)
    } else {
        (t.as_str(), 1)
    };
    let n: u64 = digits
        .trim()
        .parse()
        .map_err(|_| anyhow!("bad SRAM budget '{s}' (elements, e.g. 65536, 64k or 'unlimited')"))?;
    if n == 0 {
        bail!("SRAM budget must be > 0 elements (use 'unlimited' for no cap)");
    }
    let elems = n.checked_mul(mult).ok_or_else(|| anyhow!("SRAM budget '{s}' overflows u64"))?;
    Ok(SramBudget::Elems(elems))
}

/// Apply a `--constraints` string onto a spec.
///
/// Grammar: comma-separated `axis=v1:v2:...` pairs; axes are `macs`,
/// `sram`, `strategies`, `modes`, `fusion`. Example:
/// `macs=512:2048:16384,sram=64k:unlimited,modes=active,fusion=1:2`.
/// Axes not mentioned keep their defaults; unknown axes fail loudly.
pub fn apply_constraints(spec: &mut ExploreSpec, text: &str) -> Result<()> {
    for part in text.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (axis, values) = part
            .split_once('=')
            .ok_or_else(|| anyhow!("constraint '{part}' is not of the form axis=v1:v2:..."))?;
        let values: Vec<&str> =
            values.split(':').map(str::trim).filter(|v| !v.is_empty()).collect();
        if values.is_empty() {
            bail!("constraint '{part}' has no values");
        }
        match axis.trim().to_ascii_lowercase().as_str() {
            "macs" => {
                spec.mac_budgets = values
                    .iter()
                    .map(|v| v.parse::<usize>().map_err(|_| anyhow!("bad MAC budget '{v}'")))
                    .collect::<Result<Vec<_>>>()?;
            }
            "sram" => {
                spec.sram_budgets =
                    values.iter().map(|v| parse_sram(v)).collect::<Result<Vec<_>>>()?;
            }
            "strategies" => {
                spec.strategies =
                    values.iter().map(|v| parse_strategy(v)).collect::<Result<Vec<_>>>()?;
            }
            "modes" => {
                spec.modes = values.iter().map(|v| parse_mode(v)).collect::<Result<Vec<_>>>()?;
            }
            "fusion" => {
                spec.fusion_depths = values
                    .iter()
                    .map(|v| match v.parse::<usize>() {
                        Ok(d) if d >= 1 => Ok(d),
                        _ => Err(anyhow!("bad fusion depth '{v}' (positive integer)")),
                    })
                    .collect::<Result<Vec<_>>>()?;
            }
            other => bail!("unknown constraint axis '{other}' (macs|sram|strategies|modes|fusion)"),
        }
    }
    spec.validate()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::bandwidth::ControllerMode;
    use crate::analytics::partition::Strategy;
    use crate::models::zoo;

    #[test]
    fn parse_sram_tokens() {
        assert_eq!(parse_sram("unlimited").unwrap(), SramBudget::Unlimited);
        assert_eq!(parse_sram("inf").unwrap(), SramBudget::Unlimited);
        assert_eq!(parse_sram("65536").unwrap(), SramBudget::Elems(65536));
        assert_eq!(parse_sram("64k").unwrap(), SramBudget::Elems(65536));
        assert_eq!(parse_sram("1m").unwrap(), SramBudget::Elems(1 << 20));
        assert_eq!(parse_sram(" 2G ").unwrap(), SramBudget::Elems(2 << 30));
        assert!(parse_sram("0").is_err());
        assert!(parse_sram("lots").is_err());
        assert!(parse_sram("").is_err());
    }

    #[test]
    fn labels_round_trip() {
        for b in DEFAULT_SRAM_BUDGETS {
            assert_eq!(parse_sram(&b.label()).unwrap(), b);
        }
    }

    #[test]
    fn constraints_override_axes() {
        let mut spec = ExploreSpec::new(vec![zoo::alexnet()]);
        apply_constraints(&mut spec, "macs=512:2048,sram=64k:unlimited,modes=active,fusion=1:2")
            .unwrap();
        assert_eq!(spec.mac_budgets, vec![512, 2048]);
        assert_eq!(spec.sram_budgets, vec![SramBudget::Elems(65536), SramBudget::Unlimited]);
        assert_eq!(spec.modes, vec![ControllerMode::Active]);
        assert_eq!(spec.fusion_depths, vec![1, 2]);
        // strategies untouched
        assert_eq!(spec.strategies, Strategy::TABLE1.to_vec());
    }

    #[test]
    fn constraints_reject_garbage() {
        let mut spec = ExploreSpec::new(vec![zoo::alexnet()]);
        assert!(apply_constraints(&mut spec, "volts=3").is_err());
        assert!(apply_constraints(&mut spec, "macs").is_err());
        assert!(apply_constraints(&mut spec, "macs=").is_err());
        assert!(apply_constraints(&mut spec, "macs=zero").is_err());
        assert!(apply_constraints(&mut spec, "strategies=voodoo").is_err());
        assert!(apply_constraints(&mut spec, "macs=0").is_err());
        assert!(apply_constraints(&mut spec, "fusion=0").is_err());
        assert!(apply_constraints(&mut spec, "fusion=deep").is_err());
    }
}
