//! Design-space exploration (DSE): Pareto frontiers over hardware
//! budgets × partitioning policy.
//!
//! The paper evaluates a fixed grid — eight networks × six MAC budgets ×
//! four strategies × two controller modes — and reports single-objective
//! bandwidth tables. This subsystem *searches* a richer space instead:
//! MAC budget × on-chip SRAM capacity × partitioning strategy ×
//! controller mode × inter-layer fusion depth (per-layer `(m, n)` tiles
//! and stripe heights chosen within each point — fused chains via
//! [`crate::analytics::fusion`]), scoring every candidate on four objectives at
//! once — interconnect bandwidth, SRAM array accesses, energy
//! ([`crate::sim::energy`]) and MAC utilization — and keeping only the
//! Pareto-optimal designs, per network and for the whole zoo.
//!
//! * [`space`] — [`DesignPoint`]/[`ExploreSpec`]: the axes, their
//!   deterministic enumeration, the serve-protocol parser.
//! * [`budget`] — the SRAM capacity axis ([`SramBudget`]) and the
//!   `--constraints` grammar.
//! * [`pareto`] — objective vectors, dominance, frontier extraction.
//! * [`metrics`] — closed-form [`crate::sim::stats::SimStats`] for a
//!   candidate: simulator-exact unstriped, conservative halo model when
//!   SRAM-striped.
//! * [`explore`] — bound → prune → exact → frontier over
//!   [`crate::coordinator::parallel`] workers, byte-deterministic.
//!
//! Surfaces: `psim explore` (CLI), `{"cmd":"explore"}` (serve),
//! [`crate::report::frontier`] (rendering), `benches/bench_dse.rs`.

pub mod budget;
pub mod explore;
pub mod metrics;
pub mod pareto;
pub mod space;

pub use budget::SramBudget;
pub use explore::{explore, ExploreResult, FrontierPoint, ZOO_SCOPE};
pub use pareto::{Objective, Objectives};
pub use space::{DesignPoint, ExploreSpec};
