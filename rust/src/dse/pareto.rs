//! Objective vectors and Pareto dominance for the design-space explorer.
//!
//! Four objectives, three minimized (interconnect bandwidth, SRAM array
//! accesses, energy) and one maximized (MAC-array utilization). Dominance
//! and frontier extraction work over any non-empty subset of them — the
//! `--objectives` knob.

use anyhow::{anyhow, bail, Result};

use crate::models::DataTypes;
use crate::sim::stats::SimStats;

/// One optimization objective.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Objective {
    /// Activation traffic over the interconnect, elements (minimize).
    Bandwidth,
    /// Activation traffic over the interconnect in **bytes** under the
    /// spec's [`DataTypes`] precision (minimize). Equal to
    /// [`Objective::Bandwidth`] under the default uniform one-byte
    /// precision; with wide psums it re-ranks candidates toward designs
    /// that avoid psum round-trips.
    BandwidthBytes,
    /// SRAM array accesses, including controller-internal ones (minimize).
    SramAccesses,
    /// Energy estimate from [`crate::sim::energy`] (minimize).
    Energy,
    /// MAC-array utilization (maximize).
    Utilization,
}

impl Objective {
    /// The default objective mask (element bandwidth, SRAM accesses,
    /// energy, utilization). [`Objective::BandwidthBytes`] is opt-in via
    /// `--objectives` so default frontiers stay byte-identical.
    pub const ALL: [Objective; 4] = [
        Objective::Bandwidth,
        Objective::SramAccesses,
        Objective::Energy,
        Objective::Utilization,
    ];

    /// Stable wire/CLI token, accepted back by [`parse_objective`].
    pub fn label(&self) -> &'static str {
        match self {
            Objective::Bandwidth => "bandwidth",
            Objective::BandwidthBytes => "bandwidth-bytes",
            Objective::SramAccesses => "sram-accesses",
            Objective::Energy => "energy",
            Objective::Utilization => "utilization",
        }
    }
}

/// Parse one objective name (punctuation-insensitive, common aliases).
pub fn parse_objective(s: &str) -> Result<Objective> {
    match s.trim().to_ascii_lowercase().replace(['-', '_'], "").as_str() {
        "bandwidth" | "bw" => Ok(Objective::Bandwidth),
        "bandwidthbytes" | "bytes" | "bwbytes" => Ok(Objective::BandwidthBytes),
        "sramaccesses" | "sram" | "accesses" => Ok(Objective::SramAccesses),
        "energy" => Ok(Objective::Energy),
        "utilization" | "util" | "macutilization" => Ok(Objective::Utilization),
        other => bail!(
            "unknown objective '{other}' \
             (bandwidth|bandwidth-bytes|sram-accesses|energy|utilization)"
        ),
    }
}

/// Parse a comma-separated objective list; duplicates collapse, order is
/// kept.
pub fn parse_objectives(list: &str) -> Result<Vec<Objective>> {
    let mut out: Vec<Objective> = Vec::new();
    for part in list.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let o = parse_objective(part)?;
        if !out.contains(&o) {
            out.push(o);
        }
    }
    if out.is_empty() {
        return Err(anyhow!("objective list '{list}' is empty"));
    }
    Ok(out)
}

/// The explorer's objective vector for one candidate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Objectives {
    /// Activation traffic over the interconnect (elements).
    pub bandwidth: f64,
    /// Activation traffic in bytes under the exploration's precision
    /// (equals `bandwidth` under the default precision).
    pub bandwidth_bytes: f64,
    /// SRAM array accesses (elements).
    pub sram_accesses: f64,
    /// Energy estimate (picojoules).
    pub energy_pj: f64,
    /// MAC-array utilization in [0, 1].
    pub mac_utilization: f64,
}

impl Objectives {
    /// Derive the vector from simulated-or-derived counters at the
    /// default (uniform one-byte) precision.
    pub fn from_stats(stats: &SimStats, p_macs: usize) -> Objectives {
        Objectives::from_stats_dt(stats, p_macs, &DataTypes::default())
    }

    /// Derive the vector from counters, pricing bytes under `dt`.
    pub fn from_stats_dt(stats: &SimStats, p_macs: usize, dt: &DataTypes) -> Objectives {
        Objectives {
            bandwidth: stats.activation_traffic() as f64,
            bandwidth_bytes: stats.activation_bytes(dt),
            sram_accesses: stats.sram_accesses as f64,
            energy_pj: stats.energy_pj,
            mac_utilization: stats.mac_utilization(p_macs),
        }
    }

    /// The objective's value under minimization (utilization negated, so
    /// "smaller is better" holds uniformly).
    pub fn min_value(&self, o: Objective) -> f64 {
        match o {
            Objective::Bandwidth => self.bandwidth,
            Objective::BandwidthBytes => self.bandwidth_bytes,
            Objective::SramAccesses => self.sram_accesses,
            Objective::Energy => self.energy_pj,
            Objective::Utilization => -self.mac_utilization,
        }
    }
}

/// `a` dominates `b` over `objectives`: no objective worse, at least one
/// strictly better. Equal vectors dominate neither way.
pub fn dominates(a: &Objectives, b: &Objectives, objectives: &[Objective]) -> bool {
    let mut strictly = false;
    for &o in objectives {
        let (va, vb) = (a.min_value(o), b.min_value(o));
        if va > vb {
            return false;
        }
        if va < vb {
            strictly = true;
        }
    }
    strictly
}

/// Indices of the non-dominated points, preserving input order (the
/// explorer's determinism contract rides on this).
pub fn pareto_indices(points: &[Objectives], objectives: &[Objective]) -> Vec<usize> {
    (0..points.len())
        .filter(|&i| {
            !points
                .iter()
                .enumerate()
                .any(|(j, p)| j != i && dominates(p, &points[i], objectives))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obj(bw: f64, sram: f64, e: f64, util: f64) -> Objectives {
        Objectives {
            bandwidth: bw,
            bandwidth_bytes: bw,
            sram_accesses: sram,
            energy_pj: e,
            mac_utilization: util,
        }
    }

    #[test]
    fn dominance_basics() {
        let all = &Objective::ALL[..];
        let a = obj(1.0, 1.0, 1.0, 0.9);
        let b = obj(2.0, 1.0, 1.0, 0.9);
        assert!(dominates(&a, &b, all));
        assert!(!dominates(&b, &a, all));
        // equal vectors: neither dominates
        assert!(!dominates(&a, &a, all));
        // utilization is maximized
        let c = obj(1.0, 1.0, 1.0, 0.5);
        assert!(dominates(&a, &c, all));
        // trade-off: incomparable
        let d = obj(0.5, 9.0, 1.0, 0.9);
        assert!(!dominates(&a, &d, all) && !dominates(&d, &a, all));
    }

    #[test]
    fn objective_mask_changes_dominance() {
        let a = obj(1.0, 9.0, 1.0, 0.9);
        let b = obj(2.0, 1.0, 1.0, 0.9);
        assert!(!dominates(&a, &b, &Objective::ALL));
        assert!(dominates(&a, &b, &[Objective::Bandwidth]));
        assert!(dominates(&b, &a, &[Objective::SramAccesses]));
    }

    #[test]
    fn frontier_keeps_nondominated_in_order() {
        let pts = vec![
            obj(3.0, 3.0, 3.0, 0.5), // dominated by the next two
            obj(1.0, 2.0, 2.0, 0.5),
            obj(2.0, 1.0, 1.0, 0.5),
            obj(1.0, 2.0, 2.0, 0.5), // duplicate of [1]: kept (no strict win)
        ];
        assert_eq!(pareto_indices(&pts, &Objective::ALL), vec![1, 2, 3]);
        assert!(pareto_indices(&[], &Objective::ALL).is_empty());
    }

    #[test]
    fn parse_objective_aliases() {
        assert_eq!(parse_objective("BW").unwrap(), Objective::Bandwidth);
        assert_eq!(parse_objective("sram-accesses").unwrap(), Objective::SramAccesses);
        assert_eq!(parse_objective("mac_utilization").unwrap(), Objective::Utilization);
        assert_eq!(parse_objective("bandwidth-bytes").unwrap(), Objective::BandwidthBytes);
        assert_eq!(parse_objective("bytes").unwrap(), Objective::BandwidthBytes);
        assert!(parse_objective("latency").is_err());
        let list = parse_objectives("bandwidth, energy,bw").unwrap();
        assert_eq!(list, vec![Objective::Bandwidth, Objective::Energy]);
        assert!(parse_objectives(" , ").is_err());
        // round-trip every label, including the bytes objective
        for o in Objective::ALL.iter().chain([Objective::BandwidthBytes].iter()) {
            assert_eq!(parse_objective(o.label()).unwrap(), *o);
        }
    }

    #[test]
    fn bytes_objective_reranks_under_wide_psums() {
        use crate::models::DataTypes;
        let dt = DataTypes::parse("8:8:32:8").unwrap();
        // a: fewer elements (psum-heavy); b: fewer bytes (psum-light).
        // a: 90 elements = 330 bytes; b: 95 elements = 125 bytes.
        let a = SimStats { input_reads: 10, psum_reads: 40, psum_writes: 40, ..Default::default() };
        let b = SimStats { input_reads: 85, psum_reads: 5, psum_writes: 5, ..Default::default() };
        let oa = Objectives::from_stats_dt(&a, 512, &dt);
        let ob = Objectives::from_stats_dt(&b, 512, &dt);
        assert!(oa.bandwidth < ob.bandwidth, "a wins in elements");
        assert!(ob.bandwidth_bytes < oa.bandwidth_bytes, "b wins in bytes");
        assert!(dominates(&oa, &ob, &[Objective::Bandwidth]));
        assert!(dominates(&ob, &oa, &[Objective::BandwidthBytes]));
    }

    #[test]
    fn from_stats_maps_counters() {
        let s = SimStats {
            input_reads: 70,
            psum_reads: 10,
            psum_writes: 20,
            sram_accesses: 200,
            energy_pj: 1234.5,
            macs: 512 * 50,
            compute_cycles: 100,
            ..Default::default()
        };
        let o = Objectives::from_stats(&s, 512);
        assert_eq!(o.bandwidth, 100.0);
        assert_eq!(o.sram_accesses, 200.0);
        assert_eq!(o.energy_pj, 1234.5);
        assert!((o.mac_utilization - 0.5).abs() < 1e-12);
    }
}
