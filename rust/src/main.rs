//! `psim` — the command-line launcher.
//!
//! See `psim help` for the command surface; each paper table/figure has a
//! dedicated subcommand (`table1`, `table2`, `table3`, `fig2`), plus the
//! simulator (`simulate`), the analytical explorer (`analyze`, `sweep`),
//! model validation against the published numbers (`validate`), and the
//! functional inference paths (`infer`, `serve`).

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match psim::cli::run(&args) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("psim: error: {e:#}");
            std::process::exit(1);
        }
    }
}
